"""Mixture-of-Experts FFN with capacity-based token dispatch.

Sort-based routing (MegaBlocks-style, but with fixed expert capacity so all
shapes are static): tokens pick top-k experts; within each expert, tokens are
ranked by a stable sort and those beyond capacity C = ceil(T/E * cf) are
dropped (standard for large-scale MoE).  Dispatch/combine are scatter/gathers;
the expert computation is a single [E, C, d] x [E, d, f] einsum whose E axis
shards over the 'model' mesh axis (expert parallelism) — XLA inserts the
all-to-alls at the sharding boundary.

llama4-maverick: 128 experts, top-1.  mixtral-8x7b: 8 experts, top-2 (E < TP
width, so experts shard over d_ff instead; see configs)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.common import ModelConfig


def moe_ffn(x, router_w, w_gate, w_up, w_down, cfg: ModelConfig,
            capacity: int | None = None, dropless: bool = False):
    """x: [b, s, d].  router_w: [d, E].  experts: [E, d, f] / [E, f, d].

    `dropless=True` sizes capacity at the worst case (C = T) so no token is
    ever dropped — used for decode (serving must be exact) and for
    correctness tests.  Training uses capacity-factor dispatch.

    cfg.moe_groups > 1 splits the token axis into G independent dispatch
    groups (vmap), each with its own capacity C/G.  Groups shard with the
    batch over the mesh's data axes, so routing sort / rank / scatter stay
    DEVICE-LOCAL and the dispatch buffer is batch-sharded instead of
    replicated — this removed a per-layer all-reduce of the full [E*C, d]
    buffer (EXPERIMENTS.md §Perf it-B1).  The paper-faithful baseline is
    G = 1 (one global group).

    Returns ([b, s, d], aux_loss scalar)."""
    from repro import dist

    b, s, d = x.shape
    E, topk = cfg.n_experts, cfg.top_k
    T = b * s
    G = cfg.moe_groups if (cfg.moe_groups > 1 and not dropless
                           and T % cfg.moe_groups == 0) else 1
    Tg = T // G
    if dropless:
        C = Tg
    else:
        C = capacity or int(np.ceil(Tg / E * cfg.capacity_factor
                                    * max(topk, 1)))
    C = max(C, 1)

    if G > 1:
        # it-B1/B3: explicit group axis with output-side sharding
        # constraints.  Routing (sort/rank) is vmapped per group; dispatch,
        # expert einsums and combine carry the G axis natively so every
        # intermediate can be pinned group-sharded over the data axes —
        # constraining WEIGHT shardings instead (it-B2) made SPMD replicate
        # the dispatch and was refuted at 4.6x the collective bytes.
        xg = dist.shard(x.reshape(G, Tg, d), "batch", None, None)
        dest, keep, gate_vals, aux = jax.vmap(
            lambda xi: _route(xi, router_w, cfg, C))(xg)
        g_idx = jnp.arange(G, dtype=jnp.int32)[:, None]
        buf = jnp.zeros((G, E * C, d), x.dtype)
        for j in range(topk):
            buf = buf.at[g_idx, dest[:, :, j]].set(xg, mode="drop")
        buf = dist.shard(buf.reshape(G, E, C, d), "batch", None, None, None)
        if cfg.mlp == "swiglu":
            gg = jnp.einsum("gecd,edf->gecf", buf, w_gate.astype(x.dtype))
            u = jnp.einsum("gecd,edf->gecf", buf, w_up.astype(x.dtype))
            h = jax.nn.silu(gg.astype(jnp.float32)).astype(x.dtype) * u
        else:
            u = jnp.einsum("gecd,edf->gecf", buf, w_up.astype(x.dtype))
            h = jnp.square(jax.nn.relu(u.astype(jnp.float32))).astype(x.dtype)
        h = dist.shard(h, "batch", "experts", None, "expert_mlp")
        out_e = jnp.einsum("gecf,efd->gecd", h, w_down.astype(x.dtype))
        out_e = dist.shard(out_e, "batch", None, None, None)
        out_e = out_e.reshape(G, E * C, d)
        yg = jnp.zeros((G, Tg, d), jnp.float32)
        for j in range(topk):
            contrib = out_e[g_idx, jnp.minimum(dest[:, :, j], E * C - 1)
                            ].astype(jnp.float32)
            contrib = jnp.where(keep[:, :, j, None], contrib, 0.0)
            yg = yg + contrib * gate_vals[:, :, j, None]
        yg = dist.shard(yg.astype(x.dtype), "batch", None, None)
        return yg.reshape(b, s, d), jnp.mean(aux)

    y, aux = _moe_tokens(x.reshape(T, d), router_w, w_gate, w_up, w_down,
                         cfg, C)
    return y.reshape(b, s, d), aux


def _route(xt, router_w, cfg: ModelConfig, C: int):
    """Routing only: (dest[T,topk], keep[T,topk], gates[T,topk], aux)."""
    T, d = xt.shape
    E, topk = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = lax.top_k(probs, topk)
    if topk > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E), axis=0)
    aux = E * jnp.sum(me * ce)
    rank = _expert_rank(expert_idx, T, topk)
    keep = rank < C
    dest = jnp.where(keep, expert_idx * C + rank, E * C)
    return dest, keep, gate_vals, aux


def _expert_rank(expert_idx, T, topk):
    flat_expert = expert_idx.reshape(-1)
    sort_idx = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[sort_idx]
    arange = jnp.arange(T * topk, dtype=jnp.int32)
    seg_start = jnp.concatenate([jnp.ones((1,), bool),
                                 sorted_expert[1:] != sorted_expert[:-1]])

    def combine(a, b2):
        af, av = a
        bf, bv = b2
        return (af | bf, jnp.where(bf, bv, jnp.maximum(av, bv)))

    _, start_pos = lax.associative_scan(
        combine, (seg_start, jnp.where(seg_start, arange, -1)))
    rank_sorted = arange - start_pos
    rank = jnp.zeros_like(rank_sorted).at[sort_idx].set(rank_sorted)
    return rank.reshape(T, topk)


def _moe_tokens(xt, router_w, w_gate, w_up, w_down, cfg: ModelConfig,
                C: int):
    """Capacity dispatch over a flat token axis.  xt: [T, d] -> ([T, d], aux)."""
    T, d = xt.shape
    E, topk = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router_w.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = lax.top_k(probs, topk)        # [T, topk]
    if topk > 1:
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balancing auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=0)                           # [E]
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E), axis=0)
    aux = E * jnp.sum(me * ce)

    # rank of each (token, choice) within its expert, via stable sort
    flat_expert = expert_idx.reshape(-1)                   # [T*topk]
    sort_idx = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[sort_idx]
    arange = jnp.arange(T * topk, dtype=jnp.int32)
    seg_start = jnp.concatenate([jnp.ones((1,), bool),
                                 sorted_expert[1:] != sorted_expert[:-1]])
    # index of segment start for every element (inclusive max-scan)
    def combine(a, b2):
        af, av = a
        bf, bv = b2
        return (af | bf, jnp.where(bf, bv, jnp.maximum(av, bv)))
    _, start_pos = lax.associative_scan(
        combine, (seg_start, jnp.where(seg_start, arange, -1)))
    rank_sorted = arange - start_pos
    rank = jnp.zeros_like(rank_sorted).at[sort_idx].set(rank_sorted)
    rank = rank.reshape(T, topk)

    keep = rank < C                                        # capacity mask
    dest = jnp.where(keep, expert_idx * C + rank, E * C)   # drop -> OOB

    # dispatch: [E*C, d]
    buf = jnp.zeros((E * C, d), xt.dtype)
    for j in range(topk):
        buf = buf.at[dest[:, j]].set(xt, mode="drop")
    buf = buf.reshape(E, C, d)

    # expert computation (E shards over 'model' => expert parallelism)
    if cfg.mlp == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(xt.dtype))
        u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(xt.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xt.dtype) * u
    else:
        u = jnp.einsum("ecd,edf->ecf", buf, w_up.astype(xt.dtype))
        h = jnp.square(jax.nn.relu(u.astype(jnp.float32))).astype(xt.dtype)
    out_e = jnp.einsum("ecf,efd->ecd", h, w_down.astype(xt.dtype))
    out_e = out_e.reshape(E * C, d)

    # combine: gather back + weight
    yt = jnp.zeros((T, d), jnp.float32)
    for j in range(topk):
        contrib = out_e[jnp.minimum(dest[:, j], E * C - 1)].astype(jnp.float32)
        contrib = jnp.where(keep[:, j, None], contrib, 0.0)
        yt = yt + contrib * gate_vals[:, j, None]
    return yt.astype(xt.dtype), aux
