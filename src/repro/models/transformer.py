"""Unified LM stack driving all ten assigned architectures.

One `forward()` covers train / prefill / decode for: dense decoders (deepseek,
glm4, codeqwen, nemotron), MoE (llama4-maverick top-1, mixtral top-2 + SWA),
SSM (mamba2 SSD), hybrid (recurrentgemma RG-LRU 2:1 local-attn), encoder-only
(hubert, bidirectional, feature inputs), and VLM (qwen2-vl, M-RoPE + patch
embedding stub).

Layers are applied with `lax.scan` over stacked parameter "periods" (the
block_pattern unit — 1 layer for homogeneous stacks, 3 for recurrentgemma) so
the compiled HLO contains ONE period body regardless of depth: compile time
and HLO size stay flat at 48 layers, and per-layer FSDP all-gathers pipeline
inside the loop.  `n_layers % period` remainder layers run unrolled as a tail.

Sharding is expressed with logical-axis annotations (`repro.dist.shard`) that
are no-ops outside a mesh context — models stay mesh-agnostic.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro import dist
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.common import ModelConfig, dense_init, norm


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_mlp(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.is_moe:
        E = cfg.n_experts
        p = {"router": dense_init(ks[0], (d, E), jnp.float32),
             "w_up": dense_init(ks[1], (E, d, f), dtype),
             "w_down": dense_init(ks[2], (E, f, d), dtype)}
        if cfg.mlp == "swiglu":
            p["w_gate"] = dense_init(jax.random.fold_in(key, 7), (E, d, f),
                                     dtype)
        return p
    p = {"w_up": dense_init(ks[1], (d, f), dtype),
         "w_down": dense_init(ks[2], (f, d), dtype)}
    if cfg.mlp == "swiglu":
        p["w_gate"] = dense_init(ks[0], (d, f), dtype)
    return p


def _init_attn(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], (d, h, hd), dtype),
            "wk": dense_init(ks[1], (d, kv, hd), dtype),
            "wv": dense_init(ks[2], (d, kv, hd), dtype),
            "wo": dense_init(ks[3], (h, hd, d), dtype)}


def _init_layer(key, kind: str, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"norm_mix": jnp.zeros((cfg.d_model,), jnp.float32)}
    if kind == "attn":
        p["attn"] = _init_attn(ks[0], cfg, dtype)
    elif kind == "ssm":
        p["ssm"] = ssm_mod.init_ssm_params(ks[0], cfg, dtype)
    elif kind == "rglru":
        p["rglru"] = rglru_mod.init_rglru_params(ks[0], cfg, dtype)
    else:
        raise ValueError(kind)
    if cfg.d_ff > 0:
        p["norm_mlp"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["mlp"] = _init_mlp(ks[1], cfg, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = cfg.pdtype()
    period = len(cfg.block_pattern)
    n_full, tail_n = cfg.n_layers // period, cfg.n_layers % period
    k_emb, k_stack, k_tail, k_head = jax.random.split(key, 4)

    params: dict[str, Any] = {}
    if cfg.input_mode == "tokens":
        params["embed"] = dense_init(k_emb, (cfg.vocab, cfg.d_model), dtype,
                                     scale=1.0)
    else:
        params["embed"] = dense_init(k_emb, (cfg.feature_dim, cfg.d_model),
                                     dtype)
    if cfg.family == "vlm":
        params["vision_proj"] = dense_init(
            jax.random.fold_in(k_emb, 1), (cfg.d_model, cfg.d_model), dtype)

    def one_period(k):
        kk = jax.random.split(k, period)
        return tuple(_init_layer(kk[j], cfg.block_pattern[j], cfg, dtype)
                     for j in range(period))

    if n_full:
        params["stack"] = jax.vmap(one_period)(
            jax.random.split(k_stack, n_full))
    if tail_n:
        kk = jax.random.split(k_tail, tail_n)
        params["tail"] = tuple(
            _init_layer(kk[j], cfg.block_pattern[j % period], cfg, dtype)
            for j in range(tail_n))
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
    if not cfg.tie_embeddings:
        params["head"] = dense_init(k_head, (cfg.d_model, cfg.vocab), dtype)
    return params


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------

def _attn_cache_len(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.window) if cfg.window > 0 else max_len


def init_layer_cache(kind: str, cfg: ModelConfig, batch: int, max_len: int,
                     dtype):
    if kind == "attn":
        L = _attn_cache_len(cfg, max_len)
        shape = (batch, L, cfg.n_kv_heads, cfg.hd)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == "ssm":
        return ssm_mod.init_ssm_cache(batch, cfg, dtype)
    if kind == "rglru":
        return rglru_mod.init_rglru_cache(batch, cfg, dtype)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    dtype = cfg.cdtype()
    period = len(cfg.block_pattern)
    n_full, tail_n = cfg.n_layers // period, cfg.n_layers % period
    cache: dict[str, Any] = {}
    if n_full:
        one = tuple(init_layer_cache(k, cfg, batch, max_len, dtype)
                    for k in cfg.block_pattern)
        cache["stack"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_full,) + x.shape), one)
    if tail_n:
        cache["tail"] = tuple(
            init_layer_cache(cfg.block_pattern[j % period], cfg, batch,
                             max_len, dtype)
            for j in range(tail_n))
    return cache


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def _mlp_apply(x, p, cfg: ModelConfig, mode: str = "train"):
    if cfg.is_moe:
        # Decode never drops tokens (serving must be exact); train/prefill
        # use capacity-factor dispatch unless the config forces dropless.
        dropless = cfg.moe_dropless or mode == "decode"
        gate = p.get("w_gate")
        y, aux = moe_mod.moe_ffn(x, p["router"], gate, p["w_up"],
                                 p["w_down"], cfg, dropless=dropless)
        return y, aux
    if cfg.mlp == "swiglu":
        g = jnp.einsum("btd,df->btf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("btd,df->btf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    elif cfg.mlp == "sqrelu":
        u = jnp.einsum("btd,df->btf", x, p["w_up"].astype(x.dtype))
        h = jnp.square(jax.nn.relu(u.astype(jnp.float32))).astype(x.dtype)
    else:  # gelu
        u = jnp.einsum("btd,df->btf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.gelu(u.astype(jnp.float32)).astype(x.dtype)
    h = dist.shard(h, "batch", "seq", "mlp")
    y = jnp.einsum("btf,fd->btd", h, p["w_down"].astype(x.dtype))
    return y, jnp.float32(0)


def _attn_apply(x, p, cfg: ModelConfig, positions, cache, mode,
                max_len: int = 0):
    q, k, v = attn.attn_qkv(x, p["wq"], p["wk"], p["wv"], positions, cfg)
    q = dist.shard(q, "batch", "seq", "heads", None)
    k = dist.shard(k, "batch", "seq", "kv_heads", None)
    v = dist.shard(v, "batch", "seq", "kv_heads", None)
    if mode == "decode":
        pos = positions[:, 0, 0] if cfg.mrope_sections else positions[:, 0]
        W = cache["k"].shape[1]
        slot = pos % W if cfg.window > 0 else pos
        b_idx = jnp.arange(x.shape[0])
        k_cache = cache["k"].at[b_idx, slot].set(k[:, 0])
        v_cache = cache["v"].at[b_idx, slot].set(v[:, 0])
        if cfg.window > 0:
            # ring cache: reconstruct per-slot absolute positions
            j = jnp.arange(W, dtype=jnp.int32)
            kpos = pos[:, None] - ((pos[:, None] - j[None, :]) % W)
            o = attn.ring_decode_attention(q, k_cache, v_cache, pos, kpos,
                                           cfg.window)
        else:
            o = attn.decode_attention(q, k_cache, v_cache, pos,
                                      window=cfg.window)
        new_cache = {"k": k_cache, "v": v_cache}
    else:
        o = attn.flash_attention(q, k, v, causal=cfg.causal,
                                 window=cfg.window, q_block=cfg.q_block,
                                 kv_block=cfg.kv_block,
                                 score_dtype=jnp.dtype(cfg.score_dtype))
        if mode == "prefill":
            # Cache is sized by max_len (>= T) so decode has headroom; keys
            # of position p land at slot p % L (ring for windowed attn,
            # identity for full attn since L == max_len >= T).
            T = k.shape[1]
            L = _attn_cache_len(cfg, max(max_len, T))
            if T == L:
                new_cache = {"k": k, "v": v}
            elif T < L:
                pad = [(0, 0), (0, L - T), (0, 0), (0, 0)]
                new_cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
            else:  # windowed: keep the last L positions, ring layout
                pos = jnp.arange(T - L, T, dtype=jnp.int32)
                slot = pos % L
                new_cache = {
                    "k": jnp.zeros_like(k[:, :L]).at[:, slot].set(k[:, T - L:]),
                    "v": jnp.zeros_like(v[:, :L]).at[:, slot].set(v[:, T - L:]),
                }
        else:
            new_cache = None
    o = dist.shard(o, "batch", "seq", "heads", None)
    y = attn.attn_out(o, p["wo"], x.dtype)
    return y, new_cache


def apply_layer(x, p, kind: str, cfg: ModelConfig, positions, cache, mode,
                max_len: int = 0):
    """Pre-norm temporal mixer + (optional) MLP/MoE, residual wiring."""
    h = norm(x, p["norm_mix"], cfg)
    if kind == "attn":
        y, new_cache = _attn_apply(h, p["attn"], cfg, positions, cache, mode,
                                   max_len)
    elif kind == "ssm":
        y, new_cache = ssm_mod.ssm_block(
            h, p["ssm"], cfg, cache=cache if mode == "decode" else None)
        if mode == "train":
            new_cache = None
    elif kind == "rglru":
        y, new_cache = rglru_mod.rglru_block(
            h, p["rglru"], cfg, cache=cache if mode == "decode" else None)
        if mode == "train":
            new_cache = None
    else:
        raise ValueError(kind)
    x = x + y
    aux = jnp.float32(0)
    if cfg.d_ff > 0:
        h = norm(x, p["norm_mlp"], cfg)
        y, aux = _mlp_apply(h, p["mlp"], cfg, mode)
        x = x + y
    x = dist.shard(x, "batch", "seq", None)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------

def embed_inputs(params, cfg: ModelConfig, batch: dict, mode: str):
    """Returns (x [b,t,d], positions)."""
    if cfg.input_mode == "features":
        feats = batch["features"]
        x = jnp.einsum("btf,fd->btd", feats.astype(cfg.cdtype()),
                       params["embed"].astype(cfg.cdtype()))
        b, t = x.shape[:2]
    else:
        tokens = batch["tokens"]
        b, t = tokens.shape
        x = params["embed"].astype(cfg.cdtype())[tokens]
        if cfg.family == "vlm" and "vision_embeds" in batch:
            ve = jnp.einsum("bpd,de->bpe",
                            batch["vision_embeds"].astype(cfg.cdtype()),
                            params["vision_proj"].astype(cfg.cdtype()))
            nv = ve.shape[1]
            x = jnp.concatenate([ve, x[:, nv:]], axis=1)
    if mode == "decode":
        pos = batch["pos"]                                   # int32[b]
        positions = (jnp.repeat(pos[:, None, None], 3, axis=-1)
                     if cfg.mrope_sections else pos[:, None])
    else:
        ar = jnp.arange(t, dtype=jnp.int32)
        positions = (jnp.broadcast_to(ar[None, :, None], (b, t, 3))
                     if cfg.mrope_sections else
                     jnp.broadcast_to(ar[None, :], (b, t)))
        if "positions" in batch:
            positions = batch["positions"]
    x = dist.shard(x, "batch", "seq", None)
    return x, positions


def forward(params, cfg: ModelConfig, batch: dict, *, mode: str = "train",
            cache=None, max_len: int = 0):
    """mode: train (no cache) | prefill (build cache) | decode (use cache).

    `max_len` sizes the prefill cache (>= prompt length) so subsequent decode
    steps have headroom; 0 means exactly the prompt length.

    Returns (logits, new_cache, aux_loss)."""
    x, positions = embed_inputs(params, cfg, batch, mode)
    period = len(cfg.block_pattern)
    n_full, tail_n = cfg.n_layers // period, cfg.n_layers % period

    def period_body(x, layer_ps, layer_cs):
        new_cs, aux_tot = [], jnp.float32(0)
        for j, kind in enumerate(cfg.block_pattern):
            c_in = None if layer_cs is None else layer_cs[j]
            x, nc, aux = apply_layer(x, layer_ps[j], kind, cfg, positions,
                                     c_in, mode, max_len)
            new_cs.append(nc)
            aux_tot = aux_tot + aux
        return x, tuple(new_cs), aux_tot

    if n_full:
        def scan_body(carry, scanned):
            x, aux_acc = carry
            if mode == "decode":
                lp, lc = scanned
            else:
                lp, lc = scanned, None
            x, new_cs, aux = period_body(x, lp, lc)
            ys = new_cs if mode in ("prefill", "decode") else None
            return (x, aux_acc + aux), ys

        body = scan_body
        if cfg.remat and mode == "train":
            body = jax.checkpoint(scan_body, prevent_cse=False)
        xs = (params["stack"], cache["stack"]) if mode == "decode" \
            else params["stack"]
        (x, aux_acc), stack_cache = lax.scan(body, (x, jnp.float32(0)), xs)
    else:
        aux_acc, stack_cache = jnp.float32(0), None

    tail_cache = []
    if tail_n:
        for j in range(tail_n):
            c_in = cache["tail"][j] if mode == "decode" else None
            x, nc, aux = apply_layer(
                x, params["tail"][j], cfg.block_pattern[j % period], cfg,
                positions, c_in, mode, max_len)
            tail_cache.append(nc)
            aux_acc = aux_acc + aux

    if mode == "prefill":
        # Serving prefill only needs the last position's logits: slice BEFORE
        # the head projection so the [b, t, vocab] tensor never materializes
        # (at 32k x 100k-vocab that tensor would dwarf the whole model).
        x = x[:, -1:]
    x = norm(x, params["final_norm"], cfg)
    head = (params["embed"].T if cfg.tie_embeddings
            else params["head"]).astype(cfg.cdtype())
    logits = jnp.einsum("btd,dv->btv", x, head)
    if cfg.logit_softcap > 0:
        logits = cfg.logit_softcap * jnp.tanh(
            logits.astype(jnp.float32) / cfg.logit_softcap)
    logits = dist.shard(logits, "batch", "seq", "vocab")

    new_cache = None
    if mode in ("prefill", "decode"):
        new_cache = {}
        if stack_cache is not None:
            new_cache["stack"] = stack_cache
        if tail_n:
            new_cache["tail"] = tuple(tail_cache)
    return logits, new_cache, aux_acc


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def _trunk(params, cfg: ModelConfig, x, positions):
    """Train-mode layer stack + final norm, head NOT applied.
    Returns (hidden [b,t,d], aux_loss)."""
    period = len(cfg.block_pattern)
    n_full, tail_n = cfg.n_layers // period, cfg.n_layers % period

    def period_body(x, layer_ps):
        aux_tot = jnp.float32(0)
        for j, kind in enumerate(cfg.block_pattern):
            x, _, aux = apply_layer(x, layer_ps[j], kind, cfg, positions,
                                    None, "train", 0)
            aux_tot = aux_tot + aux
        return x, aux_tot

    aux_acc = jnp.float32(0)
    if n_full:
        def scan_body(carry, lp):
            x, acc = carry
            x, aux = period_body(x, lp)
            return (x, acc + aux), None

        body = scan_body
        if cfg.remat:
            body = jax.checkpoint(scan_body, prevent_cse=False)
        (x, aux_acc), _ = lax.scan(body, (x, jnp.float32(0)),
                                   params["stack"])
    if tail_n:
        for j in range(tail_n):
            x, _, aux = apply_layer(
                x, params["tail"][j], cfg.block_pattern[j % period], cfg,
                positions, None, "train", 0)
            aux_acc = aux_acc + aux
    return norm(x, params["final_norm"], cfg), aux_acc


def _ce_from_logits(logits, targets):
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.sum(logz - gold)


def lm_loss(params, cfg: ModelConfig, batch: dict):
    """Next-token CE (causal) / per-position CE (encoder).  Scalar mean.

    With cfg.loss_chunk > 0 the head projection + CE run per sequence chunk
    under lax.map, so the [b, t, vocab] logits tensor never materializes —
    at llama4's 202k vocab the monolithic fp32 logits (+ their gradient)
    dominate the memory roofline term (EXPERIMENTS.md §Perf it-A2)."""
    if cfg.loss_chunk and cfg.causal:
        x, positions = embed_inputs(params, cfg, batch, "train")
        h, aux = _trunk(params, cfg, x, positions)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["head"]).astype(cfg.cdtype())
        targets = (batch["tokens"] if cfg.input_mode == "tokens"
                   else batch["labels"])[:, 1:]
        h = h[:, :-1]
        b, tm1, d = h.shape
        nc = cfg.loss_chunk
        pad = (-tm1) % nc
        if pad:
            h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
        hc = h.reshape(b, nc, -1, d).transpose(1, 0, 2, 3)
        tc = targets.reshape(b, nc, -1).transpose(1, 0, 2)
        valid = jnp.arange(tm1 + pad).reshape(nc, -1) < tm1

        def chunk(args):
            hj, tj, vj = args
            logits = jnp.einsum("btd,dv->btv", hj, head)
            if cfg.logit_softcap > 0:
                logits = cfg.logit_softcap * jnp.tanh(
                    logits.astype(jnp.float32) / cfg.logit_softcap)
            lz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
            gold = jnp.take_along_axis(
                logits.astype(jnp.float32),
                tj[..., None].astype(jnp.int32), axis=-1)[..., 0]
            return jnp.sum(jnp.where(vj[None, :], lz - gold, 0.0))

        totals = lax.map(chunk, (hc, tc, valid))
        return jnp.sum(totals) / (b * tm1) + 0.01 * aux

    logits, _, aux = forward(params, cfg, batch, mode="train")
    logits = logits.astype(jnp.float32)
    if cfg.causal:
        targets = batch["tokens"][:, 1:] if cfg.input_mode == "tokens" \
            else batch["labels"][:, 1:]
        logits = logits[:, :-1]
    else:
        targets = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    ce = jnp.mean(logz - gold)
    return ce + 0.01 * aux
