"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence:  a_t = exp(-c * softplus(Lambda) * r_t),   r_t = sigmoid(W_a x_t)
             h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t),
             i_t = sigmoid(W_x x_t)
computed with an associative scan over time (linear recurrence), O(1) decode.
The block wraps the RG-LRU in the Griffin recurrent block: two branches
(conv+RG-LRU, GeLU), multiplied, projected out.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.common import ModelConfig
from repro.models.ssm import causal_conv

_C = 8.0  # Griffin's fixed scaling constant


def rglru_scan(x, r, i, lam):
    """x, r, i: [b, t, w]; lam: [w].  Returns (y [b,t,w], h_last [b,w])."""
    log_a = -_C * jax.nn.softplus(lam.astype(jnp.float32)) * \
        jax.nn.sigmoid(r.astype(jnp.float32))                  # [b,t,w] (<=0)
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i.astype(jnp.float32)) * x.astype(jnp.float32)
    b_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    def combine(l, r_):
        al, bl = l
        ar, br = r_
        return (al * ar, br + bl * ar)

    _, h = lax.associative_scan(combine, (a, b_in), axis=1)
    return h, h[:, -1]


def rglru_step(x, r, i, lam, h_prev):
    """One-token recurrence.  x,r,i: [b,1,w]; h_prev: [b,w]."""
    log_a = -_C * jax.nn.softplus(lam.astype(jnp.float32)) * \
        jax.nn.sigmoid(r.astype(jnp.float32)[:, 0])
    a = jnp.exp(log_a)
    gated = jax.nn.sigmoid(i.astype(jnp.float32)[:, 0]) * \
        x.astype(jnp.float32)[:, 0]
    b_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated
    h = a * h_prev + b_in
    return h[:, None], h


def rglru_block(x, params, cfg: ModelConfig, *, cache=None):
    """Griffin recurrent block.  x: [b, t, d].
    cache (decode): dict(conv [b,k-1,w], h [b,w])."""
    w = cfg.rglru_width or cfg.d_model
    xr = jnp.einsum("btd,dw->btw", x, params["w_rec"].astype(x.dtype))
    xg = jnp.einsum("btd,dw->btw", x, params["w_gelu"].astype(x.dtype))
    conv_state = cache["conv"] if cache is not None else None
    xc, new_conv = causal_conv(xr, params["conv_w"], conv_state)
    r = jnp.einsum("btw,wv->btv", xc, params["w_a"].astype(x.dtype))
    i = jnp.einsum("btw,wv->btv", xc, params["w_x"].astype(x.dtype))
    if cache is None:
        h, h_last = rglru_scan(xc, r, i, params["lam"])
    else:
        h, h_last = rglru_step(xc, r, i, params["lam"], cache["h"])
    h = h.astype(x.dtype) * jax.nn.gelu(xg.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("btw,wd->btd", h, params["w_out"].astype(x.dtype))
    return out, {"conv": new_conv, "h": h_last}


def init_rglru_params(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 5)
    def lin(k, shape):
        return (jax.random.normal(k, shape, jnp.float32)
                / np.sqrt(shape[0])).astype(dtype)
    return {
        "w_rec": lin(ks[0], (d, w)),
        "w_gelu": lin(ks[1], (d, w)),
        "conv_w": (jax.random.normal(ks[2], (4, w), jnp.float32) * 0.1
                   ).astype(dtype),
        "w_a": lin(ks[3], (w, w)),
        "w_x": lin(ks[4], (w, w)),
        "lam": jnp.linspace(0.0, 3.0, w).astype(jnp.float32),
        "w_out": lin(jax.random.fold_in(key, 9), (w, d)),
    }


def init_rglru_cache(batch: int, cfg: ModelConfig, dtype):
    w = cfg.rglru_width or cfg.d_model
    return {"conv": jnp.zeros((batch, 3, w), dtype),
            "h": jnp.zeros((batch, w), jnp.float32)}
