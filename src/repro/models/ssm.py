"""Mamba-2 SSD (state-space duality) block, chunked for TPU.

The SSD recurrence  h_t = exp(a_t) h_{t-1} + B_t x_t^T ,  y_t = C_t h_t + D x_t
is computed chunkwise (arXiv:2405.21060 §6): within a chunk of length Q the
quadratic dual form runs on the MXU; across chunks a cheap associative scan
carries the [nh, hd, state] states.  Decode is the O(1) recurrence step.

TPU adaptation: the reference implementation fuses z/x/B/C/dt into one
in_proj; we keep them as separate matrices (mathematically identical — the
depthwise conv is per-channel, so splitting is exact) so that the d_inner
axis can shard over the 'model' mesh axis (tensor parallelism) without GSPMD
having to split a mixed-sharding concatenation.

Shapes follow the paper: d_inner = expand * d_model, nh = d_inner / headdim,
single B/C group (G=1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.common import ModelConfig


def ssd_chunked(x, dt, A_log, B, C, D, *, chunk: int = 256):
    """x: [b, t, nh, hd]; dt: [b, t, nh]; A_log: [nh];
    B, C: [b, t, state]  (single group, broadcast over heads);
    D: [nh].  Returns (y: [b, t, nh, hd], final_state [b, nh, hd, state])."""
    b, t, nh, hd = x.shape
    state = B.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0
    nc = t // chunk

    a = -jnp.exp(A_log.astype(jnp.float32))                 # [nh] (negative)
    dt = jax.nn.softplus(dt.astype(jnp.float32))             # [b, t, nh]
    dA = dt * a                                               # [b, t, nh] (<=0)
    xdt = x.astype(jnp.float32) * dt[..., None]               # dt-scaled input

    xc = xdt.reshape(b, nc, chunk, nh, hd)
    dAc = dA.reshape(b, nc, chunk, nh)
    Bc = B.astype(jnp.float32).reshape(b, nc, chunk, state)
    Cc = C.astype(jnp.float32).reshape(b, nc, chunk, state)

    # cumulative decay within each chunk
    seg = jnp.cumsum(dAc, axis=2)                             # [b,nc,Q,nh]
    total = seg[:, :, -1:, :]                                 # [b,nc,1,nh]

    # ---- intra-chunk (quadratic dual form) --------------------------------
    li = seg[:, :, :, None, :]                                # i axis
    lj = seg[:, :, None, :, :]                                # j axis
    decay = jnp.exp(jnp.clip(li - lj, -60.0, 0.0))            # [b,nc,Q,Q,nh]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    cb = jnp.einsum("bcis,bcjs->bcij", Cc, Bc)                # [b,nc,Q,Q]
    y_intra = jnp.einsum("bcij,bcijh,bcjhd->bcihd",
                         cb, decay, xc)                       # [b,nc,Q,nh,hd]

    # ---- chunk states + inter-chunk scan ----------------------------------
    w = jnp.exp(jnp.clip(total - seg, -60.0, 0.0))            # [b,nc,Q,nh]
    states = jnp.einsum("bcjs,bcjh,bcjhd->bchds",
                        Bc, w, xc)                            # [b,nc,nh,hd,state]
    chunk_decay = jnp.exp(jnp.clip(total[:, :, 0, :], -60.0, 0.0))  # [b,nc,nh]

    def combine(left, right):
        dl, sl = left
        dr, sr = right
        return (dl * dr, sr + sl * dr[..., None, None])

    dec_scan, st_scan = lax.associative_scan(
        combine, (chunk_decay, states), axis=1)
    init_states = jnp.concatenate(
        [jnp.zeros_like(st_scan[:, :1]), st_scan[:, :-1]], axis=1)

    # ---- inter-chunk output ------------------------------------------------
    out_decay = jnp.exp(jnp.clip(seg, -60.0, 0.0))            # [b,nc,Q,nh]
    y_inter = jnp.einsum("bcis,bcih,bchds->bcihd",
                         Cc, out_decay, init_states)

    y = (y_intra + y_inter).reshape(b, t, nh, hd)
    y = y + x.astype(jnp.float32) * D.astype(jnp.float32)[None, None, :, None]
    final_state = st_scan[:, -1]                              # [b,nh,hd,state]
    return y.astype(x.dtype), final_state


def ssd_decode_step(x, dt, A_log, B, C, D, h_prev):
    """One-token recurrence.  x: [b,1,nh,hd]; B,C: [b,1,state];
    h_prev: [b,nh,hd,state].  Returns (y [b,1,nh,hd], h_new)."""
    a = -jnp.exp(A_log.astype(jnp.float32))
    dt = jax.nn.softplus(dt.astype(jnp.float32))[:, 0]        # [b,nh]
    dA = jnp.exp(jnp.clip(dt * a, -60.0, 0.0))                # [b,nh]
    xdt = x.astype(jnp.float32)[:, 0] * dt[..., None]         # [b,nh,hd]
    Bt = B.astype(jnp.float32)[:, 0]                          # [b,state]
    Ct = C.astype(jnp.float32)[:, 0]
    h_new = (h_prev * dA[..., None, None]
             + jnp.einsum("bhd,bs->bhds", xdt, Bt))
    y = jnp.einsum("bhds,bs->bhd", h_new, Ct)
    y = y + x.astype(jnp.float32)[:, 0] * D.astype(jnp.float32)[None, :, None]
    return y[:, None].astype(x.dtype), h_new


def causal_conv(x, w, conv_state=None):
    """Depthwise causal conv + SiLU.  x: [b, t, c]; w: [k, c].
    If conv_state [b, k-1, c] is given (decode), returns (y, new_state)."""
    k = w.shape[0]
    if conv_state is not None:
        xin = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
        new_state = xin[:, -(k - 1):]
    else:
        xin = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        new_state = xin[:, -(k - 1):]
    y = sum(xin[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(k))
    return jax.nn.silu(y.astype(jnp.float32)).astype(x.dtype), new_state


def ssm_block(x, params, cfg: ModelConfig, *, cache=None, chunk: int = 256):
    """Full mamba2 mixer: projections -> conv -> SSD -> gate -> out_proj.

    x: [b, t, d].  cache (decode): dict(conv_x/conv_B/conv_C, state).
    Returns (y [b,t,d], new_cache dict)."""
    b, t, d = x.shape
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_headdim
    hd = cfg.ssm_headdim

    z = jnp.einsum("btd,de->bte", x, params["w_z"].astype(x.dtype))
    xi = jnp.einsum("btd,de->bte", x, params["w_x"].astype(x.dtype))
    Braw = jnp.einsum("btd,ds->bts", x, params["w_B"].astype(x.dtype))
    Craw = jnp.einsum("btd,ds->bts", x, params["w_C"].astype(x.dtype))
    dt = jnp.einsum("btd,dh->bth", x, params["w_dt"].astype(x.dtype))

    cs = cache or {}
    xc, new_cx = causal_conv(xi, params["conv_x"], cs.get("conv_x"))
    B, new_cb = causal_conv(Braw, params["conv_B"], cs.get("conv_B"))
    C, new_cc = causal_conv(Craw, params["conv_C"], cs.get("conv_C"))
    xh = xc.reshape(b, t, nh, hd)
    dtb = dt + params["dt_bias"].astype(dt.dtype)

    if cache is None:
        y, final_state = ssd_chunked(xh, dtb, params["A_log"], B, C,
                                     params["D"], chunk=chunk)
    else:
        y, final_state = ssd_decode_step(xh, dtb, params["A_log"], B, C,
                                         params["D"], cache["state"])
    y = y.reshape(b, t, d_in)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)   # gate
    out = jnp.einsum("bte,ed->btd", y, params["out_proj"].astype(y.dtype))
    new_cache = {"conv_x": new_cx, "conv_B": new_cb, "conv_C": new_cc,
                 "state": final_state}
    return out, new_cache


def init_ssm_params(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    nh = d_in // cfg.ssm_headdim
    S = cfg.ssm_state
    ks = jax.random.split(key, 8)

    def lin(k, shape):
        return (jax.random.normal(k, shape, jnp.float32)
                / np.sqrt(shape[0])).astype(dtype)

    return {
        "w_z": lin(ks[0], (d, d_in)),
        "w_x": lin(ks[1], (d, d_in)),
        "w_B": lin(ks[2], (d, S)),
        "w_C": lin(ks[3], (d, S)),
        "w_dt": lin(ks[4], (d, nh)),
        "conv_x": (jax.random.normal(ks[5], (cfg.ssm_conv, d_in), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_B": (jax.random.normal(ks[6], (cfg.ssm_conv, S), jnp.float32)
                   * 0.1).astype(dtype),
        "conv_C": (jax.random.normal(ks[7], (cfg.ssm_conv, S), jnp.float32)
                   * 0.1).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "out_proj": lin(jax.random.fold_in(key, 99), (d_in, d)),
    }


def init_ssm_cache(batch: int, cfg: ModelConfig, dtype):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_headdim
    km1 = cfg.ssm_conv - 1
    return {
        "conv_x": jnp.zeros((batch, km1, d_in), dtype),
        "conv_B": jnp.zeros((batch, km1, cfg.ssm_state), dtype),
        "conv_C": jnp.zeros((batch, km1, cfg.ssm_state), dtype),
        "state": jnp.zeros((batch, nh, cfg.ssm_headdim, cfg.ssm_state),
                           jnp.float32),
    }
