"""GQA attention with pair-list flash (online-softmax) for train/prefill and
dense cache attention for decode.

Pair-list flash: instead of a nested (q-block × kv-block) loop that wastes
half its FLOPs on masked-out causal blocks, we *statically enumerate* the
(q_block, kv_block) pairs that can contain unmasked entries — lower-triangular
pairs for causal, a diagonal band for sliding-window, all pairs for
bidirectional — and `lax.scan` over that list, accumulating online-softmax
state per q block.  The compiled HLO then contains exactly the useful
attention FLOPs (the causal 2x waste of naive block iteration never appears),
and activation memory stays O(T · d) regardless of sequence length.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.models.common import ModelConfig, apply_rope

NEG_INF = -1e30


def _block_pairs(nq: int, nkv: int, q_block: int, kv_block: int,
                 causal: bool, window: int, q_offset: int = 0):
    """Static list of (qi, kj) block pairs that contain unmasked entries."""
    pairs = []
    for qi in range(nq):
        q_lo = q_offset + qi * q_block
        q_hi = q_lo + q_block - 1
        for kj in range(nkv):
            k_lo = kj * kv_block
            k_hi = k_lo + kv_block - 1
            if causal and k_lo > q_hi:
                continue                       # entirely in the future
            if window > 0 and k_hi < q_lo - window + 1:
                continue                       # entirely outside the window
            pairs.append((qi, kj))
    return pairs


def flash_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_block: int = 512, kv_block: int = 512,
                    q_offset: int = 0, softcap: float = 0.0,
                    score_dtype=jnp.float32):
    """q: [b, tq, h, hd]; k, v: [b, tkv, kvh, hd] (GQA: h % kvh == 0).

    Returns [b, tq, h, hd].  q_offset shifts query positions (prefill of a
    suffix against a longer cache).  score_dtype=bf16 halves the HBM traffic
    of the materialized score / probability blocks (the dominant roofline
    term at long S); softmax max/sum statistics stay in f32 for stability.
    """
    b, tq, h, hd = q.shape
    _, tkv, kvh, _ = k.shape
    assert h % kvh == 0
    group = h // kvh
    q_block = min(q_block, tq)
    kv_block = min(kv_block, tkv)
    # Pad ragged tails up to block multiples; padded keys are masked out and
    # padded query rows are sliced off the result.
    tq_orig, tkv_orig = tq, tkv
    q_pad = (-tq) % q_block
    kv_pad = (-tkv) % kv_block
    if q_pad:
        q = jnp.pad(q, ((0, 0), (0, q_pad), (0, 0), (0, 0)))
        tq += q_pad
    if kv_pad:
        k = jnp.pad(k, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kv_pad), (0, 0), (0, 0)))
        tkv += kv_pad
    nq, nkv = tq // q_block, tkv // kv_block
    scale = 1.0 / np.sqrt(hd)

    pairs = _block_pairs(nq, nkv, q_block, kv_block, causal, window, q_offset)
    qi_list = jnp.asarray([p[0] for p in pairs], jnp.int32)
    kj_list = jnp.asarray([p[1] for p in pairs], jnp.int32)

    qb = q.reshape(b, nq, q_block, h, hd)
    kb = k.reshape(b, nkv, kv_block, kvh, hd)
    vb = v.reshape(b, nkv, kv_block, kvh, hd)

    # online-softmax state per q block
    acc = jnp.zeros((b, nq, q_block, h, hd), jnp.float32)
    m = jnp.full((b, nq, q_block, h), NEG_INF, jnp.float32)
    l = jnp.zeros((b, nq, q_block, h), jnp.float32)

    q_pos_in_block = jnp.arange(q_block, dtype=jnp.int32)
    k_pos_in_block = jnp.arange(kv_block, dtype=jnp.int32)

    def step(carry, pair):
        acc, m, l = carry
        qi, kj = pair
        qblk = lax.dynamic_index_in_dim(qb, qi, axis=1, keepdims=False)
        kblk = lax.dynamic_index_in_dim(kb, kj, axis=1, keepdims=False)
        vblk = lax.dynamic_index_in_dim(vb, kj, axis=1, keepdims=False)
        # GQA: fold the group into the head axis of q
        qg = qblk.reshape(b, q_block, kvh, group, hd)
        s = jnp.einsum("bqkgd,bskd->bqkgs", qg.astype(score_dtype),
                       kblk.astype(score_dtype),
                       preferred_element_type=score_dtype) * scale
        if softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        qpos = q_offset + qi * q_block + q_pos_in_block    # [qb]
        kpos = kj * kv_block + k_pos_in_block              # [kvb]
        mask = jnp.broadcast_to(kpos[None, :] < tkv_orig,
                                (q_block, kv_block))     # drop kv padding
        if causal:
            mask &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            mask &= kpos[None, :] > qpos[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, NEG_INF)

        s = s.reshape(b, q_block, kvh * group, kv_block)   # [b,qb,h,kvb]
        m_blk = jnp.max(s.astype(jnp.float32), axis=-1)    # [b,qb,h] f32
        m_cur = lax.dynamic_index_in_dim(m, qi, 1, keepdims=False)
        l_cur = lax.dynamic_index_in_dim(l, qi, 1, keepdims=False)
        a_cur = lax.dynamic_index_in_dim(acc, qi, 1, keepdims=False)
        m_new = jnp.maximum(m_cur, m_blk)
        corr = jnp.exp(m_cur - m_new)
        p = jnp.exp(s.astype(jnp.float32)
                    - m_new[..., None]).astype(score_dtype)  # [b,qb,h,kvb]
        pg = p.reshape(b, q_block, kvh, group, kv_block)
        pv = jnp.einsum("bqkgs,bskd->bqkgd", pg, vblk.astype(score_dtype),
                        preferred_element_type=jnp.float32)
        pv = pv.reshape(b, q_block, kvh * group, hd)
        a_new = a_cur * corr[..., None] + pv
        l_new = l_cur * corr + jnp.sum(p.astype(jnp.float32), axis=-1)
        acc = lax.dynamic_update_index_in_dim(acc, a_new, qi, 1)
        m = lax.dynamic_update_index_in_dim(m, m_new, qi, 1)
        l = lax.dynamic_update_index_in_dim(l, l_new, qi, 1)
        return (acc, m, l), None

    (acc, m, l), _ = lax.scan(step, (acc, m, l), (qi_list, kj_list))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    out = out.reshape(b, tq, h, hd)
    if q_pad:
        out = out[:, :tq_orig]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *, window: int = 0,
                     softcap: float = 0.0):
    """Single-position decode.  q: [b, 1, h, hd]; caches: [b, S, kvh, hd];
    pos: int32[b] — index of the token being produced (attends to <= pos)."""
    b, _, h, hd = q.shape
    _, S, kvh, _ = k_cache.shape
    group = h // kvh
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, kvh, group, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale    # [b,kvh,g,S]
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    kpos = jnp.arange(S, dtype=jnp.int32)
    mask = kpos[None, :] <= pos[:, None]                   # [b,S]
    if window > 0:
        mask &= kpos[None, :] > pos[:, None] - window
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def ring_decode_attention(q, k_cache, v_cache, pos, kpos, window: int,
                          softcap: float = 0.0):
    """Decode against a *ring* (windowed) cache.  q: [b,1,h,hd];
    caches: [b, W, kvh, hd]; pos: int32[b]; kpos: int32[b, W] — the absolute
    position stored in each ring slot (negative = unwritten)."""
    b, _, h, hd = q.shape
    _, W, kvh, _ = k_cache.shape
    group = h // kvh
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(b, kvh, group, hd)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    mask = (kpos >= 0) & (kpos <= pos[:, None]) & \
        (kpos > pos[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + residual wiring lives in transformer)
# ---------------------------------------------------------------------------

def attn_qkv(x, wq, wk, wv, positions, cfg: ModelConfig):
    """Project + rope.  x: [b, t, d] -> q[b,t,h,hd], k/v[b,t,kvh,hd]."""
    q = jnp.einsum("btd,dhk->bthk", x, wq.astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, wk.astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, wv.astype(x.dtype))
    if cfg.family != "ssm":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    return q, k, v


def attn_out(o, wo, x_dtype):
    return jnp.einsum("bthk,hkd->btd", o, wo.astype(o.dtype)).astype(x_dtype)
