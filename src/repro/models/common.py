"""Shared model components: config, norms, embeddings, RoPE (incl. M-RoPE)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One config object drives all ten architectures (see repro/configs)."""

    name: str
    family: str                    # dense | moe | ssm | hybrid | encoder | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    mlp: str = "swiglu"            # swiglu | sqrelu | gelu
    norm: str = "rms"              # rms | ln
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_dropless: bool = False     # dropless dispatch (decode is always dropless)
    # attention
    causal: bool = True
    window: int = 0                # sliding-window size (0 = full attention)
    rope_theta: float = 1e6
    mrope_sections: tuple[int, ...] = ()   # qwen2-vl M-RoPE half-dim split
    logit_softcap: float = 0.0
    # ssm (mamba2 SSD)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    # hybrid (recurrentgemma): per-layer block kinds, cycled over layers
    block_pattern: tuple[str, ...] = ("attn",)    # attn | ssm | rglru
    rglru_width: int = 0           # 0 -> d_model
    # encoder/frontend
    input_mode: str = "tokens"     # tokens | features (stub frontend)
    feature_dim: int = 0
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # dtypes / training
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # perf levers (EXPERIMENTS.md §Perf; defaults = paper-faithful baseline)
    score_dtype: str = "float32"   # bfloat16 halves attention score traffic
    loss_chunk: int = 0            # chunk CE over seq (0 = monolithic logits)
    moe_groups: int = 0            # >1: group-local MoE dispatch (no global
    #                                replicated buffer; groups shard w/ batch)
    # attention blocking (flash-style pair-list attention)
    q_block: int = 512
    kv_block: int = 512

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def attn_free(self) -> bool:
        return "attn" not in self.block_pattern

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context?  (SSM/hybrid/windowed.)"""
        return self.attn_free or self.window > 0 or all(
            k != "attn" or self.window > 0 for k in self.block_pattern)

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Block kind of every layer (pattern cycled)."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def n_params(self) -> int:
        """Total parameter count (analytic, matches init shapes)."""
        return sum(int(np.prod(s.shape)) for s in jax.tree_util.tree_leaves(
            jax.eval_shape(lambda: _import_init()(self, jax.random.PRNGKey(0)))))

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top_k of n_experts)."""
        total = self.n_params()
        if not self.is_moe:
            return total
        expert_p = 3 * self.d_model * self.d_ff  # swiglu expert
        moe_total = self.n_layers * self.n_experts * expert_p
        moe_active = self.n_layers * self.top_k * expert_p
        return total - moe_total + moe_active


def _import_init():
    from repro.models.transformer import init_params
    return init_params


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * (1.0 + scale.astype(jnp.float32))
            ).astype(dt)


def layer_norm(x, scale, eps):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (((x - mu) * jax.lax.rsqrt(var + eps))
            * (1.0 + scale.astype(jnp.float32))).astype(dt)


def norm(x, scale, cfg: ModelConfig):
    return rms_norm(x, scale, cfg.norm_eps) if cfg.norm == "rms" \
        else layer_norm(x, scale, cfg.norm_eps)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float, sections: tuple[int, ...] = ()):
    """x: [..., t, h, hd]; positions: [..., t] or [..., t, 3] (M-RoPE).

    M-RoPE (Qwen2-VL): the half-dim axis is split into `sections` (t/h/w),
    each rotated by its own position coordinate.
    """
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    if sections:
        assert sum(sections) == hd // 2, (sections, hd)
        # positions [..., t, 3] -> per-frequency position selection
        sec_id = np.repeat(np.arange(len(sections)), sections)  # [hd/2]
        pos = positions[..., sec_id]                   # [..., t, hd/2]
        ang = pos.astype(jnp.float32) * freqs          # [..., t, hd/2]
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs  # [..., t, hd/2]
    cos = jnp.cos(ang)[..., None, :]                   # [..., t, 1, hd/2]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def make_mrope_positions(batch: int, seq: int) -> jax.Array:
    """Stub M-RoPE positions for text-only input: t == h == w == arange."""
    p = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32)[None, :, None],
                         (batch, seq, 3))
    return p


# ---------------------------------------------------------------------------
# Parameter init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 2 else 1
    s = scale if scale is not None else 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * s
            ).astype(dtype)
