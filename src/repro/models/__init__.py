from repro.models.common import ModelConfig  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    forward, init_params, init_cache, lm_loss,
)
