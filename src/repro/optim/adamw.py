"""AdamW with ZeRO-style sharded state (moments inherit parameter sharding,
which is already fully sharded under 2D FSDP x TP), global-norm clipping and
a warmup-stable-decay schedule.  Moment dtype is configurable per arch so the
very large models fit the per-chip HBM budget (see configs + EXPERIMENTS.md)."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"    # bfloat16 for the 400B-class models


def wsd_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup) /
                    jnp.maximum(cfg.total_steps - cfg.warmup, 1), 0.0, 1.0)
    decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * decay)


def adamw_init(params, cfg: AdamWConfig):
    mdt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)
                        if x.dtype != jnp.int32))


def clip_by_global_norm(grads, max_norm):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), grads), g


def adamw_update(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = wsd_schedule(cfg, step)
    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mh = m32 / bc1
        vh = v32 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * \
            p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(mdt), v32.astype(mdt))

    p_flat, treedef = jax.tree.flatten(params)
    g_flat = treedef.flatten_up_to(grads)
    m_flat = treedef.flatten_up_to(state["m"])
    v_flat = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(p_flat, g_flat, m_flat, v_flat)]
    new_params = jax.tree.unflatten(treedef, [t[0] for t in out])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in out])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
