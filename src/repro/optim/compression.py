"""Gradient compression for the DP all-reduce (bf16 / int8 with per-tensor
scale + error feedback).  Halves (or quarters) the dominant cross-pod
collective bytes; enabled per-config, visible in the roofline collective
term.  Error feedback keeps convergence (residual carried in fp32).

Implementation note: trees are processed via flatten/unflatten against the
grads treedef — param trees contain tuple *containers* (layer tuples), so
`is_leaf=isinstance(tuple)` tricks mis-fire on them.  int8 scales travel in
the meta (a separate leaf list), never inside the grad tree.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_grads(grads, residual, mode: str = "bf16"):
    """Returns (compressed_tree, new_residual_tree, meta).

    mode: none | bf16 | int8.  `meta` is passed to decompress_grads.
    The compressed tree has the same structure as grads (bf16/int8 leaves);
    all-reducing it moves 2x/4x fewer bytes than fp32."""
    if mode == "none":
        return grads, residual, ("none", None)
    leaves, treedef = jax.tree.flatten(grads)
    res_leaves = treedef.flatten_up_to(residual) if residual is not None \
        else [jnp.zeros(g.shape, jnp.float32) for g in leaves]

    if mode == "bf16":
        comped, new_res = [], []
        for g, r in zip(leaves, res_leaves):
            tot = g.astype(jnp.float32) + r
            q = tot.astype(jnp.bfloat16)
            comped.append(q)
            new_res.append(tot - q.astype(jnp.float32))
        return (treedef.unflatten(comped), treedef.unflatten(new_res),
                ("bf16", None))
    if mode == "int8":
        comped, new_res, scales = [], [], []
        for g, r in zip(leaves, res_leaves):
            tot = g.astype(jnp.float32) + r
            scale = jnp.maximum(jnp.max(jnp.abs(tot)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(tot / scale), -127, 127).astype(jnp.int8)
            comped.append(q)
            scales.append(scale)
            new_res.append(tot - q.astype(jnp.float32) * scale)
        return (treedef.unflatten(comped), treedef.unflatten(new_res),
                ("int8", scales))
    raise ValueError(mode)


def decompress_grads(comped, meta):
    mode, scales = meta if isinstance(meta, tuple) else (meta, None)
    if mode in (None, "none"):
        return comped
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.float32), comped)
    if mode == "int8":
        leaves, treedef = jax.tree.flatten(comped)
        return treedef.unflatten([
            q.astype(jnp.float32) * s for q, s in zip(leaves, scales)])
    raise ValueError(mode)
