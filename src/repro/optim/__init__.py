from repro.optim.adamw import (  # noqa: F401
    AdamWConfig, adamw_init, adamw_update, global_norm, clip_by_global_norm,
    wsd_schedule,
)
from repro.optim.compression import compress_grads, decompress_grads  # noqa: F401
