"""Paged KV cache whose page table is a CacheHash of big atomics.

This is the framework's flagship application of the paper (DESIGN.md §3):
the page table maps a logical page key  (seq_id << 20 | page_no)  to a
physical page index.  Every lookup is a CacheHash find — with big atomics the
common case is ONE gather of the inlined bucket cell; the Chaining baseline
(strategy comparison in the benchmarks) pays a second dependent gather per
lookup.  Page allocation / release are CacheHash insert / delete, i.e.
CAS-installs on the bucket big atomics, giving lock-free page-table updates
that never block concurrent lookups (decode of other sequences).

Physical pages live in one pool per layer-kind:
    attn pages: [n_layers, n_pages, page_size, kvh, hd]  (k and v pools)
    recurrent state (ssm / rglru): dense per-slot arrays (fixed size, no
    paging needed — one "page" per live sequence).

`lookup_pages` returns, per sequence, the physical page list padded to
max_pages — the gather that decode attention consumes.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cachehash as ch
from repro.models.common import ModelConfig
from repro.sync.queue import BigQueue

SEQ_SHIFT = 20                     # key = seq_id << 20 | page_no
PAGE_MASK = (1 << SEQ_SHIFT) - 1


class PagedKV(NamedTuple):
    table: ch.HashState            # page table (big-atomic CacheHash)
    strategy: str                  # big-atomic strategy of table + free ring
    k_pages: jax.Array             # [L_attn, n_pages, P, kvh, hd]
    v_pages: jax.Array
    states: dict                   # recurrent per-slot states (ssm/rglru)
    free: BigQueue                 # physical pages wait in a big-atomic
    #                                MPMC ring (alloc = dequeue, DESIGN.md §4)
    #                                NOTE: mutated in place — unlike the
    #                                array fields, `free` is shared across
    #                                `_replace` copies, so a PagedKV is not a
    #                                snapshot; the engine is its sole owner.
    page_size: int


def page_key(seq_id, page_no):
    return (jnp.asarray(seq_id, jnp.uint32) << SEQ_SHIFT) | \
        jnp.asarray(page_no, jnp.uint32)


def init_paged(cfg: ModelConfig, n_pages: int, page_size: int,
               max_seqs: int, strategy: str = "cached_me") -> PagedKV:
    kinds = cfg.layer_kinds
    l_attn = sum(k == "attn" for k in kinds)
    dt = cfg.cdtype()
    kv = (l_attn, n_pages, page_size, cfg.n_kv_heads, cfg.hd)
    nb = 1
    while nb < 2 * n_pages:
        nb *= 2
    table = ch.init(nb, vw=1, strategy=strategy, p_max=max(max_seqs, 64))
    states = {}
    from repro.models import rglru as rglru_mod
    from repro.models import ssm as ssm_mod
    for j, kind in enumerate(kinds):
        if kind == "ssm":
            states[f"layer{j}"] = ssm_mod.init_ssm_cache(max_seqs, cfg, dt)
        elif kind == "rglru":
            states[f"layer{j}"] = rglru_mod.init_rglru_cache(max_seqs, cfg, dt)
    # Descending order preserves the old LIFO head's allocation sequence.
    free = BigQueue(max(n_pages, 2), k=2, strategy=strategy,
                    p_max=max(max_seqs, 64),
                    initial_items=np.arange(n_pages - 1, -1, -1,
                                            dtype=np.uint32))
    return PagedKV(
        table=table,
        strategy=str(strategy),
        k_pages=jnp.zeros(kv, dt),
        v_pages=jnp.zeros(kv, dt),
        states=states,
        free=free,
        page_size=page_size,
    )


# ---------------------------------------------------------------------------
# Page-table ops (all go through the big-atomic CacheHash)
# ---------------------------------------------------------------------------

def alloc_pages(paged: PagedKV, seq_ids, page_nos) -> tuple[PagedKV, jax.Array]:
    """Map (seq, page_no) -> fresh physical pages via CacheHash insert
    (a CAS-install on the bucket big atomic).  Physical pages come off the
    big-atomic free ring (LL/SC dequeues).  Returns (state', phys[q])."""
    q = len(seq_ids)
    if q > len(paged.free):
        raise RuntimeError(f"out of KV pages ({q} wanted, "
                           f"{len(paged.free)} free)")
    vals, ok = paged.free.dequeue_batch(q)
    assert ok.all()                       # guarded by the length check above
    phys = vals[:, 0].astype(np.int32)
    keys = page_key(jnp.asarray(seq_ids, jnp.uint32),
                    jnp.asarray(page_nos, jnp.uint32))
    ops = ch.OpBatch(jnp.full((q,), ch.INSERT, jnp.int32), keys,
                     jnp.asarray(phys[:, None], jnp.uint32))
    table, res, _ = ch.apply_hash_ops(paged.table, ops, strategy=paged.strategy,
                                      inline=True, vw=1)
    return paged._replace(table=table), jnp.asarray(phys)


def lookup_pages(paged: PagedKV, seq_ids, n_pages_per_seq: int):
    """Batched page-table lookup: seq b, pages 0..max -> phys[b, max]
    (-1 where unmapped).  The hot path: one CacheHash find per (seq, page),
    inlined-bucket fast path."""
    seq_ids = jnp.asarray(seq_ids, jnp.uint32)
    b = seq_ids.shape[0]
    pages = jnp.arange(n_pages_per_seq, dtype=jnp.uint32)
    keys = page_key(seq_ids[:, None], pages[None, :]).reshape(-1)
    ops = ch.OpBatch(jnp.full((keys.shape[0],), ch.FIND, jnp.int32), keys,
                     jnp.zeros((keys.shape[0], 1), jnp.uint32))
    table, res, _ = ch.apply_hash_ops(paged.table, ops, strategy=paged.strategy,
                                      inline=True, vw=1)
    phys = jnp.where(res.found, res.value[:, 0].astype(jnp.int32), -1)
    return paged._replace(table=table), phys.reshape(b, n_pages_per_seq)


def free_pages(paged: PagedKV, seq_id: int, n_pages_used: int) -> PagedKV:
    """Release a finished sequence's pages: CacheHash delete (path-copying
    CAS) + host free-list push."""
    if n_pages_used == 0:
        return paged
    pages = np.arange(n_pages_used, dtype=np.uint32)
    keys = page_key(jnp.full((n_pages_used,), seq_id, jnp.uint32),
                    jnp.asarray(pages))
    find_ops = ch.OpBatch(jnp.full((n_pages_used,), ch.FIND, jnp.int32),
                          keys, jnp.zeros((n_pages_used, 1), jnp.uint32))
    table, res, _ = ch.apply_hash_ops(paged.table, find_ops,
                                      strategy=paged.strategy, inline=True, vw=1)
    phys = np.asarray(res.value[:, 0], np.int32)[np.asarray(res.found)]
    del_ops = ch.OpBatch(jnp.full((n_pages_used,), ch.DELETE, jnp.int32),
                         keys, jnp.zeros((n_pages_used, 1), jnp.uint32))
    table, _, _ = ch.apply_hash_ops(table, del_ops, strategy=paged.strategy,
                                    inline=True, vw=1)
    if len(phys):
        ok = paged.free.enqueue_batch(phys.astype(np.uint32))
        assert ok.all()                   # ring is sized to hold every page
    return paged._replace(table=table)


# ---------------------------------------------------------------------------
# Physical page I/O
# ---------------------------------------------------------------------------

def write_prompt(paged: PagedKV, phys_pages, layer_k, layer_v) -> PagedKV:
    """Scatter a prompt's K/V into its pages.  layer_k/v: [L_attn, T, kvh, hd]
    (batch of one sequence); phys_pages: int32[ceil(T/P)]."""
    P = paged.page_size
    L, T = layer_k.shape[0], layer_k.shape[1]
    n_full = T // P
    k_pages, v_pages = paged.k_pages, paged.v_pages
    if n_full:
        kk = layer_k[:, :n_full * P].reshape(L, n_full, P, *layer_k.shape[2:])
        vv = layer_v[:, :n_full * P].reshape(L, n_full, P, *layer_v.shape[2:])
        k_pages = k_pages.at[:, phys_pages[:n_full]].set(kk)
        v_pages = v_pages.at[:, phys_pages[:n_full]].set(vv)
    rem = T - n_full * P
    if rem:
        k_pages = k_pages.at[:, phys_pages[n_full], :rem].set(
            layer_k[:, n_full * P:])
        v_pages = v_pages.at[:, phys_pages[n_full], :rem].set(
            layer_v[:, n_full * P:])
    return paged._replace(k_pages=k_pages, v_pages=v_pages)


def append_token(paged: PagedKV, phys_page, offset, k_tok, v_tok) -> PagedKV:
    """Write one new token's K/V for a batch of sequences.
    phys_page: int32[b]; offset: int32[b] in [0, P); k/v_tok:
    [L_attn, b, kvh, hd]."""
    L = k_tok.shape[0]
    b = k_tok.shape[1]
    li = jnp.arange(L)[:, None].repeat(b, 1).reshape(-1)
    pi = jnp.broadcast_to(phys_page[None], (L, b)).reshape(-1)
    oi = jnp.broadcast_to(offset[None], (L, b)).reshape(-1)
    k_pages = paged.k_pages.at[li, pi, oi].set(
        k_tok.reshape(-1, *k_tok.shape[2:]))
    v_pages = paged.v_pages.at[li, pi, oi].set(
        v_tok.reshape(-1, *v_tok.shape[2:]))
    return paged._replace(k_pages=k_pages, v_pages=v_pages)


def gather_kv(paged: PagedKV, phys: jax.Array):
    """phys: int32[b, max_pages] (-1 pad) -> K/V [L, b, max_pages*P, kvh, hd]
    plus a validity mask [b, max_pages*P].  One gather per decode step — on
    TPU this is the page-granular DMA stream paged attention feeds on."""
    b, mp = phys.shape
    P = paged.page_size
    safe = jnp.maximum(phys, 0)
    k = paged.k_pages[:, safe]            # [L, b, mp, P, kvh, hd]
    v = paged.v_pages[:, safe]
    L = k.shape[0]
    k = k.reshape(L, b, mp * P, *k.shape[4:])
    v = v.reshape(L, b, mp * P, *v.shape[4:])
    valid = jnp.repeat(phys >= 0, P, axis=1)
    return k, v, valid
