"""Paged KV cache whose page table is a CacheHash of big atomics.

This is the framework's flagship application of the paper (DESIGN.md §3):
the page table maps a logical page key  (seq_id << 20 | page_no)  to a
physical page index.  Every lookup is a CacheHash find — with big atomics the
common case is ONE gather of the inlined bucket cell; the Chaining baseline
(strategy comparison in the benchmarks) pays a second dependent gather per
lookup.  Page allocation / release are CacheHash insert / delete, i.e.
CAS-installs on the bucket big atomics, giving lock-free page-table updates
that never block concurrent lookups (decode of other sequences).

v2 split (DESIGN.md §5): the static shape lives in a frozen `PagedSpec`
(hash spec + free-ring spec + page geometry) and the device state in
`PagedState`, a PURE pytree (page-table `HashState` + page pools) — so the
whole decode data path (`lookup_and_gather` + `append_token_fn`) traces
inside one `jax.jit` program (the serving engine's fused step).  `PagedKV`
is the host-side owner tying spec + state to the big-atomic free ring
(`BigQueue`, a host retry driver) and the dense recurrent slot states.

Physical pages live in one pool per layer-kind:
    attn pages: [n_layers, n_pages, page_size, kvh, hd]  (k and v pools)
    recurrent state (ssm / rglru): dense per-slot arrays (fixed size, no
    paging needed — one "page" per live sequence).

`lookup_pages` returns, per sequence, the physical page list padded to
max_pages — the gather that decode attention consumes.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cachehash as ch
from repro.core import distributed as dsb
from repro.core import engine
from repro.core.specs import DEFAULT_STRATEGY, HashSpec, QueueSpec
from repro.models.common import ModelConfig
from repro.sync.queue import BigQueue

SEQ_SHIFT = 20                     # key = seq_id << 20 | page_no
PAGE_MASK = (1 << SEQ_SHIFT) - 1


@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """Static geometry of the paged cache (the fused step's only static).

    With `n_shards > 1` the page table is a mesh-sharded CacheHash
    (`core.distributed`): every page-table batch — decode lookups inside the
    fused step, admission inserts, retirement deletes — routes by key owner
    over `axis` and each shard applies its slice with its own node pool.
    """

    n_pages: int
    page_size: int
    max_seqs: int
    table: HashSpec
    ring: QueueSpec
    n_shards: int = 1
    axis: str = "shard"


class PagedState(NamedTuple):
    """Pure pytree: page table + physical pools; flows through `jax.jit`."""

    table: object                  # page table: ch.HashState, or a sharded
    #                                dsb.DistState when spec.n_shards > 1
    k_pages: jax.Array             # [L_attn, n_pages, P, kvh, hd]
    v_pages: jax.Array


@dataclasses.dataclass
class PagedKV:
    """Host-side owner: spec + pytree state + big-atomic free ring.

    `free` (the physical-page MPMC ring) and `states` (dense recurrent
    slots) are host-managed; the engine is the sole owner, and the mutating
    module functions below return `self` for the functional call style the
    v1 API established."""

    spec: PagedSpec
    state: PagedState
    states: dict
    free: BigQueue
    mesh: object = None            # jax Mesh when spec.n_shards > 1

    @property
    def page_size(self) -> int:
        return self.spec.page_size

    @property
    def strategy(self) -> str:
        return self.spec.table.strategy


def page_key(seq_id, page_no):
    return (jnp.asarray(seq_id, jnp.uint32) << SEQ_SHIFT) | \
        jnp.asarray(page_no, jnp.uint32)


def make_spec(cfg: ModelConfig, n_pages: int, page_size: int, max_seqs: int,
              strategy: str = DEFAULT_STRATEGY, *, n_shards: int = 1,
              axis: str = "shard") -> PagedSpec:
    if n_shards & (n_shards - 1):
        raise ValueError(f"n_shards must be a power of two (the page table "
                         f"is a power-of-two CacheHash): {n_shards}")
    nb = 1
    while nb < max(2 * n_pages, n_shards):
        nb *= 2
    return PagedSpec(
        n_pages=n_pages, page_size=page_size, max_seqs=max_seqs,
        table=HashSpec(nb, vw=1, strategy=strategy,
                       p_max=max(max_seqs, 64)),
        ring=QueueSpec(max(n_pages, 2), k=2, strategy=strategy,
                       p_max=max(max_seqs, 64)),
        n_shards=n_shards, axis=axis)


def init(cfg: ModelConfig, spec: PagedSpec, mesh=None) -> PagedKV:
    kinds = cfg.layer_kinds
    l_attn = sum(k == "attn" for k in kinds)
    dt = cfg.cdtype()
    kv = (l_attn, spec.n_pages, spec.page_size, cfg.n_kv_heads, cfg.hd)
    if spec.n_shards > 1:
        if mesh is None:
            raise ValueError("spec.n_shards > 1 requires a mesh")
        table = dsb.init_dist(mesh, _table_dspec(spec, spec.n_shards))
    else:
        table = ch.init_hash(spec.table)
    states = {}
    from repro.models import rglru as rglru_mod
    from repro.models import ssm as ssm_mod
    for j, kind in enumerate(kinds):
        if kind == "ssm":
            states[f"layer{j}"] = ssm_mod.init_ssm_cache(spec.max_seqs, cfg, dt)
        elif kind == "rglru":
            states[f"layer{j}"] = rglru_mod.init_rglru_cache(spec.max_seqs,
                                                             cfg, dt)
    # Descending order preserves the old LIFO head's allocation sequence.
    free = BigQueue(spec=spec.ring,
                    initial_items=np.arange(spec.n_pages - 1, -1, -1,
                                            dtype=np.uint32),
                    mesh=mesh, shard_axis=spec.axis, n_shards=spec.n_shards)
    state = PagedState(table=table, k_pages=jnp.zeros(kv, dt),
                       v_pages=jnp.zeros(kv, dt))
    return PagedKV(spec=spec, state=state, states=states, free=free,
                   mesh=mesh)


def init_paged(cfg: ModelConfig, n_pages: int, page_size: int,
               max_seqs: int, strategy: str = None) -> PagedKV:
    """DEPRECATED shim: use `init(cfg, make_spec(...))`."""
    return init(cfg, make_spec(cfg, n_pages, page_size, max_seqs,
                               strategy or DEFAULT_STRATEGY))


# ---------------------------------------------------------------------------
# Pure (traceable) page-table ops — the fused decode step composes these.
# ---------------------------------------------------------------------------

def _table_dspec(spec: PagedSpec, q: int) -> dsb.DistSpec:
    """DistSpec for a q-lane page-table batch (q a multiple of n_shards).
    The default route capacity (p_local) can never overflow: a source owns
    only p_local lanes, so no (src, dst) pair exceeds it."""
    return dsb.DistSpec(spec.table, spec.axis, spec.n_shards,
                        q // spec.n_shards)


def _hash_apply(spec: PagedSpec, table, kind, keys, values=None, mesh=None):
    """One page-table batch on the local or mesh-sharded CacheHash.
    Returns (table', HashResult)."""
    kind = jnp.asarray(kind, jnp.int32)
    keys = jnp.asarray(keys, jnp.uint32)
    q = keys.shape[0]
    if values is None:
        values = jnp.zeros((q, 1), jnp.uint32)
    ops = ch.make_hash_ops(kind, keys, values, vw=1)
    if spec.n_shards == 1:
        table, res, _ = ch.apply_hash(spec.table, table, ops)
        return table, res
    # dist.apply_hash IDLE-pads the lane axis up to p_global and trims the
    # results back; we only round the spec width to a shard multiple.
    q_pad = -(-q // spec.n_shards) * spec.n_shards
    table, res, _overflow = dsb.apply_hash(mesh, _table_dspec(spec, q_pad),
                                           table, ops)
    return table, res


def lookup_and_gather(spec: PagedSpec, pstate: PagedState, seq_ids,
                      n_pages_per_seq: int, mesh=None):
    """Batched page-table lookup + KV gather, fully traceable: one CacheHash
    find per (seq, page) — inlined-bucket fast path, key-owner-routed when
    the table is sharded — then the page-granular gather decode attention
    feeds on.  Returns (pstate', phys[b, n_pages_per_seq], k, v, valid)."""
    seq_ids = jnp.asarray(seq_ids, jnp.uint32)
    b = seq_ids.shape[0]
    pages = jnp.arange(n_pages_per_seq, dtype=jnp.uint32)
    keys = page_key(seq_ids[:, None], pages[None, :]).reshape(-1)
    table, res = _hash_apply(
        spec, pstate.table,
        jnp.full((keys.shape[0],), engine.FIND, jnp.int32), keys, mesh=mesh)
    phys = jnp.where(res.found, res.value[:, 0].astype(jnp.int32), -1)
    phys = phys.reshape(b, n_pages_per_seq)
    pstate = pstate._replace(table=table)
    k, v, valid = gather_fn(spec, pstate, phys)
    return pstate, phys, k, v, valid


def gather_fn(spec: PagedSpec, pstate: PagedState, phys: jax.Array):
    """phys: int32[b, max_pages] (-1 pad) -> K/V [L, b, max_pages*P, kvh, hd]
    plus a validity mask [b, max_pages*P].  One gather per decode step — on
    TPU this is the page-granular DMA stream paged attention feeds on."""
    b, mp = phys.shape
    P = spec.page_size
    safe = jnp.maximum(phys, 0)
    k = pstate.k_pages[:, safe]            # [L, b, mp, P, kvh, hd]
    v = pstate.v_pages[:, safe]
    L = k.shape[0]
    k = k.reshape(L, b, mp * P, *k.shape[4:])
    v = v.reshape(L, b, mp * P, *v.shape[4:])
    valid = jnp.repeat(phys >= 0, P, axis=1)
    return k, v, valid


def append_token_fn(spec: PagedSpec, pstate: PagedState, phys_page, offset,
                    k_tok, v_tok) -> PagedState:
    """Write one new token's K/V for a batch of sequences (traceable).
    phys_page: int32[b]; offset: int32[b] in [0, P); k/v_tok:
    [L_attn, b, kvh, hd]."""
    L = k_tok.shape[0]
    b = k_tok.shape[1]
    li = jnp.arange(L)[:, None].repeat(b, 1).reshape(-1)
    pi = jnp.broadcast_to(phys_page[None], (L, b)).reshape(-1)
    oi = jnp.broadcast_to(offset[None], (L, b)).reshape(-1)
    k_pages = pstate.k_pages.at[li, pi, oi].set(
        k_tok.reshape(-1, *k_tok.shape[2:]))
    v_pages = pstate.v_pages.at[li, pi, oi].set(
        v_tok.reshape(-1, *v_tok.shape[2:]))
    return pstate._replace(k_pages=k_pages, v_pages=v_pages)


# ---------------------------------------------------------------------------
# Host-side page lifecycle (admission / retirement, big-atomic free ring)
# ---------------------------------------------------------------------------

def txn_bookkeep(paged: PagedKV, retires, allocs):
    """One decode step's page-table bookkeeping as ONE transaction
    (DESIGN.md §7): retirement deletes + page-boundary inserts commit
    all-or-nothing through the transactional map (`repro.txn.map`), with
    the retired mappings as the transaction's read/validation set.  On a
    sharded page table the commit rides the key-owner-routed collective
    (`transact_dist`), so cross-shard bookkeeping stays atomic.

    retires: [(seq_id, n_pages_used)]; allocs: [(seq_id, page_no)].
    Returns (paged, phys int32[len(allocs)]).  Freed physical pages recycle
    onto the big-atomic ring BEFORE the alloc dequeues, so a same-step
    retire+alloc never starves the pool."""
    from repro.txn import map as txn_map
    q_alloc = len(allocs)
    ret_keys: list[int] = []
    for seq_id, used in retires:
        ret_keys += [int(page_key(seq_id, p)) for p in range(used)]
    if not ret_keys and not q_alloc:
        return paged, jnp.zeros((0,), jnp.int32)
    # Pre-read the retired mappings (the transaction re-reads and validates
    # the same keys) to recycle their physical pages.
    if ret_keys:
        table, res = _hash_apply(
            paged.spec, paged.state.table,
            jnp.full((len(ret_keys),), engine.FIND, jnp.int32),
            jnp.asarray(ret_keys, jnp.uint32), mesh=paged.mesh)
        paged.state = paged.state._replace(table=table)
        freed = np.asarray(res.value[:, 0], np.uint32)[np.asarray(res.found)]
        if len(freed):
            ok = paged.free.enqueue_batch(freed)
            assert ok.all()               # ring is sized to hold every page
    if q_alloc > len(paged.free):
        raise RuntimeError(f"out of KV pages ({q_alloc} wanted, "
                           f"{len(paged.free)} free)")
    phys = np.zeros((0,), np.int32)
    if q_alloc:
        vals, ok = paged.free.dequeue_batch(q_alloc)
        assert ok.all()                   # guarded by the length check above
        phys = vals[:, 0].astype(np.int32)
    alloc_keys = [int(page_key(s, p)) for s, p in allocs]
    w = len(ret_keys) + q_alloc
    wval = np.zeros((1, w, 1), np.uint32)
    wval[0, len(ret_keys):, 0] = phys
    txns = txn_map.make_map_txns(
        np.asarray(ret_keys or [0], np.uint32)[None],
        np.asarray(ret_keys + alloc_keys, np.uint32)[None],
        read_mask=np.asarray([bool(ret_keys)] * max(len(ret_keys), 1))[None],
        write_del=np.asarray([True] * len(ret_keys)
                             + [False] * q_alloc)[None],
        write_value=wval)
    if paged.spec.n_shards == 1:
        table, _res = txn_map.transact(paged.spec.table, paged.state.table,
                                       txns, None)
    else:
        table, _res = txn_map.transact_dist(
            paged.mesh, _table_dspec(paged.spec, paged.spec.n_shards),
            paged.state.table, txns, None)
    paged.state = paged.state._replace(table=table)
    return paged, jnp.asarray(phys)


def alloc_pages(paged: PagedKV, seq_ids, page_nos) -> tuple[PagedKV, jax.Array]:
    """Map (seq, page_no) -> fresh physical pages via CacheHash insert
    (a CAS-install on the bucket big atomic).  Physical pages come off the
    big-atomic free ring (LL/SC dequeues).  Returns (state', phys[q])."""
    q = len(seq_ids)
    if q > len(paged.free):
        raise RuntimeError(f"out of KV pages ({q} wanted, "
                           f"{len(paged.free)} free)")
    vals, ok = paged.free.dequeue_batch(q)
    assert ok.all()                       # guarded by the length check above
    phys = vals[:, 0].astype(np.int32)
    keys = page_key(jnp.asarray(seq_ids, jnp.uint32),
                    jnp.asarray(page_nos, jnp.uint32))
    table, res = _hash_apply(
        paged.spec, paged.state.table,
        jnp.full((q,), engine.INSERT, jnp.int32), keys,
        jnp.asarray(phys[:, None], jnp.uint32), mesh=paged.mesh)
    paged.state = paged.state._replace(table=table)
    return paged, jnp.asarray(phys)


def lookup_pages(paged: PagedKV, seq_ids, n_pages_per_seq: int):
    """Batched page-table lookup: seq b, pages 0..max -> phys[b, max]
    (-1 where unmapped).  The hot path: one CacheHash find per (seq, page),
    inlined-bucket fast path."""
    seq_ids = jnp.asarray(seq_ids, jnp.uint32)
    b = seq_ids.shape[0]
    pages = jnp.arange(n_pages_per_seq, dtype=jnp.uint32)
    keys = page_key(seq_ids[:, None], pages[None, :]).reshape(-1)
    table, res = _hash_apply(
        paged.spec, paged.state.table,
        jnp.full((keys.shape[0],), engine.FIND, jnp.int32), keys,
        mesh=paged.mesh)
    phys = jnp.where(res.found, res.value[:, 0].astype(jnp.int32), -1)
    paged.state = paged.state._replace(table=table)
    return paged, phys.reshape(b, n_pages_per_seq)


def free_pages(paged: PagedKV, seq_id: int, n_pages_used: int) -> PagedKV:
    """Release a finished sequence's pages: CacheHash delete (path-copying
    CAS) + big-atomic free-ring push."""
    if n_pages_used == 0:
        return paged
    pages = np.arange(n_pages_used, dtype=np.uint32)
    keys = page_key(jnp.full((n_pages_used,), seq_id, jnp.uint32),
                    jnp.asarray(pages))
    table, res = _hash_apply(
        paged.spec, paged.state.table,
        jnp.full((n_pages_used,), engine.FIND, jnp.int32), keys,
        mesh=paged.mesh)
    phys = np.asarray(res.value[:, 0], np.int32)[np.asarray(res.found)]
    table, _ = _hash_apply(
        paged.spec, table,
        jnp.full((n_pages_used,), engine.DELETE, jnp.int32), keys,
        mesh=paged.mesh)
    if len(phys):
        ok = paged.free.enqueue_batch(phys.astype(np.uint32))
        assert ok.all()                   # ring is sized to hold every page
    paged.state = paged.state._replace(table=table)
    return paged


# ---------------------------------------------------------------------------
# Physical page I/O (host call style; the fused step uses the *_fn forms)
# ---------------------------------------------------------------------------

def write_prompt(paged: PagedKV, phys_pages, layer_k, layer_v) -> PagedKV:
    """Scatter a prompt's K/V into its pages.  layer_k/v: [L_attn, T, kvh, hd]
    (batch of one sequence); phys_pages: int32[ceil(T/P)]."""
    P = paged.page_size
    L, T = layer_k.shape[0], layer_k.shape[1]
    n_full = T // P
    k_pages, v_pages = paged.state.k_pages, paged.state.v_pages
    if n_full:
        kk = layer_k[:, :n_full * P].reshape(L, n_full, P, *layer_k.shape[2:])
        vv = layer_v[:, :n_full * P].reshape(L, n_full, P, *layer_v.shape[2:])
        k_pages = k_pages.at[:, phys_pages[:n_full]].set(kk)
        v_pages = v_pages.at[:, phys_pages[:n_full]].set(vv)
    rem = T - n_full * P
    if rem:
        k_pages = k_pages.at[:, phys_pages[n_full], :rem].set(
            layer_k[:, n_full * P:])
        v_pages = v_pages.at[:, phys_pages[n_full], :rem].set(
            layer_v[:, n_full * P:])
    paged.state = paged.state._replace(k_pages=k_pages, v_pages=v_pages)
    return paged


def append_token(paged: PagedKV, phys_page, offset, k_tok, v_tok) -> PagedKV:
    """Write one new token's K/V for a batch of sequences (host call)."""
    paged.state = append_token_fn(paged.spec, paged.state, phys_page, offset,
                                  k_tok, v_tok)
    return paged


def gather_kv(paged: PagedKV, phys: jax.Array):
    """Host-call form of `gather_fn` (v1 signature)."""
    return gather_fn(paged.spec, paged.state, phys)
