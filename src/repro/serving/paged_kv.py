"""Paged KV cache whose page table is a CacheHash of big atomics.

This is the framework's flagship application of the paper (DESIGN.md §3):
the page table maps a logical page key  (seq_id << 20 | page_no)  to a
physical page index.  Every lookup is a CacheHash find — with big atomics the
common case is ONE gather of the inlined bucket cell; the Chaining baseline
(strategy comparison in the benchmarks) pays a second dependent gather per
lookup.  Page allocation / release are CacheHash insert / delete, i.e.
CAS-installs on the bucket big atomics, giving lock-free page-table updates
that never block concurrent lookups (decode of other sequences).

v2 split (DESIGN.md §5): the static shape lives in a frozen `PagedSpec`
(hash spec + free-ring spec + page geometry) and the device state in
`PagedState`, a PURE pytree (page-table `HashState` + page pools) — so the
whole decode data path (`lookup_and_gather` + `append_token_fn`) traces
inside one `jax.jit` program (the serving engine's fused step).  `PagedKV`
is the host-side owner tying spec + state to the big-atomic free ring
(`BigQueue`, a host retry driver) and the dense recurrent slot states.

Physical pages live in one pool per layer-kind:
    attn pages: [n_layers, n_pages, page_size, kvh, hd]  (k and v pools)
    recurrent state (ssm / rglru): dense per-slot arrays (fixed size, no
    paging needed — one "page" per live sequence).

`lookup_pages` returns, per sequence, the physical page list padded to
max_pages — the gather that decode attention consumes.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cachehash as ch
from repro.core import engine
from repro.core.specs import DEFAULT_STRATEGY, HashSpec, QueueSpec
from repro.models.common import ModelConfig
from repro.sync.queue import BigQueue

SEQ_SHIFT = 20                     # key = seq_id << 20 | page_no
PAGE_MASK = (1 << SEQ_SHIFT) - 1


@dataclasses.dataclass(frozen=True)
class PagedSpec:
    """Static geometry of the paged cache (the fused step's only static)."""

    n_pages: int
    page_size: int
    max_seqs: int
    table: HashSpec
    ring: QueueSpec


class PagedState(NamedTuple):
    """Pure pytree: page table + physical pools; flows through `jax.jit`."""

    table: ch.HashState            # page table (big-atomic CacheHash)
    k_pages: jax.Array             # [L_attn, n_pages, P, kvh, hd]
    v_pages: jax.Array


@dataclasses.dataclass
class PagedKV:
    """Host-side owner: spec + pytree state + big-atomic free ring.

    `free` (the physical-page MPMC ring) and `states` (dense recurrent
    slots) are host-managed; the engine is the sole owner, and the mutating
    module functions below return `self` for the functional call style the
    v1 API established."""

    spec: PagedSpec
    state: PagedState
    states: dict
    free: BigQueue

    @property
    def page_size(self) -> int:
        return self.spec.page_size

    @property
    def strategy(self) -> str:
        return self.spec.table.strategy


def page_key(seq_id, page_no):
    return (jnp.asarray(seq_id, jnp.uint32) << SEQ_SHIFT) | \
        jnp.asarray(page_no, jnp.uint32)


def make_spec(cfg: ModelConfig, n_pages: int, page_size: int, max_seqs: int,
              strategy: str = DEFAULT_STRATEGY) -> PagedSpec:
    nb = 1
    while nb < 2 * n_pages:
        nb *= 2
    return PagedSpec(
        n_pages=n_pages, page_size=page_size, max_seqs=max_seqs,
        table=HashSpec(nb, vw=1, strategy=strategy,
                       p_max=max(max_seqs, 64)),
        ring=QueueSpec(max(n_pages, 2), k=2, strategy=strategy,
                       p_max=max(max_seqs, 64)))


def init(cfg: ModelConfig, spec: PagedSpec) -> PagedKV:
    kinds = cfg.layer_kinds
    l_attn = sum(k == "attn" for k in kinds)
    dt = cfg.cdtype()
    kv = (l_attn, spec.n_pages, spec.page_size, cfg.n_kv_heads, cfg.hd)
    table = ch.init_hash(spec.table)
    states = {}
    from repro.models import rglru as rglru_mod
    from repro.models import ssm as ssm_mod
    for j, kind in enumerate(kinds):
        if kind == "ssm":
            states[f"layer{j}"] = ssm_mod.init_ssm_cache(spec.max_seqs, cfg, dt)
        elif kind == "rglru":
            states[f"layer{j}"] = rglru_mod.init_rglru_cache(spec.max_seqs,
                                                             cfg, dt)
    # Descending order preserves the old LIFO head's allocation sequence.
    free = BigQueue(spec=spec.ring,
                    initial_items=np.arange(spec.n_pages - 1, -1, -1,
                                            dtype=np.uint32))
    state = PagedState(table=table, k_pages=jnp.zeros(kv, dt),
                       v_pages=jnp.zeros(kv, dt))
    return PagedKV(spec=spec, state=state, states=states, free=free)


def init_paged(cfg: ModelConfig, n_pages: int, page_size: int,
               max_seqs: int, strategy: str = None) -> PagedKV:
    """DEPRECATED shim: use `init(cfg, make_spec(...))`."""
    return init(cfg, make_spec(cfg, n_pages, page_size, max_seqs,
                               strategy or DEFAULT_STRATEGY))


# ---------------------------------------------------------------------------
# Pure (traceable) page-table ops — the fused decode step composes these.
# ---------------------------------------------------------------------------

def lookup_and_gather(spec: PagedSpec, pstate: PagedState, seq_ids,
                      n_pages_per_seq: int):
    """Batched page-table lookup + KV gather, fully traceable: one CacheHash
    find per (seq, page) — inlined-bucket fast path — then the page-granular
    gather decode attention feeds on.  Returns
    (pstate', phys[b, n_pages_per_seq], k, v, valid)."""
    seq_ids = jnp.asarray(seq_ids, jnp.uint32)
    b = seq_ids.shape[0]
    pages = jnp.arange(n_pages_per_seq, dtype=jnp.uint32)
    keys = page_key(seq_ids[:, None], pages[None, :]).reshape(-1)
    ops = ch.make_hash_ops(
        jnp.full((keys.shape[0],), engine.FIND, jnp.int32), keys, vw=1)
    table, res, _ = ch.apply_hash(spec.table, pstate.table, ops)
    phys = jnp.where(res.found, res.value[:, 0].astype(jnp.int32), -1)
    phys = phys.reshape(b, n_pages_per_seq)
    pstate = pstate._replace(table=table)
    k, v, valid = gather_fn(spec, pstate, phys)
    return pstate, phys, k, v, valid


def gather_fn(spec: PagedSpec, pstate: PagedState, phys: jax.Array):
    """phys: int32[b, max_pages] (-1 pad) -> K/V [L, b, max_pages*P, kvh, hd]
    plus a validity mask [b, max_pages*P].  One gather per decode step — on
    TPU this is the page-granular DMA stream paged attention feeds on."""
    b, mp = phys.shape
    P = spec.page_size
    safe = jnp.maximum(phys, 0)
    k = pstate.k_pages[:, safe]            # [L, b, mp, P, kvh, hd]
    v = pstate.v_pages[:, safe]
    L = k.shape[0]
    k = k.reshape(L, b, mp * P, *k.shape[4:])
    v = v.reshape(L, b, mp * P, *v.shape[4:])
    valid = jnp.repeat(phys >= 0, P, axis=1)
    return k, v, valid


def append_token_fn(spec: PagedSpec, pstate: PagedState, phys_page, offset,
                    k_tok, v_tok) -> PagedState:
    """Write one new token's K/V for a batch of sequences (traceable).
    phys_page: int32[b]; offset: int32[b] in [0, P); k/v_tok:
    [L_attn, b, kvh, hd]."""
    L = k_tok.shape[0]
    b = k_tok.shape[1]
    li = jnp.arange(L)[:, None].repeat(b, 1).reshape(-1)
    pi = jnp.broadcast_to(phys_page[None], (L, b)).reshape(-1)
    oi = jnp.broadcast_to(offset[None], (L, b)).reshape(-1)
    k_pages = pstate.k_pages.at[li, pi, oi].set(
        k_tok.reshape(-1, *k_tok.shape[2:]))
    v_pages = pstate.v_pages.at[li, pi, oi].set(
        v_tok.reshape(-1, *v_tok.shape[2:]))
    return pstate._replace(k_pages=k_pages, v_pages=v_pages)


# ---------------------------------------------------------------------------
# Host-side page lifecycle (admission / retirement, big-atomic free ring)
# ---------------------------------------------------------------------------

def alloc_pages(paged: PagedKV, seq_ids, page_nos) -> tuple[PagedKV, jax.Array]:
    """Map (seq, page_no) -> fresh physical pages via CacheHash insert
    (a CAS-install on the bucket big atomic).  Physical pages come off the
    big-atomic free ring (LL/SC dequeues).  Returns (state', phys[q])."""
    q = len(seq_ids)
    if q > len(paged.free):
        raise RuntimeError(f"out of KV pages ({q} wanted, "
                           f"{len(paged.free)} free)")
    vals, ok = paged.free.dequeue_batch(q)
    assert ok.all()                       # guarded by the length check above
    phys = vals[:, 0].astype(np.int32)
    keys = page_key(jnp.asarray(seq_ids, jnp.uint32),
                    jnp.asarray(page_nos, jnp.uint32))
    ops = ch.make_hash_ops(jnp.full((q,), engine.INSERT, jnp.int32), keys,
                           jnp.asarray(phys[:, None], jnp.uint32), vw=1)
    table, res, _ = ch.apply_hash(paged.spec.table, paged.state.table, ops)
    paged.state = paged.state._replace(table=table)
    return paged, jnp.asarray(phys)


def lookup_pages(paged: PagedKV, seq_ids, n_pages_per_seq: int):
    """Batched page-table lookup: seq b, pages 0..max -> phys[b, max]
    (-1 where unmapped).  The hot path: one CacheHash find per (seq, page),
    inlined-bucket fast path."""
    seq_ids = jnp.asarray(seq_ids, jnp.uint32)
    b = seq_ids.shape[0]
    pages = jnp.arange(n_pages_per_seq, dtype=jnp.uint32)
    keys = page_key(seq_ids[:, None], pages[None, :]).reshape(-1)
    ops = ch.make_hash_ops(
        jnp.full((keys.shape[0],), engine.FIND, jnp.int32), keys, vw=1)
    table, res, _ = ch.apply_hash(paged.spec.table, paged.state.table, ops)
    phys = jnp.where(res.found, res.value[:, 0].astype(jnp.int32), -1)
    paged.state = paged.state._replace(table=table)
    return paged, phys.reshape(b, n_pages_per_seq)


def free_pages(paged: PagedKV, seq_id: int, n_pages_used: int) -> PagedKV:
    """Release a finished sequence's pages: CacheHash delete (path-copying
    CAS) + big-atomic free-ring push."""
    if n_pages_used == 0:
        return paged
    pages = np.arange(n_pages_used, dtype=np.uint32)
    keys = page_key(jnp.full((n_pages_used,), seq_id, jnp.uint32),
                    jnp.asarray(pages))
    find_ops = ch.make_hash_ops(
        jnp.full((n_pages_used,), engine.FIND, jnp.int32), keys, vw=1)
    table, res, _ = ch.apply_hash(paged.spec.table, paged.state.table,
                                  find_ops)
    phys = np.asarray(res.value[:, 0], np.int32)[np.asarray(res.found)]
    del_ops = ch.make_hash_ops(
        jnp.full((n_pages_used,), engine.DELETE, jnp.int32), keys, vw=1)
    table, _, _ = ch.apply_hash(paged.spec.table, table, del_ops)
    if len(phys):
        ok = paged.free.enqueue_batch(phys.astype(np.uint32))
        assert ok.all()                   # ring is sized to hold every page
    paged.state = paged.state._replace(table=table)
    return paged


# ---------------------------------------------------------------------------
# Physical page I/O (host call style; the fused step uses the *_fn forms)
# ---------------------------------------------------------------------------

def write_prompt(paged: PagedKV, phys_pages, layer_k, layer_v) -> PagedKV:
    """Scatter a prompt's K/V into its pages.  layer_k/v: [L_attn, T, kvh, hd]
    (batch of one sequence); phys_pages: int32[ceil(T/P)]."""
    P = paged.page_size
    L, T = layer_k.shape[0], layer_k.shape[1]
    n_full = T // P
    k_pages, v_pages = paged.state.k_pages, paged.state.v_pages
    if n_full:
        kk = layer_k[:, :n_full * P].reshape(L, n_full, P, *layer_k.shape[2:])
        vv = layer_v[:, :n_full * P].reshape(L, n_full, P, *layer_v.shape[2:])
        k_pages = k_pages.at[:, phys_pages[:n_full]].set(kk)
        v_pages = v_pages.at[:, phys_pages[:n_full]].set(vv)
    rem = T - n_full * P
    if rem:
        k_pages = k_pages.at[:, phys_pages[n_full], :rem].set(
            layer_k[:, n_full * P:])
        v_pages = v_pages.at[:, phys_pages[n_full], :rem].set(
            layer_v[:, n_full * P:])
    paged.state = paged.state._replace(k_pages=k_pages, v_pages=v_pages)
    return paged


def append_token(paged: PagedKV, phys_page, offset, k_tok, v_tok) -> PagedKV:
    """Write one new token's K/V for a batch of sequences (host call)."""
    paged.state = append_token_fn(paged.spec, paged.state, phys_page, offset,
                                  k_tok, v_tok)
    return paged


def gather_kv(paged: PagedKV, phys: jax.Array):
    """Host-call form of `gather_fn` (v1 signature)."""
    return gather_fn(paged.spec, paged.state, phys)
