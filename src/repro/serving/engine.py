"""Continuous-batching serving engine over the paged KV cache.

Requests are admitted into `max_batch` decode slots as they arrive; every
`step()` decodes ONE token for all live slots in a single batched forward
against page-gathered KV, then appends the new K/V through the page table
(CacheHash insert on page-boundary crossings).  Finished sequences release
their pages (CacheHash delete) without stalling the other slots — the
lock-free property the paper buys us: page-table readers (decoding slots)
never block on table writers (admission/retirement), in the batched-step
sense established in DESIGN.md §2.

The whole admission path is lock-free big atomics (DESIGN.md §4): request
intake is an MPMC `repro.sync.queue.BigQueue` of request ids, decode-slot
claim/retirement is a second BigQueue cycling the slot indices, and the
physical-page free list inside `paged_kv` is a third — every claim an LL/SC
on a big-atomic counter cell, so admission, slot recycling and page
allocation never take a lock against the decoding readers.

Since the v2 redesign the decode hot path is ONE compiled program
(`fused=True`, the default): page-table lookup (CacheHash finds on the
big-atomic buckets), KV gather, the batched forward, and the new token's
KV append all trace into a single `jax.jit` step over the pure
`PagedState` pytree — 1 host->device dispatch per decode step instead of
the v1 path's 4 (`dispatch_count` tracks this; bench_atomics records the
delta).  Admission/prefill stays host-side (it owns the big-atomic rings
and the Python request registry).

Scale-out (`mesh=` + DESIGN.md §6): the page table becomes a mesh-sharded
CacheHash and BOTH big-atomic rings (admission, decode-slot claim/retire)
run on sharded tables through `core.distributed` — page-table finds route
by key owner inside the SAME fused step, so each decode step stays one
compiled program, executed per shard (`dispatch_count` still counts 1).

Transactional bookkeeping (DESIGN.md §7, default on): each step's
multi-cell page-table mutations — the deferred retirement deletes of
sequences that finished last step plus this step's page-boundary appends —
commit as ONE all-or-nothing transaction (`repro.txn.map` via
`paged_kv.txn_bookkeep`), locally or through the key-owner-routed sharded
collective, instead of separate alloc/free hash batches; the fused decode
dispatch stays exactly 1 per step (asserted in tests/test_serving.py).

Scope: archs whose layers are all full attention (dense / moe / vlm
backbones).  SWA / SSM / hybrid archs serve through the dense slot-state path
(`make_serve_step`) since their state is O(1) or ring-buffered per sequence —
paging would page nothing.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.specs import DEFAULT_STRATEGY
from repro.models.common import ModelConfig
from repro.obs import telemetry as obs_telemetry
from repro.models.transformer import forward
from repro.serving import paged_kv as pk
from repro.sync.queue import BigQueue


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # int32[T]
    max_new_tokens: int = 16
    temperature: float = 0.0           # 0 = greedy
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass(frozen=True)
class OverloadPolicy:
    """Admission control under overload (DESIGN.md §11).  The engine is
    *saturated* when no decode slot is free AND the admission queue sits
    at or above `watermark` of its capacity; after more than `patience`
    consecutive saturated submissions, new requests are shed with a typed
    verdict instead of growing an unbounded backlog."""
    watermark: float = 0.75
    patience: int = 2


@dataclasses.dataclass(frozen=True)
class Admitted:
    """submit() verdict: the request id is on the admission ring."""
    rid: int
    queue_depth: int


@dataclasses.dataclass(frozen=True)
class Shed:
    """submit() verdict: the request was refused under overload; the
    caller owns retry/redirect.  Counted in `serving.shed` telemetry."""
    rid: int
    reason: str
    queue_depth: int
    free_slots: int


@dataclasses.dataclass
class _Slot:
    rid: int = -1
    seq_id: int = -1
    pos: int = 0                       # next position to decode
    new_tokens: int = 0
    active: bool = False


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 4,
                 n_pages: int | None = None, page_size: int | None = None,
                 max_pages_per_seq: int = 32, strategy: str | None = None,
                 max_queue: int = 256, seed: int = 0, fused: bool = True,
                 spec: pk.PagedSpec | None = None, mesh=None,
                 shard_axis: str = "shard", txn_bookkeeping: bool = True,
                 overload: OverloadPolicy | None = None):
        assert all(k == "attn" for k in cfg.layer_kinds) and \
            cfg.causal and cfg.window == 0, \
            "paged engine serves causal full-attention archs; use " \
            "make_serve_step for SSM/hybrid/SWA/encoder"
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_pages = max_pages_per_seq
        n_shards = 1
        if mesh is not None:
            n_shards = dict(zip(mesh.axis_names,
                                mesh.devices.shape))[shard_axis]
        if spec is None:
            spec = pk.make_spec(cfg, n_pages if n_pages is not None else 256,
                                page_size if page_size is not None else 16,
                                max_batch, strategy or DEFAULT_STRATEGY,
                                n_shards=n_shards, axis=shard_axis)
        else:
            if (n_pages, page_size, strategy) != (None, None, None):
                raise ValueError("pass either spec or the n_pages/page_size/"
                                 "strategy kwargs, not both")
            if spec.max_seqs < max_batch:
                raise ValueError(f"spec.max_seqs ({spec.max_seqs}) < "
                                 f"max_batch ({max_batch})")
            if spec.n_shards != n_shards:
                raise ValueError(f"spec.n_shards ({spec.n_shards}) != mesh "
                                 f"axis size ({n_shards})")
        self.mesh = mesh
        self.paged = pk.init(cfg, spec, mesh=mesh)
        self.slots = [_Slot() for _ in range(max_batch)]
        # Lock-free intake: rids wait in an MPMC big-atomic queue; decode
        # slots cycle through a second one (claim = dequeue, retire = enq).
        # With a mesh, both rings — like the page table — run on the
        # sharded big-atomic table (LL/SC claims routed by cell owner).
        self.admit_q = BigQueue(max(max_queue, 2), k=2,
                                strategy=spec.table.strategy,
                                mesh=mesh, shard_axis=shard_axis,
                                n_shards=n_shards)
        self.slot_q = BigQueue(max(max_batch, 2), k=2,
                               strategy=spec.table.strategy,
                               initial_items=np.arange(max_batch,
                                                       dtype=np.uint32),
                               mesh=mesh, shard_axis=shard_axis,
                               n_shards=n_shards)
        self.requests: dict[int, Request] = {}
        self._next_seq = 0
        self._key = jax.random.PRNGKey(seed)
        self.fused = fused
        self.dispatch_count = 0        # decode-path host->device dispatches
        self._decode_fn = jax.jit(self._decode_batch)
        self._fused_fn = jax.jit(self._fused_step) if fused else None
        # Transactional bookkeeping (DESIGN.md §7): each step's multi-cell
        # page-table mutations — retirement deletes + boundary-crossing
        # appends — commit as ONE all-or-nothing transaction instead of
        # separate alloc/free hash batches.  Retire deletes defer to the
        # next step's transaction; `_pending_retire` holds them meanwhile.
        self.txn_bookkeeping = txn_bookkeeping
        self._pending_retire: list[tuple[int, int]] = []
        self._decode_inflight = False  # a dispatched, un-finished decode
        self.overload = overload
        self._overload_streak = 0
        self.shed_count = 0

    # -- public API ---------------------------------------------------------

    def submit(self, req: Request) -> Admitted | Shed:
        """Lock-free intake: the request id rides the admission queue; the
        Request object is parked in the host-side registry.

        Returns a typed verdict.  With an `OverloadPolicy`, sustained
        saturation (and a full ring) sheds the request — graceful
        degradation instead of an unbounded backlog; without one, a full
        ring still raises RuntimeError as before."""
        if req.rid < 0 or req.rid >= 2 ** 32:
            raise ValueError("rid must fit in a uint32 payload word")
        depth, free = len(self.admit_q), len(self.slot_q)
        if self.overload is not None:
            saturated = free == 0 and \
                depth >= self.overload.watermark * self.admit_q.capacity
            self._overload_streak = self._overload_streak + 1 if saturated \
                else 0
            if saturated and self._overload_streak > self.overload.patience:
                return self._shed(req, "sustained overload", depth, free)
        ok = self.admit_q.enqueue_batch(np.asarray([req.rid], np.uint32))
        if not ok[0]:
            if self.overload is not None:
                return self._shed(req, "admission queue full", depth, free)
            raise RuntimeError("admission queue full")
        self.requests[req.rid] = req
        return Admitted(rid=req.rid, queue_depth=depth + 1)

    def _shed(self, req: Request, reason: str, depth: int,
              free: int) -> Shed:
        self.shed_count += 1
        obs_telemetry.record(**{"serving.shed": 1})
        return Shed(rid=req.rid, reason=reason, queue_depth=depth,
                    free_slots=free)

    def step(self):
        """Admit waiting requests into free slots, then decode one token for
        every active slot.  Returns the number of live slots."""
        if self._pending_retire and \
                min(len(self.admit_q), len(self.slot_q)) > 0:
            # Admission will prefill this step: commit the deferred
            # retirement deletes FIRST so their pages are free for the
            # prefill allocs — page availability matches the legacy
            # free-on-finish path exactly.
            self.paged, _ = pk.txn_bookkeep(self.paged,
                                            self._drain_retires(), [])
        self._admit()
        live = [i for i, s in enumerate(self.slots) if s.active]
        if live:
            self._decode(live)
        elif self._pending_retire:
            # No decode this step: flush the deferred retirement deletes as
            # their own transaction so pages recycle promptly.
            self.paged, _ = pk.txn_bookkeep(self.paged,
                                            self._drain_retires(), [])
        return len(live)

    def pending(self) -> int:
        """Requests waiting in the admission queue (a counter-cell read)."""
        return len(self.admit_q)

    def run_to_completion(self, max_steps: int = 1000):
        for _ in range(max_steps):
            if not self.step() and not self.pending():
                break
        return {r.rid: r.out_tokens for r in self.requests.values()}

    def run_pipelined(self, max_steps: int = 1000):
        """Serve through `repro.runtime.Executor`: admission and decode run
        as two DECOUPLED streams, so prefill forwards (device compute)
        overlap the in-flight fused decode dispatch instead of serializing
        in front of it as `step()` does.  Greedy sampling is batch-
        composition independent, so per-request tokens are identical to
        `run_to_completion` (asserted in tests/test_serving.py)."""
        from repro.runtime.executor import Executor
        from repro.runtime.streams import serving_streams
        decode, admission = serving_streams(self)
        ex = Executor(None, [admission, decode], slots=1, oversubscription=2)
        ex.run(max_rounds=max_steps)
        return {r.rid: r.out_tokens for r in self.requests.values()}

    # -- admission / prefill -------------------------------------------------

    def _admit(self):
        """Claim (request, slot) pairs through the two big-atomic queues."""
        n = min(len(self.admit_q), len(self.slot_q))
        if not n:
            return
        rids, ok_r = self.admit_q.dequeue_batch(n)
        slot_ids, ok_s = self.slot_q.dequeue_batch(n)
        assert ok_r.all() and ok_s.all()      # sole consumer of both queues
        pairs = [(int(r), int(s)) for r, s in zip(rids[:, 0], slot_ids[:, 0])]
        for j, (rid, si) in enumerate(pairs):
            try:
                self._prefill_into(si, self.requests[rid])
            except Exception:
                self._requeue_failed(si, pairs, j)
                raise

    def _requeue_failed(self, si: int, pairs, j: int) -> None:
        # The failing request is dropped (as the old pop-then-raise path
        # did), but its slot and every not-yet-admitted pair go back on
        # their rings so nothing leaks.  FIFO is preserved: anything
        # submitted later is drained and re-enqueued BEHIND the survivors
        # of this admission round.
        self.slot_q.enqueue_batch(
            np.asarray([si] + [s for _, s in pairs[j + 1:]], np.uint32))
        survivors = [r for r, _ in pairs[j + 1:]]
        depth = len(self.admit_q)
        if survivors:
            later = []
            if depth:
                vals, ok = self.admit_q.dequeue_batch(depth)
                later = [int(v) for v in vals[ok, 0]]
            self.admit_q.enqueue_batch(
                np.asarray(survivors + later, np.uint32))

    def _prefill_compute(self, req: Request):
        """The device-heavy half of admission: the prefill forward + first
        token.  Touches NO engine state (beyond the sampling key), so the
        executor overlaps it with an in-flight decode dispatch."""
        T = len(req.prompt)
        batch = {"tokens": jnp.asarray(req.prompt[None], jnp.int32)}
        if self.cfg.family == "vlm":
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(T, dtype=jnp.int32)[None, :, None], (1, T, 3))
        logits, cache, _ = forward(self.params, self.cfg, batch,
                                   mode="prefill")
        k, v = self._cache_to_layers(cache)          # [L, T, kvh, hd]
        # first generated token comes from the prefill logits
        tok = int(self._sample(logits[:, -1])[0])
        return k, v, tok

    def _prefill_commit(self, slot_idx: int, rid: int, k, v, tok: int):
        """The page-table half: alloc pages, write the prompt KV, publish
        the slot.  Chained on `self.paged`, so it orders after whatever
        decode dispatch is in flight."""
        slot = self.slots[slot_idx]
        req = self.requests[rid]
        seq_id = self._next_seq
        self._next_seq += 1
        T = len(req.prompt)
        P = self.paged.page_size
        n_pages = (T + P - 1) // P
        self.paged, phys = pk.alloc_pages(
            self.paged, [seq_id] * n_pages, list(range(n_pages)))
        self.paged = pk.write_prompt(self.paged, phys, k, v)
        req.out_tokens.append(tok)
        slot.rid, slot.seq_id, slot.pos = req.rid, seq_id, T
        slot.new_tokens, slot.active = 1, True
        obs_telemetry.record(**{"serving.admitted": 1})

    def _prefill_into(self, slot_idx: int, req: Request):
        k, v, tok = self._prefill_compute(req)
        self._prefill_commit(slot_idx, req.rid, k, v, tok)

    def _cache_to_layers(self, cache):
        ks, vs = [], []
        if "stack" in cache:
            st = cache["stack"]
            for layer in st:                      # period tuple
                ks.append(layer["k"][:, 0])       # [n_full, T, kvh, hd]
                vs.append(layer["v"][:, 0])
        if "tail" in cache:
            for layer in cache["tail"]:
                ks.append(layer["k"][0][None])
                vs.append(layer["v"][0][None])
        return jnp.concatenate(ks, 0), jnp.concatenate(vs, 0)

    # -- decode --------------------------------------------------------------

    def _decode_batch(self, params, tokens, pos, k_dense, v_dense):
        """One batched decode step against gathered KV.  Returns (logits,
        new k/v for the produced token)."""
        cfg = self.cfg
        period = len(cfg.block_pattern)
        n_full = cfg.n_layers // period
        cache = {}
        if n_full:
            cache["stack"] = ({"k": k_dense[:n_full], "v": v_dense[:n_full]},)
        tail_n = cfg.n_layers % period
        if tail_n:
            cache["tail"] = tuple(
                {"k": k_dense[n_full + j], "v": v_dense[n_full + j]}
                for j in range(tail_n))
        batch = {"tokens": tokens, "pos": pos}
        logits, new_cache, _ = forward(params, cfg, batch, mode="decode",
                                       cache=cache)
        b_idx = jnp.arange(tokens.shape[0])
        nk, nv = [], []
        if n_full:
            nk.append(new_cache["stack"][0]["k"][:, b_idx, pos])
            nv.append(new_cache["stack"][0]["v"][:, b_idx, pos])
        if tail_n:
            for j in range(tail_n):
                nk.append(new_cache["tail"][j]["k"][b_idx, pos][None])
                nv.append(new_cache["tail"][j]["v"][b_idx, pos][None])
        return logits, jnp.concatenate(nk, 0), jnp.concatenate(nv, 0)

    def _fused_step(self, params, pstate, tokens, pos, seq_ids):
        """The whole decode data path as ONE traced program: big-atomic
        page-table lookup -> KV gather -> batched forward -> KV append.
        `pstate` (PagedState) is a pure pytree, so the admission + decode
        state flows through a single compiled step."""
        spec = self.paged.spec
        P = spec.page_size
        pstate, phys, k_dense, v_dense, _ = pk.lookup_and_gather(
            spec, pstate, seq_ids, self.max_pages, mesh=self.mesh)
        logits, nk, nv = self._decode_batch(params, tokens, pos,
                                            k_dense, v_dense)
        b = tokens.shape[0]
        phys_page = phys[jnp.arange(b), pos // P]
        pstate = pk.append_token_fn(spec, pstate, phys_page, pos % P, nk, nv)
        return pstate, logits

    def _drain_retires(self):
        retires, self._pending_retire = self._pending_retire, []
        return retires

    def _decode(self, live):
        logits = self._dispatch_decode(live)
        self._finish_decode(live, logits)

    def _dispatch_decode(self, live):
        P = self.paged.page_size
        seq_ids = [self.slots[i].seq_id for i in live]
        pos = np.asarray([self.slots[i].pos for i in live], np.int32)
        # page-boundary crossings allocate through the big-atomic table
        need = [(s, p // P) for s, p in zip(seq_ids, pos) if p % P == 0]
        if self.txn_bookkeeping:
            # ONE transaction: deferred retirement deletes + this step's
            # page-table appends, all-or-nothing (DESIGN.md §7).
            self.paged, _ = pk.txn_bookkeep(self.paged,
                                            self._drain_retires(), need)
        elif need:
            self.paged, _ = pk.alloc_pages(
                self.paged, [n[0] for n in need], [n[1] for n in need])
        tokens = np.asarray(
            [self.requests[self.slots[i].rid].out_tokens[-1] for i in live],
            np.int32)[:, None]
        if self._fused_fn is not None:
            pstate, logits = self._fused_fn(
                self.params, self.paged.state, jnp.asarray(tokens),
                jnp.asarray(pos), jnp.asarray(seq_ids, jnp.uint32))
            self.paged.state = pstate
            self.dispatch_count += 1
        else:
            # v1 path (kept for the fused-vs-unfused benchmark): 4 separate
            # host->device dispatches per decode step.
            self.paged, phys = pk.lookup_pages(self.paged, seq_ids,
                                               self.max_pages)
            k_dense, v_dense, _ = pk.gather_kv(self.paged, phys)
            logits, nk, nv = self._decode_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(pos),
                k_dense, v_dense)
            self.paged = pk.append_token(
                self.paged, jnp.asarray(phys[np.arange(len(live)), pos // P]),
                jnp.asarray(pos % P), nk, nv)
            self.dispatch_count += 4
        obs_telemetry.record(**{
            "serving.decode_steps": 1,
            "serving.dispatches": 1 if self._fused_fn is not None else 4,
            "serving.decode_tokens": len(live),
        })
        return logits

    def _finish_decode(self, live, logits):
        toks = self._sample(logits[:, 0])
        for j, i in enumerate(live):
            slot = self.slots[i]
            req = self.requests[slot.rid]
            req.out_tokens.append(int(toks[j]))
            slot.pos += 1
            slot.new_tokens += 1
            if slot.new_tokens >= req.max_new_tokens:
                self._retire(i)

    # -- pipelined halves (runtime.streams drives these) ---------------------

    @property
    def decode_inflight(self) -> bool:
        return self._decode_inflight

    def dispatch_decode(self, live):
        """Issue the fused decode for `live` slots WITHOUT consuming the
        logits: the paged state is committed (chained for whatever issues
        next) and the returned logits are an un-fetched device array.
        `finish_decode` completes the step; exactly one decode may be in
        flight (the next step's input tokens depend on this one's)."""
        if self._fused_fn is None:
            raise RuntimeError("pipelined decode needs fused=True (the v1 "
                               "4-dispatch path has nothing to overlap)")
        if self._decode_inflight:
            raise RuntimeError("a decode is already in flight; finish it "
                               "before dispatching the next")
        self._decode_inflight = True
        return self._dispatch_decode(live)

    def finish_decode(self, live, logits) -> None:
        """Host half of a dispatched decode: sample, append tokens, retire
        finished slots (their page-table deletes defer to the next
        bookkeeping transaction, exactly as in `step()`)."""
        self._finish_decode(live, logits)
        self._decode_inflight = False

    def admit_compute(self) -> list:
        """Claim every admissible (request, slot) pair and run their
        prefill FORWARDS — device compute that overlaps an in-flight
        decode — deferring the page-table commit to `commit_admissions`.
        Returns the opaque admitted list (empty = nothing to admit)."""
        n = min(len(self.admit_q), len(self.slot_q))
        if not n:
            return []
        rids, ok_r = self.admit_q.dequeue_batch(n)
        slot_ids, ok_s = self.slot_q.dequeue_batch(n)
        assert ok_r.all() and ok_s.all()      # sole consumer of both queues
        pairs = [(int(r), int(s)) for r, s in zip(rids[:, 0], slot_ids[:, 0])]
        admitted = []
        for j, (rid, si) in enumerate(pairs):
            try:
                k, v, tok = self._prefill_compute(self.requests[rid])
            except Exception:
                self._requeue_failed(si, pairs, j)
                raise
            admitted.append((si, rid, k, v, tok))
        return admitted

    def commit_admissions(self, admitted) -> None:
        """Publish computed admissions into the page table + slots.  The
        deferred retirement deletes commit FIRST (their pages must be free
        for the prefill allocs — same ordering `step()` maintains)."""
        if self._pending_retire:
            self.paged, _ = pk.txn_bookkeep(self.paged,
                                            self._drain_retires(), [])
        for si, rid, k, v, tok in admitted:
            self._prefill_commit(si, rid, k, v, tok)

    def flush_retires(self) -> None:
        """Commit deferred retirement deletes as their own transaction
        (the pipelined analog of `step()`'s no-decode flush)."""
        if self._pending_retire:
            self.paged, _ = pk.txn_bookkeep(self.paged,
                                            self._drain_retires(), [])

    def _retire(self, i):
        slot = self.slots[i]
        req = self.requests[slot.rid]
        req.done = True
        P = self.paged.page_size
        used = (slot.pos + P) // P          # pages incl. current partial
        if self.txn_bookkeeping:
            # Page-table deletes join the next step's transaction; the
            # decode slot recycles through its lock-free ring immediately.
            self._pending_retire.append((slot.seq_id, used))
        else:
            self.paged = pk.free_pages(self.paged, slot.seq_id, used)
        self.slots[i] = _Slot()
        self.slot_q.enqueue_batch(np.asarray([i], np.uint32))
        obs_telemetry.record(**{"serving.retired": 1})

    def _sample(self, logits):
        if self.requests and all(r.temperature == 0.0
                                 for r in self.requests.values()):
            return np.asarray(jnp.argmax(logits, -1))
        self._key, sub = jax.random.split(self._key)
        temp = max(next(iter(self.requests.values())).temperature, 1e-4)
        return np.asarray(
            jax.random.categorical(sub, logits.astype(jnp.float32) / temp))
