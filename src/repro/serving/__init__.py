from repro.serving.paged_kv import (  # noqa: F401
    PagedKV, init_paged, lookup_pages, alloc_pages, free_pages, page_key,
)
from repro.serving.engine import (  # noqa: F401
    Admitted, OverloadPolicy, Request, ServingEngine, Shed,
)
