"""repro.atomics — the single public entry point for big atomics (v2 API).

The paper's central claim is that one abstraction — a k-word linearizable
register with load/store/CAS (and LL/SC per Blelloch & Wei, arXiv:1911.09671)
— cleanly underlies tuples, version lists and hash tables.  This module IS
that abstraction:

  Specs (static)     AtomicSpec / HashSpec / QueueSpec — frozen, hashable
                     descriptions of shape + strategy; the ONLY static
                     argument any entry point takes.
  States (pytrees)   TableState / HashState / LinkCtx / queue ring states —
                     pure pytrees that ride through `jax.jit`, `lax.scan`,
                     donation and `shard_map` unchanged.
  One op schema      OpBatch with per-lane kind LOAD / STORE / CAS / LL /
                     SC / VALIDATE (+ FIND / INSERT / DELETE for CacheHash),
                     one linearization for mixed batches.
  Strategy registry  StrategyImpl + register_strategy(): memory layouts
                     plug in without touching core.

Canonical usage:

    from repro import atomics

    spec = atomics.AtomicSpec(n=1024, k=4, strategy="cached_me", p_max=256)
    state = atomics.init(spec)
    ops = atomics.make_ops(kind, slot, expected, desired, k=spec.k)
    state, ctx, res, stats, traffic = atomics.apply(spec, state, ops, ctx)
    vals, ok = atomics.read(spec, state, slots)        # honest layout read

Legacy entry points (`core.bigatomic.apply_ops`, `sync.llsc.apply_sync`,
`core.cachehash.apply_hash_ops`, the `BigAtomicTable`/`CacheHash` wrappers)
survive as thin deprecation shims over this module; see DESIGN.md §5 for
the migration table.
"""

from repro.core.engine import (  # noqa: F401
    CAS, DELETE, FIND, IDLE, INSERT, LL, LOAD, SC, STORE, VALIDATE,
    ApplyResult, ApplyStats, LinkCtx, OpBatch,
    apply, apply_ops_reference, cas_ops, init, init_ctx, linearize, loads,
    logical, make_ops, read, stores, sync_ops,
)
from repro.core.layout import (  # noqa: F401
    TableState, Traffic, WORD_BYTES, WORD_DTYPE, state_nbytes,
)
from repro.core.registry import (  # noqa: F401
    StrategyImpl, get_strategy, register_strategy, registered_strategies,
    unregister_strategy,
)
from repro.core.specs import (  # noqa: F401
    DEFAULT_STRATEGY, AtomicSpec, HashSpec, QueueSpec, VersionSpec,
)
from repro.core import strategies as _builtin_strategies  # noqa: F401
# The mesh-sharded execution layer (DESIGN.md §6): same specs, same
# registry, one collective round per batch.  `atomics.dist.apply(mesh,
# DistSpec(spec, axis, n_shards, p_local), state, ops, ctx)`.
from repro.core import distributed as dist  # noqa: F401
from repro.core.distributed import DistSpec, DistState  # noqa: F401
# The transaction layer (DESIGN.md §7): k-word MCAS (`atomics.mcas`,
# checked txn construction via `atomics.make_txns`), bounded version lists
# and the optimistic transactional map, all registry-dispatched; the
# mesh-sharded MCAS is `atomics.dist.mcas` (two-round prepare/commit).
from repro import txn  # noqa: F401
from repro.txn.mcas import (  # noqa: F401
    McasResult, TxnBatch, make_txns, mcas,
)


def memory_bytes(spec: AtomicSpec) -> int:
    """Exact bytes of the layout (paper Table 1 / §5.5 forms)."""
    return get_strategy(spec.strategy).memory_bytes(spec.n, spec.k,
                                                    spec.p_max)


def begin_update(spec: AtomicSpec, state, slot: int, new_value,
                 torn_words: int | None = None):
    """Freeze a writer at its most vulnerable point (mid-cache-copy), exactly
    as oversubscription deschedules a lock-holder in the paper.  Test/bench
    adversary; see `core.bigatomic.begin_update` for per-strategy effects."""
    import jax.numpy as jnp
    new_value = jnp.asarray(new_value, WORD_DTYPE)
    torn = spec.k // 2 if torn_words is None else torn_words
    return get_strategy(spec.strategy).begin_update(state, slot, new_value,
                                                    torn)
