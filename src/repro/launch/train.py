"""End-to-end trainer: config -> mesh -> sharded train loop with
checkpoint/restart, preemption safety, straggler watchdog and the versioned
in-memory snapshot store (the big-atomics multiversioning application).

Runs anywhere: `--arch deepseek-7b --reduced` trains the smoke config on CPU;
the same file drives the production mesh on a real pod (the only difference
is the mesh factory).  See examples/train_lm.py for the packaged demo.

  PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b --reduced \
      --steps 50 --ckpt-dir /tmp/ckpt --ckpt-every 20
"""

from __future__ import annotations

import argparse
import time

import jax

from repro import dist
from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.configs.shapes import SHAPES, Shape, reduced_shape
from repro.core import multiversion as mv
from repro.data import DataPipeline
from repro.launch.mesh import describe, make_host_mesh
from repro.launch.steps import init_train_state, make_train_step
from repro.optim import AdamWConfig
from repro.runtime import PreemptionGuard, StragglerWatchdog


def train(cfg, shape: Shape, *, steps: int, ckpt_dir: str | None = None,
          ckpt_every: int = 50, seed: int = 0, lr: float = 3e-4,
          grad_compression: str = "none", mesh=None, snapshot_slots: int = 2,
          log_every: int = 10, guard: PreemptionGuard | None = None,
          opt_cfg: AdamWConfig | None = None):
    """Returns (params, opt_state, history dict)."""
    mesh = mesh or make_host_mesh()
    rules = dist.make_rules(cfg, mesh)
    opt_cfg = opt_cfg or AdamWConfig(lr=lr, warmup=max(steps // 20, 1),
                                     total_steps=steps)
    pipe = DataPipeline(cfg, shape, seed=seed)

    params, opt_state = init_train_state(cfg, opt_cfg, seed)
    start = 0
    if ckpt_dir:
        last = latest_step(ckpt_dir)
        if last is not None:
            (params, opt_state), meta = restore_checkpoint(
                ckpt_dir, last, (params, opt_state))
            start = int(meta.get("next_step", last))
            print(f"[train] resumed from step_{last:08d} -> step {start}")

    p_sh = dist.param_shardings(params, cfg, mesh, rules)
    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(
        opt_state, {"m": p_sh, "v": p_sh,
                    "step": jax.sharding.NamedSharding(
                        mesh, jax.sharding.PartitionSpec())})

    with dist.axis_rules(mesh, rules):
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, grad_compression),
                          donate_argnums=(0, 1))

        store = mv.init_store((params, opt_state), n_slots=snapshot_slots)
        watchdog = StragglerWatchdog(n_hosts=1)
        history = {"loss": [], "step_time": []}
        own_guard = guard is None
        guard = guard or PreemptionGuard()
        ctx = guard if own_guard else _nullcontext()
        with ctx:
            for step in range(start, steps):
                t0 = time.time()
                raw = pipe.batch(step)
                batch = jax.device_put(
                    raw, dist.batch_shardings(raw, mesh, rules))
                params, opt_state, metrics = step_fn(params, opt_state, batch)
                loss = float(metrics["loss"])
                dt = time.time() - t0
                history["loss"].append(loss)
                history["step_time"].append(dt)
                watchdog.observe([dt])
                # publish into the versioned store (async readers snapshot it)
                store = mv.publish(store, (params, opt_state), step + 1)
                if log_every and step % log_every == 0:
                    print(f"[train] step {step} loss {loss:.4f} "
                          f"({dt*1e3:.0f} ms)", flush=True)
                stopping = guard.should_stop
                if ckpt_dir and (stopping or (step + 1) % ckpt_every == 0
                                 or step + 1 == steps):
                    snap = mv.snapshot_with_validation(store)
                    save_checkpoint(ckpt_dir, step + 1, snap.state,
                                    meta={"next_step": step + 1,
                                          "arch": cfg.name})
                if stopping:
                    print(f"[train] preempted at step {step + 1}; "
                          "checkpoint written, exiting cleanly")
                    break
    return params, opt_state, history


class _nullcontext:
    def __enter__(self):
        return self

    def __exit__(self, *a):
        return False


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config + tiny shape (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "bf16", "int8"])
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    shape = SHAPES[args.shape]
    if args.reduced:
        shape = reduced_shape(shape)
    print(f"[train] {cfg.name}  shape={shape}  mesh="
          f"{describe(make_host_mesh())}")
    _, _, hist = train(cfg, shape, steps=args.steps, ckpt_dir=args.ckpt_dir,
                       ckpt_every=args.ckpt_every, seed=args.seed,
                       lr=args.lr, grad_compression=args.grad_compression)
    print(f"[train] done: loss {hist['loss'][0]:.4f} -> "
          f"{hist['loss'][-1]:.4f} over {len(hist['loss'])} steps")


if __name__ == "__main__":
    main()
