"""Launch entry points: train / serve loops, mesh construction, input specs,
and the multi-pod dry-run (`python -m repro.launch.dryrun`)."""
