"""Input specifications per (architecture × shape).

`input_specs()` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, zero allocation — used by the dry-run;
`materialize()` turns the same specs into concrete random arrays for smoke
tests and examples.  Modality frontends are stubs per the assignment: hubert
receives precomputed frame embeddings, qwen2-vl precomputed patch embeddings
and M-RoPE positions."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.shapes import Shape
from repro.models.common import ModelConfig
from repro.models.transformer import init_cache

N_VISION_STUB = 64   # patch-embedding stub length for qwen2-vl


def input_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """ShapeDtypeStructs for the batch dict consumed by the step function."""
    B, S = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    bf16 = jnp.bfloat16
    if shape.kind == "decode":
        specs = {"tokens": jax.ShapeDtypeStruct((B, 1), i32),
                 "pos": jax.ShapeDtypeStruct((B,), i32)}
        return specs
    if cfg.input_mode == "features":
        specs = {"features": jax.ShapeDtypeStruct((B, S, cfg.feature_dim), bf16),
                 "labels": jax.ShapeDtypeStruct((B, S), i32)}
        return specs
    specs = {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    if cfg.family == "vlm":
        specs["vision_embeds"] = jax.ShapeDtypeStruct(
            (B, min(N_VISION_STUB, S), cfg.d_model), bf16)
        specs["positions"] = jax.ShapeDtypeStruct((B, S, 3), i32)
    return specs


def cache_specs(cfg: ModelConfig, shape: Shape) -> dict:
    """ShapeDtypeStructs for the KV/state cache at this shape's length."""
    return jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))


def materialize(specs, seed: int = 0, vocab: int = 256):
    """Concrete random arrays matching the specs (for smoke tests)."""
    rng = np.random.default_rng(seed)

    def mk(path, s):
        name = "/".join(str(getattr(p, "key", p)) for p in path)
        if s.dtype == jnp.int32:
            if name.endswith("pos"):
                return jnp.asarray(rng.integers(1, 64, s.shape), jnp.int32)
            if name.endswith("positions"):
                base = np.broadcast_to(
                    np.arange(s.shape[1])[None, :, None], s.shape)
                return jnp.asarray(base, jnp.int32)
            return jnp.asarray(rng.integers(0, vocab, s.shape), jnp.int32)
        return jnp.asarray(rng.standard_normal(s.shape) * 0.1, s.dtype)

    return jax.tree_util.tree_map_with_path(mk, specs)
