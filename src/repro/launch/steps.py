"""Step functions: train / prefill / serve(decode), shared by the real
launcher, the smoke tests and the multi-pod dry-run."""

from __future__ import annotations


import jax

from repro.models.common import ModelConfig
from repro.models.transformer import forward, init_params, lm_loss
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.compression import compress_grads, decompress_grads


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    grad_compression: str = "none"):
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch))(params)
        if grad_compression != "none":
            # compress -> (implicit DP all-reduce at use) -> decompress.
            grads, _, meta = compress_grads(grads, None, grad_compression)
            grads = decompress_grads(grads, meta)
        new_params, new_opt, metrics = adamw_update(
            params, grads, opt_state, opt_cfg)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: int = 0):
    """(params, batch) -> (last-token logits, cache).

    `max_len` sizes the KV cache beyond the prompt so decode can append;
    forward() already slices to the last position before the head projection
    so the full [b, t, vocab] logits never materialize."""

    def prefill_step(params, batch):
        logits, cache, _ = forward(params, cfg, batch, mode="prefill",
                                   max_len=max_len)
        return logits, cache

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """(params, cache, batch{tokens[b,1], pos[b]}) -> (logits, new_cache).

    One new token per sequence against a seq_len KV/state cache."""

    def serve_step(params, cache, batch):
        logits, new_cache, _ = forward(params, cfg, batch, mode="decode",
                                       cache=cache)
        return logits, new_cache

    return serve_step


def init_train_state(cfg: ModelConfig, opt_cfg: AdamWConfig, seed: int = 0):
    params = init_params(cfg, jax.random.PRNGKey(seed))
    opt_state = adamw_init(params, opt_cfg)
    return params, opt_state
