"""Serving launcher: load (or init) a model and serve a batch of requests
through the paged-KV continuous-batching engine (big-atomic page table).

  PYTHONPATH=src python -m repro.launch.serve --arch deepseek-7b --reduced \
      --requests 6 --prompt-len 24 --max-new 8
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint
from repro.configs import get_config
from repro.models.transformer import init_params
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--n-pages", type=int, default=256)
    ap.add_argument("--strategy", default="cached_me")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            (params, _), _ = restore_checkpoint(
                args.ckpt_dir, last,
                (params, {"m": params, "v": params,
                          "step": jax.numpy.int32(0)}))
            print(f"[serve] restored step_{last:08d}")

    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        n_pages=args.n_pages, page_size=args.page_size,
                        strategy=args.strategy,
                        max_queue=max(args.requests, 256))
    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(0, cfg.vocab, args.prompt_len).astype(np.int32)
        eng.submit(Request(rid=rid, prompt=prompt,
                           max_new_tokens=args.max_new))
    out = eng.run_to_completion()
    dt = time.time() - t0
    total = sum(len(v) for v in out.values())
    for rid, toks in sorted(out.items()):
        print(f"[serve] request {rid}: {toks}")
    print(f"[serve] {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, strategy={args.strategy})")


if __name__ == "__main__":
    main()
