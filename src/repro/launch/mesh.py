"""Production mesh construction.

`make_production_mesh()` is a FUNCTION (never a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax import,
and smoke tests / benches must keep seeing 1 device.

Mesh axes (v5e-pod oriented):
  single pod:  (data=16, model=16)          — 256 chips
  multi-pod:   (pod=2, data=16, model=16)   — 512 chips, 'pod' is pure DP
                                              over DCN (slow links)

Sharding semantics (see repro.dist.sharding):
  'pod','data'  -> batch / FSDP axes
  'model'       -> tensor / expert parallel axis
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Arbitrary mesh for tests/examples (e.g. (1,1) on a laptop)."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Mesh over whatever devices exist locally (smoke / examples)."""
    n = jax.device_count()
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))


def describe(mesh: Mesh) -> str:
    return " x ".join(f"{a}={s}" for a, s in
                      zip(mesh.axis_names, mesh.devices.shape))
