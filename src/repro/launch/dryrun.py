import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  512 placeholder host devices let jax.make_mesh build
# the production meshes: (16,16) single pod and (2,16,16) = 512 chips.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell with production shardings, prove it partitions (no sharding
mismatch / unsupported collective), capture memory_analysis() and
cost_analysis(), and derive the trip-count-corrected roofline terms from the
compiled HLO text (see repro.analysis.hlo for why XLA's own cost_analysis
is insufficient for scanned programs).

Results append to a JSON file (one record per cell) so interrupted runs
resume where they left off.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
      --shape train_4k --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --out dryrun_results.json
"""

import argparse
import gc
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import dist
from repro.analysis import analyze_hlo, roofline_terms, TPU_V5E
from repro.analysis.model_flops import model_flops
from repro.configs import ARCHS, get_config
from repro.configs.shapes import SHAPES, Shape, applicable
from repro.launch.mesh import make_production_mesh, describe
from repro.launch.specs import input_specs
from repro.launch.steps import (make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models.common import ModelConfig
from repro.models.transformer import init_cache, init_params
from repro.optim import AdamWConfig, adamw_init

HBM_PER_CHIP = 16 * 1024 ** 3  # v5e


# ---------------------------------------------------------------------------
# Shardings per entry point
# ---------------------------------------------------------------------------

def _batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _replicated(mesh):
    return NamedSharding(mesh, P())


def cache_shardings(cache_specs, cfg: ModelConfig, mesh, rules, batch: int):
    """Shardings for the KV/state cache pytree.

    Attn caches are [layers, b, L, kvh, hd] (stacked) or [b, L, kvh, hd]
    (tail).  Batch shards over ('pod','data') when divisible; otherwise
    (long_500k, b=1) the cache LENGTH shards over 'data' (decode context
    parallelism).  kv_heads shard over 'model' when divisible.
    """
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    baxes = _batch_axes(mesh)
    b_shards = 1
    for a in baxes:
        b_shards *= mesh_axes[a]
    batch_ok = batch % b_shards == 0
    model_n = mesh_axes.get("model", 1)

    def spec(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        stacked = "stack" in names
        dims = list(leaf.shape)
        ax: list = [None] * len(dims)
        o = 1 if stacked else 0          # leading layers axis on stack leaves
        if names[-1] in ("k", "v"):      # [.., b, L, kvh, hd]
            if batch_ok:
                ax[o] = baxes
            elif dims[o + 1] % mesh_axes.get("data", 1) == 0:
                ax[o + 1] = "data"       # shard cache length instead
            if dims[o + 2] % model_n == 0 and model_n > 1:
                ax[o + 2] = "model"
        else:                             # ssm/rglru state: [.., b, ...]
            if batch_ok:
                ax[o] = baxes
            # widest trailing dim over model when divisible
            for i in range(len(dims) - 1, o, -1):
                if dims[i] % model_n == 0 and model_n > 1 and dims[i] >= model_n:
                    ax[i] = "model"
                    break
        return NamedSharding(mesh, P(*ax))

    return jax.tree_util.tree_map_with_path(spec, cache_specs)


def build_cell(cfg: ModelConfig, shape: Shape, mesh):
    """Returns (fn, arg_specs, in_shardings, out_shardings, donate)."""
    rules = dist.make_rules(cfg, mesh)
    specs = input_specs(cfg, shape)
    batch_sh = dist.batch_shardings(specs, mesh, rules)
    params_spec = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    params_sh = dist.param_shardings(params_spec, cfg, mesh, rules)

    if shape.kind == "train":
        opt_cfg = AdamWConfig(
            moment_dtype="bfloat16" if cfg.n_params() > 5e10 else "float32")
        opt_spec = jax.eval_shape(lambda: adamw_init(params_spec, opt_cfg))
        opt_sh = {"m": params_sh, "v": params_sh,
                  "step": _replicated(mesh)}
        fn = make_train_step(cfg, opt_cfg)
        metrics_sh = {"loss": _replicated(mesh),
                      "grad_norm": _replicated(mesh),
                      "lr": _replicated(mesh)}
        return (fn, (params_spec, opt_spec, specs),
                (params_sh, opt_sh, batch_sh),
                (params_sh, opt_sh, metrics_sh), (0, 1))
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg, max_len=shape.seq_len)
        cache_spec = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
        cache_sh = cache_shardings(cache_spec, cfg, mesh, rules,
                                   shape.global_batch)
        logits_sh = dist.batch_shardings(
            jax.ShapeDtypeStruct((shape.global_batch, 1, cfg.vocab),
                                 jnp.float32), mesh, rules)
        return (fn, (params_spec, specs), (params_sh, batch_sh),
                (logits_sh, cache_sh), ())
    # decode
    fn = make_serve_step(cfg)
    cache_spec = jax.eval_shape(
        lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
    cache_sh = cache_shardings(cache_spec, cfg, mesh, rules,
                               shape.global_batch)
    logits_sh = dist.batch_shardings(
        jax.ShapeDtypeStruct((shape.global_batch, 1, cfg.vocab),
                             jnp.float32), mesh, rules)
    return (fn, (params_spec, cache_spec, specs),
            (params_sh, cache_sh, batch_sh),
            (logits_sh, cache_sh), (1,))


# ---------------------------------------------------------------------------
# One cell
# ---------------------------------------------------------------------------

def optimize_cfg(cfg: ModelConfig, shape: Shape) -> ModelConfig:
    """The beyond-paper perf levers (EXPERIMENTS.md §Perf), applied for
    --opt runs.  Each is individually validated for semantics in
    tests/test_perf_levers.py; the baseline run keeps defaults."""
    import dataclasses
    kw: dict = {}
    if shape.kind in ("train", "prefill"):
        kw["score_dtype"] = "bfloat16"         # it-A1: halve score traffic
        # it-A3: wide kv blocks -> the online-softmax accumulator (fp32, in
        # the scan carry) is updated once per q block instead of S/kvb times
        kw["kv_block"] = min(4096, shape.seq_len)
    if shape.kind == "train" and cfg.vocab >= 100_000:
        kw["loss_chunk"] = 8                   # it-A2: chunked CE
    if cfg.is_moe and shape.kind == "train":
        kw["moe_groups"] = 32                  # it-B1/B3: group-local dispatch
    return dataclasses.replace(cfg, **kw) if kw else cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             hw=TPU_V5E, opt: bool = False) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if opt:
        cfg = optimize_cfg(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name, "opt": opt,
                 "mesh": "multi" if multi_pod else "single"}
    ok, why = applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = dist.make_rules(cfg, mesh)
    n_dev = mesh.devices.size
    try:
        fn, arg_specs, in_sh, out_sh, donate = build_cell(cfg, shape, mesh)
        with dist.axis_rules(mesh, rules):
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                             donate_argnums=donate)
            lowered = jitted.lower(*arg_specs)
            compiled = lowered.compile()
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
        return rec
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    cost = analyze_hlo(hlo_text)
    del hlo_text

    mf = model_flops(cfg, shape)          # whole-step useful FLOPs (global)
    rl = roofline_terms(cost, hw, model_flops_per_device=mf / n_dev)

    arg_b = getattr(mem, "argument_size_in_bytes", 0)
    out_b = getattr(mem, "output_size_in_bytes", 0)
    alias_b = getattr(mem, "alias_size_in_bytes", 0)
    tmp_b = getattr(mem, "temp_size_in_bytes", 0)
    resident = arg_b + out_b - alias_b + tmp_b
    rec.update(
        status="ok",
        mesh_desc=describe(mesh),
        devices=n_dev,
        compile_s=round(t_compile, 1),
        # memory_analysis (per device)
        bytes_per_device=dict(arguments=arg_b, outputs=out_b, aliased=alias_b,
                              temps=tmp_b, resident=resident,
                              hbm_budget=HBM_PER_CHIP,
                              fits=bool(resident <= HBM_PER_CHIP)),
        # XLA's own cost_analysis (loop bodies counted ONCE — see analysis/hlo)
        xla_cost=dict(flops=ca.get("flops", 0.0),
                      bytes_accessed=ca.get("bytes accessed", 0.0)),
        # trip-corrected per-device costs
        hlo_flops_dev=cost.flops,
        hlo_bytes_dev=cost.bytes_hbm,
        coll_bytes_dev=cost.coll_bytes,
        coll_by_kind={k: round(v) for k, v in cost.coll_by_kind.items()},
        coll_ops=cost.coll_ops,
        unknown_trip_whiles=cost.unknown_trip_whiles,
        model_flops_global=mf,
        roofline={k: v for k, v in rl.items() if k != "coll_by_kind"},
    )
    del compiled, lowered
    gc.collect()
    return rec


def iter_cells(archs, shapes, mesh_mode):
    for arch in archs:
        for shape_name in shapes:
            if mesh_mode in ("single", "both"):
                yield arch, shape_name, False
            if mesh_mode in ("multi", "both"):
                yield arch, shape_name, True


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--opt", action="store_true",
                    help="apply the §Perf optimization levers")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in --out")
    args = ap.parse_args()

    archs = [args.arch.replace("-", "_")] if args.arch else ARCHS
    shapes = [args.shape] if args.shape else list(SHAPES)

    done: dict[tuple, dict] = {}
    if os.path.exists(args.out) and not args.force:
        with open(args.out) as f:
            for rec in json.load(f):
                done[(rec["arch"], rec["shape"], rec["mesh"])] = rec

    records = list(done.values())
    n_ok = n_err = n_skip = 0
    for arch, shape_name, multi in iter_cells(archs, shapes, args.mesh):
        key = (arch, shape_name, "multi" if multi else "single")
        if key in done and done[key].get("status") != "error":
            continue
        print(f"[dryrun] {arch} x {shape_name} x {key[2]}"
              f"{' [opt]' if args.opt else ''} ...", flush=True)
        rec = run_cell(arch, shape_name, multi, opt=args.opt)
        records = [r for r in records
                   if (r["arch"], r["shape"], r["mesh"]) != key]
        records.append(rec)
        st = rec["status"]
        n_ok += st == "ok"
        n_err += st == "error"
        n_skip += st == "skipped"
        if st == "ok":
            rl = rec["roofline"]
            print(f"  ok in {rec['compile_s']}s  "
                  f"compute={rl['compute_s']:.3e}s "
                  f"memory={rl['memory_s']:.3e}s "
                  f"coll={rl['collective_s']:.3e}s "
                  f"-> {rl['bottleneck']}  "
                  f"resident={rec['bytes_per_device']['resident']/2**30:.2f}GiB",
                  flush=True)
            print("  memory_analysis:", rec["bytes_per_device"], flush=True)
            print("  cost_analysis(xla):", rec["xla_cost"], flush=True)
        elif st == "error":
            print(f"  ERROR: {rec['error']}", flush=True)
        else:
            print(f"  skipped: {rec['reason']}", flush=True)
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    print(f"[dryrun] done: {n_ok} ok, {n_err} errors, {n_skip} skipped "
          f"(+{len(done)} cached) -> {args.out}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
