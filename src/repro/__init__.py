"""Big Atomics reproduction: k-word atomic cells, lock-free structures built
on them (CacheHash, multiversion stores, LL/SC + queues), and a jax_pallas
training/serving stack that exercises them at production scale.

Subpackage map:
  atomics   — THE public big-atomic API: specs, pytree states, one op
              schema, strategy registry (DESIGN.md §5)
  core      — big-atomic strategies, the unified engine, CacheHash
  sync      — LL/SC, atomic copy, MPMC ring queue (DESIGN.md §4)
  kernels   — Pallas TPU kernels + pure-jnp oracles
  serving   — paged-KV continuous-batching engine (DESIGN.md §3)
  models/optim/data/launch/runtime — the surrounding training system
"""
