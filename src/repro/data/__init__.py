from repro.data.pipeline import (  # noqa: F401
    DataPipeline, synthetic_batch, make_memmap_corpus,
)
