"""Deterministic, sharded, resumable data pipeline.

Design invariants (these are what make preemption/elasticity cheap):
  * batch(step) is a PURE FUNCTION of (seed, step, host_id, n_hosts) — the
    pipeline has no cursor state to checkpoint; resume = restart at step N;
  * each host materializes ONLY its shard of the global batch;
  * the same global batch is produced for any (n_hosts, host_id)
    factorization, so elastic rescale mid-run does not change the data
    stream (verified in tests by comparing 1-host vs 4-host assembly).

Two sources:
  synthetic — seeded Zipf-ish token stream (self-contained, used by the
      examples and tests; the Zipf skew gives the loss a realistic shape);
  memmap — fixed-length documents from a token memmap on disk (np.memmap,
      zero-copy reads; build one with `make_memmap_corpus`).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.shapes import Shape
from repro.models.common import ModelConfig


def _rng_for(seed: int, step: int, shard: int) -> np.random.Generator:
    # Philox is counter-based: O(1) construction per (step, shard), no
    # sequential state -> random access over the step axis.
    return np.random.Generator(np.random.Philox(key=seed,
                                                counter=[0, 0, step, shard]))


def synthetic_batch(cfg: ModelConfig, shape: Shape, *, seed: int, step: int,
                    host_id: int = 0, n_hosts: int = 1) -> dict:
    """This host's shard of global batch `step`."""
    B, S = shape.global_batch, shape.seq_len
    assert B % n_hosts == 0, (B, n_hosts)
    b = B // n_hosts
    rows = []
    for r in range(b):
        g_row = host_id * b + r                   # global row id
        rng = _rng_for(seed, step, g_row)
        # Zipf-ish skew: token ~ floor(v * u^3) concentrates mass on low ids
        u = rng.random(S)
        rows.append((cfg.vocab * u ** 3).astype(np.int32))
    toks = np.stack(rows)
    if cfg.input_mode == "features":
        rng = _rng_for(seed, step, 10_000_000 + host_id)
        feats = rng.standard_normal((b, S, cfg.feature_dim)).astype(
            np.float32) * 0.1
        return {"features": feats.astype(np.dtype("bfloat16") if
                                         cfg.compute_dtype == "bfloat16"
                                         else np.float32),
                "labels": toks}
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["positions"] = np.broadcast_to(
            np.arange(S, dtype=np.int32)[None, :, None], (b, S, 3)).copy()
    return batch


def make_memmap_corpus(path: str, n_tokens: int, vocab: int,
                       seed: int = 0) -> str:
    """Build a token memmap for the memmap source (tests / examples)."""
    rng = np.random.default_rng(seed)
    arr = np.memmap(path, dtype=np.int32, mode="w+", shape=(n_tokens,))
    chunk = 1 << 20
    for lo in range(0, n_tokens, chunk):
        hi = min(lo + chunk, n_tokens)
        arr[lo:hi] = rng.integers(0, vocab, hi - lo, dtype=np.int32)
    arr.flush()
    return path


@dataclasses.dataclass
class DataPipeline:
    cfg: ModelConfig
    shape: Shape
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    source: str = "synthetic"          # synthetic | memmap
    memmap_path: str | None = None
    _mm: np.ndarray | None = dataclasses.field(default=None, repr=False)

    def batch(self, step: int) -> dict:
        if self.source == "synthetic":
            return synthetic_batch(self.cfg, self.shape, seed=self.seed,
                                   step=step, host_id=self.host_id,
                                   n_hosts=self.n_hosts)
        if self._mm is None:
            self._mm = np.memmap(self.memmap_path, dtype=np.int32, mode="r")
        B, S = self.shape.global_batch, self.shape.seq_len
        b = B // self.n_hosts
        n_docs = len(self._mm) // S
        rows = []
        for r in range(b):
            g_row = self.host_id * b + r
            rng = _rng_for(self.seed, step, g_row)
            d = int(rng.integers(0, n_docs))
            rows.append(np.asarray(self._mm[d * S:(d + 1) * S]))
        return {"tokens": np.stack(rows)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
