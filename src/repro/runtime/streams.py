"""Logical op streams for the multi-stream executor (DESIGN.md §9).

A *stream* is one logical worker issuing work against a shared big-atomic
target — the paper's oversubscription regime has more streams than hardware
slots, and `repro.runtime.executor` schedules them.  Three stream kinds:

  kind="ops"    produces `engine.OpBatch`es; the executor owns the table
                state and the stream's persistent per-lane `LinkCtx`, runs
                each batch through the engine round (donated, so batch i+1's
                host pack overlaps batch i's device round) and delivers the
                per-lane results back.  `SyntheticStream` below is the
                deterministic workload generator (batch b is a pure function
                of (seed, b), so checkpoint resume and fault replay never
                regenerate different ops).
  kind="round"  holds a multi-round protocol and advances it ONE round per
                scheduling slot — `McasStream` wraps `txn.mcas.mcas_round`
                so MCAS retry loops yield to the scheduler between attempt
                rounds instead of spinning inside one `lax.while_loop`.
  kind="host"   produces opaque in-flight work via `issue()`; the returned
                token's `finish()` completes it when the executor retires
                the slot.  `serving_streams` exposes a `ServingEngine`'s
                admission and decode paths as two such streams, so prefill
                compute overlaps the in-flight fused decode dispatch.
"""

from __future__ import annotations

import numpy as np

from repro.core import engine


class InFlight:
    """Opaque in-flight work from a kind="host" stream: `finish()` runs the
    completion (host-side) half when the executor retires the slot."""

    __slots__ = ("_finish",)

    def __init__(self, finish):
        self._finish = finish

    def finish(self):
        if self._finish is not None:
            fn, self._finish = self._finish, None
            fn()


class SyntheticStream:
    """Deterministic mixed-op workload: batch b is a pure function of
    (seed, b), so a resumed or fault-replayed executor reissues bit-identical
    ops without the stream journaling anything.

    Lane layout per batch: the first half of the lanes are *sync* lanes that
    LL a cell on even batches and SC the same cell on the following odd batch
    (links therefore span batches, and SCs race writes from OTHER streams);
    the second half draws LOAD/STORE/CAS uniformly.  `hot_frac` of all lanes
    collapse onto cells [0, hot_cells) to dial contention up.
    """

    kind = "ops"

    def __init__(self, name: str, seed: int, *, n: int, k: int, width: int,
                 n_batches: int, slot_lo: int = 0, slot_hi: int | None = None,
                 hot_cells: int = 0, hot_frac: float = 0.0):
        self.name = name
        self.seed = seed
        self.n, self.k, self.width = n, k, width
        self.n_batches = n_batches
        self.slot_lo = slot_lo
        self.slot_hi = n if slot_hi is None else slot_hi
        self.hot_cells, self.hot_frac = hot_cells, hot_frac
        self._i = 0
        self.results: dict[int, tuple[np.ndarray, np.ndarray]] = {}

    def _gen(self, b: int) -> engine.OpBatch:
        q, k = self.width, self.k
        # The LL (batch 2m) and its SC (batch 2m+1) share one rng draw so
        # the pair targets the same cell.
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, b // 2]))
        slot = rng.integers(self.slot_lo, self.slot_hi, q).astype(np.int32)
        if self.hot_cells and self.hot_frac > 0:
            hot = rng.random(q) < self.hot_frac
            slot = np.where(hot, rng.integers(0, self.hot_cells, q),
                            slot).astype(np.int32)
        n_sync = q // 2
        kind = np.empty(q, np.int32)
        kind[:n_sync] = engine.LL if b % 2 == 0 else engine.SC
        kind[n_sync:] = rng.choice(
            [engine.LOAD, engine.STORE, engine.CAS], q - n_sync)
        # value-op payloads vary per batch (not per pair)
        vrng = np.random.default_rng(np.random.SeedSequence([self.seed, b,
                                                             0xBEEF]))
        expected = vrng.integers(0, 2 ** 32, (q, k), dtype=np.uint32)
        desired = vrng.integers(0, 2 ** 32, (q, k), dtype=np.uint32)
        return engine.make_ops(kind, slot, expected, desired, k=k)

    def next_batch(self) -> engine.OpBatch | None:
        if self._i >= self.n_batches:
            return None
        ops = self._gen(self._i)
        self._i += 1
        return ops

    def seek(self, seq: int) -> None:
        """Fast-forward the cursor on checkpoint resume: batches < seq were
        already executed and live in the restored state."""
        self._i = int(seq)

    def deliver(self, seq: int, value: np.ndarray, success: np.ndarray,
                overflow=None) -> None:
        """Results land here (idempotent by seq: fault replay re-delivers,
        last write wins — deliveries after the last checkpoint are
        provisional until the next one, see DESIGN.md §9)."""
        self.results[int(seq)] = (np.asarray(value), np.asarray(success))

    def done(self) -> bool:
        return self._i >= self.n_batches


class McasStream:
    """A batch of MCAS transactions advanced ONE protocol round per
    scheduling slot (`txn.mcas.mcas_round`): between attempt rounds the
    executor is free to run other streams' batches, so contended retries
    yield instead of spinning inside the table round."""

    kind = "round"

    def __init__(self, name: str, txns, *, policy=None):
        from repro.sync.queue import BackoffPolicy
        self.name = name
        self.txns = txns
        self.policy = policy or BackoffPolicy("none")
        self.carry = None
        self.rounds_run = 0

    def step(self, spec, state):
        """Advance one round against the executor-owned state; returns the
        new state (chained in place of the old)."""
        from repro.txn import mcas as txn_mcas
        if self.carry is None:
            self.carry = txn_mcas.mcas_begin(self.txns)
        state, self.carry = txn_mcas.mcas_round(
            spec, state, self.txns, self.carry, policy=self.policy)
        self.rounds_run += 1
        return state

    def done(self) -> bool:
        if self.carry is None:
            return False
        return not bool(np.asarray(self.carry.pending).any())

    def result(self):
        from repro.txn import mcas as txn_mcas
        if self.carry is None or not self.done():
            raise RuntimeError("mcas stream still pending")
        return txn_mcas.mcas_finish(self.txns, self.carry)


# ---------------------------------------------------------------------------
# Serving: admission and decode as two decoupled executor streams.
# ---------------------------------------------------------------------------

class DecodeStream:
    """Dispatches the fused decode step for the live slots WITHOUT fetching
    tokens; sampling/retirement runs at retire time, after admission has had
    the device to itself for prefill compute."""

    kind = "host"

    def __init__(self, eng):
        self.name = "decode"
        self.eng = eng

    def issue(self) -> InFlight | None:
        eng = self.eng
        if eng.decode_inflight:       # next step's tokens depend on this one
            return None
        live = [i for i, s in enumerate(eng.slots) if s.active]
        if not live:
            if eng._pending_retire:
                eng.flush_retires()
            return None
        pend = eng.dispatch_decode(live)
        return InFlight(lambda: eng.finish_decode(live, pend))

    def done(self) -> bool:
        eng = self.eng
        return not any(s.active for s in eng.slots) and not eng.pending() \
            and not eng._pending_retire


class AdmissionStream:
    """Claims (request, slot) pairs and runs the prefill forwards — device
    work that overlaps the in-flight decode — deferring the page-table
    commit to retire time (after the decode's PagedState lands)."""

    kind = "host"

    def __init__(self, eng):
        self.name = "admission"
        self.eng = eng

    def issue(self) -> InFlight | None:
        eng = self.eng
        admitted = eng.admit_compute()
        if not admitted:
            return None
        return InFlight(lambda: eng.commit_admissions(admitted))

    def done(self) -> bool:
        return not self.eng.pending()


def serving_streams(eng):
    """(DecodeStream, AdmissionStream) over a `ServingEngine` — schedule
    them with `repro.runtime.Executor(target=None, streams=[...])` and the
    engine produces tokens identical to `run_to_completion`, with admission
    prefill overlapping the in-flight decode dispatch."""
    return DecodeStream(eng), AdmissionStream(eng)
