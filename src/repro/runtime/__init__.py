"""repro.runtime — the execution/robustness layer (DESIGN.md §9).

`Executor` schedules oversubscribed logical streams against one big-atomic
target with fault-injected recovery; the watchdog, preemption guard and
elastic resharding it composes are exported alongside.
"""

from repro.runtime.preemption import PreemptionGuard  # noqa: F401
from repro.runtime.stragglers import StragglerPlan, StragglerWatchdog  # noqa: F401
from repro.runtime.elastic import (  # noqa: F401
    MeshPlan, elastic_mesh, mesh_plan, reshard_dist, reshard_state)
from repro.runtime.executor import (  # noqa: F401
    DistTarget, Executor, IssueRec, LocalTarget, Recovery, StreamShed)
from repro.runtime.streams import (  # noqa: F401
    AdmissionStream, DecodeStream, InFlight, McasStream, SyntheticStream,
    serving_streams)
from repro.runtime.faults import (  # noqa: F401
    DATA_KINDS, SCHED_KINDS, Fault, FaultInjector)
