from repro.runtime.preemption import PreemptionGuard  # noqa: F401
from repro.runtime.stragglers import StragglerWatchdog  # noqa: F401
from repro.runtime.elastic import elastic_mesh, reshard_state  # noqa: F401
