"""The oversubscribed multi-stream executor (DESIGN.md §9).

The paper's throughput story needs MORE logical workers than hardware
slots: big atomics win when oversubscribed streams keep the engine's
fast path saturated while stalled streams wait out contention.  This
module is that regime as a scheduler:

  streams      S logical op streams (`runtime.streams`) share ONE
               big-atomic target.  Each scheduling round visits every
               live stream and issues at most one batch.
  in-flight    JAX async dispatch makes every issued round a future;
               the executor holds up to `slots * oversubscription`
               un-retired rounds, so stream i+1's host-side route/pack
               overlaps stream i's device round (donation keeps the
               double-buffer at two table allocations, `apply(donate=
               True)`).
  targets      `LocalTarget` wraps the single-device engine round
               (`engine.apply_round`); `DistTarget` wraps the mesh
               round (`distributed.apply_round`) — with `n_nodes > 1`
               the round routes hierarchically (intra-node combine,
               then ONE cross-node all_to_all), and the executor's
               overlap hides the cross-node hop behind other streams'
               host work.
  faults       `runtime.faults` injects delay / preempt / shard-loss
               at exact (round, issue) points.  Delays surface through
               the StragglerWatchdog (flagged streams skip their next
               issue slot); preemption drains, checkpoints and stops
               cleanly; shard loss discards in-flight rounds, restores
               the last round-boundary checkpoint, reshards onto the
               survivors (`elastic.reshard_dist` — versions preserved,
               so LL links survive) and replays the issue journal with
               the NEW geometry's claimed orders.
  history      every ops issue is journaled (stream, seq, ops, claimed
               order, delivered results); `tests/oracle.py`'s
               `replay_executor_history` replays the whole multi-stream
               interleaving — including across a recovery boundary —
               through one sequential oracle.

Nothing here blocks except retirement past the in-flight budget and the
explicit drains at checkpoint/recovery boundaries.
"""

from __future__ import annotations

import dataclasses
import os
import time
from collections import deque

import numpy as np

from repro.core import engine
from repro.obs.recorder import Recorder


def _ops_np(ops: engine.OpBatch) -> engine.OpBatch:
    return engine.OpBatch(*[np.array(x, copy=True) for x in ops])


def _ctx_np(ctx: engine.LinkCtx) -> engine.LinkCtx:
    return engine.LinkCtx(*[np.array(x, copy=True) for x in ctx])


# ---------------------------------------------------------------------------
# Targets: the shared big-atomic structure the streams contend on.
# ---------------------------------------------------------------------------

class LocalTarget:
    """Single-device table: rounds ride `engine.apply_round` with donation,
    so the in-flight window costs two table buffers, not `budget` of them."""

    kind = "local"

    def __init__(self, spec, initial=None):
        self.spec = spec
        self.state = engine.init(spec, initial)

    @property
    def width(self) -> int:
        return self.spec.n          # no lane cap beyond table size

    @property
    def n_shards(self) -> int:
        return 1

    def issue(self, ops, ctx, *, donate=True):
        h = engine.apply_round(self.spec, self.state, ops, ctx,
                               donate=donate)
        self.state = h.state
        return h

    def snapshot(self) -> dict:
        return {"logical": np.asarray(engine.logical(self.spec, self.state)),
                "versions": np.asarray(self.state.version)}

    def load(self, snap: dict) -> None:
        self.state = engine.init(self.spec, snap["logical"])._replace(
            version=np.asarray(snap["versions"], np.uint32))

    def shrink(self, n_surviving: int):
        raise RuntimeError("shard loss against a LocalTarget is fatal: "
                           "nothing to reshard onto")


class DistTarget:
    """Mesh-sharded table: rounds ride `distributed.apply_round` (flat or
    hierarchical per the DistSpec) with the claimed linearization computed
    up front; `shrink` reshards the live state onto a smaller mesh through
    `elastic.reshard_dist`, preserving values AND versions."""

    kind = "dist"

    def __init__(self, mesh, dspec, initial=None, *, mesh_factory=None):
        from repro.core import distributed as dist
        self._dist = dist
        self.mesh, self.dspec = mesh, dspec
        self.state = dist.init_dist(mesh, dspec, initial)
        # n_surviving -> (mesh, dspec): how to rebuild after shard loss
        self.mesh_factory = mesh_factory

    @property
    def width(self) -> int:
        return self.dspec.p_global

    @property
    def n_shards(self) -> int:
        return self.dspec.n_shards

    def issue(self, ops, ctx, *, donate=True):
        h = self._dist.apply_round(self.mesh, self.dspec, self.state, ops,
                                   ctx, with_order=True)
        self.state = h.state
        return h

    def snapshot(self) -> dict:
        return {"logical": np.asarray(self._dist.logical(self.dspec,
                                                         self.state)),
                "versions": np.asarray(self._dist.versions(self.dspec,
                                                           self.state))}

    def load(self, snap: dict) -> None:
        import jax
        from jax.sharding import NamedSharding
        st = self._dist.init_dist(self.mesh, self.dspec, snap["logical"])
        # splice the versions back (inverse of distributed.versions): LL
        # links restored alongside MUST see their pre-checkpoint versions
        local = st.local._replace(
            version=_split_versions(self.dspec, snap["versions"]))
        local = jax.device_put(
            local, NamedSharding(self.mesh, self._dist._pspec(self.dspec)))
        self.state = self._dist.DistState(local)

    def shrink(self, n_surviving: int) -> None:
        if self.mesh_factory is None:
            raise RuntimeError("shard loss needs mesh_factory= to rebuild "
                               "the mesh on the survivors")
        from repro.runtime.elastic import reshard_dist
        mesh, dspec = self.mesh_factory(n_surviving)
        self.state = reshard_dist(self.dspec, self.state, dspec, mesh)
        self.mesh, self.dspec = mesh, dspec


def _split_versions(dspec, vers):
    import jax.numpy as jnp
    s, nl = dspec.n_shards, dspec.n_local
    vers = np.asarray(vers, np.uint32)
    per = vers.reshape(nl, s).T if dspec.interleave else vers.reshape(s, nl)
    return jnp.asarray(np.ascontiguousarray(per))


# ---------------------------------------------------------------------------
# The issue journal / oracle history.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class IssueRec:
    """One issued ops batch: everything `tests/oracle.py` needs to replay
    it, filled in two phases (order at issue, results at retire)."""

    stream: int
    seq: int
    ops: engine.OpBatch                    # numpy copies
    order: np.ndarray | None = None        # claimed order (None = lane order)
    overflow: np.ndarray | None = None
    value: np.ndarray | None = None
    success: np.ndarray | None = None


@dataclasses.dataclass
class Recovery:
    round: int
    shard: int
    n_shards: int          # surviving shard count
    replayed: int          # journaled batches re-issued
    latency_s: float


@dataclasses.dataclass
class StreamShed:
    """A stream dropped after exhausting its retry budget (graceful
    degradation: the run continues without it, DESIGN.md §11)."""
    stream: int
    round: int
    reason: str
    attempts: int


# ---------------------------------------------------------------------------
# The executor.
# ---------------------------------------------------------------------------

class Executor:
    """Schedule S streams against one target with more in-flight rounds
    than compute slots.

    target:           `LocalTarget` / `DistTarget` (None for pure
                      kind="host" stream sets, e.g. serving).
    streams:          `runtime.streams` objects (kinds "ops", "round",
                      "host" mix freely; "round" needs a LocalTarget).
    slots:            modeled compute slots per device.
    oversubscription: in-flight budget = slots * oversubscription; the
                      paper's regime is factor >= 4.
    watchdog:         `StragglerWatchdog(n_hosts=len(streams))`, fed the
                      per-stream issue latencies the Recorder keeps
                      (`Recorder.latency_vector`); flagged streams are
                      deprioritized (skip their next slot).
    recorder:         `obs.Recorder` sink for round/issue/lifecycle events
                      (a fresh one is built if omitted).  It owns the
                      issue-latency bookkeeping feeding the watchdog and,
                      under BIGATOMIC_OBS=trace, the Chrome-trace span
                      timeline (`obs.chrome_trace`).
    guard:            `PreemptionGuard` (or compatible) polled at round
                      boundaries; `request_stop()` drains + checkpoints.
    injector:         `faults.FaultInjector`, polled before every issue
                      (scheduling faults) and at drained round boundaries
                      (data-plane faults, `poll_boundary`).
    checkpoint_dir /  atomic disk checkpoints (checkpoint/disk.py) every
    checkpoint_every  N rounds at a drained round boundary; an in-memory
                      copy always backs shard-loss recovery.
    retry_budget /    graceful degradation: a stream whose issue raises or
    backoff           whose every lane targets quarantined cells counts a
                      failed attempt, waits out `backoff.delay(attempts)`
                      rounds (sync/queue.BackoffPolicy), and is SHED with
                      a recorded reason once attempts exceed the budget —
                      the run continues without it.
    scrub_every       with BIGATOMIC_GUARD=on, run the integrity scrub
                      (guard/scrub.py) every N drained round boundaries
                      (default every boundary); repairs from the last
                      checkpoint, quarantines what it can't.  Guard off:
                      no scrubber object exists and issue paths are
                      byte-identical to the unguarded build.
    """

    def __init__(self, target, streams, *, slots: int = 2,
                 oversubscription: int = 2, watchdog=None, guard=None,
                 injector=None, checkpoint_dir: str | None = None,
                 checkpoint_every: int = 0, donate: bool = True,
                 recorder: Recorder | None = None, retry_budget: int = 3,
                 backoff=None, scrub_every: int = 1):
        self.target = target
        self.streams = list(streams)
        self.slots = slots
        self.oversubscription = oversubscription
        self.budget = max(1, slots * oversubscription)
        self.watchdog = watchdog
        self.guard = guard
        self.injector = injector
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.donate = donate
        self.recorder = recorder if recorder is not None else Recorder()

        self._inflight: deque = deque()
        self._ctx = {i: engine.init_ctx(s.width, self._k())
                     for i, s in enumerate(self.streams)
                     if s.kind == "ops"}
        self._seq = {i: 0 for i in range(len(self.streams))}
        self._round = 0
        self._skip: set[int] = set()
        self._delays: dict[int, list] = {}      # si -> [seconds, rounds left]
        self._last_ck = None                     # (payload, meta, hist_len)
        self.history: list[IssueRec] = []
        self.recoveries: list[Recovery] = []
        self.checkpoints: list[int] = []
        self.issues = 0
        self.deprioritized = 0
        self.stopped = False

        self.retry_budget = retry_budget
        if backoff is None:
            from repro.sync.queue import BackoffPolicy
            backoff = BackoffPolicy("exp", base=1, cap=8)
        self.backoff = backoff
        self.scrub_every = scrub_every
        self.shed: list[StreamShed] = []
        self._shed_set: set[int] = set()
        self._attempts: dict[int, int] = {}
        self._cooldown: dict[int, int] = {}      # si -> rounds to sit out
        self.data_faults: list = []              # (round, Fault, info)
        self.scrubber = None
        if target is not None:
            from repro import guard as _guard
            if _guard.enabled():
                spec = getattr(target, "spec", None)
                if spec is not None:
                    self.scrubber = _guard.Scrubber(spec)
                else:
                    d = target.dspec
                    self.scrubber = _guard.Scrubber(
                        d.inner, n=d.n_shards * d.n_local)

    def _k(self) -> int:
        if self.target is None:
            return 1
        spec = getattr(self.target, "spec", None) or self.target.dspec.inner
        return spec.k

    # -- issue / retire ------------------------------------------------------

    def _retire_one(self) -> None:
        rec, h, stream, tok = self._inflight.popleft()
        if hasattr(h, "finish"):                 # host-stream token
            h.finish()
            self.recorder.end_issue(tok)
            return
        h.wait()
        if rec is None:                          # "round" stream step
            self.recorder.end_issue(tok)
            return
        rec.value = np.asarray(h.result.value)
        rec.success = np.asarray(h.result.success)
        ovf = getattr(h, "overflow", None)
        rec.overflow = None if ovf is None else np.asarray(ovf)
        if self.scrubber is not None:
            self.scrubber.note_results(rec.ops, rec.success)
        self.recorder.end_issue(tok, args={"seq": rec.seq})
        stream.deliver(rec.seq, rec.value, rec.success, rec.overflow)

    def _drain(self) -> None:
        while self._inflight:
            self._retire_one()

    def _trim(self) -> None:
        while len(self._inflight) > self.budget:
            self._retire_one()

    def _issue(self, si: int, stream) -> bool:
        name = getattr(stream, "name", None) or f"s{si}"
        if stream.kind == "ops":
            ops = stream.next_batch()
            if ops is None:
                return False
            poisoned = None
            if self.scrubber is not None:
                # quarantined cells: lanes rewritten to IDLE pre-issue, so
                # they report success=False; the MASKED ops are journaled,
                # keeping oracle replay in bit-agreement
                ops, poisoned = self.scrubber.mask_ops(ops)
            seq = self._seq[si]
            self._seq[si] += 1
            span = self.recorder.begin_issue(si, name)
            try:
                h = self.target.issue(ops, self._ctx[si], donate=self.donate)
            except Exception:
                # roll the stream back so the SAME batch retries after the
                # backoff window; non-seekable streams can't retry
                self.recorder.cancel_issue(span)
                self._seq[si] = seq
                if not hasattr(stream, "seek"):
                    raise
                stream.seek(seq)
                self._note_failure(si, "issue raised")
                return False
            self._ctx[si] = h.ctx
            rec = IssueRec(si, seq, _ops_np(ops),
                           order=getattr(h, "order", None))
            self.history.append(rec)
            self._inflight.append((rec, h, stream, span))
            if poisoned is not None and \
                    not (np.asarray(ops.kind) != engine.IDLE).any():
                self._note_failure(si, "all lanes target quarantined cells")
            elif si in self._attempts:
                del self._attempts[si]          # progress resets the budget
        elif stream.kind == "round":
            if self.target.kind != "local":
                raise RuntimeError("round streams (MCAS) drive a "
                                   "LocalTarget")
            if stream.done():
                return False
            span = self.recorder.begin_issue(si, name)
            self.target.state = stream.step(self.target.spec,
                                            self.target.state)
            if self.scrubber is not None:
                # round streams mutate state outside the journal: the
                # scrubber can't attribute writes per-slot, so the whole
                # table goes dirty (quarantine-only until next checkpoint)
                self.scrubber.note_untracked()
            self._inflight.append((None, _CarryHandle(stream), None, span))
        elif stream.kind == "host":
            span = self.recorder.begin_issue(si, name)
            tok = stream.issue()
            if tok is None:
                self.recorder.cancel_issue(span)
                return False
            self._inflight.append((None, tok, None, span))
        else:
            raise ValueError(f"unknown stream kind {stream.kind!r}")
        self.issues += 1
        self._trim()
        return True

    # -- faults --------------------------------------------------------------

    def _poll_faults(self, issues_in_round: int) -> None:
        if self.injector is None:
            return
        for f in self.injector.poll(self._round, issues_in_round):
            if f.kind == "delay":
                self._delays[f.stream] = [f.seconds, f.rounds]
            elif f.kind == "preempt":
                if self.guard is None:
                    from repro.runtime.preemption import PreemptionGuard
                    self.guard = PreemptionGuard()
                self.guard.request_stop()
            elif f.kind == "shard_loss":
                self._recover(f.shard)

    def _extra_delay(self, si: int) -> float:
        d = self._delays.get(si)
        return d[0] if d and d[1] > 0 else 0.0

    def _note_failure(self, si: int, reason: str) -> None:
        a = self._attempts.get(si, 0) + 1
        self._attempts[si] = a
        if a > self.retry_budget:
            self.shed.append(StreamShed(stream=si, round=self._round,
                                        reason=reason, attempts=a))
            self._shed_set.add(si)
            self._cooldown.pop(si, None)
            self.recorder.shed(self._round, si, reason)
        else:
            self._cooldown[si] = int(self.backoff.delay(a))

    def _guard_boundary(self) -> None:
        """Drained-round-boundary work: apply due data-plane faults, then
        scrub.  The baseline digest is taken AFTER the drain but BEFORE
        injection, so every boundary-injected bit flip / torn write is a
        guaranteed digest mismatch (see guard/scrub.py)."""
        if self.target is None:
            return
        due = self.injector.poll_boundary(self._round) \
            if self.injector is not None else []
        scrub_due = self.scrubber is not None and self.scrub_every \
            and self._round % self.scrub_every == 0
        if not due and not scrub_due:
            return
        self._drain()
        baseline = self.scrubber.digest_of(self.target) \
            if self.scrubber is not None else None
        for f, rng in due:
            self._apply_data_fault(f, rng)
        if self.scrubber is not None:
            rep = self.scrubber.scrub(self.target, round_idx=self._round,
                                      baseline=baseline)
            self.recorder.scrub(self._round, rep)

    def _apply_data_fault(self, f, rng) -> None:
        from repro.guard.inject import (inject_snapshot_fault,
                                        inject_table_fault)
        if f.kind in ("bit_flip", "torn_write"):
            if self.target.kind == "local":
                self.target.state, info = inject_table_fault(
                    self.target.spec, self.target.state, f, rng)
            else:
                snap, info = inject_snapshot_fault(self.target.snapshot(),
                                                   f, rng)
                self.target.load(snap)
        elif f.kind == "stale_resurrect":
            if self._last_ck is None:
                return
            payload, meta, _ = self._last_ck
            self.target.load(payload["table"])
            info = {"kind": f.kind, "from_round": meta["round"]}
        elif f.kind in ("ckpt_corrupt", "ckpt_truncate"):
            info = self._damage_checkpoint(f, rng)
            if info is None:
                return                           # no disk checkpoint to hit
        else:
            raise ValueError(f"unknown data fault {f.kind!r}")
        self.data_faults.append((self._round, f, info))
        self.recorder.data_fault(self._round, f.kind, info)

    def _damage_checkpoint(self, f, rng):
        from repro.checkpoint.disk import list_steps
        if not self.checkpoint_dir:
            return None
        steps = list_steps(self.checkpoint_dir)
        if not steps:
            return None
        step = steps[-1]
        path = os.path.join(self.checkpoint_dir, f"step_{step:08d}")
        leaves = sorted(fn for fn in os.listdir(path)
                        if fn.endswith(".npy"))
        if not leaves:
            return None
        victim = os.path.join(path, leaves[int(rng.integers(len(leaves)))])
        size = os.path.getsize(victim)
        info = {"kind": f.kind, "step": step,
                "leaf": os.path.basename(victim)}
        if f.kind == "ckpt_truncate":
            with open(victim, "r+b") as fh:
                fh.truncate(size // 2)
            return info
        off = int(rng.integers(size))
        with open(victim, "r+b") as fh:
            fh.seek(off)
            byte = fh.read(1)[0]
            fh.seek(off)
            fh.write(bytes([byte ^ (1 << int(rng.integers(8)))]))
        info["offset"] = off
        return info

    # -- checkpoint / recovery ----------------------------------------------

    def _ck_payload(self) -> dict:
        return {"table": self.target.snapshot(),
                "ctx": {str(si): _ctx_np(ctx)._asdict()
                        for si, ctx in self._ctx.items()}}

    def checkpoint(self) -> None:
        """Drain and snapshot at a round boundary: the recovery point for
        shard loss (in-memory) and preemption resume (disk)."""
        self._drain()
        payload = self._ck_payload()
        meta = {"round": self._round,
                "seq": {str(si): int(q) for si, q in self._seq.items()},
                "n_shards": self.target.n_shards}
        self._last_ck = (payload, meta, len(self.history))
        if self.scrubber is not None:
            self.scrubber.set_checkpoint(payload["table"])
        if self.checkpoint_dir:
            from repro.checkpoint.disk import save_checkpoint
            save_checkpoint(self.checkpoint_dir, self._round, payload,
                            meta=meta)
        self.checkpoints.append(self._round)
        self.recorder.checkpoint(self._round)

    def _load_ck(self, payload: dict, meta: dict, hist_len: int) -> list:
        """Common restore: state, ctxs, seqs, stream cursors; returns the
        journal suffix (stream, seq) pairs issued after the checkpoint."""
        journal = [(r.stream, r.seq) for r in self.history[hist_len:]]
        del self.history[hist_len:]
        self.target.load(payload["table"])
        for key, c in payload["ctx"].items():
            self._ctx[int(key)] = engine.LinkCtx(**{
                f: np.asarray(v) for f, v in dict(c).items()})
        for key, q in meta["seq"].items():
            si = int(key)
            self._seq[si] = int(q)
            if hasattr(self.streams[si], "seek"):   # ops streams only
                self.streams[si].seek(int(q))
        return journal

    def _recover(self, shard: int) -> None:
        """Shard-loss recovery: discard in-flight, restore the last
        checkpoint, reshard onto the survivors, replay the journal in its
        recorded interleaving (deliveries are idempotent by seq — results
        issued after the checkpoint were provisional)."""
        if self._last_ck is None:
            raise RuntimeError("shard loss before the first checkpoint")
        t0 = time.perf_counter()
        self._inflight.clear()                  # results may span the loss
        payload, meta, hist_len = self._last_ck
        journal = self._load_ck(payload, meta, hist_len)
        n_surviving = self.target.n_shards - 1
        self.target.shrink(n_surviving)
        for si, seq in journal:
            assert self._seq[si] == seq, (si, self._seq[si], seq)
            self._issue(si, self.streams[si])
        self._drain()
        # the post-recovery state is the new baseline
        self.checkpoint()
        rec = Recovery(self._round, shard, self.target.n_shards,
                       len(journal), time.perf_counter() - t0)
        self.recoveries.append(rec)
        self.recorder.recovery(rec.round, shard, rec.replayed, rec.latency_s)

    def resume(self, checkpoint_dir: str | None = None) -> int:
        """Resume from the newest VERIFYING disk checkpoint (preemption
        restart): restores table state + link ctxs + stream cursors;
        `run()` then continues bit-identically with the pre-preemption
        schedule.  A corrupt or truncated newest step is skipped —
        `checkpoint.restore_latest` falls back CRC-verified step by step
        (DESIGN.md §11)."""
        from repro.checkpoint import disk
        ckdir = checkpoint_dir or self.checkpoint_dir
        template = self._ck_payload()
        payload, meta, _step = disk.restore_latest(ckdir, template)
        self._load_ck(payload, meta, len(self.history))
        self._round = int(meta["round"])
        self._last_ck = (payload, meta, len(self.history))
        if self.scrubber is not None:
            self.scrubber.set_checkpoint(payload["table"])
        return self._round

    # -- the scheduling loop -------------------------------------------------

    def _live_streams(self):
        return [s for si, s in enumerate(self.streams)
                if si not in self._shed_set]

    def done(self) -> bool:
        return all(s.done() for s in self._live_streams()) \
            and not self._inflight

    def _run_round(self) -> None:
        self._round += 1
        rcd = self.recorder
        rcd.round_begin(self._round)
        issued = 0
        for si, stream in enumerate(self.streams):
            self._poll_faults(issued)
            if self.guard is not None and self.guard.should_stop:
                return
            if si in self._shed_set or stream.done():
                continue
            cd = self._cooldown.get(si, 0)
            if cd > 0:
                self._cooldown[si] = cd - 1     # backoff: sit out the round
                continue
            if si in self._skip:
                self._skip.discard(si)          # deprioritized: skip ONE slot
                continue
            t0 = rcd.clock()            # injectable (obs.Recorder(clock=))
            if self._issue(si, stream):
                issued += 1
                rcd.issue_latency(si, rcd.clock() - t0
                                  + self._extra_delay(si))
        if not issued and self._inflight:
            # nothing issuable until in-flight work retires (e.g. a decode
            # whose successor needs its tokens): guarantee progress
            self._retire_one()
        self._poll_faults(issued)
        for d in self._delays.values():
            d[1] -= 1
        rcd.round_end(self._round)
        if self.watchdog is not None and rcd.round_issued():
            plan = self.watchdog.observe(
                rcd.latency_vector(len(self.streams)))
            if plan.flagged:
                rcd.straggler_flags(self._round, plan.flagged)
                self._skip |= set(plan.flagged)
                self.deprioritized += len(plan.flagged)

    def run(self, max_rounds: int = 10_000):
        """Drive every stream to completion (or a clean preempted stop);
        returns `self.report()`."""
        if self.target is not None and self._last_ck is None \
                and not self.history:
            self.checkpoint()                   # round-0 recovery baseline
        while not all(s.done() for s in self._live_streams()):
            if self._round >= max_rounds:
                raise RuntimeError(f"executor exceeded {max_rounds} rounds")
            self._run_round()
            self._guard_boundary()
            if self.guard is not None and self.guard.should_stop:
                self.recorder.preempt(self._round,
                                      drained=len(self._inflight))
                if self.target is not None:
                    self.checkpoint()
                else:
                    self._drain()
                self.stopped = True
                return self.report()
            if self.checkpoint_every and self.target is not None \
                    and self._round % self.checkpoint_every == 0:
                self.checkpoint()
        self._drain()
        return self.report()

    def report(self) -> dict:
        return {
            "rounds": self._round,
            "issues": self.issues,
            "streams": len(self.streams),
            "budget": self.budget,
            "stopped": self.stopped,
            "deprioritized": self.deprioritized,
            "checkpoints": list(self.checkpoints),
            "recoveries": [dataclasses.asdict(r) for r in self.recoveries],
            "faults_fired": [dataclasses.asdict(f) for f in
                             (self.injector.fired if self.injector else [])],
            "shed": [dataclasses.asdict(s) for s in self.shed],
            "data_faults": [{"round": r, **info}
                            for r, _f, info in self.data_faults],
            "scrubs": [rep.to_json() for rep in
                       (self.scrubber.reports if self.scrubber else [])],
            "poisoned": int(self.scrubber.poison.sum())
            if self.scrubber else 0,
            "events": self.recorder.metrics(),
        }


class _CarryHandle:
    """Retirement handle for a "round" stream step: blocks on the carry."""

    __slots__ = ("_stream",)

    def __init__(self, stream):
        self._stream = stream

    def wait(self):
        import jax
        jax.block_until_ready(jax.tree_util.tree_leaves(self._stream.carry))
