"""Deterministic fault injection for the executor (DESIGN.md §9).

Faults fire at exact (round, issue-slot) points in the executor's schedule,
so every failure scenario is replayable:

  kind="delay"       stream `stream`'s reported step time is inflated by
                     `seconds` for `rounds` consecutive rounds — the
                     StragglerWatchdog sees a degraded stream and the
                     executor deprioritizes it (skips its next issue slot).
  kind="preempt"     `PreemptionGuard.request_stop()` — the executor drains
                     in-flight work, writes a final checkpoint at the round
                     boundary and stops cleanly (resume continues
                     bit-identically).
  kind="shard_loss"  a device/shard of the distributed target dies
                     mid-round: the executor discards in-flight rounds,
                     restores the last checkpoint, reshards onto the
                     surviving shard count and replays its issue journal —
                     tests/oracle.py accepts the claimed order spanning the
                     fault.

`after_issues` makes the fault genuinely mid-round: it fires only after
that many issue slots of its round have already dispatched (in-flight work
exists when the fault lands).
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Fault:
    round: int                    # 1-based executor round the fault fires in
    kind: str                     # "delay" | "preempt" | "shard_loss"
    stream: int | None = None     # delay: which stream is slow
    shard: int | None = None      # shard_loss: which shard died
    seconds: float = 0.0          # delay: added reported step time
    rounds: int = 1               # delay: consecutive rounds affected
    after_issues: int = 0         # fire only after this many issues in-round

    def __post_init__(self):
        if self.kind not in ("delay", "preempt", "shard_loss"):
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "delay" and self.stream is None:
            raise ValueError("delay faults need stream=")


class FaultInjector:
    """Fires each fault exactly once at its (round, issue-slot) point; the
    executor polls before every issue.  `fired` is the audit log."""

    def __init__(self, faults: list[Fault]):
        self._pending = sorted(faults, key=lambda f: (f.round,
                                                      f.after_issues))
        self.fired: list[Fault] = []

    def poll(self, round_idx: int, issues_done: int) -> list[Fault]:
        out, keep = [], []
        for f in self._pending:
            due = (round_idx > f.round
                   or (round_idx == f.round and issues_done >= f.after_issues))
            (out if due else keep).append(f)
        self._pending = keep
        self.fired.extend(out)
        return out

    @property
    def exhausted(self) -> bool:
        return not self._pending
