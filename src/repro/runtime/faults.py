"""Deterministic fault injection for the executor (DESIGN.md §9, §11).

Two fault families share one schedule.  *Scheduling* faults perturb when
work runs; *data-plane* faults (repro.guard) corrupt the state work runs
against — the half of the resilience story PR 6 left open:

  kind="delay"       stream `stream`'s reported step time is inflated by
                     `seconds` for `rounds` consecutive rounds — the
                     StragglerWatchdog sees a degraded stream and the
                     executor deprioritizes it (skips its next issue slot).
  kind="preempt"     `PreemptionGuard.request_stop()` — the executor drains
                     in-flight work, writes a final checkpoint at the round
                     boundary and stops cleanly (resume continues
                     bit-identically).
  kind="shard_loss"  a device/shard of the distributed target dies
                     mid-round: the executor discards in-flight rounds,
                     restores the last checkpoint, reshards onto the
                     surviving shard count and replays its issue journal —
                     tests/oracle.py accepts the claimed order spanning the
                     fault.

  kind="bit_flip"        flip one bit of one live table word (a cell's
                         data/backup word or its version word).
  kind="torn_write"      overwrite only a prefix of a k-word cell without
                         touching its version — the exact hazard the
                         paper's protocols defend readers against, landed
                         as silent at-rest corruption.
  kind="stale_resurrect" re-load the table (or one shard of a DistTarget)
                         from the last checkpoint snapshot: a stale
                         replica coming back as if it were current.
  kind="ckpt_corrupt"    flip one byte of one leaf file of the newest
  kind="ckpt_truncate"   disk checkpoint / truncate that leaf, so restore
                         must fall back to the newest VERIFYING step
                         (checkpoint/disk.py CRC paths).

`after_issues` makes a scheduling fault genuinely mid-round: it fires only
after that many issue slots of its round have already dispatched.

Ordering contract (what makes chaos schedules reproducible in CI):

  * Scheduling faults fire at the first `poll(round_idx, issues_done)`
    with ``round_idx > f.round or (round_idx == f.round and issues_done >=
    f.after_issues)``; simultaneous faults fire in schedule-list order.
  * Data-plane faults are deferred to the DRAINED round boundary at the
    end of round ``f.round`` (``after_issues`` is ignored: live state is
    only well-defined with nothing in flight) and applied there in
    schedule-list order, before the guard's scrub pass runs.
  * Every choice a fault leaves unspecified (victim slot, word, bit,
    torn-prefix length, victim checkpoint leaf) is drawn from a per-fault
    ``np.random.default_rng(np.random.SeedSequence([seed, index]))``
    stream, where ``index`` is the fault's position in the ORIGINAL
    schedule list — so one fault's draws never shift another's, no matter
    when either fires.
"""

from __future__ import annotations

import dataclasses

import numpy as np

SCHED_KINDS = ("delay", "preempt", "shard_loss")
DATA_KINDS = ("bit_flip", "torn_write", "stale_resurrect",
              "ckpt_corrupt", "ckpt_truncate")


@dataclasses.dataclass(frozen=True)
class Fault:
    round: int                    # 1-based executor round the fault fires in
    kind: str                     # SCHED_KINDS | DATA_KINDS
    stream: int | None = None     # delay: which stream is slow
    shard: int | None = None      # shard_loss / stale_resurrect: which shard
    seconds: float = 0.0          # delay: added reported step time
    rounds: int = 1               # delay: consecutive rounds affected
    after_issues: int = 0         # fire only after this many issues in-round
    # -- data-plane knobs (None = drawn from the fault's seeded rng) --------
    slot: int | None = None       # bit_flip/torn_write: victim cell
    word: int | None = None       # bit_flip: word in [0, k] (k = version)
    bit: int | None = None        # bit_flip: bit index in [0, 32)
    words: int | None = None      # torn_write: prefix length in [1, k)
    field: str | None = None      # bit_flip: raw layout field override
                                  #   ("data" | "version" | "bptr" | "pool")

    def __post_init__(self):
        if self.kind not in SCHED_KINDS + DATA_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.kind == "delay" and self.stream is None:
            raise ValueError("delay faults need stream=")

    @property
    def data_plane(self) -> bool:
        return self.kind in DATA_KINDS


class FaultInjector:
    """Fires each fault exactly once; `fired` is the audit log.

    The executor polls scheduling faults before every issue
    (`poll(round_idx, issues_done)`) and data-plane faults at every
    drained round boundary (`poll_boundary(round_idx)`).  See the module
    docstring for the full ordering/determinism contract; `seed` makes
    the unspecified choices of every data-plane fault reproducible."""

    def __init__(self, faults: list[Fault], *, seed: int = 0):
        self.seed = seed
        indexed = list(enumerate(faults))
        self._pending = sorted(
            ((i, f) for i, f in indexed if not f.data_plane),
            key=lambda kv: (kv[1].round, kv[1].after_issues))
        self._pending_data = sorted(
            ((i, f) for i, f in indexed if f.data_plane),
            key=lambda kv: (kv[1].round, kv[0]))
        self.fired: list[Fault] = []

    def rng(self, index: int) -> np.random.Generator:
        """The per-fault random stream (position in the original list)."""
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, index]))

    def poll(self, round_idx: int, issues_done: int) -> list[Fault]:
        """Due scheduling faults (fires each exactly once)."""
        out, keep = [], []
        for i, f in self._pending:
            due = (round_idx > f.round
                   or (round_idx == f.round and issues_done >= f.after_issues))
            (out if due else keep).append((i, f))
        self._pending = keep
        self.fired.extend(f for _, f in out)
        return [f for _, f in out]

    def poll_boundary(self, round_idx: int) -> list[tuple[Fault,
                                                          np.random.Generator]]:
        """Due data-plane faults with their seeded rngs, in schedule order;
        the executor calls this at the drained boundary ending each round."""
        out, keep = [], []
        for i, f in self._pending_data:
            (out if f.round <= round_idx else keep).append((i, f))
        self._pending_data = keep
        self.fired.extend(f for _, f in out)
        return [(f, self.rng(i)) for i, f in out]

    @property
    def pending_data(self) -> bool:
        return bool(self._pending_data)

    @property
    def exhausted(self) -> bool:
        return not self._pending and not self._pending_data
