"""Preemption-safe training: SIGTERM/SIGINT set a flag that the train loop
polls at step boundaries; the loop then writes a final atomic checkpoint and
exits 0.  Resume from that checkpoint is bit-identical (test_runtime.py) —
the data pipeline's cursor is a pure function of the step, the optimizer
state is in the checkpoint, and nothing depends on wall clock.

On a real cluster the same guard listens for the TPU maintenance-event file
descriptor; here SIGTERM is the portable stand-in.
"""

from __future__ import annotations

import signal
import threading


class PreemptionGuard:
    """Context manager that converts SIGTERM/SIGINT into a poll-able flag.

        with PreemptionGuard() as guard:
            for step in range(...):
                if guard.should_stop:
                    save_checkpoint(...); break
    """

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._signals = signals
        self._event = threading.Event()
        self._old = {}

    @property
    def should_stop(self) -> bool:
        return self._event.is_set()

    def request_stop(self):
        """Programmatic preemption (tests, orchestrator RPC)."""
        self._event.set()

    def _handler(self, signum, frame):
        self._event.set()

    def __enter__(self):
        # Partial-failure safe: if installing handler i raises (non-main
        # thread, exotic signal), handlers 0..i-1 are rolled back before the
        # error propagates — a failed __enter__ never leaks handlers.
        try:
            for s in self._signals:
                self._old[s] = signal.signal(s, self._handler)
        except BaseException:
            self._restore()
            raise
        return self

    def _restore(self):
        first = None
        for s, h in list(self._old.items()):
            try:
                signal.signal(s, h)
            except BaseException as e:
                if first is None:
                    first = e
            else:
                del self._old[s]
        if first is not None:
            raise first

    def __exit__(self, *exc):
        # Runs on body exceptions too (context-manager contract), and a
        # handler that fails to restore doesn't strand the REST un-restored.
        self._restore()
        return False
