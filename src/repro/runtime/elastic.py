"""Elastic scaling: rebuild the mesh for whatever devices survive and reshard
the checkpointed state onto it.

Checkpoints are mesh-agnostic (global numpy leaves + the rules table is
re-derived from the config), so growing 256 -> 512 chips or shrinking after
losing a host is the same operation: make a new mesh, recompute shardings,
device_put.  The only global invariant the caller must keep is
`global_batch % batch_shards == 0` — `elastic_mesh` picks the largest
(data, model) factorization that preserves it.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import dist


def elastic_mesh(n_devices: int, *, model_parallel: int = 1,
                 global_batch: int | None = None) -> Mesh:
    """Largest usable (data, model) mesh on `n_devices`."""
    model = model_parallel
    while model > 1 and n_devices % model != 0:
        model //= 2
    data = n_devices // model
    if global_batch is not None:
        while data > 1 and global_batch % data != 0:
            data //= 2
    devs = jax.devices()[: data * model]
    import numpy as np
    return Mesh(np.asarray(devs).reshape(data, model), ("data", "model"))


def reshard_state(state, cfg, mesh: Mesh):
    """device_put every leaf with shardings re-derived for `mesh`.

    Works for the (params, opt_state) training pytree: params get the rules
    table; opt moments mirror the params; scalars replicate."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rules = dist.make_rules(cfg, mesh)
    params, opt = state
    p_sh = dist.param_shardings(params, cfg, mesh, rules)
    o_sh = {"m": p_sh, "v": p_sh,
            "step": NamedSharding(mesh, P())}
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(opt, o_sh)
    return params, opt
