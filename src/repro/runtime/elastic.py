"""Elastic scaling: rebuild the mesh for whatever devices survive and reshard
the checkpointed state onto it.

Checkpoints are mesh-agnostic (global numpy leaves + the rules table is
re-derived from the config), so growing 256 -> 512 chips or shrinking after
losing a host is the same operation: make a new mesh, recompute shardings,
device_put.  The only global invariant the caller must keep is
`global_batch % batch_shards == 0` — `elastic_mesh` picks the largest
(data, model) factorization that preserves it.
"""

from __future__ import annotations

import logging
from typing import NamedTuple

import jax
from jax.sharding import Mesh

from repro import dist

logger = logging.getLogger(__name__)


class MeshPlan(NamedTuple):
    """What `elastic_mesh` decided: the (data, model) factorization plus the
    devices it could NOT use — dropped devices are REPORTED, never silently
    truncated away (a 7-survivor cluster quietly running on 4 devices is a
    capacity bug, not a convenience)."""

    data: int
    model: int
    used: int
    dropped: int


def mesh_plan(n_devices: int, *, model_parallel: int = 1,
              global_batch: int | None = None) -> MeshPlan:
    """Largest usable (data, model) factorization of `n_devices` preserving
    `global_batch % data == 0`, with the dropped-device count."""
    model = model_parallel
    while model > 1 and n_devices % model != 0:
        model //= 2
    data = n_devices // model
    if global_batch is not None:
        while data > 1 and global_batch % data != 0:
            data //= 2
    used = data * model
    return MeshPlan(data, model, used, n_devices - used)


def elastic_mesh(n_devices: int, *, model_parallel: int = 1,
                 global_batch: int | None = None) -> Mesh:
    """Largest usable (data, model) mesh on `n_devices`.  When the
    factorization cannot use every device, the dropped count is logged
    (see `mesh_plan` for the programmatic report)."""
    plan = mesh_plan(n_devices, model_parallel=model_parallel,
                     global_batch=global_batch)
    if plan.dropped:
        logger.warning(
            "elastic_mesh: dropping %d of %d devices (largest usable mesh "
            "is data=%d x model=%d%s)", plan.dropped, n_devices, plan.data,
            plan.model,
            f" under global_batch={global_batch}" if global_batch else "")
    devs = jax.devices()[: plan.used]
    import numpy as np
    return Mesh(np.asarray(devs).reshape(plan.data, plan.model),
                ("data", "model"))


def reshard_state(state, cfg, mesh: Mesh):
    """device_put every leaf with shardings re-derived for `mesh`.

    Works for the (params, opt_state) training pytree: params get the rules
    table; opt moments mirror the params; scalars replicate."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    rules = dist.make_rules(cfg, mesh)
    params, opt = state
    p_sh = dist.param_shardings(params, cfg, mesh, rules)
    o_sh = {"m": p_sh, "v": p_sh,
            "step": NamedSharding(mesh, P())}
    params = jax.device_put(params, p_sh)
    opt = jax.device_put(opt, o_sh)
    return params, opt


def reshard_dist(old_dspec, dstate, new_dspec, mesh: Mesh):
    """Re-shard a big-atomic `DistState` onto a new mesh / shard count,
    preserving logical values AND per-cell versions.

    Versions are load-bearing across a recovery boundary: an executor
    resuming from a checkpoint replays batches whose LinkCtx rows hold
    version numbers from BEFORE the fault — if resharding re-initialized
    versions to zero, every outstanding LL link would spuriously die (or
    worse, spuriously survive).  So the global [n] version vector is
    extracted, the state is rebuilt at the new geometry, and the versions
    are split back per-shard (inverse of `distributed.versions`)."""
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding
    from repro.core import distributed as d2
    if old_dspec.is_hash or new_dspec.is_hash:
        raise TypeError("reshard_dist reshards tables")
    if (old_dspec.n_global, old_dspec.inner.k) != \
            (new_dspec.n_global, new_dspec.inner.k):
        raise ValueError(f"geometry change: {old_dspec.inner} -> "
                         f"{new_dspec.inner}")
    vals = np.asarray(d2.logical(old_dspec, dstate))
    vers = np.asarray(d2.versions(old_dspec, dstate))
    new = d2.init_dist(mesh, new_dspec, vals)
    s, nl = new_dspec.n_shards, new_dspec.n_local
    per = (vers.reshape(nl, s).T if new_dspec.interleave
           else vers.reshape(s, nl))
    local = new.local._replace(
        version=jnp.asarray(np.ascontiguousarray(per)))
    local = jax.device_put(local, NamedSharding(mesh, d2._pspec(new_dspec)))
    return d2.DistState(local)
