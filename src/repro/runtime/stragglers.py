"""Straggler detection for multi-host training.

Each host reports its step wall time; the watchdog keeps a sliding window of
the last `patience` times per host and flags a host when the MEDIAN of its
full window exceeds `threshold` x the fleet median (lower median of per-host
medians).  Window-median — not EWMA — because a single 30x GC/network blip
must not trip the detector: the blip occupies one window slot and the median
ignores it, while a genuinely degraded host fills its whole window and trips
after exactly `patience` steps.

The decision output is a *plan*: which hosts to swap with hot spares, or —
with no spares left — which to drop via the elastic shrink path.  Pure logic,
no cluster dependencies; the launcher consumes the plan.  At 1000+ nodes the
fleet median is robust to up to half the fleet degrading simultaneously.
"""

from __future__ import annotations

import dataclasses
from collections import deque


@dataclasses.dataclass
class StragglerPlan:
    flagged: list            # host ids currently over threshold
    swap: dict               # host id -> spare id (as far as spares last)
    shrink: list             # flagged hosts left over with no spare


def _median(xs) -> float:
    s = sorted(xs)
    return s[(len(s) - 1) // 2]          # lower median (robust for n=2)


class StragglerWatchdog:
    def __init__(self, n_hosts: int, *, threshold: float = 1.5,
                 patience: int = 3, spares: list | None = None):
        self.n_hosts = n_hosts
        self.threshold = threshold
        self.patience = max(patience, 1)
        self.window = [deque(maxlen=self.patience) for _ in range(n_hosts)]
        self.spares = list(spares or [])

    def observe(self, step_times: list[float]) -> StragglerPlan:
        assert len(step_times) == self.n_hosts
        for i, t in enumerate(step_times):
            self.window[i].append(float(t))
        host_med = [_median(w) if w else 0.0 for w in self.window]
        fleet = _median(host_med)
        flagged = [
            i for i in range(self.n_hosts)
            if len(self.window[i]) == self.patience and fleet > 0
            and host_med[i] > self.threshold * fleet
        ]
        swap, shrink = {}, []
        for h in flagged:
            if self.spares:
                swap[h] = self.spares.pop(0)
            else:
                shrink.append(h)
        for h in swap:                       # swapped hosts start fresh
            self.window[h].clear()
        return StragglerPlan(flagged, swap, shrink)
