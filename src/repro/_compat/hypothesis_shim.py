"""Minimal deterministic stand-in for `hypothesis`.

Installed into ``sys.modules`` by the root conftest.py ONLY when the real
package is absent (the pinned CI image does not bake it), so the property
tests still *run* — they draw `max_examples` pseudo-random examples from a
PRNG seeded by the test's qualified name, with light endpoint biasing.
There is no shrinking and no example database; a failure reports the raw
falsifying example.  Supports exactly the subset this repo uses:

    @settings(max_examples=..., deadline=...)
    @given(x=st.integers(a, b), ...)
    st.integers / st.floats / st.booleans / st.sampled_from
    assume(...)

If the real hypothesis is installed, this module is never imported.
"""

from __future__ import annotations

import functools
import inspect
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class _Unsatisfied(Exception):
    """Raised by assume(False): the example is discarded, not failed."""


def assume(condition) -> bool:
    if not condition:
        raise _Unsatisfied()
    return True


def note(message):  # parity stub; real hypothesis attaches it to the report
    print(f"[hypothesis-shim note] {message}")


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng=None):
        rng = rng or np.random.default_rng(0)
        return self._draw(rng)


def _integers(min_value, max_value):
    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return int(min_value)
        if r < 0.10:
            return int(max_value)
        return int(rng.integers(min_value, max_value, endpoint=True))
    return _Strategy(draw)


def _floats(min_value=0.0, max_value=1.0, **_kw):
    def draw(rng):
        r = rng.random()
        if r < 0.05:
            return float(min_value)
        if r < 0.10:
            return float(max_value)
        return float(min_value + (max_value - min_value) * rng.random())
    return _Strategy(draw)


def _booleans():
    return _Strategy(lambda rng: bool(rng.random() < 0.5))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[int(rng.integers(len(elements)))])


strategies = types.ModuleType("hypothesis.strategies")
strategies.integers = _integers
strategies.floats = _floats
strategies.booleans = _booleans
strategies.sampled_from = _sampled_from


class settings:
    def __init__(self, max_examples=DEFAULT_MAX_EXAMPLES, deadline=None,
                 **_kw):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


class HealthCheck:
    # accessed as settings(suppress_health_check=[...]) in the wild; any
    # attribute works as an opaque token here
    too_slow = data_too_large = filter_too_much = all = object()


def given(**strats):
    """Decorator: call the test with `max_examples` drawn keyword examples."""

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = (getattr(wrapper, "_shim_settings", None)
                   or getattr(fn, "_shim_settings", None))
            n = cfg.max_examples if cfg else DEFAULT_MAX_EXAMPLES
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode("utf-8")))
            tried = 0
            budget = n * 10            # assume() discard allowance
            while tried < n and budget > 0:
                budget -= 1
                example = {k: s.example(rng) for k, s in strats.items()}
                try:
                    fn(*args, **example, **kwargs)
                except _Unsatisfied:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (shim, try {tried}): "
                        f"{fn.__name__}({example})") from e
                tried += 1

        # pytest must not see the strategy kwargs as fixture requests
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(parameters=[
            p for name, p in sig.parameters.items() if name not in strats])
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper

    return deco
