"""Fallback shims for optional third-party packages the CI image may lack."""
