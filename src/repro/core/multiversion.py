"""Versioned snapshot store — the paper's multiversioning application
(§2: "allows the first version, most commonly accessed, to be stored inline
and updated atomically"), applied to the thing a training framework actually
multi-versions: the train state.

Since the txn subsystem landed (DESIGN.md §7) the store's version/step/head
bookkeeping is no longer hand-rolled: it rides `repro.txn.versionlist` — a
per-slot bounded version chain whose head cell is a big atomic on the
unified engine.  The payload ring (`slots`, a pytree of stacked train
states) stays as before — float tensors don't fit uint32 word cells — but
every piece of METADATA a reader validates against lives in the version
list's head table:

  * the per-ring-slot `version` array IS the head table's big-atomic cell
    version (even = consistent; a publish is one engine STORE, +2), and
  * the per-slot `step` is the head cell's inline value word.

The head table is pinned to the `seqlock` layout — the protocol this module
hand-rolled before the rewrite (data + even/odd version IS a seqlock), so
`begin_publish` (freeze the writer mid-copy) remains the same odd-version
torn state, now expressed against the layout's own fields.

The reader protocol is unchanged: `snapshot()` reads head, then the slot,
then `validate()` confirms the version is even and unchanged — the paper's
fast-path invariant "validated pointer => cache equals backup" with the
ring as the backup pool.  New since the rewrite: `step_at(store, t)` — a
timestamped read of which training step was live at publish-time `t`,
straight off the bounded version chain.

Everything is functional (pytrees in, pytrees out) so it works under jit
and across process boundaries (the checkpoint package serializes
snapshots).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.specs import VersionSpec
from repro.txn import versionlist as vl

# The head table stores one word of payload per ring slot (the training
# step); depth 2 = inline head + one pooled predecessor per slot, enough to
# answer step_at() across the writer's most recent lap.
_K = 1
_DEPTH = 2
_STRATEGY = "seqlock"          # the layout this module used to hand-roll


def _vspec(n_slots: int) -> VersionSpec:
    return VersionSpec(n=n_slots, k=_K, depth=_DEPTH, strategy=_STRATEGY,
                       p_max=64)


class VersionedStore(NamedTuple):
    slots: Any                # pytree, each leaf stacked to [S, ...]
    vstate: vl.VersionState   # head table: [step] per slot + chain metadata
    head: jax.Array           # int32[], freshest consistent slot

    # -- the v1 read surface, derived from the version-list state ---------

    @property
    def version(self) -> jax.Array:
        """uint32[S]; even = consistent (the head table's cell versions)."""
        return self.vstate.table.version

    @property
    def step(self) -> jax.Array:
        """int32[S], training step held by each slot (head cell word 0)."""
        return self.vstate.table.data[:, 0].astype(jnp.int32)


def init_store(state, n_slots: int = 2) -> VersionedStore:
    """Ring of `n_slots` copies of `state` (slot 0 = the initial state)."""
    slots = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_slots,) + x.shape), state)
    return VersionedStore(
        slots=slots,
        vstate=vl.init(_vspec(n_slots), np.zeros((n_slots, _K), np.uint32)),
        head=jnp.int32(0),
    )


@jax.jit
def publish(store: VersionedStore, state, step) -> VersionedStore:
    """Writer: install `state` as the freshest snapshot.  O(bytes) copy, no
    reader can block it (lock-free by construction: readers only validate).

    The payload copy lands in the ring; the metadata update — step word +
    version bump (+2, stays even) — is ONE engine STORE on the slot's
    big-atomic head cell; swinging `head` is the linearization point."""
    n = store.version.shape[0]
    slot = (store.head + 1) % n
    slots = jax.tree.map(lambda buf, x: buf.at[slot].set(x),
                         store.slots, state)
    spec = _vspec(n)
    ts = (store.vstate.count.sum() + 1).astype(jnp.uint32)  # publish counter
    vstate = vl.publish(spec, store.vstate, slot[None],
                        jnp.asarray(step, jnp.uint32).reshape(1, _K),
                        ts[None])
    return VersionedStore(slots, vstate, slot)


class Snapshot(NamedTuple):
    state: Any
    step: jax.Array
    slot: jax.Array
    version: jax.Array


def snapshot(store: VersionedStore) -> Snapshot:
    """Reader fast path: head -> slot -> validate.  Under jit-level atomicity
    of a step this always validates; the cross-step race (writer lapping the
    reader) is exercised by `snapshot_with_validation` below."""
    slot = store.head
    state = jax.tree.map(lambda buf: buf[slot], store.slots)
    return Snapshot(state, store.step[slot], slot, store.version[slot])


def validate(store: VersionedStore, snap: Snapshot) -> jax.Array:
    """True iff `snap` is still a consistent snapshot (version unchanged and
    even).  A checkpointer calls this AFTER serializing: if False, the bytes
    written may be torn across publishes — retry from the new head."""
    v = store.version[snap.slot]
    return jnp.logical_and(v == snap.version, v % 2 == 0)


def snapshot_with_validation(store: VersionedStore, *, max_retries: int = 3):
    """Host-side reader loop (not jitted): snapshot, validate, retry.  This
    is the paper's load retry loop; with S >= 2 slots a single retry suffices
    unless the writer publishes S times during one read."""
    for _ in range(max_retries):
        snap = snapshot(store)
        if bool(validate(store, snap)):
            return snap
    raise RuntimeError("snapshot validation failed after retries "
                       "(writer lapped the reader repeatedly)")


def step_at(store: VersionedStore, publish_ts):
    """Timestamped metadata read off the version chains: the training step
    each ring slot held at global publish time `publish_ts` (uint32[S] step,
    bool[S] ok; ok=False where that history is evicted or torn)."""
    n = store.version.shape[0]
    slots = jnp.arange(n, dtype=jnp.int32)
    ts = jnp.full((n,), publish_ts, jnp.uint32)
    vals, _fts, ok = vl.snapshot_read(_vspec(n), store.vstate, slots, ts)
    return vals[:, 0], ok


# ---------------------------------------------------------------------------
# Torn-state simulation (the oversubscription analogue, for tests/benchmarks)
# ---------------------------------------------------------------------------

def begin_publish(store: VersionedStore, state) -> VersionedStore:
    """Freeze the writer mid-copy (payload half-written, head-cell version
    bumped ODD, `head` not yet swung): readers using the protocol keep
    returning the OLD consistent snapshot; a naive reader of the torn slot
    returns garbage (negative control in tests).  This is the version
    list's head table playing its seqlock role: odd = locked."""
    n = store.version.shape[0]
    slot = (store.head + 1) % n
    table = store.vstate.table
    table = table._replace(
        version=table.version.at[slot].add(jnp.uint32(1)))   # odd = locked

    def half_copy(buf, x):
        flat = x.reshape(-1)
        half = flat.shape[0] // 2
        cur = buf[slot].reshape(-1)
        torn = jnp.concatenate([flat[:half], cur[half:]]).reshape(x.shape)
        return buf.at[slot].set(torn)

    slots = jax.tree.map(half_copy, store.slots, state)
    return store._replace(slots=slots,
                          vstate=store.vstate._replace(table=table))
