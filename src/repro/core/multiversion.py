"""Versioned snapshot store — the paper's multiversioning application
(§2: "allows the first version, most commonly accessed, to be stored inline
and updated atomically"), adapted to the thing a training framework actually
multi-versions: the train state.

The writer (optimizer loop) `publish()`es each new state into a ring of S
slots using the Cached-ME protocol:

    1. bump the slot's version to ODD  (slot locked / mid-copy),
    2. copy the pytree into the slot,
    3. bump to EVEN,
    4. atomically swing `head` to the slot  (the linearization point).

Async readers (`snapshot()`) — checkpointer, evaluator, elastic joiners —
read `head`, then the slot, then validate the slot's version is even and
unchanged.  A reader never blocks the writer and never observes a torn
state: if the writer lapped it mid-read (possible only after S further
publishes), validation fails and the reader retries on the new head.  This
is exactly the paper's fast-path invariant "validated pointer => cache equals
backup", with the ring playing the role of the backup pool and `head` the
role of the backup pointer.

Everything is functional (pytrees in, pytrees out) so it works under jit and
across process boundaries (the checkpoint package serializes snapshots).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class VersionedStore(NamedTuple):
    slots: Any                # pytree, each leaf stacked to [S, ...]
    version: jax.Array        # uint32[S], even = consistent
    step: jax.Array           # int32[S], training step held by each slot
    head: jax.Array           # int32[], freshest consistent slot


def init_store(state, n_slots: int = 2) -> VersionedStore:
    """Ring of `n_slots` copies of `state` (slot 0 = the initial state)."""
    slots = jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (n_slots,) + x.shape), state)
    return VersionedStore(
        slots=slots,
        version=jnp.zeros((n_slots,), jnp.uint32),
        step=jnp.zeros((n_slots,), jnp.int32),
        head=jnp.int32(0),
    )


@jax.jit
def publish(store: VersionedStore, state, step) -> VersionedStore:
    """Writer: install `state` as the freshest snapshot.  O(bytes) copy, no
    reader can block it (lock-free by construction: readers only validate)."""
    n = store.version.shape[0]
    slot = (store.head + 1) % n
    # 1. lock (odd) — readers of THIS slot start failing validation
    ver = store.version.at[slot].add(jnp.uint32(1))
    # 2. copy
    slots = jax.tree.map(lambda buf, x: buf.at[slot].set(x),
                         store.slots, state)
    # 3. unlock (even, advanced)
    ver = ver.at[slot].add(jnp.uint32(1))
    stepv = store.step.at[slot].set(jnp.asarray(step, jnp.int32))
    # 4. linearization point: swing head
    return VersionedStore(slots, ver, stepv, slot)


class Snapshot(NamedTuple):
    state: Any
    step: jax.Array
    slot: jax.Array
    version: jax.Array


def snapshot(store: VersionedStore) -> Snapshot:
    """Reader fast path: head -> slot -> validate.  Under jit-level atomicity
    of a step this always validates; the cross-step race (writer lapping the
    reader) is exercised by `snapshot_with_validation` below."""
    slot = store.head
    state = jax.tree.map(lambda buf: buf[slot], store.slots)
    return Snapshot(state, store.step[slot], slot, store.version[slot])


def validate(store: VersionedStore, snap: Snapshot) -> jax.Array:
    """True iff `snap` is still a consistent snapshot (version unchanged and
    even).  A checkpointer calls this AFTER serializing: if False, the bytes
    written may be torn across publishes — retry from the new head."""
    v = store.version[snap.slot]
    return jnp.logical_and(v == snap.version, v % 2 == 0)


def snapshot_with_validation(store: VersionedStore, *, max_retries: int = 3):
    """Host-side reader loop (not jitted): snapshot, validate, retry.  This
    is the paper's load retry loop; with S >= 2 slots a single retry suffices
    unless the writer publishes S times during one read."""
    for _ in range(max_retries):
        snap = snapshot(store)
        if bool(validate(store, snap)):
            return snap
    raise RuntimeError("snapshot validation failed after retries "
                       "(writer lapped the reader repeatedly)")


# ---------------------------------------------------------------------------
# Torn-state simulation (the oversubscription analogue, for tests/benchmarks)
# ---------------------------------------------------------------------------

def begin_publish(store: VersionedStore, state) -> VersionedStore:
    """Freeze the writer mid-copy (steps 1-2 done, 3-4 pending): the target
    slot is odd/torn, head still points at the previous slot.  Readers using
    the protocol keep returning the OLD consistent snapshot; a naive reader
    of the torn slot returns garbage (negative control in tests)."""
    n = store.version.shape[0]
    slot = (store.head + 1) % n
    ver = store.version.at[slot].add(jnp.uint32(1))      # odd = locked

    def half_copy(buf, x):
        flat = x.reshape(-1)
        half = flat.shape[0] // 2
        cur = buf[slot].reshape(-1)
        torn = jnp.concatenate([flat[:half], cur[half:]]).reshape(x.shape)
        return buf.at[slot].set(torn)

    slots = jax.tree.map(half_copy, store.slots, state)
    return store._replace(slots=slots, version=ver)
