"""Cached-WaitFree-Writable (paper §3.3, Algorithm 3): wait-free load +
store + CAS built over a Load/CAS big atomic, via a write-buffer W and
mark-matching help protocol.

Faithful state per atomic i:
    Z[i]       — the central (k+2)-word triple (value, seq, zmark), held in a
                 `bigatomic` table (our Load/CAS object);
    W[i]       — write-buffer: index into a node pool, plus a wmark bit.
Invariant: zmark != wmark  <=>  there is a PENDING store (installed in W,
not yet transferred to Z).  Transfer = CAS on Z that copies *W's* value,
bumps seq, and flips zmark to re-match — done by ANY helper (writers and
CASers both help; that is what makes stores wait-free).

TPU adaptation: one SPMD step applies a batch of ops.  The protocol's
cross-thread interleavings become cross-STEP interleavings: `begin_store`
installs into W and returns *without* transferring (the descheduled-writer
case); any later batch — even one containing only CAS ops — transfers the
pending write first (helping), exactly like Algorithm 3's help_write call in
cas().  Tests drive these interleavings explicitly and check linearizability
against a sequential oracle.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import semantics as sem

NULLW = jnp.int32(-1)


class WritableState(NamedTuple):
    z_value: jax.Array        # word[n, k]  — Z.value
    z_seq: jax.Array          # uint32[n]   — Z.seq (ABA guard)
    z_mark: jax.Array         # bool[n]     — Z.mark
    w_node: jax.Array         # int32[n]    — W pointer (pool index, -1 none)
    w_mark: jax.Array         # bool[n]     — mark carried by W
    pool: jax.Array           # word[m, k]  — write-buffer nodes
    pool_next: jax.Array      # uint32[]    — bump allocator (ring)


def init(n: int, k: int, p_max: int = 64,
         initial: np.ndarray | None = None) -> WritableState:
    data = jnp.zeros((n, k), sem.WORD_DTYPE) if initial is None else \
        jnp.asarray(initial, sem.WORD_DTYPE)
    m = max(2 * p_max, 2)
    return WritableState(
        z_value=data,
        z_seq=jnp.zeros((n,), jnp.uint32),
        z_mark=jnp.zeros((n,), bool),
        w_node=jnp.full((n,), NULLW),
        w_mark=jnp.zeros((n,), bool),
        pool=jnp.zeros((m, k), sem.WORD_DTYPE),
        pool_next=jnp.uint32(0),
    )


def pending(st: WritableState) -> jax.Array:
    """bool[n]: marks mismatched <=> a store is installed but untransferred."""
    return st.z_mark != st.w_mark


def load(st: WritableState, slots: jax.Array) -> jax.Array:
    """Wait-free: one read of Z.value (Line 11).  Pending writes in W are
    invisible until transferred — they linearize at transfer time."""
    return st.z_value[slots]


def help_write(st: WritableState) -> WritableState:
    """Transfer every pending write from W to Z (Lines 35-41).  In a batched
    step the helper resolves ALL mismatched cells at once; seq += 1 and
    zmark flips to re-match (the CAS on Z of Algorithm 3)."""
    mism = pending(st)
    w_val = st.pool[jnp.maximum(st.w_node, 0)]
    z_value = jnp.where(mism[:, None], w_val, st.z_value)
    z_seq = jnp.where(mism, st.z_seq + 1, st.z_seq)
    z_mark = jnp.where(mism, st.w_mark, st.z_mark)
    return st._replace(z_value=z_value, z_seq=z_seq, z_mark=z_mark)


def begin_store(st: WritableState, slot: int, value) -> WritableState:
    """First half of store(): install the node in W and mismatch the marks
    (Lines 19-20), then 'get descheduled' — NO transfer.  Returns with the
    store pending; any later operation completes it (helping).

    If a pending write already exists on this slot the new writer linearizes
    silently before it (Line 18 branch: it does not even install) —
    mirrored here by returning the state unchanged."""
    value = jnp.asarray(value, sem.WORD_DTYPE)
    already = pending(st)[slot]
    same = jnp.all(st.z_value[slot] == value)
    m = st.pool.shape[0]
    node = (st.pool_next % jnp.uint32(m)).astype(jnp.int32)
    do = jnp.logical_not(jnp.logical_or(already, same))
    pool = st.pool.at[jnp.where(do, node, m)].set(value, mode="drop")
    w_node = st.w_node.at[slot].set(jnp.where(do, node, st.w_node[slot]))
    w_mark = st.w_mark.at[slot].set(
        jnp.where(do, jnp.logical_not(st.z_mark[slot]), st.w_mark[slot]))
    return st._replace(pool=pool, w_node=w_node, w_mark=w_mark,
                       pool_next=st.pool_next + do.astype(jnp.uint32))


def store(st: WritableState, slot: int, value) -> WritableState:
    """Complete store: install + help twice (Line 23: one help can fail to a
    racing CAS at most once, so two suffice — here batched help is total)."""
    st = begin_store(st, slot, value)
    return help_write(st)


def cas_batch(st: WritableState, slots, expected, desired):
    """Batched CAS (Lines 25-33): helpers first (transfer pending writes),
    then the compare-exchange on Z with seq bump.  Within the batch, same-slot
    CASes serialize in lane order via the shared combining scan.

    Returns (state', success bool[p])."""
    st = help_write(st)                      # Line 30: casers help writers
    ops = sem.OpBatch(
        jnp.full((slots.shape[0],), sem.CAS, jnp.int32),
        jnp.asarray(slots, jnp.int32),
        jnp.asarray(expected, sem.WORD_DTYPE),
        jnp.asarray(desired, sem.WORD_DTYPE))
    new_val, new_seq_x2, res, _ = sem.apply_batch(
        st.z_value, st.z_seq * 2, ops)       # reuse parity-versioned engine
    return st._replace(z_value=new_val, z_seq=new_seq_x2 // 2), res.success


def store_batch(st: WritableState, slots, values) -> WritableState:
    """Batched stores: install every lane's write (last lane per slot wins,
    = lane-order linearization), then transfer."""
    slots = jnp.asarray(slots, jnp.int32)
    values = jnp.asarray(values, sem.WORD_DTYPE)
    n = st.z_value.shape[0]
    m = st.pool.shape[0]
    p = slots.shape[0]
    # last write per slot wins: scatter in lane order
    base = (st.pool_next % jnp.uint32(m)).astype(jnp.int32)
    nodes = (base + jnp.arange(p, dtype=jnp.int32)) % m
    pool = st.pool.at[nodes].set(values)
    w_node = st.w_node.at[slots].set(nodes)
    w_mark = st.w_mark.at[slots].set(jnp.logical_not(st.z_mark[slots]))
    st = st._replace(pool=pool, w_node=w_node, w_mark=w_mark,
                     pool_next=st.pool_next + jnp.uint32(p))
    return help_write(st)


# ---------------------------------------------------------------------------
# Sequential oracle for linearizability tests
# ---------------------------------------------------------------------------

def oracle_apply(values: np.ndarray, script: list[tuple]) -> tuple:
    """Apply a script of ('load',s) / ('store',s,v) / ('cas',s,e,d) /
    ('help',) sequentially; pending stores take effect at the next help or
    op that helps.  Returns (values, outputs)."""
    values = np.array(values, copy=True)
    pending_w: dict[int, np.ndarray] = {}
    out = []

    def flush():
        for s, v in list(pending_w.items()):
            values[s] = v
        pending_w.clear()

    for op in script:
        if op[0] == "load":
            out.append(values[op[1]].copy())
        elif op[0] == "begin_store":
            s, v = op[1], np.asarray(op[2])
            if s not in pending_w and not np.array_equal(values[s], v):
                pending_w[s] = v
        elif op[0] == "store":
            s, v = op[1], np.asarray(op[2])
            had_pending = s in pending_w
            flush()
            # Algorithm 3: a store that finds a pending write on its slot
            # linearizes SILENTLY immediately before that write's transfer —
            # its own value never appears (Line 18 false-branch).  Same for
            # a store of the current value (Line 17).
            if not had_pending and not np.array_equal(values[s], v):
                values[s] = v
        elif op[0] == "help":
            flush()
        elif op[0] == "cas":
            flush()                       # casers help first
            s, e, d = op[1], np.asarray(op[2]), np.asarray(op[3])
            ok = np.array_equal(values[s], e)
            if ok:
                values[s] = d
            out.append(ok)
    return values, out
