"""Mesh-sharded big-atomic table (beyond-paper: the paper is single-node).

The table's n cells shard over one mesh axis; each device owns a contiguous
range of cells plus its own lane-slice of the op batch.  One collective
round-trip executes a globally linearizable batch:

  1. route   — each device buckets its ops by owner shard and exchanges them
               with a fixed-capacity `all_to_all` (capacity = p_local per
               (src, dst) pair; overflow beyond capacity is reported, not
               silently dropped);
  2. apply   — every shard runs the LOCAL deterministic linearization
               (`semantics.apply_batch`) on the ops it owns.  Linearization
               order is (src_device, lane) — a fixed total order, so the
               result equals a global sequential application in that order;
  3. return  — results ride the inverse `all_to_all` back to the issuing
               lane.

Collective cost per batch: 2 all_to_alls of p_local * (2k+4) words each —
this is the '(most representative of the paper)' roofline cell and hillclimb
target; see benchmarks/bench_distributed.py.

Device-local code runs under `shard_map`, so the same `semantics` engine is
reused unchanged — the distribution layer is ~150 lines on top of it.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import semantics as sem


class ShardedTable(NamedTuple):
    data: jax.Array        # word[n, k], sharded over axis 0
    version: jax.Array     # uint32[n], sharded over axis 0


def init_sharded(mesh: Mesh, axis: str, n: int, k: int,
                 initial: np.ndarray | None = None) -> ShardedTable:
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    assert n % n_shards == 0, (n, n_shards)
    data = jnp.zeros((n, k), sem.WORD_DTYPE) if initial is None \
        else jnp.asarray(initial, sem.WORD_DTYPE)
    ver = jnp.zeros((n,), jnp.uint32)
    sh = NamedSharding(mesh, P(axis))
    return ShardedTable(jax.device_put(data, NamedSharding(mesh, P(axis, None))),
                        jax.device_put(ver, sh))


def make_apply(mesh: Mesh, axis: str, n: int, k: int, p_local: int,
               *, route_capacity: int | None = None,
               dedup_loads: bool = False, interleave: bool = False):
    """Build the jitted distributed apply for a fixed op-batch geometry.

    Returned fn: (table, ops) -> (table', result, overflow_count) where
    `ops` is an OpBatch of p_global = p_local * n_shards lanes, sharded on
    lane axis.  Lanes whose slot routes beyond a (src,dst) pair's capacity
    are rejected (kind treated as IDLE) and counted in overflow_count —
    at uniform load the capacity is ~n_shards x the mean, so overflow means
    severe skew (raise capacity or rebalance).

    §Perf levers (hillclimb C, EXPERIMENTS.md):
      route_capacity — per-(src,dst) slots in the all_to_all buffers.  The
          collective bytes are EXACTLY proportional to this (fixed-shape
          exchange), so shrinking it below p_local cuts the wire cost;
      dedup_loads — loads of the same cell from the same source device with
          no same-source update to that cell route ONCE; duplicates are
          filled in locally from the representative's answer.  Safe because
          the linearization order is source-major: such loads are adjacent
          in the global order and must return identical values.  Under
          Zipfian skew this collapses the routed load count by ~the mean
          duplicate multiplicity, letting route_capacity shrink without
          overflow."""
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    cells_per = n // n_shards
    cap = route_capacity or p_local

    def local(data, ver, kind, slot, expected, desired):
        # data: [cells_per, k]; ops: this device's [p_local] lanes
        my = lax.axis_index(axis)

        rep = jnp.arange(p_local, dtype=jnp.int32)   # dedup representative
        if dedup_loads:
            d_order = jnp.argsort(slot, stable=True)
            d_inv = jnp.argsort(d_order, stable=True)
            d_slot = slot[d_order]
            d_kind = kind[d_order]
            idxs = jnp.arange(p_local, dtype=jnp.int32)
            d_start = jnp.concatenate([jnp.ones((1,), bool),
                                       d_slot[1:] != d_slot[:-1]])
            start_idx = sem._segmented_scan_max(
                jnp.where(d_start, idxs, -1), d_start)
            is_upd_l = (d_kind == sem.STORE) | (d_kind == sem.CAS)
            # does this segment contain any update? (fwd+bwd broadcast)
            seg_end = jnp.concatenate([d_start[1:], jnp.ones((1,), bool)])
            any_upd = jnp.flip(sem._segmented_scan_max(
                jnp.flip(is_upd_l.astype(jnp.int32)), jnp.flip(seg_end))) > 0
            dup = (d_kind == sem.LOAD) & ~any_upd & ~d_start
            rep_sorted = jnp.where(dup, d_order[start_idx], d_order)
            rep = rep_sorted[d_inv]
            kind = jnp.where(rep != jnp.arange(p_local), sem.IDLE, kind)

        if interleave:
            owner = slot % n_shards
            local_slot = slot // n_shards
        else:
            owner = jnp.clip(slot // cells_per, 0, n_shards - 1)
            local_slot = slot % cells_per
        owner = jnp.where(kind != sem.IDLE, owner, n_shards)  # idle -> drop

        # --- route out: bucket by owner, capacity p_local per destination --
        # rank of each lane within its destination bucket
        order = jnp.argsort(owner, stable=True)
        inv = jnp.argsort(order, stable=True)
        s_owner = owner[order]
        idx = jnp.arange(p_local, dtype=jnp.int32)
        seg_start = jnp.concatenate([jnp.ones((1,), bool),
                                     s_owner[1:] != s_owner[:-1]])
        start = sem._segmented_scan_max(jnp.where(seg_start, idx, -1),
                                        seg_start)
        rank_sorted = idx - start
        rank = rank_sorted[inv]
        fits = (rank < cap) & (owner < n_shards)
        overflow = jnp.sum((~fits & (kind != sem.IDLE)).astype(jnp.int32))

        # pack into [n_shards, cap] send buffers (IDLE padding)
        dst = jnp.where(fits, owner * cap + rank, n_shards * cap)
        pack = lambda x, fill: jnp.full(
            (n_shards * cap,) + x.shape[1:], fill, x.dtype
        ).at[dst].set(x, mode="drop")
        snd_kind = pack(jnp.where(fits, kind, sem.IDLE), sem.IDLE)
        snd_slot = pack(local_slot, 0)
        snd_exp = pack(expected, 0)
        snd_des = pack(desired, 0)
        # remember where each of my lanes went (dst shard, position)
        src_pos = jnp.where(fits, rank, -1)

        a2a = lambda x: lax.all_to_all(
            x.reshape((n_shards, cap) + x.shape[1:]), axis,
            split_axis=0, concat_axis=0, tiled=False)
        r_kind = a2a(snd_kind).reshape(n_shards * cap)
        r_slot = a2a(snd_slot).reshape(n_shards * cap)
        r_exp = a2a(snd_exp).reshape((n_shards * cap, k))
        r_des = a2a(snd_des).reshape((n_shards * cap, k))

        # --- apply locally: linearization order = (src shard, lane rank) ---
        ops = sem.OpBatch(r_kind, r_slot, r_exp, r_des)
        data, ver, res, _ = sem.apply_batch(data, ver, ops)

        # --- route back ------------------------------------------------------
        back = lambda x: lax.all_to_all(
            x.reshape((n_shards, cap) + x.shape[1:]), axis,
            split_axis=0, concat_axis=0, tiled=False)
        b_val = back(res.value).reshape((n_shards, cap) + (k,))
        b_suc = back(res.success).reshape((n_shards, cap))
        # my lane i's answer sits at [owner[i], src_pos[i]]
        safe_owner = jnp.clip(owner, 0, n_shards - 1)
        safe_pos = jnp.maximum(src_pos, 0)
        value = b_val[safe_owner, safe_pos]
        success = jnp.where(fits, b_suc[safe_owner, safe_pos], False)
        if dedup_loads:
            # duplicates copy their representative's answer locally
            value = value[rep]
            success = success[rep]
        return data, ver, value, success, overflow[None]

    spec_tab = P(axis, None)
    spec_ver = P(axis)
    spec_lane = P(axis)
    spec_lane2 = P(axis, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(spec_tab, spec_ver, spec_lane, spec_lane, spec_lane2,
                  spec_lane2),
        out_specs=(spec_tab, spec_ver, spec_lane2, spec_lane, spec_lane),
        check_rep=False)

    @jax.jit
    def apply_ops(table: ShardedTable, ops: sem.OpBatch):
        data, ver, value, success, overflow = fn(
            table.data, table.version, ops.kind, ops.slot, ops.expected,
            ops.desired)
        return (ShardedTable(data, ver), sem.ApplyResult(value, success),
                jnp.sum(overflow))

    return apply_ops


def reference_apply(data, version, ops: sem.OpBatch, *, n_shards: int,
                    p_local: int, interleave: bool = False):
    """Sequential oracle in the distributed linearization order
    (src shard-major, then destination-bucket rank order == lane order
    within each src)."""
    kind = np.asarray(ops.kind)
    slot = np.asarray(ops.slot)
    n = data.shape[0]
    cells_per = n // n_shards
    # order ops as each owner shard sees them: for owner o, for src s, the
    # lanes of src s with owner o in lane order (capacity p_local per pair)
    per_src = np.split(np.arange(kind.shape[0]), n_shards)
    owner_of = (lambda x: x % n_shards) if interleave \
        else (lambda x: x // cells_per)
    seq = []
    dropped = []
    for o in range(n_shards):
        for s in range(n_shards):
            cnt = 0
            for i in per_src[s]:
                if kind[i] == sem.IDLE:
                    continue
                if owner_of(slot[i]) == o:
                    if cnt < p_local:
                        seq.append(i)
                        cnt += 1
                    else:
                        dropped.append(i)
    reordered = sem.OpBatch(
        jnp.asarray(kind[seq]), jnp.asarray(slot[seq]),
        jnp.asarray(np.asarray(ops.expected)[seq]),
        jnp.asarray(np.asarray(ops.desired)[seq]))
    d2, v2, res = sem.apply_batch_reference(data, version, reordered)
    # scatter results back to lane order
    p = kind.shape[0]
    k = data.shape[1]
    value = np.zeros((p, k), data.dtype)
    success = np.zeros((p,), bool)
    value[seq] = np.asarray(res.value)
    success[seq] = np.asarray(res.success)
    return d2, v2, sem.ApplyResult(value, success), dropped
