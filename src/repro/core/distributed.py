"""Mesh-sharded big atomics on the v2 spec/registry engine (DESIGN.md §6).

The paper's experiments stop at one node; this module is the scale-out
execution layer for everything the unified engine can express.  A structure's
n cells shard over one mesh axis; each device owns a contiguous block of
cells (or the `slot % n_shards` residue class with `interleave=True`) plus
its own `p_local`-lane slice of the op batch.  One collective round-trip
executes a globally linearizable batch over the FULL op schema:

  1. route   — each device buckets its ops by owner shard and exchanges them
               with a fixed-capacity `all_to_all` (capacity = `cap` per
               (src, dst) pair).  LL/SC/VALIDATE lanes ride with their link
               version and a link-matches-slot bit, so the owner shard can
               arbitrate links it has never seen (the routed per-owner
               `LinkCtx`).  Lanes beyond capacity are NOT silently dropped:
               they surface in the returned per-lane `overflow` mask with
               `success=False` and leave the table untouched.
  2. apply   — every shard runs the LOCAL v2 linearization
               (`engine.linearize` over `StrategyImpl.engine_view`/`commit`,
               resolved through the strategy registry) on the ops it owns,
               so all registered layouts — built-in or test-registered —
               run sharded unchanged.  Linearization order is
               (owner, src device, lane) — a fixed total order, so the
               result equals a global sequential application in that order
               (`linearization_order` emits it for the oracle harness).
  3. return  — results (and, for LL lanes, the linked version) ride the
               inverse `all_to_all` back to the issuing lane, which merges
               them into its persistent per-lane `LinkCtx`.

`apply_hash` runs the same round for a `HashSpec` CacheHash: ops route by
key owner (`bucket // nb_local`, the top bits of the bucket hash) and every
shard applies its slice with `cachehash.apply_hash` over its own node pool.

Collective cost per batch and device: 2 all_to_alls moving
`n_shards * cap * (3k + 6)` words (table) / `n_shards * cap * (2vw + 4)`
words (hash) — the roofline cell `benchmarks/bench_distributed.py` sweeps;
`collective_words` is the exact model.

The v1 surface (`ShardedTable` / `init_sharded` / `make_apply` /
`reference_apply`, load/store/CAS only, PLAIN layout) survives as
deprecation shims over this engine.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import cachehash as ch
from repro.core import engine
from repro.core import registry
from repro.core.deprecation import warn_once
from repro.core.layout import TableState, WORD_DTYPE
from repro.core.specs import AtomicSpec, HashSpec
from repro.obs import telemetry as obs_telemetry


@dataclasses.dataclass(frozen=True)
class DistSpec:
    """Static shape of a sharded structure: an inner spec + mesh geometry.

    inner:          the structure being sharded (`AtomicSpec` or `HashSpec`);
                    its strategy resolves through the registry per shard.
    axis:           mesh axis name the cells and lanes shard over.
    n_shards:       devices along `axis` (cells split n / n_shards each).
    p_local:        op lanes issued per device; p_global = n_shards * p_local.
    route_capacity: per-(src, dst) slots in the all_to_all buffers (default
                    p_local, which can never overflow ops a device issues).
                    The collective bytes are EXACTLY proportional to this.
    dedup_loads:    loads of one cell from one source device whose cell sees
                    only loads from that source route ONCE; duplicates are
                    filled locally from the representative (safe: the order
                    is source-major, such loads are adjacent).
    interleave:     owner = slot % n_shards instead of contiguous blocks
                    (tables only; spreads contiguous-slot hotspots).
    n_nodes:        > 1 factors the shard axis as (n_nodes, devs_per_node)
                    and routes HIERARCHICALLY (tables only): phase 1 is an
                    intra-node all_to_all over `axis` that combines each
                    node's lanes onto the relay device whose in-node index
                    matches the owner's, phase 2 is ONE cross-node
                    all_to_all over `node_axis`.  Cross-node words drop from
                    n_shards*cap to n_nodes*node_capacity per device — the
                    cross-node combining win the executor overlaps rounds
                    behind (DESIGN.md §9).
    node_axis:      mesh axis of size n_nodes the cross-node hop runs over.
    node_capacity:  per-(relay, dst-node) slots in the phase-2 buffers
                    (default devs_per_node * cap, which can never overflow).
    """

    inner: Any                       # AtomicSpec | HashSpec
    axis: str = "shard"
    n_shards: int = 1
    p_local: int = 64
    route_capacity: int | None = None
    dedup_loads: bool = False
    interleave: bool = False
    n_nodes: int = 1
    node_axis: str = "node"
    node_capacity: int | None = None

    def __post_init__(self):
        if self.n_shards <= 0 or self.p_local <= 0:
            raise ValueError(f"mesh geometry must be positive: {self}")
        if self.n_nodes <= 0:
            raise ValueError("n_nodes must be positive")
        if self.n_nodes > 1:
            if isinstance(self.inner, HashSpec):
                raise ValueError("hierarchical routing applies to tables "
                                 "only (hash ops route flat)")
            if self.n_shards % self.n_nodes:
                raise ValueError(f"n_shards={self.n_shards} not divisible "
                                 f"by n_nodes={self.n_nodes}")
        if self.node_capacity is not None and self.node_capacity <= 0:
            raise ValueError("node_capacity must be positive")
        if isinstance(self.inner, HashSpec):
            if self.interleave:
                raise ValueError("interleave applies to tables only (hash "
                                 "buckets route by hash top bits)")
            if self.dedup_loads:
                raise ValueError("dedup_loads applies to tables only (hash "
                                 "FINDs are not dedup'd)")
            if self.inner.nb % self.n_shards:
                raise ValueError(f"nb={self.inner.nb} not divisible by "
                                 f"n_shards={self.n_shards}")
        elif isinstance(self.inner, AtomicSpec):
            if self.inner.n % self.n_shards:
                raise ValueError(f"n={self.inner.n} not divisible by "
                                 f"n_shards={self.n_shards}")
        else:
            raise TypeError(f"inner must be AtomicSpec or HashSpec: "
                            f"{type(self.inner)}")
        if self.route_capacity is not None and self.route_capacity <= 0:
            raise ValueError("route_capacity must be positive")

    # -- derived geometry ----------------------------------------------------

    @property
    def is_hash(self) -> bool:
        return isinstance(self.inner, HashSpec)

    @property
    def n_global(self) -> int:
        return self.inner.nb if self.is_hash else self.inner.n

    @property
    def n_local(self) -> int:
        return self.n_global // self.n_shards

    @property
    def p_global(self) -> int:
        return self.n_shards * self.p_local

    @property
    def cap(self) -> int:
        return self.route_capacity or self.p_local

    @property
    def devs_per_node(self) -> int:
        return self.n_shards // self.n_nodes

    @property
    def cap2(self) -> int:
        """Phase-2 per-(relay, dst-node) capacity (hierarchical only)."""
        return self.node_capacity or self.devs_per_node * self.cap

    def local_spec(self):
        """The per-shard spec the local engine runs (same strategy name, so
        the registry resolves the same `StrategyImpl` on every shard)."""
        if self.is_hash:
            return dataclasses.replace(self.inner, nb=self.n_local)
        return dataclasses.replace(self.inner, n=self.n_local)


class DistState(NamedTuple):
    """Pure pytree: the per-shard local states stacked on a leading
    [n_shards] axis (every leaf), sharded `P(axis)` over the mesh.  The
    local states are whatever the strategy's `init` builds (`TableState`)
    or `cachehash.init_hash` builds (`HashState`) — the distribution layer
    never looks inside them."""

    local: Any


def _unstack(state):
    """Inside shard_map: leading [1] shard axis -> the local pytree."""
    return jax.tree_util.tree_map(lambda x: x[0], state)


def _restack(state):
    return jax.tree_util.tree_map(lambda x: x[None], state)


def _mesh_shards(mesh: Mesh, axis: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape))[axis]


def _pspec(dspec: DistSpec) -> P:
    """Shard-axis partition spec: hierarchical specs split the stacked
    [n_shards] leading dim over (node_axis, axis) — shard o lives on mesh
    coordinate (o // devs_per_node, o % devs_per_node)."""
    if dspec.n_nodes > 1:
        return P((dspec.node_axis, dspec.axis))
    return P(dspec.axis)


def _check_mesh(mesh: Mesh, dspec: DistSpec) -> None:
    if dspec.n_nodes > 1:
        got = (_mesh_shards(mesh, dspec.node_axis),
               _mesh_shards(mesh, dspec.axis))
        want = (dspec.n_nodes, dspec.devs_per_node)
        if got != want:
            raise ValueError(f"mesh axes ({dspec.node_axis!r}, "
                             f"{dspec.axis!r}) have {got} devices, spec "
                             f"says {want}")
    elif _mesh_shards(mesh, dspec.axis) != dspec.n_shards:
        raise ValueError(f"mesh axis {dspec.axis!r} has "
                         f"{_mesh_shards(mesh, dspec.axis)} devices, spec "
                         f"says {dspec.n_shards}")


def init_dist(mesh: Mesh, dspec: DistSpec, initial: np.ndarray | None = None
              ) -> DistState:
    """Build the sharded initial state: one local state per shard, stacked
    and placed `P(axis)` on the mesh (`P((node_axis, axis))` when
    hierarchical).  `initial` (tables only) is the word[n, k] array of
    initial GLOBAL logical values."""
    s = dspec.n_shards
    _check_mesh(mesh, dspec)
    lsp = dspec.local_spec()
    if dspec.is_hash:
        if initial is not None:
            raise ValueError("hash tables initialize empty; insert instead")
        locals_ = [ch.init_hash(lsp) for _ in range(s)]
    else:
        if initial is None:
            shards = [None] * s
        else:
            initial = np.asarray(initial)
            if initial.shape != (dspec.n_global, lsp.k):
                raise ValueError(f"initial shape {initial.shape} != "
                                 f"({dspec.n_global}, {lsp.k})")
            shards = [initial[i::s] if dspec.interleave
                      else initial[i * dspec.n_local:(i + 1) * dspec.n_local]
                      for i in range(s)]
        locals_ = [engine.init(lsp, sh) for sh in shards]
    local = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *locals_)
    return DistState(jax.device_put(local,
                                    NamedSharding(mesh, _pspec(dspec))))


def init_dist_ctx(mesh: Mesh, dspec: DistSpec) -> engine.LinkCtx:
    """A fresh p_global-lane LinkCtx, sharded by source lane."""
    ctx = engine.init_ctx(dspec.p_global, dspec.inner.k)
    return jax.device_put(ctx, NamedSharding(mesh, _pspec(dspec)))


# ---------------------------------------------------------------------------
# The route -> apply -> return round (tables: full LOAD/STORE/CAS/LL/SC/
# VALIDATE schema with a routed per-owner LinkCtx).
# ---------------------------------------------------------------------------

def _owner_and_local(dspec: DistSpec, slot):
    """Owner shard + local cell index of each (table) global slot."""
    s = dspec.n_shards
    if dspec.interleave:
        return slot % s, slot // s
    return jnp.clip(slot // dspec.n_local, 0, s - 1), slot % dspec.n_local


def _dst_ranks(owner, cap: int, s: int, p: int):
    """Rank of each lane within its (src, dst) bucket + the fits mask."""
    order = jnp.argsort(owner, stable=True)
    inv = jnp.argsort(order, stable=True)
    s_owner = owner[order]
    idx = jnp.arange(p, dtype=jnp.int32)
    seg_start = jnp.concatenate([jnp.ones((1,), bool),
                                 s_owner[1:] != s_owner[:-1]])
    start = engine._segmented_scan_max(jnp.where(seg_start, idx, -1),
                                       seg_start)
    rank = (idx - start)[inv]
    fits = (rank < cap) & (owner < s)
    return rank, fits


def _packer(dst, size: int):
    """Masked scatter into flat [size] send buffers (`dst == size` drops)."""
    def pack(x, fill):
        buf = jnp.full((size,) + x.shape[1:], fill, x.dtype)
        return buf.at[dst].set(x, mode="drop")
    return pack


def _a2a(axis: str, s: int, cap: int):
    def go(x):
        return lax.all_to_all(x.reshape((s, cap) + x.shape[1:]), axis,
                              split_axis=0, concat_axis=0, tiled=False)
    return go


def _dedup(kind, slot, n: int, p: int):
    """Source-side load dedup: in each same-slot group whose active lanes
    are ALL loads, every load after the first becomes IDLE and inherits the
    first lane's routed answer.  Returns (kind', rep[p])."""
    lane = jnp.arange(p, dtype=jnp.int32)
    active = kind != engine.IDLE
    key = jnp.where(active, slot, n)              # idle lanes group apart
    d_order = jnp.argsort(key, stable=True)
    d_inv = jnp.argsort(d_order, stable=True)
    ds = key[d_order]
    dk = kind[d_order]
    d_start = jnp.concatenate([jnp.ones((1,), bool), ds[1:] != ds[:-1]])
    seg_end = jnp.concatenate([d_start[1:], jnp.ones((1,), bool)])
    start_idx = engine._segmented_scan_max(jnp.where(d_start, lane, -1),
                                           d_start)
    nonload = (dk != engine.LOAD) & (ds < n)
    # Suffix-any per segment, gathered at the segment START = full-segment
    # any (the suffix scan alone would miss non-loads BEFORE a lane).
    any_nonload = engine._seg_broadcast_any(nonload, seg_end)[start_idx]
    dup = (dk == engine.LOAD) & (ds < n) & ~any_nonload & ~d_start
    rep = jnp.where(dup, d_order[start_idx], d_order)[d_inv]
    return jnp.where(rep != lane, engine.IDLE, kind), rep


@functools.lru_cache(maxsize=256)
def _build_table_apply(mesh: Mesh, dspec: DistSpec):
    s, cap, axis = dspec.n_shards, dspec.cap, dspec.axis
    lsp: AtomicSpec = dspec.local_spec()
    p_local, k = dspec.p_local, lsp.k

    def local_fn(state, ctx, kind, slot, expected, desired):
        st = _unstack(state)
        impl = registry.get_strategy(lsp.strategy)
        lane = jnp.arange(p_local, dtype=jnp.int32)
        active0 = kind != engine.IDLE

        rep = lane
        if dspec.dedup_loads:
            kind, rep = _dedup(kind, slot, dspec.n_global, p_local)
        active = kind != engine.IDLE

        owner, lslot = _owner_and_local(dspec, slot)
        owner = jnp.where(active, owner, s)
        rank, fits = _dst_ranks(owner, cap, s, p_local)

        # -- route out: ops + the link info the owner needs to arbitrate ----
        link_ok = ctx.linked & (ctx.slot == slot)     # global-slot compare
        dst = jnp.where(fits, owner * cap + rank, s * cap)
        pack = _packer(dst, s * cap)
        snd_kind = pack(jnp.where(fits, kind, engine.IDLE), engine.IDLE)
        snd_slot = pack(lslot, 0)
        snd_exp = pack(expected, 0)
        snd_des = pack(desired, 0)
        snd_lver = pack(ctx.version, 0)
        snd_lok = pack(link_ok, False)
        go = _a2a(axis, s, cap)
        r_kind = go(snd_kind).reshape(s * cap)
        r_slot = go(snd_slot).reshape(s * cap)
        r_exp = go(snd_exp).reshape(s * cap, k)
        r_des = go(snd_des).reshape(s * cap, k)
        r_lver = go(snd_lver).reshape(s * cap)
        r_lok = go(snd_lok).reshape(s * cap)

        # -- apply: the v2 engine, strategy dispatched through the registry,
        #    against a routed per-owner LinkCtx ------------------------------
        octx = engine.LinkCtx(
            slot=jnp.where(r_lok, r_slot, -1), version=r_lver,
            value=jnp.zeros((s * cap, k), WORD_DTYPE), linked=r_lok)
        rops = engine.OpBatch(r_kind, r_slot, r_exp, r_des)
        new_data, new_ver, new_octx, res, stats = engine.linearize(
            impl.engine_view(st), st.version, octx, rops)
        st = impl.commit(st, new_data, new_ver, stats.n_updates, s * cap)

        # -- route back: values, success, and the LL-linked version ---------
        b_val = go(res.value).reshape(s, cap, k)
        b_suc = go(res.success).reshape(s, cap)
        b_ver = go(new_octx.version).reshape(s, cap)
        safe_owner = jnp.clip(owner, 0, s - 1)
        safe_pos = jnp.maximum(jnp.where(fits, rank, -1), 0)
        value = jnp.where(fits[:, None], b_val[safe_owner, safe_pos], 0)
        success = jnp.where(fits, b_suc[safe_owner, safe_pos], False)
        ret_ver = b_ver[safe_owner, safe_pos]
        value = value[rep]
        success = success[rep]
        overflow = active0 & ~fits[rep]

        # -- merge the routed answers into the persistent source ctx --------
        is_ll = fits & (kind == engine.LL)
        is_sc = fits & (kind == engine.SC)     # dropped SCs keep their link
        nctx = engine.LinkCtx(
            slot=jnp.where(is_ll, slot, ctx.slot),
            version=jnp.where(is_ll, ret_ver, ctx.version),
            value=jnp.where(is_ll[:, None], value, ctx.value),
            linked=jnp.where(is_ll, True,
                             jnp.where(is_sc, False, ctx.linked)))
        return _restack(st), nctx, value, success, overflow

    spec = P(axis)
    mapped = shard_map(local_fn, mesh=mesh, in_specs=(spec,) * 6,
                       out_specs=(spec,) * 5, check_rep=False)
    return jax.jit(mapped)


@functools.lru_cache(maxsize=256)
def _build_table_apply_2level(mesh: Mesh, dspec: DistSpec):
    """Hierarchical route -> apply -> return: intra-node combine onto the
    relay device whose in-node index matches the owner's, then ONE
    cross-node all_to_all (DESIGN.md §9).

    The owner device of shard o = o_node * d + o_dev sits at mesh
    coordinate (o_node, o_dev); phase 1 (over `axis`, within each node)
    moves every lane to the local device with index o_dev, phase 2 (over
    `node_axis`) moves it to the owner node — the in-node index is
    preserved across the node hop, so it lands exactly on the owner.
    Owner-side lane order is [src_node, phase-2 rank], and phase-2 ranks
    follow relay-lane order [src_dev, phase-1 rank]: the claimed total
    order is (owner, src node, src dev, lane) — `linearization_order`
    mirrors it host-side.  Capacity rejects at EITHER hop surface in the
    returned per-lane overflow mask; rejected lanes never reach a table.
    """
    axis, node_axis = dspec.axis, dspec.node_axis
    nn, d = dspec.n_nodes, dspec.devs_per_node
    cap1, cap2 = dspec.cap, dspec.cap2
    lsp: AtomicSpec = dspec.local_spec()
    p_local, k = dspec.p_local, lsp.k

    def local_fn(state, ctx, kind, slot, expected, desired):
        st = _unstack(state)
        impl = registry.get_strategy(lsp.strategy)
        active0 = kind != engine.IDLE

        rep = jnp.arange(p_local, dtype=jnp.int32)
        if dspec.dedup_loads:
            kind, rep = _dedup(kind, slot, dspec.n_global, p_local)
        active = kind != engine.IDLE

        owner, lslot = _owner_and_local(dspec, slot)
        o_node = jnp.where(active, owner // d, nn)
        o_dev = jnp.where(active, owner % d, d)

        # -- phase 1 out: intra-node combine onto the o_dev relay -----------
        link_ok = ctx.linked & (ctx.slot == slot)
        rank1, fits1 = _dst_ranks(o_dev, cap1, d, p_local)
        dst1 = jnp.where(fits1, o_dev * cap1 + rank1, d * cap1)
        pack1 = _packer(dst1, d * cap1)
        go1 = _a2a(axis, d, cap1)
        r1_kind = go1(pack1(jnp.where(fits1, kind, engine.IDLE),
                            engine.IDLE)).reshape(d * cap1)
        r1_slot = go1(pack1(lslot, 0)).reshape(d * cap1)
        r1_node = go1(pack1(o_node, nn)).reshape(d * cap1)
        r1_exp = go1(pack1(expected, 0)).reshape(d * cap1, k)
        r1_des = go1(pack1(desired, 0)).reshape(d * cap1, k)
        r1_lver = go1(pack1(ctx.version, 0)).reshape(d * cap1)
        r1_lok = go1(pack1(link_ok, False)).reshape(d * cap1)

        # -- phase 2 out: ONE cross-node hop to the owner node --------------
        key2 = jnp.where(r1_kind != engine.IDLE, r1_node, nn)
        rank2, fits2 = _dst_ranks(key2, cap2, nn, d * cap1)
        dst2 = jnp.where(fits2, key2 * cap2 + rank2, nn * cap2)
        pack2 = _packer(dst2, nn * cap2)
        go2 = _a2a(node_axis, nn, cap2)
        r2_kind = go2(pack2(jnp.where(fits2, r1_kind, engine.IDLE),
                            engine.IDLE)).reshape(nn * cap2)
        r2_slot = go2(pack2(r1_slot, 0)).reshape(nn * cap2)
        r2_exp = go2(pack2(r1_exp, 0)).reshape(nn * cap2, k)
        r2_des = go2(pack2(r1_des, 0)).reshape(nn * cap2, k)
        r2_lver = go2(pack2(r1_lver, 0)).reshape(nn * cap2)
        r2_lok = go2(pack2(r1_lok, False)).reshape(nn * cap2)

        # -- apply at the owner (same engine round as the flat path) --------
        octx = engine.LinkCtx(
            slot=jnp.where(r2_lok, r2_slot, -1), version=r2_lver,
            value=jnp.zeros((nn * cap2, k), WORD_DTYPE), linked=r2_lok)
        rops = engine.OpBatch(r2_kind, r2_slot, r2_exp, r2_des)
        new_data, new_ver, new_octx, res, stats = engine.linearize(
            impl.engine_view(st), st.version, octx, rops)
        st = impl.commit(st, new_data, new_ver, stats.n_updates, nn * cap2)

        # -- return hop 2: owner node -> relay ------------------------------
        b2_val = go2(res.value).reshape(nn, cap2, k)
        b2_suc = go2(res.success).reshape(nn, cap2)
        b2_ver = go2(new_octx.version).reshape(nn, cap2)
        safe_n = jnp.clip(key2, 0, nn - 1)
        safe_r2 = jnp.maximum(jnp.where(fits2, rank2, -1), 0)
        v1 = jnp.where(fits2[:, None], b2_val[safe_n, safe_r2], 0)
        s1 = jnp.where(fits2, b2_suc[safe_n, safe_r2], False)
        ver1 = b2_ver[safe_n, safe_r2]

        # -- return hop 1: relay -> source (the fits2 bit rides back so the
        #    source learns which lanes ACTUALLY executed) --------------------
        b1_val = go1(v1).reshape(d, cap1, k)
        b1_suc = go1(s1).reshape(d, cap1)
        b1_ver = go1(ver1).reshape(d, cap1)
        b1_exe = go1(fits2).reshape(d, cap1)
        safe_dev = jnp.clip(o_dev, 0, d - 1)
        safe_r1 = jnp.maximum(jnp.where(fits1, rank1, -1), 0)
        executed = fits1 & b1_exe[safe_dev, safe_r1]
        value = jnp.where(executed[:, None], b1_val[safe_dev, safe_r1], 0)
        success = jnp.where(executed, b1_suc[safe_dev, safe_r1], False)
        ret_ver = b1_ver[safe_dev, safe_r1]
        value = value[rep]
        success = success[rep]
        overflow = active0 & ~executed[rep]

        is_ll = executed & (kind == engine.LL)
        is_sc = executed & (kind == engine.SC)   # dropped SCs keep their link
        nctx = engine.LinkCtx(
            slot=jnp.where(is_ll, slot, ctx.slot),
            version=jnp.where(is_ll, ret_ver, ctx.version),
            value=jnp.where(is_ll[:, None], value, ctx.value),
            linked=jnp.where(is_ll, True,
                             jnp.where(is_sc, False, ctx.linked)))
        return _restack(st), nctx, value, success, overflow

    spec = P((node_axis, axis))
    mapped = shard_map(local_fn, mesh=mesh, in_specs=(spec,) * 6,
                       out_specs=(spec,) * 5, check_rep=False)
    return jax.jit(mapped)


def _pad_ops(ops: engine.OpBatch, p: int) -> engine.OpBatch:
    """IDLE-pad the lane axis up to p (callers may issue fewer lanes)."""
    q = ops.kind.shape[0]
    if q == p:
        return ops
    pad, k = p - q, ops.desired.shape[1]
    return engine.OpBatch(
        jnp.concatenate([jnp.asarray(ops.kind, jnp.int32),
                         jnp.full((pad,), engine.IDLE, jnp.int32)]),
        jnp.concatenate([jnp.asarray(ops.slot, jnp.int32),
                         jnp.zeros((pad,), jnp.int32)]),
        jnp.concatenate([jnp.asarray(ops.expected, WORD_DTYPE),
                         jnp.zeros((pad, k), WORD_DTYPE)]),
        jnp.concatenate([jnp.asarray(ops.desired, WORD_DTYPE),
                         jnp.zeros((pad, k), WORD_DTYPE)]))


def _pad_ctx(ctx: engine.LinkCtx, p: int, k: int) -> engine.LinkCtx:
    q = ctx.slot.shape[0]
    if q == p:
        return ctx
    blank = engine.init_ctx(p - q, k)
    return engine.LinkCtx(*[jnp.concatenate([a, b])
                            for a, b in zip(ctx, blank)])


def _check_width(q: int, dspec: DistSpec) -> None:
    if q > dspec.p_global:
        raise ValueError(f"batch width {q} > p_global {dspec.p_global}")


def apply(mesh: Mesh, dspec: DistSpec, dstate: DistState, ops: engine.OpBatch,
          ctx: engine.LinkCtx | None = None):
    """Linearize a mixed table batch across the mesh in ONE collective round.

    `ops` has up to p_global lanes laid out source-major (lane i issues from
    shard i // p_local; missing trailing lanes are IDLE-padded and their
    results trimmed away); `ctx` carries per-lane LL/SC links across
    batches.

    Returns (dstate', ctx', ApplyResult, overflow) where `overflow` is the
    per-lane bool mask of ops rejected by route capacity — reported, never
    silently dropped; rejected lanes have success=False and no table effect.
    """
    if dspec.is_hash:
        raise TypeError("hash DistSpec: use distributed.apply_hash")
    engine.check_kinds(ops.kind, engine.TABLE_KINDS, "table")
    q, k = ops.kind.shape[0], dspec.inner.k
    _check_width(q, dspec)
    ops = _pad_ops(ops, dspec.p_global)
    ctx = engine.init_ctx(dspec.p_global, k) if ctx is None \
        else _pad_ctx(ctx, dspec.p_global, k)
    fn = (_build_table_apply_2level(mesh, dspec) if dspec.n_nodes > 1
          else _build_table_apply(mesh, dspec))
    local, nctx, value, success, overflow = fn(
        dstate.local, ctx, ops.kind, ops.slot, ops.expected, ops.desired)
    if q != dspec.p_global:
        nctx = engine.LinkCtx(*[x[:q] for x in nctx])
        value, success, overflow = value[:q], success[:q], overflow[:q]
    if obs_telemetry.carry_in(dstate.local, ops.kind) is not None:
        # One tiny scalar-accumulate dispatch per collective round when
        # counters are on (threading the pytree through shard_map is not
        # worth the churn); zero work when off.  `collective_words(dspec)`
        # is static per dspec, so the jitted accumulator never retraces.
        obs_telemetry.record_dist(overflow, collective_words(dspec))
    return (DistState(local), nctx, engine.ApplyResult(value, success),
            overflow)


class DistRoundHandle:
    """An in-flight distributed round (the collective analog of
    `engine.RoundHandle`): `apply_round` returns immediately thanks to
    JAX async dispatch, so the executor routes/packs the NEXT stream's
    batch while this round's all_to_alls are still on the wire.  `order`
    (when requested) is the host-side claimed linearization — computed
    up front, so oracle replay never has to wait on the device."""

    __slots__ = ("state", "ctx", "result", "overflow", "order")

    def __init__(self, state, ctx, result, overflow, order=None):
        self.state = state
        self.ctx = ctx
        self.result = result
        self.overflow = overflow
        self.order = order

    def _leaves(self):
        return jax.tree_util.tree_leaves(
            (self.state, self.ctx, self.result, self.overflow))

    def ready(self) -> bool:
        return all(getattr(leaf, "is_ready", lambda: True)()
                   for leaf in self._leaves())

    def wait(self) -> "DistRoundHandle":
        jax.block_until_ready(self._leaves())
        return self


def apply_round(mesh: Mesh, dspec: DistSpec, dstate: DistState,
                ops: engine.OpBatch, ctx: engine.LinkCtx | None = None, *,
                with_order: bool = False) -> DistRoundHandle:
    """`apply` wrapped as an overlappable handle for the executor; with
    `with_order=True` the claimed linearization rides along for replay."""
    order = None
    if with_order:
        order, _ = linearization_order(dspec, ops)
    state, nctx, res, ovf = apply(mesh, dspec, dstate, ops, ctx)
    return DistRoundHandle(state, nctx, res, ovf, order)


# ---------------------------------------------------------------------------
# Cross-shard MCAS: the two-round prepare/commit collective (DESIGN.md §7).
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=256)
def _build_mcas_apply(mesh: Mesh, dspec: DistSpec, t_local: int, w: int):
    """One prepare/commit round pair for up to `t_local` transactions of
    width `w` per source device, as a single shard_mapped program:

      prepare — every active txn lane routes (cell, expected, desired,
                global txn id) to its owner shard; the owner LLs the cell
                through the local engine, checks expected, and VOTES: a
                lane's vote is yes iff it matched AND its txn id is the
                lowest matching id claiming that cell (the per-owner vote —
                arbitration needs no global view because ids are global).
                Match + vote + the witnessed value route back.
      decide  — the SOURCE holds all of its txn's lanes, so the commit
                mask is local: commit iff every lane matched and voted.
      commit  — the commit bit routes out over the SAME lane packing (so
                it lands on the owner's phase-A link ctx), the owner SCs
                every committing lane (one-round fast path: links predate
                the batch, voted lanes are cell-disjoint across txns), and
                SC success routes back.

    Nothing writes during prepare, so a transaction's reads — even spanning
    shards — form a consistent global snapshot; voted lanes are pairwise
    cell-disjoint, so commit is all-or-nothing by construction.
    """
    s, axis = dspec.n_shards, dspec.axis
    lsp: AtomicSpec = dspec.local_spec()
    k = lsp.k
    p_lane = t_local * w
    cap = p_lane                 # a source owns p_lane lanes: never overflows

    def local_fn(state, slot, expected, desired, active):
        st = _unstack(state)
        impl = registry.get_strategy(lsp.strategy)
        my = lax.axis_index(axis).astype(jnp.int32)
        gid_t = my * t_local + jnp.arange(t_local, dtype=jnp.int32)
        gid = jnp.repeat(gid_t, w)
        f_slot = slot.reshape(p_lane)
        f_exp = expected.reshape(p_lane, k)
        f_des = desired.reshape(p_lane, k)
        lane_used = (f_slot >= 0) & (f_slot < dspec.n_global)
        live = active.reshape(t_local)[jnp.arange(p_lane) // w] & lane_used

        owner, lslot = _owner_and_local(dspec, jnp.where(lane_used,
                                                         f_slot, 0))
        owner = jnp.where(live, owner, s)
        rank, fits = _dst_ranks(owner, cap, s, p_lane)

        # -- prepare: route (cell, expected, desired, gid) to the owner ----
        dst = jnp.where(fits, owner * cap + rank, s * cap)
        pack = _packer(dst, s * cap)
        go = _a2a(axis, s, cap)
        r_live = go(pack(fits, False)).reshape(s * cap)
        r_slot = go(pack(lslot, 0)).reshape(s * cap)
        r_exp = go(pack(f_exp, 0)).reshape(s * cap, k)
        r_des = go(pack(f_des, 0)).reshape(s * cap, k)
        r_gid = go(pack(gid, s * t_local)).reshape(s * cap)

        ops1 = engine.OpBatch(
            jnp.where(r_live, engine.LL, engine.IDLE), r_slot,
            jnp.zeros((s * cap, k), WORD_DTYPE),
            jnp.zeros((s * cap, k), WORD_DTYPE))
        d1, v1, octx, res1, st1 = engine.linearize(
            impl.engine_view(st), st.version,
            engine.init_ctx(s * cap, k), ops1)
        st = impl.commit(st, d1, v1, st1.n_updates, s * cap)
        vals = res1.value
        match = r_live & jnp.all(vals == r_exp, axis=1)
        # per-owner vote: lowest MATCHING txn id claiming each local cell
        n_loc = dspec.n_local
        claim = jnp.where(match, r_slot, n_loc)
        cgid = jnp.where(match, r_gid, s * t_local)
        cell_min = jnp.full((n_loc + 1,), s * t_local, jnp.int32)
        cell_min = cell_min.at[claim].min(cgid, mode="drop")
        vote = match & (cell_min[jnp.minimum(claim, n_loc)] == r_gid)

        # -- route match/vote/witness back to the source -------------------
        b_match = go(match).reshape(s, cap)
        b_vote = go(vote).reshape(s, cap)
        b_val = go(vals).reshape(s, cap, k)
        safe_owner = jnp.clip(owner, 0, s - 1)
        safe_pos = jnp.maximum(jnp.where(fits, rank, -1), 0)
        l_match = jnp.where(fits, b_match[safe_owner, safe_pos], False)
        l_vote = jnp.where(fits, b_vote[safe_owner, safe_pos], False)
        l_wit = jnp.where(fits[:, None], b_val[safe_owner, safe_pos], 0)

        def per_txn_all(flag):
            return jnp.all((flag | ~lane_used).reshape(t_local, w), axis=1)

        act_t = active.reshape(t_local)
        match_t = act_t & per_txn_all(l_match)
        commit_t = match_t & per_txn_all(l_vote)

        # -- commit: the commit bit rides the SAME packing onto the same
        #    owner lanes (phase-A links), then SC success rides back -------
        commit_lane = commit_t[jnp.arange(p_lane) // w] & lane_used
        r_commit = go(pack(commit_lane & fits, False)).reshape(s * cap)
        ops2 = engine.OpBatch(
            jnp.where(r_commit, engine.SC, engine.IDLE), r_slot,
            jnp.zeros((s * cap, k), WORD_DTYPE), r_des)
        d2, v2, _octx2, res2, st2 = engine.linearize(
            impl.engine_view(st), st.version, octx, ops2)
        st = impl.commit(st, d2, v2, st2.n_updates, s * cap)
        b_sc = go(res2.success).reshape(s, cap)
        l_sc = jnp.where(fits, b_sc[safe_owner, safe_pos], False)
        success_t = commit_t & per_txn_all(l_sc)
        return (_restack(st), match_t, success_t,
                l_wit.reshape(t_local, w, k))

    spec = P(axis)
    mapped = shard_map(local_fn, mesh=mesh, in_specs=(spec,) * 5,
                       out_specs=(spec,) * 4, check_rep=False)
    return jax.jit(mapped)


def mcas(mesh: Mesh, dspec: DistSpec, dstate: DistState, txns, *,
         policy=None, max_rounds: int | None = None):
    """Cross-shard k-word MCAS: transactions whose lanes span shards commit
    all-or-nothing through the two-round prepare/commit collective.

    `txns` is a `repro.txn.mcas.TxnBatch` of T transactions issued
    source-major (txn i from shard i // ceil(T / n_shards); T is IDLE-padded
    to a shard multiple).  Retries of arbitration losers run host-side under
    the queue's Dice-style `BackoffPolicy` (default none).  Each round moves
    `n_shards * t_local * w * (3k + 7)` words per device through four
    all_to_alls (`mcas_collective_words` is the exact model).

    Returns (dstate', McasResult) — same result contract, claimed
    linearization and `TxnOracle` compatibility as the single-device
    `repro.txn.mcas.mcas` (`txn.mcas.linearization_order(result)`).
    """
    from repro.sync.queue import BackoffPolicy
    from repro.txn import mcas as txn_mcas
    if dspec.is_hash:
        raise TypeError("hash DistSpec: MCAS runs on tables")
    if dspec.n_nodes > 1:
        raise NotImplementedError("cross-shard MCAS routes flat; build its "
                                  "DistSpec with n_nodes=1")
    policy = policy or BackoffPolicy("none")
    t, w, k = txns.t, txns.w, dspec.inner.k
    if txns.expected.shape[2] != k:
        raise ValueError(f"txn word width {txns.expected.shape[2]} != "
                         f"spec.k {k}")
    if max_rounds is None:
        max_rounds = txn_mcas.max_rounds_bound(t, policy)
    s = dspec.n_shards
    t_local = -(-t // s)
    t_pad = t_local * s
    pad = t_pad - t
    slot = jnp.concatenate(
        [jnp.asarray(txns.slot, jnp.int32),
         jnp.full((pad, w), -1, jnp.int32)]) if pad else \
        jnp.asarray(txns.slot, jnp.int32)
    expected = jnp.concatenate(
        [jnp.asarray(txns.expected, WORD_DTYPE),
         jnp.zeros((pad, w, k), WORD_DTYPE)]) if pad else \
        jnp.asarray(txns.expected, WORD_DTYPE)
    desired = jnp.concatenate(
        [jnp.asarray(txns.desired, WORD_DTYPE),
         jnp.zeros((pad, w, k), WORD_DTYPE)]) if pad else \
        jnp.asarray(txns.desired, WORD_DTYPE)
    fn = _build_mcas_apply(mesh, dspec, t_local, w)

    pending = np.concatenate([np.ones(t, bool), np.zeros(pad, bool)])
    success = np.zeros(t_pad, bool)
    witness = np.zeros((t_pad, w, k), np.uint32)
    round_res = np.zeros(t_pad, np.int32)
    attempts = np.zeros(t_pad, np.int32)
    delay = np.zeros(t_pad, np.int32)
    rnd = 0
    while pending.any():
        rnd += 1
        if rnd > max_rounds:
            raise RuntimeError(f"mcas round bound exceeded ({max_rounds}); "
                               f"pending={np.nonzero(pending)[0].tolist()}")
        active = pending & (delay <= 0)
        if not active.any():
            delay = np.maximum(delay - 1, 0)
            continue
        local, match_t, success_t, wit = fn(
            dstate.local, slot, expected, desired, jnp.asarray(active))
        dstate = DistState(local)
        match_t = np.asarray(match_t)
        success_t = np.asarray(success_t)
        failed = active & ~match_t
        committed = active & success_t
        resolved = failed | committed
        witness = np.where(resolved[:, None, None], np.asarray(wit), witness)
        success |= committed
        round_res = np.where(resolved, rnd, round_res)
        pending &= ~resolved
        lost = active & ~resolved
        attempts += lost.astype(np.int32)
        for i in np.nonzero(lost)[0]:
            delay[i] = policy.delay(int(attempts[i]))
        delay[~lost] = np.maximum(delay[~lost] - 1, 0)
    result = txn_mcas.McasResult(
        success[:t], jnp.asarray(witness[:t]), round_res[:t], attempts[:t],
        np.int32(rnd))
    return dstate, result


def mcas_collective_words(dspec: DistSpec, t_local: int, w: int) -> int:
    """Words per device per prepare/commit round pair (4 all_to_alls):
    out (slot, expected[k], desired[k], gid, live) + back (match, vote,
    witness[k]) + commit out/back (2)."""
    return dspec.n_shards * t_local * w * (3 * dspec.inner.k + 7)


# ---------------------------------------------------------------------------
# Sharded CacheHash: FIND/INSERT/DELETE route by key owner.
# ---------------------------------------------------------------------------

def _hash_owner(dspec: DistSpec, key_bits):
    """Owner shard of each key: top bits of the bucket hash (the local
    apply re-derives the local bucket from the SAME hash's low bits)."""
    hs: HashSpec = dspec.inner
    gb = (ch.hash_u32(key_bits.astype(jnp.uint32))
          & jnp.uint32(hs.nb - 1)).astype(jnp.int32)
    return gb // dspec.n_local


@functools.lru_cache(maxsize=256)
def _build_hash_apply(mesh: Mesh, dspec: DistSpec):
    s, cap, axis = dspec.n_shards, dspec.cap, dspec.axis
    lsp: HashSpec = dspec.local_spec()
    p_local, vw = dspec.p_local, lsp.vw

    def local_fn(state, kind, key, value):
        st = _unstack(state)
        active = kind != engine.IDLE
        owner = jnp.where(active, _hash_owner(dspec, key), s)
        rank, fits = _dst_ranks(owner, cap, s, p_local)

        dst = jnp.where(fits, owner * cap + rank, s * cap)
        pack = _packer(dst, s * cap)
        snd_kind = pack(jnp.where(fits, kind, engine.IDLE), engine.IDLE)
        snd_key = pack(key, 0)
        snd_val = pack(value, 0)
        go = _a2a(axis, s, cap)
        r_kind = go(snd_kind).reshape(s * cap)
        r_key = go(snd_key).reshape(s * cap)
        r_val = go(snd_val).reshape(s * cap, vw)

        rops = ch.make_hash_ops(r_kind, r_key.astype(jnp.uint32), r_val,
                                vw=vw)
        st, res, _stats = ch.apply_hash(lsp, st, rops)

        b_found = go(res.found).reshape(s, cap)
        b_val = go(res.value).reshape(s, cap, vw)
        b_over = go(res.overflow).reshape(s, cap)
        safe_owner = jnp.clip(owner, 0, s - 1)
        safe_pos = jnp.maximum(jnp.where(fits, rank, -1), 0)
        found = jnp.where(fits, b_found[safe_owner, safe_pos], False)
        val = jnp.where(fits[:, None], b_val[safe_owner, safe_pos], 0)
        walk_over = jnp.where(fits, b_over[safe_owner, safe_pos], False)
        overflow = active & ~fits
        return _restack(st), found, val, walk_over, overflow

    spec = P(axis)
    mapped = shard_map(local_fn, mesh=mesh, in_specs=(spec,) * 4,
                       out_specs=(spec,) * 5, check_rep=False)
    return jax.jit(mapped)


def apply_hash(mesh: Mesh, dspec: DistSpec, dstate: DistState,
               ops: engine.OpBatch):
    """Key-owner-routed sharded CacheHash batch (unified hash schema).

    Returns (dstate', HashResult, overflow) — same overflow contract as
    `apply`: capacity-rejected lanes are reported with found=False, never
    silently dropped, and never touch any shard's table.
    """
    if not dspec.is_hash:
        raise TypeError("table DistSpec: use distributed.apply")
    engine.check_kinds(ops.kind, engine.HASH_KINDS, "hash")
    q = ops.kind.shape[0]
    _check_width(q, dspec)
    ops = _pad_ops(ops, dspec.p_global)
    fn = _build_hash_apply(mesh, dspec)
    local, found, value, walk_over, overflow = fn(
        dstate.local, ops.kind, ops.slot, ops.desired)
    if q != dspec.p_global:
        found, value = found[:q], value[:q]
        walk_over, overflow = walk_over[:q], overflow[:q]
    return DistState(local), ch.HashResult(found, value, walk_over), overflow


# ---------------------------------------------------------------------------
# Host-side inspection (tests / debugging).
# ---------------------------------------------------------------------------

def logical(dspec: DistSpec, dstate: DistState) -> jax.Array:
    """Global logical values [n, k], de-sharded (tables only)."""
    impl = registry.get_strategy(dspec.inner.strategy)
    vals = jax.vmap(impl.logical)(dstate.local)      # [s, n_local, k]
    if dspec.interleave:
        return jnp.swapaxes(vals, 0, 1).reshape(dspec.n_global, -1)
    return vals.reshape(dspec.n_global, -1)


def versions(dspec: DistSpec, dstate: DistState) -> jax.Array:
    """Global cell versions [n] (tables only)."""
    ver = dstate.local.version                       # [s, n_local]
    if dspec.interleave:
        return jnp.swapaxes(ver, 0, 1).reshape(-1)
    return ver.reshape(-1)


def hash_items(dspec: DistSpec, dstate: DistState) -> dict:
    """All (key, value) pairs across every shard's CacheHash."""
    hs: HashSpec = dspec.inner
    out: dict = {}
    for i in range(dspec.n_shards):
        shard = jax.tree_util.tree_map(lambda x: np.asarray(x)[i],
                                       dstate.local)
        out.update(ch.items(shard, inline=hs.inline, vw=hs.vw))
    return out


def collective_words(dspec: DistSpec) -> int:
    """Exact words each device moves through the all_to_alls per batch
    (the roofline term the §Perf hillclimb drives down).  Hierarchical
    specs split into an intra-node term (phase 1 also carries the owner
    node id) and a cross-node term (phase 2 also rides the executed bit
    back) — the CROSS-NODE words drop from n_shards*cap to
    n_nodes*cap2 per device, which is the whole point."""
    if not dspec.is_hash and dspec.n_nodes > 1:
        k = dspec.inner.k
        return (dspec.devs_per_node * dspec.cap * (3 * k + 8)
                + dspec.n_nodes * dspec.cap2 * (3 * k + 7))
    per_lane = (2 * dspec.inner.vw + 4) if dspec.is_hash \
        else (3 * dspec.inner.k + 6)
    return dspec.n_shards * dspec.cap * per_lane


# ---------------------------------------------------------------------------
# The claimed linearization (host-side, for the oracle harness).
# ---------------------------------------------------------------------------

def _hash_u32_np(key):
    """Host-side bucket hash: evaluate THE jax implementation so device
    routing and the claimed order can never diverge."""
    return np.asarray(ch.hash_u32(jnp.asarray(key, jnp.uint32)))


def linearization_order(dspec: DistSpec, ops: engine.OpBatch):
    """The total order `apply`/`apply_hash` claims for a batch.

    Returns (order, overflow): `order` lists the executed lane ids in the
    claimed global sequence (owner-major, then source device, then in-bucket
    rank = lane order; dedup'd loads ride directly after their
    representative), `overflow` is the bool[p_global] mask of
    capacity-rejected lanes.  Feed both to `tests/oracle.py`.

    Hierarchical specs (n_nodes > 1) claim (owner, src node, src device,
    lane) with capacity charged at BOTH hops: cap per (src device, in-node
    owner index) — lanes bound for different nodes share a relay budget —
    then cap2 per (relay, owner node) in relay-lane arrival order.
    """
    kind = np.asarray(ops.kind)
    slot = np.asarray(ops.slot)
    p, s, pl, cap = dspec.p_global, dspec.n_shards, dspec.p_local, dspec.cap
    q = kind.shape[0]
    _check_width(q, dspec)
    if q < p:                                  # mirror apply's IDLE padding
        kind = np.concatenate([kind, np.full(p - q, engine.IDLE, np.int32)])
        slot = np.concatenate([slot, np.zeros(p - q, np.int32)])
    if dspec.is_hash:
        gb = (_hash_u32_np(slot) & np.uint32(dspec.inner.nb - 1)) \
            .astype(np.int64)
        owner_of = gb // dspec.n_local
    elif dspec.interleave:
        owner_of = slot % s
    else:
        owner_of = np.clip(slot // dspec.n_local, 0, s - 1)

    active = kind != engine.IDLE
    rep = np.arange(p)
    dups: dict[int, list[int]] = {}
    if dspec.dedup_loads and not dspec.is_hash:
        for src in range(s):
            groups: dict[int, list[int]] = {}
            for i in range(src * pl, (src + 1) * pl):
                if active[i]:
                    groups.setdefault(int(slot[i]), []).append(i)
            for lanes in groups.values():
                if all(kind[i] == engine.LOAD for i in lanes) \
                        and len(lanes) > 1:
                    first = lanes[0]
                    dups[first] = lanes[1:]
                    for i in lanes[1:]:
                        rep[i] = first

    overflow = np.zeros(p, bool)
    order: list[int] = []
    if not dspec.is_hash and dspec.n_nodes > 1:
        nn, d, cap2 = dspec.n_nodes, dspec.devs_per_node, dspec.cap2
        # phase 1: per source device, cap lanes per in-node owner index
        # (relay) — relay buffers fill src-device-major, lane order.
        relay: dict[tuple[int, int], list[int]] = {
            (m, j): [] for m in range(nn) for j in range(d)}
        for g in range(s):
            m = g // d
            cnt1: dict[int, int] = {}
            for i in range(g * pl, (g + 1) * pl):
                if not active[i] or rep[i] != i:
                    continue
                j = int(owner_of[i]) % d
                c = cnt1.get(j, 0)
                if c < cap:
                    relay[(m, j)].append(i)
                    cnt1[j] = c + 1
                else:
                    overflow[i] = True
                    for x in dups.get(i, []):
                        overflow[x] = True
        # phase 2: per relay, cap2 lanes per owner node, arrival order.
        accepted: dict[tuple[int, int], list[int]] = {}
        for (m, j), lanes in relay.items():
            cnt2: dict[int, int] = {}
            for i in lanes:
                onode = int(owner_of[i]) // d
                c = cnt2.get(onode, 0)
                if c < cap2:
                    accepted.setdefault((int(owner_of[i]), m), []).append(i)
                    cnt2[onode] = c + 1
                else:
                    overflow[i] = True
                    for x in dups.get(i, []):
                        overflow[x] = True
        for o in range(s):
            for m in range(nn):
                for i in accepted.get((o, m), []):
                    order.append(i)
                    order.extend(dups.get(i, []))
        return np.asarray(order, np.int64), overflow[:q]
    for o in range(s):
        for src in range(s):
            cnt = 0
            for i in range(src * pl, (src + 1) * pl):
                if not active[i] or rep[i] != i or owner_of[i] != o:
                    continue
                if cnt < cap:
                    order.append(i)
                    order.extend(dups.get(i, []))
                    cnt += 1
                else:
                    overflow[i] = True
                    for j in dups.get(i, []):
                        overflow[j] = True
    return np.asarray(order, np.int64), overflow[:q]


# ---------------------------------------------------------------------------
# DEPRECATED v1 surface: raw (data, version) PLAIN table, load/store/CAS.
# ---------------------------------------------------------------------------

class ShardedTable(NamedTuple):
    """DEPRECATED raw sharded table; new code holds a `DistSpec`+`DistState`."""

    data: jax.Array        # word[n, k], sharded over axis 0
    version: jax.Array     # uint32[n], sharded over axis 0


def init_sharded(mesh: Mesh, axis: str, n: int, k: int,
                 initial: np.ndarray | None = None) -> ShardedTable:
    """DEPRECATED shim: use `init_dist(mesh, DistSpec(AtomicSpec(...)))`."""
    warn_once("core.distributed.init_sharded",
              "distributed.init_dist(mesh, DistSpec(...))")
    n_shards = _mesh_shards(mesh, axis)
    assert n % n_shards == 0, (n, n_shards)
    data = jnp.zeros((n, k), WORD_DTYPE) if initial is None \
        else jnp.asarray(initial, WORD_DTYPE)
    ver = jnp.zeros((n,), jnp.uint32)
    return ShardedTable(
        jax.device_put(data, NamedSharding(mesh, P(axis, None))),
        jax.device_put(ver, NamedSharding(mesh, P(axis))))


def _plain_local(table: ShardedTable, s: int, n_local: int, k: int
                 ) -> TableState:
    """Stacked PLAIN-layout local states viewing a raw ShardedTable."""
    z = lambda dt, shape: jnp.zeros(shape, dt)
    return TableState(
        data=table.data.reshape(s, n_local, k),
        version=table.version.reshape(s, n_local),
        bptr=z(jnp.int32, (s, 0)), mark=z(bool, (s, 0)),
        lock=z(jnp.uint32, (s, 0)), pool=z(WORD_DTYPE, (s, 0, k)),
        free_ring=z(jnp.int32, (s, 0)),
        ring_head=z(jnp.uint32, (s,)), alloc_gen=z(jnp.uint32, (s,)))


def make_apply(mesh: Mesh, axis: str, n: int, k: int, p_local: int,
               *, route_capacity: int | None = None,
               dedup_loads: bool = False, interleave: bool = False):
    """DEPRECATED shim: use `distributed.apply(mesh, DistSpec(...), ...)`.

    Returned fn keeps the v1 contract: (table, ops) ->
    (table', result, overflow_count)."""
    warn_once("core.distributed.make_apply",
              "distributed.apply(mesh, DistSpec(...), state, ops)")
    s = _mesh_shards(mesh, axis)
    dspec = DistSpec(AtomicSpec(n, k, "plain"), axis, s, p_local,
                     route_capacity=route_capacity, dedup_loads=dedup_loads,
                     interleave=interleave)
    fn = _build_table_apply(mesh, dspec)

    @jax.jit
    def apply_ops(table: ShardedTable, ops: engine.OpBatch):
        local = _plain_local(table, s, n // s, k)
        ctx = engine.init_ctx(dspec.p_global, k)
        local, _, value, success, overflow = fn(
            local, ctx, ops.kind, ops.slot, ops.expected, ops.desired)
        return (ShardedTable(local.data.reshape(n, k),
                             local.version.reshape(n)),
                engine.ApplyResult(value, success),
                jnp.sum(overflow.astype(jnp.int32)))

    return apply_ops


def reference_apply(data, version, ops: engine.OpBatch, *, n_shards: int,
                    p_local: int, interleave: bool = False):
    """DEPRECATED sequential oracle (v1 signature); new tests use
    `tests/oracle.py` + `linearization_order`."""
    from repro.core import semantics as sem
    dspec = DistSpec(AtomicSpec(data.shape[0], data.shape[1], "plain"),
                     "shard", n_shards, p_local, interleave=interleave)
    seq, overflow = linearization_order(dspec, ops)
    kind = np.asarray(ops.kind)
    reordered = engine.OpBatch(
        jnp.asarray(kind[seq]), jnp.asarray(np.asarray(ops.slot)[seq]),
        jnp.asarray(np.asarray(ops.expected)[seq]),
        jnp.asarray(np.asarray(ops.desired)[seq]))
    d2, v2, res = sem.apply_batch_reference(data, version, reordered)
    p = kind.shape[0]
    k = data.shape[1]
    value = np.zeros((p, k), data.dtype)
    success = np.zeros((p,), bool)
    value[seq] = np.asarray(res.value)
    success[seq] = np.asarray(res.success)
    return d2, v2, engine.ApplyResult(value, success), \
        np.nonzero(overflow)[0].tolist()
