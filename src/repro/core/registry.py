"""Strategy registry: big-atomic memory layouts plug into the core engine.

The paper's observation is that *one* abstraction — a k-word linearizable
register — underlies tuples, version lists and hash tables; a memory layout
only decides how that register is stored and read.  `StrategyImpl` is that
boundary: the unified engine (`repro.core.engine`) linearizes a batch of ops
against logical values, then hands layout maintenance to the registered
implementation.  New layouts (e.g. contention-managed variants per Dice,
Hendler & Mirsky, arXiv:1305.5800) register themselves here and are
immediately usable from every entry point — tables, CacheHash, LL/SC,
queues, paged KV — without touching core:

    from repro import atomics

    class MyLayout(atomics.StrategyImpl):
        name = "my_layout"
        ...

    atomics.register_strategy(MyLayout())
    table = atomics.init(atomics.AtomicSpec(n, k, "my_layout", p_max))

The base class implements the PLAIN protocol (raw data + version, no reader
protection), so a minimal subclass only sets `name`; richer layouts override
the hooks they need.  All hooks are traced under `jax.jit` (except `init`,
`begin_update` and `memory_bytes`, which run at setup / test time).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.layout import (TableState, Traffic, WORD_BYTES, WORD_DTYPE,
                               _empty)


class StrategyImpl:
    """Protocol for a big-atomic memory layout (defaults = PLAIN).

    name:           registry key; `AtomicSpec.strategy` strings resolve here.
    lock_free:      readers always make progress from any observed state.
    blocks_readers: the honest read protocol can return ok=False (retry).
    """

    name: str | None = None
    lock_free: bool = False
    blocks_readers: bool = False

    # -- setup ---------------------------------------------------------------

    def init(self, n: int, k: int, p_max: int, data) -> TableState:
        """Build the initial layout for a table of n cells x k words; `data`
        is the word[n, k] array of initial logical values."""
        return TableState(data, jnp.zeros((n,), jnp.uint32),
                          _empty(jnp.int32), _empty(bool), _empty(jnp.uint32),
                          _empty(WORD_DTYPE, (0, k)), _empty(jnp.int32),
                          jnp.uint32(0), jnp.uint32(0))

    # -- engine hooks (traced) -----------------------------------------------

    def logical(self, state: TableState):
        """The current logical value of every cell, derived from the layout."""
        return state.data

    def engine_view(self, state: TableState):
        """The word[n, k] array the unified engine linearizes against.

        Defaults to `logical(state)`, which is always correct.  A layout
        whose `commit` maintains `state.data` as an exact shadow of the
        logical values may override this to return `state.data` directly and
        skip a derivation gather (see `strategies.Indirect`)."""
        return self.logical(state)

    def commit(self, state: TableState, new_data, new_version, n_updates,
               p: int) -> TableState:
        """Reconcile the layout after the logical values have advanced.

        `new_data`/`new_version` are the post-batch logical values and
        versions; `n_updates` the number of update writes performed (node
        pool accounting); `p` the batch width (static allocation bound)."""
        return state._replace(data=new_data, version=new_version)

    def read(self, state: TableState, slots):
        """Honest reader protocol: values + ok mask from layout fields only.

        ok=False means the reader is *blocked* (torn state / lock held) and
        must retry — see `bigatomic.read_protocol` for the full contract."""
        return state.data[slots], jnp.ones((slots.shape[0],), bool)

    def check_invariants(self, spec, state: TableState) -> dict:
        """Structural invariants of the layout at a QUIESCENT point (no
        batch in flight) — the redundancy `repro.guard.scrub` checks.

        Returns ``{invariant_name: bool[n] violation mask}`` (True =
        violated).  Called under `jax.jit`; every mask must be a traced
        bool[n].  The base PLAIN layout stores no redundancy, so nothing
        is checkable and the dict is empty; richer layouts report the
        paper's at-rest invariants (even seqlock versions, indirect
        pointer/shadow agreement, cached tag consistency — see
        `core.strategies` and DESIGN.md §11)."""
        return {}

    def lower_round(self, spec, *, mode: str, interpret: bool):
        """Hand the engine a fused execution round for this layout, or None.

        Called at trace time by `engine.round_for` with the resolved
        engine-kernel mode ('pallas' or 'xla'; 'off' never reaches here) and
        whether Pallas kernels must run interpreted (non-TPU backends).  A
        layout returns a callable with the exact `engine.linearize`
        signature — typically `repro.kernels.engine_round.make_round(spec.n,
        spec.k, mode=mode, interpret=interpret)` — or None to keep the
        pure-XLA `linearize` path (the default: plug-in strategies get the
        reference engine until they opt in; see DESIGN.md §8)."""
        return None

    def traffic(self, stats, k: int, p: int) -> Traffic:
        """Analytic HBM bytes + dependency depth per batch (roofline)."""
        w = WORD_BYTES
        cell = k * w
        loads = stats.n_loads
        upd = stats.n_updates
        return Traffic(
            jnp.asarray(loads * cell + upd * cell, jnp.float32),
            jnp.asarray(upd * cell, jnp.float32),
            jnp.asarray(1, jnp.int32), jnp.asarray(0, jnp.int32))

    # -- simulation / accounting (host-side) ---------------------------------

    def begin_update(self, state: TableState, slot: int, new_value,
                     torn_words: int) -> TableState:
        """Freeze a writer at its most vulnerable point (torn-state test)."""
        half = state.data[slot].at[:torn_words].set(new_value[:torn_words])
        return state._replace(data=state.data.at[slot].set(half))

    def memory_bytes(self, n: int, k: int, p: int) -> int:
        """Exact bytes of the layout (paper Table 1 / §5.5 forms)."""
        return n * k * WORD_BYTES


_REGISTRY: dict[str, StrategyImpl] = {}


def register_strategy(impl: StrategyImpl | type, *,
                      overwrite: bool = False) -> StrategyImpl:
    """Add a layout to the dispatch table (usable as a class decorator).

    Raises on duplicate names unless `overwrite=True` — tests override
    built-ins deliberately; production code never should."""
    if isinstance(impl, type):
        impl = impl()
    if not impl.name:
        raise ValueError("StrategyImpl.name must be a non-empty string")
    if impl.name in _REGISTRY and not overwrite:
        raise ValueError(f"strategy {impl.name!r} already registered "
                         "(pass overwrite=True to replace)")
    _REGISTRY[impl.name] = impl
    return impl


def unregister_strategy(name: str) -> None:
    """Remove a registered layout (test hygiene)."""
    _REGISTRY.pop(name, None)


def get_strategy(name: str) -> StrategyImpl:
    """Resolve a strategy name to its implementation."""
    if name not in _REGISTRY:
        # Built-ins self-register on first use; lazy import avoids a cycle.
        from repro.core import strategies  # noqa: F401
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown big-atomic strategy {name!r}; registered: "
                       f"{sorted(_REGISTRY)}") from None


def registered_strategies() -> tuple[str, ...]:
    get_strategy("plain")  # force built-in registration
    return tuple(sorted(_REGISTRY))
