"""Warn-once `DeprecationWarning` helper for the v1 shim surface.

Every deprecated entry point calls `warn_once(<its name>, <replacement>)`:
the first call per process emits a single `DeprecationWarning` (so tier-1
output stays readable), later calls are silent.  Tests that assert the
exactly-once contract use `reset()` to rearm a name.
"""

from __future__ import annotations

import warnings

_WARNED: set[str] = set()


def warn_once(name: str, alternative: str) -> None:
    """Emit `DeprecationWarning` for `name` once per process."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(f"{name} is deprecated; use {alternative} instead",
                  DeprecationWarning, stacklevel=3)


def reset(name: str | None = None) -> None:
    """Rearm one deprecated name (or all of them) — test hygiene only."""
    if name is None:
        _WARNED.clear()
    else:
        _WARNED.discard(name)
