"""The paper's big-atomic memory layouts as registered `StrategyImpl`s.

Every strategy provides the *same* linearizable batch semantics (the unified
engine in `repro.core.engine`, property-tested against sequential oracles)
but a *different* memory layout, reader protocol, and traffic profile:

  SEQLOCK    data[n,k] + ver[n].            1 gather/load; blocking on torn state.
  INDIRECT   ptr[n] -> pool[n+2p, k].       2 *dependent* gathers per load; never blocks.
  CACHED_WF  cache[n,k] + ver[n] + bptr[n] -> pool[n+2p,k].  1 gather fast path,
             backup fallback on race; never blocks.  Space 2nk + O(pk).
  CACHED_ME  cache[n,k] + ver[n] + bptr[n](tagged null) -> pool[3p,k].  1 gather
             fast path; backup only *during* a race; space nk + O(pk).
  SIMPLOCK   data[n,k] + lock[n].           lock RMW on every op; blocks readers.
  PLAIN      data[n,k], no protocol.        negative control: returns torn data.

Node reclamation uses a FIFO ring of free slots — the deterministic analogue
of the paper's hazard-pointer/private-slab schemes (DESIGN.md §2).  Further
layouts plug in from anywhere via `registry.register_strategy` without
touching this file or the engine (DESIGN.md §5).
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.layout import (NULL, TableState, Traffic, WORD_BYTES,
                               WORD_DTYPE, _empty, ring_alloc, ring_free,
                               sim_alloc)
from repro.core.registry import StrategyImpl, register_strategy


class _KernelLowering:
    """Mixin: lower the engine round to the fused fast/slow kernels
    (DESIGN.md §8).  The four paper layouts share the kernel — they all
    linearize against the same (engine_view, version) pair — but each owns
    its lane-tile width so a layout with wider cells can trade grid steps
    for VMEM (the (8, 128) register tile is the default).  PLAIN/SIMPLOCK
    and external plug-ins inherit the base `lower_round` (None) and stay on
    the pure-XLA reference path."""

    kernel_block: int = 8

    def lower_round(self, spec, *, mode: str, interpret: bool):
        from repro.kernels import engine_round
        return engine_round.make_round(spec.n, spec.k, mode=mode,
                                       interpret=interpret,
                                       block=self.kernel_block)


@register_strategy
class Plain(StrategyImpl):
    """Negative control: no protocol, readers may observe torn cells."""

    name = "plain"
    lock_free = False


class _Versioned(StrategyImpl):
    """Shared base for layouts that keep data[n,k] + an even/odd version."""

    def memory_bytes(self, n, k, p):
        return n * (k + 1) * WORD_BYTES

    def check_invariants(self, spec, state):
        # At a quiescent point every writer has unlocked: versions even.
        return {"version_parity": state.version % 2 != 0}


@register_strategy
class Seqlock(_KernelLowering, _Versioned):
    name = "seqlock"
    blocks_readers = True

    def read(self, state, slots):
        v1 = state.version[slots]
        val = state.data[slots]
        v2 = state.version[slots]
        ok = (v1 == v2) & (v1 % 2 == 0)
        return val, ok

    def traffic(self, stats, k, p):
        w = WORD_BYTES
        cell = k * w
        loads, raced, upd = stats.n_loads, stats.n_raced_loads, stats.n_updates
        br = loads * (cell + 2 * w) + raced * (cell + 2 * w) + upd * (cell + 2 * w)
        bw = upd * (cell + 2 * w)
        chains = jnp.where(raced > 0, 2, 1)
        return Traffic(jnp.asarray(br, jnp.float32), jnp.asarray(bw, jnp.float32),
                       jnp.asarray(chains, jnp.int32), jnp.asarray(upd, jnp.int32))

    def begin_update(self, state, slot, new_value, torn_words):
        half = state.data[slot].at[:torn_words].set(new_value[:torn_words])
        return state._replace(
            version=state.version.at[slot].add(jnp.uint32(1)),  # odd = locked
            data=state.data.at[slot].set(half))


@register_strategy
class Simplock(_Versioned):
    name = "simplock"
    blocks_readers = True

    def init(self, n, k, p_max, data):
        base = super().init(n, k, p_max, data)
        return base._replace(lock=jnp.zeros((n,), jnp.uint32))

    def read(self, state, slots):
        held = state.lock[slots] != 0
        return state.data[slots], ~held

    def traffic(self, stats, k, p):
        w = WORD_BYTES
        cell = k * w
        loads, upd = stats.n_loads, stats.n_updates
        br = (loads + upd) * (cell + w)
        bw = upd * cell + (loads + upd) * 2 * w        # lock/unlock writes
        return Traffic(jnp.asarray(br, jnp.float32), jnp.asarray(bw, jnp.float32),
                       jnp.asarray(2, jnp.int32),     # lock acquire precedes data
                       jnp.asarray(loads + upd, jnp.int32))

    def begin_update(self, state, slot, new_value, torn_words):
        half = state.data[slot].at[:torn_words].set(new_value[:torn_words])
        return state._replace(lock=state.lock.at[slot].set(jnp.uint32(1)),
                              data=state.data.at[slot].set(half))

    def check_invariants(self, spec, state):
        out = super().check_invariants(spec, state)
        out["lock_released"] = state.lock != 0      # no holder at rest
        return out


class _NodePool(_Versioned):
    """Shared base for INDIRECT / CACHED_WF: pool of n + 2p immutable nodes."""

    def init(self, n, k, p_max, data):
        # n installed nodes + 2p slack (SMR in-flight bound).
        m = n + 2 * p_max
        pool = jnp.zeros((m, k), WORD_DTYPE)
        pool = pool.at[:n].set(data)
        bptr = jnp.arange(n, dtype=jnp.int32)           # cell i -> node i
        free_ring = jnp.concatenate(
            [jnp.arange(n, m, dtype=jnp.int32),
             jnp.full((n,), NULL)])                     # slots occupied by live nodes
        mark = jnp.zeros((n,), bool) if self.name == "cached_wf" else _empty(bool)
        return TableState(data, jnp.zeros((n,), jnp.uint32), bptr, mark,
                          _empty(jnp.uint32), pool, free_ring,
                          jnp.uint32(0), jnp.uint32(0))

    def commit(self, state, new_data, new_version, n_updates, p):
        # One fresh node per dirty cell holds the final value; the old node is
        # retired to the ring.  (Intermediate values of a CAS chain live and
        # die inside the batch; they are counted in stats.n_updates.)
        n = state.version.shape[0]
        dirty = new_version != state.version
        d_count = jnp.sum(dirty.astype(jnp.uint32))
        order = jnp.argsort(~dirty, stable=True)   # dirty slots first
        dslots = jnp.where(jnp.arange(n) < d_count, order, n)
        max_d = min(n, p)
        dslots = dslots[:max_d]
        live = dslots < n
        new_nodes, st2 = ring_alloc(state, d_count, max_d)
        old_nodes = state.bptr[jnp.minimum(dslots, n - 1)]
        pool = st2.pool.at[jnp.where(live, new_nodes, st2.pool.shape[0])].set(
            new_data[jnp.minimum(dslots, n - 1)], mode="drop")
        bptr = st2.bptr.at[jnp.where(live, dslots, n)].set(
            jnp.where(live, new_nodes, NULL), mode="drop")
        st3 = st2._replace(pool=pool, bptr=bptr, data=new_data,
                           version=new_version)
        return ring_free(st3, jnp.where(live, old_nodes, NULL), d_count, max_d)

    def memory_bytes(self, n, k, p):
        w = WORD_BYTES
        pool = (n + 2 * p) * k * w + (n + 2 * p) * w    # pool + ring
        if self.name == "indirect":
            return n * w + pool                          # ptr + pool + ring
        return n * (k + 2) * w + pool


@register_strategy
class Indirect(_KernelLowering, _NodePool):
    name = "indirect"
    lock_free = True

    def logical(self, state):
        return state.pool[state.bptr]

    def engine_view(self, state):
        # `commit` writes new_data into the shadow alongside the node swing,
        # so the shadow always equals pool[bptr]; reading it saves the
        # dependent gather on every engine batch (reads never touch it).
        return state.data

    def read(self, state, slots):
        node = state.bptr[slots]
        return state.pool[node], jnp.ones((slots.shape[0],), bool)

    def traffic(self, stats, k, p):
        w = WORD_BYTES
        cell = k * w
        loads, upd, dirty = stats.n_loads, stats.n_updates, stats.n_dirty_cells
        br = loads * (w + cell) + upd * (w + cell)
        bw = upd * cell + dirty * w
        return Traffic(jnp.asarray(br, jnp.float32), jnp.asarray(bw, jnp.float32),
                       jnp.asarray(2, jnp.int32),       # ptr chase on EVERY load
                       jnp.asarray(upd, jnp.int32))

    def begin_update(self, state, slot, new_value, torn_words):
        # Node written; pointer swing (the linearization point) pending.
        free_slot, state = sim_alloc(state)
        pool = state.pool.at[free_slot].set(new_value)
        return state._replace(pool=pool)

    def check_invariants(self, spec, state):
        out = super().check_invariants(spec, state)
        m = state.pool.shape[0]
        bad_ptr = (state.bptr < 0) | (state.bptr >= m)
        node = state.pool[jnp.clip(state.bptr, 0, m - 1)]
        out["pointer_range"] = bad_ptr
        # commit maintains data as an exact shadow of pool[bptr]
        out["shadow_agrees"] = ~bad_ptr & jnp.any(node != state.data, axis=1)
        return out


class _Cached(_NodePool):
    """Shared traffic model for the two cached layouts (1-gather fast path)."""

    def traffic(self, stats, k, p):
        w = WORD_BYTES
        cell = k * w
        loads, raced, upd = stats.n_loads, stats.n_raced_loads, stats.n_updates
        fast = loads - raced
        br = fast * (cell + 2 * w) + raced * (cell + 2 * w + cell) + upd * (cell + 3 * w)
        bw = upd * (2 * cell + 3 * w)                   # node + cache + ver/ptr
        chains = jnp.where(raced > 0, 2, 1)             # fast path: ONE gather
        return Traffic(jnp.asarray(br, jnp.float32), jnp.asarray(bw, jnp.float32),
                       jnp.asarray(chains, jnp.int32),
                       jnp.asarray(2 * upd, jnp.int32))  # ptr CAS + ver lock


@register_strategy
class CachedWF(_KernelLowering, _Cached):
    name = "cached_wf"
    lock_free = True

    def commit(self, state, new_data, new_version, n_updates, p):
        new_state = super().commit(state, new_data, new_version, n_updates, p)
        # Batch completes cleanly: every dirty cell ends validated (unmarked)
        # with cache == backup.
        return new_state._replace(mark=jnp.zeros_like(state.mark))

    def read(self, state, slots):
        v1 = state.version[slots]
        val = state.data[slots]
        marked = state.mark[slots]
        v2 = state.version[slots]
        fastok = (~marked) & (v1 == v2) & (v1 % 2 == 0)
        backup = state.pool[state.bptr[slots]]          # slow path (protected)
        return (jnp.where(fastok[:, None], val, backup),
                jnp.ones((slots.shape[0],), bool))

    def begin_update(self, state, slot, new_value, torn_words):
        # Linearization point (pointer install) HAS happened: new node is the
        # truth; cache is mid-copy and marked invalid; version odd.
        half = state.data[slot].at[:torn_words].set(new_value[:torn_words])
        free_slot, state = sim_alloc(state)
        pool = state.pool.at[free_slot].set(new_value)
        return state._replace(
            pool=pool,
            bptr=state.bptr.at[slot].set(free_slot),
            mark=state.mark.at[slot].set(True),
            version=state.version.at[slot].add(jnp.uint32(1)),
            data=state.data.at[slot].set(half))

    def check_invariants(self, spec, state):
        out = super().check_invariants(spec, state)
        m = state.pool.shape[0]
        bad_ptr = (state.bptr < 0) | (state.bptr >= m)
        backup = state.pool[jnp.clip(state.bptr, 0, m - 1)]
        out["pointer_range"] = bad_ptr
        # every batch ends validated: cache == backup, marks clear
        out["cache_matches_backup"] = \
            ~bad_ptr & jnp.any(backup != state.data, axis=1)
        out["mark_clear"] = state.mark
        return out


@register_strategy
class CachedME(_KernelLowering, _Cached):
    name = "cached_me"
    lock_free = True

    def init(self, n, k, p_max, data):
        m = max(3 * p_max, 1)
        pool = jnp.zeros((m, k), WORD_DTYPE)
        bptr = jnp.full((n,), NULL)                     # null: cache is live
        free_ring = jnp.arange(m, dtype=jnp.int32)
        return TableState(data, jnp.zeros((n,), jnp.uint32), bptr,
                          mark=_empty(bool), lock=_empty(jnp.uint32),
                          pool=pool, free_ring=free_ring,
                          ring_head=jnp.uint32(0), alloc_gen=jnp.uint32(0))

    def commit(self, state, new_data, new_version, n_updates, p):
        # Transient backups: installed during the update, uninstalled after
        # the cache copy (backup returns to tagged null carrying the version).
        # Pool slots cycle through the 3p ring within the batch; the final
        # layout has all-null bptr (paper §3.2 invariant).
        dirty = new_version != state.version
        ring_cap = state.free_ring.shape[0]
        u_count = jnp.minimum(n_updates.astype(jnp.uint32),
                              jnp.uint32(ring_cap))
        max_u = min(p, ring_cap)
        slots_alloc, st2 = ring_alloc(state, u_count, max_u)
        # All transients are freed within the batch: push them straight back.
        st3 = ring_free(st2, slots_alloc, u_count, max_u)
        # Tagged null: encode low version bits so a stale CAS can't ABA.
        tag = (new_version >> 1).astype(jnp.int32) & jnp.int32(0x3FFFFFFF)
        bptr = jnp.where(dirty, -(tag + 2), st3.bptr)
        return st3._replace(data=new_data, version=new_version, bptr=bptr)

    def read(self, state, slots):
        v1 = state.version[slots]
        val = state.data[slots]
        bp = state.bptr[slots]
        is_null = bp < 0
        v2 = state.version[slots]
        fastok = is_null & (v1 == v2) & (v1 % 2 == 0)
        backup = state.pool[jnp.maximum(bp, 0)]         # slow path: live node
        # If bptr is a real node, the node holds the live value (invariant);
        # either way the reader makes progress -> ok is always True.
        return (jnp.where(fastok[:, None], val, backup),
                jnp.ones((slots.shape[0],), bool))

    def begin_update(self, state, slot, new_value, torn_words):
        half = state.data[slot].at[:torn_words].set(new_value[:torn_words])
        free_slot, state = sim_alloc(state)
        pool = state.pool.at[free_slot].set(new_value)
        return state._replace(
            pool=pool,
            bptr=state.bptr.at[slot].set(free_slot),
            version=state.version.at[slot].add(jnp.uint32(1)),
            data=state.data.at[slot].set(half))

    def memory_bytes(self, n, k, p):
        w = WORD_BYTES
        return n * (k + 2) * w + 3 * p * k * w + 3 * p * w

    def check_invariants(self, spec, state):
        out = super().check_invariants(spec, state)
        # At rest every bptr is null (paper §3.2): either the init/restore
        # NULL or the tagged null commit leaves, whose tag must agree with
        # the cell's version (-(tag+2) with tag = (ver >> 1) & 0x3FFFFFFF).
        tag = (state.version >> 1).astype(jnp.int32) & jnp.int32(0x3FFFFFFF)
        ok = (state.bptr == NULL) | (state.bptr == -(tag + 2))
        out["tagged_null"] = ~ok
        return out
