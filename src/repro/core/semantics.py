"""v1 load/store/CAS batch semantics — now a facade over the unified engine.

This module used to own the vectorized linearizer for LOAD/STORE/CAS
batches; that algorithm (stable-sort by cell, L combining rounds for
updates, segmented-scan load resolution — see the module history and
DESIGN.md §1) now lives generalized in `repro.core.engine.linearize`, which
adds LL/SC/VALIDATE lanes and a one-round fast path for pure-sync batches.
What remains here is the v1 surface:

  * the kind constants LOAD/STORE/CAS/IDLE (numerically identical to the
    unified namespace, so a v1 `OpBatch` IS a valid unified batch),
  * `apply_batch(data, version, ops)` — raw-array entry point used by
    `wf_writable` and the sharded table (`core/distributed.py`),
  * `apply_batch_reference` — the sequential numpy oracle that DEFINES
    store/CAS correctness (property tests),
  * `make_op_batch` / `random_batch` — batch constructors shared by tests
    and benchmarks.

Table-level callers should use `repro.atomics.apply(spec, state, ops)`.
"""

from __future__ import annotations

import functools

import jax
import numpy as np
import jax.numpy as jnp

from repro.core import engine
from repro.core.engine import (  # noqa: F401  (v1 re-exports)
    CAS, IDLE, LOAD, STORE, ApplyResult, ApplyStats, OpBatch,
    _segmented_scan_max,
)
from repro.core.layout import WORD_DTYPE  # noqa: F401  (v1 re-export)


def make_op_batch(kind, slot, expected=None, desired=None, *, k: int) -> OpBatch:
    """Checked constructor (validation + dtype coercion in `engine.make_ops`)."""
    return engine.make_ops(kind, slot, expected, desired, k=k)


# ---------------------------------------------------------------------------
# Sequential oracle (numpy) — THE definition of correctness.
# ---------------------------------------------------------------------------

def apply_batch_reference(data: np.ndarray, version: np.ndarray, ops: OpBatch):
    """Apply ops one at a time in lane order.  Pure numpy, for tests.

    Returns (new_data, new_version, ApplyResult-as-numpy).
    """
    p, k = np.asarray(ops.desired).shape
    ctx = engine.LinkCtx(np.full(p, -1, np.int32), np.zeros(p, np.uint32),
                         np.zeros((p, k), np.uint32), np.zeros(p, bool))
    new_data, new_version, _, result = engine.apply_ops_reference(
        data, version, ctx, ops)
    return new_data, new_version, result


# ---------------------------------------------------------------------------
# Vectorized linearization — the unified engine, ctx-free entry point.
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, donate_argnums=(0, 1))
def apply_batch(data: jax.Array, version: jax.Array, ops: OpBatch):
    """Linearize and apply a batch of ops.  Returns (data, version, result, stats).

    `data` is word[n, k]; `version` is uint32[n] (bumped by 2 per successful
    update, paper-style even==unlocked parity).
    """
    p = ops.kind.shape[0]
    k = ops.desired.shape[1]
    new_data, new_version, _, result, stats = engine.linearize(
        data, version, engine.init_ctx(p, k), ops)
    return new_data, new_version, result, stats


# ---------------------------------------------------------------------------
# Random batch generator (shared by tests & benchmarks).
# ---------------------------------------------------------------------------

def random_batch(rng: np.random.Generator, *, p: int, n: int, k: int,
                 update_frac: float = 0.5, zipf: float = 0.0,
                 current: np.ndarray | None = None) -> OpBatch:
    """Paper-style workload: u%% updates (half store, half CAS), Zipfian slots.

    If `current` (the live table) is given, half the CAS ops use the true
    current value as `expected` so they succeed; otherwise comparands are
    random (mostly failing), matching the microbenchmark's insert/delete mix.
    """
    if zipf <= 0.0:
        slots = rng.integers(0, n, size=p)
    else:
        ranks = rng.zipf(max(zipf, 1.01), size=p)   # zipf >= 1 required
        slots = (ranks - 1) % n
    u = rng.random(p) < update_frac
    is_cas = rng.random(p) < 0.5
    kind = np.where(u, np.where(is_cas, CAS, STORE), LOAD).astype(np.int32)
    desired = rng.integers(0, 2**32, size=(p, k), dtype=np.uint32)
    expected = rng.integers(0, 2**32, size=(p, k), dtype=np.uint32)
    if current is not None:
        use_cur = rng.random(p) < 0.5
        expected = np.where(use_cur[:, None], current[slots], expected)
    return OpBatch(
        jnp.asarray(kind), jnp.asarray(slots.astype(np.int32)),
        jnp.asarray(expected), jnp.asarray(desired),
    )
