"""Deterministic batch linearization of big-atomic operations.

This module is the TPU-native core of the Big Atomics reproduction.  On a CPU,
`p` threads issue load/store/CAS operations against `n` cells of `k` adjacent
words each, and the hardware's coherence protocol serializes conflicting
accesses.  In an SPMD/XLA program there are no threads: we model one "step" of
the concurrent system as a *batch* of `p` operations applied against a
device-resident table, linearized in a deterministic order (lane order).  The
result is bit-identical to applying the ops one at a time — the sequential
oracle in `apply_batch_reference` defines the semantics and the vectorized
`apply_batch` must match it exactly (property-tested).

Algorithm (vectorized, jit-able):
  1. stable-sort ops by cell id -> per-cell contiguous segments;
  2. updates (store / CAS) are serialized *within* a segment only: a
     `lax.while_loop` runs ``L = max updates per cell`` rounds, and round ``t``
     applies the t-th update of every segment in parallel (masked gather ->
     compare -> masked scatter).  This is the classic PRAM "combining"
     technique: contention (the paper's Zipfian ``z``) shows up as L rounds,
     uncontended batches finish in one.
  3. loads never serialize: each load reads the recorded post-value of the
     last update preceding it in its segment (segmented max-scan), or the
     pre-batch value.

Cost: O(p) work per round, L rounds.  Throughput ~ p / L, matching the
qualitative contention behaviour of the paper's microbenchmarks.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

# Op kinds.  IDLE lanes are padding: they read slot 0 but report invalid.
LOAD = 0
STORE = 1
CAS = 2
IDLE = 3

WORD_DTYPE = jnp.uint32  # one "word" of a big atomic; k words per cell


class OpBatch(NamedTuple):
    """A batch of `p` operations over an `(n, k)` table.

    kind:     int32[p]   — LOAD / STORE / CAS / IDLE
    slot:     int32[p]   — target cell index in [0, n)
    expected: word[p, k] — CAS comparand (ignored for load/store)
    desired:  word[p, k] — value to write (store / successful CAS)
    """

    kind: jax.Array
    slot: jax.Array
    expected: jax.Array
    desired: jax.Array

    @property
    def p(self) -> int:
        return self.kind.shape[0]

    @property
    def k(self) -> int:
        return self.desired.shape[1]


class ApplyResult(NamedTuple):
    """Per-lane results of a linearized batch.

    value:   word[p, k] — the value witnessed at the op's linearization point
                          (loads: the loaded value; CAS: the comparand seen).
    success: bool[p]    — CAS success (loads/stores: True, IDLE: False).
    """

    value: jax.Array
    success: jax.Array


class ApplyStats(NamedTuple):
    """Traffic/contention statistics for one batch (all scalars).

    rounds:        number of serialization rounds L (max updates on one cell).
    n_updates:     number of store/CAS lanes.
    n_loads:       number of load lanes.
    n_cas_fail:    CAS lanes that failed.
    n_raced_loads: loads whose cell had >=1 update in this batch (these take
                   the slow path in the cached strategies).
    n_dirty_cells: distinct cells receiving >=1 successful update.
    """

    rounds: jax.Array
    n_updates: jax.Array
    n_loads: jax.Array
    n_cas_fail: jax.Array
    n_raced_loads: jax.Array
    n_dirty_cells: jax.Array


def make_op_batch(kind, slot, expected=None, desired=None, *, k: int) -> OpBatch:
    """Convenience constructor that fills unused fields with zeros."""
    kind = jnp.asarray(kind, jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)
    p = kind.shape[0]
    if expected is None:
        expected = jnp.zeros((p, k), WORD_DTYPE)
    if desired is None:
        desired = jnp.zeros((p, k), WORD_DTYPE)
    return OpBatch(kind, slot, jnp.asarray(expected, WORD_DTYPE), jnp.asarray(desired, WORD_DTYPE))


# ---------------------------------------------------------------------------
# Sequential oracle (numpy) — THE definition of correctness.
# ---------------------------------------------------------------------------

def apply_batch_reference(data: np.ndarray, version: np.ndarray, ops: OpBatch):
    """Apply ops one at a time in lane order.  Pure numpy, for tests.

    Returns (new_data, new_version, ApplyResult-as-numpy).
    """
    data = np.array(data, copy=True)
    version = np.array(version, copy=True)
    kind = np.asarray(ops.kind)
    slot = np.asarray(ops.slot)
    expected = np.asarray(ops.expected)
    desired = np.asarray(ops.desired)
    p, k = expected.shape
    value = np.zeros((p, k), dtype=data.dtype)
    success = np.zeros((p,), dtype=bool)
    for i in range(p):
        s = slot[i]
        if kind[i] == IDLE:
            continue
        cur = data[s].copy()
        value[i] = cur
        if kind[i] == LOAD:
            success[i] = True
        elif kind[i] == STORE:
            data[s] = desired[i]
            version[s] += 2
            success[i] = True
        elif kind[i] == CAS:
            if np.array_equal(cur, expected[i]):
                data[s] = desired[i]
                version[s] += 2
                success[i] = True
            else:
                success[i] = False
    return data, version, ApplyResult(value, success)


# ---------------------------------------------------------------------------
# Vectorized linearization (jnp) — bit-identical to the oracle.
# ---------------------------------------------------------------------------

def _segmented_scan_max(values: jax.Array, seg_start: jax.Array) -> jax.Array:
    """Inclusive segmented max-scan.  seg_start marks first element of a segment."""

    def combine(a, b):
        a_flag, a_val = a
        b_flag, b_val = b
        val = jnp.where(b_flag, b_val, jnp.maximum(a_val, b_val))
        return (a_flag | b_flag, val)

    _, out = lax.associative_scan(combine, (seg_start, values))
    return out


@functools.partial(jax.jit, donate_argnums=(0, 1))
def apply_batch(data: jax.Array, version: jax.Array, ops: OpBatch):
    """Linearize and apply a batch of ops.  Returns (data, version, result, stats).

    `data` is word[n, k]; `version` is uint32[n] (bumped by 2 per successful
    update, paper-style even==unlocked parity).
    """
    n, k = data.shape
    p = ops.p
    kind, slot = ops.kind, ops.slot

    active = kind != IDLE
    # Inactive lanes get an out-of-range slot so they can never collide.
    slot = jnp.where(active, slot, n)

    order = jnp.argsort(slot, stable=True)  # (slot, lane) lexicographic
    inv_order = jnp.argsort(order, stable=True)

    s_slot = slot[order]
    s_kind = kind[order]
    s_expected = ops.expected[order]
    s_desired = ops.desired[order]

    idx = jnp.arange(p, dtype=jnp.int32)
    seg_start = jnp.concatenate([jnp.ones((1,), bool), s_slot[1:] != s_slot[:-1]])
    # Index of the first element of each lane's segment.
    start_idx = _segmented_scan_max(jnp.where(seg_start, idx, -1), seg_start)

    is_upd = (s_kind == STORE) | (s_kind == CAS)
    # Exclusive count of updates before each position, segment-scoped.
    cum_upd = jnp.cumsum(is_upd.astype(jnp.int32))
    excl_upd = cum_upd - is_upd.astype(jnp.int32)
    upd_rank = excl_upd - excl_upd[start_idx]
    n_rounds = jnp.where(jnp.any(is_upd), jnp.max(jnp.where(is_upd, upd_rank, -1)) + 1, 0)

    init_vals = data[jnp.minimum(s_slot, n - 1)]  # pre-batch values per lane

    # --- serialization rounds over updates ---------------------------------
    res_after = jnp.zeros((p, k), data.dtype)   # value AFTER each update lane
    witness = jnp.zeros((p, k), data.dtype)     # value BEFORE each update lane
    succ = jnp.zeros((p,), bool)

    def round_body(state):
        t, data_, version_, res_after_, witness_, succ_ = state
        live = is_upd & (upd_rank == t)
        cur = data_[jnp.minimum(s_slot, n - 1)]
        match = jnp.all(cur == s_expected, axis=1)
        ok = live & ((s_kind == STORE) | match)
        newv = jnp.where(ok[:, None], s_desired, cur)
        # masked scatter: inactive rows target index n (dropped)
        w_idx = jnp.where(ok, s_slot, n)
        data_ = data_.at[w_idx].set(s_desired, mode="drop")
        version_ = version_.at[w_idx].add(jnp.uint32(2), mode="drop")
        res_after_ = jnp.where(live[:, None], newv, res_after_)
        witness_ = jnp.where(live[:, None], cur, witness_)
        succ_ = jnp.where(live, ok, succ_)
        return (t + 1, data_, version_, res_after_, witness_, succ_)

    def round_cond(state):
        return state[0] < n_rounds

    _, data, version, res_after, witness, succ = lax.while_loop(
        round_cond, round_body, (jnp.int32(0), data, version, res_after, witness, succ)
    )

    # --- resolve loads -------------------------------------------------------
    # Last update position strictly before each lane, within its segment.
    upd_pos = jnp.where(is_upd, idx, -1)
    last_upd_incl = _segmented_scan_max(upd_pos, seg_start)
    # For a load lane (not an update itself) the inclusive scan already
    # excludes it; for update lanes we don't need this value.
    prev_upd = last_upd_incl
    has_prev = prev_upd >= 0
    load_vals = jnp.where(
        has_prev[:, None],
        res_after[jnp.maximum(prev_upd, 0)],
        init_vals,
    )

    is_load = s_kind == LOAD
    s_value = jnp.where(is_load[:, None], load_vals, witness)
    s_success = jnp.where(is_load, True, succ)
    s_success = jnp.where(s_kind == IDLE, False, s_success)

    value = s_value[inv_order]
    success = s_success[inv_order]

    # --- stats ---------------------------------------------------------------
    seg_has_upd = _segmented_scan_max(
        jnp.where(is_upd, jnp.int32(1), jnp.int32(0)), seg_start
    )
    # per-lane flag: does this lane's segment contain ANY update?  Use the
    # segment-final value gathered via start of next segment trick: a segment's
    # max is found at its last element; propagate backwards by flipping.
    seg_end = jnp.concatenate([seg_start[1:], jnp.ones((1,), bool)])
    # reverse segmented scan to broadcast the segment max to all members
    seg_any_upd_rev = _segmented_scan_max(
        jnp.flip(jnp.where(is_upd, jnp.int32(1), jnp.int32(0))), jnp.flip(seg_end)
    )
    seg_any_upd = jnp.flip(seg_any_upd_rev) > 0

    raced_load = is_load & seg_any_upd
    del seg_has_upd
    stats = ApplyStats(
        rounds=n_rounds,
        n_updates=jnp.sum(is_upd.astype(jnp.int32)),
        n_loads=jnp.sum(is_load.astype(jnp.int32)),
        n_cas_fail=jnp.sum(((s_kind == CAS) & ~succ).astype(jnp.int32)),
        n_raced_loads=jnp.sum(raced_load.astype(jnp.int32)),
        n_dirty_cells=_count_dirty_cells(succ, s_slot, seg_start, seg_end, n),
    )
    return data, version, ApplyResult(value, success), stats


def _count_dirty_cells(succ, s_slot, seg_start, seg_end, n):
    """Distinct cells with >=1 successful update in this batch."""
    seg_any_succ_rev = _segmented_scan_max(
        jnp.flip(succ.astype(jnp.int32)), jnp.flip(seg_end)
    )
    seg_any_succ = jnp.flip(seg_any_succ_rev) > 0
    return jnp.sum((seg_start & seg_any_succ & (s_slot < n)).astype(jnp.int32))


# ---------------------------------------------------------------------------
# Random batch generator (shared by tests & benchmarks).
# ---------------------------------------------------------------------------

def random_batch(rng: np.random.Generator, *, p: int, n: int, k: int,
                 update_frac: float = 0.5, zipf: float = 0.0,
                 current: np.ndarray | None = None) -> OpBatch:
    """Paper-style workload: u%% updates (half store, half CAS), Zipfian slots.

    If `current` (the live table) is given, half the CAS ops use the true
    current value as `expected` so they succeed; otherwise comparands are
    random (mostly failing), matching the microbenchmark's insert/delete mix.
    """
    if zipf <= 0.0:
        slots = rng.integers(0, n, size=p)
    else:
        ranks = rng.zipf(max(zipf, 1.01), size=p)   # zipf >= 1 required
        slots = (ranks - 1) % n
    u = rng.random(p) < update_frac
    is_cas = rng.random(p) < 0.5
    kind = np.where(u, np.where(is_cas, CAS, STORE), LOAD).astype(np.int32)
    desired = rng.integers(0, 2**32, size=(p, k), dtype=np.uint32)
    expected = rng.integers(0, 2**32, size=(p, k), dtype=np.uint32)
    if current is not None:
        use_cur = rng.random(p) < 0.5
        expected = np.where(use_cur[:, None], current[slots], expected)
    return OpBatch(
        jnp.asarray(kind), jnp.asarray(slots.astype(np.int32)),
        jnp.asarray(expected), jnp.asarray(desired),
    )
