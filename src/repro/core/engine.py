"""The unified big-atomic engine: ONE op schema, ONE linearization.

This module merges the repo's three historical op-batch schemas
(`core/semantics.OpBatch` for load/store/CAS, `sync/llsc.SyncOpBatch` for
LL/SC/validate, `core/cachehash.OpBatch` for hash ops) into a single
`OpBatch` whose per-lane `kind` covers

    LOAD / STORE / CAS / IDLE        (value ops, numeric-compatible with v1)
    LL / SC / VALIDATE               (version ops, per-lane LinkCtx)
    FIND / INSERT / DELETE           (hash ops, dispatched by cachehash)

and gives the first seven ONE vectorized linearization, `linearize`, that is
bit-identical to the sequential oracle `apply_ops_reference`: ops apply in
lane order; STORE/CAS serialize within a cell segment (L combining rounds);
SC commits iff its lane's link version still matches the cell.  Mixed
batches — a decode lookup, a page CAS, and a queue SC in the same round —
therefore linearize in one call.

Fast path: when a batch carries no STORE/CAS lanes, the one-SC-per-cell-
per-batch fact (DESIGN.md §4) applies — every link predates the batch, so
the first eligible SC per cell wins and everyone behind it is stale.  The
engine detects this at runtime (`lax.cond`) and resolves the whole batch in
closed form, ONE round, instead of the L-round combining loop.

`apply(spec, state, ops, ctx)` is the single table-level entry point: `spec`
(an `AtomicSpec`) is the only static argument; layout maintenance and the
traffic model dispatch through the strategy registry, so new layouts plug in
without touching this file.

Execution is two-tier since ISSUE 5 (DESIGN.md §8): `linearize` below is the
pure-XLA *reference* executor, and `round_for(spec)` swaps in the strategy's
lowered fused round (`repro.kernels.engine_round` — a runtime fast path for
collision-free batches, a single-pass sequential-replay kernel for contended
ones) whenever the layout provides one.  Every result remains bit-identical
to `linearize`, which remains bit-identical to `apply_ops_reference`.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import registry
from repro.core.layout import WORD_DTYPE
from repro.core.specs import AtomicSpec
from repro.obs import telemetry as obs_telemetry

# Op kinds.  LOAD/STORE/CAS/IDLE keep their v1 numeric values so legacy
# `semantics.OpBatch` instances are valid unified batches as-is.
LOAD = 0
STORE = 1
CAS = 2
IDLE = 3     # padding lane: reads slot 0, reports invalid
LL = 4       # load-linked: read value, link (slot, version)
SC = 5       # store-conditional: commit desired iff link still valid
VALIDATE = 6  # is my link still valid?  (never writes)

# Hash-table kinds (same schema, dispatched by cachehash.apply_hash; the
# `slot` field carries the uint32 key bit-pattern, `desired[:, :vw]` the
# value).  Kept in one namespace so a kind value means one thing everywhere.
FIND = 7
INSERT = 8
DELETE = 9

TABLE_KINDS = (LOAD, STORE, CAS, IDLE, LL, SC, VALIDATE)
HASH_KINDS = (FIND, INSERT, DELETE, IDLE)


class OpBatch(NamedTuple):
    """A batch of `p` operations over an `(n, k)` table.

    kind:     int32[p]   — one of the kind constants above
    slot:     int32[p]   — target cell index in [0, n)  (hash ops: key bits)
    expected: word[p, k] — CAS comparand (ignored otherwise)
    desired:  word[p, k] — value to write (STORE / successful CAS / SC;
                           hash ops: INSERT value in the first vw words)
    """

    kind: jax.Array
    slot: jax.Array
    expected: jax.Array
    desired: jax.Array

    @property
    def p(self) -> int:
        return self.kind.shape[0]

    @property
    def k(self) -> int:
        return self.desired.shape[1]


class LinkCtx(NamedTuple):
    """Per-lane link state, carried across batches (a pure pytree).

    slot:    int32[p]   linked cell (-1 = never linked)
    version: uint32[p]  version observed at the LL
    value:   word[p,k]  value observed at the LL
    linked:  bool[p]    link is live (consumed by any SC attempt)
    """

    slot: jax.Array
    version: jax.Array
    value: jax.Array
    linked: jax.Array


class ApplyResult(NamedTuple):
    """Per-lane results of a linearized batch.

    value:   word[p, k] — the value witnessed at the op's linearization point
                          (loads/LL: the value read; CAS/SC: the pre-value).
    success: bool[p]    — CAS/SC success, VALIDATE link validity
                          (LOAD/STORE/LL: True, IDLE: False).
    """

    value: jax.Array
    success: jax.Array


class ApplyStats(NamedTuple):
    """Traffic/contention statistics for one batch (all scalars).

    rounds:        serialization rounds L (1 on the pure-sync fast path).
    n_updates:     store/CAS lanes + successful SC lanes (writes attempted).
    n_loads:       LOAD + LL lanes.
    n_cas_fail:    CAS/SC lanes that failed.
    n_raced_loads: loads whose cell had >=1 write in this batch (these take
                   the slow path in the cached strategies).
    n_dirty_cells: distinct cells receiving >=1 successful write.
    """

    rounds: jax.Array
    n_updates: jax.Array
    n_loads: jax.Array
    n_cas_fail: jax.Array
    n_raced_loads: jax.Array
    n_dirty_cells: jax.Array


def init_ctx(p: int, k: int) -> LinkCtx:
    return LinkCtx(
        slot=jnp.full((p,), -1, jnp.int32),
        version=jnp.zeros((p,), jnp.uint32),
        value=jnp.zeros((p, k), WORD_DTYPE),
        linked=jnp.zeros((p,), bool),
    )


def make_ops(kind, slot, expected=None, desired=None, *, k: int) -> OpBatch:
    """THE checked op-batch constructor: every public wrapper routes through
    here so validation and dtype coercion can never be skipped.

    Checks (on concrete inputs): kind values are known, shapes line up with
    the batch width p and cell width k.  Word payloads are coerced to the
    canonical WORD_DTYPE (uint32)."""
    kind = jnp.asarray(kind, jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)
    if kind.ndim != 1:
        raise ValueError(f"kind must be rank-1, got shape {kind.shape}")
    p = kind.shape[0]
    if slot.shape != (p,):
        raise ValueError(f"slot shape {slot.shape} != ({p},)")
    try:
        kind_np = np.asarray(kind)          # concrete only; tracers skip
    except Exception:
        kind_np = None
    if kind_np is not None:
        bad = np.setdiff1d(kind_np, np.arange(DELETE + 1))
        if bad.size:
            raise ValueError(f"unknown op kinds {bad.tolist()}")
    if expected is None:
        expected = jnp.zeros((p, k), WORD_DTYPE)
    else:
        expected = jnp.asarray(expected, WORD_DTYPE)
    if desired is None:
        desired = jnp.zeros((p, k), WORD_DTYPE)
    else:
        desired = jnp.asarray(desired, WORD_DTYPE)
    for name, arr in (("expected", expected), ("desired", desired)):
        if arr.shape != (p, k):
            raise ValueError(f"{name} shape {arr.shape} != ({p}, {k})")
    return OpBatch(kind, slot, expected, desired)


def loads(slots, *, k: int) -> OpBatch:
    slots = jnp.asarray(slots, jnp.int32)
    return make_ops(jnp.full(slots.shape, LOAD, jnp.int32), slots, k=k)


def stores(slots, desired, *, k: int) -> OpBatch:
    slots = jnp.asarray(slots, jnp.int32)
    return make_ops(jnp.full(slots.shape, STORE, jnp.int32), slots,
                    desired=desired, k=k)


def cas_ops(slots, expected, desired, *, k: int) -> OpBatch:
    slots = jnp.asarray(slots, jnp.int32)
    return make_ops(jnp.full(slots.shape, CAS, jnp.int32), slots,
                    expected=expected, desired=desired, k=k)


def sync_ops(kind, slots, desired=None, *, k: int) -> OpBatch:
    return make_ops(kind, slots, desired=desired, k=k)


# ---------------------------------------------------------------------------
# Sequential oracle (numpy) — THE definition of correctness.
# ---------------------------------------------------------------------------

def apply_ops_reference(data: np.ndarray, version: np.ndarray,
                        ctx: LinkCtx, ops: OpBatch):
    """Apply mixed table ops one at a time in lane order.  Pure numpy.

    Returns (new_data, new_version, new_ctx, ApplyResult-as-numpy)."""
    data = np.array(data, copy=True)
    version = np.array(version, copy=True)
    c_slot = np.array(ctx.slot, copy=True)
    c_ver = np.array(ctx.version, copy=True)
    c_val = np.array(ctx.value, copy=True)
    c_lnk = np.array(ctx.linked, copy=True)
    kind = np.asarray(ops.kind)
    slot = np.asarray(ops.slot)
    expected = np.asarray(ops.expected)
    desired = np.asarray(ops.desired)
    p, k = desired.shape
    value = np.zeros((p, k), dtype=data.dtype)
    success = np.zeros((p,), dtype=bool)
    for i in range(p):
        s = slot[i]
        if kind[i] == IDLE:
            continue
        cur = data[s].copy()
        value[i] = cur
        if kind[i] == LOAD:
            success[i] = True
        elif kind[i] == STORE:
            data[s] = desired[i]
            version[s] += 2
            success[i] = True
        elif kind[i] == CAS:
            if np.array_equal(cur, expected[i]):
                data[s] = desired[i]
                version[s] += 2
                success[i] = True
        elif kind[i] == LL:
            c_slot[i], c_ver[i], c_val[i], c_lnk[i] = \
                s, version[s], cur, True
            success[i] = True
        elif kind[i] == VALIDATE:
            success[i] = bool(c_lnk[i] and c_slot[i] == s
                              and c_ver[i] == version[s])
        elif kind[i] == SC:
            ok = bool(c_lnk[i] and c_slot[i] == s
                      and c_ver[i] == version[s])
            if ok:
                data[s] = desired[i]
                version[s] += 2
            c_lnk[i] = False            # any SC attempt consumes the link
            success[i] = ok
        else:
            raise ValueError(f"lane {i}: kind {kind[i]} is not a table op")
    new_ctx = LinkCtx(c_slot, c_ver, c_val, c_lnk)
    return data, version, new_ctx, ApplyResult(value, success)


# ---------------------------------------------------------------------------
# Vectorized linearization (jnp) — bit-identical to the oracle.
# ---------------------------------------------------------------------------

def _segmented_scan_max(values: jax.Array, seg_start: jax.Array) -> jax.Array:
    """Inclusive segmented max-scan.  seg_start marks first element of a segment."""

    def combine(a, b):
        a_flag, a_val = a
        b_flag, b_val = b
        val = jnp.where(b_flag, b_val, jnp.maximum(a_val, b_val))
        return (a_flag | b_flag, val)

    _, out = lax.associative_scan(combine, (seg_start, values))
    return out


def _seg_broadcast_any(flags: jax.Array, seg_end: jax.Array) -> jax.Array:
    """Broadcast `any(flags)` within each segment to all its members."""
    rev = _segmented_scan_max(jnp.flip(flags.astype(jnp.int32)),
                              jnp.flip(seg_end))
    return jnp.flip(rev) > 0


def stats_on_sorted(n: int, s_slot, s_kind, succ_s) -> ApplyStats:
    """`ApplyStats` from the (slot, lane)-sorted order — THE single
    definition, shared by `linearize` and the fused kernel round
    (`repro.kernels.engine_round`), so the two can never drift.

    succ_s is per-lane update success in sorted order (meaningful for
    STORE/CAS/SC lanes; the closed `rounds` form below equals what the
    general/pure-sync execution branches would report)."""
    p = s_slot.shape[0]
    idx = jnp.arange(p, dtype=jnp.int32)
    seg_start = jnp.concatenate(
        [jnp.ones((1,), bool), s_slot[1:] != s_slot[:-1]])
    seg_end = jnp.concatenate([seg_start[1:], jnp.ones((1,), bool)])
    start_idx = _segmented_scan_max(jnp.where(seg_start, idx, -1), seg_start)
    is_valcas = (s_kind == STORE) | (s_kind == CAS)
    is_sc = (s_kind == SC) & (s_slot < n)
    is_upd = is_valcas | is_sc
    is_read = (s_kind == LOAD) | (s_kind == LL)
    cum_upd = jnp.cumsum(is_upd.astype(jnp.int32))
    excl_upd = cum_upd - is_upd.astype(jnp.int32)
    upd_rank = excl_upd - excl_upd[start_idx]
    n_rounds = jnp.where(jnp.any(is_upd),
                         jnp.max(jnp.where(is_upd, upd_rank, -1)) + 1, 0)
    wrote = is_valcas | (is_sc & succ_s)
    seg_any_wrote = _seg_broadcast_any(wrote, seg_end)
    seg_any_succ = _seg_broadcast_any(succ_s & is_upd, seg_end)
    return ApplyStats(
        rounds=jnp.where(jnp.any(is_valcas), n_rounds,
                         jnp.where(jnp.any(is_sc), 1, 0)).astype(jnp.int32),
        n_updates=jnp.sum(wrote.astype(jnp.int32)),
        n_loads=jnp.sum(is_read.astype(jnp.int32)),
        n_cas_fail=jnp.sum((((s_kind == CAS) | is_sc) & ~succ_s)
                           .astype(jnp.int32)),
        n_raced_loads=jnp.sum((is_read & seg_any_wrote).astype(jnp.int32)),
        n_dirty_cells=jnp.sum((seg_start & seg_any_succ & (s_slot < n))
                              .astype(jnp.int32)),
    )


@jax.jit
def linearize(data: jax.Array, version: jax.Array, ctx: LinkCtx,
              ops: OpBatch):
    """Linearize a mixed LOAD/STORE/CAS/LL/SC/VALIDATE batch in lane order.

    Returns (data', version', ctx', ApplyResult, ApplyStats).  `data` is
    word[n, k]; `version` is uint32[n] (bumped by 2 per successful write,
    paper-style even==unlocked parity)."""
    n, k = data.shape
    p = ops.p
    kind = ops.kind

    active = kind != IDLE
    # Inactive lanes get an out-of-range slot so they can never collide.
    slot = jnp.where(active, ops.slot, n)

    order = jnp.argsort(slot, stable=True)  # (slot, lane) lexicographic
    inv = jnp.argsort(order, stable=True)

    s_slot = slot[order]
    s_kind = kind[order]
    s_expected = ops.expected[order]
    s_desired = ops.desired[order]
    s_cslot = ctx.slot[order]
    s_cver = ctx.version[order]
    s_clnk = ctx.linked[order]

    idx = jnp.arange(p, dtype=jnp.int32)
    seg_start = jnp.concatenate(
        [jnp.ones((1,), bool), s_slot[1:] != s_slot[:-1]])
    start_idx = _segmented_scan_max(jnp.where(seg_start, idx, -1), seg_start)

    is_valcas = (s_kind == STORE) | (s_kind == CAS)
    is_sc = (s_kind == SC) & (s_slot < n)
    is_upd = is_valcas | is_sc
    # Exclusive count of updates before each position, segment-scoped.
    cum_upd = jnp.cumsum(is_upd.astype(jnp.int32))
    excl_upd = cum_upd - is_upd.astype(jnp.int32)
    upd_rank = excl_upd - excl_upd[start_idx]
    n_rounds = jnp.where(jnp.any(is_upd),
                         jnp.max(jnp.where(is_upd, upd_rank, -1)) + 1, 0)

    safe_slot = jnp.minimum(s_slot, n - 1)
    init_vals = data[safe_slot]          # pre-batch values per lane
    ver0 = version[safe_slot]            # pre-batch versions per lane

    def _general(data, version):
        """L-round combining loop: round t applies the t-th write of every
        cell segment in parallel (masked gather -> check -> masked scatter).
        Handles arbitrary STORE/CAS/SC interleavings."""
        res_after = jnp.zeros((p, k), data.dtype)   # value AFTER each write lane
        ver_after = jnp.zeros((p,), jnp.uint32)     # version AFTER each write lane
        witness = jnp.zeros((p, k), data.dtype)     # value BEFORE each write lane
        wver = jnp.zeros((p,), jnp.uint32)          # version BEFORE each write lane
        succ = jnp.zeros((p,), bool)

        def body(state):
            t, data_, version_, res_after_, ver_after_, witness_, wver_, succ_ = state
            live = is_upd & (upd_rank == t)
            cur = data_[safe_slot]
            curv = version_[safe_slot]
            match = jnp.all(cur == s_expected, axis=1)
            link_ok = s_clnk & (s_cslot == s_slot) & (s_cver == curv)
            ok = live & jnp.where(
                s_kind == STORE, True,
                jnp.where(s_kind == CAS, match, link_ok))
            w_idx = jnp.where(ok, s_slot, n)        # masked scatter (drop)
            data_ = data_.at[w_idx].set(s_desired, mode="drop")
            version_ = version_.at[w_idx].add(jnp.uint32(2), mode="drop")
            res_after_ = jnp.where(live[:, None],
                                   jnp.where(ok[:, None], s_desired, cur),
                                   res_after_)
            ver_after_ = jnp.where(live, curv + 2 * ok.astype(jnp.uint32),
                                   ver_after_)
            witness_ = jnp.where(live[:, None], cur, witness_)
            wver_ = jnp.where(live, curv, wver_)
            succ_ = jnp.where(live, ok, succ_)
            return (t + 1, data_, version_, res_after_, ver_after_,
                    witness_, wver_, succ_)

        out = lax.while_loop(
            lambda st: st[0] < n_rounds, body,
            (jnp.int32(0), data, version, res_after, ver_after,
             witness, wver, succ))
        _, data, version, res_after, ver_after, witness, wver, succ = out

        # Non-write lanes observe the last write preceding them in-segment.
        upd_pos = jnp.where(is_upd, idx, -1)
        prev_upd = _segmented_scan_max(upd_pos, seg_start)
        has_prev = prev_upd >= 0
        val_pt = jnp.where(has_prev[:, None],
                           res_after[jnp.maximum(prev_upd, 0)], init_vals)
        ver_pt = jnp.where(has_prev, ver_after[jnp.maximum(prev_upd, 0)],
                           ver0)
        val_s = jnp.where(is_upd[:, None], witness, val_pt)
        verpt_s = jnp.where(is_upd, wver, ver_pt)
        return data, version, val_s, verpt_s, succ

    def _fast(data, version):
        """One-round closed form for batches without STORE/CAS lanes: every
        SC's link predates the batch, so the first eligible SC per cell wins
        and every later SC on that cell is already stale (DESIGN.md §4)."""
        eligible = is_sc & s_clnk & (s_cslot == s_slot) & (s_cver == ver0)
        elig_incl = _segmented_scan_max(eligible.astype(jnp.int32), seg_start)
        elig_before = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32), elig_incl[:-1]])
        elig_before = jnp.where(seg_start, 0, elig_before) > 0
        win = eligible & ~elig_before
        # Lanes strictly after the winner observe the committed value/version.
        wpos_incl = _segmented_scan_max(jnp.where(win, idx, -1), seg_start)
        post_excl = (wpos_incl >= 0) & ~win
        val_s = jnp.where(post_excl[:, None],
                          s_desired[jnp.maximum(wpos_incl, 0)], init_vals)
        verpt_s = ver0 + jnp.where(post_excl, jnp.uint32(2), jnp.uint32(0))
        w_idx = jnp.where(win, s_slot, n)
        new_data = data.at[w_idx].set(s_desired, mode="drop")
        new_version = version.at[w_idx].add(jnp.uint32(2), mode="drop")
        return new_data, new_version, val_s, verpt_s, win

    new_data, new_version, val_s, verpt_s, succ_s = lax.cond(
        jnp.any(is_valcas), _general, _fast, data, version)

    # --- per-lane results ---------------------------------------------------
    is_read = (s_kind == LOAD) | (s_kind == LL)
    vl_ok = s_clnk & (s_cslot == s_slot) & (s_cver == verpt_s)
    s_success = jnp.where(
        is_read | (s_kind == STORE), s_slot < n,
        jnp.where(s_kind == VALIDATE, vl_ok,
                  jnp.where(is_upd, succ_s, False)))
    s_value = jnp.where((s_kind != IDLE)[:, None], val_s,
                        jnp.zeros_like(val_s))

    # --- link context updates ----------------------------------------------
    is_ll = (s_kind == LL) & (s_slot < n)
    n_slot = jnp.where(is_ll, s_slot, s_cslot)
    n_ver = jnp.where(is_ll, verpt_s, s_cver)
    n_val = jnp.where(is_ll[:, None], val_s, ctx.value[order])
    n_lnk = jnp.where(is_ll, True,
                      jnp.where(s_kind == SC, False, s_clnk))
    new_ctx = LinkCtx(n_slot[inv], n_ver[inv], n_val[inv], n_lnk[inv])
    result = ApplyResult(s_value[inv], s_success[inv])

    # --- stats (the shared sorted-order definition) --------------------------
    stats = stats_on_sorted(n, s_slot, s_kind, succ_s)
    return new_data, new_version, new_ctx, result, stats


# ---------------------------------------------------------------------------
# Txn-group lane metadata: conflict arbitration for multi-lane transactions.
# ---------------------------------------------------------------------------

def arbitrate_groups(slot, group, eligible, *, n: int, n_groups: int):
    """The linearizer's lane-order rule lifted to whole lane GROUPS.

    A transaction (`repro.txn.mcas`) is a group of lanes that must commit
    all-or-nothing.  Within one batch the engine arbitrates single lanes by
    lane order (first eligible SC per cell wins); for groups the same rule
    becomes: the lowest-id eligible group claiming a cell wins that cell,
    and a group is a WINNER iff it wins every cell it claims.  Winners are
    therefore pairwise cell-disjoint, so a pure-SC commit batch of all their
    lanes resolves on the engine's one-round fast path with every SC
    succeeding — no descriptors, no helping.

    The lowest-id eligible group always wins all its cells, so arbitration
    guarantees progress (>= 1 group resolves per round).

    slot:     int32[p]  claimed cell per lane (out-of-range = unused lane)
    group:    int32[p]  owning group id per lane, in [0, n_groups)
    eligible: bool[p]   lane belongs to a group contending this round

    Returns bool[n_groups]: the winner mask.
    """
    slot = jnp.asarray(slot, jnp.int32)
    group = jnp.asarray(group, jnp.int32)
    in_range = (slot >= 0) & (slot < n)
    live = eligible & in_range
    claim = jnp.where(live, slot, n)
    gid = jnp.where(live, group, n_groups)
    # Lowest eligible group id per claimed cell (scatter-min).
    cell_min = jnp.full((n + 1,), n_groups, jnp.int32)
    cell_min = cell_min.at[claim].min(gid, mode="drop")
    lane_wins = cell_min[jnp.minimum(claim, n)] == group
    # A group wins iff ALL its live lanes win (scatter-AND via min).
    grp = jnp.ones((n_groups + 1,), jnp.int32)
    grp = grp.at[gid].min(lane_wins.astype(jnp.int32), mode="drop")
    return grp[:n_groups] > 0


# ---------------------------------------------------------------------------
# Round lowering: strategies may swap `linearize` for a fused kernel round.
# ---------------------------------------------------------------------------

def _engine_round():
    from repro.kernels import engine_round  # lazy: kernels import engine
    return engine_round


def round_for(spec: AtomicSpec, impl=None, mode: str | None = None):
    """The execution round for `spec`: the strategy's lowered kernel round
    (DESIGN.md §8) when it provides one and the engine-kernel mode allows
    it, else the pure-XLA `linearize`.  The returned callable has the exact
    `linearize` signature and is resolved at trace time (spec is static).

    Jitted callers must thread `mode` through as a static argument (see
    `_apply`) so a mid-process BIGATOMIC_ENGINE_KERNEL change can never hit
    a stale trace of the other engine."""
    mode, interpret = _engine_round().resolved_mode(mode)
    if mode == "off":
        return linearize
    if impl is None:
        impl = registry.get_strategy(spec.strategy)
    lowered = impl.lower_round(spec, mode=mode, interpret=interpret)
    return linearize if lowered is None else lowered


def canonicalize_ops(ops: OpBatch) -> OpBatch:
    """Coerce an op batch to the canonical dtypes (int32 kinds/slots, uint32
    words, no weak types) so equal-shaped batches can never retrace the
    jitted round (tests/test_engine_round.py asserts this with the
    `repro.analysis.tracing` counter)."""
    return OpBatch(jnp.asarray(ops.kind, jnp.int32),
                   jnp.asarray(ops.slot, jnp.int32),
                   jnp.asarray(ops.expected, WORD_DTYPE),
                   jnp.asarray(ops.desired, WORD_DTYPE))


def canonicalize_ctx(ctx: LinkCtx) -> LinkCtx:
    return LinkCtx(jnp.asarray(ctx.slot, jnp.int32),
                   jnp.asarray(ctx.version, jnp.uint32),
                   jnp.asarray(ctx.value, WORD_DTYPE),
                   jnp.asarray(ctx.linked, bool))


# ---------------------------------------------------------------------------
# The single public entry point: apply(spec, state, ops [, ctx]).
# ---------------------------------------------------------------------------

def check_kinds(kind, allowed, what: str) -> None:
    """Reject op kinds outside `allowed` when `kind` is concrete (traced
    kinds are the caller's contract — the oracle would raise on them)."""
    try:
        kind_np = np.asarray(kind)
    except Exception:
        return
    bad = np.setdiff1d(kind_np, np.asarray(allowed))
    if bad.size:
        raise ValueError(f"op kinds {bad.tolist()} are not {what} ops "
                         f"(allowed: {sorted(allowed)})")


def _apply_impl(spec: AtomicSpec, state, ops: OpBatch, ctx: LinkCtx | None,
                mode: str, telem=None):
    impl = registry.get_strategy(spec.strategy)
    if ctx is None:
        ctx = init_ctx(ops.p, spec.k)
    round_fn = round_for(spec, impl, mode)
    new_data, new_version, new_ctx, result, stats = round_fn(
        impl.engine_view(state), state.version, ctx, ops)
    new_state = impl.commit(state, new_data, new_version,
                            stats.n_updates, ops.p)
    traffic = impl.traffic(stats, spec.k, ops.p)
    if telem is None:
        # BIGATOMIC_OBS=off: None is an empty pytree, so this traces the
        # exact pre-observability program (tests/test_obs.py asserts it).
        return new_state, new_ctx, result, stats, traffic
    eligible, taken = _engine_round().path_counts(
        spec.n, ops, fused=round_fn is not linearize)
    telem = obs_telemetry.count_table(telem, spec.n, ops, result, stats,
                                      eligible=eligible, taken=taken)
    return new_state, new_ctx, result, stats, traffic, telem


# The engine-kernel mode rides the jit cache key, so flipping
# BIGATOMIC_ENGINE_KERNEL mid-process retraces instead of silently reusing
# the other engine's compiled round.
_apply = functools.partial(jax.jit,
                           static_argnames=("spec", "mode"))(_apply_impl)
# Donating twin: hands the state buffers to XLA so the round updates them in
# place instead of copying the table once per call.  Correct only when the
# caller treats the passed state as dead; `apply(donate=True)` routes here
# (off-CPU only — the CPU runtime cannot donate and would warn every call).
_apply_donated = functools.partial(
    jax.jit, static_argnames=("spec", "mode"),
    donate_argnums=(1,))(_apply_impl)


def apply(spec: AtomicSpec, state, ops: OpBatch, ctx: LinkCtx | None = None,
          *, donate: bool = False):
    """Linearize `ops` against the table; maintain the strategy's layout.

    `spec` is the only static argument; `state`, `ops` and `ctx` are pure
    pytrees, so this call composes with `jax.jit`, `lax.scan`, donation and
    `shard_map`.  `ctx` carries per-lane LL/SC links across batches; omit it
    for batches without LL/SC/VALIDATE lanes.  Hash kinds (FIND/INSERT/
    DELETE) belong to `cachehash.apply_hash`, not here.

    Op/ctx leaves are canonicalized (int32 kinds/slots, uint32 words) before
    dispatch, so differently-typed but equal-shaped batches reuse one trace.
    `donate=True` additionally donates the state buffers to the jitted
    round (one fewer full table copy per call); the passed `state` must not
    be reused afterwards.  Donation is skipped on CPU backends, which
    cannot donate.

    Returns (state', ctx', ApplyResult, ApplyStats, Traffic)."""
    check_kinds(ops.kind, TABLE_KINDS, "table")
    ops = canonicalize_ops(ops)
    if ctx is not None:
        ctx = canonicalize_ctx(ctx)
    mode = _engine_round().configured_mode()
    # Under BIGATOMIC_OBS=counters the global counter pytree rides the same
    # jit call as one extra argument/output (no extra dispatch); when off —
    # or when an outer jit owns this call — telem is None and the traced
    # program is byte-identical to the pre-observability one.
    telem = obs_telemetry.carry_in(state, ops.kind)
    fn = (_apply_donated if donate and jax.default_backend() != "cpu"
          else _apply)
    out = fn(spec, state, ops, ctx, mode, telem)
    if telem is not None:
        *out, telem = out
        obs_telemetry.carry_out(telem)
        return tuple(out)
    return out


class RoundHandle:
    """A dispatched-but-not-awaited engine round (DESIGN.md §9).

    JAX arrays are futures under async dispatch, so `apply_round` returns
    the moment the round is enqueued; the handle names the five outputs and
    lets an executor overlap the NEXT batch's host-side route/pack work with
    this round's device compute.  `state`/`ctx` may be chained into the next
    `apply_round` immediately (XLA sequences the data dependency); `wait()`
    blocks until every output buffer is resident."""

    __slots__ = ("state", "ctx", "result", "stats", "traffic")

    def __init__(self, state, ctx, result, stats, traffic):
        self.state = state
        self.ctx = ctx
        self.result = result
        self.stats = stats
        self.traffic = traffic

    def _leaves(self):
        return jax.tree_util.tree_leaves(
            (self.state, self.ctx, self.result, self.stats, self.traffic))

    def ready(self) -> bool:
        """True iff every output buffer is already resident (non-blocking;
        conservatively False if the runtime lacks `Array.is_ready`)."""
        return all(getattr(leaf, "is_ready", lambda: False)()
                   for leaf in self._leaves())

    def wait(self) -> "RoundHandle":
        jax.block_until_ready(self._leaves())
        return self


def apply_round(spec: AtomicSpec, state, ops: OpBatch,
                ctx: LinkCtx | None = None, *, donate: bool = False
                ) -> RoundHandle:
    """`apply` as an overlappable round: identical semantics, but the outputs
    come back wrapped in a `RoundHandle` the executor can hold in its
    in-flight window while it packs the next stream's batch."""
    return RoundHandle(*apply(spec, state, ops, ctx, donate=donate))


def init(spec: AtomicSpec, initial=None):
    """Build the initial `TableState` pytree for `spec`."""
    impl = registry.get_strategy(spec.strategy)
    data = (jnp.zeros((spec.n, spec.k), WORD_DTYPE) if initial is None
            else jnp.asarray(initial, WORD_DTYPE))
    if data.shape != (spec.n, spec.k):
        raise ValueError(f"initial shape {data.shape} != ({spec.n}, {spec.k})")
    return impl.init(spec.n, spec.k, spec.p_max, data)


@functools.partial(jax.jit, static_argnames=("spec",))
def _read(spec: AtomicSpec, state, slots, telem=None):
    impl = registry.get_strategy(spec.strategy)
    values, ok = impl.read(state, jnp.asarray(slots, jnp.int32))
    if telem is None:
        return values, ok
    return values, ok, obs_telemetry.count_read(telem, ok)


def read(spec: AtomicSpec, state, slots):
    """Honest per-strategy read protocol.  Returns (values[q, k], ok[q]).

    ok=False means the reader observed a torn/locked cell and must retry
    (blocking strategies only); lock-free strategies always return ok=True
    with a consistent value.  Under BIGATOMIC_OBS=counters the retry count
    accumulates into `obs` as `read.torn_retries` (same jitted call)."""
    telem = obs_telemetry.carry_in(state, slots)
    if telem is None:
        return _read(spec, state, slots)
    values, ok, telem = _read(spec, state, slots, telem)
    obs_telemetry.carry_out(telem)
    return values, ok


@functools.partial(jax.jit, static_argnames=("spec",))
def logical(spec: AtomicSpec, state):
    """The current logical value of every cell, derived from the layout."""
    return registry.get_strategy(spec.strategy).logical(state)
