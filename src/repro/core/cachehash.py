"""CacheHash — the paper's §4 separate-chaining hash table with the first
link *inlined* into the bucket array as a big atomic, plus the no-inline
`Chaining` baseline.

Bucket cell layout (a big atomic of ``cellw = 2 + vw`` words):
    [key, value(vw words), next]
``next`` codes: EMPTY (no first link — length-0 list), NULLP (no successor —
length-1 list), else an index into the chain-node pool.  The distinction
between EMPTY and NULLP is the paper's stolen flag bit.

Semantics (faithful to §4):
  find    — walk the chain, return the value if present.
  insert  — add-if-absent; new elements become the *inlined first link*, the
            previous first link is copied out to a fresh pool node.
  delete  — inline hit: the successor node (if any) is copied INTO the bucket
            and retired; chain hit: *path copying* — links ahead of the victim
            are copied to fresh nodes, the bucket's big-atomic cell is CAS'd
            to the new chain head, old links retired.

Chain nodes are written once and are immutable until retired (that is what
makes the scheme lock-free given a big-atomic bucket cell); only the bucket
cell mutates, which is exactly why it must be a big atomic.  The bucket array
is a `TableState` parameterized by the spec's strategy, and layout
maintenance dispatches through the strategy registry, so the Fig-3
comparison (CacheHash over seqlock / cached_me / cached_wf / indirect vs
Chaining) falls out of one implementation — and a strategy registered from
anywhere works here untouched.

v2 API (DESIGN.md §5): `apply_hash(spec, state, ops)` with a static
`HashSpec` and ops in the unified schema (`kind` ∈ FIND/INSERT/DELETE/IDLE,
`slot` = key bits, `desired[:, :vw]` = value; build with `make_hash_ops`).
`HashState` is a pure pytree.  The legacy `apply_hash_ops(...,
strategy=..., inline=..., vw=...)`, the 3-field `OpBatch` and the stateful
`CacheHash` wrapper survive as deprecation shims.

Batch execution mirrors the unified engine: ops are grouped by bucket and
serialized per bucket in lane order (`L = max ops per bucket` rounds); rounds
touch disjoint buckets so all scatters are conflict-free.  Pool slots come
from an explicit FIFO ring (head = alloc cursor, tail = free cursor), the
deterministic stand-in for the paper's hazard-pointer reclamation: a retired
node is reused only after all free slots ahead of it are consumed.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import bigatomic as ba
from repro.core import engine
from repro.core import semantics as sem
from repro.core.deprecation import warn_once
from repro.core.engine import _segmented_scan_max
from repro.core.specs import DEFAULT_STRATEGY, HashSpec

# Legacy kind numbering (v1).  The unified namespace uses engine.FIND /
# INSERT / DELETE; `_TO_UNIFIED` maps v1 batches onto it.
FIND = 0
INSERT = 1
DELETE = 2
IDLE = 3

_TO_UNIFIED = np.asarray(
    [engine.FIND, engine.INSERT, engine.DELETE, engine.IDLE], np.int32)

EMPTY = jnp.uint32(0xFFFFFFFF)   # bucket has no first link
NULLP = jnp.uint32(0xFFFFFFFE)   # link has no successor
_CODE_MIN = jnp.uint32(0xFFFFFFFE)  # next >= this <=> not a pool index


class HashState(NamedTuple):
    """Pure pytree: rides through `jax.jit` / `lax.scan` unchanged."""

    table: ba.TableState      # bucket cells [nb, cellw] (+ strategy fields)
    pool: jax.Array           # chain nodes [cap, 2+vw]
    free_ring: jax.Array      # FIFO ring of free pool slots
    ring_head: jax.Array      # uint32 alloc cursor (monotonic, used mod cap)
    ring_tail: jax.Array      # uint32 free cursor  (monotonic, used mod cap)
    count: jax.Array          # live elements


class HashResult(NamedTuple):
    found: jax.Array          # FIND: key present; INSERT/DELETE: op succeeded
    value: jax.Array          # FIND: the value (zeros if absent)
    overflow: jax.Array       # walk exceeded max_chain (should never fire)


class HashStats(NamedTuple):
    rounds: jax.Array         # bucket-contention serialization rounds
    chain_steps: jax.Array    # total dependent pool gathers (indirection cost)
    inline_hits: jax.Array    # live ops resolved at the inlined first link
    allocs: jax.Array
    frees: jax.Array


class OpBatch(NamedTuple):
    """Legacy 3-field hash batch (v1).  New code: `make_hash_ops`."""

    kind: jax.Array      # int32[q]  (v1 numbering)
    key: jax.Array       # uint32[q]
    value: jax.Array     # uint32[q, vw]


def make_hash_ops(kind, key, value=None, *, vw: int) -> engine.OpBatch:
    """Build a unified-schema hash batch: `slot` carries the uint32 key
    bit-pattern, `desired[:, :vw]` the value.  Kinds are the unified
    FIND/INSERT/DELETE/IDLE constants."""
    key = jnp.asarray(key, jnp.uint32).astype(jnp.int32)
    return engine.make_ops(kind, key, desired=value, k=vw)


def _to_unified(ops) -> engine.OpBatch:
    """Accept a legacy 3-field OpBatch or a unified batch; return unified."""
    if isinstance(ops, OpBatch) or hasattr(ops, "key"):
        kind = jnp.asarray(_TO_UNIFIED)[jnp.clip(ops.kind, 0, 3)]
        return make_hash_ops(kind, ops.key, ops.value,
                             vw=ops.value.shape[1])
    return ops


def hash_u32(key: jax.Array) -> jax.Array:
    """splitmix-style avalanche; buckets = hash & (nb-1)."""
    h = key.astype(jnp.uint32)
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    return h ^ (h >> 16)


def init_hash(spec: HashSpec) -> HashState:
    """Build the initial `HashState` pytree for `spec`."""
    nb, vw = spec.nb, spec.vw
    cellw = spec.cellw
    empty_cell = np.zeros((cellw,), np.uint32)
    empty_cell[-1] = 0xFFFFFFFF
    data = np.broadcast_to(empty_cell, (nb, cellw))
    table = ba.init(nb, cellw, spec.strategy, spec.p_max, initial=data)
    cap = spec.pool_cap
    pool = jnp.zeros((cap, 2 + vw), sem.WORD_DTYPE)
    return HashState(table, pool, jnp.arange(cap, dtype=jnp.int32),
                     jnp.uint32(0), jnp.uint32(cap), jnp.uint32(0))


def init(nb: int, vw: int, strategy, p_max: int,
         *, inline: bool = True, chain_factor: float = 2.0) -> HashState:
    """DEPRECATED shim: use `init_hash(HashSpec(...))`."""
    return init_hash(HashSpec(nb, vw, ba.strategy_name(strategy), p_max,
                              inline=inline, chain_factor=chain_factor))


# ---------------------------------------------------------------------------
# Sequential oracle (python dict) — defines the semantics.
# ---------------------------------------------------------------------------

def apply_reference(model: dict, ops, vw: int):
    ops = _to_unified(ops)
    kind = np.asarray(ops.kind)
    key = np.asarray(ops.slot).astype(np.uint32)
    value = np.asarray(ops.desired)[:, :vw]
    q = kind.shape[0]
    found = np.zeros(q, bool)
    out = np.zeros((q, vw), np.uint32)
    for i in range(q):
        k = int(key[i])
        if kind[i] == engine.FIND:
            if k in model:
                found[i] = True
                out[i] = model[k]
        elif kind[i] == engine.INSERT:
            if k not in model:        # add-if-absent (paper semantics)
                model[k] = value[i].copy()
                found[i] = True
        elif kind[i] == engine.DELETE:
            if k in model:
                del model[k]
                found[i] = True
    return model, HashResult(found, out, np.zeros(q, bool))


# ---------------------------------------------------------------------------
# Vectorized batched ops.
# ---------------------------------------------------------------------------

def apply_hash(spec: HashSpec, state: HashState, ops: engine.OpBatch):
    """Apply a batch of FIND/INSERT/DELETE ops, linearized in lane order.

    `spec` is the only static argument; `state` and `ops` are pure pytrees
    (ops in the unified schema — see `make_hash_ops`).

    Returns (new_state, HashResult, HashStats).
    """
    engine.check_kinds(ops.kind, engine.HASH_KINDS, "hash")
    return _apply_hash(spec, state, ops)


@functools.partial(jax.jit, static_argnames=("spec",))
def _apply_hash(spec: HashSpec, state: HashState, ops: engine.OpBatch):
    inline, vw, max_chain = spec.inline, spec.vw, spec.max_chain
    nb = state.table.version.shape[0]
    cap = state.pool.shape[0]
    q = ops.kind.shape[0]
    cellw = state.table.data.shape[1]
    cellw_pool = 2 + vw
    grab_n = min(q * max_chain, cap)   # per-round allocation upper bound

    u_key = ops.slot.astype(jnp.uint32)
    active = ops.kind != engine.IDLE
    bucket = jnp.where(
        active, (hash_u32(u_key) & jnp.uint32(nb - 1)).astype(jnp.int32), nb)
    order = jnp.argsort(bucket, stable=True)
    inv_order = jnp.argsort(order, stable=True)
    s_bucket = bucket[order]
    s_kind = ops.kind[order]
    s_key = u_key[order]
    s_value = ops.desired[order, :vw]

    idx = jnp.arange(q, dtype=jnp.int32)
    seg_start = jnp.concatenate([jnp.ones((1,), bool),
                                 s_bucket[1:] != s_bucket[:-1]])
    start_idx = _segmented_scan_max(jnp.where(seg_start, idx, -1), seg_start)
    rank = idx - start_idx
    n_rounds = jnp.where(jnp.any(active),
                         jnp.max(jnp.where(s_bucket < nb, rank, -1)) + 1, 0)

    lanes = jnp.arange(q, dtype=jnp.int32)

    def walk(data, pool, b_idx, key):
        """Vectorized bounded chain walk.  Returns per-lane info."""
        cell = data[jnp.minimum(b_idx, nb - 1)]
        if inline:
            c_key = cell[:, 0]
            c_next = cell[:, cellw - 1]
            is_empty = c_next == EMPTY
            found0 = (~is_empty) & (c_key == key)
            head = jnp.where(found0 | is_empty, NULLP, c_next)
        else:
            c_next = cell[:, 0]
            is_empty = c_next == EMPTY
            found0 = jnp.zeros_like(is_empty)
            head = jnp.where(is_empty, NULLP, c_next)

        vis = jnp.full((q, max_chain), -1, jnp.int32)
        found_depth = jnp.where(found0, 0, -1)
        cur = head
        steps = jnp.zeros((q,), jnp.int32)
        for j in range(max_chain):
            is_node = (cur < _CODE_MIN) & (found_depth < 0)
            nidx = jnp.where(is_node, cur.astype(jnp.int32), 0)
            nkey = pool[nidx, 0]
            nnext = pool[nidx, cellw_pool - 1]
            hit = is_node & (nkey == key)
            found_depth = jnp.where(hit, j + 1, found_depth)
            vis = vis.at[:, j].set(jnp.where(is_node, cur.astype(jnp.int32), -1))
            steps = steps + is_node.astype(jnp.int32)
            cur = jnp.where(is_node & ~hit, nnext, NULLP)
        overflow = (cur < _CODE_MIN) & (found_depth < 0)
        return dict(cell=cell, is_empty=is_empty, found_depth=found_depth,
                    vis=vis, steps=steps, overflow=overflow)

    def found_value(w, pool):
        """(found node index, found value) from a walk result: the inlined
        first link when found_depth == 0, else the pool node at that depth.
        THE one definition of FIND value extraction, shared by the round
        loop and the probe fast path."""
        fd = w["found_depth"]
        node_at_fd = w["vis"][lanes, jnp.clip(fd - 1, 0, max_chain - 1)]
        if inline:
            inline_val = w["cell"][:, 1:1 + vw]
        else:
            inline_val = jnp.zeros((q, vw), sem.WORD_DTYPE)
        pool_val = pool[jnp.maximum(node_at_fd, 0), 1:1 + vw]
        return node_at_fd, jnp.where((fd == 0)[:, None], inline_val,
                                     pool_val)

    def round_body(carry):
        (t, data, ver, pool, ring, head, tail, count,
         r_found, r_value, r_over, chain_steps, inline_hits,
         allocs, frees) = carry
        live = active[order] & (rank == t) & (s_bucket < nb)
        w = walk(data, pool, s_bucket, s_key)
        fd = w["found_depth"]
        vis = w["vis"]
        cell = w["cell"]
        is_empty = w["is_empty"]
        found = fd >= 0
        chain_steps = chain_steps + jnp.sum(jnp.where(live, w["steps"], 0))
        inline_hits = inline_hits + jnp.sum(
            (live & ((fd == 0) | is_empty)).astype(jnp.int32))

        # ---- FIND ----------------------------------------------------------
        f_live = live & (s_kind == engine.FIND)
        node_at_fd, fval = found_value(w, pool)
        r_value = jnp.where((f_live & found)[:, None], fval, r_value)
        r_found = jnp.where(f_live, found, r_found)

        # ---- allocation plan (conflict-free: disjoint buckets) -------------
        i_live = live & (s_kind == engine.INSERT) & ~found & ~w["overflow"]
        d_live = live & (s_kind == engine.DELETE) & found
        if inline:
            ins_need = jnp.where(i_live & ~is_empty, 1, 0)
        else:
            ins_need = jnp.where(i_live, 1, 0)
        del_need = jnp.where(d_live & (fd >= 1), jnp.maximum(fd - 1, 0), 0)
        need = (ins_need + del_need).astype(jnp.int32)
        off = jnp.cumsum(need) - need
        total = jnp.sum(need).astype(jnp.uint32)

        ranks = jnp.arange(grab_n, dtype=jnp.uint32)
        grab = ring[((head + ranks) % jnp.uint32(cap)).astype(jnp.int32)]
        slot_at = lambda o: grab[jnp.clip(o, 0, grab_n - 1)]
        head_new = head + total
        allocs = allocs + total

        # ---- INSERT ---------------------------------------------------------
        if inline:
            disp = i_live & ~is_empty          # displaced first link
            dst = jnp.where(disp, slot_at(off), cap)
            pool = pool.at[dst].set(cell, mode="drop")
            new_next = jnp.where(is_empty, NULLP,
                                 slot_at(off).astype(jnp.uint32))
            new_cell = jnp.concatenate(
                [s_key[:, None], s_value, new_next[:, None]], axis=1)
            w_idx = jnp.where(i_live, s_bucket, nb)
            data = data.at[w_idx].set(new_cell, mode="drop")
        else:
            dst = jnp.where(i_live, slot_at(off), cap)
            old_head = jnp.where(is_empty, NULLP, cell[:, 0])
            node = jnp.concatenate(
                [s_key[:, None], s_value, old_head[:, None]], axis=1)
            pool = pool.at[dst].set(node, mode="drop")
            w_idx = jnp.where(i_live, s_bucket, nb)
            data = data.at[w_idx, 0].set(slot_at(off).astype(jnp.uint32),
                                         mode="drop")
        r_found = jnp.where(live & (s_kind == engine.INSERT), i_live, r_found)

        # ---- DELETE ---------------------------------------------------------
        # Case A (inline only): victim is the inlined first link (fd == 0).
        freedA = jnp.full((q,), -1, jnp.int32)
        if inline:
            a_live = d_live & (fd == 0)
            succ = cell[:, cellw - 1]
            has_succ = succ < _CODE_MIN
            empty_cell = jnp.zeros((cellw,), sem.WORD_DTYPE).at[-1].set(EMPTY)
            w_idx = jnp.where(a_live & ~has_succ, s_bucket, nb)
            data = data.at[w_idx].set(empty_cell, mode="drop")
            succ_i = jnp.where(has_succ, succ.astype(jnp.int32), 0)
            w_idx = jnp.where(a_live & has_succ, s_bucket, nb)
            data = data.at[w_idx].set(pool[succ_i], mode="drop")
            freedA = jnp.where(a_live & has_succ, succ_i, freedA)

        # Case B: victim at chain depth fd >= 1 -> path copy.
        b_live = d_live & (fd >= 1)
        victim = node_at_fd
        tail_code = pool[jnp.maximum(victim, 0), cellw_pool - 1]
        ncopies = jnp.where(b_live, jnp.maximum(fd - 1, 0), 0)
        copy_base = off + ins_need
        new_head_code = jnp.where(
            ncopies > 0, slot_at(copy_base).astype(jnp.uint32), tail_code)
        for j in range(max_chain - 1):
            c_live = b_live & (j < ncopies)
            src = vis[:, j]                      # original node at depth j+1
            nxt = jnp.where(j + 1 < ncopies,
                            slot_at(copy_base + j + 1).astype(jnp.uint32),
                            tail_code)
            row = pool[jnp.maximum(src, 0)]
            row = jnp.concatenate([row[:, :cellw_pool - 1], nxt[:, None]],
                                  axis=1)
            dstj = jnp.where(c_live, slot_at(copy_base + j), cap)
            pool = pool.at[dstj].set(row, mode="drop")
        if inline:
            w_idx = jnp.where(b_live, s_bucket, nb)
            data = data.at[w_idx, cellw - 1].set(new_head_code, mode="drop")
        else:
            w_idx = jnp.where(b_live, s_bucket, nb)
            hcode = jnp.where(new_head_code == NULLP, EMPTY, new_head_code)
            data = data.at[w_idx, 0].set(hcode, mode="drop")
        r_found = jnp.where(live & (s_kind == engine.DELETE), d_live, r_found)
        r_over = jnp.where(live, w["overflow"], r_over)

        # ---- retire: case A successor, case B originals(1..fd-1) + victim --
        n_retired = (jnp.where(b_live, fd, 0)
                     + jnp.where(freedA >= 0, 1, 0)).astype(jnp.int32)
        roff = jnp.cumsum(n_retired) - n_retired
        rtotal = jnp.sum(n_retired).astype(jnp.uint32)
        for j in range(max_chain):
            srcB = vis[:, min(j, max_chain - 1)]
            src = jnp.where(b_live, srcB,
                            jnp.where(jnp.int32(j) == 0, freedA, -1))
            r_live = (j < n_retired) & (src >= 0)
            pos = ((tail + (roff + j).astype(jnp.uint32)) % jnp.uint32(cap)
                   ).astype(jnp.int32)
            ring = ring.at[jnp.where(r_live, pos, cap)].set(src, mode="drop")
        tail_new = tail + rtotal
        frees = frees + rtotal

        count = (count + jnp.sum(i_live.astype(jnp.uint32))
                 - jnp.sum(d_live.astype(jnp.uint32)))
        modified = i_live | d_live
        ver = ver.at[jnp.where(modified, s_bucket, nb)].add(
            jnp.uint32(2), mode="drop")
        return (t + 1, data, ver, pool, ring, head_new, tail_new, count,
                r_found, r_value, r_over, chain_steps, inline_hits,
                allocs, frees)

    init_carry = (jnp.int32(0), state.table.data, state.table.version,
                  state.pool, state.free_ring, state.ring_head,
                  state.ring_tail, state.count,
                  jnp.zeros((q,), bool), jnp.zeros((q, vw), sem.WORD_DTYPE),
                  jnp.zeros((q,), bool), jnp.int32(0), jnp.int32(0),
                  jnp.uint32(0), jnp.uint32(0))

    def _mutating():
        """The full path: L = max-ops-per-bucket serialization rounds."""
        out = lax.while_loop(lambda c: c[0] < n_rounds, round_body,
                             init_carry)
        return out[1:]

    def _find_only():
        """The probe fast path (the hash analogue of the engine's fast
        round, DESIGN.md §8): FINDs commute even on the same bucket, so a
        mutation-free batch is ONE chain walk over the live table — no
        round loop, no alloc/retire scatter machinery, state untouched."""
        w = walk(state.table.data, state.pool, s_bucket, s_key)
        fd = w["found_depth"]
        found = fd >= 0
        live = active[order] & (s_bucket < nb)
        f_live = live & (s_kind == engine.FIND)
        _, fval = found_value(w, state.pool)
        r_value = jnp.where((f_live & found)[:, None], fval,
                            jnp.zeros((q, vw), sem.WORD_DTYPE))
        chain_steps = jnp.sum(jnp.where(live, w["steps"], 0))
        inline_hits = jnp.sum(
            (live & ((fd == 0) | w["is_empty"])).astype(jnp.int32))
        return (state.table.data, state.table.version, state.pool,
                state.free_ring, state.ring_head, state.ring_tail,
                state.count, f_live & found, r_value, live & w["overflow"],
                chain_steps, inline_hits, jnp.uint32(0), jnp.uint32(0))

    has_mut = jnp.any(active & ((ops.kind == engine.INSERT)
                                | (ops.kind == engine.DELETE)))
    (data, ver, pool, ring, head, tail, count,
     r_found, r_value, r_over, chain_steps, inline_hits, allocs, frees) = \
        lax.cond(has_mut, _mutating, _find_only)

    n_upd = ((ver - state.table.version) // 2).sum().astype(jnp.int32)
    table = ba.commit_layout(state.table, data, ver, n_upd,
                             spec.strategy, min(q, nb))
    new_state = HashState(table, pool, ring, head, tail, count)
    result = HashResult(r_found[inv_order], r_value[inv_order],
                        r_over[inv_order])
    stats = HashStats(n_rounds, chain_steps, inline_hits, allocs, frees)
    return new_state, result, stats


def apply_hash_ops(state: HashState, ops, *, strategy: str,
                   inline: bool, vw: int, max_chain: int = 8):
    """DEPRECATED shim: use `apply_hash(HashSpec(...), state, ops)`.
    Warns `DeprecationWarning` once per process."""
    warn_once("core.cachehash.apply_hash_ops",
              "cachehash.apply_hash(HashSpec(...), state, ops)")
    nb = state.table.version.shape[0]
    spec = HashSpec(nb, vw, ba.strategy_name(strategy), inline=inline,
                    max_chain=max_chain)
    return apply_hash(spec, state, _to_unified(ops))


# ---------------------------------------------------------------------------
# Host-side inspection (tests): enumerate the table's contents.
# ---------------------------------------------------------------------------

def items(state: HashState, *, inline: bool, vw: int) -> dict:
    data = np.asarray(state.table.data)
    pool = np.asarray(state.pool)
    nb = data.shape[0]
    out = {}
    for b in range(nb):
        if inline:
            nxt = data[b, -1]
            if nxt == np.uint32(0xFFFFFFFF):
                continue
            out[int(data[b, 0])] = data[b, 1:1 + vw].copy()
            cur = nxt
        else:
            cur = data[b, 0]
        guard = 0
        while cur < np.uint32(0xFFFFFFFE) and guard < 10_000:
            row = pool[int(cur)]
            out[int(row[0])] = row[1:1 + vw].copy()
            cur = row[-1]
            guard += 1
    return out


def free_slots_available(state: HashState) -> int:
    """Free pool slots remaining (tail - head in the FIFO ring)."""
    return int((int(state.ring_tail) - int(state.ring_head)) % (1 << 32))


class CacheHash:
    """Stateful DEPRECATION shim.  strategy + inline select the paper's
    variants: CacheHash = inline=True over {seqlock, cached_me, cached_wf,
    indirect}; Chaining baseline = inline=False.  New code should hold a
    `HashSpec` + `HashState` and call `apply_hash` directly."""

    def __init__(self, nb: int | None = None, vw: int = 1,
                 strategy: str | None = None, p_max: int = 1024,
                 *, inline: bool = True, max_chain: int = 8,
                 chain_factor: float = 2.0, spec: HashSpec | None = None):
        if spec is None:
            if nb is None:
                raise ValueError("pass either nb or spec")
            spec = HashSpec(nb, vw,
                            ba.strategy_name(strategy) if strategy is not None
                            else DEFAULT_STRATEGY,
                            p_max, inline=inline, max_chain=max_chain,
                            chain_factor=chain_factor)
        self.spec = spec
        self.state = init_hash(spec)

    @property
    def nb(self) -> int:
        return self.spec.nb

    @property
    def vw(self) -> int:
        return self.spec.vw

    @property
    def strategy(self) -> str:
        return self.spec.strategy

    @property
    def inline(self) -> bool:
        return self.spec.inline

    @property
    def max_chain(self) -> int:
        return self.spec.max_chain

    def apply(self, ops):
        self.state, result, stats = apply_hash(self.spec, self.state,
                                               _to_unified(ops))
        return result, stats

    def find(self, keys):
        return self.apply(self._ops(engine.FIND, keys))

    def insert(self, keys, values):
        q = len(keys)
        values = jnp.asarray(values, sem.WORD_DTYPE).reshape(q, self.vw)
        return self.apply(make_hash_ops(
            jnp.full((q,), engine.INSERT, jnp.int32), keys, values,
            vw=self.vw))

    def delete(self, keys):
        return self.apply(self._ops(engine.DELETE, keys))

    def _ops(self, kind, keys):
        q = len(keys)
        return make_hash_ops(jnp.full((q,), kind, jnp.int32), keys,
                             vw=self.vw)

    def items(self) -> dict:
        return items(self.state, inline=self.inline, vw=self.vw)
