"""Static specs: the ONLY static argument the v2 big-atomic API takes.

A spec is a small frozen (hashable) dataclass describing the *shape* of a
structure — table size, words per cell, strategy name, concurrency bound.
Every `apply`-style entry point is `fn(spec, state, ops)` with `spec` the
sole `jax.jit` static argument; the state is a pure pytree that flows
through `jit`, `lax.scan`, donation and `shard_map` unchanged.  Equal specs
hash equal, so rebuilding a spec per call never retraces.

`DEFAULT_STRATEGY` honours the `BIGATOMIC_STRATEGY` environment variable so
CI can run the whole tier-1 suite as a strategy matrix (one process per
layout) without touching test code.
"""

from __future__ import annotations

import dataclasses
import os

DEFAULT_STRATEGY = os.environ.get("BIGATOMIC_STRATEGY", "cached_me")

# Queue cell indices (the ring layout prefix; see repro.sync.queue).
QUEUE_HEAD, QUEUE_TAIL, QUEUE_SLOT0 = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class AtomicSpec:
    """A table of `n` big atomics of `k` words under `strategy`, sized for
    at most `p_max` concurrent lanes (node-pool / SMR in-flight bound)."""

    n: int
    k: int
    strategy: str = DEFAULT_STRATEGY
    p_max: int = 1024

    def __post_init__(self):
        if self.n <= 0 or self.k <= 0 or self.p_max <= 0:
            raise ValueError(f"AtomicSpec sizes must be positive: {self}")
        if not isinstance(self.strategy, str) or not self.strategy:
            raise ValueError(f"strategy must be a registry name: {self}")


@dataclasses.dataclass(frozen=True)
class HashSpec:
    """A CacheHash of `nb` buckets holding `vw`-word values.

    inline=True is the paper's CacheHash (first link inlined into the bucket
    big atomic); inline=False is the Chaining baseline.  The bucket array is
    an `AtomicSpec(nb, cellw, strategy, p_max)` table (`cell_spec()`)."""

    nb: int
    vw: int = 1
    strategy: str = DEFAULT_STRATEGY
    p_max: int = 1024
    inline: bool = True
    max_chain: int = 8
    chain_factor: float = 2.0

    def __post_init__(self):
        if self.nb & (self.nb - 1) != 0:
            raise ValueError(f"nb must be a power of two: {self.nb}")
        if self.vw <= 0 or self.max_chain <= 0:
            raise ValueError(f"HashSpec sizes must be positive: {self}")

    @property
    def cellw(self) -> int:
        return (2 + self.vw) if self.inline else 1

    @property
    def pool_cap(self) -> int:
        return int(self.nb * self.chain_factor) + 2 * self.p_max

    def cell_spec(self) -> AtomicSpec:
        return AtomicSpec(self.nb, self.cellw, self.strategy, self.p_max)


@dataclasses.dataclass(frozen=True)
class VersionSpec:
    """A table of `n` version lists: per-slot bounded chains of `k`-word
    timestamped versions (the paper's version-list application).

    The newest version of every slot lives INLINE in a big-atomic head cell
    of `cellw = k + 2` words — [value(k), ts, prev] — on an ordinary
    `AtomicSpec` table (`head_spec()`), so head updates ride the unified
    engine and every registered strategy.  Older versions sit in a per-slot
    ring of `depth - 1` immutable pool nodes; a node is overwritten only
    after `depth - 1` further publishes of its slot, which bounds every
    chain to the `depth` newest versions (reads past that report ok=False —
    honesty, not silence)."""

    n: int
    k: int
    depth: int = 4
    strategy: str = DEFAULT_STRATEGY
    p_max: int = 256

    def __post_init__(self):
        if self.n <= 0 or self.k <= 0 or self.p_max <= 0:
            raise ValueError(f"VersionSpec sizes must be positive: {self}")
        if self.depth < 2:
            raise ValueError(f"depth must be >= 2 (inline head + >= 1 "
                             f"pooled version): {self}")

    @property
    def cellw(self) -> int:
        return self.k + 2            # [value(k), ts, prev]

    @property
    def ring_depth(self) -> int:
        return self.depth - 1        # pooled (non-inline) versions per slot

    def head_spec(self) -> AtomicSpec:
        return AtomicSpec(self.n, self.cellw, self.strategy, self.p_max)


@dataclasses.dataclass(frozen=True)
class QueueSpec:
    """A bounded MPMC ticket-ring of `capacity` slots whose head, tail and
    slot cells are `k`-word big atomics (1 seq word + k-1 payload words)."""

    capacity: int
    k: int = 2
    strategy: str = DEFAULT_STRATEGY
    p_max: int = 64

    def __post_init__(self):
        if self.capacity < 2:
            raise ValueError("capacity must be >= 2 (seq tags are ambiguous "
                             "for a 1-slot ring)")
        if self.k < 2:
            raise ValueError("k must be >= 2 (seq word + >=1 payload word)")

    def table_spec(self) -> AtomicSpec:
        return AtomicSpec(QUEUE_SLOT0 + self.capacity, self.k, self.strategy,
                          self.p_max)
