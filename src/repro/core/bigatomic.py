"""Big-atomic tables — v1 compatibility layer over the v2 `repro.atomics` API.

The paper's four lock-free strategies (plus the SIMPLOCK / PLAIN controls)
now live behind the strategy registry: layouts are `StrategyImpl`s in
`repro.core.strategies`, linearization is the unified engine in
`repro.core.engine`, and the canonical entry point is

    repro.atomics.apply(spec, state, ops [, ctx])

with `AtomicSpec` the only static argument (see DESIGN.md §5 for the
migration table).  This module keeps the v1 surface — `init` / `logical` /
`apply_ops` / `read_protocol` / `commit_layout` / `begin_update` /
`memory_bytes` and the stateful `BigAtomicTable` wrapper — as thin shims so
existing callers and the tier-1 suite keep working; the old five if/elif
strategy chains are gone, every path dispatches through the registry, so a
strategy registered from *anywhere* works here too.
"""

from __future__ import annotations

import enum

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core import semantics as sem
from repro.core.deprecation import warn_once
from repro.core.layout import (  # noqa: F401  (re-exports: v1 import surface)
    NULL, TableState, Traffic, WORD_BYTES, state_nbytes,
)
from repro.core.registry import get_strategy
from repro.core.specs import DEFAULT_STRATEGY, AtomicSpec


class Strategy(str, enum.Enum):
    """The built-in layouts (legacy enum).  The v2 API uses plain registry
    names so third-party strategies are first-class; `strategy_name` accepts
    both."""

    SEQLOCK = "seqlock"
    INDIRECT = "indirect"
    CACHED_WF = "cached_wf"
    CACHED_ME = "cached_me"
    SIMPLOCK = "simplock"
    PLAIN = "plain"


def strategy_name(strategy) -> str:
    """Normalize a Strategy enum / string to its registry name."""
    return strategy.value if isinstance(strategy, Strategy) else str(strategy)


def _spec(state: TableState, strategy, k: int | None = None,
          p_max: int = 1024) -> AtomicSpec:
    n = state.version.shape[0]
    k = state.data.shape[1] if k is None else k
    return AtomicSpec(n, k, strategy_name(strategy), p_max)


def init(n: int, k: int, strategy, p_max: int,
         initial: np.ndarray | None = None) -> TableState:
    """Build the initial state for a table of n cells × k words."""
    return engine.init(AtomicSpec(n, k, strategy_name(strategy), p_max),
                       initial)


def logical(state: TableState, strategy) -> jax.Array:
    """The current logical value of every cell, derived from the layout."""
    return get_strategy(strategy_name(strategy)).logical(state)


def commit_layout(state: TableState, new_data: jax.Array,
                  new_version: jax.Array, n_updates: jax.Array,
                  strategy, p: int) -> TableState:
    """Reconcile a strategy's layout after the logical values have advanced
    (shared by the unified engine and by CacheHash's bucket table)."""
    return get_strategy(strategy_name(strategy)).commit(
        state, new_data, new_version, n_updates, p)


def _traffic_model(strategy, stats: sem.ApplyStats, k: int, p: int):
    """Analytic HBM bytes + dependency depth per batch (roofline inputs)."""
    return get_strategy(strategy_name(strategy)).traffic(stats, k, p)


def apply_ops(state: TableState, ops: sem.OpBatch, *, strategy: str, k: int):
    """DEPRECATED shim: use `repro.atomics.apply(spec, state, ops)`.
    Warns `DeprecationWarning` once per process.

    Returns (new_state, ApplyResult, ApplyStats, Traffic)."""
    warn_once("core.bigatomic.apply_ops",
              "repro.atomics.apply(spec, state, ops)")
    new_state, _, result, stats, traffic = engine.apply(
        _spec(state, strategy, k), state, ops)
    return new_state, result, stats, traffic


def read_protocol(state: TableState, slots: jax.Array, *, strategy: str):
    """Read cells using ONLY the strategy's layout fields, exactly as the
    paper's load would.  Returns (values[q,k], ok[q]).

    ok=False means the reader is *blocked* (seqlock torn / simplock held) and
    would have to retry — the lock-based failure mode under oversubscription.
    Lock-free strategies always return ok=True with a consistent value.
    PLAIN returns whatever bytes are there (possibly torn) with ok=True.
    """
    return engine.read(_spec(state, strategy), state, slots)


def begin_update(state: TableState, slot: int, new_value: np.ndarray,
                 *, strategy: str, torn_words: int | None = None) -> TableState:
    """Freeze a writer at its most vulnerable point (mid-cache-copy), exactly
    as oversubscription deschedules a lock-holder in the paper.

    SEQLOCK:   version odd, cache half-written               -> readers blocked.
    SIMPLOCK:  lock held, cache half-written                 -> readers blocked.
    INDIRECT:  new node written, pointer NOT yet swung       -> readers see OLD value.
    CACHED_WF: backup installed+marked, cache half-written   -> readers see NEW value.
    CACHED_ME: backup installed (non-null), cache half-torn  -> readers see NEW value.
    PLAIN:     cache half-written, no protocol               -> readers corrupt.
    """
    k = state.data.shape[1] if state.data.size else state.pool.shape[1]
    torn = k // 2 if torn_words is None else torn_words
    new_value = jnp.asarray(new_value, sem.WORD_DTYPE)
    return get_strategy(strategy_name(strategy)).begin_update(
        state, slot, new_value, torn)


def memory_bytes(n: int, k: int, p: int, strategy) -> int:
    """Exact bytes of the layout, matching the paper's Table 1 / §5.5 forms."""
    return get_strategy(strategy_name(strategy)).memory_bytes(n, k, p)


class BigAtomicTable:
    """Thin stateful DEPRECATION shim over `repro.atomics` — new code should
    hold an `AtomicSpec` + `TableState` and call `atomics.apply` directly."""

    def __init__(self, n: int, k: int, strategy=None,
                 p_max: int = 1024, initial: np.ndarray | None = None):
        name = strategy_name(strategy) if strategy is not None \
            else DEFAULT_STRATEGY
        self.spec = AtomicSpec(n, k, name, p_max)
        self.state = engine.init(self.spec, initial)

    # -- v1 attribute surface ------------------------------------------------

    @property
    def n(self) -> int:
        return self.spec.n

    @property
    def k(self) -> int:
        return self.spec.k

    @property
    def p_max(self) -> int:
        return self.spec.p_max

    @property
    def strategy(self) -> str:
        return self.spec.strategy

    # -- ops (all construction routes through the checked make_ops family) ---

    def apply(self, ops: sem.OpBatch):
        self.state, _, result, stats, traffic = engine.apply(
            self.spec, self.state, ops)
        return result, stats, traffic

    def load(self, slots, *, return_ok: bool = False):
        """Honest per-strategy read of `slots`.

        Returns values[q, k]; with `return_ok=True`, returns (values, ok).

        Torn-read/retry contract: `ok[i]` is False when the strategy's
        reader protocol *blocked* — a SEQLOCK cell observed mid-update (torn
        version check) or a SIMPLOCK cell whose lock is held — in which case
        `values[i]` is NOT a linearizable snapshot and the caller must retry
        the read (the paper's oversubscription failure mode).  The four
        lock-free strategies always return ok=True with a consistent value;
        PLAIN returns ok=True even for torn bytes (negative control).  The
        default `return_ok=False` form is only safe on lock-free strategies
        and asserts nothing — prefer `return_ok=True` anywhere a blocking
        strategy may be in play.
        """
        vals, ok = engine.read(self.spec, self.state,
                               jnp.asarray(slots, jnp.int32))
        return (vals, ok) if return_ok else vals

    def store(self, slots, values):
        return self.apply(engine.stores(slots, values, k=self.k))

    def cas(self, slots, expected, desired):
        return self.apply(engine.cas_ops(slots, expected, desired, k=self.k))

    def logical(self) -> jax.Array:
        return engine.logical(self.spec, self.state)

    def memory_bytes(self) -> int:
        return memory_bytes(self.n, self.k, self.p_max, self.strategy)
