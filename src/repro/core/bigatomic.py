"""Big-atomic tables: the paper's four strategies as real memory layouts.

Every strategy provides the *same* linearizable batch semantics (delegated to
`semantics.apply_batch`, property-tested against the sequential oracle) but a
*different* memory layout, reader protocol, and traffic profile:

  SEQLOCK    data[n,k] + ver[n].            1 gather/load; blocking on torn state.
  INDIRECT   ptr[n] -> pool[n+2p, k].       2 *dependent* gathers per load; never blocks.
  CACHED_WF  cache[n,k] + ver[n] + bptr[n] -> pool[n+2p,k].  1 gather fast path,
             backup fallback on race; never blocks.  Space 2nk + O(pk).
  CACHED_ME  cache[n,k] + ver[n] + bptr[n](tagged null) -> pool[3p,k].  1 gather
             fast path; backup only *during* a race; space nk + O(pk).
  SIMPLOCK   data[n,k] + lock[n].           lock RMW on every op; blocks readers.
  PLAIN      data[n,k], no protocol.        negative control: returns torn data.

The reader protocol (`read_protocol`) is honest: it computes its answer only
from layout fields, and the torn-state simulator (`begin_update`) freezes a
writer at its most vulnerable point so tests can verify which strategies
detect (seqlock), tolerate (indirect/cached), or corrupt (plain).

Node reclamation uses a FIFO ring of free slots — the deterministic analogue
of the paper's hazard-pointer/private-slab schemes: a retired node is reused
only after every other free slot has been consumed, giving the same O(p·k)
in-flight bound without a scheduler adversary (see DESIGN.md §2).
"""

from __future__ import annotations

import enum
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import semantics as sem

WORD_BYTES = 4  # uint32 words
NULL = jnp.int32(-1)


class Strategy(str, enum.Enum):
    SEQLOCK = "seqlock"
    INDIRECT = "indirect"
    CACHED_WF = "cached_wf"
    CACHED_ME = "cached_me"
    SIMPLOCK = "simplock"
    PLAIN = "plain"


class TableState(NamedTuple):
    """Unified pytree; unused fields are size-0 arrays for lean strategies.

    data:      word[n, k]  inline cache / value array (INDIRECT: engine shadow,
               not part of the logical layout — reads never touch it).
    version:   uint32[n]   seqlock version (even = unlocked).
    bptr:      int32[n]    backup / indirect node index; -1 null; for
               CACHED_ME, -(tag+2) encodes a *tagged* null (paper §3.2).
    mark:      bool[n]     CACHED_WF invalid-mark on the backup pointer.
    lock:      uint32[n]   SIMPLOCK lock word (0 = free).
    pool:      word[m, k]  node pool.
    free_ring: int32[m]    FIFO ring of free node indices.
    ring_head: uint32[]    next allocation position (mod ring size).
    alloc_gen: uint32[]    total allocations ever (reclamation generation).
    """

    data: jax.Array
    version: jax.Array
    bptr: jax.Array
    mark: jax.Array
    lock: jax.Array
    pool: jax.Array
    free_ring: jax.Array
    ring_head: jax.Array
    alloc_gen: jax.Array


class Traffic(NamedTuple):
    """Analytic HBM traffic for one batch (TPU roofline inputs).

    bytes_read / bytes_written: modeled HBM bytes.
    dep_chains: number of *dependent* gather rounds on the critical path
                (1 = fully pipelineable, 2 = pointer chase).
    rmw_ops:    single-word atomic RMWs (CAS/lock) — contention proxy.
    """

    bytes_read: jax.Array
    bytes_written: jax.Array
    dep_chains: jax.Array
    rmw_ops: jax.Array


def _empty(dtype, shape=(0,)):
    return jnp.zeros(shape, dtype)


def init(n: int, k: int, strategy: Strategy, p_max: int,
         initial: np.ndarray | None = None) -> TableState:
    """Build the initial state for a table of n cells × k words."""
    strategy = Strategy(strategy)
    data = jnp.zeros((n, k), sem.WORD_DTYPE) if initial is None else jnp.asarray(
        initial, sem.WORD_DTYPE)
    version = jnp.zeros((n,), jnp.uint32)
    if strategy in (Strategy.SEQLOCK, Strategy.PLAIN):
        return TableState(data, version, _empty(jnp.int32), _empty(bool),
                          _empty(jnp.uint32), _empty(sem.WORD_DTYPE, (0, k)),
                          _empty(jnp.int32), jnp.uint32(0), jnp.uint32(0))
    if strategy == Strategy.SIMPLOCK:
        return TableState(data, version, _empty(jnp.int32), _empty(bool),
                          jnp.zeros((n,), jnp.uint32),
                          _empty(sem.WORD_DTYPE, (0, k)),
                          _empty(jnp.int32), jnp.uint32(0), jnp.uint32(0))
    if strategy in (Strategy.INDIRECT, Strategy.CACHED_WF):
        # n installed nodes + 2p slack (SMR in-flight bound).
        m = n + 2 * p_max
        pool = jnp.zeros((m, k), sem.WORD_DTYPE)
        pool = pool.at[:n].set(data)
        bptr = jnp.arange(n, dtype=jnp.int32)           # cell i -> node i
        free_ring = jnp.concatenate(
            [jnp.arange(n, m, dtype=jnp.int32),
             jnp.full((n,), NULL)])                      # slots occupied by live nodes
        mark = jnp.zeros((n,), bool) if strategy == Strategy.CACHED_WF else _empty(bool)
        return TableState(data, version, bptr, mark, _empty(jnp.uint32),
                          pool, free_ring, jnp.uint32(0), jnp.uint32(0))
    if strategy == Strategy.CACHED_ME:
        m = max(3 * p_max, 1)
        pool = jnp.zeros((m, k), sem.WORD_DTYPE)
        bptr = jnp.full((n,), NULL)                      # null: cache is live
        free_ring = jnp.arange(m, dtype=jnp.int32)
        return TableState(data, version, bptr, mark=_empty(bool),
                          lock=_empty(jnp.uint32), pool=pool,
                          free_ring=free_ring, ring_head=jnp.uint32(0),
                          alloc_gen=jnp.uint32(0))
    raise ValueError(strategy)


def logical(state: TableState, strategy: Strategy) -> jax.Array:
    """The current logical value of every cell, derived from the layout."""
    strategy = Strategy(strategy)
    if strategy == Strategy.INDIRECT:
        return state.pool[state.bptr]
    return state.data


# ---------------------------------------------------------------------------
# Batched apply: engine semantics + per-strategy layout maintenance.
# ---------------------------------------------------------------------------

def _ring_alloc(state: TableState, want: jax.Array, max_want: int):
    """Pop up to `max_want` node slots from the FIFO free ring (masked by
    rank < want).  Returns (slots[max_want], new_state)."""
    m = state.free_ring.shape[0]
    ranks = jnp.arange(max_want, dtype=jnp.uint32)
    pos = (state.ring_head + ranks) % jnp.uint32(m)
    slots = state.free_ring[pos]
    live = ranks < want
    # Consumed entries are cleared (debug hygiene; not required for safety).
    ring = state.free_ring.at[jnp.where(live, pos, m)].set(NULL, mode="drop")
    new_head = state.ring_head + want
    return jnp.where(live, slots, NULL), state._replace(
        free_ring=ring, ring_head=new_head % jnp.uint32(m),
        alloc_gen=state.alloc_gen + want)


def _ring_free(state: TableState, slots: jax.Array, count: jax.Array,
               live_total: int):
    """Push retired node slots at the ring tail (head + free_count)."""
    m = state.free_ring.shape[0]
    # Tail = head + number of currently-free entries.  We track it implicitly:
    # ring is FIFO and #free is invariant per strategy, so tail == head works
    # when every alloc is matched by exactly one free in the same batch.
    ranks = jnp.arange(live_total, dtype=jnp.uint32)
    live = ranks < count
    pos = (state.ring_head + jnp.uint32(m) - count + ranks) % jnp.uint32(m)
    ring = state.free_ring.at[jnp.where(live, pos, m)].set(
        jnp.where(live, slots, NULL), mode="drop")
    return state._replace(free_ring=ring)


def commit_layout(state: TableState, new_data: jax.Array,
                  new_version: jax.Array, n_updates: jax.Array,
                  strategy: Strategy, p: int) -> TableState:
    """Reconcile a strategy's layout after the logical values have advanced
    (shared by `apply_ops` and by CacheHash's bucket table).

    `new_data`/`new_version` are the post-batch logical values + versions;
    `n_updates` the number of update operations performed (CACHED_ME transient
    accounting).  Versions advance by 2 per successful update (paper parity).
    """
    strategy = Strategy(strategy)
    n = state.version.shape[0]
    dirty = new_version != state.version

    if strategy in (Strategy.SEQLOCK, Strategy.PLAIN, Strategy.SIMPLOCK):
        return state._replace(data=new_data, version=new_version)

    if strategy in (Strategy.INDIRECT, Strategy.CACHED_WF):
        # One fresh node per dirty cell holds the final value; the old node is
        # retired to the ring.  (Intermediate values of a CAS chain live and
        # die inside the batch; they are counted in stats.n_updates.)
        d_count = jnp.sum(dirty.astype(jnp.uint32))
        order = jnp.argsort(~dirty, stable=True)   # dirty slots first
        dslots = jnp.where(jnp.arange(n) < d_count, order, n)
        max_d = min(n, p)
        dslots = dslots[:max_d]
        live = dslots < n
        new_nodes, st2 = _ring_alloc(state, d_count, max_d)
        old_nodes = state.bptr[jnp.minimum(dslots, n - 1)]
        pool = st2.pool.at[jnp.where(live, new_nodes, st2.pool.shape[0])].set(
            new_data[jnp.minimum(dslots, n - 1)], mode="drop")
        bptr = st2.bptr.at[jnp.where(live, dslots, n)].set(
            jnp.where(live, new_nodes, NULL), mode="drop")
        st3 = st2._replace(pool=pool, bptr=bptr, data=new_data,
                           version=new_version)
        new_state = _ring_free(st3, jnp.where(live, old_nodes, NULL),
                               d_count, max_d)
        if strategy == Strategy.CACHED_WF:
            # Batch completes cleanly: every dirty cell ends validated
            # (unmarked) with cache == backup.
            new_state = new_state._replace(mark=jnp.zeros_like(state.mark))
        return new_state

    if strategy == Strategy.CACHED_ME:
        # Transient backups: installed during the update, uninstalled after
        # the cache copy (backup returns to tagged null carrying the version).
        # Pool slots cycle through the 3p ring within the batch; the final
        # layout has all-null bptr (paper §3.2 invariant).
        ring_cap = state.free_ring.shape[0]
        u_count = jnp.minimum(n_updates.astype(jnp.uint32),
                              jnp.uint32(ring_cap))
        max_u = min(p, ring_cap)
        slots_alloc, st2 = _ring_alloc(state, u_count, max_u)
        # All transients are freed within the batch: push them straight back.
        st3 = _ring_free(st2, slots_alloc, u_count, max_u)
        # Tagged null: encode low version bits so a stale CAS can't ABA.
        tag = (new_version >> 1).astype(jnp.int32) & jnp.int32(0x3FFFFFFF)
        bptr = jnp.where(dirty, -(tag + 2), st3.bptr)
        return st3._replace(data=new_data, version=new_version, bptr=bptr)

    raise ValueError(strategy)  # pragma: no cover


@functools.partial(jax.jit, static_argnames=("strategy", "k"))
def apply_ops(state: TableState, ops: sem.OpBatch, *, strategy: str, k: int):
    """Linearize `ops` against the table; maintain the strategy's layout.

    Returns (new_state, ApplyResult, ApplyStats, Traffic).
    """
    strategy = Strategy(strategy)
    p = ops.p

    ver_before = state.version
    new_logical, new_version, result, stats = sem.apply_batch(
        logical(state, strategy) if strategy != Strategy.INDIRECT else state.data,
        ver_before, ops)

    new_state = commit_layout(state, new_logical, new_version,
                              stats.n_updates, strategy, p)
    traffic = _traffic_model(strategy, stats, k, p)
    return new_state, result, stats, traffic


def _traffic_model(strategy: Strategy, stats: sem.ApplyStats, k: int, p: int):
    """Analytic HBM bytes + dependency depth per batch (roofline inputs)."""
    w = WORD_BYTES
    cell = k * w
    loads = stats.n_loads
    raced = stats.n_raced_loads
    fast = loads - raced
    upd = stats.n_updates
    dirty = stats.n_dirty_cells
    z = jnp.int32(0)

    if strategy == Strategy.SEQLOCK:
        br = loads * (cell + 2 * w) + raced * (cell + 2 * w) + upd * (cell + 2 * w)
        bw = upd * (cell + 2 * w)
        chains = jnp.where(raced > 0, 2, 1)
        rmw = upd  # version lock increment
    elif strategy == Strategy.PLAIN:
        br, bw, chains, rmw = loads * cell + upd * cell, upd * cell, jnp.int32(1), z
    elif strategy == Strategy.SIMPLOCK:
        br = (loads + upd) * (cell + w)
        bw = upd * cell + (loads + upd) * 2 * w        # lock/unlock writes
        chains, rmw = jnp.int32(2), loads + upd        # lock acquire precedes data
    elif strategy == Strategy.INDIRECT:
        br = loads * (w + cell) + upd * (w + cell)
        bw = upd * cell + dirty * w
        chains, rmw = jnp.int32(2), upd                 # ptr chase on EVERY load
    elif strategy == Strategy.CACHED_WF:
        br = fast * (cell + 2 * w) + raced * (cell + 2 * w + cell) + upd * (cell + 3 * w)
        bw = upd * (2 * cell + 3 * w)                   # node + cache + ver/ptr
        chains = jnp.where(raced > 0, 2, 1)             # fast path: ONE gather
        rmw = 2 * upd                                   # ptr CAS + ver lock
    elif strategy == Strategy.CACHED_ME:
        br = fast * (cell + 2 * w) + raced * (cell + 2 * w + cell) + upd * (cell + 3 * w)
        bw = upd * (2 * cell + 3 * w)
        chains = jnp.where(raced > 0, 2, 1)
        rmw = 2 * upd
    else:  # pragma: no cover
        raise ValueError(strategy)
    return Traffic(jnp.asarray(br, jnp.float32), jnp.asarray(bw, jnp.float32),
                   jnp.asarray(chains, jnp.int32), jnp.asarray(rmw, jnp.int32))


# ---------------------------------------------------------------------------
# Honest reader protocol + torn-state simulation (oversubscription analogue).
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("strategy",))
def read_protocol(state: TableState, slots: jax.Array, *, strategy: str):
    """Read cells using ONLY the strategy's layout fields, exactly as the
    paper's load would.  Returns (values[q,k], ok[q]).

    ok=False means the reader is *blocked* (seqlock torn / simplock held) and
    would have to retry — the lock-based failure mode under oversubscription.
    Lock-free strategies always return ok=True with a consistent value.
    PLAIN returns whatever bytes are there (possibly torn) with ok=True.
    """
    strategy = Strategy(strategy)
    q = slots.shape[0]
    if strategy == Strategy.PLAIN:
        return state.data[slots], jnp.ones((q,), bool)
    if strategy == Strategy.SEQLOCK:
        v1 = state.version[slots]
        val = state.data[slots]
        v2 = state.version[slots]
        ok = (v1 == v2) & (v1 % 2 == 0)
        return val, ok
    if strategy == Strategy.SIMPLOCK:
        held = state.lock[slots] != 0
        return state.data[slots], ~held
    if strategy == Strategy.INDIRECT:
        node = state.bptr[slots]
        return state.pool[node], jnp.ones((q,), bool)
    if strategy == Strategy.CACHED_WF:
        v1 = state.version[slots]
        val = state.data[slots]
        marked = state.mark[slots]
        v2 = state.version[slots]
        fastok = (~marked) & (v1 == v2) & (v1 % 2 == 0)
        backup = state.pool[state.bptr[slots]]          # slow path (protected)
        return jnp.where(fastok[:, None], val, backup), jnp.ones((q,), bool)
    if strategy == Strategy.CACHED_ME:
        v1 = state.version[slots]
        val = state.data[slots]
        bp = state.bptr[slots]
        is_null = bp < 0
        v2 = state.version[slots]
        fastok = is_null & (v1 == v2) & (v1 % 2 == 0)
        backup = state.pool[jnp.maximum(bp, 0)]         # slow path: live node
        # If bptr is a real node, the node holds the live value (invariant);
        # either way the reader makes progress -> ok is always True.
        return jnp.where(fastok[:, None], val, backup), jnp.ones((q,), bool)
    raise ValueError(strategy)


def _sim_alloc(state: TableState):
    """Pop ONE node slot for the torn-state simulator (each frozen writer
    must hold a distinct node, like a distinct thread's private slab)."""
    m = state.free_ring.shape[0]
    slot = state.free_ring[state.ring_head]
    return slot, state._replace(
        ring_head=(state.ring_head + 1) % jnp.uint32(m),
        alloc_gen=state.alloc_gen + 1)


def begin_update(state: TableState, slot: int, new_value: np.ndarray,
                 *, strategy: str, torn_words: int | None = None) -> TableState:
    """Freeze a writer at its most vulnerable point (mid-cache-copy), exactly
    as oversubscription deschedules a lock-holder in the paper.

    SEQLOCK:   version odd, cache half-written               -> readers blocked.
    SIMPLOCK:  lock held, cache half-written                 -> readers blocked.
    INDIRECT:  new node written, pointer NOT yet swung       -> readers see OLD value.
    CACHED_WF: backup installed+marked, cache half-written   -> readers see NEW value.
    CACHED_ME: backup installed (non-null), cache half-torn  -> readers see NEW value.
    PLAIN:     cache half-written, no protocol               -> readers corrupt.
    """
    strategy = Strategy(strategy)
    k = state.data.shape[1] if state.data.size else state.pool.shape[1]
    torn = k // 2 if torn_words is None else torn_words
    new_value = jnp.asarray(new_value, sem.WORD_DTYPE)
    half = state.data[slot].at[:torn].set(new_value[:torn]) if state.data.size else None

    if strategy == Strategy.PLAIN:
        return state._replace(data=state.data.at[slot].set(half))
    if strategy == Strategy.SEQLOCK:
        return state._replace(
            version=state.version.at[slot].add(jnp.uint32(1)),  # odd = locked
            data=state.data.at[slot].set(half))
    if strategy == Strategy.SIMPLOCK:
        return state._replace(lock=state.lock.at[slot].set(jnp.uint32(1)),
                              data=state.data.at[slot].set(half))
    if strategy == Strategy.INDIRECT:
        # Node written; pointer swing (the linearization point) pending.
        free_slot, state = _sim_alloc(state)
        pool = state.pool.at[free_slot].set(new_value)
        return state._replace(pool=pool)
    if strategy == Strategy.CACHED_WF:
        # Linearization point (pointer install) HAS happened: new node is the
        # truth; cache is mid-copy and marked invalid; version odd.
        free_slot, state = _sim_alloc(state)
        pool = state.pool.at[free_slot].set(new_value)
        return state._replace(
            pool=pool,
            bptr=state.bptr.at[slot].set(free_slot),
            mark=state.mark.at[slot].set(True),
            version=state.version.at[slot].add(jnp.uint32(1)),
            data=state.data.at[slot].set(half))
    if strategy == Strategy.CACHED_ME:
        free_slot, state = _sim_alloc(state)
        pool = state.pool.at[free_slot].set(new_value)
        return state._replace(
            pool=pool,
            bptr=state.bptr.at[slot].set(free_slot),
            version=state.version.at[slot].add(jnp.uint32(1)),
            data=state.data.at[slot].set(half))
    raise ValueError(strategy)


# ---------------------------------------------------------------------------
# Table 1 space accounting (§5.5 constants).
# ---------------------------------------------------------------------------

def memory_bytes(n: int, k: int, p: int, strategy: Strategy) -> int:
    """Exact bytes of the layout, matching the paper's Table 1 / §5.5 forms."""
    w = WORD_BYTES
    strategy = Strategy(strategy)
    if strategy == Strategy.PLAIN:
        return n * k * w
    if strategy == Strategy.SEQLOCK:
        return n * (k + 1) * w
    if strategy == Strategy.SIMPLOCK:
        return n * (k + 1) * w
    if strategy == Strategy.INDIRECT:
        return n * w + (n + 2 * p) * k * w + (n + 2 * p) * w      # ptr + pool + ring
    if strategy == Strategy.CACHED_WF:
        return n * (k + 2) * w + (n + 2 * p) * k * w + (n + 2 * p) * w
    if strategy == Strategy.CACHED_ME:
        return n * (k + 2) * w + 3 * p * k * w + 3 * p * w
    raise ValueError(strategy)


def state_nbytes(state: TableState) -> int:
    """Actual bytes held by the pytree (validates memory_bytes in tests)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(state))


class BigAtomicTable:
    """Thin stateful wrapper (functional core above) — the public API."""

    def __init__(self, n: int, k: int, strategy: str | Strategy = Strategy.CACHED_ME,
                 p_max: int = 1024, initial: np.ndarray | None = None):
        self.n, self.k = n, k
        self.strategy = Strategy(strategy)
        self.p_max = p_max
        self.state = init(n, k, self.strategy, p_max, initial)

    def apply(self, ops: sem.OpBatch):
        self.state, result, stats, traffic = apply_ops(
            self.state, ops, strategy=self.strategy.value, k=self.k)
        return result, stats, traffic

    def load(self, slots) -> jax.Array:
        vals, ok = read_protocol(self.state, jnp.asarray(slots, jnp.int32),
                                 strategy=self.strategy.value)
        return vals

    def store(self, slots, values):
        p = len(slots)
        ops = sem.make_op_batch(np.full(p, sem.STORE), slots,
                                desired=values, k=self.k)
        return self.apply(ops)

    def cas(self, slots, expected, desired):
        p = len(slots)
        ops = sem.OpBatch(jnp.full((p,), sem.CAS, jnp.int32),
                          jnp.asarray(slots, jnp.int32),
                          jnp.asarray(expected, sem.WORD_DTYPE),
                          jnp.asarray(desired, sem.WORD_DTYPE))
        return self.apply(ops)

    def logical(self) -> jax.Array:
        return logical(self.state, self.strategy)

    def memory_bytes(self) -> int:
        return memory_bytes(self.n, self.k, self.p_max, self.strategy)
