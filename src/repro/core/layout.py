"""Shared big-atomic layout state and reclamation-ring helpers.

`TableState` is the one pytree every strategy layout lives in (unused fields
are size-0 arrays), so any strategy's table rides through `jax.jit`,
`lax.scan`, donation and `shard_map` unchanged — NamedTuples are native JAX
pytrees, and the round-trip property is asserted by tests/test_atomics_v2.py.

Strategy-specific interpretation of the fields (init / commit / read /
traffic) lives in `repro.core.strategies` behind the `StrategyImpl` protocol
(`repro.core.registry`); this module only owns the state container and the
FIFO free-ring allocator shared by the node-based layouts (DESIGN.md §2).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

WORD_BYTES = 4  # uint32 words
WORD_DTYPE = jnp.uint32
NULL = jnp.int32(-1)


class TableState(NamedTuple):
    """Unified pytree; unused fields are size-0 arrays for lean strategies.

    data:      word[n, k]  inline cache / value array (INDIRECT: engine shadow,
               not part of the logical layout — reads never touch it).
    version:   uint32[n]   seqlock version (even = unlocked).
    bptr:      int32[n]    backup / indirect node index; -1 null; for
               CACHED_ME, -(tag+2) encodes a *tagged* null (paper §3.2).
    mark:      bool[n]     CACHED_WF invalid-mark on the backup pointer.
    lock:      uint32[n]   SIMPLOCK lock word (0 = free).
    pool:      word[m, k]  node pool.
    free_ring: int32[m]    FIFO ring of free node indices.
    ring_head: uint32[]    next allocation position (mod ring size).
    alloc_gen: uint32[]    total allocations ever (reclamation generation).
    """

    data: jax.Array
    version: jax.Array
    bptr: jax.Array
    mark: jax.Array
    lock: jax.Array
    pool: jax.Array
    free_ring: jax.Array
    ring_head: jax.Array
    alloc_gen: jax.Array


class Traffic(NamedTuple):
    """Analytic HBM traffic for one batch (TPU roofline inputs).

    bytes_read / bytes_written: modeled HBM bytes.
    dep_chains: number of *dependent* gather rounds on the critical path
                (1 = fully pipelineable, 2 = pointer chase).
    rmw_ops:    single-word atomic RMWs (CAS/lock) — contention proxy.
    """

    bytes_read: jax.Array
    bytes_written: jax.Array
    dep_chains: jax.Array
    rmw_ops: jax.Array


def _empty(dtype, shape=(0,)):
    return jnp.zeros(shape, dtype)


def ring_alloc(state: TableState, want: jax.Array, max_want: int):
    """Pop up to `max_want` node slots from the FIFO free ring (masked by
    rank < want).  Returns (slots[max_want], new_state)."""
    m = state.free_ring.shape[0]
    ranks = jnp.arange(max_want, dtype=jnp.uint32)
    pos = (state.ring_head + ranks) % jnp.uint32(m)
    slots = state.free_ring[pos]
    live = ranks < want
    # Consumed entries are cleared (debug hygiene; not required for safety).
    ring = state.free_ring.at[jnp.where(live, pos, m)].set(NULL, mode="drop")
    new_head = state.ring_head + want
    return jnp.where(live, slots, NULL), state._replace(
        free_ring=ring, ring_head=new_head % jnp.uint32(m),
        alloc_gen=state.alloc_gen + want)


def ring_free(state: TableState, slots: jax.Array, count: jax.Array,
              live_total: int):
    """Push retired node slots at the ring tail (head + free_count)."""
    m = state.free_ring.shape[0]
    # Tail = head + number of currently-free entries.  We track it implicitly:
    # ring is FIFO and #free is invariant per strategy, so tail == head works
    # when every alloc is matched by exactly one free in the same batch.
    ranks = jnp.arange(live_total, dtype=jnp.uint32)
    live = ranks < count
    pos = (state.ring_head + jnp.uint32(m) - count + ranks) % jnp.uint32(m)
    ring = state.free_ring.at[jnp.where(live, pos, m)].set(
        jnp.where(live, slots, NULL), mode="drop")
    return state._replace(free_ring=ring)


def sim_alloc(state: TableState):
    """Pop ONE node slot for the torn-state simulator (each frozen writer
    must hold a distinct node, like a distinct thread's private slab)."""
    m = state.free_ring.shape[0]
    slot = state.free_ring[state.ring_head]
    return slot, state._replace(
        ring_head=(state.ring_head + 1) % jnp.uint32(m),
        alloc_gen=state.alloc_gen + 1)


def state_nbytes(state: TableState) -> int:
    """Actual bytes held by the pytree (validates memory_bytes in tests)."""
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(state))
