"""Seeded chaos harness: randomized fault schedules over executor runs.

The zero-undetected-corruptions gate (DESIGN.md §11): a chaos run composes
a seeded schedule of scheduling faults (delays) and data-plane faults
(bit flips, torn writes, stale resurrections, checkpoint damage) over an
oversubscribed multi-stream executor with the guard on, then `verify_chaos`
replays the surviving issue history through the sequential oracle
(tests/oracle.py) and checks three things:

  1. every result the executor DELIVERED bit-agrees with the oracle's
     replay of the journaled (post-masking) ops — linearizability held
     across every fault;
  2. the live table bit-agrees with the oracle on every NON-quarantined
     cell — corruption never leaked into served state;
  3. every injected bit_flip / torn_write appears in some scrub report's
     detected (or contained, if it hit an already-poisoned cell) set,
     and ends the run repaired or quarantined — nothing slipped past.
     (A corruption ERASED by a stale_resurrect applied later at the same
     boundary is exempt: the resurrect reloaded the table from the
     checkpoint, so there is nothing left in state to detect.)

Everything is a pure function of (seed, strategy): schedules, stream
workloads, and the injector's per-fault rngs, so a CI failure replays
locally from the seed alone.
"""

from __future__ import annotations

import os

import numpy as np

from repro.core.specs import AtomicSpec
from repro.runtime.executor import Executor, LocalTarget
from repro.runtime.faults import Fault, FaultInjector
from repro.runtime.streams import SyntheticStream

CHAOS_STRATEGIES = ("seqlock", "indirect", "cached_wf", "cached_me")


def random_schedule(rng, *, rounds: int, n_streams: int,
                    data_faults: int = 3, sched_faults: int = 1,
                    ckpt_faults: int = 0) -> list[Fault]:
    """Draw a fault schedule: every choice comes from `rng`, so the
    schedule is a pure function of the caller's seed."""
    faults: list[Fault] = []
    for _ in range(sched_faults):
        faults.append(Fault(
            round=int(rng.integers(1, rounds + 1)), kind="delay",
            stream=int(rng.integers(n_streams)),
            seconds=float(rng.uniform(1e-4, 1e-3)),
            rounds=int(rng.integers(1, 3))))
    # stale resurrections quarantine every dirty cell at once, so keep
    # them rare relative to single-cell corruptions
    kinds = ["bit_flip"] * 5 + ["torn_write"] * 4 + ["stale_resurrect"]
    for _ in range(data_faults):
        faults.append(Fault(
            round=int(rng.integers(1, rounds + 1)),
            kind=kinds[int(rng.integers(len(kinds)))]))
    for _ in range(ckpt_faults):
        faults.append(Fault(
            round=int(rng.integers(1, rounds + 1)),
            kind="ckpt_corrupt" if rng.integers(2) else "ckpt_truncate"))
    return faults


def run_chaos(seed: int, strategy: str, *, n: int = 24, k: int = 2,
              width: int = 6, n_streams: int = 3, n_batches: int = 4,
              data_faults: int = 3, sched_faults: int = 1,
              ckpt_faults: int = 0, checkpoint_every: int = 2,
              scrub_every: int = 1, checkpoint_dir: str | None = None,
              retry_budget: int = 2) -> dict:
    """One seeded chaos run with the guard forced on; returns the executor,
    its report, and everything `verify_chaos` needs."""
    rng = np.random.default_rng(np.random.SeedSequence(
        [seed, CHAOS_STRATEGIES.index(strategy)
         if strategy in CHAOS_STRATEGIES else 97]))
    spec = AtomicSpec(n, k, strategy, max(16, width))
    streams = [SyntheticStream(f"s{i}", seed=seed * 131 + i, n=n, k=k,
                               width=width, n_batches=n_batches)
               for i in range(n_streams)]
    schedule = random_schedule(rng, rounds=n_batches, n_streams=n_streams,
                               data_faults=data_faults,
                               sched_faults=sched_faults,
                               ckpt_faults=ckpt_faults)
    injector = FaultInjector(schedule, seed=seed)
    prev = os.environ.get("BIGATOMIC_GUARD")
    os.environ["BIGATOMIC_GUARD"] = "on"
    try:
        ex = Executor(LocalTarget(spec), streams,
                      checkpoint_every=checkpoint_every,
                      checkpoint_dir=checkpoint_dir, injector=injector,
                      scrub_every=scrub_every, retry_budget=retry_budget)
    finally:
        if prev is None:
            os.environ.pop("BIGATOMIC_GUARD", None)
        else:
            os.environ["BIGATOMIC_GUARD"] = prev
    report = ex.run()
    return {"seed": seed, "strategy": strategy, "spec": spec,
            "schedule": schedule, "executor": ex, "report": report}


def _load_oracle_module():
    """tests/oracle.py ships with the repo tree, not the package; load it
    by path so the harness works from any PYTHONPATH=src entry point."""
    import importlib.util
    import pathlib
    path = pathlib.Path(__file__).resolve().parents[3] / "tests" / \
        "oracle.py"
    if not path.exists():
        raise FileNotFoundError(
            f"chaos verification needs the repo's tests/oracle.py ({path})")
    spec = importlib.util.spec_from_file_location("_chaos_oracle", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def verify_chaos(result: dict, *, oracle_mod=None) -> dict:
    """Replay a chaos run through the sequential oracle; returns the
    verdict dict (see module docstring for the three checks)."""
    from repro.core import engine
    ex, spec = result["executor"], result["spec"]
    oracle_mod = oracle_mod or _load_oracle_module()
    widths = [s.width for s in ex.streams]
    # check 1: every delivered result matches the oracle (raises on diff)
    oracle = oracle_mod.replay_executor_history(
        spec.n, spec.k, widths, ex.history, check=True)
    poison = ex.scrubber.poison
    live_logical = np.asarray(engine.logical(spec, ex.target.state))
    live_version = np.asarray(ex.target.state.version)
    clean = ~poison
    # check 2: non-quarantined live state bit-agrees with the oracle
    mismatched = np.zeros((spec.n,), bool)
    mismatched[clean] |= (live_logical[clean] != oracle.data[clean]).any(1)
    mismatched[clean] |= live_version[clean] != oracle.version[clean]
    undetected = np.flatnonzero(mismatched).tolist()
    # check 3: every injected single-cell corruption was seen + resolved.
    # Exception: a stale_resurrect applied LATER at the same boundary
    # reloads the whole table from the checkpoint, which ERASES any
    # corruption injected before it — there is nothing left in state to
    # detect, so those injections are exempt (reported as `erased`).
    by_round = {}
    for rep in ex.scrubber.reports:
        by_round.setdefault(rep.round, []).append(rep)
    last_resurrect = {}              # round -> index of last resurrect
    for idx, (rnd, fault, _info) in enumerate(ex.data_faults):
        if fault.kind == "stale_resurrect":
            last_resurrect[rnd] = idx
    unseen, unresolved, erased = [], [], []
    for idx, (rnd, fault, info) in enumerate(ex.data_faults):
        if fault.kind not in ("bit_flip", "torn_write"):
            continue
        slot = info["slot"]
        reps = by_round.get(rnd, [])
        seen = any(slot in rep.detected or slot in rep.contained
                   for rep in reps)
        resolved = any(slot in rep.repaired or slot in rep.quarantined
                       or slot in rep.contained for rep in reps)
        if not (seen and resolved) and idx < last_resurrect.get(rnd, -1):
            erased.append({"round": rnd, **info})
            continue
        if not seen:
            unseen.append({"round": rnd, **info})
        if not resolved:
            unresolved.append({"round": rnd, **info})
    return {
        "seed": result["seed"], "strategy": result["strategy"],
        "ok": not undetected and not unseen and not unresolved,
        "undetected_corruptions": undetected,
        "undetected_injections": unseen,
        "unresolved_injections": unresolved,
        "erased_injections": erased,
        "injected_data_faults": len(ex.data_faults),
        "quarantined": int(poison.sum()),
        "shed_streams": len(ex.shed),
        "scrub_reports": [rep.to_json() for rep in ex.scrubber.reports],
    }


def main(argv=None) -> int:
    """Seeded chaos sweep for CI: run `--seeds` schedules per strategy,
    write every verdict (with its ScrubReports) as one JSON document, and
    exit non-zero if ANY run had an undetected corruption.  A CI failure
    replays locally from the (seed, strategy) pair in the report alone."""
    import argparse
    import json

    ap = argparse.ArgumentParser(description=main.__doc__)
    ap.add_argument("--seeds", type=int, default=10)
    ap.add_argument("--strategies", default=",".join(CHAOS_STRATEGIES))
    ap.add_argument("--ckpt-faults", type=int, default=0)
    ap.add_argument("--out", default="benchmarks/results/chaos_reports.json")
    args = ap.parse_args(argv)

    import tempfile

    oracle_mod = _load_oracle_module()
    verdicts, bad = [], 0
    for strategy in args.strategies.split(","):
        for seed in range(args.seeds):
            with tempfile.TemporaryDirectory(prefix="chaos_ck_") as ckdir:
                res = run_chaos(seed, strategy, data_faults=2 + seed % 3,
                                sched_faults=seed % 2,
                                ckpt_faults=args.ckpt_faults,
                                checkpoint_dir=ckdir
                                if args.ckpt_faults else None)
                v = verify_chaos(res, oracle_mod=oracle_mod)
            verdicts.append(v)
            bad += not v["ok"]
            print(f"chaos {strategy:10s} seed={seed:3d} "
                  f"ok={v['ok']} injected={v['injected_data_faults']} "
                  f"quarantined={v['quarantined']}")
    doc = {"runs": len(verdicts), "failed": bad, "verdicts": verdicts}
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(doc, f, indent=1, default=float)
        f.write("\n")
    print(f"{len(verdicts)} chaos runs, {bad} failed -> {args.out}")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
