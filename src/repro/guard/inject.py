"""Data-plane fault realization: mutate live big-atomic state (DESIGN.md §11).

`runtime.faults` decides WHEN a data-plane fault fires; this module decides
WHAT it does to the table, per strategy, at a drained round boundary:

  bit_flip     XOR one bit of one live table word.  The victim word is the
               cell's value storage in its OWN layout — seqlock/cached_me
               flip `data`, indirect flips the live pool node, cached_wf
               flips either the cache row or its backup node — or the
               version word (word == k).  `field=` overrides the choice
               ("data" | "version" | "pool" | "bptr") for invariant-
               targeted tests.
  torn_write   overwrite a prefix of the cell's k-word value row WITHOUT
               touching the version — the paper's torn-write hazard landed
               at rest.  The first garbage word is forced to differ from
               the live word, so the corruption is never a no-op.

Both return `(new_state, info)` with the realized choices, so chaos runs
can assert the guard detected every single injection.  XOR always changes
the victim word, and the scrub digest's FNV-1a chain is a bijection of the
running hash at every word, so ANY single-cell change flips the cell's
digest — 100% detection is structural, not probabilistic.

`DistTarget` corruption goes through `inject_snapshot_fault` on the
(logical, versions) snapshot instead — layout internals stay consistent
(the shards rebuild on load), but the value/version corruption is the
same and detection rides the same digest.
"""

from __future__ import annotations

import numpy as np


def _flip32(word, bit: int):
    return (np.uint32(word) ^ np.uint32(1 << bit)).astype(np.uint32)


def _value_field(strategy: str, rng) -> str:
    if strategy == "indirect":
        return "pool"
    if strategy == "cached_wf":
        return str(rng.choice(["data", "pool"]))
    return "data"


def inject_table_fault(spec, state, fault, rng):
    """Apply one bit_flip / torn_write to a quiescent `TableState`."""
    import jax.numpy as jnp
    n, k = spec.n, spec.k
    slot = fault.slot if fault.slot is not None else int(rng.integers(n))
    info = {"kind": fault.kind, "slot": slot}

    if fault.kind == "bit_flip":
        word = fault.word if fault.word is not None \
            else int(rng.integers(k + 1))
        bit = fault.bit if fault.bit is not None else int(rng.integers(32))
        field = fault.field
        if field is None:
            field = "version" if word == k else _value_field(spec.strategy,
                                                             rng)
        info.update(word=word, bit=bit, field=field)
        if field == "version":
            ver = np.array(state.version)
            ver[slot] = _flip32(ver[slot], bit)
            return state._replace(version=jnp.asarray(ver)), info
        if field == "bptr":
            bp = np.array(state.bptr)
            bp[slot] = np.int32(_flip32(np.uint32(bp[slot]), bit))
            return state._replace(bptr=jnp.asarray(bp)), info
        if field == "pool":
            node = int(np.asarray(state.bptr)[slot])
            if 0 <= node < state.pool.shape[0]:
                pool = np.array(state.pool)
                w = min(word, k - 1)
                pool[node, w] = _flip32(pool[node, w], bit)
                info["node"] = node
                return state._replace(pool=jnp.asarray(pool)), info
            field = "data"              # no live node: fall through
            info["field"] = field
        data = np.array(state.data)
        w = min(word, k - 1)
        data[slot, w] = _flip32(data[slot, w], bit)
        return state._replace(data=jnp.asarray(data)), info

    if fault.kind == "torn_write":
        words = fault.words if fault.words is not None \
            else int(rng.integers(1, k + 1))
        words = max(1, min(words, k))
        garbage = rng.integers(0, 2 ** 32, words, dtype=np.uint32)
        info.update(words=words)
        if spec.strategy == "indirect":
            node = int(np.asarray(state.bptr)[slot])
            pool = np.array(state.pool)
            # never a no-op: force the first torn word to differ
            garbage[0] = pool[node, 0] ^ np.uint32(rng.integers(1, 2 ** 32))
            pool[node, :words] = garbage
            info["node"] = node
            return state._replace(pool=jnp.asarray(pool)), info
        data = np.array(state.data)
        garbage[0] = data[slot, 0] ^ np.uint32(rng.integers(1, 2 ** 32))
        data[slot, :words] = garbage
        return state._replace(data=jnp.asarray(data)), info

    raise ValueError(f"not a state fault: {fault.kind!r}")


def inject_snapshot_fault(snap: dict, fault, rng):
    """bit_flip / torn_write against a {'logical', 'versions'} snapshot
    (the DistTarget path: corruption in the logical plane, layout rebuilt
    consistently on load)."""
    logical = np.array(snap["logical"], copy=True)
    versions = np.array(snap["versions"], np.uint32, copy=True)
    n, k = logical.shape
    slot = fault.slot if fault.slot is not None else int(rng.integers(n))
    info = {"kind": fault.kind, "slot": slot}
    if fault.kind == "bit_flip":
        word = fault.word if fault.word is not None \
            else int(rng.integers(k + 1))
        bit = fault.bit if fault.bit is not None else int(rng.integers(32))
        info.update(word=word, bit=bit,
                    field="version" if word == k else "data")
        if word == k:
            versions[slot] = _flip32(versions[slot], bit)
        else:
            logical[slot, word] = _flip32(logical[slot, word], bit)
    elif fault.kind == "torn_write":
        words = fault.words if fault.words is not None \
            else int(rng.integers(1, k + 1))
        words = max(1, min(words, k))
        garbage = rng.integers(0, 2 ** 32, words, dtype=np.uint32)
        garbage[0] = logical[slot, 0] ^ np.uint32(rng.integers(1, 2 ** 32))
        logical[slot, :words] = garbage
        info.update(words=words)
    else:
        raise ValueError(f"not a state fault: {fault.kind!r}")
    return {"logical": logical, "versions": versions}, info
