"""Structural invariants of the big-atomic layouts (DESIGN.md §11).

Each registered strategy exposes its at-rest redundancy through the
`StrategyImpl.check_invariants(spec, state)` registry hook; this module is
the jitted front door the scrub pass (and tests) call.  An *invariant* here
is a property every quiescent state reachable by the engine satisfies —
so any violation proves corruption (no false positives), while satisfying
all of them proves nothing (a flipped data bit leaves every structural
invariant intact; that is what the scrub digest is for).

Per-layout invariants (derived from the paper's cell layouts, see
core/strategies.py):

  all versioned     version_parity       even version at rest (odd = a
                                         writer died mid-cell)
  simplock          lock_released        no lock word held at rest
  indirect          pointer_range        bptr in [0, pool)
                    shadow_agrees        data == pool[bptr] (commit's shadow)
  cached_wf         pointer_range        bptr in [0, pool)
                    cache_matches_backup data == pool[bptr] after validation
                    mark_clear           no invalidation mark at rest
  cached_me         tagged_null          bptr is NULL or -(tag+2) with
                                         tag = (version >> 1) & 0x3FFFFFFF
  version lists     head_prev_agrees     head's prev pointer names the ring
                                         slot the last publish displaced
                    head_ts_newest       every published pool node is older
                                         than the inline head
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.registry import get_strategy


@functools.partial(jax.jit, static_argnames=("spec",))
def check_invariants(spec, state) -> dict:
    """{invariant_name: bool[n] violation mask} for the table's strategy
    at a quiescent point (no batch in flight)."""
    return get_strategy(spec.strategy).check_invariants(spec, state)


def violation_mask(spec, state) -> np.ndarray:
    """bool[n]: cells violating ANY structural invariant (host-side)."""
    masks = check_invariants(spec, state)
    out = np.zeros((spec.n,), bool)
    for m in masks.values():
        out |= np.asarray(m)
    return out


@functools.partial(jax.jit, static_argnames=("vspec",))
def check_version_list(vspec, vstate) -> dict:
    """Head/pool agreement for `txn.versionlist` chains (bool[n] masks).

    A healthy slot's inline head is its newest version: the head's `prev`
    word names exactly the ring slot the last publish displaced into
    (NULLV before any publish), and every published pool node carries a
    strictly older timestamp than the head (`publish` requires strictly
    increasing ts per slot)."""
    from repro.txn.versionlist import NULLV
    k, rd = vspec.k, vspec.ring_depth
    head = get_strategy(vspec.strategy).logical(vstate.table)   # [n, k+2]
    hts, hprev = head[:, k], head[:, k + 1]
    cnt = vstate.count
    slots = jnp.arange(vspec.n, dtype=jnp.uint32)
    last_pos = jnp.where(cnt > 0, (cnt - 1) % jnp.uint32(rd), 0)
    expect = jnp.where(cnt > 0, slots * jnp.uint32(rd) + last_pos, NULLV)
    pool_ts = vstate.pool[:, :, k]                              # [n, rd]
    published = (jnp.arange(rd, dtype=jnp.uint32)[None, :]
                 < jnp.minimum(cnt, jnp.uint32(rd))[:, None])
    return {
        "head_prev_agrees": hprev != expect,
        "head_ts_newest": jnp.any(published & (pool_ts >= hts[:, None]),
                                  axis=1),
    }
