"""repro.guard — integrity scrubbing and graceful degradation (DESIGN.md §11).

The data-plane half of the resilience story: `runtime.faults` can corrupt
live big-atomic state (bit flips, torn k-word writes, stale shard
resurrection, damaged checkpoints); this package detects that corruption
at drained round boundaries, repairs what the last checkpoint still
vouches for, and quarantines the rest so subsequent ops report
`success=False` per the overflow-mask contract instead of serving garbage.

Layers:

  invariants   per-strategy structural checks via the
               `StrategyImpl.check_invariants` registry hook (seqlock
               parity, indirect pointer/shadow agreement, cached_wf/
               cached_me tag consistency, version-list head/pool
               agreement).
  scrub        jitted whole-table digest + invariant pass classifying
               each cell clean / repairable / quarantined (`ScrubReport`);
               XLA always, blocked Pallas digest where the strategy
               already lowers the engine round.
  chaos        seeded harness composing randomized scheduling + data-plane
               fault schedules over executor runs, replayed through
               tests/oracle.py — the zero-undetected-corruptions gate.

Gate: `BIGATOMIC_GUARD` = off (default) | on, read per executor
construction.  Off is FREE: no guard object exists, no jitted program
changes shape, and executor/engine traces are byte-identical to the
pre-guard build (pinned by tests/test_guard.py via
`analysis.tracing.assert_max_new_traces`).
"""

from __future__ import annotations

import os

from repro.guard.invariants import (  # noqa: F401
    check_invariants, check_version_list, violation_mask,
)
from repro.guard.scrub import (  # noqa: F401
    ScrubReport, Scrubber, cell_digest, scrub,
)


def configured() -> str:
    mode = os.environ.get("BIGATOMIC_GUARD", "off")
    if mode not in ("off", "on"):
        raise ValueError(f"BIGATOMIC_GUARD={mode!r}; expected off|on")
    return mode


def enabled() -> bool:
    """True when the guard tier is requested (read per call, like the
    BIGATOMIC_OBS / BIGATOMIC_ENGINE_KERNEL flags)."""
    return configured() == "on"
