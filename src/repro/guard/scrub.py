"""Whole-table integrity scrub: digest + invariants → repair/quarantine.

The scrub pass runs at drained round boundaries (no batch in flight) and
classifies every cell:

  clean        digest matches the pre-boundary baseline and every
               structural invariant holds.
  repairable   corruption detected AND the cell has not been written
               since the last in-memory checkpoint — the checkpoint's
               (logical, version) pair is still the truth, so the cell
               is spliced back and the target reloads (a full layout
               rebuild, which also restores indirect/cached internals).
  quarantined  corruption detected on a cell that WAS written since the
               checkpoint (or before any checkpoint exists): no trusted
               copy survives, so the cell is poisoned.  Subsequent ops
               against it are rewritten to IDLE before issue and report
               `success=False` — the overflow-mask contract extended to
               integrity, never silently serving garbage.

Detection is a per-cell FNV-1a digest over the cell's LOGICAL value row
plus its version word.  Each FNV step `h -> (h ^ w) * PRIME` is a
bijection of `h` for fixed `w` (PRIME is odd), so any single-cell change
to any word yields a different digest — boundary-injected bit flips and
torn writes are detected with probability 1, not 1 - 2^-32.  Structural
invariants (guard/invariants.py) catch corruption the logical plane
can't see (cached_wf backup flips, bptr damage).

Two lowering paths compute the digest, per ISSUE: the XLA twin always
exists; where the strategy lowers the engine round to Pallas
(`lower_round` overridden) and BIGATOMIC_ENGINE_KERNEL resolves to
"pallas", a blocked Pallas pass computes the same digest (equality is
pinned by tests/test_guard.py).
"""

from __future__ import annotations

import dataclasses
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import CAS, IDLE, SC, STORE
from repro.core.registry import StrategyImpl, get_strategy
from repro.guard import invariants as _inv

FNV_OFFSET = np.uint32(2166136261)
FNV_PRIME = np.uint32(16777619)


# ---------------------------------------------------------------------------
# digest: XLA twin + blocked Pallas pass
# ---------------------------------------------------------------------------

def _digest_xla(vals, ver):
    h = jnp.full(ver.shape, FNV_OFFSET, jnp.uint32)
    for j in range(vals.shape[1]):
        h = (h ^ vals[:, j]) * FNV_PRIME
    return (h ^ ver) * FNV_PRIME


def digest_np(logical, versions) -> np.ndarray:
    """Numpy twin of the digest, for snapshot-plane (DistTarget) scrubs."""
    vals = np.asarray(logical, np.uint32)
    ver = np.asarray(versions, np.uint32)
    h = np.full(ver.shape, FNV_OFFSET, np.uint32)
    with np.errstate(over="ignore"):
        for j in range(vals.shape[1]):
            h = (h ^ vals[:, j]) * FNV_PRIME
        h = (h ^ ver) * FNV_PRIME
    return h


def _digest_pallas(vals, ver, *, block: int = 8, interpret: bool = True):
    from jax.experimental import pallas as pl
    n, k = vals.shape

    def kernel(vals_ref, ver_ref, out_ref):
        h = jnp.full(ver_ref.shape, FNV_OFFSET, jnp.uint32)
        for j in range(k):
            h = (h ^ vals_ref[:, j:j + 1]) * FNV_PRIME
        out_ref[...] = (h ^ ver_ref[...]) * FNV_PRIME

    pad = (-n) % block
    if pad:
        vals = jnp.concatenate(
            [vals, jnp.zeros((pad, k), jnp.uint32)], axis=0)
        ver = jnp.concatenate([ver, jnp.zeros((pad,), jnp.uint32)])
    out = pl.pallas_call(
        kernel,
        grid=((n + pad) // block,),
        in_specs=[pl.BlockSpec((block, k), lambda i: (i, 0)),
                  pl.BlockSpec((block, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, 1), jnp.uint32),
        interpret=interpret,
    )(vals, ver[:, None])
    return out[:n, 0]


@functools.partial(jax.jit, static_argnames=("spec", "mode", "interpret"))
def _cell_digest(spec, state, mode: str, interpret: bool):
    impl = get_strategy(spec.strategy)
    vals = jnp.asarray(impl.logical(state), jnp.uint32)
    ver = jnp.asarray(state.version, jnp.uint32)
    if mode == "pallas":
        return _digest_pallas(vals, ver, interpret=interpret)
    return _digest_xla(vals, ver)


def cell_digest(spec, state, *, mode: str | None = None):
    """uint32[n] FNV-1a digest of each cell's (logical row, version).

    mode None defers to BIGATOMIC_ENGINE_KERNEL (kernels/engine_round
    resolution): the Pallas pass is used only where the strategy already
    lowers the engine round — same eligibility rule as the fused round."""
    from repro.kernels import engine_round
    resolved, interpret = engine_round.resolved_mode(mode)
    impl = get_strategy(spec.strategy)
    lowers = type(impl).lower_round is not StrategyImpl.lower_round
    use = "pallas" if (resolved == "pallas" and lowers) else "xla"
    return _cell_digest(spec, state, use, interpret)


# ---------------------------------------------------------------------------
# reports
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ScrubReport:
    """One scrub pass's classification (slot lists are global indices)."""
    round: int
    strategy: str
    n: int
    digest_checked: bool                  # had a pre-boundary baseline
    digest_mismatch: list
    invariant_violations: dict            # name -> [slots]
    detected: list                        # newly-anomalous, not yet poisoned
    contained: list                       # anomalous but already quarantined
    repaired: list
    quarantined: list
    poisoned_total: int
    latency_s: float

    @property
    def clean(self) -> bool:
        return not self.detected and not self.contained

    def to_json(self) -> dict:
        out = dataclasses.asdict(self)
        out["clean"] = self.clean
        return out


def _mask_slots(mask) -> list:
    return np.flatnonzero(np.asarray(mask)).tolist()


def scrub(spec, state, *, baseline=None, round_idx: int = 0) -> ScrubReport:
    """Standalone detection-only scrub of a quiescent LocalTarget state.

    `baseline`: uint32[n] digest from `cell_digest` taken at an earlier
    trusted point; None skips the digest check (invariants only)."""
    t0 = time.perf_counter()
    inv = {name: _mask_slots(m)
           for name, m in _inv.check_invariants(spec, state).items()
           if np.asarray(m).any()}
    mismatch = []
    if baseline is not None:
        mismatch = _mask_slots(
            np.asarray(cell_digest(spec, state)) != np.asarray(baseline))
    detected = sorted(set(mismatch).union(*inv.values()) if inv
                      else set(mismatch))
    return ScrubReport(
        round=round_idx, strategy=spec.strategy, n=spec.n,
        digest_checked=baseline is not None, digest_mismatch=mismatch,
        invariant_violations=inv, detected=detected, contained=[],
        repaired=[], quarantined=detected, poisoned_total=len(detected),
        latency_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# executor-side scrubber: baseline digests, dirty tracking, repair
# ---------------------------------------------------------------------------

class Scrubber:
    """Owns the guard state the executor threads through a run: the sticky
    poison mask, dirty-since-checkpoint tracking (what repair may touch),
    and the last checkpoint's logical plane (what repair splices from)."""

    def __init__(self, spec, *, n: int | None = None):
        self.spec = spec
        self.n = spec.n if n is None else n
        self.poison = np.zeros((self.n,), bool)
        self.dirty = np.ones((self.n,), bool)   # no checkpoint yet: all dirty
        self._ckpt = None                       # {"logical","versions"}
        self.reports: list[ScrubReport] = []

    # -- baseline / bookkeeping -------------------------------------------
    def digest_of(self, target) -> np.ndarray:
        if target.kind == "local":
            return np.asarray(cell_digest(target.spec, target.state))
        snap = target.snapshot()
        return digest_np(snap["logical"], snap["versions"])

    def set_checkpoint(self, table_snap: dict) -> None:
        """A round-boundary checkpoint was taken: it becomes repair truth
        and every cell becomes clean-relative-to-it."""
        self._ckpt = {
            "logical": np.array(table_snap["logical"], np.uint32, copy=True),
            "versions": np.array(table_snap["versions"], np.uint32,
                                 copy=True)}
        self.dirty[:] = False

    def note_results(self, ops, success) -> None:
        """Mark cells written by a retired batch dirty (STORE/CAS/SC that
        reported success — failed writes don't move the cell)."""
        kind = np.asarray(ops.kind)
        wrote = np.isin(kind, (STORE, CAS, SC)) & np.asarray(success, bool)
        if wrote.any():
            self.dirty[np.asarray(ops.slot)[wrote]] = True

    def note_untracked(self) -> None:
        """A mutation the journal can't attribute per-slot (round streams'
        direct state steps): conservatively dirty the whole table."""
        self.dirty[:] = True

    # -- poison contract ---------------------------------------------------
    def mask_ops(self, ops):
        """Rewrite lanes aimed at quarantined cells to IDLE; returns
        (masked_ops, bool[q] poisoned-lane mask or None).  The MASKED ops
        are what gets issued AND journaled, so oracle replay agrees that
        those lanes report success=False."""
        kind = np.asarray(ops.kind)
        slot = np.asarray(ops.slot)
        bad = self.poison[np.clip(slot, 0, self.n - 1)] & (kind != IDLE)
        if not bad.any():
            return ops, None
        masked = ops._replace(
            kind=np.where(bad, IDLE, kind).astype(kind.dtype))
        return masked, bad

    # -- the pass ----------------------------------------------------------
    def scrub(self, target, *, round_idx: int, baseline) -> ScrubReport:
        t0 = time.perf_counter()
        if target.kind == "local":
            inv_masks = _inv.check_invariants(target.spec, target.state)
            digest = np.asarray(cell_digest(target.spec, target.state))
        else:
            snap = target.snapshot()
            # snapshot plane: parity is the one invariant visible globally
            inv_masks = {"version_parity": snap["versions"] % 2 != 0}
            digest = digest_np(snap["logical"], snap["versions"])

        anomaly = np.zeros((self.n,), bool)
        inv = {}
        for name, m in inv_masks.items():
            m = np.asarray(m)
            if m.any():
                inv[name] = _mask_slots(m)
                anomaly |= m
        mismatch = np.zeros((self.n,), bool)
        if baseline is not None:
            mismatch = digest != np.asarray(baseline)
            anomaly |= mismatch

        detected = anomaly & ~self.poison
        contained = anomaly & self.poison
        repairable = detected & ~self.dirty if self._ckpt is not None \
            else np.zeros((self.n,), bool)
        quarantine = detected & ~repairable

        if detected.any():
            snap = target.snapshot()
            logical = np.array(snap["logical"], np.uint32, copy=True)
            versions = np.array(snap["versions"], np.uint32, copy=True)
            if repairable.any():
                logical[repairable] = self._ckpt["logical"][repairable]
                versions[repairable] = self._ckpt["versions"][repairable]
            # full reload even when nothing was repairable: init rebuilds
            # the layout (pointers, pool, parity) consistently, so a
            # quarantined cell is structurally sound — just untrusted
            target.load({"logical": logical, "versions": versions})
            self.poison |= quarantine

        report = ScrubReport(
            round=round_idx,
            strategy=getattr(self.spec, "strategy", "?"), n=self.n,
            digest_checked=baseline is not None,
            digest_mismatch=_mask_slots(mismatch),
            invariant_violations=inv,
            detected=_mask_slots(detected),
            contained=_mask_slots(contained),
            repaired=_mask_slots(repairable),
            quarantined=_mask_slots(quarantine),
            poisoned_total=int(self.poison.sum()),
            latency_s=time.perf_counter() - t0)
        self.reports.append(report)
        return report
