"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, 8 experts top-2, sliding window 4096 [arXiv:2401.04088; hf]."""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
        mlp="swiglu", n_experts=8, top_k=2, window=4096, rope_theta=1e6,
    )

def reduced() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=128, vocab=256,
                               n_experts=4, top_k=2, window=64,
                               q_block=32, kv_block=32, moe_dropless=True)
