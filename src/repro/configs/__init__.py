"""Architecture registry: one module per assigned architecture.

Each `<arch>.py` exposes `config() -> ModelConfig` with the exact published
numbers, plus `reduced() -> ModelConfig` for CPU smoke tests.  Shapes
(train_4k / prefill_32k / decode_32k / long_500k) are defined in
`repro.configs.shapes` and apply to every architecture, with per-family skips
(encoder-only: no decode; pure full-attention: no long_500k) — see
DESIGN.md §5."""

from __future__ import annotations

import importlib

ARCHS = [
    "hubert_xlarge",
    "llama4_maverick_400b_a17b",
    "mixtral_8x7b",
    "deepseek_7b",
    "glm4_9b",
    "codeqwen15_7b",
    "nemotron_4_15b",
    "mamba2_780m",
    "recurrentgemma_9b",
    "qwen2_vl_7b",
]

# CLI ids (dashes) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCHS}


def get_config(name: str, reduced: bool = False):
    mod_name = ALIASES.get(name, name).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.reduced() if reduced else mod.config()


def all_configs(reduced: bool = False):
    return {a: get_config(a, reduced) for a in ARCHS}
