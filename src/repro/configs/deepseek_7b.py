"""deepseek-7b [dense]: 30L d_model=4096 32H (kv=32, i.e. MHA) d_ff=11008
vocab=102400 — llama-arch [arXiv:2401.02954; hf]."""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-7b", family="dense", n_layers=30, d_model=4096,
        n_heads=32, n_kv_heads=32, d_ff=11008, vocab=102400,
        mlp="swiglu", rope_theta=1e4,
    )

def reduced() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=4, d_ff=128, vocab=256,
                               q_block=32, kv_block=32)
