"""llama4-maverick-400b-a17b [moe]: 48L d_model=5120 40H (GQA kv=8)
d_ff=8192, vocab=202048, MoE 128 experts top-1 — early fusion
[hf:meta-llama/Llama-4-*; unverified]."""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b", family="moe", n_layers=48,
        d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
        mlp="swiglu", n_experts=128, top_k=1, rope_theta=5e5,
    )

def reduced() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=96, vocab=256,
                               n_experts=8, top_k=1,
                               q_block=32, kv_block=32, moe_dropless=True)
