"""mamba2-780m [ssm]: 48L d_model=1536, attention-free, d_ff=0, vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified]."""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m", family="ssm", n_layers=48, d_model=1536,
        n_heads=1, n_kv_heads=1, d_ff=0, vocab=50280,
        block_pattern=("ssm",), ssm_state=128, ssm_headdim=64,
        tie_embeddings=True,
    )

def reduced() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, vocab=256,
                               ssm_state=16, ssm_headdim=16,
                               q_block=32, kv_block=32)
