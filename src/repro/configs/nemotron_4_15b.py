"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — GQA, squared-ReLU MLP [arXiv:2402.16819; unverified]."""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="nemotron-4-15b", family="dense", n_layers=32, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=24576, vocab=256000,
        mlp="sqrelu", norm="ln", rope_theta=1e4,
    )

def reduced() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=96, n_heads=6,
                               n_kv_heads=2, d_ff=192, vocab=256,
                               q_block=32, kv_block=32)
