"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (GQA kv=1, MQA)
d_ff=12288 vocab=256000 — RG-LRU + local attention (window 2048), pattern
1 attn : 2 recurrent [arXiv:2402.19427; unverified]."""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
        n_heads=16, n_kv_heads=1, d_ff=12288, vocab=256000,
        mlp="gelu", block_pattern=("rglru", "rglru", "attn"),
        window=2048, rglru_width=4096, logit_softcap=30.0, rope_theta=1e4,
    )

def reduced() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=5, d_model=64, n_heads=4,
                               n_kv_heads=1, d_ff=128, vocab=256, window=64,
                               rglru_width=64, q_block=32, kv_block=32)
