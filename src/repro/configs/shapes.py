"""Assigned input shapes (same four for every architecture).

  train_4k     seq 4,096   global_batch 256   -> train_step
  prefill_32k  seq 32,768  global_batch 32    -> prefill_step
  decode_32k   seq 32,768  global_batch 128   -> serve_step (1 token, 32k cache)
  long_500k    seq 524,288 global_batch 1     -> serve_step (sub-quadratic only)

`applicable()` encodes the assignment's skips: encoder-only archs have no
decode step; pure full-attention archs skip long_500k."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4_096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32_768, 128, "decode"),
    "long_500k": Shape("long_500k", 524_288, 1, "decode"),
}


def applicable(cfg, shape: Shape) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment rules."""
    if shape.kind == "decode":
        if not cfg.causal:
            return False, "encoder-only: no decode step"
        if shape.name == "long_500k" and not cfg.sub_quadratic:
            return False, "pure full attention: 500k dense decode out of scope"
    if shape.kind == "prefill" and not cfg.causal:
        # encoder 'prefill' = one full forward pass over 32k frames
        return True, ""
    return True, ""


def reduced_shape(shape: Shape) -> Shape:
    """Tiny version of a shape for CPU smoke tests."""
    return Shape(shape.name, min(shape.seq_len, 128),
                 min(shape.global_batch, 2), shape.kind)
