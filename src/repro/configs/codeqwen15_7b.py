"""codeqwen1.5-7b [dense]: 32L d_model=4096 32H (kv=32) d_ff=13440
vocab=92416 — qwen1.5-arch [hf:Qwen/CodeQwen1.5-7B; hf]."""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="codeqwen1.5-7b", family="dense", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=32, d_ff=13440, vocab=92416,
        mlp="swiglu", rope_theta=1e6,
    )

def reduced() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=4, d_ff=128, vocab=256,
                               q_block=32, kv_block=32)
