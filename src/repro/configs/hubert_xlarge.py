"""hubert-xlarge [audio]: 48L encoder-only d_model=1280 16H d_ff=5120
vocab=504 (cluster targets) — same arch as wav2vec2 [arXiv:2106.07447;
unverified].  Audio frontend is a STUB: inputs are precomputed frame
embeddings [B, T, 1280]; no decode step (encoder-only)."""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="hubert-xlarge", family="encoder", n_layers=48, d_model=1280,
        n_heads=16, n_kv_heads=16, d_ff=5120, vocab=504,
        mlp="gelu", norm="ln", causal=False,
        input_mode="features", feature_dim=1280,
    )

def reduced() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=4, d_ff=128, vocab=64,
                               feature_dim=64, q_block=32, kv_block=32)
