"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191; hf].
Vision frontend is a STUB: input_specs provides precomputed patch
embeddings; M-RoPE positions [B, S, 3] supplied by the pipeline."""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm", n_layers=28, d_model=3584,
        n_heads=28, n_kv_heads=4, d_ff=18944, vocab=152064,
        mlp="swiglu", mrope_sections=(16, 24, 24), rope_theta=1e6,
    )

def reduced() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=128, vocab=256,
                               mrope_sections=(4, 2, 2),
                               q_block=32, kv_block=32)
