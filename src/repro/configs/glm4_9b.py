"""glm4-9b [dense]: 40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552
— RoPE, GQA [hf:THUDM/glm-4-9b; hf]."""
import dataclasses
from repro.models.common import ModelConfig

def config() -> ModelConfig:
    return ModelConfig(
        name="glm4-9b", family="dense", n_layers=40, d_model=4096,
        n_heads=32, n_kv_heads=2, d_ff=13696, vocab=151552,
        mlp="swiglu", rope_theta=1e4,
    )

def reduced() -> ModelConfig:
    return dataclasses.replace(config(), n_layers=2, d_model=64, n_heads=4,
                               n_kv_heads=2, d_ff=128, vocab=256,
                               q_block=32, kv_block=32)
