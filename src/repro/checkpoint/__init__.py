from repro.checkpoint.disk import (  # noqa: F401
    CheckpointError, save_checkpoint, restore_checkpoint, restore_latest,
    verify_checkpoint, latest_step, list_steps,
)
