"""Atomic, mesh-agnostic disk checkpoints.

The durability protocol is the seqlock/validated-pointer idea applied to the
filesystem (DESIGN.md §3): leaf arrays are written to a staging directory,
and a manifest naming every leaf (with its logical sharding axes) is written
LAST, then the staging dir is atomically renamed to `step_%08d`.  A manifest
is the validated pointer: a crash mid-write leaves a staging dir that restore
ignores, never a torn checkpoint.  Restore is *elastic*: leaves are plain
global arrays + logical axes, so they reshard onto any mesh shape
(`restore_checkpoint(..., mesh=..., cfg=...)` re-derives shardings from the
same rules table the trainer uses).

The writer side composes with `core.multiversion`: the train loop publishes
into the on-device versioned store every step (cheap), and the async
checkpointer serializes a validated snapshot at its own cadence without ever
blocking the optimizer.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import zlib

import jax
import ml_dtypes
import numpy as np


class CheckpointError(Exception):
    """A checkpoint failed verification (corrupt, truncated, or missing a
    leaf) — `restore_latest` falls back to the newest step that verifies."""

# numpy can't np.save extension dtypes (bfloat16, fp8); store them as raw
# unsigned views and record the logical dtype in the manifest.
_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
           "int8", "uint64", "uint32", "uint16", "uint8", "bool"}


def _to_native(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _NATIVE:
        return arr, name
    return arr.view(np.dtype(f"u{arr.dtype.itemsize}")), name


def _from_native(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _NATIVE:
        return arr
    return arr.view(getattr(ml_dtypes, name))


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, state, *, meta: dict | None
                    = None) -> str:
    """Write `state` (pytree) atomically as <ckpt_dir>/step_<step>."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    stage = tempfile.mkdtemp(prefix=".staging_", dir=ckpt_dir)
    flat, _ = _flatten(state)
    manifest = {"step": step, "leaves": {}, "meta": meta or {}}
    try:
        for key, leaf in flat.items():
            arr = np.asarray(leaf)
            raw, dtype_name = _to_native(arr)
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(stage, fname), raw)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": dtype_name,
                # integrity word (DESIGN.md §11): CRC32 of the raw (native
                # view) bytes, checked by restore when verify=True
                "crc32": zlib.crc32(np.ascontiguousarray(raw).tobytes())}
        # manifest LAST = the validated-pointer swing; its own write is
        # write-then-rename so a crash can never leave a torn manifest
        # that still parses
        mtmp = os.path.join(stage, ".manifest.tmp")
        with open(mtmp, "w") as f:
            json.dump(manifest, f)
        os.replace(mtmp, os.path.join(stage, "manifest.json"))
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(stage, final)                 # atomic on one filesystem
    except BaseException:
        shutil.rmtree(stage, ignore_errors=True)
        raise
    return final


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d{8})", name)
        # only manifest-complete (validated) checkpoints count
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template,
                       *, shardings=None, verify: bool = False):
    """Restore into the structure of `template` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of
    NamedShardings — leaves are device_put with them, which is what makes
    restore elastic (any mesh, any process count).

    verify=True checks every leaf against its manifest CRC32 and raises
    `CheckpointError` on any damage (corrupt bytes, truncated file,
    missing leaf) instead of returning silently wrong state; checkpoints
    written before CRCs existed load unverified with a pass."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = _flatten(template)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    leaves_out = []
    for key in flat_t:
        ent = manifest["leaves"].get(key)
        if ent is None:
            if verify:
                raise CheckpointError(f"checkpoint missing leaf {key!r}")
            raise KeyError(f"checkpoint missing leaf {key!r}")
        try:
            raw = np.load(os.path.join(path, ent["file"]))
        except Exception as e:               # truncated / unreadable npy
            if verify:
                raise CheckpointError(f"{key}: unreadable leaf "
                                      f"({type(e).__name__}: {e})") from e
            raise
        if verify and ent.get("crc32") is not None:
            got = zlib.crc32(np.ascontiguousarray(raw).tobytes())
            if got != ent["crc32"]:
                raise CheckpointError(
                    f"{key}: CRC mismatch ({got:#010x} != "
                    f"{ent['crc32']:#010x})")
        arr = _from_native(raw, ent["dtype"])
        want = flat_t[key]
        if tuple(arr.shape) != tuple(want.shape):
            if verify:
                raise CheckpointError(f"{key}: shape {arr.shape} != "
                                      f"{want.shape}")
            raise ValueError(f"{key}: shape {arr.shape} != {want.shape}")
        if flat_s:
            leaves_out.append(jax.device_put(arr, flat_s[key]))
        else:
            leaves_out.append(jax.numpy.asarray(arr, want.dtype))
    # rebuild in template order
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)[0]
    return jax.tree_util.tree_unflatten(treedef, leaves_out), \
        manifest.get("meta", {})


def verify_checkpoint(ckpt_dir: str, step: int) -> bool:
    """True iff every leaf of `step` reads back and matches its manifest
    CRC32 (pre-CRC checkpoints verify by readability alone)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        for key, ent in manifest["leaves"].items():
            raw = np.load(os.path.join(path, ent["file"]))
            if tuple(raw.shape) != tuple(ent["shape"]) and \
                    ent["dtype"] in _NATIVE:
                return False
            crc = ent.get("crc32")
            if crc is not None and \
                    zlib.crc32(np.ascontiguousarray(raw).tobytes()) != crc:
                return False
    except Exception:
        return False
    return True


def restore_latest(ckpt_dir: str, template, *, shardings=None):
    """Restore the newest VERIFYING checkpoint: walks steps newest-first,
    skipping any that fail CRC/read verification (corrupt or truncated),
    and returns `(state, meta, step)`.  Raises `CheckpointError` when no
    step verifies, `FileNotFoundError` when there are no steps at all."""
    steps = list_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    for step in reversed(steps):
        try:
            state, meta = restore_checkpoint(ckpt_dir, step, template,
                                             shardings=shardings,
                                             verify=True)
            return state, meta, step
        except CheckpointError:
            continue
    raise CheckpointError(f"no checkpoint under {ckpt_dir} verifies "
                          f"(tried steps {steps})")
