"""Atomic, mesh-agnostic disk checkpoints.

The durability protocol is the seqlock/validated-pointer idea applied to the
filesystem (DESIGN.md §3): leaf arrays are written to a staging directory,
and a manifest naming every leaf (with its logical sharding axes) is written
LAST, then the staging dir is atomically renamed to `step_%08d`.  A manifest
is the validated pointer: a crash mid-write leaves a staging dir that restore
ignores, never a torn checkpoint.  Restore is *elastic*: leaves are plain
global arrays + logical axes, so they reshard onto any mesh shape
(`restore_checkpoint(..., mesh=..., cfg=...)` re-derives shardings from the
same rules table the trainer uses).

The writer side composes with `core.multiversion`: the train loop publishes
into the on-device versioned store every step (cheap), and the async
checkpointer serializes a validated snapshot at its own cadence without ever
blocking the optimizer.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import jax
import ml_dtypes
import numpy as np

# numpy can't np.save extension dtypes (bfloat16, fp8); store them as raw
# unsigned views and record the logical dtype in the manifest.
_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
           "int8", "uint64", "uint32", "uint16", "uint8", "bool"}


def _to_native(arr: np.ndarray) -> tuple[np.ndarray, str]:
    name = arr.dtype.name
    if name in _NATIVE:
        return arr, name
    return arr.view(np.dtype(f"u{arr.dtype.itemsize}")), name


def _from_native(arr: np.ndarray, name: str) -> np.ndarray:
    if name in _NATIVE:
        return arr
    return arr.view(getattr(ml_dtypes, name))


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out, treedef


def save_checkpoint(ckpt_dir: str, step: int, state, *, meta: dict | None
                    = None) -> str:
    """Write `state` (pytree) atomically as <ckpt_dir>/step_<step>."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    stage = tempfile.mkdtemp(prefix=".staging_", dir=ckpt_dir)
    flat, _ = _flatten(state)
    manifest = {"step": step, "leaves": {}, "meta": meta or {}}
    try:
        for key, leaf in flat.items():
            arr = np.asarray(leaf)
            raw, dtype_name = _to_native(arr)
            fname = key.replace("/", "__") + ".npy"
            np.save(os.path.join(stage, fname), raw)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": dtype_name}
        # manifest LAST = the validated-pointer swing
        with open(os.path.join(stage, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(stage, final)                 # atomic on one filesystem
    except BaseException:
        shutil.rmtree(stage, ignore_errors=True)
        raise
    return final


def list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d{8})", name)
        # only manifest-complete (validated) checkpoints count
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template,
                       *, shardings=None):
    """Restore into the structure of `template` (a pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of
    NamedShardings — leaves are device_put with them, which is what makes
    restore elastic (any mesh, any process count)."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_t, treedef = _flatten(template)
    flat_s, _ = _flatten(shardings) if shardings is not None else ({}, None)
    leaves_out = []
    for key in flat_t:
        ent = manifest["leaves"].get(key)
        if ent is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = _from_native(np.load(os.path.join(path, ent["file"])),
                           ent["dtype"])
        want = flat_t[key]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {want.shape}")
        if flat_s:
            leaves_out.append(jax.device_put(arr, flat_s[key]))
        else:
            leaves_out.append(jax.numpy.asarray(arr, want.dtype))
    # rebuild in template order
    paths_leaves = jax.tree_util.tree_flatten_with_path(template)[0]
    return jax.tree_util.tree_unflatten(treedef, leaves_out), \
        manifest.get("meta", {})
