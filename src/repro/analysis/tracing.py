"""Trace/compile counters: make silent retracing a test failure.

`jax.jit` retraces whenever an argument's *abstract* signature changes —
a weak-typed scalar vs a committed int32, a numpy int64 batch vs the jnp
int32 one, a `None` ctx vs a concrete one.  Each retrace silently
recompiles and doubles dispatch latency; for the engine hot round (ISSUE 5)
a weakly-varying `OpBatch`/`LinkCtx` leaf meant one full recompile per
call site.  These helpers read the jitted function's compilation-cache
size so tests can pin the trace count:

    with tracing.assert_max_new_traces(engine._apply, 1):
        atomics.apply(spec, state, ops_a)      # first call: 1 trace
        atomics.apply(spec, state, ops_b)      # same signature: 0 traces

`cache_entries` works on anything produced by `jax.jit` (including
`functools.partial(jax.jit, ...)` application).  For plain functions that
are traced *inside* another jit, `counting(fn)` wraps the Python callable —
its body only runs while tracing, so the wrapper's counter IS the trace
count.
"""

from __future__ import annotations

import contextlib
from typing import Callable


def cache_entries(jitted) -> int:
    """Number of compiled entries in a jitted function's cache (one per
    distinct abstract signature seen)."""
    try:
        return jitted._cache_size()
    except AttributeError as e:
        raise TypeError(
            f"{jitted!r} has no compilation cache; pass the object returned "
            "by jax.jit (or use tracing.counting for plain functions)"
        ) from e


@contextlib.contextmanager
def assert_max_new_traces(jitted, n: int):
    """Fail if the block adds more than `n` entries to the jit cache."""
    before = cache_entries(jitted)
    yield
    added = cache_entries(jitted) - before
    assert added <= n, (
        f"{added} new traces of {getattr(jitted, '__name__', jitted)!r} "
        f"(allowed {n}) — an argument's dtype/weak-type/shape is varying "
        "between calls; canonicalize it (see engine.canonicalize_ops)")


class TraceCounter:
    """Counts executions of a function's Python body (= times traced when
    the function is only ever called under `jax.jit`)."""

    def __init__(self) -> None:
        self.count = 0


def counting(fn: Callable) -> tuple[Callable, TraceCounter]:
    """Wrap `fn` so each trace of its body increments a counter.  Wrap
    BEFORE jitting: `jit_fn = jax.jit(counting(fn)[0])`."""
    counter = TraceCounter()

    def wrapper(*args, **kwargs):
        counter.count += 1
        return fn(*args, **kwargs)

    wrapper.__name__ = getattr(fn, "__name__", "wrapped")
    return wrapper, counter
