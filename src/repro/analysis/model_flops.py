"""Analytic "useful" FLOPs per (architecture x shape) step.

MODEL_FLOPS follows the assignment's definition — 6*N*D for dense training,
6*N_active*D for MoE — extended with the attention quadratic term (which
6ND omits) and with forward-only factors for serving steps:

  train    : 6 * N_active * tokens  +  3 * attn_fwd_flops
  prefill  : 2 * N_active * tokens  +      attn_fwd_flops
  decode   : 2 * N_active * batch   +      attn_decode_flops

Attention fwd = 4 * B * S^2 * h * hd per layer (QK^T + AV), halved when
causal, windowed S^2 -> S*W.  SSM/RG-LRU layers have linear-in-S state
updates whose FLOPs are inside the projection counts (the recurrence itself
is O(S*d*state), added explicitly).  The ratio MODEL_FLOPS / HLO_FLOPS in
the roofline table measures compiled-compute waste (remat, dropped-token
capacity padding, dead work).
"""

from __future__ import annotations

from repro.configs.shapes import Shape
from repro.models.common import ModelConfig


def _attn_layer_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    hd = cfg.hd
    if cfg.window > 0:
        eff = min(S, cfg.window)
        pairs = B * S * eff - (B * eff * (eff - 1) / 2 if cfg.causal else 0)
    elif cfg.causal:
        pairs = B * S * (S + 1) / 2
    else:
        pairs = B * S * S
    return 4.0 * pairs * cfg.n_heads * hd


def _ssm_layer_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    d_in = cfg.ssm_expand * cfg.d_model
    # SSD state update + output: O(S * d_in * state) each
    return 6.0 * B * S * d_in * cfg.ssm_state


def _rglru_layer_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    w = cfg.rglru_width or cfg.d_model
    return 10.0 * B * S * w          # gates + recurrence, elementwise-dominated


def _mixer_fwd(cfg: ModelConfig, B: int, S: int) -> float:
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind == "attn":
            total += _attn_layer_fwd(cfg, B, S)
        elif kind == "ssm":
            total += _ssm_layer_fwd(cfg, B, S)
        elif kind == "rglru":
            total += _rglru_layer_fwd(cfg, B, S)
    return total


def _attn_decode(cfg: ModelConfig, B: int, S: int) -> float:
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind == "attn":
            eff = min(S, cfg.window) if cfg.window > 0 else S
            total += 4.0 * B * eff * cfg.n_heads * cfg.hd
        elif kind == "ssm":
            d_in = cfg.ssm_expand * cfg.d_model
            total += 6.0 * B * d_in * cfg.ssm_state
        elif kind == "rglru":
            total += 10.0 * B * (cfg.rglru_width or cfg.d_model)
    return total


def model_flops(cfg: ModelConfig, shape: Shape) -> float:
    """Global useful FLOPs of ONE step of this cell."""
    B, S = shape.global_batch, shape.seq_len
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n_active * B * S + 3.0 * _mixer_fwd(cfg, B, S)
    if shape.kind == "prefill":
        return 2.0 * n_active * B * S + _mixer_fwd(cfg, B, S)
    # decode: one token per sequence against an S-long cache
    return 2.0 * n_active * B + _attn_decode(cfg, B, S)
