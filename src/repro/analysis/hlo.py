"""Trip-count-corrected cost analysis of compiled (post-SPMD) HLO text.

Why this exists: `compiled.cost_analysis()` visits every computation ONCE —
a `lax.scan` over 48 layers reports the FLOPs of a single layer body, and
collectives inside the loop are counted once instead of 48 times (verified
empirically on jax 0.8 / XLA CPU).  For a framework whose roofline is read
off the dry-run, that is a 24-48x error.  This module re-derives

  * FLOPs          — dot / convolution ops, each `while` body multiplied by
                     its XLA-annotated `known_trip_count`;
  * HBM bytes      — per scheduled top-level instruction: operands + outputs,
                     with in-place ops (dynamic-update-slice) counted at slice
                     granularity, layout-only ops free, fusions counted at
                     their I/O boundary (the TPU reality: one read of each
                     input, one write of each output per fusion);
  * collective ICI bytes — per op kind with standard ring-algorithm factors:
        all-gather       out_bytes x (g-1)/g
        reduce-scatter   in_bytes  x (g-1)/g
        all-reduce       2 x in_bytes x (g-1)/g
        all-to-all       in_bytes  x (g-1)/g
        collective-permute  in_bytes

from the *compiled* module text (collectives only exist post-partitioning).
All shapes in that text are per-device shard shapes, so every number this
module reports is per-device.
"""

from __future__ import annotations

import dataclasses
import math
import re

# ---------------------------------------------------------------------------
# Hardware model (TPU v5e, per chip)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HwSpec:
    name: str
    peak_flops: float      # bf16 FLOP/s
    hbm_bw: float          # bytes/s
    ici_bw: float          # bytes/s per link


TPU_V5E = HwSpec("tpu-v5e", peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e5m2": 1, "f8e4m3fn": 1,
    "f8e4m3": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 0.5, "s2": 0.25,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 0.5, "u2": 0.25,
    "c64": 8, "c128": 16, "pred": 1, "token": 0, "opaque": 0,
}

# Ops that move no bytes (pure layout / bookkeeping / metadata).
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "reshape",
    "rng-bit-generator-state", "opt-barrier", "custom-call",  # custom-call counted separately
}

_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "all-gather-start", "all-reduce-start",
                "collective-permute-start"}


# ---------------------------------------------------------------------------
# Shape parsing
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")


def _shape_bytes(shape_text: str) -> float:
    """Bytes of one (possibly tuple) shape string."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(shape_text: str) -> list[int]:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


def _shape_elems(shape_text: str) -> int:
    return int(math.prod(_shape_dims(shape_text)) or 1)


# ---------------------------------------------------------------------------
# HLO text parsing
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Instr:
    name: str
    shape: str            # result type text
    op: str
    args: str             # raw text inside op(...)
    attrs: str            # trailing attributes text
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    params: dict[str, str]      # param name -> shape text


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")


def _split_type_rest(text: str) -> tuple[str, str]:
    """Split '<type> op(args), attrs' -> (type, rest).  Type may be a tuple."""
    text = text.strip()
    if text.startswith("("):
        depth = 0
        for i, ch in enumerate(text):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return text[: i + 1], text[i + 1:].strip()
        return text, ""
    sp = text.find(" ")
    return (text, "") if sp < 0 else (text[:sp], text[sp + 1:].strip())


def _split_op_args(rest: str) -> tuple[str, str, str]:
    """'op(args), attrs' -> (op, args, attrs) with paren matching."""
    p = rest.find("(")
    if p < 0:
        return rest.strip(), "", ""
    op = rest[:p].strip()
    depth = 0
    for i in range(p, len(rest)):
        if rest[i] == "(":
            depth += 1
        elif rest[i] == ")":
            depth -= 1
            if depth == 0:
                return op, rest[p + 1:i], rest[i + 1:]
    return op, rest[p + 1:], ""


def parse_computations(hlo_text: str) -> tuple[dict[str, Computation], str]:
    """Parse module text -> ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = ""
    cur: Computation | None = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m and line.endswith("{"):
                is_entry, name, params_text = m.group(1), m.group(2), m.group(3)
                params = {}
                for pm in re.finditer(r"([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?[^,]*)",
                                      params_text):
                    params[pm.group(1)] = pm.group(2).strip()
                cur = Computation(name, [], params)
                if is_entry:
                    entry = name
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        shape, rest = _split_type_rest(rhs)
        op, args, attrs = _split_op_args(rest)
        cur.instrs.append(Instr(name, shape, op, args, attrs, line))
    return comps, entry


# ---------------------------------------------------------------------------
# Cost model
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class HloCost:
    flops: float = 0.0              # trip-corrected dot+conv FLOPs (per device)
    bytes_hbm: float = 0.0          # trip-corrected HBM traffic (per device)
    coll_bytes: float = 0.0         # trip-corrected ICI bytes (per device)
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    coll_ops: int = 0
    dots: int = 0
    unknown_trip_whiles: int = 0
    notes: list = dataclasses.field(default_factory=list)
    by_site: dict = dataclasses.field(default_factory=dict)   # op_name -> bytes
    coll_site: dict = dataclasses.field(default_factory=dict)  # op_name -> ICI bytes

    def add(self, other: "HloCost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes_hbm += other.bytes_hbm * mult
        self.coll_bytes += other.coll_bytes * mult
        self.coll_ops += int(other.coll_ops * mult)
        self.dots += int(other.dots * mult)
        self.unknown_trip_whiles += other.unknown_trip_whiles
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        for k, v in other.by_site.items():
            self.by_site[k] = self.by_site.get(k, 0.0) + v * mult
        for k, v in other.coll_site.items():
            self.coll_site[k] = self.coll_site.get(k, 0.0) + v * mult

    def top_sites(self, n=12):
        return sorted(self.by_site.items(), key=lambda kv: -kv[1])[:n]

    def top_coll_sites(self, n=12):
        return sorted(self.coll_site.items(), key=lambda kv: -kv[1])[:n]


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_BRACED = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA = re.compile(r"replica_groups=\[([\d,]+)\]<=\[")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _group_size(attrs: str) -> int:
    m = _GROUPS_BRACED.search(attrs)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA.search(attrs)
    if m:
        dims = [int(d) for d in m.group(1).split(",")]
        return int(math.prod(dims) / dims[0]) if dims else 1
    return 1


def _operand_names(args: str) -> list[str]:
    return re.findall(r"%([\w\.\-]+)", args)


class _Analyzer:
    def __init__(self, comps: dict[str, Computation]):
        self.comps = comps
        self.memo: dict[str, HloCost] = {}

    def _shape_of(self, comp: Computation, name: str,
                  table: dict[str, str]) -> str:
        if name in table:
            return table[name]
        if name in comp.params:
            return comp.params[name]
        return ""

    def comp_cost(self, name: str) -> HloCost:
        if name in self.memo:
            return self.memo[name]
        # memoize-in-progress guard (HLO call graphs are acyclic)
        self.memo[name] = HloCost()
        comp = self.comps.get(name)
        if comp is None:
            return self.memo[name]
        cost = HloCost()
        table: dict[str, str] = dict(comp.params)
        for ins in comp.instrs:
            table[ins.name] = ins.shape
        for ins in comp.instrs:
            self._instr_cost(comp, ins, table, cost)
        self.memo[name] = cost
        return cost

    @staticmethod
    def _site(ins: Instr) -> str:
        m = re.search(r'op_name="([^"]+)"', ins.attrs)
        if m:
            # strip jit wrapper + unique suffixes for aggregation
            name = m.group(1)
            name = re.sub(r"jit\([^)]*\)/", "", name)
            name = re.sub(r"\d+", "#", name)
            return f"{ins.op}:{name[:90]}"
        return ins.op

    def _instr_cost(self, comp: Computation, ins: Instr,
                    table: dict[str, str], cost: HloCost):
        op = ins.op
        out_bytes = _shape_bytes(ins.shape)

        def acct(nbytes):
            cost.bytes_hbm += nbytes
            key = self._site(ins)
            cost.by_site[key] = cost.by_site.get(key, 0.0) + nbytes
        opnds = _operand_names(ins.args)
        in_bytes = sum(_shape_bytes(self._shape_of(comp, o, table))
                       for o in opnds)

        if op == "while":
            trips = 1
            m = _TRIP_RE.search(ins.attrs)
            if m:
                trips = int(m.group(1))
            else:
                cost.unknown_trip_whiles += 1
            body = re.search(r"body=%?([\w\.\-]+)", ins.attrs)
            cond = re.search(r"condition=%?([\w\.\-]+)", ins.attrs)
            if body:
                cost.add(self.comp_cost(body.group(1)), trips)
            if cond:
                cost.add(self.comp_cost(cond.group(1)), trips)
            return
        if op == "conditional":
            branches = re.findall(r"branch_computations=\{([^}]*)\}", ins.attrs)
            names = re.findall(r"%([\w\.\-]+)", branches[0]) if branches else \
                re.findall(r"(?:true|false)_computation=%?([\w\.\-]+)", ins.attrs)
            if names:
                sub = [self.comp_cost(n) for n in names]
                best = max(sub, key=lambda c: c.flops + c.bytes_hbm)
                cost.add(best)
            return
        if op in ("call", "async-start"):
            callee = re.search(r"to_apply=%?([\w\.\-]+)", ins.attrs)
            if callee:
                cost.add(self.comp_cost(callee.group(1)))
            cost.bytes_hbm += 0.0
            return
        if op == "fusion":
            callee = re.search(r"calls=%?([\w\.\-]+)", ins.attrs)
            if callee:
                inner = self.comp_cost(callee.group(1))
                # fusions execute inner dots but their memory traffic is the
                # fusion's own I/O (inner intermediates stay in registers/VMEM)
                cost.flops += inner.flops
                cost.dots += inner.dots
            acct(out_bytes + in_bytes)
            return
        if op in _COLLECTIVES:
            g = _group_size(ins.attrs)
            kind = op.replace("-start", "")
            if kind == "all-gather":
                moved = out_bytes * (g - 1) / max(g, 1)
            elif kind == "reduce-scatter":
                moved = in_bytes * (g - 1) / max(g, 1)
            elif kind == "all-reduce":
                moved = 2.0 * in_bytes * (g - 1) / max(g, 1)
            elif kind == "all-to-all":
                moved = in_bytes * (g - 1) / max(g, 1)
            else:  # collective-permute
                moved = in_bytes
            cost.coll_bytes += moved
            cost.coll_ops += 1
            cost.coll_by_kind[kind] = cost.coll_by_kind.get(kind, 0.0) + moved
            key = self._site(ins)
            cost.coll_site[key] = cost.coll_site.get(key, 0.0) + moved
            return
        if op == "dot":
            m = _CONTRACT_RE.search(ins.attrs)
            k = 1
            if m and opnds:
                lhs_shape = _shape_dims(self._shape_of(comp, opnds[0], table))
                if m.group(1):
                    for d in m.group(1).split(","):
                        di = int(d)
                        if di < len(lhs_shape):
                            k *= lhs_shape[di]
            cost.flops += 2.0 * _shape_elems(ins.shape) * k
            cost.dots += 1
            acct(out_bytes + in_bytes)
            return
        if op == "convolution":
            # flops = 2 * out_elems * kernel_spatial * in_channels / groups
            kern = _shape_dims(self._shape_of(comp, opnds[1], table)) \
                if len(opnds) > 1 else []
            dl = re.search(r"dim_labels=(\S+?)->", ins.attrs)
            groups = re.search(r"feature_group_count=(\d+)", ins.attrs)
            gc = int(groups.group(1)) if groups else 1
            k_prod = 1
            if dl and kern:
                # kernel labels are the part after '_' e.g. b01f_01io->b01f
                klabels = dl.group(1).split("_")[1]
                for lab, size in zip(klabels, kern):
                    if lab not in ("o",):
                        k_prod *= size            # spatial dims and 'i'
            else:
                k_prod = math.prod(kern) if kern else 1
            cost.flops += 2.0 * _shape_elems(ins.shape) * k_prod / max(gc, 1)
            acct(out_bytes + in_bytes)
            return
        if op == "dynamic-update-slice":
            # in-place: only the updated slice is read+written
            upd = _shape_bytes(self._shape_of(comp, opnds[1], table)) \
                if len(opnds) > 1 else out_bytes
            acct(2.0 * upd)
            return
        if op == "dynamic-slice":
            acct(2.0 * out_bytes)     # read slice + write out
            return
        if op in ("scatter", "gather"):
            acct(out_bytes + min(in_bytes, 4 * out_bytes))
            return
        if op == "custom-call":
            # count I/O only; flops unknown (rare on this path)
            acct(out_bytes + in_bytes)
            return
        if op in _FREE_OPS or not op:
            return
        # generic elementwise / reduce / select / compare / copy / sort ...
        acct(out_bytes + in_bytes)


def analyze_hlo(hlo_text: str) -> HloCost:
    """Per-device, trip-corrected cost of a compiled HLO module."""
    comps, entry = parse_computations(hlo_text)
    if not entry:
        raise ValueError("no ENTRY computation found")
    an = _Analyzer(comps)
    return an.comp_cost(entry)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

def roofline_terms(cost: HloCost, hw: HwSpec = TPU_V5E,
                   model_flops_per_device: float | None = None) -> dict:
    """Three roofline terms in SECONDS (per device, per step) + diagnosis."""
    t_compute = cost.flops / hw.peak_flops
    t_memory = cost.bytes_hbm / hw.hbm_bw
    t_coll = cost.coll_bytes / hw.ici_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    out = dict(terms)
    out["bottleneck"] = dom.replace("_s", "")
    out["step_time_s"] = max(t_compute, t_memory, t_coll)
    out["hlo_flops_dev"] = cost.flops
    out["hlo_bytes_dev"] = cost.bytes_hbm
    out["coll_bytes_dev"] = cost.coll_bytes
    out["coll_by_kind"] = dict(cost.coll_by_kind)
    if model_flops_per_device is not None and cost.flops > 0:
        out["useful_flops_ratio"] = model_flops_per_device / cost.flops
        out["mfu_bound"] = (model_flops_per_device / hw.peak_flops) / \
            max(out["step_time_s"], 1e-30)
    return out
