from repro.analysis.hlo import (  # noqa: F401
    HloCost, analyze_hlo, parse_computations, roofline_terms,
    TPU_V5E,
)
from repro.analysis import tracing  # noqa: F401
from repro.analysis.tracing import (  # noqa: F401
    assert_max_new_traces, cache_entries, counting,
)
