from repro.analysis.hlo import (  # noqa: F401
    HloCost, analyze_hlo, parse_computations, roofline_terms,
    TPU_V5E,
)
