"""repro.sync — retry-safe synchronization primitives over big atomics.

Layered exactly as Blelloch & Wei ("LL/SC and Atomic Copy") prescribe:

  llsc        v1 compatibility shim for k-word LL / SC / validate; since the
              v2 redesign these are first-class kinds of the unified engine
              (`repro.atomics.apply`), mixable with load/store/CAS lanes.
              Everything here routes through `atomics.apply` directly; only
              the deprecated `apply_sync` shim (re-exported for v1 callers)
              warns, once, when called
  atomic_copy linearizable big-atomic -> big-atomic copy built on LL/SC
              (one mixed LL+LOAD batch, then an SC batch, per wave)
  queue       bounded MPMC ring queue (Vyukov-style tickets) whose head,
              tail and slot cells are big atomics driven through LL/SC,
              with Dice-style bounded-backoff contention management

See DESIGN.md §4 for the batch-step concurrency model and §5 for the
v2 spec/pytree/registry API.
"""

from repro.sync.llsc import (  # noqa: F401
    IDLE, LL, SC, VL, LinkCtx, SyncOpBatch, SyncResult, apply_sync,
    apply_sync_reference, init_ctx, make_sync_batch,
)
from repro.sync.atomic_copy import (  # noqa: F401
    copy_batch, copy_batch_reference,
)
from repro.sync.queue import BackoffPolicy, BigQueue  # noqa: F401
