"""repro.sync — retry-safe synchronization primitives over big atomics.

Layered exactly as Blelloch & Wei ("LL/SC and Atomic Copy") prescribe:

  llsc        k-word load-linked / store-conditional / validate, with
              per-lane link contexts over a `bigatomic.TableState`
  atomic_copy linearizable big-atomic -> big-atomic copy built on LL/SC
  queue       bounded MPMC ring queue (Vyukov-style tickets) whose head,
              tail and slot cells are big atomics driven through LL/SC,
              with Dice-style bounded-backoff contention management

See DESIGN.md §4 for the batch-step concurrency model.
"""

from repro.sync.llsc import (  # noqa: F401
    IDLE, LL, SC, VL, LinkCtx, SyncOpBatch, SyncResult, apply_sync,
    apply_sync_reference, init_ctx, make_sync_batch,
)
from repro.sync.atomic_copy import (  # noqa: F401
    copy_batch, copy_batch_reference,
)
from repro.sync.queue import BackoffPolicy, BigQueue  # noqa: F401
