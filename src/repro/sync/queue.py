"""Bounded MPMC ring queue over big atomics, driven through LL/SC.

Layout (one big-atomic table, k >= 2 words per cell, capacity C >= 2):

    cell 0        HEAD   word0 = dequeue ticket counter
    cell 1        TAIL   word0 = enqueue ticket counter
    cell 2+j      slot j word0 = sequence tag, words 1.. = payload

Tickets are Vyukov-style: slot j starts with seq = j; an enqueue that
claimed ticket t (slot t mod C) publishes (seq=t+1, payload) in ONE atomic
k-word store — payload and tag can never tear apart, which is exactly what
big atomics buy over a word-at-a-time ring.  A dequeue that claimed ticket h
consumes the slot and recycles it with seq = h + C.

Claiming is an LL/SC on the counter cell through the unified engine
(`repro.atomics.apply` with a static `QueueSpec.table_spec()`): LL reads the
ticket and links the cell, SC commits ticket+1 iff no other lane committed
in between — a pure-sync batch, so the engine resolves it on its one-round
fast path.  Per batch-round at most one enqueuer and one dequeuer win;
losers retry under the contention-management policy of Dice, Hendler &
Mirsky (arXiv:1305.5800) — bounded constant or capped-exponential backoff
measured in ROUNDS, the batch-step analogue of their wasted-CAS spin loops.
The benchmarks compare the policies; `none` makes commit order deterministic
(lane order), which the linearizability tests exploit.

Non-blocking semantics: an enqueue on a stably-full queue and a dequeue on a
stably-empty queue return failure ("stably" = no pending opposite-kind lane
in the same call could change the verdict; such lanes defer instead).

The ring state is the table's `TableState` pytree (`.state`); `BigQueue` is
the host-side retry driver around it.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.obs import telemetry as obs_telemetry
from repro.core.specs import (DEFAULT_STRATEGY, QUEUE_HEAD, QUEUE_SLOT0,
                              QUEUE_TAIL, AtomicSpec, QueueSpec)

HEAD, TAIL, SLOT0 = QUEUE_HEAD, QUEUE_TAIL, QUEUE_SLOT0

# run_batch op kinds
ENQ, DEQ, QIDLE = 0, 1, 2


class BackoffPolicy(NamedTuple):
    """Deterministic retry schedule after a lost SC (delay in rounds).

    kind: 'none' | 'const' | 'exp'.  `exp` is capped (Dice et al.: unbounded
    exponential over-serializes; a small cap wins under steady contention).
    """

    kind: str = "none"
    base: int = 1
    cap: int = 8

    def delay(self, attempts: int) -> int:
        if self.kind == "none":
            return 0
        if self.kind == "const":
            return self.base
        if self.kind == "exp":
            return min(self.base * (2 ** max(attempts - 1, 0)), self.cap)
        raise ValueError(self.kind)


class BigQueue:
    """Bounded MPMC queue; every cell a big atomic, every claim an LL/SC.

    With `mesh`/`n_shards` the ring's cells shard over the mesh axis and
    every claim/publish round routes through `core.distributed.apply` — the
    sharded decode-slot/admission path of the serving engine.  The host
    retry driver is unchanged; only the table execution layer swaps.
    """

    def __init__(self, capacity: int | None = None, *, k: int = 2,
                 strategy: str | None = None,
                 policy: BackoffPolicy = BackoffPolicy("none"),
                 p_max: int = 64, max_rounds: int | None = None,
                 initial_items=None, spec: QueueSpec | None = None,
                 mesh=None, shard_axis: str = "shard", n_shards: int = 1):
        if spec is None:
            if capacity is None:
                raise ValueError("pass either capacity or spec")
            spec = QueueSpec(capacity, k=k,
                             strategy=strategy or DEFAULT_STRATEGY,
                             p_max=p_max)
        self.spec = spec
        self._tspec = spec.table_spec()
        self.policy = policy
        self.max_rounds = max_rounds or 16 * (spec.capacity + spec.p_max + 8)
        C, k, n = spec.capacity, spec.k, self._tspec.n
        initial = np.zeros((n, k), np.uint32)
        initial[SLOT0:, 0] = np.arange(C, dtype=np.uint32)
        if initial_items is not None:
            # Pre-image of m enqueues (tickets 0..m-1), written directly
            # into the initial layout: O(1) instead of m contended rounds.
            items = self._payload(initial_items)
            m = len(items)
            if m > C:
                raise ValueError(f"{m} initial items > capacity {C}")
            initial[SLOT0:SLOT0 + m, 0] = \
                np.arange(1, m + 1, dtype=np.uint32)
            initial[SLOT0:SLOT0 + m, 1:] = items
            initial[TAIL, 0] = m
        self._mesh = mesh if n_shards > 1 else None
        self._axis = shard_axis
        self._n_shards = n_shards if self._mesh is not None else 1
        if self._mesh is not None:
            from repro.core import distributed as dsb
            # Cell count padded up to a multiple of the shard count; the
            # padding cells exist but no op ever targets them.
            n_pad = -(-n // n_shards) * n_shards
            self._dist_inner = AtomicSpec(n_pad, k, spec.strategy,
                                          spec.p_max)
            pad = np.zeros((n_pad, k), np.uint32)
            pad[:n] = initial
            self._dstate = dsb.init_dist(
                mesh, dsb.DistSpec(self._dist_inner, shard_axis, n_shards,
                                   1), pad)
            self.state = None
        else:
            self.state = engine.init(self._tspec, initial)
        self.commit_log: list[tuple[str, int, int]] = []  # (kind, lane, ticket)

    # -- v1 attribute surface ------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.spec.capacity

    @property
    def k(self) -> int:
        return self.spec.k

    @property
    def strategy(self) -> str:
        return self.spec.strategy

    # -- execution layer: single-device engine or the sharded dist round ----

    def _pad_width(self, p: int) -> int:
        s = self._n_shards
        return -(-p // s) * s

    def _apply_ops(self, ops, ctx):
        """One unified batch against the ring table; returns (result, ctx').

        Sharded mode routes through `distributed.apply` (which IDLE-pads
        the lane axis to a shard multiple and trims results back); the
        default capacity (p_local) can never overflow because a source
        device only owns p_local lanes in the first place."""
        if self._mesh is None:
            self.state, ctx, res, _, _ = engine.apply(
                self._tspec, self.state, ops, ctx)
            return res, ctx
        from repro.core import distributed as dsb
        p = self._pad_width(ops.kind.shape[0])
        dspec = dsb.DistSpec(self._dist_inner, self._axis, self._n_shards,
                             p // self._n_shards)
        self._dstate, ctx, res, _ovf = dsb.apply(
            self._mesh, dspec, self._dstate, ops, ctx)
        return res, ctx

    def _read_cells(self, cells) -> np.ndarray:
        """Linearizable read of ring cells: the strategy's honest read
        protocol locally, a routed LOAD batch when sharded."""
        cells = np.asarray(cells, np.int32)
        if self._mesh is None:
            vals, _ = engine.read(self._tspec, self.state,
                                  jnp.asarray(cells))
            return np.asarray(vals)
        res, _ = self._apply_ops(engine.loads(cells, k=self.k), None)
        return np.asarray(res.value)

    # -- introspection -------------------------------------------------------

    def _counters(self) -> tuple[int, int]:
        vals = self._read_cells([HEAD, TAIL])
        return int(vals[0, 0]), int(vals[1, 0])

    def __len__(self) -> int:
        h, t = self._counters()
        return (t - h) % (1 << 32)

    # -- public ops ----------------------------------------------------------

    def enqueue_batch(self, values) -> np.ndarray:
        """Enqueue values[i] from lane i.  Returns success bool[p]."""
        values = self._payload(values)
        _, succ, _ = self.run_batch(np.full(len(values), ENQ), values)
        return succ

    def dequeue_batch(self, p: int):
        """Dequeue into p lanes.  Returns (payload uint32[p, k-1],
        success bool[p]); payload rows of failed lanes are zero."""
        out, succ, _ = self.run_batch(np.full(p, DEQ))
        return out, succ

    def _payload(self, values) -> np.ndarray:
        values = np.asarray(values, np.uint32)
        if values.ndim == 1:
            values = values[:, None]
        if values.shape[1] != self.k - 1:
            raise ValueError(f"payload width {values.shape[1]} != k-1 "
                             f"({self.k - 1})")
        return values

    # -- the round loop ------------------------------------------------------

    def run_batch(self, kinds, values=None):
        """Run a mixed batch of ENQ/DEQ/QIDLE lane-ops to completion.

        Returns (payload uint32[p, k-1], success bool[p], rounds).  With
        policy 'none' commit order equals lane order per counter; with
        backoff it is the recorded `commit_log` order (still a valid
        linearization).
        """
        kinds = np.asarray(kinds, np.int32)
        p = len(kinds)
        C, k = self.capacity, self.k
        values = self._payload(values) if values is not None else \
            np.zeros((p, k - 1), np.uint32)

        pending = kinds != QIDLE
        success = np.zeros(p, bool)
        out = np.zeros((p, k - 1), np.uint32)
        attempts = np.zeros(p, np.int64)
        delay = np.zeros(p, np.int64)
        counter_cell = np.where(kinds == ENQ, TAIL, HEAD).astype(np.int32)
        ctx = engine.init_ctx(p, k)
        rounds = 0
        # Host-side telemetry (repro.obs): a few int adds per round here,
        # one `record` call at the end (itself a no-op unless
        # BIGATOMIC_OBS=counters).  The signals are the loop's own masks.
        n_full = n_empty = n_lost = n_backoff = 0

        while pending.any():
            rounds += 1
            if rounds > self.max_rounds:
                raise RuntimeError(
                    f"queue round bound exceeded ({self.max_rounds}); "
                    f"pending={np.nonzero(pending)[0].tolist()}")
            active = pending & (delay == 0)
            if not active.any():
                delay = np.maximum(delay - 1, 0)
                continue

            # 1. LL the counter cell (tail for ENQ lanes, head for DEQ).
            ops1 = engine.make_ops(
                np.where(active, engine.LL, engine.IDLE), counter_cell, k=k)
            res1, ctx = self._apply_ops(ops1, ctx)
            tick = np.asarray(res1.value[:, 0], np.uint32)

            # 2. Honest reads: my ring slot + the opposite counter.
            slot_cell = (SLOT0 + (tick % np.uint32(C))).astype(np.int32)
            other_cell = np.where(kinds == ENQ, HEAD, TAIL).astype(np.int32)
            rvals = self._read_cells(np.concatenate([slot_cell, other_cell]))
            seq = rvals[:p, 0].astype(np.uint32)
            other = rvals[p:, 0].astype(np.uint32)

            is_enq = active & (kinds == ENQ)
            is_deq = active & (kinds == DEQ)
            enq_ready = is_enq & (seq == tick)
            deq_ready = is_deq & (seq == tick + np.uint32(1))
            enq_full = is_enq & ~enq_ready       # C >= 2: seq != t <=> full
            deq_empty = is_deq & ~deq_ready & (other == tick)
            n_full += int(enq_full.sum())
            n_empty += int(deq_empty.sum())

            # Stably full/empty only if no pending opposite-kind lane could
            # still flip the verdict; otherwise defer and retry.
            if not (pending & (kinds == DEQ)).any():
                pending[enq_full] = False
            if not (pending & (kinds == ENQ)).any():
                pending[deq_empty] = False

            attempt = enq_ready | deq_ready
            if not attempt.any():
                delay = np.maximum(delay - 1, 0)
                continue

            # 3. SC the counter (claim ticket `tick` by committing tick+1);
            #    the slot publish rides the same round as a follow-up STORE
            #    once the winners are known.
            des = np.zeros((p, k), np.uint32)
            des[:, 0] = tick + np.uint32(1)
            ops2 = engine.make_ops(
                np.where(attempt, engine.SC, engine.IDLE), counter_cell,
                desired=des, k=k)
            res2, ctx = self._apply_ops(ops2, ctx)
            won = np.asarray(res2.success) & attempt

            # 4. Winners publish their slot in one atomic k-word store:
            #    ENQ: (t+1, payload)   DEQ: (h+C, zeros) — recycled.
            st_des = np.zeros((p, k), np.uint32)
            st_des[:, 0] = np.where(kinds == ENQ, tick + np.uint32(1),
                                    tick + np.uint32(C))
            st_des[:, 1:] = np.where((kinds == ENQ)[:, None], values, 0)
            ops3 = engine.make_ops(
                np.where(won, engine.STORE, engine.IDLE), slot_cell,
                desired=st_des, k=k)
            self._apply_ops(ops3, None)

            # 5. Bookkeeping: payload capture, commit log, backoff.
            for lane in np.nonzero(won & (kinds == ENQ))[0]:
                self.commit_log.append(("enq", int(lane), int(tick[lane])))
            for lane in np.nonzero(won & (kinds == DEQ))[0]:
                out[lane] = rvals[lane, 1:]
                self.commit_log.append(("deq", int(lane), int(tick[lane])))
            success |= won
            pending &= ~won
            lost = attempt & ~won
            attempts[lost] += 1
            n_lost += int(lost.sum())
            for lane in np.nonzero(lost)[0]:
                delay[lane] = self.policy.delay(int(attempts[lane]))
                n_backoff += 1
            delay[~active] = np.maximum(delay[~active] - 1, 0)

        obs_telemetry.record(**{
            "queue.rounds": rounds,
            "queue.enq": int((success & (kinds == ENQ)).sum()),
            "queue.deq": int((success & (kinds == DEQ)).sum()),
            "queue.enq_full": n_full,
            "queue.deq_empty": n_empty,
            "queue.sc_lost": n_lost,
            "queue.backoff": n_backoff,
        })
        return out, success, rounds
