"""Linearizable big-atomic -> big-atomic copy, built on LL/SC.

Blelloch & Wei's atomic copy reads a source cell and writes its k words to a
destination cell so that the whole transfer is observable at a single point.
In the batch-step model a `copy_batch` call applies q copies in lane order;
copies may chain (lane j's source is lane i's destination) and may collide
(two lanes, one destination) — the sequential oracle defines the result.

Implementation: lanes are scheduled into *waves* such that no lane shares a
source-after-write or destination with an earlier unfinished lane.  A wave
is TWO unified-engine calls (the v2 mixed-batch API earns its keep here —
v1 needed three):

  1. one mixed batch: LL lanes link every destination while LOAD lanes read
     every source, linearized together in one call;
  2. SC every destination with the loaded source bytes.

Within a wave nothing intervenes between a lane's source read and its SC —
the SC is the linearization point and always succeeds, so the wave loop
terminates in at most q waves.  Wave scheduling is host-side (numpy) because
the conflict graph is data-dependent; each wave's table work is the jitted
unified `apply`, so every strategy's layout maintenance is exercised.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.specs import AtomicSpec


def copy_batch_reference(data: np.ndarray, version: np.ndarray,
                         src: np.ndarray, dst: np.ndarray):
    """Sequential oracle: copies applied one at a time in lane order."""
    data = np.array(data, copy=True)
    version = np.array(version, copy=True)
    for s, d in zip(np.asarray(src), np.asarray(dst)):
        data[d] = data[s]
        version[d] += 2
    return data, version


def _waves(src: np.ndarray, dst: np.ndarray) -> list[np.ndarray]:
    """Partition lanes into waves.  For earlier lane i and later lane j:
    j reads/writes what i writes (dst_i ∈ {src_j, dst_j}) -> j waits a full
    wave; i reads what j writes (src_i == dst_j) -> j may not run EARLIER
    than i (same wave is fine: a wave's reads all precede its writes)."""
    q = len(src)
    depth = np.zeros(q, np.int64)
    for j in range(q):
        for i in range(j):
            if dst[i] == src[j] or dst[i] == dst[j]:
                depth[j] = max(depth[j], depth[i] + 1)
            if src[i] == dst[j]:
                depth[j] = max(depth[j], depth[i])
    return [np.nonzero(depth == t)[0] for t in range(int(depth.max()) + 1)] \
        if q else []


def copy_batch(spec: AtomicSpec, state, src, dst):
    """Atomically copy cell src[i] -> dst[i] for each lane, in lane order.

    Returns (state', n_waves).  Linearizable: matches
    `copy_batch_reference` on the logical values for every strategy.
    """
    src = np.asarray(src, np.int32)
    dst = np.asarray(dst, np.int32)
    k = spec.k
    n_waves = 0
    for lanes in _waves(src, dst):
        m = len(lanes)
        # 1. One mixed batch: lanes 0..m-1 LL the destinations, lanes
        #    m..2m-1 LOAD the sources — a single linearization.
        kind = np.concatenate([np.full(m, engine.LL, np.int32),
                               np.full(m, engine.LOAD, np.int32)])
        slots = np.concatenate([dst[lanes], src[lanes]])
        ctx = engine.init_ctx(2 * m, k)
        state, ctx, res, _, _ = engine.apply(
            spec, state, engine.make_ops(kind, slots, k=k), ctx)
        src_vals = res.value[m:]
        # 2. Commit; fresh links with nothing in between => always succeeds.
        kind = np.concatenate([np.full(m, engine.SC, np.int32),
                               np.full(m, engine.IDLE, np.int32)])
        desired = jnp.concatenate([src_vals, jnp.zeros_like(src_vals)])
        state, ctx, _res, _, _ = engine.apply(
            spec, state, engine.make_ops(kind, slots, desired=desired, k=k),
            ctx)
        n_waves += 1
    return state, n_waves
