"""k-word Load-Linked / Store-Conditional over big-atomic tables.

LL/SC is the paper's headline application of big atomics: a k-word LL
records the cell's *version* alongside its value, and the matching SC
commits iff the version is still the one that was linked.  Because the
comparison is on the version — not the value — SC is immune to ABA (a cell
restored to its linked bytes after intervening commits still fails) and to
lapped linkers (a lane that held its link across many other commits).

Batch-step model (mirrors `semantics.apply_batch`): one call linearizes a
batch of p lane-ops (LL / SC / VL / IDLE) in lane order against the table.
Lane i's link state lives in `LinkCtx[i]` and persists across batches —
cross-thread interleavings of the pointer-machine protocol become
cross-batch interleavings here, driven explicitly by the tests.

The key structural fact, and why the fused Pallas kernel
(`kernels/llsc_commit.py`) needs no serialization loop: **at most one SC per
cell can succeed per batch.**  Every SC in the batch carries a link version
<= the cell's pre-batch version, so the first eligible SC in lane order
commits (bumping the version by 2) and every later SC on that cell is
already stale.  Unlike `apply_batch`'s L-round CAS chains, an SC batch
always linearizes in ONE round.

Every strategy (SEQLOCK / INDIRECT / CACHED_WF / CACHED_ME) gets identical
semantics; layout maintenance is delegated to `bigatomic.commit_layout`,
exactly as `bigatomic.apply_ops` does for store/CAS batches.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bigatomic as ba
from repro.core import semantics as sem
from repro.core.semantics import _segmented_scan_max

# Sync op kinds (distinct namespace from semantics.LOAD/STORE/CAS).
LL = 0     # load-linked: read value, link (slot, version)
SC = 1     # store-conditional: commit desired iff link still valid
VL = 2     # validate: is my link still valid?  (never writes)
IDLE = 3   # padding lane


class SyncOpBatch(NamedTuple):
    """Batch of p sync ops.  kind: int32[p]; slot: int32[p];
    desired: word[p, k] (SC payload; ignored otherwise)."""

    kind: jax.Array
    slot: jax.Array
    desired: jax.Array

    @property
    def p(self) -> int:
        return self.kind.shape[0]


class LinkCtx(NamedTuple):
    """Per-lane link state, carried across batches.

    slot:    int32[p]   linked cell (-1 = never linked)
    version: uint32[p]  version observed at the LL
    value:   word[p,k]  value observed at the LL
    linked:  bool[p]    link is live (consumed by any SC attempt)
    """

    slot: jax.Array
    version: jax.Array
    value: jax.Array
    linked: jax.Array


class SyncResult(NamedTuple):
    """value: word[p,k] witnessed at the op's linearization point;
    success: bool[p] (LL: always True; SC/VL: link validity)."""

    value: jax.Array
    success: jax.Array


def init_ctx(p: int, k: int) -> LinkCtx:
    return LinkCtx(
        slot=jnp.full((p,), -1, jnp.int32),
        version=jnp.zeros((p,), jnp.uint32),
        value=jnp.zeros((p, k), sem.WORD_DTYPE),
        linked=jnp.zeros((p,), bool),
    )


def make_sync_batch(kind, slot, desired=None, *, k: int) -> SyncOpBatch:
    kind = jnp.asarray(kind, jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)
    p = kind.shape[0]
    if desired is None:
        desired = jnp.zeros((p, k), sem.WORD_DTYPE)
    return SyncOpBatch(kind, slot, jnp.asarray(desired, sem.WORD_DTYPE))


# ---------------------------------------------------------------------------
# Sequential oracle (numpy) — THE definition of correctness.
# ---------------------------------------------------------------------------

def apply_sync_reference(data: np.ndarray, version: np.ndarray,
                         ctx: LinkCtx, ops: SyncOpBatch):
    """Apply sync ops one at a time in lane order.  Pure numpy, for tests.

    Returns (new_data, new_version, new_ctx, SyncResult-as-numpy).
    """
    data = np.array(data, copy=True)
    version = np.array(version, copy=True)
    c_slot = np.array(ctx.slot, copy=True)
    c_ver = np.array(ctx.version, copy=True)
    c_val = np.array(ctx.value, copy=True)
    c_lnk = np.array(ctx.linked, copy=True)
    kind = np.asarray(ops.kind)
    slot = np.asarray(ops.slot)
    desired = np.asarray(ops.desired)
    p, k = desired.shape
    value = np.zeros((p, k), data.dtype)
    success = np.zeros((p,), bool)
    for i in range(p):
        s = slot[i]
        if kind[i] == IDLE:
            continue
        cur = data[s].copy()
        value[i] = cur
        if kind[i] == LL:
            c_slot[i], c_ver[i], c_val[i], c_lnk[i] = \
                s, version[s], cur, True
            success[i] = True
        elif kind[i] == VL:
            success[i] = bool(c_lnk[i] and c_slot[i] == s
                              and c_ver[i] == version[s])
        elif kind[i] == SC:
            ok = bool(c_lnk[i] and c_slot[i] == s
                      and c_ver[i] == version[s])
            if ok:
                data[s] = desired[i]
                version[s] += 2
            c_lnk[i] = False            # any SC attempt consumes the link
            success[i] = ok
    new_ctx = LinkCtx(c_slot, c_ver, c_val, c_lnk)
    return data, version, new_ctx, SyncResult(value, success)


# ---------------------------------------------------------------------------
# Vectorized linearization (jnp) — bit-identical to the oracle.
# ---------------------------------------------------------------------------

def sync_batch(data: jax.Array, version: jax.Array, ctx: LinkCtx,
               ops: SyncOpBatch):
    """Table-level vectorized LL/SC batch.  Returns
    (data', version', ctx', SyncResult, ApplyStats)."""
    n, k = data.shape
    p = ops.p
    kind = ops.kind

    active = kind != IDLE
    slot = jnp.where(active, ops.slot, n)

    order = jnp.argsort(slot, stable=True)       # (slot, lane) lexicographic
    inv = jnp.argsort(order, stable=True)

    s_slot = slot[order]
    s_kind = kind[order]
    s_desired = ops.desired[order]
    s_cslot = ctx.slot[order]
    s_cver = ctx.version[order]
    s_clnk = ctx.linked[order]

    idx = jnp.arange(p, dtype=jnp.int32)
    seg_start = jnp.concatenate(
        [jnp.ones((1,), bool), s_slot[1:] != s_slot[:-1]])

    safe_slot = jnp.minimum(s_slot, n - 1)
    ver0 = version[safe_slot]                    # pre-batch version per lane
    pre_val = data[safe_slot]                    # pre-batch value per lane

    # An SC is eligible iff its lane's link names this cell at its pre-batch
    # version.  The FIRST eligible SC in each segment wins; versions only
    # move forward inside the batch, so everyone behind the winner is stale.
    eligible = (s_kind == SC) & s_clnk & (s_cslot == s_slot) & \
        (s_cver == ver0) & (s_slot < n)
    elig_incl = _segmented_scan_max(eligible.astype(jnp.int32), seg_start)
    elig_before = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), elig_incl[:-1]])
    elig_before = jnp.where(seg_start, 0, elig_before) > 0
    win = eligible & ~elig_before

    # Winner position (inclusive prefix): lanes after the winner observe the
    # committed value/version; lanes before it observe the pre-batch state.
    wpos_incl = _segmented_scan_max(jnp.where(win, idx, -1), seg_start)
    post = wpos_incl >= 0                        # a commit at-or-before me
    post_excl = post & ~win                      # strictly before me (win is
    #                                              unique, so at == mine)
    cur_val = jnp.where(post_excl[:, None],
                        s_desired[jnp.maximum(wpos_incl, 0)], pre_val)
    cur_ver = ver0 + jnp.where(post_excl, jnp.uint32(2), jnp.uint32(0))

    is_ll = (s_kind == LL) & (s_slot < n)
    is_vl = (s_kind == VL) & (s_slot < n)
    is_sc = (s_kind == SC) & (s_slot < n)

    s_value = jnp.where((is_ll | is_vl | is_sc)[:, None], cur_val,
                        jnp.zeros_like(cur_val))
    vl_ok = s_clnk & (s_cslot == s_slot) & (s_cver == cur_ver)
    s_success = jnp.where(is_ll, True,
                          jnp.where(is_vl, vl_ok,
                                    jnp.where(is_sc, win, False)))

    # --- commit winners --------------------------------------------------
    w_idx = jnp.where(win, s_slot, n)
    new_data = data.at[w_idx].set(s_desired, mode="drop")
    new_version = version.at[w_idx].add(jnp.uint32(2), mode="drop")

    # --- link context updates --------------------------------------------
    n_slot = jnp.where(is_ll, s_slot, s_cslot)
    n_ver = jnp.where(is_ll, cur_ver, s_cver)
    n_val = jnp.where(is_ll[:, None], cur_val, ctx.value[order])
    n_lnk = jnp.where(is_ll, True, jnp.where(is_sc, False, s_clnk))

    new_ctx = LinkCtx(n_slot[inv], n_ver[inv], n_val[inv], n_lnk[inv])
    result = SyncResult(s_value[inv], s_success[inv])

    # --- stats (feed the same traffic model as apply_ops) ----------------
    seg_end = jnp.concatenate([seg_start[1:], jnp.ones((1,), bool)])
    seg_any_win_rev = _segmented_scan_max(
        jnp.flip(win.astype(jnp.int32)), jnp.flip(seg_end))
    seg_any_win = jnp.flip(seg_any_win_rev) > 0
    stats = sem.ApplyStats(
        rounds=jnp.where(jnp.any(is_sc), 1, 0).astype(jnp.int32),
        n_updates=jnp.sum(win.astype(jnp.int32)),
        n_loads=jnp.sum(is_ll.astype(jnp.int32)),
        n_cas_fail=jnp.sum((is_sc & ~win).astype(jnp.int32)),
        n_raced_loads=jnp.sum((is_ll & seg_any_win).astype(jnp.int32)),
        n_dirty_cells=jnp.sum(win.astype(jnp.int32)),  # <=1 winner per cell
    )
    return new_data, new_version, new_ctx, result, stats


@functools.partial(jax.jit, static_argnames=("strategy", "k"))
def apply_sync(state: ba.TableState, ctx: LinkCtx, ops: SyncOpBatch, *,
               strategy: str, k: int):
    """Linearize a sync batch against a big-atomic table; maintain the
    strategy's layout.  Returns (state', ctx', SyncResult, stats, Traffic).
    """
    strategy = ba.Strategy(strategy)
    vals = ba.logical(state, strategy) \
        if strategy != ba.Strategy.INDIRECT else state.data
    new_data, new_version, new_ctx, result, stats = sync_batch(
        vals, state.version, ctx, ops)
    new_state = ba.commit_layout(state, new_data, new_version,
                                 stats.n_updates, strategy, ops.p)
    traffic = ba._traffic_model(strategy, stats, k, ops.p)
    return new_state, new_ctx, result, stats, traffic


# ---------------------------------------------------------------------------
# Convenience single-kind wrappers
# ---------------------------------------------------------------------------

def ll(state, ctx, slots, *, strategy: str, k: int):
    """Link every lane i to slots[i].  Returns (ctx', values)."""
    slots = jnp.asarray(slots, jnp.int32)
    ops = make_sync_batch(jnp.full(slots.shape, LL, jnp.int32), slots, k=k)
    _, ctx, res, _, _ = apply_sync(state, ctx, ops, strategy=strategy, k=k)
    return ctx, res.value


def sc(state, ctx, slots, desired, *, strategy: str, k: int):
    """Conditionally commit desired[i] to slots[i].  Returns
    (state', ctx', success)."""
    slots = jnp.asarray(slots, jnp.int32)
    ops = make_sync_batch(jnp.full(slots.shape, SC, jnp.int32), slots,
                          desired, k=k)
    state, ctx, res, _, _ = apply_sync(state, ctx, ops, strategy=strategy,
                                       k=k)
    return state, ctx, res.success


def validate(state, ctx, slots, *, strategy: str, k: int):
    """Is each lane's link still valid?  Returns bool[p]."""
    slots = jnp.asarray(slots, jnp.int32)
    ops = make_sync_batch(jnp.full(slots.shape, VL, jnp.int32), slots, k=k)
    _, _, res, _, _ = apply_sync(state, ctx, ops, strategy=strategy, k=k)
    return res.success
