"""k-word Load-Linked / Store-Conditional — v1 shim over `repro.atomics`.

LL/SC is the paper's headline application of big atomics: a k-word LL
records the cell's *version* alongside its value, and the matching SC
commits iff the version is still the one that was linked.  Because the
comparison is on the version — not the value — SC is immune to ABA (a cell
restored to its linked bytes after intervening commits still fails) and to
lapped linkers (a lane that held its link across many other commits).

Since the v2 redesign (DESIGN.md §5) LL/SC is not a separate subsystem: the
unified engine linearizes LL / SC / VALIDATE lanes in the SAME batch as
LOAD / STORE / CAS, and the one-SC-per-cell-per-batch fact (DESIGN.md §4)
is its runtime fast path — a batch with no store/CAS lanes resolves in ONE
round, which is also what the fused Pallas kernel
(`kernels/llsc_commit.py`) exploits.  New code should call

    repro.atomics.apply(spec, state, ops, ctx)

with sync kinds from `repro.atomics` (LL / SC / VALIDATE).  This module
keeps the v1 surface — `SyncOpBatch` (its own kind numbering), `apply_sync`,
the `ll`/`sc`/`validate` convenience wrappers and the sequential oracle —
as deprecation shims: `apply_sync` translates the legacy batch and defers
to the unified engine.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bigatomic as ba
from repro.core import engine
from repro.core.deprecation import warn_once
from repro.core.engine import LinkCtx, init_ctx  # noqa: F401  (v1 re-exports)
from repro.core import semantics as sem

# Legacy sync op kinds (v1 numbering, distinct from the unified namespace;
# `_TO_UNIFIED` maps them onto engine.LL / engine.SC / engine.VALIDATE).
LL = 0     # load-linked: read value, link (slot, version)
SC = 1     # store-conditional: commit desired iff link still valid
VL = 2     # validate: is my link still valid?  (never writes)
IDLE = 3   # padding lane

_TO_UNIFIED = np.asarray(
    [engine.LL, engine.SC, engine.VALIDATE, engine.IDLE], np.int32)


class SyncOpBatch(NamedTuple):
    """Legacy batch of p sync ops.  kind: int32[p] (v1 numbering);
    slot: int32[p]; desired: word[p, k] (SC payload; ignored otherwise)."""

    kind: jax.Array
    slot: jax.Array
    desired: jax.Array

    @property
    def p(self) -> int:
        return self.kind.shape[0]


SyncResult = engine.ApplyResult


def make_sync_batch(kind, slot, desired=None, *, k: int) -> SyncOpBatch:
    kind = jnp.asarray(kind, jnp.int32)
    slot = jnp.asarray(slot, jnp.int32)
    p = kind.shape[0]
    if desired is None:
        desired = jnp.zeros((p, k), sem.WORD_DTYPE)
    return SyncOpBatch(kind, slot, jnp.asarray(desired, sem.WORD_DTYPE))


def to_unified(ops: SyncOpBatch, *, k: int) -> engine.OpBatch:
    """Translate a legacy sync batch into the unified op schema."""
    kind = jnp.asarray(_TO_UNIFIED)[jnp.clip(ops.kind, 0, 3)]
    return engine.make_ops(kind, ops.slot, desired=ops.desired, k=k)


# ---------------------------------------------------------------------------
# Sequential oracle (numpy) — THE definition of correctness.
# ---------------------------------------------------------------------------

def apply_sync_reference(data: np.ndarray, version: np.ndarray,
                         ctx: LinkCtx, ops: SyncOpBatch):
    """Apply sync ops one at a time in lane order.  Pure numpy, for tests.

    Returns (new_data, new_version, new_ctx, SyncResult-as-numpy).
    """
    data = np.array(data, copy=True)
    version = np.array(version, copy=True)
    c_slot = np.array(ctx.slot, copy=True)
    c_ver = np.array(ctx.version, copy=True)
    c_val = np.array(ctx.value, copy=True)
    c_lnk = np.array(ctx.linked, copy=True)
    kind = np.asarray(ops.kind)
    slot = np.asarray(ops.slot)
    desired = np.asarray(ops.desired)
    p, k = desired.shape
    value = np.zeros((p, k), data.dtype)
    success = np.zeros((p,), bool)
    for i in range(p):
        s = slot[i]
        if kind[i] == IDLE:
            continue
        cur = data[s].copy()
        value[i] = cur
        if kind[i] == LL:
            c_slot[i], c_ver[i], c_val[i], c_lnk[i] = \
                s, version[s], cur, True
            success[i] = True
        elif kind[i] == VL:
            success[i] = bool(c_lnk[i] and c_slot[i] == s
                              and c_ver[i] == version[s])
        elif kind[i] == SC:
            ok = bool(c_lnk[i] and c_slot[i] == s
                      and c_ver[i] == version[s])
            if ok:
                data[s] = desired[i]
                version[s] += 2
            c_lnk[i] = False            # any SC attempt consumes the link
            success[i] = ok
    new_ctx = LinkCtx(c_slot, c_ver, c_val, c_lnk)
    return data, version, new_ctx, SyncResult(value, success)


# ---------------------------------------------------------------------------
# DEPRECATED shims over the unified engine.
# ---------------------------------------------------------------------------

def _apply_unified(state, ctx, ops: SyncOpBatch, *, strategy: str, k: int):
    """The non-deprecated core: translate the legacy batch, run the unified
    engine.  Everything in repro.sync routes through here (never through the
    warning `apply_sync` shim) so tier-1 runs warning-free."""
    spec = ba._spec(state, strategy, k)
    return engine.apply(spec, state, to_unified(ops, k=k), ctx)


def apply_sync(state: ba.TableState, ctx: LinkCtx, ops: SyncOpBatch, *,
               strategy: str, k: int):
    """DEPRECATED shim: use `repro.atomics.apply(spec, state, ops, ctx)`
    with unified kinds.  Returns (state', ctx', SyncResult, stats, Traffic).
    Warns `DeprecationWarning` once per process.
    """
    warn_once("sync.llsc.apply_sync",
              "repro.atomics.apply(spec, state, ops, ctx)")
    return _apply_unified(state, ctx, ops, strategy=strategy, k=k)


def ll(state, ctx, slots, *, strategy: str, k: int):
    """Link every lane i to slots[i].  Returns (ctx', values)."""
    slots = jnp.asarray(slots, jnp.int32)
    ops = make_sync_batch(jnp.full(slots.shape, LL, jnp.int32), slots, k=k)
    _, ctx, res, _, _ = _apply_unified(state, ctx, ops, strategy=strategy,
                                       k=k)
    return ctx, res.value


def sc(state, ctx, slots, desired, *, strategy: str, k: int):
    """Conditionally commit desired[i] to slots[i].  Returns
    (state', ctx', success)."""
    slots = jnp.asarray(slots, jnp.int32)
    ops = make_sync_batch(jnp.full(slots.shape, SC, jnp.int32), slots,
                          desired, k=k)
    state, ctx, res, _, _ = _apply_unified(state, ctx, ops,
                                           strategy=strategy, k=k)
    return state, ctx, res.success


def validate(state, ctx, slots, *, strategy: str, k: int):
    """Is each lane's link still valid?  Returns bool[p]."""
    slots = jnp.asarray(slots, jnp.int32)
    ops = make_sync_batch(jnp.full(slots.shape, VL, jnp.int32), slots, k=k)
    _, _, res, _, _ = _apply_unified(state, ctx, ops, strategy=strategy, k=k)
    return res.success
