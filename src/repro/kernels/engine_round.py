"""The fused engine round: blocked fast-path / slow-path megakernels.

`core/engine.linearize` gives every batch the full slow-path pipeline — two
stable argsorts, four segmented scans and a `lax.while_loop` of masked
gather -> check -> scatter rounds — even when the batch is collision-free.
The paper's whole performance story (Schweizer et al., "Evaluating the Cost
of Atomic Operations") is that the *uncontended* path must be one cache-line
round trip; this module is that path made real at the XLA/Pallas level:

  fast path   When a batch has no intra-batch slot collisions (or is
              read-only, where collisions cannot matter), every lane is
              independent: ONE blocked pass gathers each lane's cell row,
              evaluates LOAD/STORE/CAS/LL/SC/VALIDATE in registers, and
              scatters data+version back — no sort, no scans, no rounds.
              On TPU this is a Pallas kernel (grid over lane tiles of
              `block` lanes, scalar-prefetched slot routing as in
              `cas_apply.py`, input/output aliasing, conditional write-back
              DMA); off-TPU it is the equivalent O(p) gather/compute/scatter
              XLA program.

  slow path   Contended batches sort by (slot, lane) once, then ONE Pallas
              pass replays the sorted lanes sequentially per cell segment:
              a cell row is DMA'd into VMEM at its segment start, all its
              ops apply in registers, and the row is written back at the
              segment end — each dirty cell makes exactly one HBM round
              trip instead of L gather/scatter rounds.  Off-TPU the slow
              path is `engine.linearize` itself (the pure-XLA reference).

  dispatch    `fast_path_ok` is one cheap duplicate-scatter check; a
              `lax.cond` picks the branch at runtime.  The predicate is
              conservative: any batch it cannot prove independent takes the
              slow path, so a colliding batch can NEVER take the fast
              kernel (property-tested in tests/test_engine_round.py).

Strategies opt in through `StrategyImpl.lower_round` (DESIGN.md §8); the
round returned by `make_round` is signature-compatible with
`engine.linearize` and bit-identical to it on every in-contract batch
(slots of active lanes inside [0, n); out-of-range active slots are
formally out of contract — the kernels treat them as failed no-ops, where
`linearize` reports a clamp-gathered value).

The fast path subsumes `kernels/llsc_commit`: a pure-SC batch over distinct
cells is exactly a collision-free batch with SC lanes, so the one-round SC
commit is just the fast kernel with link versions routed in (stale links
arrive poisoned odd and can never match an even cell version).
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import engine
from repro.core.engine import (
    ApplyResult, ApplyStats, CAS, IDLE, LL, LOAD, LinkCtx, OpBatch, SC,
    STORE, VALIDATE,
)

_ANY = pltpu.TPUMemorySpace.ANY

# Lane-tile width: 8 sublanes per grid step, so a block of k-word payloads
# is one (8, k<=128) register tile (ops.pad_cells lane-aligns k on TPU).
DEFAULT_BLOCK = 8

_MODES = ("auto", "pallas", "xla", "off")


def configured_mode() -> str:
    """The engine-kernel mode requested by the environment.

    BIGATOMIC_ENGINE_KERNEL = auto (default) | pallas | xla | off:
      auto    pallas on TPU backends, xla elsewhere;
      pallas  always use the Pallas kernels (interpret=True off-TPU — the
              CI kernel-exercise mode);
      xla     fused round with the pure-XLA fast path (the CPU production
              mode: still skips sort+scans on collision-free batches);
      off     pure `engine.linearize` everywhere (the pre-kernel engine).
    """
    mode = os.environ.get("BIGATOMIC_ENGINE_KERNEL", "auto")
    if mode not in _MODES:
        raise ValueError(f"BIGATOMIC_ENGINE_KERNEL={mode!r}; "
                         f"expected one of {_MODES}")
    return mode


def resolved_mode(mode: str | None = None) -> tuple[str, bool]:
    """Resolve `auto` against the backend.  Returns (mode, interpret)."""
    mode = mode or configured_mode()
    on_tpu = jax.default_backend() == "tpu"
    if mode == "auto":
        mode = "pallas" if on_tpu else "xla"
    return mode, not on_tpu


# ---------------------------------------------------------------------------
# The fast-path predicate: one duplicate-scatter check.
# ---------------------------------------------------------------------------

def fast_path_ok(n: int, ops: OpBatch) -> jax.Array:
    """True iff every lane of the batch is provably independent.

    Exactly when (a) every active slot is in [0, n), AND (b) the batch is
    read-only (no STORE/CAS/SC — reads and validates commute freely even on
    the same cell) OR no two active lanes share a slot (one scatter-add of
    lane counts, then a max).  False positives are impossible by
    construction: a colliding batch with any write fails (b), so it can
    never take the fast kernel."""
    kind, slot = ops.kind, ops.slot
    active = kind != IDLE
    in_range = (slot >= 0) & (slot < n)
    all_in = ~jnp.any(active & ~in_range)
    is_write = active & ((kind == STORE) | (kind == CAS) | (kind == SC))
    read_only = ~jnp.any(is_write)
    cslot = jnp.where(active & in_range, slot, n)
    counts = jnp.zeros((n + 1,), jnp.int32).at[cslot].add(1, mode="drop")
    no_dup = jnp.max(counts[:n], initial=0) <= 1
    return all_in & (read_only | no_dup)


def path_counts(n: int, ops: OpBatch, *, fused: bool):
    """(eligible, taken) for the telemetry tier (`repro.obs`).

    `eligible` is the fast-path predicate above; `taken` is the branch the
    `lax.cond` in `make_round` resolves this batch to — identical to the
    predicate when the fused round is in play, statically False otherwise
    (engine-kernel mode `off`, or a strategy with no lowered round, routes
    every batch through the slow-path `linearize`)."""
    eligible = fast_path_ok(n, ops)
    taken = eligible if fused else jnp.zeros((), bool)
    return eligible, taken


# ---------------------------------------------------------------------------
# Shared fast-path assembly: kernel/XLA producers feed the same epilogue.
# ---------------------------------------------------------------------------

def _poisoned_link_ver(ctx: LinkCtx, slot: jax.Array) -> jax.Array:
    """A lane's link version, odd-poisoned when the link cannot validate
    (dead link or link naming a different cell) — cell versions are always
    even, so a poisoned link never matches (the llsc_commit idiom)."""
    link_ok = ctx.linked & (ctx.slot == slot)
    return jnp.where(link_ok, ctx.version, jnp.uint32(1))


def _assemble_fast(n: int, ctx: LinkCtx, ops: OpBatch, link_ver, cur, ver,
                   okw, new_data, new_version):
    """Per-lane results / ctx / stats for an independent (fast-path) batch.

    cur/ver are each lane's pre-batch cell value+version; okw is write
    success for STORE/CAS/SC lanes (False elsewhere)."""
    kind = ops.kind
    active = kind != IDLE
    is_read = (kind == LOAD) | (kind == LL)
    is_valcas = active & ((kind == STORE) | (kind == CAS))
    is_sc = active & (kind == SC)
    is_upd = is_valcas | is_sc

    vl_ok = link_ver == ver                      # poisoned-odd never matches
    success = jnp.where(
        is_read | (kind == STORE), active,
        jnp.where(kind == VALIDATE, vl_ok,
                  jnp.where(is_upd, okw, False)))
    value = jnp.where(active[:, None], cur, jnp.zeros_like(cur))

    is_ll = (kind == LL) & active
    new_ctx = LinkCtx(
        slot=jnp.where(is_ll, ops.slot, ctx.slot),
        version=jnp.where(is_ll, ver, ctx.version),
        value=jnp.where(is_ll[:, None], cur, ctx.value),
        linked=jnp.where(is_ll, True,
                         jnp.where(kind == SC, False, ctx.linked)),
    )
    stats = ApplyStats(
        rounds=jnp.any(is_upd).astype(jnp.int32),
        n_updates=jnp.sum((is_valcas | (is_sc & okw)).astype(jnp.int32)),
        n_loads=jnp.sum((active & is_read).astype(jnp.int32)),
        n_cas_fail=jnp.sum((((kind == CAS) & active) | is_sc) & ~okw)
        .astype(jnp.int32),
        # No two lanes share a written cell on the fast path, so no load
        # ever races a write and every successful write dirties its own cell.
        n_raced_loads=jnp.int32(0),
        n_dirty_cells=jnp.sum(okw.astype(jnp.int32)),
    )
    return new_data, new_version, new_ctx, ApplyResult(value, success), stats


def _fast_xla(n: int, data, version, ctx: LinkCtx, ops: OpBatch):
    """Pure-XLA fast path: one gather, register math, one scatter.  No sort,
    no scans, no rounds — the off-TPU production fast path."""
    kind, slot = ops.kind, ops.slot
    active = kind != IDLE
    safe = jnp.clip(slot, 0, n - 1)
    cur = data[safe]
    ver = version[safe]
    match = jnp.all(cur == ops.expected, axis=1)
    link_ver = _poisoned_link_ver(ctx, slot)
    okw = active & ((kind == STORE) | ((kind == CAS) & match)
                    | ((kind == SC) & (link_ver == ver)))
    w_idx = jnp.where(okw, slot, n)
    new_data = data.at[w_idx].set(ops.desired, mode="drop")
    new_version = version.at[w_idx].add(jnp.uint32(2), mode="drop")
    return _assemble_fast(n, ctx, ops, link_ver, cur, ver, okw,
                          new_data, new_version)


# ---------------------------------------------------------------------------
# The blocked fast-path Pallas kernel.
# ---------------------------------------------------------------------------

def _fast_kernel(n: int, block: int):
    def kernel(slot_ref, kind_ref, linkver_ref, exp_ref, des_ref,
               data_hbm, ver_hbm, out_data, out_ver, wit_ref, verpt_ref,
               succ_ref, rows, vrows, sems, vsems, wsem):
        b = pl.program_id(0)

        def _gathers(j):
            # Dead (and out-of-contract) lanes clamp to row 0: the read is
            # masked out below, and a DMA must never index outside the
            # table (negative s would wrap in interpret mode and be a rogue
            # DMA on silicon).
            s = slot_ref[b * block + j]
            sd = jnp.clip(s, 0, n - 1)
            return (
                pltpu.make_async_copy(out_data.at[pl.ds(sd, 1)],
                                      rows.at[pl.ds(j, 1)], sems.at[j]),
                pltpu.make_async_copy(out_ver.at[pl.ds(sd, 1)],
                                      vrows.at[pl.ds(j, 1)], vsems.at[j]),
            )

        # Phase 1 — overlapped gather: all of the tile's row DMAs in flight
        # at once (fast-path contract: live lanes target distinct rows).
        def start(j, _):
            for cp in _gathers(j):
                cp.start()
            return 0

        def wait(j, _):
            for cp in _gathers(j):
                cp.wait()
            return 0

        lax.fori_loop(0, block, start, 0)
        lax.fori_loop(0, block, wait, 0)

        # Phase 2 — evaluate the whole tile in registers.
        slots = jnp.stack([slot_ref[b * block + j] for j in range(block)])
        live = (slots >= 0) & (slots < n)
        cv = rows[...]                               # [block, k]
        vr = vrows[...][:, 0]
        kd = kind_ref[...][:, 0]
        lv = linkver_ref[...][:, 0]
        match = jnp.all(cv == exp_ref[...], axis=1)
        okw = live & ((kd == STORE) | ((kd == CAS) & match)
                      | ((kd == SC) & (lv == vr)))
        wit_ref[...] = jnp.where(live[:, None], cv, jnp.zeros_like(cv))
        verpt_ref[...] = jnp.where(live, vr, jnp.uint32(0))[:, None]
        succ_ref[...] = okw.astype(jnp.int32)[:, None]
        rows[...] = jnp.where(okw[:, None], des_ref[...], cv)
        vrows[...] = (vr + jnp.uint32(2) * okw.astype(jnp.uint32))[:, None]

        # Phase 3 — write-back only the lanes that actually wrote (their
        # rows are distinct by the fast-path contract; serialized starts
        # keep the common mostly-read case cheap).
        def writeback(j, _):
            s = slot_ref[b * block + j]

            @pl.when(okw[j])
            def _():
                cp = pltpu.make_async_copy(
                    rows.at[pl.ds(j, 1)], out_data.at[pl.ds(s, 1)], wsem)
                cp.start()
                cp.wait()
                cp = pltpu.make_async_copy(
                    vrows.at[pl.ds(j, 1)], out_ver.at[pl.ds(s, 1)], wsem)
                cp.start()
                cp.wait()

            return 0

        lax.fori_loop(0, block, writeback, 0)

    return kernel


@functools.partial(jax.jit,
                   static_argnames=("block", "interpret"))
def fast_round_pallas(data, version, slot, kind, link_ver, expected, desired,
                      *, block: int = DEFAULT_BLOCK, interpret: bool = False):
    """One blocked fast-path pass.  data: word[n, k]; version: uint32[n];
    slot: int32[p] (inactive lanes -> n); link_ver: uint32[p] (odd-poisoned
    when the lane's link cannot validate).  Precondition: active lanes
    target distinct in-range slots (or the batch is read-only).

    Returns (data', version', witness[p, k], ver_pt[p], okw[p])."""
    n, k = data.shape
    p = slot.shape[0]
    pad = (-p) % block
    if pad:
        slot = jnp.concatenate([slot, jnp.full((pad,), n, jnp.int32)])
        kind = jnp.concatenate([kind, jnp.full((pad,), IDLE, jnp.int32)])
        link_ver = jnp.concatenate([link_ver, jnp.ones((pad,), jnp.uint32)])
        expected = jnp.concatenate(
            [expected, jnp.zeros((pad, k), expected.dtype)])
        desired = jnp.concatenate(
            [desired, jnp.zeros((pad, k), desired.dtype)])
    pp = p + pad

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(pp // block,),
        in_specs=[
            pl.BlockSpec((block, 1), lambda i, s: (i, 0)),     # kind
            pl.BlockSpec((block, 1), lambda i, s: (i, 0)),     # link ver
            pl.BlockSpec((block, k), lambda i, s: (i, 0)),     # expected
            pl.BlockSpec((block, k), lambda i, s: (i, 0)),     # desired
            pl.BlockSpec(memory_space=_ANY),                   # data
            pl.BlockSpec(memory_space=_ANY),                   # version
        ],
        out_specs=[
            pl.BlockSpec(memory_space=_ANY),                   # data back
            pl.BlockSpec(memory_space=_ANY),                   # version back
            pl.BlockSpec((block, k), lambda i, s: (i, 0)),     # witness
            pl.BlockSpec((block, 1), lambda i, s: (i, 0)),     # ver at point
            pl.BlockSpec((block, 1), lambda i, s: (i, 0)),     # write ok
        ],
        scratch_shapes=[
            pltpu.VMEM((block, k), data.dtype),
            pltpu.VMEM((block, 1), jnp.uint32),
            pltpu.SemaphoreType.DMA((block,)),
            pltpu.SemaphoreType.DMA((block,)),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    new_data, new_ver, wit, verpt, okw = pl.pallas_call(
        _fast_kernel(n, block),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, k), data.dtype),
            jax.ShapeDtypeStruct((n, 1), jnp.uint32),
            jax.ShapeDtypeStruct((pp, k), data.dtype),
            jax.ShapeDtypeStruct((pp, 1), jnp.uint32),
            jax.ShapeDtypeStruct((pp, 1), jnp.int32),
        ],
        # alias the table through: 0 = slot prefetch, then 4 blocked inputs,
        # so data=5, version=6
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(slot, kind.reshape(pp, 1), link_ver.reshape(pp, 1).astype(jnp.uint32),
      expected, desired, data, version.reshape(n, 1))
    return (new_data, new_ver.reshape(n), wit[:p], verpt[:p, 0], okw[:p, 0])


def _fast_pallas(n: int, data, version, ctx: LinkCtx, ops: OpBatch, *,
                 block: int, interpret: bool):
    slot = jnp.where(ops.kind != IDLE, ops.slot, n)
    link_ver = _poisoned_link_ver(ctx, ops.slot)
    new_data, new_version, wit, verpt, okw = fast_round_pallas(
        data, version, slot, ops.kind, link_ver, ops.expected, ops.desired,
        block=block, interpret=interpret)
    return _assemble_fast(n, ctx, ops, link_ver, wit, verpt, okw != 0,
                          new_data, new_version)


# ---------------------------------------------------------------------------
# The slow-path Pallas kernel: one sequential replay pass over sorted lanes.
# ---------------------------------------------------------------------------

def _slow_kernel(n: int, p: int, block: int):
    def kernel(slot_ref, kind_ref, linkver_ref, exp_ref, des_ref,
               data_hbm, ver_hbm, out_data, out_ver, valpt_ref, verpt_ref,
               succ_ref, row, vrow, sem):
        b = pl.program_id(0)

        def lane(j, _):
            g = b * block + j
            s = slot_ref[g]
            # Same out-of-contract guard as the fast kernel: a negative slot
            # must never become a DMA index.
            live = (s >= 0) & (s < n)
            prev = slot_ref[jnp.maximum(g - 1, 0)]
            nxt = slot_ref[jnp.minimum(g + 1, p - 1)]
            seg_start = (g == 0) | (s != prev)
            seg_end = (g == p - 1) | (s != nxt)

            @pl.when(live)
            def _():
                # Segment start: the cell row makes its ONE trip into VMEM.
                @pl.when(seg_start)
                def _():
                    cp = pltpu.make_async_copy(
                        out_data.at[pl.ds(s, 1)], row, sem)
                    cp.start()
                    cp.wait()
                    cp = pltpu.make_async_copy(
                        out_ver.at[pl.ds(s, 1)], vrow, sem)
                    cp.start()
                    cp.wait()

                cv = row[...]
                vr = vrow[0, 0]
                kd = kind_ref[j, 0]
                match = jnp.all(cv == exp_ref[pl.ds(j, 1), :])
                link_ok = linkver_ref[j, 0] == vr
                okw = ((kd == STORE) | ((kd == CAS) & match)
                       | ((kd == SC) & link_ok))
                succ = ((kd == LOAD) | (kd == STORE) | (kd == LL)
                        | ((kd == VALIDATE) & link_ok)
                        | (((kd == CAS) | (kd == SC)) & okw))
                valpt_ref[pl.ds(j, 1), :] = cv
                verpt_ref[pl.ds(j, 1), :] = vrow[...]
                succ_ref[pl.ds(j, 1), :] = succ.astype(jnp.int32)[None, None]
                row[...] = jnp.where(okw, des_ref[pl.ds(j, 1), :], cv)
                vrow[0, 0] = vr + jnp.uint32(2) * okw.astype(jnp.uint32)

                # Segment end: write the (possibly dirty) row back.
                @pl.when(seg_end)
                def _():
                    cp = pltpu.make_async_copy(
                        row, out_data.at[pl.ds(s, 1)], sem)
                    cp.start()
                    cp.wait()
                    cp = pltpu.make_async_copy(
                        vrow, out_ver.at[pl.ds(s, 1)], sem)
                    cp.start()
                    cp.wait()

            @pl.when(~live)
            def _():
                valpt_ref[pl.ds(j, 1), :] = jnp.zeros(
                    (1, valpt_ref.shape[1]), valpt_ref.dtype)
                verpt_ref[pl.ds(j, 1), :] = jnp.zeros(
                    (1, 1), verpt_ref.dtype)
                succ_ref[pl.ds(j, 1), :] = jnp.zeros((1, 1), jnp.int32)

            return 0

        lax.fori_loop(0, block, lane, 0)

    return kernel


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def slow_round_pallas(data, version, s_slot, s_kind, s_link_ver, s_expected,
                      s_desired, *, block: int = DEFAULT_BLOCK,
                      interpret: bool = False):
    """One fused sequential-replay pass over lanes SORTED by (slot, lane).

    Fuses the per-segment arbitration and all L combining rounds of
    `engine.linearize._general` into one kernel: per-cell segment metadata
    is derived from the scalar-prefetched sorted slots, a segment's cell row
    is DMA'd in once, every op of the segment applies in registers (full
    LOAD/STORE/CAS/LL/SC/VALIDATE semantics), and the row is written back
    once — replacing the gather -> check -> scatter `while_loop` round
    trips.  The per-lane DMAs here are deliberately serialized: the replay
    is sequential by definition (lane j+1 may read what lane j wrote), so
    only the blocked op tiles pipeline across grid steps.

    Returns (data', version', val_pt[p, k], ver_pt[p], success[p]) in the
    SORTED lane order."""
    n, k = data.shape
    p = s_slot.shape[0]
    pad = (-p) % block
    if pad:
        # Padding lanes are dead (slot n) and sort AFTER every live lane, so
        # they never split a real segment.
        s_slot = jnp.concatenate([s_slot, jnp.full((pad,), n, jnp.int32)])
        s_kind = jnp.concatenate([s_kind, jnp.full((pad,), IDLE, jnp.int32)])
        s_link_ver = jnp.concatenate(
            [s_link_ver, jnp.ones((pad,), jnp.uint32)])
        s_expected = jnp.concatenate(
            [s_expected, jnp.zeros((pad, k), s_expected.dtype)])
        s_desired = jnp.concatenate(
            [s_desired, jnp.zeros((pad, k), s_desired.dtype)])
    pp = p + pad

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(pp // block,),
        in_specs=[
            pl.BlockSpec((block, 1), lambda i, s: (i, 0)),     # kind
            pl.BlockSpec((block, 1), lambda i, s: (i, 0)),     # link ver
            pl.BlockSpec((block, k), lambda i, s: (i, 0)),     # expected
            pl.BlockSpec((block, k), lambda i, s: (i, 0)),     # desired
            pl.BlockSpec(memory_space=_ANY),                   # data
            pl.BlockSpec(memory_space=_ANY),                   # version
        ],
        out_specs=[
            pl.BlockSpec(memory_space=_ANY),
            pl.BlockSpec(memory_space=_ANY),
            pl.BlockSpec((block, k), lambda i, s: (i, 0)),     # value at pt
            pl.BlockSpec((block, 1), lambda i, s: (i, 0)),     # ver at pt
            pl.BlockSpec((block, 1), lambda i, s: (i, 0)),     # success
        ],
        scratch_shapes=[
            pltpu.VMEM((1, k), data.dtype),
            pltpu.VMEM((1, 1), jnp.uint32),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    new_data, new_ver, valpt, verpt, succ = pl.pallas_call(
        _slow_kernel(n, pp, block),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n, k), data.dtype),
            jax.ShapeDtypeStruct((n, 1), jnp.uint32),
            jax.ShapeDtypeStruct((pp, k), data.dtype),
            jax.ShapeDtypeStruct((pp, 1), jnp.uint32),
            jax.ShapeDtypeStruct((pp, 1), jnp.int32),
        ],
        input_output_aliases={5: 0, 6: 1},
        interpret=interpret,
    )(s_slot, s_kind.reshape(pp, 1),
      s_link_ver.reshape(pp, 1).astype(jnp.uint32), s_expected, s_desired,
      data, version.reshape(n, 1))
    return (new_data, new_ver.reshape(n), valpt[:p], verpt[:p, 0],
            succ[:p, 0])


def _slow_pallas(n: int, data, version, ctx: LinkCtx, ops: OpBatch, *,
                 block: int, interpret: bool):
    """Sort once, replay in one kernel pass, then rebuild ctx/result/stats
    exactly as `linearize` defines them (two cheap scans; no while_loop)."""
    p, k = ops.desired.shape
    kind = ops.kind
    active = kind != IDLE
    slot = jnp.where(active, ops.slot, n)
    order = jnp.argsort(slot, stable=True)
    inv = jnp.argsort(order, stable=True)

    s_slot = slot[order]
    s_kind = kind[order]
    s_link_ver = _poisoned_link_ver(ctx, ops.slot)[order]

    new_data, new_version, val_s, verpt_s, succ_i = slow_round_pallas(
        data, version, s_slot, s_kind, s_link_ver, ops.expected[order],
        ops.desired[order], block=block, interpret=interpret)
    s_success = succ_i != 0

    is_ll = (s_kind == LL) & (s_slot < n)
    n_slot = jnp.where(is_ll, s_slot, ctx.slot[order])
    n_ver = jnp.where(is_ll, verpt_s, ctx.version[order])
    n_val = jnp.where(is_ll[:, None], val_s, ctx.value[order])
    n_lnk = jnp.where(is_ll, True,
                      jnp.where(s_kind == SC, False, ctx.linked[order]))
    new_ctx = LinkCtx(n_slot[inv], n_ver[inv], n_val[inv], n_lnk[inv])
    s_value = jnp.where((s_kind != IDLE)[:, None], val_s,
                        jnp.zeros_like(val_s))
    result = ApplyResult(s_value[inv], s_success[inv])

    # Stats: the single sorted-order definition shared with `linearize`.
    stats = engine.stats_on_sorted(n, s_slot, s_kind, s_success)
    return new_data, new_version, new_ctx, result, stats


# ---------------------------------------------------------------------------
# The round factory: what StrategyImpl.lower_round hands the engine.
# ---------------------------------------------------------------------------

def make_round(n: int, k: int, *, mode: str | None = None,
               interpret: bool | None = None, block: int = DEFAULT_BLOCK):
    """Build a fused round callable, signature-compatible with
    `engine.linearize`: (data, version, ctx, ops) ->
    (data', version', ctx', ApplyResult, ApplyStats).

    mode  'xla'    runtime fast path in pure XLA, `linearize` slow path;
          'pallas' blocked Pallas fast + slow kernels (interpret off-TPU);
          'off'/None resolves via `resolved_mode()`.
    """
    r_mode, r_interp = resolved_mode(mode)
    if interpret is None:
        interpret = r_interp
    if r_mode == "off":
        return engine.linearize

    def round_fn(data, version, ctx: LinkCtx, ops: OpBatch):
        # linearize gathers ctx lanes by sorted lane index, which for a ctx
        # wider than the batch means "the first p lanes"; replicate that so
        # both tiers see (and return) batch-width ctx exactly as it does.
        if ctx.slot.shape[0] != ops.p:
            ctx = LinkCtx(ctx.slot[:ops.p], ctx.version[:ops.p],
                          ctx.value[:ops.p], ctx.linked[:ops.p])
        take_fast = fast_path_ok(n, ops)
        if r_mode == "pallas":
            fast = functools.partial(_fast_pallas, n, block=block,
                                     interpret=interpret)
            slow = functools.partial(_slow_pallas, n, block=block,
                                     interpret=interpret)
        else:
            fast = functools.partial(_fast_xla, n)

            def slow(data, version, ctx, ops):
                return engine.linearize(data, version, ctx, ops)

        return lax.cond(take_fast, fast, slow, data, version, ctx, ops)

    return round_fn
