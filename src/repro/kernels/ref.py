"""Pure-jnp oracles for every Pallas kernel (the definition of correctness).

Each function computes exactly what the corresponding kernel computes, with
plain gathers — tests sweep shapes/dtypes and assert bit-equality against
the interpret-mode kernels.  `indirect_gather` additionally models the
paper's INDIRECT strategy (two dependent gathers) for the benchmarks.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.cas_apply import CAS, STORE


def seqlock_gather_ref(data, meta, idx):
    """(values[q,k], ok[q,1]) — fast-path load with validity check."""
    vals = data[idx]
    ver = meta[idx, 0]
    mark = meta[idx, 1]
    ok = ((ver % 2 == 0) & (mark == 0)).astype(jnp.int32)[:, None]
    return vals, ok


def indirect_gather_ref(ptr, pool, idx):
    """INDIRECT load: gather the pointer, then gather the node it names.
    Two *dependent* gathers — the traffic/latency baseline CacheHash beats."""
    node = ptr[idx]
    return pool[node]


def cas_apply_round_ref(data, meta, slot, kind, expected, desired):
    """Sequential oracle of one conflict-free round (slots distinct or dummy).

    Returns (data', meta', success[p,1], witness[p,k])."""
    import numpy as np
    data = np.array(data, copy=True)
    meta = np.array(meta, copy=True)
    slot = np.asarray(slot)
    kind = np.asarray(kind).reshape(-1)
    expected = np.asarray(expected)
    desired = np.asarray(desired)
    p, k = expected.shape
    succ = np.zeros((p, 1), np.int32)
    wit = np.zeros((p, k), data.dtype)
    for i in range(p):
        s = slot[i]
        cur = data[s].copy()
        wit[i] = cur
        live = kind[i] in (STORE, CAS)
        ok = live and (kind[i] == STORE or np.array_equal(cur, expected[i]))
        if ok:
            data[s] = desired[i]
            meta[s, 0] += 2
            succ[i, 0] = 1
    return (jnp.asarray(data), jnp.asarray(meta), jnp.asarray(succ),
            jnp.asarray(wit))


def llsc_commit_round_ref(data, meta, slot, live, link_ver, desired):
    """Sequential oracle of one fused SC commit round (distinct live slots).

    Returns (data', meta', success[p,1], witness[p,k])."""
    import numpy as np
    data = np.array(data, copy=True)
    meta = np.array(meta, copy=True)
    slot = np.asarray(slot)
    live = np.asarray(live).reshape(-1)
    link_ver = np.asarray(link_ver).reshape(-1)
    desired = np.asarray(desired)
    p, k = desired.shape
    succ = np.zeros((p, 1), np.int32)
    wit = np.zeros((p, k), data.dtype)
    for i in range(p):
        s = slot[i]
        cur = data[s].copy()
        wit[i] = cur
        ok = bool(live[i]) and meta[s, 0] == link_ver[i]
        if ok:
            data[s] = desired[i]
            meta[s, 0] += 2
            succ[i, 0] = 1
    return (jnp.asarray(data), jnp.asarray(meta), jnp.asarray(succ),
            jnp.asarray(wit))


def cachehash_probe_ref(cells, bucket_idx, query_keys, *, kw, vw):
    """(hit[q,1], empty[q,1], value[q,vw], next[q,1])."""
    from repro.kernels.cachehash_probe import FULL
    cell = cells[bucket_idx]                     # [q, cw]
    key = cell[:, :kw]
    value = cell[:, kw:kw + vw]
    nxt = cell[:, kw + vw].astype(jnp.int32)[:, None]
    flags = cell[:, kw + vw + 1]
    is_full = flags == FULL
    hit = (is_full & jnp.all(key == query_keys, axis=1)).astype(jnp.int32)
    empty = (~is_full).astype(jnp.int32)
    return hit[:, None], empty[:, None], value, nxt
