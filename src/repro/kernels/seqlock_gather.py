"""Pallas TPU kernel: version-validated k-word cell gather (the fast path).

This is the paper's whole point made into silicon-shaped code: a big-atomic
load is ONE contiguous cell read (data row + 2 metadata words) — no pointer
chase.  On TPU the k-word cell lives in HBM as a row of a [n, k] array;
indices arrive as scalar-prefetched SMEM values so each grid step's BlockSpec
index_map selects the row to DMA into VMEM.  Pallas double-buffers the row
DMAs across grid steps, so the gather is a single pipelined HBM stream —
exactly the "one cache miss, pipelineable" property the paper's cached fast
path buys over INDIRECT's two dependent misses (which on TPU would be two
*serialized* DMA waves: see indirect_gather in ref.py and the benchmark).

Layout notes (TPU adaptation):
  * cells are rows; k is padded by ops.py to a multiple of the 128-lane
    register width so each row DMA is lane-aligned;
  * the two metadata words (version, invalid-mark) are a [n, 2] array — on
    real silicon they share the cell's first cache line; here they ride a
    second tiny BlockSpec stream;
  * validation (version even && mark clear) is elementwise in VMEM; the
    caller falls back to the backup pool for !ok rows (slow path, rare).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, data_ref, meta_ref, out_ref, ok_ref):
    # one cell per grid step: data_ref is the [1, k] row selected by idx
    out_ref[...] = data_ref[...]
    ver = meta_ref[0, 0]
    mark = meta_ref[0, 1]
    valid = jnp.logical_and(ver % 2 == 0, mark == 0)
    ok_ref[0, 0] = valid.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def seqlock_gather(data: jax.Array, meta: jax.Array, idx: jax.Array,
                   *, interpret: bool = False):
    """data: uint32[n, k] (k lane-aligned); meta: uint32[n, 2] =
    (version, mark); idx: int32[q].  Returns (values uint32[q, k],
    ok int32[q, 1]) — ok=0 rows must take the slow path."""
    n, k = data.shape
    q = idx.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(q,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, idx_ref: (idx_ref[i], 0)),
            pl.BlockSpec((1, 2), lambda i, idx_ref: (idx_ref[i], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, idx_ref: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, idx_ref: (i, 0)),
        ],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((q, k), data.dtype),
            jax.ShapeDtypeStruct((q, 1), jnp.int32),
        ],
        interpret=interpret,
    )(idx, data, meta)
