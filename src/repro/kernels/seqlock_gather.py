"""Pallas TPU kernel: version-validated k-word cell gather (the fast path).

This is the paper's whole point made into silicon-shaped code: a big-atomic
load is ONE contiguous cell read (data row + 2 metadata words) — no pointer
chase.  On TPU the k-word cell lives in HBM as a row of a [n, k] array; the
query indices arrive scalar-prefetched in SMEM, and each grid step owns a
*tile of `block` lanes* (8 sublanes x the lane-aligned k, the native (8, 128)
register tile once ops.py pads k): the kernel starts ALL of the tile's row
DMAs from the HBM-resident table before waiting on any (a per-lane
semaphore array keeps `block` copies in flight), so the gather is an
overlapped HBM stream at `ceil(q / block)` grid steps instead of the
historical one-lane-per-step shape with one dependent round trip per lane.

Layout notes (TPU adaptation):
  * cells are rows; k is padded by ops.py to a multiple of the 128-lane
    register width so each row DMA is lane-aligned;
  * the two metadata words (version, invalid-mark) are a [n, 2] array — on
    real silicon they share the cell's first cache line; here they ride a
    per-lane scratch DMA;
  * validation (version even && mark clear) is elementwise in VMEM; the
    caller falls back to the backup pool for !ok rows (slow path, rare).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ANY = pltpu.TPUMemorySpace.ANY

BLOCK = 8


def _kernel(n: int, block: int):
    def kernel(idx_ref, data_ref, meta_ref, out_ref, ok_ref, mrows,
               sems, msems):
        b = pl.program_id(0)

        def _copies(j):
            row = idx_ref[b * block + j]
            return (
                pltpu.make_async_copy(data_ref.at[pl.ds(row, 1)],
                                      out_ref.at[pl.ds(j, 1)], sems.at[j]),
                pltpu.make_async_copy(meta_ref.at[pl.ds(row, 1)],
                                      mrows.at[pl.ds(j, 1)], msems.at[j]),
            )

        # Start ALL of the tile's row DMAs before waiting on any: the
        # per-lane semaphore array keeps `block` copies in flight, so the
        # gather is an overlapped HBM stream, not 2q dependent round trips.
        def start(j, _):
            for cp in _copies(j):
                cp.start()
            return 0

        def wait(j, _):
            for cp in _copies(j):
                cp.wait()
            return 0

        lax.fori_loop(0, block, start, 0)
        lax.fori_loop(0, block, wait, 0)
        meta = mrows[...]
        valid = jnp.logical_and(meta[:, :1] % 2 == 0, meta[:, 1:2] == 0)
        ok_ref[...] = valid.astype(jnp.int32)

    return kernel


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def seqlock_gather(data: jax.Array, meta: jax.Array, idx: jax.Array,
                   *, block: int = BLOCK, interpret: bool = False):
    """data: uint32[n, k] (k lane-aligned); meta: uint32[n, 2] =
    (version, mark); idx: int32[q] in [0, n).  Returns (values uint32[q, k],
    ok int32[q, 1]) — ok=0 rows must take the slow path."""
    n, k = data.shape
    q = idx.shape[0]
    pad = (-q) % block
    if pad:
        idx = jnp.concatenate([idx, jnp.zeros((pad,), jnp.int32)])
    qq = q + pad

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qq // block,),
        in_specs=[
            pl.BlockSpec(memory_space=_ANY),                  # data (HBM)
            pl.BlockSpec(memory_space=_ANY),                  # meta (HBM)
        ],
        out_specs=[
            pl.BlockSpec((block, k), lambda i, s: (i, 0)),    # values tile
            pl.BlockSpec((block, 1), lambda i, s: (i, 0)),    # ok tile
        ],
        scratch_shapes=[
            pltpu.VMEM((block, 2), jnp.uint32),
            pltpu.SemaphoreType.DMA((block,)),
            pltpu.SemaphoreType.DMA((block,)),
        ],
    )
    vals, ok = pl.pallas_call(
        _kernel(n, block),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qq, k), data.dtype),
            jax.ShapeDtypeStruct((qq, 1), jnp.int32),
        ],
        interpret=interpret,
    )(idx, data, meta)
    return vals[:q], ok[:q]
