"""Pallas TPU kernel: CacheHash bucket probe with inlined first link.

The paper's CacheHash inlines the first chain link into the bucket array so
the common case (hit on the first link, or miss on an empty bucket) costs ONE
memory access.  On TPU that access is one row DMA of the bucket cell

    cell = [key_words | value_words | next | flags | version | pad]

selected by a scalar-prefetched bucket index (hash computed by the host
wrapper).  The kernel compares the inlined key against the query in VMEM and
emits (hit, empty, value, next) — the chain walk for the <load-factor>-rare
collision case stays in the jnp wrapper, exactly like the paper's slow path.

The no-inline Chaining baseline (ref.py) needs a bucket-head gather AND a
dependent node gather per probe — two serialized DMA waves.  The benchmark
measures both and reports the byte/dependency-depth delta.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# flags word values (matches core.cachehash)
EMPTY = 0
FULL = 1


def make_probe_kernel(kw: int, vw: int):
    """Specialize the kernel on (key words, value words) — static layout."""

    def kernel(bkt_ref, cells_ref, query_ref,
               hit_ref, empty_ref, val_ref, next_ref):
        cell = cells_ref[...]                    # [1, cw]
        q = query_ref[...]                       # [1, kw]
        key = cell[:, :kw]
        value = cell[:, kw:kw + vw]
        nxt = cell[0, kw + vw].astype(jnp.int32)
        flags = cell[0, kw + vw + 1]
        is_full = flags == FULL
        match = jnp.logical_and(is_full, jnp.all(key == q))
        hit_ref[0, 0] = match.astype(jnp.int32)
        empty_ref[0, 0] = jnp.logical_not(is_full).astype(jnp.int32)
        val_ref[...] = value
        next_ref[0, 0] = nxt

    return kernel


@functools.partial(jax.jit, static_argnames=("kw", "vw", "interpret"))
def cachehash_probe(cells: jax.Array, bucket_idx: jax.Array,
                    query_keys: jax.Array, *, kw: int, vw: int,
                    interpret: bool = False):
    """cells: uint32[m, cw] bucket array (cw >= kw+vw+2);
    bucket_idx: int32[q] (host-computed hash); query_keys: uint32[q, kw].

    Returns (hit int32[q,1], empty int32[q,1], value uint32[q,vw],
             next int32[q,1])."""
    m, cw = cells.shape
    qn = bucket_idx.shape[0]
    kernel = make_probe_kernel(kw, vw)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qn,),
        in_specs=[
            pl.BlockSpec((1, cw), lambda i, b: (b[i], 0)),   # bucket cell
            pl.BlockSpec((1, kw), lambda i, b: (i, 0)),      # query key
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i, b: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, b: (i, 0)),
            pl.BlockSpec((1, vw), lambda i, b: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, b: (i, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((qn, 1), jnp.int32),
            jax.ShapeDtypeStruct((qn, 1), jnp.int32),
            jax.ShapeDtypeStruct((qn, vw), cells.dtype),
            jax.ShapeDtypeStruct((qn, 1), jnp.int32),
        ],
        interpret=interpret,
    )(bucket_idx, cells, query_keys)
