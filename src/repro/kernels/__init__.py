"""Pallas TPU kernels for the paper's hot paths.

  engine_round     — THE fused engine round: blocked fast-path kernel for
                     collision-free batches + sorted sequential-replay slow
                     kernel, dispatched by one duplicate-scatter predicate
                     (DESIGN.md §8; strategies plug it in via lower_round)
  seqlock_gather   — version-validated k-word cell gather (the fast path)
  cas_apply        — one conflict-free combining round of store/CAS
  cachehash_probe  — CacheHash bucket probe with inlined first link
  llsc_commit      — fused validate+commit SC round (subsumed by
                     engine_round's fast path; kept for direct kernel use)

ops.py holds the jit'd wrappers (interpret-mode on CPU), ref.py the pure-jnp
oracles that define correctness.
"""

from repro.kernels.cachehash_probe import cachehash_probe  # noqa: F401
from repro.kernels.cas_apply import cas_apply_round  # noqa: F401
from repro.kernels.engine_round import (  # noqa: F401
    fast_path_ok, fast_round_pallas, make_round, slow_round_pallas,
)
from repro.kernels.seqlock_gather import seqlock_gather  # noqa: F401
