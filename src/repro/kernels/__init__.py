"""Pallas TPU kernels for the paper's hot paths.

  seqlock_gather   — version-validated k-word cell gather (the fast path)
  cas_apply        — one conflict-free combining round of store/CAS
  cachehash_probe  — CacheHash bucket probe with inlined first link

ops.py holds the jit'd wrappers (interpret-mode on CPU), ref.py the pure-jnp
oracles that define correctness.
"""

from repro.kernels.cachehash_probe import cachehash_probe  # noqa: F401
from repro.kernels.cas_apply import cas_apply_round  # noqa: F401
from repro.kernels.seqlock_gather import seqlock_gather  # noqa: F401
