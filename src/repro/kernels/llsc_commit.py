"""Pallas TPU kernel: fused validate + conditional-commit for one SC round.

`repro.sync.llsc` proves that an SC batch linearizes in ONE round (at most
one SC per cell can succeed per batch), so the whole commit is a single
embarrassingly-parallel pass once same-cell losers are filtered: for each
live lane, validate the link (`meta[slot,0] == link_version`) and, iff it
holds, write the k-word payload and bump the version — fused so the cell row
makes one trip through VMEM instead of a validate gather followed by a
separate commit scatter.

Same BlockSpec routing idiom as `cas_apply.py`: grid step i owns lane i, the
scalar-prefetched slot vector routes the cell's data and meta rows in and
back out via input/output aliasing.  Host contract (mirrors cas_apply's
round invariant): live lanes target DISTINCT cells; dead lanes point at the
reserved dummy row n and benignly rewrite it.  CAS-failure semantics are an
idempotent write-back of the unchanged row (no conditional DMA on TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.registry import get_strategy
from repro.core.specs import AtomicSpec


def _kernel(slot_ref, data_ref, meta_ref, live_ref, ver_ref, des_ref,
            out_data_ref, out_meta_ref, succ_ref, wit_ref):
    cur = data_ref[...]                       # [1, k] current cell value
    live = live_ref[0, 0] != 0
    ver = meta_ref[0, 0]
    ok = jnp.logical_and(live, ver == ver_ref[0, 0])   # link still valid?
    out_data_ref[...] = jnp.where(ok, des_ref[...], cur)
    out_meta_ref[0, 0] = ver + 2 * ok.astype(jnp.uint32)
    out_meta_ref[0, 1] = meta_ref[0, 1]
    succ_ref[0, 0] = ok.astype(jnp.int32)
    wit_ref[...] = cur


@functools.partial(jax.jit, static_argnames=("interpret",))
def llsc_commit_round(data: jax.Array, meta: jax.Array, slot: jax.Array,
                      live: jax.Array, link_ver: jax.Array,
                      desired: jax.Array, *, interpret: bool = False):
    """One fused SC commit round.  data: uint32[n+1, k] (row n = dummy);
    meta: uint32[n+1, 2] (word0 = version); slot: int32[p] (dead lanes -> n);
    live: int32[p]; link_ver: uint32[p]; desired: uint32[p, k].

    Returns (data', meta', success int32[p,1], witness uint32[p,k]).
    Live slots must be distinct (winners of the jnp eligibility pass, or
    cells known disjoint by construction, e.g. a queue's head/tail cells).
    """
    n1, k = data.shape
    p = slot.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, s: (s[i], 0)),    # data row
            pl.BlockSpec((1, 2), lambda i, s: (s[i], 0)),    # meta row
            pl.BlockSpec((1, 1), lambda i, s: (i, 0)),       # live flag
            pl.BlockSpec((1, 1), lambda i, s: (i, 0)),       # link version
            pl.BlockSpec((1, k), lambda i, s: (i, 0)),       # desired
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, s: (s[i], 0)),    # data row back
            pl.BlockSpec((1, 2), lambda i, s: (s[i], 0)),    # meta row back
            pl.BlockSpec((1, 1), lambda i, s: (i, 0)),       # success
            pl.BlockSpec((1, k), lambda i, s: (i, 0)),       # witness
        ],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n1, k), data.dtype),
            jax.ShapeDtypeStruct((n1, 2), meta.dtype),
            jax.ShapeDtypeStruct((p, 1), jnp.int32),
            jax.ShapeDtypeStruct((p, k), data.dtype),
        ],
        # aliasing indices count ALL inputs incl. the scalar-prefetch operand
        # (slot=0), so data=1, meta=2
        input_output_aliases={1: 0, 2: 1},
        interpret=interpret,
    )(slot, data, meta, live.reshape(p, 1).astype(jnp.int32),
      link_ver.reshape(p, 1).astype(meta.dtype), desired)


# ---------------------------------------------------------------------------
# Spec-routed entry point (v2 API): table in, table out.
# ---------------------------------------------------------------------------

def commit_round(spec: AtomicSpec, state, ctx, slots, desired, *,
                 interpret: bool = False):
    """Run one fused SC commit round against a `TableState`, routed by spec.

    Since the engine grew its own fused round (DESIGN.md §8) this entry
    point is SUBSUMED by the fast-path kernel: a pure-SC batch over distinct
    cells is exactly a collision-free batch, so the round dispatches through
    `repro.kernels.engine_round` (the strategy's lowered round in the
    resolved engine-kernel mode; `interpret=True` forces the Pallas kernels
    in interpret mode, the test configuration).  The standalone
    `llsc_commit_round` kernel above is kept for direct kernel tests and
    non-engine callers.  Caller contract (one-SC-per-cell fast path,
    DESIGN.md §4): live lanes target DISTINCT cells; dead lanes carry
    slot == spec.n.

    Returns (state', ctx', success bool[p], witness word[p, k]).
    """
    from repro.core import engine
    from repro.kernels import engine_round

    impl = get_strategy(spec.strategy)
    n, k = spec.n, spec.k
    slots = jnp.asarray(slots, jnp.int32)
    p = slots.shape[0]
    kind = jnp.where(slots < n, engine.SC, engine.IDLE)
    ops = engine.OpBatch(kind, slots, jnp.zeros((p, k), state.data.dtype),
                         jnp.asarray(desired, state.data.dtype))
    round_fn = engine_round.make_round(
        n, k, mode="pallas" if interpret else None,
        interpret=True if interpret else None)
    new_data, new_version, new_ctx, result, stats = round_fn(
        impl.engine_view(state), state.version, ctx, ops)
    new_state = impl.commit(state, new_data, new_version,
                            stats.n_updates, p)
    return new_state, new_ctx, result.success, result.value
