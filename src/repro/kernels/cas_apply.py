"""Pallas TPU kernel: one combining round of batched store/CAS.

The deterministic linearization in `repro.core.semantics` serializes updates
to the same cell into rounds; *within* one round every live op targets a
distinct cell, so a round is an embarrassingly parallel
gather -> compare -> conditional write-back.  This kernel is that round,
executed as *lane tiles*: grid step b owns `block` ops (8 sublanes x the
lane-aligned k words = the native TPU (8, 128) register tile once ops.py
pads k).  The table stays HBM-resident; the tile's cell and metadata rows
are gathered with OVERLAPPED DMAs (all `block` copies started before any
wait, per-lane semaphores), the whole tile is evaluated in registers at
once, and rows are written back in place through input/output aliasing
(write-back is serialized per lane because dead lanes share the dummy
row) — `ceil(p / block)` grid steps instead of the historical p single-row
steps, with the gather phase an overlapped HBM stream.

Dead lanes (ops not live in this round) are pointed at a reserved dummy row
n by the host wrapper; they rewrite that row with its own contents (benign).
Write-back of the *unchanged* value on CAS failure keeps the dataflow static
— the moral equivalent of the paper's compare_exchange leaving memory
untouched, expressed as an idempotent store (TPU has no conditional DMA).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_ANY = pltpu.TPUMemorySpace.ANY

STORE = 1
CAS = 2

BLOCK = 8


def _kernel(block: int):
    def kernel(slot_ref, kind_ref, exp_ref, des_ref, data_in, meta_in,
               out_data, out_meta, succ_ref, wit_ref, rows, mrows,
               sems, msems, wsem):
        b = pl.program_id(0)

        def _gathers(j):
            s = slot_ref[b * block + j]
            return (
                pltpu.make_async_copy(out_data.at[pl.ds(s, 1)],
                                      rows.at[pl.ds(j, 1)], sems.at[j]),
                pltpu.make_async_copy(out_meta.at[pl.ds(s, 1)],
                                      mrows.at[pl.ds(j, 1)], msems.at[j]),
            )

        # Phase 1 — overlapped gather: start ALL of the tile's row DMAs
        # before waiting on any (within a round live slots are distinct;
        # dead lanes share the dummy row, and concurrent reads are benign).
        def start(j, _):
            for cp in _gathers(j):
                cp.start()
            return 0

        def wait(j, _):
            for cp in _gathers(j):
                cp.wait()
            return 0

        lax.fori_loop(0, block, start, 0)
        lax.fori_loop(0, block, wait, 0)

        # Phase 2 — evaluate the whole tile in registers.
        cur = rows[...]                            # [block, k]
        kind = kind_ref[...][:, 0]
        live = jnp.logical_or(kind == STORE, kind == CAS)
        match = jnp.all(cur == exp_ref[...], axis=1)
        ok = jnp.logical_and(live, jnp.logical_or(kind == STORE, match))
        wit_ref[...] = cur
        succ_ref[...] = ok.astype(jnp.int32)[:, None]
        rows[...] = jnp.where(ok[:, None], des_ref[...], cur)
        meta = mrows[...]
        mrows[...] = meta.at[:, 0].add(jnp.uint32(2) *
                                       ok.astype(jnp.uint32))

        # Phase 3 — write-back, serialized per lane: dead lanes all rewrite
        # the shared dummy row, so their stores must not be in flight
        # together.
        def writeback(j, _):
            s = slot_ref[b * block + j]
            cp = pltpu.make_async_copy(
                rows.at[pl.ds(j, 1)], out_data.at[pl.ds(s, 1)], wsem)
            cp.start()
            cp.wait()
            cp = pltpu.make_async_copy(
                mrows.at[pl.ds(j, 1)], out_meta.at[pl.ds(s, 1)], wsem)
            cp.start()
            cp.wait()
            return 0

        lax.fori_loop(0, block, writeback, 0)

    return kernel


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def cas_apply_round(data: jax.Array, meta: jax.Array, slot: jax.Array,
                    kind: jax.Array, expected: jax.Array, desired: jax.Array,
                    *, block: int = BLOCK, interpret: bool = False):
    """One conflict-free round.  data: uint32[n+1, k] (row n = dummy);
    meta: uint32[n+1, 2]; slot: int32[p] (dead lanes -> n); kind: int32[p]
    or [p, 1]; expected/desired: uint32[p, k].

    Returns (data', meta', success int32[p,1], witness uint32[p,k]).
    Within a round all live slots are distinct -> no write conflicts."""
    n1, k = data.shape
    p = slot.shape[0]
    kind = kind.reshape(p).astype(jnp.int32)
    pad = (-p) % block
    if pad:
        # Padding lanes are dead: they benignly rewrite the dummy row n.
        slot = jnp.concatenate([slot, jnp.full((pad,), n1 - 1, jnp.int32)])
        kind = jnp.concatenate([kind, jnp.zeros((pad,), jnp.int32)])
        expected = jnp.concatenate(
            [expected, jnp.zeros((pad, k), expected.dtype)])
        desired = jnp.concatenate(
            [desired, jnp.zeros((pad, k), desired.dtype)])
    pp = p + pad

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(pp // block,),
        in_specs=[
            pl.BlockSpec((block, 1), lambda i, s: (i, 0)),    # kind tile
            pl.BlockSpec((block, k), lambda i, s: (i, 0)),    # expected tile
            pl.BlockSpec((block, k), lambda i, s: (i, 0)),    # desired tile
            pl.BlockSpec(memory_space=_ANY),                  # data (HBM)
            pl.BlockSpec(memory_space=_ANY),                  # meta (HBM)
        ],
        out_specs=[
            pl.BlockSpec(memory_space=_ANY),                  # data back
            pl.BlockSpec(memory_space=_ANY),                  # meta back
            pl.BlockSpec((block, 1), lambda i, s: (i, 0)),    # success tile
            pl.BlockSpec((block, k), lambda i, s: (i, 0)),    # witness tile
        ],
        scratch_shapes=[
            pltpu.VMEM((block, k), data.dtype),
            pltpu.VMEM((block, 2), jnp.uint32),
            pltpu.SemaphoreType.DMA((block,)),
            pltpu.SemaphoreType.DMA((block,)),
            pltpu.SemaphoreType.DMA(()),
        ],
    )
    new_data, new_meta, succ, wit = pl.pallas_call(
        _kernel(block),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n1, k), data.dtype),
            jax.ShapeDtypeStruct((n1, 2), meta.dtype),
            jax.ShapeDtypeStruct((pp, 1), jnp.int32),
            jax.ShapeDtypeStruct((pp, k), data.dtype),
        ],
        # aliasing indices count ALL inputs incl. the scalar-prefetch operand
        # (slot=0) and the blocked op tiles, so data=4, meta=5
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(slot, kind.reshape(pp, 1), expected, desired, data, meta)
    return new_data, new_meta, succ[:p], wit[:p]
