"""Pallas TPU kernel: one combining round of batched store/CAS.

The deterministic linearization in `repro.core.semantics` serializes updates
to the same cell into rounds; *within* one round every live op targets a
distinct cell, so a round is an embarrassingly parallel
gather -> compare -> conditional write-back.  This kernel is that round:

  grid step i owns op i; BlockSpec index_maps route the op's cell row (data)
  and metadata row (version) in and back out via input/output aliasing, so
  the table is updated in place, one pipelined pass over the op list.

Dead lanes (ops not live in this round) are pointed at a reserved dummy row
n by the host wrapper; they rewrite that row with its own contents (benign).
Write-back of the *unchanged* value on CAS failure keeps the dataflow static
— the moral equivalent of the paper's compare_exchange leaving memory
untouched, expressed as an idempotent store (TPU has no conditional DMA).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

STORE = 1
CAS = 2


def _kernel(slot_ref, data_ref, meta_ref, kind_ref, exp_ref, des_ref,
            out_data_ref, out_meta_ref, succ_ref, wit_ref):
    cur = data_ref[...]                        # [1, k] current cell value
    kind = kind_ref[0, 0]
    live = jnp.logical_or(kind == STORE, kind == CAS)
    match = jnp.all(cur == exp_ref[...])
    ok = jnp.logical_and(live, jnp.logical_or(kind == STORE, match))
    new = jnp.where(ok, des_ref[...], cur)
    out_data_ref[...] = new
    ver = meta_ref[0, 0]
    out_meta_ref[0, 0] = ver + 2 * ok.astype(jnp.uint32)
    out_meta_ref[0, 1] = meta_ref[0, 1]
    succ_ref[0, 0] = ok.astype(jnp.int32)
    wit_ref[...] = cur


@functools.partial(jax.jit, static_argnames=("interpret",))
def cas_apply_round(data: jax.Array, meta: jax.Array, slot: jax.Array,
                    kind: jax.Array, expected: jax.Array, desired: jax.Array,
                    *, interpret: bool = False):
    """One conflict-free round.  data: uint32[n+1, k] (row n = dummy);
    meta: uint32[n+1, 2]; slot: int32[p] (dead lanes -> n); kind: int32[p,1];
    expected/desired: uint32[p, k].

    Returns (data', meta', success int32[p,1], witness uint32[p,k]).
    Within a round all live slots are distinct -> no write conflicts."""
    n1, k = data.shape
    p = slot.shape[0]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(p,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i, s: (s[i], 0)),    # data row
            pl.BlockSpec((1, 2), lambda i, s: (s[i], 0)),    # meta row
            pl.BlockSpec((1, 1), lambda i, s: (i, 0)),       # kind
            pl.BlockSpec((1, k), lambda i, s: (i, 0)),       # expected
            pl.BlockSpec((1, k), lambda i, s: (i, 0)),       # desired
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i, s: (s[i], 0)),    # data row back
            pl.BlockSpec((1, 2), lambda i, s: (s[i], 0)),    # meta row back
            pl.BlockSpec((1, 1), lambda i, s: (i, 0)),       # success
            pl.BlockSpec((1, k), lambda i, s: (i, 0)),       # witness
        ],
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n1, k), data.dtype),
            jax.ShapeDtypeStruct((n1, 2), meta.dtype),
            jax.ShapeDtypeStruct((p, 1), jnp.int32),
            jax.ShapeDtypeStruct((p, k), data.dtype),
        ],
        # aliasing indices count ALL inputs incl. the scalar-prefetch operand
        # (slot=0), so data=1, meta=2
        input_output_aliases={1: 0, 2: 1},
        interpret=interpret,
    )(slot, data, meta, kind.reshape(p, 1).astype(jnp.int32),
      expected, desired)
