"""Pallas TPU flash attention (beyond-paper perf work, EXPERIMENTS.md §Perf).

The dry-run roofline shows every train/prefill cell is MEMORY-bound, and the
dominant term is attention score traffic: the pure-XLA pair-list attention
(models/attention.py) materializes each [qb, h, kvb] score block to HBM
several times (dot out -> mask/exp fusion -> dot in), so HBM bytes scale as
S^2 while useful compute scales the same — a hard ~2% MFU ceiling at 4k-32k
sequence lengths.

This kernel keeps the entire online-softmax state (scores, running max, sum,
accumulator) in VMEM scratch across the kv-block grid axis: HBM traffic drops
to one read of Q/K/V + one write of O per sweep — S-linear, not S^2.  Causal
and sliding-window masking skip fully-masked kv blocks via pl.when (no FLOPs
and no DMA for skipped blocks thanks to Pallas block-index deduplication).

Layout: [b, h, t, hd] (wrapper transposes from the model's [b, t, h, hd]);
grid = (b, h, nq, nkv) with nkv innermost so scratch carries the running
state; GQA indexes the kv head as h // group in the K/V BlockSpecs (no
repeat-interleave — KV is read once per q-head group sweep).

Validated in interpret mode against models.attention.flash_attention (the
pure-jnp oracle) over shape/dtype/mask sweeps in tests/test_flash_kernel.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m, l, *, scale, causal,
            window, qb, kvb, nkv, t_kv):
    kj = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m[...] = jnp.full_like(m, NEG_INF)
        l[...] = jnp.zeros_like(l)

    q_lo = qi * qb
    k_lo = kj * kvb
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + qb - 1)
    if window > 0:
        live = jnp.logical_and(live, k_lo + kvb - 1 > q_lo - window)

    @pl.when(live)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)          # [qb, hd]
        k = k_ref[0, 0].astype(jnp.float32)          # [kvb, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale  # [qb, kvb]
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (qb, kvb), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (qb, kvb), 1)
        mask = kpos < t_kv                           # kv padding
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m[...]                              # [qb, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                       # [qb, kvb]
        corr = jnp.exp(m_prev - m_new)               # [qb, 1]
        l[...] = l[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc[...] = acc[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))          # [qb, hd]
        m[...] = m_new

    @pl.when(kj == nkv - 1)
    def _final():
        o_ref[0, 0] = (acc[...] / jnp.maximum(l[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_block", "kv_block", "interpret"))
def flash_attention_tpu(q, k, v, *, causal: bool = True, window: int = 0,
                        q_block: int = 512, kv_block: int = 512,
                        interpret: bool = False):
    """q: [b, tq, h, hd]; k, v: [b, tkv, kvh, hd].  Returns [b, tq, h, hd].

    Drop-in for models.attention.flash_attention on TPU backends."""
    b, tq, h, hd = q.shape
    _, tkv, kvh, _ = k.shape
    assert h % kvh == 0
    g = h // kvh
    qb = min(q_block, tq)
    kvb = min(kv_block, tkv)
    tq_orig, tkv_orig = tq, tkv
    if tq % qb:
        q = jnp.pad(q, ((0, 0), (0, (-tq) % qb), (0, 0), (0, 0)))
        tq = q.shape[1]
    if tkv % kvb:
        pad = ((0, 0), (0, (-tkv) % kvb), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        tkv = k.shape[1]
    nq, nkv = tq // qb, tkv // kvb

    qt = q.transpose(0, 2, 1, 3)                 # [b, h, tq, hd]
    kt = k.transpose(0, 2, 1, 3)                 # [b, kvh, tkv, hd]
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, scale=1.0 / np.sqrt(hd), causal=causal, window=window,
        qb=qb, kvb=kvb, nkv=nkv, t_kv=tkv_orig)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, qb, hd), lambda b_, h_, qi, kj: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, kvb, hd),
                         lambda b_, h_, qi, kj, g=g: (b_, h_ // g, kj, 0)),
            pl.BlockSpec((1, 1, kvb, hd),
                         lambda b_, h_, qi, kj, g=g: (b_, h_ // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qb, hd),
                               lambda b_, h_, qi, kj: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, tq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qb, hd), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
            pltpu.VMEM((qb, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.transpose(0, 2, 1, 3)
    if tq != tq_orig:
        out = out[:, :tq_orig]
    return out


def hbm_bytes_model(b, t, h, kvh, hd, *, dtype_bytes=2, train=True) -> float:
    """Analytic HBM traffic of this kernel per layer (for the roofline
    substitution in EXPERIMENTS.md §Perf).  Train counts fwd + recompute +
    bwd (dq/dk/dv) sweeps; inference counts the single fwd sweep."""
    q_bytes = b * t * h * hd * dtype_bytes
    kv_bytes = 2 * b * t * kvh * hd * dtype_bytes
    fwd = 2 * q_bytes + kv_bytes                 # read q, write o, read k/v
    if not train:
        return fwd
    # bwd kernel: read q,k,v,o,do + write dq,dk,dv  (+ fwd recompute)
    bwd = 3 * q_bytes + 2 * kv_bytes + 2 * q_bytes + kv_bytes
    return fwd + bwd
