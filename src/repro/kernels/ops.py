"""jit'd public wrappers for the Pallas kernels.

On a TPU backend the kernels lower to real Mosaic kernels; on CPU (this
container) they run in interpret mode, which executes the kernel body in
Python per grid step — bit-identical semantics, used by the test suite's
shape/dtype sweeps against the ref.py oracles.

`pad_cells` lane-aligns the cell width: TPU vector registers are 8x128, so
ops.py pads k up to a multiple of 128 words for the [n, k] table used by the
kernels (the pure-XLA core keeps logical k; padding is a kernels-layer
concern).
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.cachehash_probe import cachehash_probe as _cachehash_probe
from repro.kernels.cas_apply import cas_apply_round as _cas_apply_round
from repro.kernels.seqlock_gather import seqlock_gather as _seqlock_gather

LANE = 128


def on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def pad_cells(data: jax.Array, lane: int = LANE) -> jax.Array:
    """Pad cell width to the TPU lane multiple."""
    n, k = data.shape
    pad = (-k) % lane
    return jnp.pad(data, ((0, 0), (0, pad))) if pad else data


def bigatomic_load(data, meta, idx, *, interpret: bool | None = None):
    """Fast-path batched load (kernel) -> (values[q,k], ok[q])."""
    interpret = on_cpu() if interpret is None else interpret
    vals, ok = _seqlock_gather(data, meta, idx, interpret=interpret)
    return vals, ok[:, 0] != 0


def bigatomic_update_rounds(data, meta, slot, kind, expected, desired,
                            rounds: int, upd_rank, *,
                            interpret: bool | None = None):
    """Apply `rounds` combining rounds with the cas_apply kernel.

    slot/kind/expected/desired are the SORTED op list (see core.semantics);
    upd_rank[i] is op i's serialization round.  Dead lanes in a round point
    at the dummy row n.  Returns (data', meta', success[p], witness[p,k])."""
    interpret = on_cpu() if interpret is None else interpret
    n1 = data.shape[0]
    p, k = expected.shape
    success = jnp.zeros((p,), jnp.int32)
    witness = jnp.zeros((p, k), data.dtype)
    for t in range(rounds):
        live = upd_rank == t
        slot_t = jnp.where(live, slot, n1 - 1)
        kind_t = jnp.where(live, kind, 0)
        data, meta, succ, wit = _cas_apply_round(
            data, meta, slot_t, kind_t, expected, desired,
            interpret=interpret)
        success = jnp.where(live, succ[:, 0], success)
        witness = jnp.where(live[:, None], wit, witness)
    return data, meta, success, witness


def hash_keys(keys: jax.Array, m: int) -> jax.Array:
    """Fibonacci-style multiplicative hash of uint32[q, kw] -> bucket [q]."""
    h = jnp.zeros(keys.shape[0], jnp.uint32)
    for j in range(keys.shape[1]):
        h = (h ^ keys[:, j]) * jnp.uint32(0x9E3779B1)
        h = h ^ (h >> 15)
    return (h % jnp.uint32(m)).astype(jnp.int32)


def cachehash_find(cells, chain_pool, query_keys, *, kw, vw,
                   max_chain: int = 8, interpret: bool | None = None):
    """Full CacheHash lookup: kernel probe of the inlined first link, then a
    bounded jnp chain walk for the rare collision case.

    cells: uint32[m, cw]; chain_pool: uint32[c, cw] (same layout);
    returns (found[q] bool, value[q, vw])."""
    interpret = on_cpu() if interpret is None else interpret
    m = cells.shape[0]
    bidx = hash_keys(query_keys, m)
    hit, empty, value, nxt = _cachehash_probe(
        cells, bidx, query_keys, kw=kw, vw=vw, interpret=interpret)
    found = hit[:, 0] != 0
    done = found | (empty[:, 0] != 0) | (nxt[:, 0] < 0)
    cur = nxt[:, 0]
    val = value
    for _ in range(max_chain):                      # slow path: chain walk
        node = chain_pool[jnp.maximum(cur, 0)]
        nkey = node[:, :kw]
        nval = node[:, kw:kw + vw]
        nnxt = node[:, kw + vw].astype(jnp.int32)
        step_hit = ~done & (cur >= 0) & jnp.all(nkey == query_keys, axis=1)
        val = jnp.where(step_hit[:, None], nval, val)
        found = found | step_hit
        done = done | step_hit | (nnxt < 0) | (cur < 0)
        cur = jnp.where(done, cur, nnxt)
    return found, val
