"""Batched k-word MCAS over big atomics — multi-location atomicity on the
unified engine (DESIGN.md §7).

A transaction is a group of up to W (slot, expected, desired) lanes that
commit ALL-OR-NOTHING: if every claimed cell still holds its expected value
at the transaction's linearization point, all W desired values are written
(each cell's version bumps by 2, exactly as a store); otherwise nothing is
written and the transaction reports failure with the witnessed values.
This is the CAS-semantics MCAS of Blelloch & Wei ("LL/SC and Atomic Copy",
arXiv:1911.09671): multi-word atomicity built from LL/SC, with NO
descriptors — the batch-step engine arbitrates conflicts directly.

Protocol, per attempt round (all three batches ride `engine.linearize`
through the strategy registry, so every layout gets MCAS for free):

  1. LL-all       every lane of every contending txn load-links its cell.
                  A lane whose value != expected fails its whole txn NOW
                  (the txn linearizes at this read — the failure witness).
  2. VALIDATE-all surviving txns validate every link (a pure VALIDATE
                  batch; honesty round — links can only die if a caller
                  interleaves foreign traffic between engine batches).
  3. arbitrate    `engine.arbitrate_groups`: lowest txn id claiming a cell
                  wins it; a txn is a winner iff it wins EVERY cell it
                  claims.  Winners are pairwise cell-disjoint.
  4. SC-commit    ONE pure-SC batch commits every winner lane — the
                  engine's one-round fast path (every link predates the
                  batch and winners never share a cell, so every SC
                  succeeds).

Losers (ready but out-arbitrated) retry after a Dice-style abort backoff
(`repro.sync.queue.BackoffPolicy`, the queue's contention-management module,
arXiv:1305.5800) measured in rounds.  The lowest pending txn id always wins
arbitration, so every round either fails or commits at least one txn:
termination is guaranteed within `max_rounds`.

The CLAIMED linearization: round-major, failures before commits within a
round, txn id within each class — `linearization_order(result)` emits it
for the `TxnOracle` harness (tests/oracle.py), and `mcas_reference` is the
sequential replay that defines the semantics.

Everything is a pure pytree under one `jax.jit` (`spec`, the backoff policy
and `max_rounds` are the only statics), so `mcas` composes with `lax.scan`,
donation and `shard_map`; the mesh-sharded two-round prepare/commit variant
lives in `core.distributed.mcas`.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import engine, registry
from repro.core.layout import WORD_DTYPE
from repro.core.specs import AtomicSpec
from repro.obs import telemetry as obs_telemetry
from repro.sync.queue import BackoffPolicy


class TxnBatch(NamedTuple):
    """T transactions of up to W lanes each (a pure pytree).

    slot:     int32[T, W]  claimed cell per lane; -1 = unused (txn width < W)
    expected: word[T, W, k]  per-lane comparand
    desired:  word[T, W, k]  per-lane value to install on commit
    """

    slot: jax.Array
    expected: jax.Array
    desired: jax.Array

    @property
    def t(self) -> int:
        return self.slot.shape[0]

    @property
    def w(self) -> int:
        return self.slot.shape[1]


class McasResult(NamedTuple):
    """Per-transaction results of one `mcas` call.

    success:  bool[T]     txn committed (all lanes written atomically)
    witness:  word[T,W,k] value of each claimed cell at the txn's
                          linearization point (failed txns: the mismatching
                          read; committed txns: the pre-write values)
    round:    int32[T]    1-based attempt round at which the txn resolved
    attempts: int32[T]    arbitration losses before resolving
    rounds:   int32[]     total rounds the batch took
    """

    success: jax.Array
    witness: jax.Array
    round: jax.Array
    attempts: jax.Array
    rounds: jax.Array


def make_txns(slot, expected=None, desired=None, *, k: int) -> TxnBatch:
    """THE checked transaction constructor (mirrors `engine.make_ops`).

    Checks (on concrete inputs): slot is rank-2 [T, W]; expected/desired are
    [T, W, k] (a mismatched trailing dim is the "mismatched k" error);
    no duplicate live slots within one transaction.  Word payloads coerce
    to the canonical WORD_DTYPE."""
    slot = jnp.asarray(slot, jnp.int32)
    if slot.ndim != 2:
        raise ValueError(f"slot must be rank-2 [T, W], got shape "
                         f"{slot.shape}")
    t, w = slot.shape
    if t == 0 or w == 0:
        raise ValueError(f"need at least one transaction lane: {slot.shape}")
    if expected is None:
        expected = jnp.zeros((t, w, k), WORD_DTYPE)
    else:
        expected = jnp.asarray(expected, WORD_DTYPE)
    if desired is None:
        desired = jnp.zeros((t, w, k), WORD_DTYPE)
    else:
        desired = jnp.asarray(desired, WORD_DTYPE)
    for name, arr in (("expected", expected), ("desired", desired)):
        if arr.shape != (t, w, k):
            raise ValueError(f"{name} shape {arr.shape} != ({t}, {w}, {k}) "
                             f"(mismatched k?)")
    try:
        slot_np = np.asarray(slot)          # concrete only; tracers skip
    except Exception:
        slot_np = None
    if slot_np is not None:
        for i in range(t):
            live = slot_np[i][slot_np[i] >= 0]
            if len(np.unique(live)) != len(live):
                raise ValueError(f"transaction {i} claims duplicate slots: "
                                 f"{sorted(live.tolist())}")
    return TxnBatch(slot, expected, desired)


def _policy_delay(policy: BackoffPolicy, attempts: jax.Array) -> jax.Array:
    """`policy.delay` as a traced expression (policy fields are static)."""
    if policy.kind == "none":
        return jnp.zeros_like(attempts)
    if policy.kind == "const":
        return jnp.full_like(attempts, policy.base)
    if policy.kind == "exp":
        e = jnp.clip(attempts - 1, 0, 16)
        return jnp.minimum(jnp.left_shift(jnp.int32(policy.base), e),
                           jnp.int32(policy.cap))
    raise ValueError(f"unknown backoff kind {policy.kind!r}")


def max_rounds_bound(t: int, policy: BackoffPolicy) -> int:
    """Rounds after which every txn has provably resolved: >= 1 txn resolves
    per backoff window, and a window is at most max-delay + 1 rounds."""
    max_delay = {"none": 0, "const": policy.base, "exp": policy.cap}
    return t * (max_delay.get(policy.kind, policy.cap) + 2) + 4


class McasCarry(NamedTuple):
    """The protocol state between attempt rounds (a pure pytree) — identical
    to `_mcas`'s while_loop carry minus the table state, so one cooperative
    `mcas_round` step is BIT-IDENTICAL to one iteration of the fused loop.

    r:         int32[]     rounds run so far
    pending:   bool[T]     txns not yet resolved
    success:   bool[T]     txns committed
    witness:   word[T*W,k] flattened per-lane witness values
    round_res: int32[T]    1-based round each txn resolved in (0 = pending)
    attempts:  int32[T]    arbitration losses so far
    delay:     int32[T]    backoff rounds left before the txn re-contends
    """

    r: jax.Array
    pending: jax.Array
    success: jax.Array
    witness: jax.Array
    round_res: jax.Array
    attempts: jax.Array
    delay: jax.Array


def _round_step(spec: AtomicSpec, impl, round_fn, state, txns: TxnBatch,
                carry: McasCarry, policy: BackoffPolicy, telem=None):
    """ONE attempt round (LL-all / VALIDATE-all / arbitrate / SC-commit):
    the single traced body both `_mcas`'s while_loop and the cooperative
    `mcas_round` run, so yielding to a scheduler between rounds cannot
    change any result.

    `telem` (BIGATOMIC_OBS=counters) accumulates the protocol's own
    bookkeeping masks — committed / failed_now / lost — into the mcas.*
    counters and rides the return as a third element; None keeps the
    pre-observability two-element return and trace."""
    t, w, k, n = txns.t, txns.w, spec.k, spec.n
    p = t * w
    f_slot = txns.slot.reshape(p)
    f_exp = txns.expected.reshape(p, k)
    f_des = txns.desired.reshape(p, k)
    lane_txn = jnp.repeat(jnp.arange(t, dtype=jnp.int32), w)
    lane_used = (f_slot >= 0) & (f_slot < n)
    safe_slot = jnp.where(lane_used, f_slot, 0)

    def per_txn_all(flag_lane):
        """AND a per-lane flag over each txn's USED lanes (unused ⇒ True)."""
        return jnp.all((flag_lane | ~lane_used).reshape(t, w), axis=1)

    (r, pending, success, witness, round_res, attempts, delay) = carry
    r = r + 1
    active_t = pending & (delay <= 0)
    active_lane = active_t[lane_txn] & lane_used

    # 1. LL-all --------------------------------------------------------------
    ops1 = engine.OpBatch(
        jnp.where(active_lane, engine.LL, engine.IDLE), safe_slot,
        jnp.zeros((p, k), WORD_DTYPE), jnp.zeros((p, k), WORD_DTYPE))
    d1, v1, ctx, res1, st1 = round_fn(
        impl.engine_view(state), state.version,
        engine.init_ctx(p, k), ops1)
    state = impl.commit(state, d1, v1, st1.n_updates, p)
    vals = res1.value
    match_lane = jnp.all(vals == f_exp, axis=1)
    txn_match = per_txn_all(match_lane)
    failed_now = active_t & ~txn_match

    # 2. VALIDATE-all --------------------------------------------------------
    ready_lane = (active_t & txn_match)[lane_txn] & lane_used
    ops2 = engine.OpBatch(
        jnp.where(ready_lane, engine.VALIDATE, engine.IDLE), safe_slot,
        jnp.zeros((p, k), WORD_DTYPE), jnp.zeros((p, k), WORD_DTYPE))
    d2, v2, ctx, res2, st2 = round_fn(
        impl.engine_view(state), state.version, ctx, ops2)
    state = impl.commit(state, d2, v2, st2.n_updates, p)
    ready_t = active_t & txn_match & per_txn_all(res2.success)

    # 3. arbitrate -----------------------------------------------------------
    winner_t = ready_t & engine.arbitrate_groups(
        safe_slot, lane_txn, ready_t[lane_txn] & lane_used,
        n=n, n_groups=t)

    # 4. SC-commit (one round: pure-SC fast path, disjoint cells) ------------
    win_lane = winner_t[lane_txn] & lane_used
    ops3 = engine.OpBatch(
        jnp.where(win_lane, engine.SC, engine.IDLE), safe_slot,
        jnp.zeros((p, k), WORD_DTYPE), f_des)
    d3, v3, ctx, res3, st3 = round_fn(
        impl.engine_view(state), state.version, ctx, ops3)
    state = impl.commit(state, d3, v3, st3.n_updates, p)
    committed = winner_t & per_txn_all(res3.success)

    # 5. bookkeeping ---------------------------------------------------------
    resolved = failed_now | committed
    res_lane = resolved[lane_txn] & lane_used
    witness = jnp.where(res_lane[:, None], vals, witness)
    success = success | committed
    round_res = jnp.where(resolved, r, round_res)
    pending = pending & ~resolved
    lost = ready_t & ~committed
    attempts = attempts + lost.astype(jnp.int32)
    delay = jnp.where(lost, _policy_delay(policy, attempts),
                      jnp.maximum(delay - 1, 0))
    carry = McasCarry(r, pending, success, witness, round_res,
                      attempts, delay)
    if telem is None:
        return state, carry
    return state, carry, obs_telemetry.count_mcas_round(
        telem, committed, failed_now, lost)


@functools.partial(jax.jit,
                   static_argnames=("spec", "policy", "max_rounds", "mode"))
def _mcas(spec: AtomicSpec, state, txns: TxnBatch,
          policy: BackoffPolicy, max_rounds: int, mode: str, telem=None):
    impl = registry.get_strategy(spec.strategy)
    # Commit rounds ride the strategy's lowered kernel round (DESIGN.md §8):
    # the LL-all batch is collision-free under low contention and the SC
    # batch always is (winners are cell-disjoint), so both hit the fast
    # path.  `mode` is static so an engine-kernel env change retraces.
    round_fn = engine.round_for(spec, impl, mode)
    t, w, k = txns.t, txns.w, spec.k

    def body(c):
        return _round_step(spec, impl, round_fn, c[0], txns, c[1], policy,
                           *c[2:])

    init = ((state, mcas_begin(txns)) if telem is None
            else (state, mcas_begin(txns), telem))
    out = lax.while_loop(
        lambda c: (c[1].r < max_rounds) & jnp.any(c[1].pending), body, init)
    state, carry = out[0], out[1]
    result = McasResult(carry.success, carry.witness.reshape(t, w, k),
                        carry.round_res, carry.attempts, carry.r)
    if telem is None:
        return state, result
    return state, result, out[2]


def mcas(spec: AtomicSpec, state, txns: TxnBatch, *,
         policy: BackoffPolicy = BackoffPolicy("none"),
         max_rounds: int | None = None):
    """Commit a batch of k-word MCAS transactions against the table.

    `spec` / `policy` / `max_rounds` are the only statics; `state` and
    `txns` are pure pytrees.  Returns (state', McasResult); the claimed
    linearization order is `linearization_order(result)`.
    """
    if txns.expected.shape[2] != spec.k:
        raise ValueError(f"txn word width {txns.expected.shape[2]} != "
                         f"spec.k {spec.k}")
    if max_rounds is None:
        max_rounds = max_rounds_bound(txns.t, policy)
    mode = engine._engine_round().configured_mode()
    telem = obs_telemetry.carry_in(state, txns.slot)
    if telem is None:
        return _mcas(spec, state, txns, policy, max_rounds, mode)
    state, result, telem = _mcas(spec, state, txns, policy, max_rounds,
                                 mode, telem)
    obs_telemetry.carry_out(telem)
    return state, result


# ---------------------------------------------------------------------------
# Cooperative rounds: the SAME protocol advanced one round per call, so a
# scheduler (repro.runtime.executor) can run other streams' batches between
# contended retries instead of spinning inside one lax.while_loop.
# ---------------------------------------------------------------------------

def mcas_begin(txns: TxnBatch) -> McasCarry:
    """The fresh carry `_mcas` starts its while_loop from — hand it to
    `mcas_round` to run the identical protocol cooperatively."""
    t, w, k = txns.t, txns.w, txns.expected.shape[2]
    return McasCarry(jnp.int32(0), jnp.ones((t,), bool),
                     jnp.zeros((t,), bool),
                     jnp.zeros((t * w, k), WORD_DTYPE),
                     jnp.zeros((t,), jnp.int32), jnp.zeros((t,), jnp.int32),
                     jnp.zeros((t,), jnp.int32))


@functools.partial(jax.jit, static_argnames=("spec", "policy", "mode"))
def _mcas_round(spec: AtomicSpec, state, txns: TxnBatch, carry: McasCarry,
                policy: BackoffPolicy, mode: str, telem=None):
    impl = registry.get_strategy(spec.strategy)
    round_fn = engine.round_for(spec, impl, mode)
    return _round_step(spec, impl, round_fn, state, txns, carry, policy,
                       telem)


def mcas_round(spec: AtomicSpec, state, txns: TxnBatch, carry: McasCarry, *,
               policy: BackoffPolicy = BackoffPolicy("none")):
    """Advance the MCAS protocol by ONE attempt round (LL-all /
    VALIDATE-all / arbitrate / SC-commit) and return (state', carry').

    Because links never span rounds (each round builds and consumes its own
    ctx), a caller may interleave ARBITRARY foreign batches against `state`
    between rounds — pending txns simply re-read on their next attempt.
    Driving this to `not carry.pending.any()` yields bit-identical results
    to `mcas` with the same policy; `mcas_finish` packages them.
    """
    if txns.expected.shape[2] != spec.k:
        raise ValueError(f"txn word width {txns.expected.shape[2]} != "
                         f"spec.k {spec.k}")
    mode = engine._engine_round().configured_mode()
    telem = obs_telemetry.carry_in(state, txns.slot)
    if telem is None:
        return _mcas_round(spec, state, txns, carry, policy, mode)
    state, carry, telem = _mcas_round(spec, state, txns, carry, policy,
                                      mode, telem)
    obs_telemetry.carry_out(telem)
    return state, carry


def mcas_finish(txns: TxnBatch, carry: McasCarry) -> McasResult:
    """Package a drained cooperative run as the standard `McasResult` (same
    contract as `mcas`, so `linearization_order` and the TxnOracle apply)."""
    t, w, k = txns.t, txns.w, txns.expected.shape[2]
    return McasResult(carry.success, carry.witness.reshape(t, w, k),
                      carry.round_res, carry.attempts, carry.r)


# ---------------------------------------------------------------------------
# The claimed order + the sequential replay that defines the semantics.
# ---------------------------------------------------------------------------

def linearization_order(result: McasResult) -> np.ndarray:
    """Txn ids in the claimed linearization: round-major, failures before
    commits within a round (failures witness the pre-commit values), txn id
    within each class.  Txns that never resolved (round == 0, possible only
    under a caller-supplied `max_rounds` below the provable bound) never
    executed and are excluded — the oracle treats them as dropped."""
    rnd = np.asarray(result.round)
    suc = np.asarray(result.success).astype(np.int64)
    ids = np.arange(rnd.shape[0])
    order = ids[np.lexsort((ids, suc, rnd))]
    return order[rnd[order] > 0]


def mcas_reference(data: np.ndarray, version: np.ndarray, txns: TxnBatch,
                   order) -> tuple:
    """Replay whole transactions one at a time in `order`.  Pure numpy.

    Returns (data', version', success[T], witness[T, W, k])."""
    data = np.array(data, copy=True)
    version = np.array(version, copy=True)
    slot = np.asarray(txns.slot)
    expected = np.asarray(txns.expected)
    desired = np.asarray(txns.desired)
    t, w, k = expected.shape
    success = np.zeros((t,), bool)
    witness = np.zeros((t, w, k), data.dtype)
    for i in np.asarray(order, np.int64):
        used = [j for j in range(w)
                if 0 <= slot[i, j] < data.shape[0]]
        ok = True
        for j in used:
            witness[i, j] = data[slot[i, j]]
            if not np.array_equal(data[slot[i, j]], expected[i, j]):
                ok = False
        if ok:
            for j in used:
                data[slot[i, j]] = desired[i, j]
                version[slot[i, j]] += 2
            success[i] = True
    return data, version, success, witness
