"""Optimistic transactional map over CacheHash (DESIGN.md §7).

A map transaction declares a READ SET (keys whose values it observes) and a
WRITE SET (keys it upserts or deletes, with write values computed by a
traceable function of the read values).  A batch of T transactions executes
serializably: every committed transaction behaves as if its reads and
writes happened atomically at its commit point, in the claimed order
(commit round, then txn id).

Protocol, per attempt round (optimistic concurrency control, batch-step):

  1. read       one CacheHash FIND batch fetches every contending txn's
                read set.
  2. compute    `fn(read_values, read_found) -> write_values` (traced once).
  3. arbitrate  a txn wins iff no lower-id contending txn touches any of
                its written keys (read OR write) and no lower-id txn
                writes any of its read keys — two scatter-mins over the
                bucket domain (conservative: bucket-granular, exact on
                distinct buckets).  Winners are pairwise conflict-free, so
                their reads stay valid through every same-round commit.
  4. validate   winners re-FIND their read sets and compare against step 1
                (the OCC validation read; with no foreign traffic between
                batches it always passes — the code path is the contract).
  5. commit     ONE hash batch: DELETE lanes then INSERT lanes in lane
                order — CacheHash linearizes per bucket in lane order, so
                delete-then-insert is an atomic upsert; pure deletes skip
                the INSERT lane.

Losers retry after Dice-style backoff (`sync.queue.BackoffPolicy`); the
lowest contending txn id always wins, so every round commits at least one
txn and the loop terminates.  The single-device driver runs entirely under
`lax.while_loop` (spec/policy/max_rounds are the only statics); the
mesh-sharded driver (`transact_dist`) runs the same round logic host-side
over `core.distributed.apply_hash`, so cross-shard transactions linearize
through the key-owner-routed collective.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import cachehash as ch
from repro.core import engine
from repro.core.layout import WORD_DTYPE
from repro.core.specs import HashSpec
from repro.sync.queue import BackoffPolicy
from repro.txn.mcas import _policy_delay, max_rounds_bound


class MapTxns(NamedTuple):
    """T map transactions (a pure pytree).

    read_key:    uint32[T, R]  keys observed (masked by read_mask)
    read_mask:   bool[T, R]
    write_key:   uint32[T, W]  keys written (masked by write_mask)
    write_mask:  bool[T, W]
    write_del:   bool[T, W]    True = delete the key; False = upsert
    write_value: word[T, W, vw] upsert values used when `fn is None`
                 (data-carrying transactions, e.g. the serving bookkeeping
                 txn; ignored when a compute `fn` is supplied)
    """

    read_key: jax.Array
    read_mask: jax.Array
    write_key: jax.Array
    write_mask: jax.Array
    write_del: jax.Array
    write_value: jax.Array

    @property
    def t(self) -> int:
        return self.read_key.shape[0]


class MapResult(NamedTuple):
    """read_value/read_found: each txn's read set AS OBSERVED at its commit
    point; round: 1-based commit round; attempts: arbitration losses;
    rounds: total rounds the batch took."""

    read_value: jax.Array
    read_found: jax.Array
    round: jax.Array
    attempts: jax.Array
    rounds: jax.Array


def make_map_txns(read_key, write_key, *, read_mask=None, write_mask=None,
                  write_del=None, write_value=None, vw: int = 1) -> MapTxns:
    """Checked constructor: rank-2 key arrays sharing T, masks matching,
    no duplicate live write keys within one transaction.  `write_value`
    ([T, W, vw], coerced to words) feeds fn-less transactions; it defaults
    to zeros of width `vw`."""
    read_key = jnp.asarray(read_key, jnp.uint32)
    write_key = jnp.asarray(write_key, jnp.uint32)
    if read_key.ndim != 2 or write_key.ndim != 2:
        raise ValueError(f"keys must be rank-2 [T, ...]: read "
                         f"{read_key.shape}, write {write_key.shape}")
    t, r = read_key.shape
    tw, w = write_key.shape
    if tw != t:
        raise ValueError(f"read/write txn counts differ: {t} vs {tw}")

    def mask(m, shape, default):
        if m is None:
            return jnp.full(shape, default, bool)
        m = jnp.asarray(m, bool)
        if m.shape != shape:
            raise ValueError(f"mask shape {m.shape} != {shape}")
        return m

    read_mask = mask(read_mask, (t, r), True)
    write_mask = mask(write_mask, (t, w), True)
    write_del = mask(write_del, (t, w), False)
    if write_value is None:
        write_value = jnp.zeros((t, w, vw), WORD_DTYPE)
    else:
        write_value = jnp.asarray(write_value, WORD_DTYPE)
        if write_value.ndim != 3 or write_value.shape[:2] != (t, w):
            raise ValueError(f"write_value shape {write_value.shape} != "
                             f"({t}, {w}, vw)")
    try:
        wk, wm = np.asarray(write_key), np.asarray(write_mask)
    except Exception:
        wk = None
    if wk is not None:
        for i in range(t):
            live = wk[i][wm[i]]
            if len(np.unique(live)) != len(live):
                raise ValueError(f"transaction {i} writes duplicate keys: "
                                 f"{sorted(live.tolist())}")
    return MapTxns(read_key, read_mask, write_key, write_mask, write_del,
                   write_value)


def _winners(txns: MapTxns, active, nb: int):
    """Conflict arbitration over the bucket domain: txn i wins iff
    (a) no active j < i reads-or-writes any bucket i writes, and
    (b) no active j < i writes any bucket i reads.  The winner set is
    pairwise conflict-free and always contains the lowest active id."""
    t = txns.t
    gid = jnp.arange(t, dtype=jnp.int32)

    def bucket(keys):
        return (ch.hash_u32(keys) & jnp.uint32(nb - 1)).astype(jnp.int32)

    def scatter_min(b, mask):
        flat_b = jnp.where(mask, b, nb).reshape(-1)
        flat_g = jnp.where(mask, gid[:, None], t).reshape(-1)
        out = jnp.full((nb + 1,), t, jnp.int32)
        return out.at[flat_b].min(flat_g, mode="drop")

    rb = bucket(txns.read_key)
    wb = bucket(txns.write_key)
    r_live = txns.read_mask & active[:, None]
    w_live = txns.write_mask & active[:, None]
    wmin = scatter_min(wb, w_live)               # lowest active WRITER
    amin = jnp.minimum(wmin, scatter_min(rb, r_live))  # lowest active TOUCHER

    def per_txn_ok(cond, mask):
        return jnp.all(cond | ~mask, axis=1)

    ok_w = per_txn_ok(amin[jnp.minimum(wb, nb)] >= gid[:, None], w_live)
    ok_r = per_txn_ok(wmin[jnp.minimum(rb, nb)] >= gid[:, None], r_live)
    return active & ok_w & ok_r


def _round(happly, spec: HashSpec, txns: MapTxns, fn, state, active):
    """One OCC attempt round (pure jnp; shared by the jitted single-device
    driver and the host-side sharded driver).  Returns
    (state', committed[T], read_value[T,R,vw], read_found[T,R])."""
    t, vw = txns.t, spec.vw
    r = txns.read_key.shape[1]
    w = txns.write_key.shape[1]
    rk = txns.read_key.reshape(t * r)
    r_act = (txns.read_mask & active[:, None]).reshape(t * r)

    # 1. read ---------------------------------------------------------------
    state, res = happly(state, ch.make_hash_ops(
        jnp.where(r_act, engine.FIND, engine.IDLE), rk, vw=vw))
    rv = res.value.reshape(t, r, vw)
    rf = res.found.reshape(t, r)

    # 2. compute (fn=None: the txns carry their write values) ---------------
    wv = txns.write_value if fn is None else jnp.asarray(fn(rv, rf),
                                                         WORD_DTYPE)
    if wv.shape != (t, w, vw):
        raise ValueError(f"fn returned shape {wv.shape}, want "
                         f"({t}, {w}, {vw})")

    # 3. arbitrate ----------------------------------------------------------
    winner = _winners(txns, active, spec.nb)

    # 4. validate (winners re-read; must equal step 1) ----------------------
    v_act = (txns.read_mask & winner[:, None]).reshape(t * r)
    state, vres = happly(state, ch.make_hash_ops(
        jnp.where(v_act, engine.FIND, engine.IDLE), rk, vw=vw))
    vf = vres.found.reshape(t, r)
    vvals = vres.value.reshape(t, r, vw)
    same = (vf == rf) & (jnp.all(vvals == rv, axis=2) | ~rf)
    confirmed = winner & jnp.all(same | ~txns.read_mask, axis=1)

    # 5. commit: DELETE lanes then INSERT lanes, one batch ------------------
    wk = txns.write_key.reshape(t * w)
    d_lane = (txns.write_mask & confirmed[:, None]).reshape(t * w)
    i_lane = d_lane & ~txns.write_del.reshape(t * w)
    kinds = jnp.concatenate([
        jnp.where(d_lane, engine.DELETE, engine.IDLE),
        jnp.where(i_lane, engine.INSERT, engine.IDLE)])
    keys = jnp.concatenate([wk, wk])
    vals = jnp.concatenate([jnp.zeros((t * w, vw), WORD_DTYPE),
                            wv.reshape(t * w, vw)])
    state, _ = happly(state, ch.make_hash_ops(kinds, keys, vals, vw=vw))
    return state, confirmed, rv, rf


@functools.partial(jax.jit,
                   static_argnames=("spec", "fn", "policy", "max_rounds"))
def _transact(spec: HashSpec, state, txns: MapTxns, fn,
              policy: BackoffPolicy, max_rounds: int):
    t, vw = txns.t, spec.vw
    r = txns.read_key.shape[1]

    def happly(st, ops):
        st, res, _ = ch.apply_hash(spec, st, ops)
        return st, res

    def body(carry):
        rnd, state, pending, round_res, attempts, delay, orv, orf = carry
        rnd = rnd + 1
        active = pending & (delay <= 0)
        state, committed, rv, rf = _round(happly, spec, txns, fn, state,
                                          active)
        orv = jnp.where(committed[:, None, None], rv, orv)
        orf = jnp.where(committed[:, None], rf, orf)
        round_res = jnp.where(committed, rnd, round_res)
        pending = pending & ~committed
        lost = active & ~committed
        attempts = attempts + lost.astype(jnp.int32)
        delay = jnp.where(lost, _policy_delay(policy, attempts),
                          jnp.maximum(delay - 1, 0))
        return rnd, state, pending, round_res, attempts, delay, orv, orf

    init = (jnp.int32(0), state, jnp.ones((t,), bool),
            jnp.zeros((t,), jnp.int32), jnp.zeros((t,), jnp.int32),
            jnp.zeros((t,), jnp.int32),
            jnp.zeros((t, r, vw), WORD_DTYPE), jnp.zeros((t, r), bool))
    out = lax.while_loop(
        lambda c: (c[0] < max_rounds) & jnp.any(c[2]), body, init)
    rnd, state, _pending, round_res, attempts, _delay, orv, orf = out
    return state, MapResult(orv, orf, round_res, attempts, rnd)


def transact(spec: HashSpec, state, txns: MapTxns, fn, *,
             policy: BackoffPolicy = BackoffPolicy("none"),
             max_rounds: int | None = None):
    """Run a batch of map transactions to serializable commit.

    `fn(read_values[T,R,vw], read_found[T,R]) -> write_values[T,W,vw]` must
    be traceable (it runs under `jax.jit` inside the retry loop) and
    hashable (a module-level function or functools.partial — it is a static
    argument).  Returns (state', MapResult); the claimed serialization is
    `linearization_order(result)`."""
    if max_rounds is None:
        max_rounds = max_rounds_bound(txns.t, policy)
    return _transact(spec, state, txns, fn, policy, max_rounds)


def transact_dist(mesh, dspec, dstate, txns: MapTxns, fn, *,
                  policy: BackoffPolicy = BackoffPolicy("none"),
                  max_rounds: int | None = None):
    """`transact` over a mesh-sharded CacheHash: identical round logic, but
    every hash batch routes by key owner through `distributed.apply_hash`
    (host-side retry driver — the collective is the jitted part), so
    transactions whose read/write sets span shards commit atomically."""
    from repro.core import distributed as dsb
    hs: HashSpec = dspec.inner
    if max_rounds is None:
        max_rounds = max_rounds_bound(txns.t, policy)

    def happly(st, ops):
        q = ops.kind.shape[0]
        q_pad = -(-q // dspec.n_shards) * dspec.n_shards
        d = dataclasses.replace(dspec, p_local=q_pad // dspec.n_shards,
                                route_capacity=q_pad)
        st, res, _ovf = dsb.apply_hash(mesh, d, st, ops)
        # Materialize results on the host before the round logic reuses
        # them: the collective's outputs carry the mesh sharding (claimed
        # replicated over spare axes under check_rep=False), and eager
        # re-use in jnp ops would re-reduce those "replicas".
        return st, type(res)(*[np.asarray(x) for x in res])

    t, vw = txns.t, hs.vw
    r = txns.read_key.shape[1]
    pending = np.ones((t,), bool)
    round_res = np.zeros((t,), np.int32)
    attempts = np.zeros((t,), np.int32)
    delay = np.zeros((t,), np.int32)
    orv = np.zeros((t, r, vw), np.uint32)
    orf = np.zeros((t, r), bool)
    rnd = 0
    while pending.any() and rnd < max_rounds:
        rnd += 1
        active = pending & (delay <= 0)
        if not active.any():
            delay = np.maximum(delay - 1, 0)
            continue
        dstate, committed, rv, rf = _round(happly, hs, txns, fn, dstate,
                                           jnp.asarray(active))
        committed = np.asarray(committed)
        orv = np.where(committed[:, None, None], np.asarray(rv), orv)
        orf = np.where(committed[:, None], np.asarray(rf), orf)
        round_res = np.where(committed, rnd, round_res)
        pending &= ~committed
        lost = active & ~committed
        attempts = attempts + lost.astype(np.int32)
        delay = np.maximum(delay - 1, 0)
        for i in np.nonzero(lost)[0]:
            delay[i] = policy.delay(int(attempts[i]))
    if pending.any():
        raise RuntimeError(f"transact_dist round bound exceeded "
                           f"({max_rounds}); pending="
                           f"{np.nonzero(pending)[0].tolist()}")
    return dstate, MapResult(orv, orf, round_res, attempts, rnd)


def linearization_order(result: MapResult) -> np.ndarray:
    """Txn ids in the claimed serialization: commit round, then txn id."""
    rnd = np.asarray(result.round)
    ids = np.arange(rnd.shape[0])
    return ids[np.lexsort((ids, rnd))]


def transact_reference(model: dict, txns: MapTxns, fn, order, vw: int):
    """Sequential replay defining the semantics: apply whole transactions
    one at a time in `order` against a dict model.  Returns
    (model', read_value[T,R,vw], read_found[T,R])."""
    rk = np.asarray(txns.read_key)
    rm = np.asarray(txns.read_mask)
    wk = np.asarray(txns.write_key)
    wm = np.asarray(txns.write_mask)
    wd = np.asarray(txns.write_del)
    t, r = rk.shape
    w = wk.shape[1]
    out_v = np.zeros((t, r, vw), np.uint32)
    out_f = np.zeros((t, r), bool)
    for i in np.asarray(order, np.int64):
        rv = np.zeros((1, r, vw), np.uint32)
        rf = np.zeros((1, r), bool)
        for j in range(r):
            if rm[i, j] and int(rk[i, j]) in model:
                rv[0, j] = model[int(rk[i, j])]
                rf[0, j] = True
        wv = np.asarray(txns.write_value)[i] if fn is None else \
            np.asarray(fn(jnp.asarray(rv), jnp.asarray(rf)))[0]
        for j in range(w):
            if not wm[i, j]:
                continue
            key = int(wk[i, j])
            if wd[i, j]:
                model.pop(key, None)
            else:
                model[key] = np.asarray(wv[j], np.uint32).copy()
        out_v[i], out_f[i] = rv[0], rf[0]
    return model, out_v, out_f
