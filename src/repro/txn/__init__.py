"""repro.txn — transactions over big atomics (DESIGN.md §7).

Three layers, all dispatching through the strategy registry:

  mcas         batched k-word MCAS: groups of (slot, expected, desired)
               lanes commit all-or-nothing via LL-all / VALIDATE-all /
               one-round-SC on the unified engine, conflicts arbitrated by
               txn-group id (no descriptors), losers backing off Dice-style.
  versionlist  per-slot bounded version chains with the newest version
               inline in a big-atomic head cell — timestamped
               `snapshot_read` of arbitrary slot sets (the paper's
               version-list application; `core.multiversion` rides on it).
  map          optimistic transactional map over CacheHash: read-set /
               write-set, validate + commit, serializable, retried under
               `lax.while_loop`.

The mesh-sharded MCAS (two-round prepare/commit collective) lives in
`core.distributed.mcas`; the sharded map driver is `map.transact_dist`.
"""

from repro.txn import map as map  # noqa: F401  (txn.map module alias)
from repro.txn import mcas as mcas  # noqa: F401
from repro.txn import versionlist as versionlist  # noqa: F401
from repro.txn.map import (  # noqa: F401
    MapResult, MapTxns, make_map_txns, transact, transact_dist,
    transact_reference,
)
from repro.txn.mcas import (  # noqa: F401
    McasResult, TxnBatch, make_txns, mcas_reference,
)
from repro.txn.mcas import mcas as run_mcas  # noqa: F401
from repro.txn.versionlist import (  # noqa: F401
    VersionState, init as init_versions, latest, publish, snapshot_read,
)
