"""Per-slot bounded version lists over big atomics (DESIGN.md §7).

The paper's §2 names version lists as a headline application: "allows the
first version, most commonly accessed, to be stored inline and updated
atomically".  This module is that application done properly ON the engine:

  head cells   The newest version of every slot lives INLINE in a
               `cellw = k + 2` word big-atomic cell — [value(k), ts, prev]
               — of an ordinary `AtomicSpec` table.  Publishing is ONE
               engine STORE batch (`atomics.apply` semantics), so value,
               timestamp and chain pointer can never tear apart, every
               registered strategy (and plug-ins) gets version lists for
               free, and the head's cell version gives readers the usual
               even/odd torn-write detection.
  node pool    Older versions sit in a per-slot ring of `depth - 1`
               immutable pool nodes (`pool[n, depth-1, k+2]`).  A publish
               copies the displaced head into its ring position
               (`count % (depth-1)`) and links the new head to it; a node
               is overwritten only after depth-1 further publishes of its
               slot, so every chain is bounded to the `depth` newest
               versions.

`snapshot_read(spec, state, slots, ts)` returns, per queried slot, the
newest version with timestamp <= ts — a TIMESTAMPED snapshot of an
arbitrary slot set, consistent by construction (the walk runs against one
immutable state pytree; concurrency is cross-batch).  Reads past the
retained window are reported honestly (`ok=False`, lap detection via the
strict timestamp-decrease invariant of a healthy chain), never silently
wrong.  `core.multiversion` is rewired on top of this module.

Timestamps are caller-supplied uint32 and must be strictly increasing per
slot (e.g. a training step or a global publish counter); `publish` does not
reorder history.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.layout import WORD_DTYPE
from repro.core.specs import VersionSpec

NULLV = jnp.uint32(0xFFFFFFFF)     # "no older version" chain terminator


class VersionState(NamedTuple):
    """Pure pytree: head table + version-node pool + per-slot publish count.

    table: TableState of the `spec.head_spec()` big-atomic head cells
    pool:  word[n, depth-1, k+2] per-slot ring of displaced versions
    count: uint32[n] publishes per slot (ring cursor + version index)
    """

    table: object
    pool: jax.Array
    count: jax.Array


def init(spec: VersionSpec, initial=None, ts0: int = 0) -> VersionState:
    """Every slot starts with one inline version (`initial` values, ts=ts0)
    and an empty chain."""
    vals = (np.zeros((spec.n, spec.k), np.uint32) if initial is None
            else np.asarray(initial, np.uint32))
    if vals.shape != (spec.n, spec.k):
        raise ValueError(f"initial shape {vals.shape} != "
                         f"({spec.n}, {spec.k})")
    cells = np.zeros((spec.n, spec.cellw), np.uint32)
    cells[:, :spec.k] = vals
    cells[:, spec.k] = np.uint32(ts0)
    cells[:, spec.k + 1] = np.uint32(0xFFFFFFFF)        # NULLV
    table = engine.init(spec.head_spec(), cells)
    pool = jnp.zeros((spec.n, spec.ring_depth, spec.cellw), WORD_DTYPE)
    return VersionState(table, pool, jnp.zeros((spec.n,), jnp.uint32))


@functools.partial(jax.jit, static_argnames=("spec",))
def _publish(spec: VersionSpec, state: VersionState, slots, values, ts):
    n, k, rd = spec.n, spec.k, spec.ring_depth
    slots = jnp.asarray(slots, jnp.int32)
    values = jnp.asarray(values, WORD_DTYPE)
    ts = jnp.asarray(ts, jnp.uint32)
    q = slots.shape[0]
    # The displaced head's ring position and global chain pointer.
    pos = (state.count[slots] % jnp.uint32(rd)).astype(jnp.int32)
    prev = (slots.astype(jnp.uint32) * jnp.uint32(rd)
            + pos.astype(jnp.uint32))
    new_cells = jnp.concatenate(
        [values, ts[:, None], prev[:, None]], axis=1)
    # ONE engine STORE batch: installs the new head atomically AND returns
    # the displaced head cell (STORE's witnessed pre-value).
    ops = engine.stores(slots, new_cells, k=spec.cellw)
    table, _, res, _, _ = engine.apply(spec.head_spec(), state.table, ops)
    pool = state.pool.at[slots, pos].set(res.value)
    count = state.count.at[slots].add(jnp.uint32(1))
    del n, q
    return VersionState(table, pool, count)


def publish(spec: VersionSpec, state: VersionState, slots, values, ts
            ) -> VersionState:
    """Install a new version (value, ts) at each of `slots` — one engine
    STORE batch; the displaced heads move into the per-slot pool rings.

    Slots must be distinct within one batch (checked on concrete input)
    and `ts` strictly greater than each slot's current head timestamp
    (caller contract; history is never reordered)."""
    try:
        s_np = np.asarray(slots)
    except Exception:
        s_np = None
    if s_np is not None and len(np.unique(s_np)) != len(s_np):
        raise ValueError(f"publish slots must be distinct within one "
                         f"batch: {sorted(np.asarray(s_np).tolist())}")
    return _publish(spec, state, slots, values, ts)


@functools.partial(jax.jit, static_argnames=("spec",))
def snapshot_read(spec: VersionSpec, state: VersionState, slots, ts):
    """Timestamped snapshot of an arbitrary slot set.

    Per queried slot: the value + timestamp of the newest version with
    version-ts <= ts[i].  ok=False when the head cell is torn (blocking
    strategies only) or the requested time predates the bounded chain
    (version evicted — honesty, not silence).

    Returns (values[q, k], found_ts[q], ok[q]).
    """
    n, k, rd = spec.n, spec.k, spec.ring_depth
    slots = jnp.asarray(slots, jnp.int32)
    ts = jnp.asarray(ts, jnp.uint32)
    q = slots.shape[0]
    heads, hok = engine.read(spec.head_spec(), state.table, slots)
    hval, hts, hprev = heads[:, :k], heads[:, k], heads[:, k + 1]

    flat = state.pool.reshape(n * rd, spec.cellw)
    values = jnp.where((hts <= ts)[:, None], hval,
                       jnp.zeros((q, k), WORD_DTYPE))
    found_ts = jnp.where(hts <= ts, hts, jnp.uint32(0))
    found = hts <= ts
    cur = jnp.where(found, NULLV, hprev)       # walk only unresolved lanes
    prev_ts = hts
    for _ in range(rd):
        is_node = cur != NULLV
        node = flat[jnp.where(is_node, cur, 0).astype(jnp.int32)]
        nts = node[:, k]
        # A healthy chain strictly decreases in ts; a recycled ring slot
        # holds a NEWER version and breaks the invariant => lap detected.
        valid = is_node & (nts < prev_ts)
        hit = valid & (nts <= ts)
        values = jnp.where(hit[:, None], node[:, :k], values)
        found_ts = jnp.where(hit, nts, found_ts)
        found = found | hit
        cur = jnp.where(valid & ~hit, node[:, k + 1], NULLV)
        prev_ts = jnp.where(valid, nts, prev_ts)
    return values, found_ts, hok & found


def latest(spec: VersionSpec, state: VersionState, slots):
    """Newest version of each slot: (values[q, k], ts[q], ok[q])."""
    heads, hok = engine.read(spec.head_spec(), state.table,
                             jnp.asarray(slots, jnp.int32))
    return heads[:, :spec.k], heads[:, spec.k], hok


def history(spec: VersionSpec, state: VersionState, slot: int) -> list:
    """Host-side debug/test helper: the retained (ts, value) chain of one
    slot, newest first (walks exactly like `snapshot_read`)."""
    head = np.asarray(engine.logical(spec.head_spec(), state.table))[slot]
    flat = np.asarray(state.pool).reshape(spec.n * spec.ring_depth,
                                          spec.cellw)
    k = spec.k
    out = [(int(head[k]), head[:k].copy())]
    cur, prev_ts = head[k + 1], head[k]
    for _ in range(spec.ring_depth):
        if cur == np.uint32(0xFFFFFFFF):
            break
        node = flat[int(cur)]
        if not node[k] < prev_ts:
            break                               # lapped (recycled ring slot)
        out.append((int(node[k]), node[:k].copy()))
        cur, prev_ts = node[k + 1], node[k]
    return out
