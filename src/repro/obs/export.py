"""Serialization for the observability subsystem: Chrome-trace/Perfetto
JSON for `Recorder` timelines, JSONL for counter snapshots.

The trace format is the Chrome trace-event JSON object form — loadable in
Perfetto (ui.perfetto.dev) and chrome://tracing.  The metrics sink is one
JSON object per line with the stable schema

    {"metric": "<name from DESIGN.md §10>", "value": <int|float>}

so downstream tooling can stream-parse it without knowing the full set of
metric names in advance.
"""

from __future__ import annotations

import json

from repro.obs.recorder import PID_SLOTS, PID_STREAMS
from repro.obs.telemetry import derived, snapshot


def chrome_trace(recorder) -> dict:
    """The full Chrome-trace document for a `Recorder`: process metadata
    for the two track groups plus every recorded event."""
    events = [
        {"ph": "M", "name": "process_name", "pid": PID_STREAMS,
         "args": {"name": "logical streams"}},
        {"ph": "M", "name": "process_name", "pid": PID_SLOTS,
         "args": {"name": "device slots"}},
    ]
    events.extend(recorder.events)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(recorder, path: str) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(recorder), f)
        f.write("\n")


def write_metrics_jsonl(path: str, extra: dict | None = None) -> None:
    """Dump the global counter snapshot (+ derived rates, + any `extra`
    host counters such as `Recorder.metrics()`) as one metric per line."""
    snap = snapshot()
    snap.update(derived(snap))
    if extra:
        snap.update(extra)
    with open(path, "w") as f:
        for name in sorted(snap):
            f.write(json.dumps({"metric": name, "value": snap[name]}))
            f.write("\n")
