"""Tier-1 observability: in-graph engine counters (DESIGN.md §10).

The paper's headline claims are RATES — fast-path hit frequency, slow-path
round counts, CAS retry behavior under contention — and the engine already
materializes every signal they need (`fast_path_ok`, `ApplyStats`, per-lane
`success`, overflow masks).  This module accumulates those signals into a
`Telemetry` pure-pytree of int32 counters INSIDE the existing jitted
programs: the counter state rides the jit boundary as one extra (tiny)
pytree argument and output, so counting adds no extra host->device
dispatches and no extra HBM traffic beyond the scalar counters themselves.

The gate is the static BIGATOMIC_OBS flag:

  off       (default) the counter pytree is None everywhere — entry points
            trace the EXACT pre-observability programs (asserted via
            `analysis/tracing.assert_max_new_traces`): zero cost when off.
  counters  the global `Telemetry` threads through `engine.apply`,
            `txn.mcas`, `distributed.apply` (one extra scalar-accumulate
            dispatch per collective round), and host-side retry loops
            (`sync.queue`, `serving.engine`) record into a host counter
            dict.
  trace     counters + the tier-2 executor timeline (`obs.recorder`).

Like BIGATOMIC_ENGINE_KERNEL, the flag is read per call and threaded as a
static jit argument (or None-vs-pytree structure), so flipping it
mid-process retraces instead of silently reusing the other mode's program.

Counters are int32 (jax x64 is disabled repo-wide): they wrap at 2^31.
Call `reset()` per measurement window; a window of >2e9 of any single
event is out of scope for these counters.

Every counter is recomputable bit-exactly from the claimed linearization
orders — `tests/oracle.py::TelemetryOracle` is the numpy recount, and
tests/test_obs.py holds the equivalence to it across strategies and
engine-kernel modes.
"""

from __future__ import annotations

import os
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

N_KINDS = 10          # engine.LOAD .. engine.DELETE
N_HIST = 16           # log2 contention buckets: [1], [2,3], [4,7], ...

_MODES = ("off", "counters", "trace")

_KIND_NAMES = ("load", "store", "cas", "idle", "ll", "sc", "validate",
               "find", "insert", "delete")


def configured_mode() -> str:
    """The observability mode requested by the environment (read per call,
    exactly like `kernels.engine_round.configured_mode`, so a mid-process
    flip always retraces)."""
    mode = os.environ.get("BIGATOMIC_OBS", "off")
    if mode not in _MODES:
        raise ValueError(f"BIGATOMIC_OBS={mode!r}; expected one of {_MODES}")
    return mode


def counters_on() -> bool:
    return configured_mode() != "off"


def trace_on() -> bool:
    return configured_mode() == "trace"


class Telemetry(NamedTuple):
    """The in-graph counter state: a pure pytree of int32 scalars (plus the
    per-kind vector and the contention histogram).  All fields accumulate;
    `snapshot()` names them (DESIGN.md §10 metric table).

    Engine counters (per `engine.apply` batch):
      batches         table batches observed
      ops_kind        [N_KINDS] lanes per op kind (IDLE padding included)
      fast_eligible   batches passing `fast_path_ok` (provably independent)
      fast_taken      batches whose round resolved on the fused fast path
                      (the branch the `lax.cond` in `make_round` took;
                      always 0 under BIGATOMIC_ENGINE_KERNEL=off)
      rounds          sum of ApplyStats.rounds (serialization rounds L)
      slow_rounds     rounds spent on batches NOT taken by the fast path
                      (the slow-path replay cost)
      cas_fail        active CAS lanes that failed
      sc_fail         active SC lanes that failed (stale link or lost race)
      raced_loads     loads whose cell saw a same-batch write
      dirty_cells     distinct cells written per batch, summed
      contention_hist [N_HIST] cells by log2(active lanes targeting them):
                      bucket b counts cells with lane count in [2^b, 2^(b+1))
    Read-protocol counters:
      torn_retries    reads that observed a torn/locked cell (ok=False)
    MCAS protocol counters (per `txn.mcas` attempt round):
      mcas_commits / mcas_aborts   txns resolved either way
      mcas_rounds                  attempt rounds executed
      mcas_backoff                 arbitration losses (backoff events)
    Distributed counters (per `distributed.apply` collective round):
      route_overflow    lanes rejected by route capacity
      collective_rounds collective rounds executed
      collective_words  sum of `distributed.collective_words(dspec)`
    """

    batches: jax.Array
    ops_kind: jax.Array
    fast_eligible: jax.Array
    fast_taken: jax.Array
    rounds: jax.Array
    slow_rounds: jax.Array
    cas_fail: jax.Array
    sc_fail: jax.Array
    raced_loads: jax.Array
    dirty_cells: jax.Array
    contention_hist: jax.Array
    torn_retries: jax.Array
    mcas_commits: jax.Array
    mcas_aborts: jax.Array
    mcas_rounds: jax.Array
    mcas_backoff: jax.Array
    route_overflow: jax.Array
    collective_rounds: jax.Array
    collective_words: jax.Array


def init_telemetry() -> Telemetry:
    z = jnp.int32(0)
    return Telemetry(
        batches=z, ops_kind=jnp.zeros((N_KINDS,), jnp.int32),
        fast_eligible=z, fast_taken=z, rounds=z, slow_rounds=z,
        cas_fail=z, sc_fail=z, raced_loads=z, dirty_cells=z,
        contention_hist=jnp.zeros((N_HIST,), jnp.int32),
        torn_retries=z, mcas_commits=z, mcas_aborts=z, mcas_rounds=z,
        mcas_backoff=z, route_overflow=z, collective_rounds=z,
        collective_words=z)


# ---------------------------------------------------------------------------
# In-graph accumulators (traced inside the existing jitted programs).
# ---------------------------------------------------------------------------

def contention_bucket(c: jax.Array) -> jax.Array:
    """floor(log2(c)) clipped to N_HIST-1, via integer threshold compares —
    bit-exact and mirrored verbatim by the numpy recount (no float log)."""
    th = jnp.left_shift(jnp.int32(1), jnp.arange(1, N_HIST, dtype=jnp.int32))
    return jnp.sum((c[:, None] >= th[None, :]).astype(jnp.int32), axis=1)


def count_table(t: Telemetry, n: int, ops, result, stats, *,
                eligible: jax.Array, taken: jax.Array) -> Telemetry:
    """Accumulate one `engine.apply` batch from masks the round already
    materialized (ops, per-lane success, ApplyStats, and the fast-path
    predicate / taken branch from `engine_round.path_counts`)."""
    kind, slot = ops.kind, ops.slot
    success = result.success
    one = jnp.int32(1)
    active = kind != 3                                    # engine.IDLE
    in_range = (slot >= 0) & (slot < n)
    elig = eligible.astype(jnp.int32)
    taken = taken.astype(jnp.int32)
    # Per-cell active-lane counts: the same scatter `fast_path_ok` builds,
    # so XLA CSEs it inside the fused round (no second pass over the batch).
    cslot = jnp.where(active & in_range, slot, n)
    counts = jnp.zeros((n + 1,), jnp.int32).at[cslot].add(1, mode="drop")
    c = counts[:n]
    hist = jnp.zeros((N_HIST,), jnp.int32).at[
        jnp.where(c > 0, contention_bucket(c), N_HIST)].add(1, mode="drop")
    return t._replace(
        batches=t.batches + one,
        ops_kind=t.ops_kind.at[kind].add(1, mode="drop"),
        fast_eligible=t.fast_eligible + elig,
        fast_taken=t.fast_taken + taken,
        rounds=t.rounds + stats.rounds,
        slow_rounds=t.slow_rounds + (1 - taken) * stats.rounds,
        cas_fail=t.cas_fail + jnp.sum(
            (active & (kind == 2) & ~success).astype(jnp.int32)),
        sc_fail=t.sc_fail + jnp.sum(
            (active & (kind == 5) & ~success).astype(jnp.int32)),
        raced_loads=t.raced_loads + stats.n_raced_loads,
        dirty_cells=t.dirty_cells + stats.n_dirty_cells,
        contention_hist=t.contention_hist + hist)


def count_read(t: Telemetry, ok: jax.Array) -> Telemetry:
    """Accumulate one `engine.read` batch: ok=False lanes observed a torn/
    locked cell and must retry (blocking strategies only)."""
    return t._replace(torn_retries=t.torn_retries
                      + jnp.sum((~ok).astype(jnp.int32)))


def count_mcas_round(t: Telemetry, committed, failed_now,
                     lost) -> Telemetry:
    """Accumulate one MCAS attempt round from the protocol's own masks."""
    i32 = lambda m: jnp.sum(m.astype(jnp.int32))  # noqa: E731
    return t._replace(
        mcas_commits=t.mcas_commits + i32(committed),
        mcas_aborts=t.mcas_aborts + i32(failed_now),
        mcas_rounds=t.mcas_rounds + jnp.int32(1),
        mcas_backoff=t.mcas_backoff + i32(lost))


@jax.jit
def _dist_accum(t: Telemetry, overflow, words) -> Telemetry:
    return t._replace(
        route_overflow=t.route_overflow
        + jnp.sum(overflow.astype(jnp.int32)),
        collective_rounds=t.collective_rounds + jnp.int32(1),
        collective_words=t.collective_words + words)


# ---------------------------------------------------------------------------
# The global store: one device-side Telemetry + one host-side counter dict.
# ---------------------------------------------------------------------------

_telem: Telemetry | None = None
_host: dict[str, int] = {}


def _is_tracer(x) -> bool:
    return isinstance(x, jax.core.Tracer)


def telemetry() -> Telemetry:
    """The live global counter pytree (device arrays; initialized lazily)."""
    global _telem
    if _telem is None:
        _telem = init_telemetry()
    return _telem


def carry_in(*samples) -> Telemetry | None:
    """The counter pytree an entry point should thread into its jitted
    program, or None when counting is off OR the entry point is itself
    being traced (any tracer among the sample pytrees' leaves means an
    outer jit owns this call, and the global must never absorb tracers —
    the outer program's own entry point does the counting)."""
    if not counters_on():
        return None
    for s in samples:
        if any(_is_tracer(leaf) for leaf in jax.tree_util.tree_leaves(s)):
            return None
    return telemetry()


def carry_out(t: Telemetry) -> None:
    """Absorb the counter pytree an entry point got back."""
    global _telem
    _telem = t


def record(**events: int) -> None:
    """Host-side counters (queue retry loops, serving dispatch counts,
    executor events): plain ints keyed by metric name, merged into
    `snapshot()`.  No-op when counting is off."""
    if not counters_on():
        return
    for name, v in events.items():
        _host[name] = _host.get(name, 0) + int(v)


def record_dist(overflow, words: int) -> None:
    """Accumulate one distributed collective round (route-overflow mask +
    the static `collective_words(dspec)` count).  One tiny scalar-
    accumulate dispatch per round when counters are on; nothing when off
    (the `counters_on` gate lives in the caller)."""
    carry_out(_dist_accum(telemetry(), overflow, jnp.int32(words)))


def reset() -> None:
    """Zero every counter (device and host)."""
    global _telem
    _telem = None
    _host.clear()


def snapshot() -> dict:
    """Every counter as one flat {metric_name: int} dict — THE stable
    metric-name schema (DESIGN.md §10).  Pulls the device counters to host;
    host-side counters (`record`) merge in under their own names."""
    t = telemetry()
    out = {"engine.batches": int(t.batches)}
    kinds = np.asarray(t.ops_kind)
    for j, name in enumerate(_KIND_NAMES):
        out[f"engine.ops.{name}"] = int(kinds[j])
    out["engine.fast.eligible"] = int(t.fast_eligible)
    out["engine.fast.taken"] = int(t.fast_taken)
    out["engine.rounds.total"] = int(t.rounds)
    out["engine.rounds.slow"] = int(t.slow_rounds)
    out["engine.fail.cas"] = int(t.cas_fail)
    out["engine.fail.sc"] = int(t.sc_fail)
    out["engine.loads.raced"] = int(t.raced_loads)
    out["engine.cells.dirty"] = int(t.dirty_cells)
    hist = np.asarray(t.contention_hist)
    for b in range(N_HIST):
        out[f"engine.contention.log2_{b:02d}"] = int(hist[b])
    out["read.torn_retries"] = int(t.torn_retries)
    out["mcas.commits"] = int(t.mcas_commits)
    out["mcas.aborts"] = int(t.mcas_aborts)
    out["mcas.rounds"] = int(t.mcas_rounds)
    out["mcas.backoff"] = int(t.mcas_backoff)
    out["dist.route_overflow"] = int(t.route_overflow)
    out["dist.rounds"] = int(t.collective_rounds)
    out["dist.words"] = int(t.collective_words)
    out.update(_host)
    return out


def derived(snap: dict) -> dict:
    """The counter-derived rates the BENCH payload carries (warn-only in
    benchmarks/compare.py; throughput stays the hard gate)."""
    batches = snap.get("engine.batches", 0)
    taken = snap.get("engine.fast.taken", 0)
    slow_batches = batches - taken
    return {
        "hit_rate_fast": taken / batches if batches else 0.0,
        "eligible_rate": (snap.get("engine.fast.eligible", 0) / batches
                          if batches else 0.0),
        "mean_slow_rounds": (snap.get("engine.rounds.slow", 0) / slow_batches
                             if slow_batches else 0.0),
    }
