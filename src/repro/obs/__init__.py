"""repro.obs — two-tier observability (DESIGN.md §10).

Tier 1 (`obs.telemetry`): in-graph int32 counters accumulated inside the
existing jitted programs, gated by BIGATOMIC_OBS=off|counters|trace so
`off` compiles to the exact pre-observability programs.

Tier 2 (`obs.recorder` + `obs.export`): the host-side executor timeline —
Chrome-trace/Perfetto spans per logical stream and per device slot, plus
a JSONL metrics sink with a stable name schema.
"""

from repro.obs.export import (chrome_trace, write_chrome_trace,
                              write_metrics_jsonl)
from repro.obs.recorder import Recorder
from repro.obs.telemetry import (Telemetry, configured_mode, counters_on,
                                 derived, init_telemetry, record, reset,
                                 snapshot, trace_on)

__all__ = [
    "Telemetry", "configured_mode", "counters_on", "trace_on",
    "init_telemetry", "record", "reset", "snapshot", "derived",
    "Recorder", "chrome_trace", "write_chrome_trace", "write_metrics_jsonl",
]
