"""Tier-2 observability: the host-side executor timeline (DESIGN.md §10).

The executor already journals everything a timeline needs — per-issue
`IssueRec`s, round boundaries, checkpoint/restore, shard-loss recoveries,
preempt drains, watchdog flags.  `Recorder` is the sink those hooks feed:

  * always (any BIGATOMIC_OBS mode): per-round issue-latency bookkeeping —
    this replaces the executor's old ad-hoc `_last_times` dict as the
    input to `runtime.stragglers.StragglerWatchdog` — plus event counts.
  * under BIGATOMIC_OBS=trace: Chrome-trace/Perfetto span events, one
    timeline track per logical stream (pid 0) and one per device slot
    (pid 1), exported by `obs.export.chrome_trace`.

The Recorder is pure host-side python: it never touches jax and costs a
few dict writes per issue when tracing is off.
"""

from __future__ import annotations

import time

from repro.obs import telemetry as _telemetry

# Chrome-trace pids: one process per conceptual track group.
PID_STREAMS = 0
PID_SLOTS = 1


class Recorder:
    """Collects executor events; see `obs.export` for serialization.

    trace: force the span-event tier on/off; defaults to the static
        BIGATOMIC_OBS flag (`trace_on()`), read once at construction.
    clock: seconds-returning monotonic clock (injectable for tests).
    """

    def __init__(self, *, trace: bool | None = None, clock=time.perf_counter):
        self.trace = _telemetry.trace_on() if trace is None else trace
        self.clock = clock
        self._t0 = clock()
        self.events: list[dict] = []     # chrome-trace events (trace tier)
        self.counts: dict[str, int] = {}
        self.flags: list[tuple[int, list[int]]] = []  # (round, streams)
        # Issue-latency bookkeeping (the watchdog's input): latest latency
        # per stream this round, and the last-known latency per stream ever.
        self._round_lat: dict[int, float] = {}
        self._last_lat: dict[int, float] = {}
        # Device-slot track allocation: lowest free slot id per span.
        self._free_slots: list[int] = []
        self._next_slot = 0
        self._names: dict[tuple[int, int], str] = {}

    # -- clock helpers ----------------------------------------------------

    def _us(self) -> float:
        return (self.clock() - self._t0) * 1e6

    def _bump(self, name: str, v: int = 1) -> None:
        self.counts[name] = self.counts.get(name, 0) + v

    def _meta(self, pid: int, tid: int, name: str) -> None:
        if self._names.setdefault((pid, tid), name) == name:
            self.events.append({"ph": "M", "name": "thread_name", "pid": pid,
                                "tid": tid, "args": {"name": name}})

    # -- round / issue hooks (called by runtime.executor) ------------------

    def round_begin(self, round_idx: int) -> None:
        self._round_lat.clear()
        self._bump("exec.rounds")

    def round_end(self, round_idx: int) -> None:
        self._last_lat.update(self._round_lat)

    def issue_latency(self, stream_idx: int, seconds: float) -> None:
        """Record the host-side issue latency of one stream this round."""
        self._round_lat[stream_idx] = seconds
        self._bump("exec.issues")

    def round_issued(self) -> bool:
        return bool(self._round_lat)

    def latency_vector(self, n_streams: int) -> list[float]:
        """Per-stream latencies for `StragglerWatchdog.observe`: streams
        quiet this round carry their last-known latency, streams never seen
        carry the fleet's current median (so they read as healthy)."""
        lats = sorted(self._round_lat.values())
        fill = lats[len(lats) // 2]
        return [self._last_lat.get(si, self._round_lat.get(si, fill))
                for si in range(n_streams)]

    def straggler_flags(self, round_idx: int, flagged) -> None:
        flagged = sorted(flagged)
        self.flags.append((round_idx, flagged))
        self._bump("exec.straggler_flags", len(flagged))
        self.instant(f"straggler:{flagged}", pid=PID_STREAMS,
                     tid=flagged[0] if flagged else 0)

    # -- span events (trace tier) -----------------------------------------

    def begin_issue(self, stream_idx: int, stream_name: str):
        """Open a span: returns an opaque token for `end_issue`, or None
        when the trace tier is off (hot-path callers pass it straight
        back, no branching needed)."""
        if not self.trace:
            return None
        if self._free_slots:
            slot = self._free_slots.pop()
        else:
            slot = self._next_slot
            self._next_slot += 1
        self._meta(PID_STREAMS, stream_idx, f"stream:{stream_name}")
        self._meta(PID_SLOTS, slot, f"slot:{slot}")
        return (stream_idx, slot, self._us())

    def end_issue(self, token, *, name: str = "issue",
                  args: dict | None = None) -> None:
        """Close a span at retire time: emits one complete ("X") event on
        the stream track and one on the device-slot track."""
        if token is None:
            return
        stream_idx, slot, t0 = token
        dur = max(self._us() - t0, 0.01)
        base = {"ph": "X", "name": name, "ts": t0, "dur": dur,
                "args": args or {}}
        self.events.append({**base, "pid": PID_STREAMS, "tid": stream_idx})
        self.events.append({**base, "pid": PID_SLOTS, "tid": slot})
        self._free_slots.append(slot)
        self._bump("exec.retires")

    def cancel_issue(self, token) -> None:
        """Abandon a span whose issue turned out to be a no-op: frees the
        device slot, emits nothing."""
        if token is not None:
            self._free_slots.append(token[1])

    def instant(self, name: str, *, pid: int = PID_STREAMS,
                tid: int = 0, args: dict | None = None) -> None:
        if not self.trace:
            return
        self.events.append({"ph": "i", "name": name, "ts": self._us(),
                            "pid": pid, "tid": tid, "s": "g",
                            "args": args or {}})

    # -- lifecycle events --------------------------------------------------

    def checkpoint(self, round_idx: int) -> None:
        self._bump("exec.checkpoints")
        self.instant(f"checkpoint@{round_idx}")

    def recovery(self, round_idx: int, shard: int, replayed: int,
                 latency_s: float) -> None:
        self._bump("exec.recoveries")
        self._bump("exec.replayed", replayed)
        self.instant(f"recover:shard{shard}", args={
            "round": round_idx, "replayed": replayed,
            "latency_s": latency_s})

    def preempt(self, round_idx: int, drained: int) -> None:
        self._bump("exec.preempts")
        self.instant(f"preempt@{round_idx}", args={"drained": drained})

    def data_fault(self, round_idx: int, kind: str, info: dict) -> None:
        self._bump("exec.data_faults")
        self.instant(f"fault:{kind}@{round_idx}", args=info)

    def scrub(self, round_idx: int, report) -> None:
        self._bump("exec.scrubs")
        self._bump("guard.cells_detected", len(report.detected))
        self._bump("guard.cells_repaired", len(report.repaired))
        self._bump("guard.cells_quarantined", len(report.quarantined))
        self.instant(f"scrub@{round_idx}", args={
            "detected": report.detected, "repaired": report.repaired,
            "quarantined": report.quarantined,
            "latency_s": report.latency_s})

    def shed(self, round_idx: int, stream: int, reason: str) -> None:
        self._bump("exec.shed")
        self.instant(f"shed:s{stream}", args={"round": round_idx,
                                              "reason": reason})

    # -- output ------------------------------------------------------------

    def metrics(self) -> dict:
        """Host counter snapshot (merged with the in-graph counters by
        `obs.export.write_metrics_jsonl`)."""
        return dict(self.counts)
