"""Logical-axis sharding annotations + mesh rule tables.

Models annotate intermediates with *logical* axis names
(``dist.shard(x, "batch", "seq", "heads", None)``) and stay mesh-agnostic:
outside an ``axis_rules`` context the annotation is a no-op, inside one it
lowers to ``with_sharding_constraint`` against the active mesh.  The rules
table maps logical names to mesh axes:

    batch                  -> ('pod', 'data')   whichever exist on the mesh
    seq                    -> replicated (no context parallelism by default)
    heads / kv_heads / mlp / vocab / experts / expert_mlp
                           -> 'model'           when the mesh has one

An annotation silently drops a mapping when the dimension is not divisible
by the mapped axis size, or when the mesh axis is already used by an earlier
dimension of the same array — so reduced test configs and laptop meshes
never fail to compile, they just shard less.
"""

from __future__ import annotations

import contextlib
import math
from typing import NamedTuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical axes that map onto the tensor/expert-parallel mesh axis.
MODEL_AXES = ("heads", "kv_heads", "mlp", "vocab", "experts", "expert_mlp",
              "embed")


def make_rules(cfg, mesh: Mesh) -> dict:
    """Logical-name -> mesh-axis table for this (config, mesh) pair."""
    axes = set(mesh.axis_names)
    batch = tuple(a for a in ("pod", "data") if a in axes)
    model = "model" if "model" in axes else None
    rules: dict = {"batch": batch or None, "seq": None}
    for name in MODEL_AXES:
        rules[name] = model
    return rules


class _Ctx(NamedTuple):
    mesh: Mesh
    rules: dict


_STACK: list[_Ctx] = []


@contextlib.contextmanager
def axis_rules(mesh: Mesh, rules: dict):
    """Activate (mesh, rules) for `shard` annotations traced inside."""
    _STACK.append(_Ctx(mesh, rules))
    try:
        yield
    finally:
        _STACK.pop()


def _axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _spec(shape, names, mesh: Mesh, rules: dict) -> P:
    sizes = _axis_sizes(mesh)
    used: set = set()
    out = []
    for dim, name in zip(shape, names):
        mapped = rules.get(name) if name is not None else None
        if mapped is None:
            out.append(None)
            continue
        axes = mapped if isinstance(mapped, tuple) else (mapped,)
        axes = tuple(a for a in axes if a not in used)
        n = math.prod(sizes[a] for a in axes) if axes else 1
        if n <= 1 or dim % n != 0:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def shard(x, *names):
    """Constrain `x` (rank == len(names)) to its logical-axis sharding.

    No-op outside an `axis_rules` context, so models, kernels and tests run
    unchanged on a single device.
    """
    if not _STACK:
        return x
    ctx = _STACK[-1]
    if x.ndim != len(names):
        raise ValueError(f"shard(): rank {x.ndim} != {len(names)} names")
    spec = _spec(x.shape, names, ctx.mesh, ctx.rules)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Whole-pytree shardings (device_put / jit in_shardings)
# ---------------------------------------------------------------------------

def batch_shardings(batch, mesh: Mesh, rules: dict):
    """Data-parallel shardings for an input pytree: leading axis over the
    batch mesh axes when divisible, replicated otherwise.  Works on arrays
    and ShapeDtypeStructs alike."""
    baxes = rules.get("batch") or ()
    baxes = baxes if isinstance(baxes, tuple) else (baxes,)
    sizes = _axis_sizes(mesh)
    n = math.prod(sizes[a] for a in baxes) if baxes else 1

    def leaf(x):
        if n > 1 and getattr(x, "ndim", 0) >= 1 and x.shape[0] % n == 0:
            ax = baxes if len(baxes) > 1 else baxes[0]
            return NamedSharding(mesh, P(ax))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(leaf, batch)


def param_shardings(params, cfg, mesh: Mesh, rules: dict):
    """Tensor-parallel shardings for a parameter pytree: the largest dim
    divisible by the 'model' axis shards over it; everything else (norm
    scales, odd shapes) replicates.  Mirrored by optimizer moments."""
    sizes = _axis_sizes(mesh)
    model_n = sizes.get("model", 1)

    def leaf(x):
        shape = getattr(x, "shape", ())
        if model_n > 1 and len(shape) >= 2:
            dims = sorted(range(len(shape)), key=lambda i: -shape[i])
            for i in dims:
                if shape[i] >= model_n and shape[i] % model_n == 0:
                    ax: list = [None] * len(shape)
                    ax[i] = "model"
                    return NamedSharding(mesh, P(*ax))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map(leaf, params)
