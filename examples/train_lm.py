"""End-to-end training driver: a ~100M-parameter llama-style model trained
for a few hundred steps with the full production stack — sharded step,
deterministic data pipeline, atomic checkpoints, preemption guard, versioned
snapshot store.

  PYTHONPATH=src python examples/train_lm.py                 # ~100M, 200 steps
  PYTHONPATH=src python examples/train_lm.py --tiny          # CI-sized
  PYTHONPATH=src python examples/train_lm.py --resume        # restart test

The config is deepseek-7b's family scaled to ~100M params (8L x 768d, the
same GQA/SwiGLU/RMSNorm stack as the full config) so everything exercised
here is exactly what the production configs run.
"""

import argparse
import dataclasses

import numpy as np

from repro.configs import get_config
from repro.configs.shapes import Shape
from repro.launch.train import train


def model_100m():
    base = get_config("deepseek_7b")
    return dataclasses.replace(
        base, name="llama-100m", n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=12, d_ff=2048, vocab=32000, q_block=256, kv_block=256)


def model_tiny():
    return get_config("deepseek_7b", reduced=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--ckpt-dir", default="/tmp/atomax_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.tiny:
        cfg = model_tiny()
        shape = Shape("train", args.seq or 128, args.batch or 2, "train")
        steps = args.steps or 20
    else:
        cfg = model_100m()
        shape = Shape("train", args.seq or 512, args.batch or 4, "train")
        steps = args.steps or 200

    import jax
    n_params = cfg.n_params()
    print(f"[example] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"seq={shape.seq_len} batch={shape.global_batch} steps={steps}")
    if not args.resume:
        import shutil
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    params, opt, hist = train(cfg, shape, steps=steps,
                              ckpt_dir=args.ckpt_dir, ckpt_every=50,
                              log_every=10, lr=1e-3)
    losses = hist["loss"]
    print(f"[example] loss {losses[0]:.3f} -> {losses[-1]:.3f}  "
          f"({np.mean(hist['step_time'][1:]):.2f}s/step)")
    assert losses[-1] < losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
