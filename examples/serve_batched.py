"""Batched serving demo: continuous batching through the paged-KV engine
whose page table is a big-atomic CacheHash.

  PYTHONPATH=src python examples/serve_batched.py
  PYTHONPATH=src python examples/serve_batched.py --strategy seqlock

Submits a staggered stream of requests (different lengths and arrival times),
decodes them concurrently, and prints per-request tokens plus engine
throughput.  `--strategy` switches the page-table big-atomic implementation —
the serving loop is oblivious, which is the point: big atomics are a
substrate, not an API change.
"""

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models.transformer import init_params
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b")
    ap.add_argument("--strategy", default="cached_me",
                    choices=["cached_me", "cached_wf", "seqlock", "indirect"])
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=3, n_pages=64, page_size=8,
                        max_pages_per_seq=8, strategy=args.strategy)

    rng = np.random.default_rng(0)
    import time
    t0 = time.time()
    pending = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab,
                                           int(rng.integers(8, 30))
                                           ).astype(np.int32),
                max_new_tokens=args.max_new)
        for i in range(args.requests)
    ]
    # staggered arrivals: submit two up front, one more every 2 steps
    eng.submit(pending.pop(0))
    eng.submit(pending.pop(0))
    steps = 0
    while True:
        live = eng.step()
        steps += 1
        if steps % 2 == 0 and pending:
            eng.submit(pending.pop(0))
        if live == 0 and not pending and not eng.pending():
            break
    dt = time.time() - t0
    out = {r.rid: r.out_tokens for r in eng.requests.values()}
    total = sum(len(v) for v in out.values())
    for rid in sorted(out):
        print(f"[serve] request {rid} ({len(out[rid])} tokens): {out[rid]}")
    print(f"[serve] {total} tokens / {steps} engine steps / {dt:.2f}s "
          f"({total/dt:.1f} tok/s) page-table strategy={args.strategy}")


if __name__ == "__main__":
    main()
