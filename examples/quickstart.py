"""Quickstart: the big-atomic table API in 60 lines.

  PYTHONPATH=src python examples/quickstart.py

Creates a table of 1024 big atomics of 4 words each (strategy: the paper's
Cached-Memory-Efficient), runs batched load/store/CAS against it, shows the
torn-writer resilience that motivates the whole design, and finishes with a
CacheHash insert/find/delete round-trip.
"""

import numpy as np
import jax.numpy as jnp

from repro.core import semantics as sem
from repro.core.bigatomic import BigAtomicTable, begin_update, read_protocol
from repro.core.cachehash import CacheHash

# --- a table of 1024 cells x 4 words, Cached-Memory-Efficient --------------
table = BigAtomicTable(n=1024, k=4, strategy="cached_me", p_max=256)

# batched stores: lanes are the "threads" of one linearized step
slots = np.arange(8)
values = np.arange(32, dtype=np.uint32).reshape(8, 4)
table.store(slots, values)
print("loaded:", np.asarray(table.load(slots[:3])))

# batched CAS: succeeds only where `expected` matches
expected = values[:3].copy()
expected[1] += 99                                  # lane 1 will fail
desired = values[:3] + 1000
res, stats, traffic = table.cas(slots[:3], expected, desired)
print("cas success:", np.asarray(res.success))     # [True, False, True]
print("rounds:", int(stats.rounds), "| modeled bytes:",
      float(traffic.bytes_read + traffic.bytes_written))

# --- the paper's point: a stalled writer doesn't hurt readers --------------
frozen = begin_update(table.state, slot=5, new_value=np.full(4, 7, np.uint32),
                      strategy="cached_me")        # writer stalls mid-copy
vals, ok = read_protocol(frozen, jnp.asarray([5]), strategy="cached_me")
print("read under torn writer: ok =", bool(ok[0]),
      "value =", np.asarray(vals[0]), "(consistent NEW value, no blocking)")

# --- CacheHash: the §4 hash table with inlined first links -----------------
h = CacheHash(nb=256, vw=2, strategy="cached_me")
keys = np.asarray([11, 22, 33], np.uint32)
vals = np.asarray([[1, 2], [3, 4], [5, 6]], np.uint32)
h.insert(keys, vals)
res, stats = h.find(keys)
print("find:", np.asarray(res.found), np.asarray(res.value))
print("inline hits:", int(stats.inline_hits), "of 3 (one cell access each)")
h.delete(keys[:1])
res, _ = h.find(keys)
print("after delete:", np.asarray(res.found))

# --- observability: the §10 counters, on demand ----------------------------
# BIGATOMIC_OBS=off (the default) costs nothing — the jitted programs are
# byte-identical.  Flip it to "counters" and every engine call accumulates
# the in-graph telemetry; pull it any time with obs.snapshot():
import os

os.environ["BIGATOMIC_OBS"] = "counters"
import repro.obs as obs

obs.reset()
table.store(slots, values)
table.cas(slots[:3], expected, desired)
snap = obs.snapshot()          # flat {metric_name: int}, stable schema
rates = obs.derived(snap)      # hit_rate_fast / eligible_rate / mean_slow_rounds
print("engine.batches:", snap["engine.batches"],
      "| fast-path hit rate:", round(rates["hit_rate_fast"], 2),
      "| cas failures:", snap["engine.fail.cas"])
# The executor timeline tier: pass obs.Recorder(trace=True) to
# runtime.Executor and export with obs.write_chrome_trace(rcd, path) —
# one Perfetto track per logical stream, one per device slot.  The full
# metric-name table lives in DESIGN.md §10.
os.environ.pop("BIGATOMIC_OBS")

# --- fault tolerance: the §11 guard, on demand -----------------------------
# BIGATOMIC_GUARD=off (the default) costs nothing.  The guard layer gives
# you a per-cell integrity digest, a scrub pass that detects/repairs/
# quarantines corruption, and a seeded injector to prove it works:
from repro import guard
from repro.guard.inject import inject_table_fault
from repro.runtime.faults import Fault

baseline = np.asarray(guard.cell_digest(table.spec, table.state))
corrupt, info = inject_table_fault(                 # flip one random bit
    table.spec, table.state, Fault(round=1, kind="bit_flip"),
    np.random.default_rng(0))
report = guard.scrub(table.spec, corrupt, baseline=baseline)
print("injected", info["kind"], "at slot", info["slot"],
      "-> detected:", sorted(report.detected),
      "| quarantined:", sorted(report.quarantined))
# Under runtime.Executor(scrub_every=1, retry_budget=...) the scrub runs
# automatically at round boundaries, repairs cells with a trusted copy,
# masks ops against quarantined cells (success=False), and sheds streams
# that exhaust their retry budget instead of crashing the run; the
# serving engine's OverloadPolicy sheds admissions the same way.  The
# chaos gate (`python -m repro.guard.chaos`) replays seeded fault
# schedules through the sequential oracle — see DESIGN.md §11.
