"""repro.obs smoke bench (ISSUE 9): drive the two observability tiers and
export both artifact kinds.

  * counters sweep — a fixed mixed LOAD/STORE/CAS + MCAS + queue workload
    under BIGATOMIC_OBS=counters; the full snapshot (+ derived rates)
    lands in benchmarks/results/obs_metrics.jsonl.
  * trace run — an oversubscribed executor with an injected straggler
    delay, recorded span-by-span; the Chrome-trace/Perfetto timeline
    lands in benchmarks/results/obs_trace.json.

CI's `obs` job runs this with --quick and uploads both files as workflow
artifacts.
"""

from __future__ import annotations

import contextlib
import os

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")


@contextlib.contextmanager
def _obs_mode(mode: str):
    saved = os.environ.get("BIGATOMIC_OBS")
    os.environ["BIGATOMIC_OBS"] = mode
    try:
        yield
    finally:
        if saved is None:
            os.environ.pop("BIGATOMIC_OBS", None)
        else:
            os.environ["BIGATOMIC_OBS"] = saved


def counters_sweep(quick: bool = False) -> dict:
    """The fixed counter workload; returns the snapshot it produced.
    Assumes BIGATOMIC_OBS=counters is already in force."""
    import numpy as np

    from repro import atomics, obs
    from repro.core import engine

    obs.reset()
    n, k, p = 256, 2, 64
    batches = 4 if quick else 16
    spec = atomics.AtomicSpec(n, k, "cached_me", p_max=p)
    state, ctx = engine.init(spec), None
    rng = np.random.default_rng(0)
    for b in range(batches):
        kind = rng.integers(0, 3, p).astype(np.int32)   # LOAD/STORE/CAS
        if b % 2:
            # contended: half the lanes hammer 4 hot cells (slow path) ...
            slot = np.where(rng.random(p) < 0.5,
                            rng.integers(0, 4, p),
                            rng.integers(0, n, p)).astype(np.int32)
        else:
            # ... alternating with all-distinct batches (fast path).
            slot = rng.permutation(n)[:p].astype(np.int32)
        current = np.asarray(atomics.logical(spec, state))
        expected = np.where((rng.random(p) < 0.5)[:, None],
                            current[slot],
                            rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32))
        desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
        ops = atomics.make_ops(kind, slot, expected.astype(np.uint32),
                               desired, k=k)
        state, ctx, _, _, _ = engine.apply(spec, state, ops, ctx)

    # one MCAS round (mcas.* counters) ...
    t, w = 16, 3
    slots = np.stack([rng.choice(n, w, replace=False)
                      for _ in range(t)]).astype(np.int32)
    current = np.asarray(atomics.logical(spec, state))
    expected = np.where((rng.random(t) < 0.6)[:, None, None],
                        current[slots],
                        rng.integers(0, 2 ** 32, (t, w, k), dtype=np.uint32))
    txns = atomics.make_txns(slots, expected.astype(np.uint32),
                             rng.integers(0, 2 ** 32, (t, w, k),
                                          dtype=np.uint32), k=k)
    atomics.mcas(spec, state, txns)

    # ... and one over-subscribed queue run (queue.* host counters).
    from repro.sync.queue import BigQueue
    q = BigQueue(8, k=2, strategy="cached_me")
    q.enqueue_batch(np.arange(12, dtype=np.uint32))
    q.dequeue_batch(12)
    return obs.snapshot()


def trace_run(quick: bool = False):
    """One oversubscribed executor run with a straggler fault, recorded in
    the span tier; returns the Recorder."""
    from repro import atomics
    from repro.obs import Recorder
    from repro.runtime import (Executor, Fault, FaultInjector, LocalTarget,
                               SyntheticStream)

    n, k, width = 128, 2, 16
    n_batches = 4 if quick else 12
    target = LocalTarget(atomics.AtomicSpec(n, k, "seqlock", p_max=64))
    streams = [SyntheticStream(f"s{i}", seed=i, n=n, k=k, width=width,
                               n_batches=n_batches, hot_cells=4,
                               hot_frac=0.25)
               for i in range(4)]
    rcd = Recorder(trace=True)
    ex = Executor(target, streams, slots=2, oversubscription=2,
                  injector=FaultInjector([Fault(round=2, kind="delay",
                                                stream=1, seconds=0.01,
                                                rounds=3)]),
                  recorder=rcd)
    ex.run()
    return rcd


def main(quick: bool = False) -> None:
    from repro import obs

    os.makedirs(RESULTS, exist_ok=True)
    with _obs_mode("counters"):
        snap = counters_sweep(quick)
        rcd = trace_run(quick)
        metrics_path = os.path.join(RESULTS, "obs_metrics.jsonl")
        obs.write_metrics_jsonl(metrics_path, extra=rcd.metrics())
        trace_path = os.path.join(RESULTS, "obs_trace.json")
        obs.write_chrome_trace(rcd, trace_path)

    rates = obs.derived(snap)
    print(f"  engine batches      {snap['engine.batches']}")
    print(f"  fast-path hit rate  {rates['hit_rate_fast']:.2f}")
    print(f"  mean slow rounds    {rates['mean_slow_rounds']:.2f}")
    print(f"  mcas commits/aborts {snap['mcas.commits']}/{snap['mcas.aborts']}")
    print(f"  queue rounds        {snap.get('queue.rounds', 0)}")
    print(f"  trace events        {len(rcd.events)}")
    print(f"  wrote {metrics_path}")
    print(f"  wrote {trace_path}")


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
