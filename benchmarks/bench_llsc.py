"""LL/SC + sync-queue workload family: op mix × contention × strategy.

Two sweeps, both emitted to benchmarks/results/bench_llsc.json:

  llsc   raw k-word LL/SC batches against a big-atomic table.  Op mix is
         the LL fraction (the rest SC), contention is Zipfian slot skew z:
         as z grows, more SCs collide on hot cells and only one per cell
         per batch can win, so the success rate and effective Mops/s fall —
         the batch-step analogue of CAS retry storms.  bytes/op and rmw/op
         come from the same modeled Traffic terms as bench_atomics.

  queue  bounded MPMC ring drains (p enqueuers then p dequeuers, and a
         mixed half/half race) under the three contention-management
         policies of Dice et al. (none / const / capped-exp backoff).
         rounds/op is the wasted-work metric: every round a lane spends
         retrying or backing off is a round it isn't serving traffic.

Usage:
  PYTHONPATH=src python -m benchmarks.bench_llsc [--quick] [--tiny]

--tiny is the CI smoke mode (a few seconds): one strategy, one size.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, save_results, time_op
from repro import atomics
from repro.sync.queue import DEQ, ENQ, BackoffPolicy, BigQueue

STRATEGIES = ["seqlock", "indirect", "cached_wf", "cached_me"]
POLICIES = [BackoffPolicy("none"), BackoffPolicy("const", 1),
            BackoffPolicy("exp", 1, 4)]
CONTENTION_Z = [0.0, 0.9, 2.0]        # >= 3 contention levels (acceptance)


def _llsc_batch(rng, *, p, n, k, ll_frac, z):
    kind = np.where(rng.random(p) < ll_frac, atomics.LL, atomics.SC).astype(
        np.int32)
    if z <= 0.0:
        slots = rng.integers(0, n, p)
    else:
        slots = (rng.zipf(max(z, 1.01), size=p) - 1) % n
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    return atomics.sync_ops(kind, slots.astype(np.int32), desired, k=k)


def run_llsc_cell(strategy, *, n, k, p, ll_frac, z, reps=3, seed=0):
    rng = np.random.default_rng(seed)
    spec = atomics.AtomicSpec(n, k, strategy, p_max=p)
    state = atomics.init(spec)
    ctx = atomics.init_ctx(p, k)
    # link every lane first so the SC lanes have something to commit against
    link_slots = (rng.zipf(max(z, 1.01), size=p) - 1) % n if z > 0 \
        else rng.integers(0, n, p)
    state, ctx, _, _, _ = atomics.apply(
        spec, state,
        atomics.sync_ops(np.full(p, atomics.LL),
                         np.asarray(link_slots, np.int32), k=k), ctx)
    ops = _llsc_batch(rng, p=p, n=n, k=k, ll_frac=ll_frac, z=z)
    # SC lanes must target their linked slot to be meaningful
    slots = np.where(np.asarray(ops.kind) == atomics.SC,
                     np.asarray(ctx.slot), np.asarray(ops.slot))
    ops = atomics.OpBatch(ops.kind, np.asarray(slots, np.int32),
                          ops.expected, ops.desired)

    def step(state, ctx, ops):
        return atomics.apply(spec, state, ops, ctx)

    dt, (st2, ctx2, res, stats, traffic) = time_op(step, state, ctx, ops,
                                                   reps=reps)
    n_sc = int(stats.n_updates) + int(stats.n_cas_fail)
    return {
        "strategy": strategy, "n": n, "k": k, "p": p,
        "ll_frac": ll_frac, "z": z,
        "mops_s": p / dt / 1e6,
        "sc_success": (int(stats.n_updates) / n_sc) if n_sc else 1.0,
        "bytes_op": float((traffic.bytes_read + traffic.bytes_written) / p),
        "rmw_op": float(traffic.rmw_ops / p),
    }


def run_queue_cell(strategy, policy: BackoffPolicy, *, capacity, p, k=2,
                   seed=0):
    rng = np.random.default_rng(seed)

    def drive(q):
        vals = rng.integers(0, 2 ** 32, p, dtype=np.uint32)
        s1 = q.enqueue_batch(vals)
        out, s2 = q.dequeue_batch(p)
        # mixed race: half enqueue, half dequeue, same call
        kinds = np.asarray([ENQ, DEQ] * (p // 2) or [ENQ, DEQ])
        mix_vals = rng.integers(0, 2 ** 32, (len(kinds), k - 1),
                                dtype=np.uint32)
        _, s3, r_mix = q.run_batch(kinds, mix_vals)
        return int(s1.sum() + s2.sum() + s3.sum()), r_mix, int(s3.sum())

    def fresh():
        return BigQueue(capacity, k=k, strategy=strategy, policy=policy,
                        p_max=p)

    drive(fresh())                   # warmup: pay JIT outside the clock
    import time as _time
    q = fresh()
    t0 = _time.perf_counter()
    n_ops, r_mix, n_mix = drive(q)
    dt = _time.perf_counter() - t0
    return {
        "strategy": strategy, "policy": policy.kind,
        "capacity": capacity, "p": p,
        "ops_s": n_ops / dt,
        "rounds_mixed": r_mix,
        "rounds_per_op": r_mix / max(n_mix, 1),
        "committed": len(q.commit_log),
    }


def main(quick: bool = False, tiny: bool = False):
    strategies = ["cached_me"] if tiny else STRATEGIES
    n = 256 if tiny else (1 << 10 if quick else 1 << 14)
    p = 64 if tiny else (256 if quick else 1024)
    k = 4

    llsc_rows = []
    for z in CONTENTION_Z:
        for ll_frac in ([0.5] if tiny else [0.9, 0.5, 0.1]):
            for s in strategies:
                llsc_rows.append(run_llsc_cell(
                    s, n=n, k=k, p=p, ll_frac=ll_frac, z=z,
                    reps=1 if tiny else 3))
    print_table("LL/SC: op mix x contention x strategy", llsc_rows,
                ["strategy", "z", "ll_frac", "mops_s", "sc_success",
                 "bytes_op", "rmw_op"])

    queue_rows = []
    lanes = [4] if tiny else [2, 8, 16]          # queue contention levels
    cap = 8 if tiny else 16
    for p_lanes in lanes:
        for policy in (POLICIES[:1] if tiny else POLICIES):
            for s in (["cached_me"] if tiny else ["seqlock", "cached_me"]):
                queue_rows.append(run_queue_cell(
                    s, policy, capacity=cap, p=p_lanes))
    print_table("MPMC queue: contention x backoff policy", queue_rows,
                ["strategy", "policy", "p", "ops_s", "rounds_mixed",
                 "rounds_per_op"])

    payload = {"llsc": llsc_rows, "queue": queue_rows}
    path = save_results("bench_llsc", payload)
    print(f"\nresults -> {path}")

    # soft paper-claim checks
    by_z = {}
    for r in llsc_rows:
        by_z.setdefault(r["z"], []).append(r["sc_success"])
    rates = [float(np.mean(v)) for _, v in sorted(by_z.items())]
    print(f"[check] SC success vs contention z {sorted(by_z)}: "
          f"{[f'{r:.2f}' for r in rates]} -> "
          f"{'OK' if rates[0] >= rates[-1] else 'UNEXPECTED'} "
          f"(skew should cost success)")
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick, tiny=args.tiny)
