"""repro.guard perf bench (DESIGN.md §11): what fault tolerance costs.

Three fixed cells, each a number the guard's design argues about:

  * scrub throughput — cells/s of the whole-table integrity pass
    (FNV digest + structural invariants) per strategy; this bounds how
    often `scrub_every` can afford to run.
  * recovery latency — wall-clock from an injected bit flip at a drained
    boundary to the cell spliced back from the checkpoint (the
    `ScrubReport.latency_s` the executor records).
  * shed rate under overload — streams confined to a quarantined slot
    range retry through their backoff budgets and shed; the rate (shed
    streams / streams) measures how fast degradation converges instead
    of livelocking.

Results land in benchmarks/results/faults.json; `benchmarks/baseline.py`
commits the same cells into the BENCH document (`faults` suite), where
scrub throughput is gated like any other `ops_s` metric and the latency /
rate cells ride along informationally.
"""

from __future__ import annotations

import json
import os
import time

RESULTS = os.path.join(os.path.dirname(os.path.abspath(__file__)), "results")

SCRUB_N, SCRUB_K = 1 << 14, 4


def scrub_throughput_cell(strategy: str, *, reps: int = 5) -> dict:
    """cells/s of a full detection pass (digest + invariants) at the
    fixed table shape."""
    import numpy as np

    from repro.core import engine
    from repro.core.specs import AtomicSpec
    from repro.guard import cell_digest, check_invariants

    spec = AtomicSpec(SCRUB_N, SCRUB_K, strategy, 64)
    state = engine.init(spec, np.arange(SCRUB_N * SCRUB_K, dtype=np.uint32)
                        .reshape(SCRUB_N, SCRUB_K))

    def one_pass():
        d = cell_digest(spec, state)
        masks = check_invariants(spec, state)
        d.block_until_ready()
        for m in masks.values():
            m.block_until_ready()

    one_pass()                                      # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        one_pass()
    dt = (time.perf_counter() - t0) / reps
    return {"strategy": strategy, "cells_s": SCRUB_N / dt,
            "pass_s": dt, "n": SCRUB_N, "k": SCRUB_K}


def recovery_latency_cell(*, seed: int = 11) -> dict:
    """Inject one bit flip into a checkpoint-clean cell mid-run; report
    the scrub pass latency and that the cell came back repaired."""
    from repro.guard.chaos import run_chaos

    res = run_chaos(seed, "seqlock", n=256, k=2, width=16, n_streams=3,
                    n_batches=4, data_faults=2, sched_faults=0)
    reports = [r for r in res["executor"].scrubber.reports
               if r.detected or r.repaired]
    lat = [r.latency_s for r in reports]
    return {"scrubs": len(res["executor"].scrubber.reports),
            "detecting_scrubs": len(reports),
            "repaired": sum(len(r.repaired) for r in reports),
            "quarantined": sum(len(r.quarantined) for r in reports),
            "latency_s": max(lat) if lat else 0.0}


def shed_rate_cell(*, n_streams: int = 4) -> dict:
    """Overload degradation: every stream hammers one slot range that the
    guard quarantines wholesale; measure how many shed (vs livelock)."""
    import numpy as np

    from repro.core.specs import AtomicSpec
    from repro.runtime.executor import Executor, LocalTarget
    from repro.runtime.faults import Fault, FaultInjector
    from repro.runtime.streams import SyntheticStream
    from repro.sync.queue import BackoffPolicy

    os.environ["BIGATOMIC_GUARD"] = "on"
    try:
        lo, hi = 0, 4
        spec = AtomicSpec(16, 2, "seqlock", 16)
        streams = [SyntheticStream(f"s{i}", seed=500 + i, n=16, k=2,
                                   width=4, n_batches=8,
                                   slot_lo=lo, slot_hi=hi)
                   for i in range(n_streams)]
        faults = [Fault(round=2, kind="bit_flip", slot=s, field="data")
                  for s in range(lo, hi)]
        ex = Executor(LocalTarget(spec), streams,
                      injector=FaultInjector(faults, seed=3),
                      checkpoint_every=0, retry_budget=1,
                      backoff=BackoffPolicy("none"))
        t0 = time.perf_counter()
        rep = ex.run()
        dt = time.perf_counter() - t0
    finally:
        os.environ.pop("BIGATOMIC_GUARD", None)
    return {"streams": n_streams, "shed": len(rep["shed"]),
            "shed_rate": len(rep["shed"]) / n_streams,
            "quarantined": rep["poisoned"], "rounds": rep["rounds"],
            "wall_s": dt}


def main(quick: bool = False) -> None:
    reps = 2 if quick else 5
    doc = {"scrub_throughput": [], "recovery": None, "shed": None}
    for strategy in ("seqlock", "indirect", "cached_wf", "cached_me"):
        cell = scrub_throughput_cell(strategy, reps=reps)
        doc["scrub_throughput"].append(cell)
        print(f"scrub  {strategy:10s} {cell['cells_s'] / 1e6:8.2f} Mcells/s"
              f"  ({cell['pass_s'] * 1e3:.2f} ms/pass)")
    doc["recovery"] = recovery_latency_cell()
    print(f"recover  repaired={doc['recovery']['repaired']} "
          f"quarantined={doc['recovery']['quarantined']} "
          f"scrub_latency={doc['recovery']['latency_s'] * 1e3:.2f} ms")
    doc["shed"] = shed_rate_cell()
    print(f"shed     rate={doc['shed']['shed_rate']:.2f} "
          f"({doc['shed']['shed']}/{doc['shed']['streams']} streams, "
          f"{doc['shed']['quarantined']} cells quarantined)")
    os.makedirs(RESULTS, exist_ok=True)
    out = os.path.join(RESULTS, "faults.json")
    with open(out, "w") as f:
        json.dump(doc, f, indent=1, default=float)
        f.write("\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
