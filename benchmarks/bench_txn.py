"""repro.txn workload family: txn width × contention × strategy ×
abort-backoff, single-device and mesh-sharded (ISSUE 4 satellite).

Three sweeps, all emitted to benchmarks/results/bench_txn.json:

  mcas        batched k-word MCAS on one device.  Width W is the number of
              cells per transaction; contention is the table size (small n
              forces overlapping claim sets, so arbitration serializes
              rounds); the backoff axis compares Dice-style abort policies
              (none / const / capped-exp) on commit throughput and wasted
              rounds.  commit_rate counts txns whose comparands survived
              to commit; attempts/txn is the arbitration-loss metric.

  map         optimistic transactional map: T read-modify-write txns on a
              CacheHash, from disjoint keys (all commit round 1) to one
              hot counter key (fully serialized, T rounds) — the OCC
              conflict spectrum.

  mcas_dist   cross-shard MCAS through the two-round prepare/commit
              collective, shard counts {1→8} on 8 placeholder devices
              (subprocess), with the exact per-device collective-word
              model (`distributed.mcas_collective_words`).

Usage:
  PYTHONPATH=src python -m benchmarks.bench_txn [--quick] [--tiny]

--tiny is the CI smoke mode (a few seconds): one strategy, one size,
single device only.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

import numpy as np

from benchmarks.common import print_table, save_results, time_op
from repro import atomics
from repro.sync.queue import BackoffPolicy

STRATEGIES = ["seqlock", "indirect", "cached_wf", "cached_me"]
POLICIES = [BackoffPolicy("none"), BackoffPolicy("const", 1),
            BackoffPolicy("exp", 1, 4)]


def _txns(rng, *, t, w, n, k):
    slot = np.stack([rng.choice(n, size=w, replace=False)
                     for _ in range(t)]).astype(np.int32)
    expected = rng.integers(0, 2 ** 32, (t, w, k), dtype=np.uint32)
    desired = rng.integers(0, 2 ** 32, (t, w, k), dtype=np.uint32)
    return slot, expected, desired


def run_mcas_cell(strategy, policy, *, t, w, n, k, match_frac, reps=3,
                  seed=0):
    rng = np.random.default_rng(seed)
    spec = atomics.AtomicSpec(n, k, strategy, p_max=max(t * w, 64))
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    state = atomics.init(spec, init)
    slot, expected, desired = _txns(rng, t=t, w=w, n=n, k=k)
    fresh = rng.random(t) < match_frac
    expected[fresh] = init[slot[fresh]]
    txns = atomics.make_txns(slot, expected, desired, k=k)

    def step(state, txns):
        return atomics.mcas(spec, state, txns, policy=policy)

    dt, (st2, res) = time_op(step, state, txns, reps=reps)
    succ = np.asarray(res.success)
    return {
        "strategy": strategy, "policy": policy.kind, "t": t, "w": w, "n": n,
        "ktxn_s": round(t / dt / 1e3, 2),
        "commit_rate": float(succ.mean()),
        "rounds": int(res.rounds),
        "attempts_txn": float(np.asarray(res.attempts).mean()),
    }


def _fn_rmw(rv, rf):
    return rv.sum(axis=1, keepdims=True) + 1


def run_map_cell(strategy, *, t, hot: bool, seed=0):
    from repro.core import cachehash as ch
    from repro.txn import map as txn_map
    rng = np.random.default_rng(seed)
    hs = atomics.HashSpec(256, vw=1, strategy=strategy, p_max=max(4 * t, 64))
    state = ch.init_hash(hs)
    keys = (np.full((t, 1), 7, np.uint32) if hot
            else rng.choice(200, size=t, replace=False)
            .astype(np.uint32)[:, None])
    txns = txn_map.make_map_txns(keys, keys)

    def step(state, txns):
        return txn_map.transact(hs, state, txns, _fn_rmw)

    dt, (st2, res) = time_op(step, state, txns, reps=3)
    return {
        "strategy": strategy, "workload": "hot-key" if hot else "disjoint",
        "t": t,
        "ktxn_s": round(t / dt / 1e3, 2),
        "rounds": int(res.rounds),
        "attempts_txn": float(np.asarray(res.attempts).mean()),
    }


_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import jax, numpy as np
    from repro import atomics
    from repro.core import distributed as dsb

    n, k, w = {n}, 2, {w}
    t = {t}
    strategies = {strategies}
    rows = []
    for strategy in strategies:
        for shards in {shards}:
            mesh = jax.make_mesh((shards, 8 // shards), ("shard", "rest"))
            dspec = dsb.DistSpec(atomics.AtomicSpec(n, k, strategy,
                                                    p_max=1024),
                                 "shard", shards, 8)
            rng = np.random.default_rng(0)
            init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
            st = dsb.init_dist(mesh, dspec, init)
            slot = np.stack([rng.choice(n, size=w, replace=False)
                             for _ in range(t)]).astype(np.int32)
            exp = init[slot]
            des = rng.integers(0, 2 ** 32, (t, w, k), dtype=np.uint32)
            txns = atomics.make_txns(slot, exp, des, k=k)
            dsb.mcas(mesh, dspec, st, txns)          # warmup/compile
            st = dsb.init_dist(mesh, dspec, init)
            t0 = time.perf_counter()
            st, res = dsb.mcas(mesh, dspec, st, txns)
            dt = time.perf_counter() - t0
            t_local = -(-t // shards)
            wire = 4 * dsb.mcas_collective_words(dspec, t_local, w) \\
                * (shards - 1) // shards
            rows.append(dict(
                strategy=strategy, shards=shards, t=t, w=w,
                ktxn_s=round(t / dt / 1e3, 2),
                commit_rate=float(np.asarray(res.success).mean()),
                rounds=int(res.rounds),
                coll_bytes_dev_round=wire))
    print("JSON:" + json.dumps(rows))
""")


def main(quick: bool = False, tiny: bool = False):
    strategies = ["cached_me"] if tiny else STRATEGIES
    t = 8 if tiny else (32 if quick else 128)
    k = 2

    mcas_rows = []
    for w in ([2] if tiny else [1, 2, 4]):
        for n, cont in ([(64, "low")] if tiny
                        else [(max(8, w + 1), "high"), (1 << 10, "low")]):
            for policy in (POLICIES[:1] if tiny else POLICIES):
                for s in strategies:
                    mcas_rows.append(run_mcas_cell(
                        s, policy, t=t, w=w, n=n, k=k, match_frac=0.8,
                        reps=1 if tiny else 3))
                    mcas_rows[-1]["contention"] = cont
    print_table("MCAS: width x contention x strategy x backoff", mcas_rows,
                ["strategy", "policy", "w", "n", "contention", "ktxn_s",
                 "commit_rate", "rounds", "attempts_txn"])

    map_rows = []
    for s in (["cached_me"] if tiny else ["seqlock", "cached_me"]):
        for hot in ((False,) if tiny else (False, True)):
            map_rows.append(run_map_cell(s, t=min(t, 16), hot=hot))
    print_table("Transactional map: OCC conflict spectrum", map_rows,
                ["strategy", "workload", "t", "ktxn_s", "rounds",
                 "attempts_txn"])

    dist_rows = []
    if not tiny:
        script = _DIST_SCRIPT.format(
            n=1 << 8, w=2, t=16 if quick else 64,
            strategies=["cached_me"] if quick else ["seqlock", "cached_me"],
            shards=(1, 4) if quick else (1, 2, 4, 8))
        env = dict(os.environ, PYTHONPATH=os.path.join(
            os.path.dirname(__file__), "..", "src"))
        r = subprocess.run([sys.executable, "-c", script], env=env,
                           capture_output=True, text=True, timeout=3000)
        line = [l for l in r.stdout.splitlines() if l.startswith("JSON:")]
        assert line, r.stdout + r.stderr[-2000:]
        dist_rows = json.loads(line[0][5:])
        print_table("Cross-shard MCAS (8 placeholder devices)", dist_rows,
                    ["strategy", "shards", "t", "w", "ktxn_s",
                     "commit_rate", "rounds", "coll_bytes_dev_round"])

    payload = {"mcas": mcas_rows, "map": map_rows, "mcas_dist": dist_rows}
    path = save_results("bench_txn", payload)
    print(f"\nresults -> {path}")

    # soft claim checks: contention costs rounds; hot-key map serializes
    if not tiny:
        hi = np.mean([r["rounds"] for r in mcas_rows
                      if r["contention"] == "high"])
        lo = np.mean([r["rounds"] for r in mcas_rows
                      if r["contention"] == "low"])
        print(f"[check] MCAS rounds high vs low contention: "
              f"{hi:.1f} vs {lo:.1f} -> "
              f"{'OK' if hi >= lo else 'UNEXPECTED'}")
        hot = [r for r in map_rows if r["workload"] == "hot-key"]
        if hot:
            ok = all(r["rounds"] == r["t"] for r in hot)
            print(f"[check] hot-key map fully serializes (rounds == T): "
                  f"{'OK' if ok else 'UNEXPECTED'}")
    return payload


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--tiny", action="store_true")
    args = ap.parse_args()
    main(quick=args.quick, tiny=args.tiny)
