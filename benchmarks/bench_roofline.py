"""Roofline report: reads dryrun_results.json (produced by
`python -m repro.launch.dryrun`) and prints the per-(arch x shape x mesh)
three-term table + bottleneck diagnosis that EXPERIMENTS.md §Roofline embeds.
"""

from __future__ import annotations

import json
import os

from benchmarks.common import print_table, save_results


def load(path="dryrun_results.json"):
    if not os.path.exists(path):
        raise SystemExit(f"{path} not found — run "
                         "`PYTHONPATH=src python -m repro.launch.dryrun` first")
    with open(path) as f:
        return json.load(f)


def rows_from(records, mesh="single"):
    rows = []
    for r in records:
        if r.get("status") != "ok" or r.get("mesh") != mesh:
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "coll_s": rl["collective_s"], "bneck": rl["bottleneck"],
            "useful_ratio": rl.get("useful_flops_ratio"),
            "mfu_bound": rl.get("mfu_bound"),
            "resident_GiB": r["bytes_per_device"]["resident"] / 2**30,
            "fits": r["bytes_per_device"]["fits"],
        })
    rows.sort(key=lambda x: (x["arch"], x["shape"]))
    return rows


def main(quick: bool = False, path="dryrun_results.json"):
    records = load(path)
    out = {}
    for mesh in ("single", "multi"):
        rows = rows_from(records, mesh)
        if rows:
            print_table(f"Roofline terms per cell ({mesh}-pod, per device, "
                        "seconds/step)", rows,
                        ["arch", "shape", "compute_s", "memory_s", "coll_s",
                         "bneck", "useful_ratio", "mfu_bound",
                         "resident_GiB", "fits"])
            out[mesh] = rows
    # summary: bottleneck census
    for mesh, rows in out.items():
        census: dict = {}
        for r in rows:
            census[r["bneck"]] = census.get(r["bneck"], 0) + 1
        print(f"\n[{mesh}] bottleneck census: {census}")
    save_results("bench_roofline", out)
    return out


if __name__ == "__main__":
    main()
