"""Shared benchmark machinery: timing, result I/O, table printing.

Wall-clock numbers here are CPU/XLA throughput — they reproduce the paper's
*relative* strategy ordering and contention curves (Figs 1-5).  The absolute
TPU numbers come from the modeled Traffic terms (bytes, dependency depth,
RMWs) that every bench also records; EXPERIMENTS.md reads both.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def save_results(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return path


def time_op(fn, *args, reps: int = 5, warmup: int = 2):
    """Median wall time of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return float(np.median(times)), out


def print_table(title: str, rows: list[dict], cols: list[str]):
    print(f"\n== {title} ==")
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows))
              for c in cols}
    print("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        print("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))


def _fmt(v):
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e4 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)
