"""Beyond-paper: the mesh-sharded big-atomic table (core.distributed).

Runs in a subprocess with 8 placeholder devices, measures throughput of the
route -> apply -> return pipeline vs a single-shard table, and reports the
modeled collective bytes per batch (the roofline term that the §Perf
hillclimb drives down).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import print_table, save_results

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import jax, numpy as np
    from repro.core import distributed as dsb
    from repro.core import semantics as sem

    n, k = 1 << {log_n}, 4
    p_local = {p_local}
    rows = []
    for shards in (1, 2, 4, 8):
        mesh = jax.make_mesh((shards,), ("shard",)) if shards > 1 else \
            jax.make_mesh((1,), ("shard",))
        rng = np.random.default_rng(0)
        p = shards * p_local
        ops = sem.random_batch(rng, p=p, n=n, k=k, update_frac=0.2)
        ops_hot = sem.random_batch(rng, p=p, n=n, k=k, update_frac=0.1,
                                   zipf=1.2)
        variants = [("baseline", dict()),
                    ("opt(dedup+interleave+cap/4)",
                     dict(dedup_loads=True, interleave=True,
                          route_capacity=max(p_local // 4, 8)))]
        for vname, kw in variants:
            table = dsb.init_sharded(mesh, "shard", n, k)
            apply_ops = dsb.make_apply(mesh, "shard", n, k, p_local, **kw)
            out = apply_ops(table, ops); jax.block_until_ready(out)
            t0 = time.perf_counter()
            reps = 10
            for _ in range(reps):
                table, res, ovf = apply_ops(table, ops)
            jax.block_until_ready(res)
            dt = (time.perf_counter() - t0) / reps
            _, _, ovf_hot = apply_ops(table, ops_hot)
            cap = kw.get("route_capacity", p_local)
            coll = 2 * cap * (2 * k + 5) * 4 * (shards - 1) / max(shards, 1) \
                * shards / max(shards, 1)
            rows.append(dict(variant=vname, shards=shards, p_global=p,
                             mops_s=p / dt / 1e6, overflow=int(ovf),
                             overflow_z1_2=int(ovf_hot),
                             coll_bytes_dev=coll))
    print("JSON:" + json.dumps(rows))
""")


def main(quick: bool = False):
    script = SCRIPT.format(log_n=12 if quick else 16,
                           p_local=256 if quick else 1024)
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=900)
    line = [l for l in r.stdout.splitlines() if l.startswith("JSON:")]
    assert line, r.stdout + r.stderr[-2000:]
    import json
    rows = json.loads(line[0][5:])
    print_table("Distributed big-atomic table (8 placeholder devices)", rows,
                ["variant", "shards", "p_global", "mops_s", "overflow",
                 "overflow_z1_2", "coll_bytes_dev"])
    save_results("bench_distributed", rows)
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
