"""Mesh-sharded big atomics v2 (core.distributed, DESIGN.md §6).

Strategy × shard-count × contention × op-mix sweep of the
route -> apply -> return collective round, run in a subprocess with 8
placeholder devices.  Each row records throughput, the observed overflow
count, and the modeled per-device collective bytes
(`distributed.collective_words`) — the roofline cell the §Perf hillclimb
drives down (shrinking `route_capacity` cuts the wire bytes EXACTLY
proportionally; the `opt` variant shows dedup+interleave+cap/4 doing so
without overflow on the read-heavy mix).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from benchmarks.common import print_table, save_results

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import jax, numpy as np
    from repro import atomics
    from repro.core import distributed as dsb
    from repro.core import engine

    n, k = 1 << {log_n}, 4
    p_local = {p_local}
    strategies = {strategies}
    shard_counts = {shards}
    reps = {reps}

    def batch(rng, p, upd, zipf, sync_frac):
        if zipf > 0.0:
            slots = (rng.zipf(zipf, size=p) - 1) % n
        else:
            slots = rng.integers(0, n, size=p)
        slots = slots.astype(np.int32)
        r = rng.random(p)
        kind = np.where(r < upd * 0.5, engine.STORE,
                        np.where(r < upd, engine.CAS,
                                 engine.LOAD)).astype(np.int32)
        if sync_frac > 0.0:
            s = rng.random(p) < sync_frac
            kind = np.where(s & (kind == engine.LOAD), engine.LL, kind)
            kind = np.where(s & (kind == engine.STORE), engine.SC, kind)
        expected = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
        desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
        return atomics.make_ops(kind, slots, expected, desired, k=k)

    MIXES = [("read90", 0.1, 0.0), ("upd60", 0.6, 0.0), ("sync50", 0.1, 0.5)]
    CONTENTION = [("uniform", 0.0), ("zipf1.2", 1.2)]
    rows = []
    for strategy in strategies:
        for shards in shard_counts:
            mesh = jax.make_mesh((shards, 8 // shards), ("shard", "rest"))
            variants = [("baseline", dict())]
            if shards > 1:
                variants.append(
                    ("opt(dedup+ilv+cap/4)",
                     dict(dedup_loads=True, interleave=True,
                          route_capacity=max(p_local // 4, 8))))
            for vname, kw in variants:
                dspec = dsb.DistSpec(
                    atomics.AtomicSpec(n, k, strategy, p_max=1024),
                    "shard", shards, p_local, **kw)
                p = dspec.p_global
                for mix, upd, sync_frac in MIXES:
                    if vname != "baseline" and mix != "read90":
                        continue          # the opt levers target read traffic
                    for cont, zipf in CONTENTION:
                        rng = np.random.default_rng(0)
                        st = dsb.init_dist(mesh, dspec)
                        ctx = dsb.init_dist_ctx(mesh, dspec)
                        ops = batch(rng, p, upd, zipf, sync_frac)
                        out = dsb.apply(mesh, dspec, st, ops, ctx)
                        jax.block_until_ready(out[2])
                        t0 = time.perf_counter()
                        for _ in range(reps):
                            st, ctx, res, ovf = dsb.apply(mesh, dspec, st,
                                                          ops, ctx)
                        jax.block_until_ready(res)
                        dt = (time.perf_counter() - t0) / reps
                        # wire bytes = buffer bytes x the off-device
                        # fraction (shards-1)/shards; 0 when unsharded,
                        # matching the historical column semantics.
                        wire = 4 * dsb.collective_words(dspec) \
                            * (shards - 1) // shards
                        rows.append(dict(
                            strategy=strategy, variant=vname, shards=shards,
                            mix=mix, contention=cont, p_global=p,
                            mops_s=round(p / dt / 1e6, 3),
                            overflow=int(np.asarray(ovf).sum()),
                            coll_bytes_dev=wire))
    print("JSON:" + json.dumps(rows))
""")


def main(quick: bool = False):
    script = SCRIPT.format(
        log_n=10 if quick else 14,
        p_local=64 if quick else 256,
        strategies=["cached_me", "seqlock"] if quick
        else ["seqlock", "indirect", "cached_wf", "cached_me"],
        shards=(1, 4) if quick else (1, 2, 4, 8),
        reps=5 if quick else 10)
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=3000)
    line = [l for l in r.stdout.splitlines() if l.startswith("JSON:")]
    assert line, r.stdout + r.stderr[-2000:]
    import json
    rows = json.loads(line[0][5:])
    print_table("Distributed big atomics v2 (8 placeholder devices)", rows,
                ["strategy", "variant", "shards", "mix", "contention",
                 "p_global", "mops_s", "overflow", "coll_bytes_dev"])
    save_results("bench_distributed", rows)
    return rows


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
