"""Paper Table 1 / §5.5: exact memory accounting per strategy, validated
against the actual bytes held by the pytree layouts."""

from __future__ import annotations

from benchmarks.common import print_table, save_results
from repro import atomics

CASES = [(1 << 14, 4, 256), (1 << 17, 4, 256), (1 << 14, 16, 1024)]


def main(quick: bool = False):
    rows = []
    for n, k, p in CASES[:2] if quick else CASES:
        for strategy in ["plain", "seqlock", "simplock", "indirect",
                         "cached_wf", "cached_me"]:
            spec = atomics.AtomicSpec(n, k, strategy, p_max=p)
            pred = atomics.memory_bytes(spec)
            state = atomics.init(spec)
            actual = atomics.state_nbytes(state)
            rows.append({
                "strategy": strategy, "n": n, "k": k, "p": p,
                "model_bytes": pred, "actual_bytes": actual,
                "ratio": actual / pred,
                "per_cell_words": actual / n / 4,
            })
    print_table("Table 1 / §5.5 memory accounting", rows,
                ["strategy", "n", "k", "p", "model_bytes", "actual_bytes",
                 "ratio", "per_cell_words"])
    save_results("bench_memory", rows)
    # Table-1 structure: cached_wf ~ 2x cell space of cached_me at large n
    big = [r for r in rows if r["n"] == max(c[0] for c in CASES[:2])]
    wf = next(r for r in big if r["strategy"] == "cached_wf")
    me = next(r for r in big if r["strategy"] == "cached_me")
    print(f"\n[check] cached_wf/cached_me cell space = "
          f"{wf['actual_bytes']/me['actual_bytes']:.2f}x "
          f"(paper: 2nk vs nk) -> "
          f"{'OK' if wf['actual_bytes'] > 1.5 * me['actual_bytes'] else 'UNEXPECTED'}")
    return rows


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
