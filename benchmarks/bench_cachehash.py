"""Paper Figures 3-4: CacheHash (inlined first link, per big-atomic strategy)
vs the Chaining baseline (no inlining) vs a python-dict oracle reference.

Reported per cell: Mop/s, inline-hit fraction (ops resolved with ONE cell
access — the paper's whole point), chain steps per op (dependent pool
gathers), serialization rounds (bucket contention).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, save_results, time_op
from repro import atomics
from repro.core import cachehash as ch

VARIANTS = [("cachehash/seqlock", "seqlock", True),
            ("cachehash/cached_me", "cached_me", True),
            ("cachehash/cached_wf", "cached_wf", True),
            ("cachehash/indirect", "indirect", True),
            ("chaining", "cached_me", False)]

DEF = dict(nb=1 << 14, p=2048, u=0.1, z=0.0)


def _ops(rng, *, nb, p, u, z, vw=1):
    if z <= 0:
        keys = rng.integers(0, nb, p)
    else:
        keys = (rng.zipf(max(z, 1.01), p) - 1) % nb
    upd = rng.random(p) < u
    ins = rng.random(p) < 0.5
    kind = np.where(upd, np.where(ins, atomics.INSERT, atomics.DELETE),
                    atomics.FIND).astype(np.int32)
    vals = rng.integers(0, 2**32, (p, vw), dtype=np.uint32)
    return ch.make_hash_ops(jnp.asarray(kind),
                            jnp.asarray(keys.astype(np.uint32)),
                            jnp.asarray(vals), vw=vw)


def run_cell(name, strategy, inline, *, nb, p, u, z, seed=0):
    rng = np.random.default_rng(seed)
    spec = atomics.HashSpec(nb, vw=1, strategy=strategy, p_max=p,
                            inline=inline)
    state0 = ch.init_hash(spec)
    # preload ~ load factor 0.5
    pre = _ops(rng, nb=nb, p=min(nb // 2, 4 * p), u=1.0, z=0.0)
    pre = pre._replace(kind=jnp.full_like(pre.kind, atomics.INSERT))
    state0, _, _ = ch.apply_hash(spec, state0, pre)
    ops = _ops(rng, nb=nb, p=p, u=u, z=z)

    def step(state, ops):
        return ch.apply_hash(spec, state, ops)

    dt, (state, res, stats) = time_op(step, state0, ops, reps=3)
    live = p
    return {
        "variant": name, "nb": nb, "p": p, "u": u, "z": z,
        "mops_s": p / dt / 1e6,
        "inline_hit": float(stats.inline_hits / max(live, 1)),
        "chain_steps_op": float(stats.chain_steps / max(live, 1)),
        "rounds": int(stats.rounds),
    }


def dict_oracle_throughput(*, nb, p, u, z, seed=0):
    """Single-threaded python dict — the 'ideal sequential' reference."""
    rng = np.random.default_rng(seed)
    ops = _ops(rng, nb=nb, p=p, u=u, z=z)
    kind = np.asarray(ops.kind)
    key = np.asarray(ops.slot).astype(np.uint32)
    val = np.asarray(ops.desired)
    model = {}
    t0 = time.perf_counter()
    for i in range(p):
        k = int(key[i])
        if kind[i] == atomics.FIND:
            model.get(k)
        elif kind[i] == atomics.INSERT:
            model.setdefault(k, val[i])
        else:
            model.pop(k, None)
    dt = time.perf_counter() - t0
    return {"variant": "python-dict(1-thread)", "nb": nb, "p": p, "u": u,
            "z": z, "mops_s": p / dt / 1e6, "inline_hit": None,
            "chain_steps_op": None, "rounds": None}


def main(quick: bool = False):
    base = dict(DEF)
    if quick:
        base["nb"], base["p"] = 1 << 10, 512
    out = {}
    for param, values in [("u", [0.0, 0.1, 0.5, 1.0]),
                          ("z", [0.0, 0.9, 0.99]),
                          ("nb", [1 << 10, 1 << 14] if quick else
                           [1 << 10, 1 << 14, 1 << 18])]:
        rows = []
        for v in values:
            kw = dict(base)
            kw[param] = v
            for name, strat, inline in VARIANTS:
                rows.append(run_cell(name, strat, inline, **kw))
            rows.append(dict_oracle_throughput(**kw))
        print_table(f"Fig3/4 analogue: vary {param}", rows,
                    ["variant", param, "mops_s", "inline_hit",
                     "chain_steps_op", "rounds"])
        out[param] = rows
    save_results("bench_cachehash", out)
    # claim check: inlining removes most chain walks
    inl = [r for r in out["u"] if r["variant"] == "cachehash/cached_me"]
    cha = [r for r in out["u"] if r["variant"] == "chaining"]
    a = np.mean([r["chain_steps_op"] for r in inl])
    b = np.mean([r["chain_steps_op"] for r in cha])
    print(f"\n[check] chain steps/op: cachehash={a:.3f} chaining={b:.3f} "
          f"-> {'OK' if a < b else 'UNEXPECTED'} (paper: inlining avoids "
          "the dependent miss)")
    return out


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
