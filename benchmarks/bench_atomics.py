"""Paper Figure 2: big-atomic strategy comparison across u (update fraction),
z (Zipfian contention), n (table size), k (cell words) and p (batch lanes =
the thread-count analogue).

For every cell we record
  * ops/s        — measured XLA-on-CPU throughput (relative ordering);
  * bytes/op     — the strategy's modeled HBM traffic (TPU roofline input);
  * dep_chains   — dependent-gather depth on the load critical path (1 =
                   pipelineable stream = the paper's 'one cache miss');
  * rmw/op       — single-word RMW count (contention proxy).

INDIRECT's 2-deep chain and SEQLOCK/CACHED_*'s 1-deep fast path are the
paper's central claim, visible here as structure, not just time.

v2 additions:
  * a MIXED-op-batch sweep (LOAD/STORE/CAS/LL/SC/VALIDATE lanes in ONE
    `atomics.apply` call) over the sync-lane fraction — the unified-engine
    capability the v1 API could not express at all;
  * the fused-serving-step delta: decode steps/s and host->device
    dispatches per step for the v1 4-dispatch decode path vs the v2 single
    jitted program (engine `fused=True`).
"""

from __future__ import annotations

import functools
import time

import numpy as np

from benchmarks.common import print_table, save_results, time_op
from repro import atomics
from repro.core import semantics as sem

STRATEGIES = ["seqlock", "indirect", "cached_wf", "cached_me", "simplock",
              "plain"]

DEF = dict(n=1 << 16, k=4, p=4096, u=0.2, z=0.0)


def run_cell(strategy: str, *, n, k, p, u, z, reps=3, seed=0):
    rng = np.random.default_rng(seed)
    spec = atomics.AtomicSpec(n, k, strategy, p_max=p)
    state0 = atomics.init(spec)
    cur = np.asarray(atomics.logical(spec, state0))
    ops = sem.random_batch(rng, p=p, n=n, k=k, update_frac=u, zipf=z,
                           current=cur)

    def step(state, ops):
        new_state, _, res, stats, traffic = atomics.apply(spec, state, ops)
        return new_state, res, stats, traffic

    dt, (state, res, stats, traffic) = time_op(step, state0, ops, reps=reps)
    return {
        "strategy": strategy, "n": n, "k": k, "p": p, "u": u, "z": z,
        "mops_s": p / dt / 1e6,
        "rounds": int(stats.rounds),
        "bytes_op": float((traffic.bytes_read + traffic.bytes_written) / p),
        "dep_chains": int(traffic.dep_chains),
        "rmw_op": float(traffic.rmw_ops / p),
    }


def mixed_batch(rng, *, p, n, k, sync_frac, z=0.0):
    """Mixed unified batch: sync_frac of the lanes are LL/SC/VALIDATE, the
    rest LOAD/STORE/CAS (paper mix), all in one op schema."""
    table_kinds = np.asarray([atomics.LOAD, atomics.STORE, atomics.CAS])
    sync_kinds = np.asarray([atomics.LL, atomics.SC, atomics.VALIDATE])
    is_sync = rng.random(p) < sync_frac
    kind = np.where(is_sync, rng.choice(sync_kinds, p),
                    rng.choice(table_kinds, p)).astype(np.int32)
    if z <= 0.0:
        slots = rng.integers(0, n, p)
    else:
        slots = (rng.zipf(max(z, 1.01), size=p) - 1) % n
    expected = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    return atomics.make_ops(kind, slots.astype(np.int32), expected, desired,
                            k=k)


def run_mixed_cell(strategy: str, *, n, k, p, sync_frac, reps=3, seed=0):
    """One mixed-kind batch through the unified engine, timed end to end."""
    rng = np.random.default_rng(seed)
    spec = atomics.AtomicSpec(n, k, strategy, p_max=p)
    state = atomics.init(spec)
    ctx = atomics.init_ctx(p, k)
    # pre-link every lane so SC/VALIDATE lanes have live links to consume
    slots = rng.integers(0, n, p).astype(np.int32)
    state, ctx, _, _, _ = atomics.apply(
        spec, state, atomics.sync_ops(np.full(p, atomics.LL), slots, k=k),
        ctx)
    ops = mixed_batch(rng, p=p, n=n, k=k, sync_frac=sync_frac)
    # SC/VALIDATE lanes target their linked slot to be meaningful
    kind = np.asarray(ops.kind)
    tgt = np.where(np.isin(kind, [atomics.SC, atomics.VALIDATE]),
                   np.asarray(ctx.slot), np.asarray(ops.slot))
    ops = atomics.OpBatch(ops.kind, np.asarray(tgt, np.int32), ops.expected,
                          ops.desired)

    def step(state, ctx, ops):
        return atomics.apply(spec, state, ops, ctx)

    dt, (st2, ctx2, res, stats, traffic) = time_op(step, state, ctx, ops,
                                                   reps=reps)
    return {
        "strategy": strategy, "n": n, "k": k, "p": p,
        "sync_frac": sync_frac,
        "mops_s": p / dt / 1e6,
        "rounds": int(stats.rounds),
        "writes": int(stats.n_updates),
        "bytes_op": float((traffic.bytes_read + traffic.bytes_written) / p),
    }


def sweep_mixed(*, quick=False, strategies=None):
    strategies = strategies or ["seqlock", "indirect", "cached_wf",
                                "cached_me"]
    n = 1 << 12 if quick else 1 << 16
    p = 1024 if quick else 4096
    rows = []
    for sync_frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        for s in strategies:
            rows.append(run_mixed_cell(s, n=n, k=4, p=p,
                                       sync_frac=sync_frac))
    return rows


@functools.lru_cache(maxsize=None)
def _pure_xla_step_fn():
    """The pre-ISSUE-5 engine: every batch through `linearize` (sort + scans
    + combining-round while_loop), bypassing the strategy's lowered round."""
    import jax

    from repro.core import engine

    @functools.partial(jax.jit, static_argnames=("spec",))
    def step(spec, state, ops):
        impl = atomics.get_strategy(spec.strategy)
        nd, nv, _, res, stats = engine.linearize(
            impl.engine_view(state), state.version,
            engine.init_ctx(ops.p, spec.k), ops)
        new_state = impl.commit(state, nd, nv, stats.n_updates, ops.p)
        return new_state, res, stats

    return step


def _pure_xla_step(spec, state, ops):
    return _pure_xla_step_fn()(spec, state, ops)


def _fastpath_batch(rng, *, n, k, p, scenario):
    """The ISSUE-5 acceptance scenarios: uncontended load / CAS batches
    (the fast path) and the all-same-slot worst case (the slow path)."""
    slots = rng.choice(n, p, replace=False).astype(np.int32)
    if scenario == "load_uncontended":
        kind = np.full(p, atomics.LOAD, np.int32)
    elif scenario == "cas_uncontended":
        kind = np.full(p, atomics.CAS, np.int32)
    elif scenario == "mixed_uncontended":
        kind = rng.choice(np.asarray(
            [atomics.LOAD, atomics.STORE, atomics.CAS]), p).astype(np.int32)
    elif scenario == "cas_all_same_slot":
        kind = np.full(p, atomics.CAS, np.int32)
        slots = np.full(p, slots[0], np.int32)
    else:
        raise ValueError(scenario)
    expected = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    return atomics.make_ops(kind, slots, expected, desired, k=k)


def run_fastpath_cell(strategy, scenario, *, n, k, p, reps=5, seed=0):
    """One scenario timed through BOTH engines: the fused round (runtime
    fast/slow dispatch, `atomics.apply`) and the pure-XLA `linearize`."""
    rng = np.random.default_rng(seed)
    spec = atomics.AtomicSpec(n, k, strategy, p_max=p)
    state0 = atomics.init(spec)
    ops = _fastpath_batch(rng, n=n, k=k, p=p, scenario=scenario)
    # half the CAS lanes succeed so the write path is truly exercised
    cur = np.asarray(atomics.logical(spec, state0))
    exp = np.array(ops.expected, copy=True)
    sl = np.asarray(ops.slot)
    for i in range(0, p, 2):
        exp[i] = cur[sl[i]]
    ops = atomics.OpBatch(ops.kind, ops.slot, exp, ops.desired)

    def fused(state, ops):
        new_state, _, res, stats, _ = atomics.apply(spec, state, ops)
        return new_state, res, stats

    # Interleave the two arms' repetitions: shared-runner clock drift is
    # larger than the effect under test, and pairing cancels it.
    import time as _time

    import jax

    for _ in range(2):                                    # warmup both arms
        jax.block_until_ready(fused(state0, ops))
        jax.block_until_ready(_pure_xla_step(spec, state0, ops))
    ts_f, ts_x = [], []
    for _ in range(reps):
        t0 = _time.perf_counter()
        out_f = fused(state0, ops)
        jax.block_until_ready(out_f)
        ts_f.append(_time.perf_counter() - t0)
        t0 = _time.perf_counter()
        out_x = _pure_xla_step(spec, state0, ops)
        jax.block_until_ready(out_x)
        ts_x.append(_time.perf_counter() - t0)
    dt_f, dt_x = float(np.median(ts_f)), float(np.median(ts_x))
    _, _, stats = out_f
    return {
        "strategy": strategy, "scenario": scenario, "n": n, "k": k, "p": p,
        "mops_s_fused": p / dt_f / 1e6,
        "mops_s_linearize": p / dt_x / 1e6,
        "speedup": dt_x / dt_f,
        "rounds": int(stats.rounds),
    }


FASTPATH_SCENARIOS = ["load_uncontended", "cas_uncontended",
                      "mixed_uncontended", "cas_all_same_slot"]


def sweep_fastpath(*, quick=False, strategies=None):
    strategies = strategies or ["seqlock", "cached_me"]
    n = 1 << 12 if quick else 1 << 14
    p = 1024 if quick else 8192
    # all-same-slot serializes into p combining rounds; cap its batch so the
    # worst-case cell stays seconds, not minutes
    p_contended = min(p, 1024)
    rows = []
    for scenario in FASTPATH_SCENARIOS:
        for s in strategies:
            rows.append(run_fastpath_cell(
                s, scenario, n=n, k=4,
                p=p_contended if scenario == "cas_all_same_slot" else p))
    return rows


def bench_fused_serving(quick: bool = False):
    """Dispatch-count / wall-clock delta from jitting the fused serving step:
    the same decode workload through the v1 4-dispatch path and the v2
    single compiled program (ISSUE 2 satellite)."""
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models.transformer import init_params
    from repro.serving import Request, ServingEngine

    cfg = get_config("deepseek_7b", reduced=True)
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n_new = 8 if quick else 16
    prompts = [rng.integers(0, cfg.vocab, 12).astype(np.int32)
               for _ in range(2)]

    rows = []
    for fused in (False, True):
        eng = ServingEngine(cfg, params, max_batch=2, n_pages=32,
                            page_size=8, max_pages_per_seq=8, fused=fused)
        # Warmup wave: pays every one-time JIT (prefill, decode, page
        # alloc/free) on THIS engine so the timed wave measures steady state.
        for rid, pr in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=pr, max_new_tokens=n_new))
        eng.run_to_completion()
        d0, t0 = eng.dispatch_count, time.perf_counter()
        for rid, pr in enumerate(prompts):
            eng.submit(Request(rid=100 + rid, prompt=pr,
                               max_new_tokens=n_new))
        steps = 0
        while eng.step():
            steps += 1
        dt = time.perf_counter() - t0
        rows.append({
            "mode": "fused" if fused else "v1 (4-dispatch)",
            "decode_steps": steps,
            "dispatches_step": (eng.dispatch_count - d0) / max(steps, 1),
            "ms_step": dt / max(steps, 1) * 1e3,
            "steps_s": steps / dt,
        })
    return rows


def sweep(param: str, values, *, quick=False, strategies=STRATEGIES):
    rows = []
    for v in values:
        kw = dict(DEF)
        kw[param] = v
        if quick:
            kw["n"] = min(kw["n"], 1 << 12)
            kw["p"] = min(kw["p"], 1024)
        for s in strategies:
            rows.append(run_cell(s, **kw))
    return rows


def main(quick: bool = False):
    all_rows = {}
    all_rows["u"] = sweep("u", [0.0, 0.2, 0.5, 1.0], quick=quick)
    all_rows["z"] = sweep("z", [0.0, 0.6, 0.9, 0.99], quick=quick)
    all_rows["n"] = sweep("n", [1 << 10, 1 << 14] if quick else
                          [1 << 10, 1 << 14, 1 << 18, 1 << 22], quick=quick)
    all_rows["k"] = sweep("k", [1, 4, 16] if quick else [1, 2, 4, 8, 16],
                          quick=quick)
    all_rows["p"] = sweep("p", [256, 1024] if quick else
                          [256, 1024, 4096, 16384], quick=quick)
    for key, rows in all_rows.items():
        print_table(f"Fig2 analogue: vary {key}", rows,
                    ["strategy", key, "mops_s", "rounds", "bytes_op",
                     "dep_chains", "rmw_op"])
    all_rows["mixed"] = sweep_mixed(quick=quick)
    print_table("Mixed LOAD/STORE/CAS + LL/SC/VALIDATE batches "
                "(one unified apply)", all_rows["mixed"],
                ["strategy", "sync_frac", "mops_s", "rounds", "writes",
                 "bytes_op"])
    all_rows["fastpath"] = sweep_fastpath(quick=quick)
    print_table("Fused engine round vs pure-XLA linearize (ISSUE 5)",
                all_rows["fastpath"],
                ["strategy", "scenario", "mops_s_fused", "mops_s_linearize",
                 "speedup", "rounds"])
    fp = [r for r in all_rows["fastpath"]
          if r["scenario"] != "cas_all_same_slot"]
    sl = [r for r in all_rows["fastpath"]
          if r["scenario"] == "cas_all_same_slot"]
    fp_speed = float(np.mean([r["speedup"] for r in fp]))
    sl_speed = float(np.mean([r["speedup"] for r in sl]))
    print(f"\n[check] fast path speedup on uncontended batches: "
          f"{fp_speed:.2f}x -> {'OK' if fp_speed > 1 else 'UNEXPECTED'}")
    print(f"[check] all-same-slot speedup (>=~1 expected, the predicate "
          f"must not cost): {sl_speed:.2f}x -> "
          f"{'OK' if sl_speed > 0.9 else 'UNEXPECTED'}")
    try:
        all_rows["fused_serving"] = bench_fused_serving(quick=quick)
        print_table("Fused serving decode step: v1 4-dispatch vs one "
                    "compiled program", all_rows["fused_serving"],
                    ["mode", "decode_steps", "dispatches_step", "ms_step",
                     "steps_s"])
    except Exception as e:                     # model deps optional here
        print(f"[fused serving bench skipped: {e!r}]")
    save_results("bench_atomics", all_rows)
    # paper-claim checks (soft, printed): cached fast path beats indirect
    by = {}
    for r in all_rows["u"]:
        by.setdefault(r["strategy"], []).append(r)
    cm = np.mean([r["mops_s"] for r in by["cached_me"]])
    ind = np.mean([r["mops_s"] for r in by["indirect"]])
    print(f"\n[check] cached_me {cm:.1f} Mop/s vs indirect {ind:.1f} Mop/s "
          f"-> {'OK' if cm > ind else 'UNEXPECTED'} (paper: cached wins)")
    dep_cm = by["cached_me"][0]["dep_chains"]
    dep_in = by["indirect"][0]["dep_chains"]
    print(f"[check] dep chains: cached_me={dep_cm} indirect={dep_in} "
          f"-> {'OK' if dep_cm < dep_in else 'UNEXPECTED'}")
    return all_rows


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
