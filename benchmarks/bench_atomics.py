"""Paper Figure 2: big-atomic strategy comparison across u (update fraction),
z (Zipfian contention), n (table size), k (cell words) and p (batch lanes =
the thread-count analogue).

For every cell we record
  * ops/s        — measured XLA-on-CPU throughput (relative ordering);
  * bytes/op     — the strategy's modeled HBM traffic (TPU roofline input);
  * dep_chains   — dependent-gather depth on the load critical path (1 =
                   pipelineable stream = the paper's 'one cache miss');
  * rmw/op       — single-word RMW count (contention proxy).

INDIRECT's 2-deep chain and SEQLOCK/CACHED_*'s 1-deep fast path are the
paper's central claim, visible here as structure, not just time.

v2 additions:
  * a MIXED-op-batch sweep (LOAD/STORE/CAS/LL/SC/VALIDATE lanes in ONE
    `atomics.apply` call) over the sync-lane fraction — the unified-engine
    capability the v1 API could not express at all;
  * the fused-serving-step delta: decode steps/s and host->device
    dispatches per step for the v1 4-dispatch decode path vs the v2 single
    jitted program (engine `fused=True`).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import print_table, save_results, time_op
from repro import atomics
from repro.core import semantics as sem

STRATEGIES = ["seqlock", "indirect", "cached_wf", "cached_me", "simplock",
              "plain"]

DEF = dict(n=1 << 16, k=4, p=4096, u=0.2, z=0.0)


def run_cell(strategy: str, *, n, k, p, u, z, reps=3, seed=0):
    rng = np.random.default_rng(seed)
    spec = atomics.AtomicSpec(n, k, strategy, p_max=p)
    state0 = atomics.init(spec)
    cur = np.asarray(atomics.logical(spec, state0))
    ops = sem.random_batch(rng, p=p, n=n, k=k, update_frac=u, zipf=z,
                           current=cur)

    def step(state, ops):
        new_state, _, res, stats, traffic = atomics.apply(spec, state, ops)
        return new_state, res, stats, traffic

    dt, (state, res, stats, traffic) = time_op(step, state0, ops, reps=reps)
    return {
        "strategy": strategy, "n": n, "k": k, "p": p, "u": u, "z": z,
        "mops_s": p / dt / 1e6,
        "rounds": int(stats.rounds),
        "bytes_op": float((traffic.bytes_read + traffic.bytes_written) / p),
        "dep_chains": int(traffic.dep_chains),
        "rmw_op": float(traffic.rmw_ops / p),
    }


def mixed_batch(rng, *, p, n, k, sync_frac, z=0.0):
    """Mixed unified batch: sync_frac of the lanes are LL/SC/VALIDATE, the
    rest LOAD/STORE/CAS (paper mix), all in one op schema."""
    table_kinds = np.asarray([atomics.LOAD, atomics.STORE, atomics.CAS])
    sync_kinds = np.asarray([atomics.LL, atomics.SC, atomics.VALIDATE])
    is_sync = rng.random(p) < sync_frac
    kind = np.where(is_sync, rng.choice(sync_kinds, p),
                    rng.choice(table_kinds, p)).astype(np.int32)
    if z <= 0.0:
        slots = rng.integers(0, n, p)
    else:
        slots = (rng.zipf(max(z, 1.01), size=p) - 1) % n
    expected = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    return atomics.make_ops(kind, slots.astype(np.int32), expected, desired,
                            k=k)


def run_mixed_cell(strategy: str, *, n, k, p, sync_frac, reps=3, seed=0):
    """One mixed-kind batch through the unified engine, timed end to end."""
    rng = np.random.default_rng(seed)
    spec = atomics.AtomicSpec(n, k, strategy, p_max=p)
    state = atomics.init(spec)
    ctx = atomics.init_ctx(p, k)
    # pre-link every lane so SC/VALIDATE lanes have live links to consume
    slots = rng.integers(0, n, p).astype(np.int32)
    state, ctx, _, _, _ = atomics.apply(
        spec, state, atomics.sync_ops(np.full(p, atomics.LL), slots, k=k),
        ctx)
    ops = mixed_batch(rng, p=p, n=n, k=k, sync_frac=sync_frac)
    # SC/VALIDATE lanes target their linked slot to be meaningful
    kind = np.asarray(ops.kind)
    tgt = np.where(np.isin(kind, [atomics.SC, atomics.VALIDATE]),
                   np.asarray(ctx.slot), np.asarray(ops.slot))
    ops = atomics.OpBatch(ops.kind, np.asarray(tgt, np.int32), ops.expected,
                          ops.desired)

    def step(state, ctx, ops):
        return atomics.apply(spec, state, ops, ctx)

    dt, (st2, ctx2, res, stats, traffic) = time_op(step, state, ctx, ops,
                                                   reps=reps)
    return {
        "strategy": strategy, "n": n, "k": k, "p": p,
        "sync_frac": sync_frac,
        "mops_s": p / dt / 1e6,
        "rounds": int(stats.rounds),
        "writes": int(stats.n_updates),
        "bytes_op": float((traffic.bytes_read + traffic.bytes_written) / p),
    }


def sweep_mixed(*, quick=False, strategies=None):
    strategies = strategies or ["seqlock", "indirect", "cached_wf",
                                "cached_me"]
    n = 1 << 12 if quick else 1 << 16
    p = 1024 if quick else 4096
    rows = []
    for sync_frac in (0.0, 0.25, 0.5, 0.75, 1.0):
        for s in strategies:
            rows.append(run_mixed_cell(s, n=n, k=4, p=p,
                                       sync_frac=sync_frac))
    return rows


def bench_fused_serving(quick: bool = False):
    """Dispatch-count / wall-clock delta from jitting the fused serving step:
    the same decode workload through the v1 4-dispatch path and the v2
    single compiled program (ISSUE 2 satellite)."""
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.models.transformer import init_params
    from repro.serving import Request, ServingEngine

    cfg = get_config("deepseek_7b", reduced=True)
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    n_new = 8 if quick else 16
    prompts = [rng.integers(0, cfg.vocab, 12).astype(np.int32)
               for _ in range(2)]

    rows = []
    for fused in (False, True):
        eng = ServingEngine(cfg, params, max_batch=2, n_pages=32,
                            page_size=8, max_pages_per_seq=8, fused=fused)
        # Warmup wave: pays every one-time JIT (prefill, decode, page
        # alloc/free) on THIS engine so the timed wave measures steady state.
        for rid, pr in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=pr, max_new_tokens=n_new))
        eng.run_to_completion()
        d0, t0 = eng.dispatch_count, time.perf_counter()
        for rid, pr in enumerate(prompts):
            eng.submit(Request(rid=100 + rid, prompt=pr,
                               max_new_tokens=n_new))
        steps = 0
        while eng.step():
            steps += 1
        dt = time.perf_counter() - t0
        rows.append({
            "mode": "fused" if fused else "v1 (4-dispatch)",
            "decode_steps": steps,
            "dispatches_step": (eng.dispatch_count - d0) / max(steps, 1),
            "ms_step": dt / max(steps, 1) * 1e3,
            "steps_s": steps / dt,
        })
    return rows


def sweep(param: str, values, *, quick=False, strategies=STRATEGIES):
    rows = []
    for v in values:
        kw = dict(DEF)
        kw[param] = v
        if quick:
            kw["n"] = min(kw["n"], 1 << 12)
            kw["p"] = min(kw["p"], 1024)
        for s in strategies:
            rows.append(run_cell(s, **kw))
    return rows


def main(quick: bool = False):
    all_rows = {}
    all_rows["u"] = sweep("u", [0.0, 0.2, 0.5, 1.0], quick=quick)
    all_rows["z"] = sweep("z", [0.0, 0.6, 0.9, 0.99], quick=quick)
    all_rows["n"] = sweep("n", [1 << 10, 1 << 14] if quick else
                          [1 << 10, 1 << 14, 1 << 18, 1 << 22], quick=quick)
    all_rows["k"] = sweep("k", [1, 4, 16] if quick else [1, 2, 4, 8, 16],
                          quick=quick)
    all_rows["p"] = sweep("p", [256, 1024] if quick else
                          [256, 1024, 4096, 16384], quick=quick)
    for key, rows in all_rows.items():
        print_table(f"Fig2 analogue: vary {key}", rows,
                    ["strategy", key, "mops_s", "rounds", "bytes_op",
                     "dep_chains", "rmw_op"])
    all_rows["mixed"] = sweep_mixed(quick=quick)
    print_table("Mixed LOAD/STORE/CAS + LL/SC/VALIDATE batches "
                "(one unified apply)", all_rows["mixed"],
                ["strategy", "sync_frac", "mops_s", "rounds", "writes",
                 "bytes_op"])
    try:
        all_rows["fused_serving"] = bench_fused_serving(quick=quick)
        print_table("Fused serving decode step: v1 4-dispatch vs one "
                    "compiled program", all_rows["fused_serving"],
                    ["mode", "decode_steps", "dispatches_step", "ms_step",
                     "steps_s"])
    except Exception as e:                     # model deps optional here
        print(f"[fused serving bench skipped: {e!r}]")
    save_results("bench_atomics", all_rows)
    # paper-claim checks (soft, printed): cached fast path beats indirect
    by = {}
    for r in all_rows["u"]:
        by.setdefault(r["strategy"], []).append(r)
    cm = np.mean([r["mops_s"] for r in by["cached_me"]])
    ind = np.mean([r["mops_s"] for r in by["indirect"]])
    print(f"\n[check] cached_me {cm:.1f} Mop/s vs indirect {ind:.1f} Mop/s "
          f"-> {'OK' if cm > ind else 'UNEXPECTED'} (paper: cached wins)")
    dep_cm = by["cached_me"][0]["dep_chains"]
    dep_in = by["indirect"][0]["dep_chains"]
    print(f"[check] dep chains: cached_me={dep_cm} indirect={dep_in} "
          f"-> {'OK' if dep_cm < dep_in else 'UNEXPECTED'}")
    return all_rows


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
