"""Paper Figure 2: big-atomic strategy comparison across u (update fraction),
z (Zipfian contention), n (table size), k (cell words) and p (batch lanes =
the thread-count analogue).

For every cell we record
  * ops/s        — measured XLA-on-CPU throughput (relative ordering);
  * bytes/op     — the strategy's modeled HBM traffic (TPU roofline input);
  * dep_chains   — dependent-gather depth on the load critical path (1 =
                   pipelineable stream = the paper's 'one cache miss');
  * rmw/op       — single-word RMW count (contention proxy).

INDIRECT's 2-deep chain and SEQLOCK/CACHED_*'s 1-deep fast path are the
paper's central claim, visible here as structure, not just time.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, save_results, time_op
from repro.core import bigatomic as ba
from repro.core import semantics as sem

STRATEGIES = ["seqlock", "indirect", "cached_wf", "cached_me", "simplock",
              "plain"]

DEF = dict(n=1 << 16, k=4, p=4096, u=0.2, z=0.0)


def run_cell(strategy: str, *, n, k, p, u, z, reps=3, seed=0):
    rng = np.random.default_rng(seed)
    table = ba.BigAtomicTable(n, k, strategy, p_max=p)
    cur = np.asarray(table.logical())
    ops = sem.random_batch(rng, p=p, n=n, k=k, update_frac=u, zipf=z,
                           current=cur)

    def step(state, ops):
        new_state, res, stats, traffic = ba.apply_ops(
            state, ops, strategy=strategy, k=k)
        return new_state, res, stats, traffic

    dt, (state, res, stats, traffic) = time_op(step, table.state, ops,
                                               reps=reps)
    return {
        "strategy": strategy, "n": n, "k": k, "p": p, "u": u, "z": z,
        "mops_s": p / dt / 1e6,
        "rounds": int(stats.rounds),
        "bytes_op": float((traffic.bytes_read + traffic.bytes_written) / p),
        "dep_chains": int(traffic.dep_chains),
        "rmw_op": float(traffic.rmw_ops / p),
    }


def sweep(param: str, values, *, quick=False, strategies=STRATEGIES):
    rows = []
    for v in values:
        kw = dict(DEF)
        kw[param] = v
        if quick:
            kw["n"] = min(kw["n"], 1 << 12)
            kw["p"] = min(kw["p"], 1024)
        for s in strategies:
            rows.append(run_cell(s, **kw))
    return rows


def main(quick: bool = False):
    all_rows = {}
    all_rows["u"] = sweep("u", [0.0, 0.2, 0.5, 1.0], quick=quick)
    all_rows["z"] = sweep("z", [0.0, 0.6, 0.9, 0.99], quick=quick)
    all_rows["n"] = sweep("n", [1 << 10, 1 << 14] if quick else
                          [1 << 10, 1 << 14, 1 << 18, 1 << 22], quick=quick)
    all_rows["k"] = sweep("k", [1, 4, 16] if quick else [1, 2, 4, 8, 16],
                          quick=quick)
    all_rows["p"] = sweep("p", [256, 1024] if quick else
                          [256, 1024, 4096, 16384], quick=quick)
    for key, rows in all_rows.items():
        print_table(f"Fig2 analogue: vary {key}", rows,
                    ["strategy", key, "mops_s", "rounds", "bytes_op",
                     "dep_chains", "rmw_op"])
    save_results("bench_atomics", all_rows)
    # paper-claim checks (soft, printed): cached fast path beats indirect
    by = {}
    for r in all_rows["u"]:
        by.setdefault(r["strategy"], []).append(r)
    cm = np.mean([r["mops_s"] for r in by["cached_me"]])
    ind = np.mean([r["mops_s"] for r in by["indirect"]])
    print(f"\n[check] cached_me {cm:.1f} Mop/s vs indirect {ind:.1f} Mop/s "
          f"-> {'OK' if cm > ind else 'UNEXPECTED'} (paper: cached wins)")
    dep_cm = by["cached_me"][0]["dep_chains"]
    dep_in = by["indirect"][0]["dep_chains"]
    print(f"[check] dep chains: cached_me={dep_cm} indirect={dep_in} "
          f"-> {'OK' if dep_cm < dep_in else 'UNEXPECTED'}")
    return all_rows


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
