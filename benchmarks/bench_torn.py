"""The oversubscription experiment, TPU-adapted (paper Fig 2 right column).

On a CPU, oversubscription deschedules a lock-holding writer and readers
stall.  The SPMD analogue (DESIGN.md §2): a writer is frozen at its most
vulnerable point (`bigatomic.begin_update` — mid-cache-copy, lock held /
backup installed), and a wave of readers runs the honest per-strategy read
protocol.  We measure, per strategy:

  blocked%   — reads that must retry (lock-based failure mode),
  correct%   — reads that return a CONSISTENT value (old or new),
  torn%      — reads returning a half-written cell (PLAIN's failure mode).

Paper's finding, reproduced structurally: SEQLOCK/SIMPLOCK block; INDIRECT
and CACHED_* return consistent values without waiting; PLAIN corrupts.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import print_table, save_results
from repro import atomics

STRATEGIES = ["seqlock", "simplock", "indirect", "cached_wf", "cached_me",
              "plain"]


def run(n=1024, k=8, n_writers=64, q=4096, seed=0):
    rng = np.random.default_rng(seed)
    rows = []
    for strategy in STRATEGIES:
        spec = atomics.AtomicSpec(n, k, strategy, p_max=256)
        state = atomics.init(spec)
        old = np.asarray(atomics.logical(spec, state)).copy()
        hot = rng.choice(n, n_writers, replace=False)
        new_vals = rng.integers(0, 2**32, (n_writers, k), dtype=np.uint32)
        for slot, nv in zip(hot, new_vals):
            state = atomics.begin_update(spec, state, int(slot), nv)
        slots = rng.choice(hot, q)                     # readers hit hot cells
        vals, ok = atomics.read(spec, state, slots)
        vals, ok = np.asarray(vals), np.asarray(ok)
        want_new = {int(s): nv for s, nv in zip(hot, new_vals)}
        is_old = (vals == old[slots]).all(1)
        is_new = np.array([
            (vals[i] == want_new[int(slots[i])]).all() for i in range(q)])
        blocked = ~ok
        torn = ok & ~is_old & ~is_new
        rows.append({
            "strategy": strategy,
            "blocked_pct": 100.0 * blocked.mean(),
            "consistent_pct": 100.0 * (ok & (is_old | is_new)).mean(),
            "torn_pct": 100.0 * torn.mean(),
            "reads_new_pct": 100.0 * (ok & is_new).mean(),
        })
    print_table("Torn-state resilience (frozen writer = descheduled "
                "lock holder)", rows,
                ["strategy", "blocked_pct", "consistent_pct", "torn_pct",
                 "reads_new_pct"])
    save_results("bench_torn", rows)
    # hard claims (paper): lock-free strategies never block nor tear
    by = {r["strategy"]: r for r in rows}
    assert by["cached_me"]["blocked_pct"] == 0
    assert by["cached_me"]["torn_pct"] == 0
    assert by["cached_wf"]["blocked_pct"] == 0
    assert by["cached_wf"]["torn_pct"] == 0
    assert by["indirect"]["blocked_pct"] == 0
    assert by["seqlock"]["blocked_pct"] > 0         # blocks under torn state
    assert by["plain"]["torn_pct"] > 0              # negative control
    print("\n[check] lock-free never blocked/torn; seqlock blocked; "
          "plain torn -> OK")
    return rows


def main(quick: bool = False):
    return run(q=1024 if quick else 4096)


if __name__ == "__main__":
    import sys
    main(quick="--quick" in sys.argv)
