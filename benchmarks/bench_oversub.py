"""Oversubscribed multi-stream execution (repro.runtime, DESIGN.md §9).

The paper's throughput regime keeps MORE logical streams in flight than
compute slots; the executor's async-dispatch window must make that (nearly)
free.  Sweep: oversubscription factor × contention against one local
big-atomic table, at constant TOTAL work — the acceptance cell (ISSUE 7)
is factor >= 4 throughput within 2x of the 1-stream-per-slot baseline.

A subprocess cell (8 placeholder devices) additionally injects a mid-round
shard loss into a distributed executor run and reports the measured
recovery latency (checkpoint restore + reshard onto survivors + journal
replay) — the number committed in BENCH_7.json.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap
import time

from benchmarks.common import print_table, save_results

# Fixed baseline shapes (independent of --quick, see baseline.py).
N, K, WIDTH, SLOTS = 1 << 10, 4, 256, 2
TOTAL_BATCHES = 48


def run_oversub_cell(strategy: str, *, factor: int, hot_frac: float,
                     reps: int = 3) -> dict:
    """One sweep cell: S = SLOTS*factor streams, in-flight budget
    SLOTS*factor, TOTAL_BATCHES batches of WIDTH lanes split evenly."""
    import numpy as np

    from repro import atomics
    from repro.runtime import Executor, LocalTarget, SyntheticStream

    n_streams = SLOTS * factor
    per_stream = TOTAL_BATCHES // n_streams
    spec = atomics.AtomicSpec(N, K, strategy, p_max=WIDTH)
    rng = np.random.default_rng(0)
    init = rng.integers(0, 2 ** 32, (N, K), dtype=np.uint32)

    def once() -> float:
        target = LocalTarget(spec, init)
        streams = [SyntheticStream(f"s{i}", seed=i, n=N, k=K, width=WIDTH,
                                   n_batches=per_stream, hot_cells=4,
                                   hot_frac=hot_frac)
                   for i in range(n_streams)]
        ex = Executor(target, streams, slots=SLOTS, oversubscription=factor)
        t0 = time.perf_counter()
        ex.run()
        return time.perf_counter() - t0

    once()                                        # compile warmup
    dt = min(once() for _ in range(reps))
    lanes = TOTAL_BATCHES * WIDTH
    return dict(strategy=strategy, factor=factor, streams=n_streams,
                contention=("hot" if hot_frac else "uniform"),
                batches=TOTAL_BATCHES,
                mops_s=round(lanes / dt / 1e6, 3))


RECOVERY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, numpy as np
    from repro import atomics
    from repro.core import distributed as dsb
    from repro.runtime import (DistTarget, Executor, Fault, FaultInjector,
                               SyntheticStream)

    n, k, strategy = 32, 2, "seqlock"

    def factory(n_surviving):
        s = 1
        while s * 2 <= n_surviving and n % (s * 2) == 0:
            s *= 2
        mesh = jax.make_mesh((s, 8 // s), ("shard", "rest"))
        return mesh, dsb.DistSpec(
            atomics.AtomicSpec(n, k, strategy, p_max=64), "shard", s,
            32 // s)

    rng = np.random.default_rng(0)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    mesh0, dspec0 = factory(8)
    target = DistTarget(mesh0, dspec0, init, mesh_factory=factory)
    streams = [SyntheticStream(f"s{i}", seed=i, n=n, k=k,
                               width=dspec0.p_global, n_batches=3)
               for i in range(4)]
    inj = FaultInjector([Fault(round=2, kind="shard_loss", shard=3,
                               after_issues=1)])
    ex = Executor(target, streams, slots=1, oversubscription=4,
                  injector=inj, checkpoint_every=2)
    rep = ex.run()
    (rec,) = rep["recoveries"]
    print("JSON:" + json.dumps(dict(
        latency_s=rec["latency_s"], replayed=rec["replayed"],
        shards_after=rec["n_shards"], issues=rep["issues"])))
""")


def run_recovery_cell() -> dict:
    """Injected mid-round shard loss on the 8-device fixture: measured
    recovery latency (restore + reshard + replay + re-checkpoint)."""
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", RECOVERY_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    line = [l for l in r.stdout.splitlines() if l.startswith("JSON:")]
    assert line, r.stdout + r.stderr[-2000:]
    return json.loads(line[0][5:])


def main(quick: bool = False):
    reps = 2 if quick else 3
    strategies = ("seqlock",) if quick else ("seqlock", "cached_wf")
    rows = []
    for strategy in strategies:
        base = {}
        for hot_frac in (0.0, 0.5):
            for factor in (1, 2, 4) if quick else (1, 2, 4, 8):
                cell = run_oversub_cell(strategy, factor=factor,
                                        hot_frac=hot_frac, reps=reps)
                if factor == 1:
                    base[hot_frac] = cell["mops_s"]
                cell["x_of_f1"] = round(cell["mops_s"] / base[hot_frac], 3)
                rows.append(cell)
    print_table("Oversubscribed executor (S = 2*factor streams, 2 slots)",
                rows, ["strategy", "factor", "streams", "contention",
                       "mops_s", "x_of_f1"])
    for r in rows:
        if r["factor"] == 4:
            assert r["x_of_f1"] >= 0.5, \
                f"factor-4 throughput fell below 2x of baseline: {r}"
    print("acceptance: factor-4 cells within 2x of 1-stream-per-slot "
          "baseline: OK")

    rec = run_recovery_cell()
    print(f"\nshard-loss recovery (8 -> {rec['shards_after']} shards, "
          f"{rec['replayed']} batches replayed): {rec['latency_s']:.2f}s")
    save_results("bench_oversub", dict(sweep=rows, recovery=rec))
    return rows, rec


if __name__ == "__main__":
    main(quick="--quick" in sys.argv)
