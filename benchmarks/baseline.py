"""The perf-regression baseline: a fixed, machine-readable benchmark subset.

`python -m benchmarks.run --baseline [--out BENCH_N.json]` runs this suite
and writes one JSON document; `python -m benchmarks.compare OLD NEW` diffs
two such documents and fails on >threshold regressions.  The committed
`BENCH_<pr>.json` at the repo root is the contract every future PR is held
to (ISSUE 5): CI regenerates the suite and reports the diff as a
non-blocking step.

Sizes are FIXED (small enough for a CI runner) and independent of --quick,
so baselines are comparable across commits; --quick only trims repetitions.
Every row carries a stable `name` key (suite/scenario/strategy) used by
compare.py to match rows across files, `ops_s`-class throughput metrics
(higher is better) and `dispatches`-class cost metrics (lower is better).
"""

from __future__ import annotations

import json
import platform
import sys

SCHEMA = 1

# Fixed baseline shapes: small enough for CI, large enough to resolve the
# fast-path / slow-path gap above timer noise (the sort+scan cost the fast
# path elides grows with p, so the uncontended cells use the larger batch;
# the all-same-slot cell serializes into p combining rounds, so it keeps a
# smaller one to bound wall-clock).
ATOMICS_N, ATOMICS_K, ATOMICS_P = 1 << 14, 4, 8192
ATOMICS_P_CONTENDED = 1024
TXN_N, TXN_K = 1 << 10, 2


def _atomics_suite(reps: int):
    from benchmarks import bench_atomics

    rows = []
    for scenario in bench_atomics.FASTPATH_SCENARIOS:
        p = (ATOMICS_P_CONTENDED if scenario == "cas_all_same_slot"
             else ATOMICS_P)
        for strategy in ("seqlock", "cached_me"):
            # Fast-path cells are ~ms-scale: take more reps so the committed
            # medians are stable on noisy shared runners.
            cell = bench_atomics.run_fastpath_cell(
                strategy, scenario, n=ATOMICS_N, k=ATOMICS_K, p=p,
                reps=max(reps, 11))
            rows.append({
                "name": f"atomics/{scenario}/{strategy}",
                "ops_s": cell["mops_s_fused"] * 1e6,
                "ops_s_linearize": cell["mops_s_linearize"] * 1e6,
                "rounds": cell["rounds"],
            })
    for strategy in ("indirect", "cached_me"):
        cell = bench_atomics.run_cell(
            strategy, n=ATOMICS_N, k=ATOMICS_K, p=ATOMICS_P, u=0.2, z=0.0,
            reps=reps)
        rows.append({
            "name": f"atomics/u0.2_z0/{strategy}",
            "ops_s": cell["mops_s"] * 1e6,
            "bytes_op": cell["bytes_op"],
            "dep_chains": cell["dep_chains"],
        })
    return rows


def _txn_suite(reps: int):
    import numpy as np

    from benchmarks.common import time_op
    from repro import atomics

    rows = []
    for t, w, contention in ((64, 4, "low"), (64, 4, "high")):
        rng = np.random.default_rng(0)
        spec = atomics.AtomicSpec(TXN_N, TXN_K, "cached_me", p_max=t * w)
        state = atomics.init(spec)
        hi = TXN_N if contention == "low" else 4 * w
        slots = np.stack([rng.choice(hi, w, replace=False)
                          for _ in range(t)]).astype(np.int32)
        txns = atomics.make_txns(
            slots,
            expected=np.zeros((t, w, TXN_K), np.uint32),
            desired=rng.integers(0, 2 ** 32, (t, w, TXN_K), dtype=np.uint32),
            k=TXN_K)

        def step(state, txns):
            return atomics.mcas(spec, state, txns)

        dt, (st2, res) = time_op(step, state, txns, reps=reps)
        rows.append({
            "name": f"txn/mcas_w{w}_{contention}/cached_me",
            "ops_s": t / dt,
            "rounds": int(res.rounds),
            "commit_frac": float(np.mean(np.asarray(res.success))),
        })
    return rows


def _serving_suite(reps: int):
    from benchmarks import bench_atomics

    rows = []
    cells = bench_atomics.bench_fused_serving(quick=True)
    for cell in cells:
        tag = "fused" if cell["mode"] == "fused" else "v1"
        rows.append({
            "name": f"serving/decode_{tag}",
            "ops_s": cell["steps_s"],
            "dispatches": cell["dispatches_step"],
        })
    return rows


def _oversub_suite(reps: int):
    from benchmarks import bench_oversub

    rows = []
    for strategy in ("seqlock", "cached_wf"):
        for hot_frac, cont in ((0.0, "uniform"), (0.5, "hot")):
            base = None
            for factor in (1, 4):
                cell = bench_oversub.run_oversub_cell(
                    strategy, factor=factor, hot_frac=hot_frac, reps=reps)
                base = base or cell["mops_s"]
                rows.append({
                    "name": f"oversub/f{factor}_{cont}/{strategy}",
                    "ops_s": cell["mops_s"] * 1e6,
                    "x_of_f1": round(cell["mops_s"] / base, 3),
                })
    rec = bench_oversub.run_recovery_cell()
    rows.append({
        "name": "oversub/shard_loss_recovery",
        "latency_s": rec["latency_s"],          # informational: the ISSUE 7
        "replayed": rec["replayed"],            # acceptance number
        "shards_after": rec["shards_after"],
    })
    return rows


def _obs_suite(reps: int):
    """Counter snapshots (ISSUE 9): the fixed bench_obs mixed sweep under
    BIGATOMIC_OBS=counters.  compare.py diffs the derived rates WARN-only;
    throughput stays the only hard gate."""
    from benchmarks import bench_obs
    from repro import obs

    with bench_obs._obs_mode("counters"):
        snap = bench_obs.counters_sweep(quick=False)
    rates = obs.derived(snap)
    return [{
        "name": "obs/counters/mixed_sweep",
        "hit_rate_fast": rates["hit_rate_fast"],
        "eligible_rate": rates["eligible_rate"],
        "mean_slow_rounds": rates["mean_slow_rounds"],
        "counter.engine.batches": snap["engine.batches"],
        "counter.engine.rounds.slow": snap["engine.rounds.slow"],
        "counter.engine.fail.cas": snap["engine.fail.cas"],
        "counter.engine.loads.raced": snap["engine.loads.raced"],
        "counter.mcas.commits": snap["mcas.commits"],
        "counter.mcas.aborts": snap["mcas.aborts"],
        "counter.queue.rounds": snap.get("queue.rounds", 0),
    }]


def _faults_suite(reps: int):
    """Guard cells (DESIGN.md §11): scrub throughput is gated like any
    `ops_s` metric; recovery latency and the overload shed rate ride
    along informationally."""
    from benchmarks import bench_faults

    rows = []
    for strategy in ("seqlock", "indirect", "cached_wf", "cached_me"):
        cell = bench_faults.scrub_throughput_cell(strategy, reps=reps)
        rows.append({
            "name": f"faults/scrub/{strategy}",
            "ops_s": cell["cells_s"],
        })
    rec = bench_faults.recovery_latency_cell()
    rows.append({
        "name": "faults/recovery",
        "latency_s": rec["latency_s"],
        "repaired": rec["repaired"],
        "quarantined": rec["quarantined"],
    })
    shed = bench_faults.shed_rate_cell()
    rows.append({
        "name": "faults/shed_overload",
        "shed_rate": shed["shed_rate"],
        "quarantined": shed["quarantined"],
    })
    return rows


def run_baseline(out_path: str, quick: bool = False) -> dict:
    reps = 2 if quick else 5
    doc = {
        "schema": SCHEMA,
        "config": {
            "python": sys.version.split()[0],
            "platform": platform.machine(),
            "atomics": {"n": ATOMICS_N, "k": ATOMICS_K, "p": ATOMICS_P,
                        "p_contended": ATOMICS_P_CONTENDED},
            "txn": {"n": TXN_N, "k": TXN_K},
            "reps": reps,
        },
        "suites": {},
    }
    import jax
    doc["config"]["jax"] = jax.__version__
    doc["config"]["backend"] = jax.default_backend()

    doc["suites"]["atomics"] = _atomics_suite(reps)
    doc["suites"]["txn"] = _txn_suite(reps)
    doc["suites"]["oversub"] = _oversub_suite(reps)
    doc["suites"]["obs"] = _obs_suite(reps)
    doc["suites"]["faults"] = _faults_suite(reps)
    try:
        doc["suites"]["serving"] = _serving_suite(reps)
    except Exception as e:                 # model deps are optional here
        print(f"[baseline] serving suite skipped: {e!r}")
        doc["suites"]["serving"] = []

    with open(out_path, "w") as f:
        json.dump(doc, f, indent=1, default=float)
        f.write("\n")
    n_rows = sum(len(v) for v in doc["suites"].values())
    print(f"[baseline] wrote {n_rows} rows to {out_path}")
    return doc
