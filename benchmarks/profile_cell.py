"""Per-site HBM/collective profile of one dry-run cell — the 'profiler' of
the §Perf hypothesis loop (no TPU, so the profile is the compiled HLO).

  PYTHONPATH=src python -m benchmarks.profile_cell --arch mixtral-8x7b \
      --shape train_4k [--opt] [--top 15]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--opt", action="store_true")
    ap.add_argument("--top", type=int, default=15)
    args = ap.parse_args()

    import jax
    from repro import dist
    from repro.analysis import analyze_hlo
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES
    from repro.launch.dryrun import build_cell, optimize_cfg
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    if args.opt:
        cfg = optimize_cfg(cfg, shape)
    mesh = make_production_mesh(multi_pod=args.mesh == "multi")
    rules = dist.make_rules(cfg, mesh)
    fn, arg_specs, in_sh, out_sh, donate = build_cell(cfg, shape, mesh)
    with dist.axis_rules(mesh, rules):
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                           donate_argnums=donate).lower(*arg_specs).compile()
    cost = analyze_hlo(compiled.as_text())
    print(f"cell: {args.arch} x {args.shape} x {args.mesh} "
          f"opt={args.opt}")
    print(f"flops/dev: {cost.flops:.3e}  bytes/dev: {cost.bytes_hbm:.3e}  "
          f"coll/dev: {cost.coll_bytes:.3e}")
    print(f"coll by kind: "
          f"{ {k: f'{v:.2e}' for k, v in cost.coll_by_kind.items()} }")
    print(f"\ntop {args.top} HBM sites (trip-corrected bytes/device):")
    total = cost.bytes_hbm
    for name, b in cost.top_sites(args.top):
        print(f"  {b:12.3e}  {100*b/total:5.1f}%  {name}")
    if cost.coll_site:
        print(f"\ntop collective sites (ICI bytes/device):")
        for name, b in cost.top_coll_sites(args.top):
            print(f"  {b:12.3e}  {100*b/max(cost.coll_bytes,1):5.1f}%  {name}")


if __name__ == "__main__":
    main()
