"""Benchmark harness entry point: one bench per paper table/figure plus the
framework's roofline report.

  PYTHONPATH=src python -m benchmarks.run [--quick]
  PYTHONPATH=src python -m benchmarks.run --baseline [--out BENCH_N.json]

--quick shrinks sizes for CI; default finishes in a few minutes on one CPU
core.  Results land in benchmarks/results/*.json.

--baseline runs the FIXED machine-readable perf-regression suite
(benchmarks/baseline.py: atomics fast-path cells, txn MCAS cells, serving
dispatch counts) and writes one JSON document; diff two of them with
`python -m benchmarks.compare OLD NEW` (fails on >10% regression).  The
committed BENCH_<pr>.json at the repo root is the reference every PR is
held to.
"""

from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from benchmarks import (bench_atomics, bench_cachehash, bench_distributed,
                        bench_faults, bench_llsc, bench_memory, bench_obs,
                        bench_oversub, bench_torn, bench_txn)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--skip", default="", help="comma-list to skip")
    ap.add_argument("--baseline", action="store_true",
                    help="run the fixed perf-regression suite and exit")
    ap.add_argument("--out", default="BENCH_baseline.json",
                    help="output path for --baseline")
    args, _ = ap.parse_known_args()
    skip = set(s for s in args.skip.split(",") if s)

    if args.baseline:
        from benchmarks.baseline import run_baseline
        run_baseline(args.out, quick=args.quick)
        return

    benches = [
        ("atomics (Fig 2)", bench_atomics.main),
        ("cachehash (Figs 3-4)", bench_cachehash.main),
        ("torn-state / oversubscription (Fig 2 right)", bench_torn.main),
        ("llsc + sync queue (LL/SC application)", bench_llsc.main),
        ("memory (Table 1)", bench_memory.main),
        ("distributed table (beyond paper)", bench_distributed.main),
        ("txn: MCAS + transactional map (tuples/version-list apps)",
         bench_txn.main),
        ("oversubscribed executor + shard-loss recovery (runtime)",
         bench_oversub.main),
        ("observability: counters sweep + executor trace (repro.obs)",
         bench_obs.main),
        ("fault tolerance: scrub throughput + recovery + shed (repro.guard)",
         bench_faults.main),
    ]
    failures = []
    for name, fn in benches:
        if any(s in name for s in skip):
            print(f"\n##### SKIP {name}")
            continue
        print(f"\n##### {name}")
        t0 = time.time()
        try:
            fn(quick=args.quick)
            print(f"##### done in {time.time()-t0:.1f}s")
        except Exception:
            failures.append(name)
            traceback.print_exc()

    # roofline report (needs dryrun_results.json; optional)
    try:
        from benchmarks import bench_roofline
        print("\n##### roofline (from dry-run)")
        bench_roofline.main()
    except SystemExit as e:
        print(e)
    except Exception:
        failures.append("roofline")
        traceback.print_exc()

    if failures:
        print(f"\nFAILED benches: {failures}")
        raise SystemExit(1)
    print("\nAll benchmarks completed.")


if __name__ == "__main__":
    main()
