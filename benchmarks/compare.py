"""Diff two perf baselines; fail on regression.

  python -m benchmarks.compare BENCH_5.json new.json [--threshold 0.10]

Rows are matched across files by their stable `name` key.  Metrics are
classed by name: `ops_s*` are throughputs (regression = NEW below OLD by
more than the threshold fraction), `dispatches*` are per-step costs
(regression = NEW above OLD by more than the threshold — dispatch counts
are deterministic, so even small increases are real).  Counter-derived
observability metrics (`hit_rate*`, `eligible_rate`, `mean_*`,
`counter.*` from the obs suite) are WARN-only: drift prints a WARN row
but can never fail the diff.  Everything else is informational.  Exit
status 1 iff any regression; CI runs this as a
non-blocking report step, humans run it before merging perf-sensitive PRs.

A row absent from the new file is a REGRESSION only when its whole suite
still exists there; a suite absent from one side entirely (a bench that
didn't run — e.g. a quick/--baseline subset, or a fault-injection suite
gated off) downgrades its rows to WARN-only `MISSING-SUITE` so a partial
run can never hard-fail the diff on coverage alone.
"""

from __future__ import annotations

import argparse
import json
import sys


def load(path: str) -> dict:
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != 1:
        raise SystemExit(f"{path}: unknown baseline schema "
                         f"{doc.get('schema')!r}")
    return doc


def rows_by_name(doc: dict) -> dict:
    out = {}
    for suite, rows in doc.get("suites", {}).items():
        for row in rows:
            out[row["name"]] = row
    return out


def classify(metric: str) -> str:
    if metric.startswith("ops_s"):
        return "throughput"
    if metric.startswith("dispatches"):
        return "cost"
    # Counter-derived observability metrics (ISSUE 9): drift is surfaced
    # as WARN but never fails the diff — throughput stays the hard gate.
    if metric.startswith(("hit_rate", "eligible_rate", "mean_", "counter.")):
        return "counter"
    return "info"


def compare(old: dict, new: dict, threshold: float):
    """Yields (name, metric, old, new, delta_frac, verdict)."""
    old_rows = rows_by_name(old)
    new_rows = rows_by_name(new)
    new_suites = set(new.get("suites", {}))
    suite_of = {row["name"]: suite
                for suite, rows in old.get("suites", {}).items()
                for row in rows}
    for name in sorted(old_rows):
        o = old_rows[name]
        n = new_rows.get(name)
        if n is None:
            missing_suite = suite_of.get(name) not in new_suites
            yield (name, "-", None, None, None,
                   "MISSING-SUITE" if missing_suite else "MISSING")
            continue
        for metric, oval in o.items():
            if metric == "name" or not isinstance(oval, (int, float)):
                continue
            nval = n.get(metric)
            if nval is None:
                continue
            kind = classify(metric)
            if kind == "info":
                continue
            if oval == 0:
                delta = 0.0 if nval == 0 else float("inf")
            else:
                delta = (nval - oval) / abs(oval)
            if kind == "throughput":
                verdict = "REGRESSION" if delta < -threshold else "ok"
            elif kind == "counter":                 # warn-only, never fails
                verdict = "WARN" if abs(delta) > threshold else "ok"
            else:                                   # cost
                verdict = "REGRESSION" if delta > threshold else "ok"
            yield (name, metric, oval, nval, delta, verdict)
    for name in sorted(set(new_rows) - set(old_rows)):
        yield (name, "-", None, None, None, "NEW")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("old", help="reference baseline (e.g. BENCH_5.json)")
    ap.add_argument("new", help="freshly generated baseline")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="regression threshold as a fraction (default 0.10)")
    args = ap.parse_args(argv)

    old, new = load(args.old), load(args.new)
    regressions = 0
    print(f"{'row':44s} {'metric':14s} {'old':>12s} {'new':>12s} "
          f"{'delta':>8s}  verdict")
    for name, metric, oval, nval, delta, verdict in compare(
            old, new, args.threshold):
        if verdict in ("MISSING", "MISSING-SUITE", "NEW"):
            print(f"{name:44s} {'-':14s} {'-':>12s} {'-':>12s} "
                  f"{'-':>8s}  {verdict}")
            regressions += verdict == "MISSING"
            continue
        if verdict == "REGRESSION":
            regressions += 1
        print(f"{name:44s} {metric:14s} {oval:12.4g} {nval:12.4g} "
              f"{delta:+8.1%}  {verdict}")
    if regressions:
        print(f"\n{regressions} regression(s) beyond "
              f"{args.threshold:.0%} vs {args.old}")
        return 1
    print(f"\nno regressions beyond {args.threshold:.0%} vs {args.old}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
