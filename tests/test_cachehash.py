"""CacheHash vs dict-oracle: linearizable batched find/insert/delete,
inline vs chaining equivalence, path-copying deletes, pool reclamation."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cachehash as ch


def _rand_ops(rng, q, key_space, vw, mix=(0.4, 0.4, 0.2)):
    kind = rng.choice([ch.FIND, ch.INSERT, ch.DELETE], size=q, p=mix)
    keys = rng.integers(0, key_space, size=q, dtype=np.uint32)
    vals = rng.integers(0, 2**32, size=(q, vw), dtype=np.uint32)
    return ch.OpBatch(jnp.asarray(kind.astype(np.int32)),
                      jnp.asarray(keys), jnp.asarray(vals))


def _run_and_check(table, model, ops, vw):
    model, ref = ch.apply_reference(model, ops, vw)
    res, stats = table.apply(ops)
    assert not bool(jnp.any(res.overflow)), "chain walk overflow — resize test"
    np.testing.assert_array_equal(np.asarray(res.found), ref.found)
    np.testing.assert_array_equal(np.asarray(res.value), ref.value)
    return model


STRATS = ["seqlock", "cached_me", "cached_wf", "indirect"]


@pytest.mark.parametrize("strategy", STRATS)
@pytest.mark.parametrize("inline", [True, False])
def test_basic_insert_find_delete(strategy, inline):
    t = ch.CacheHash(16, vw=2, strategy=strategy, p_max=64, inline=inline)
    model = {}
    rng = np.random.default_rng(0)
    keys = np.array([1, 2, 3, 17, 33], np.uint32)  # 17,33 collide with 1 mod 16? (hash-dependent)
    vals = rng.integers(0, 2**32, (5, 2), dtype=np.uint32)
    model = _run_and_check(t, model, ch.OpBatch(
        jnp.full((5,), ch.INSERT, jnp.int32), jnp.asarray(keys),
        jnp.asarray(vals)), 2)
    model = _run_and_check(t, model, ch.OpBatch(
        jnp.full((5,), ch.FIND, jnp.int32), jnp.asarray(keys),
        jnp.zeros((5, 2), jnp.uint32)), 2)
    model = _run_and_check(t, model, ch.OpBatch(
        jnp.asarray([ch.DELETE, ch.FIND, ch.DELETE, ch.FIND, ch.DELETE],
                    jnp.int32),
        jnp.asarray(keys), jnp.zeros((5, 2), jnp.uint32)), 2)
    got = {k: tuple(int(x) for x in v) for k, v in t.items().items()}
    want = {int(k): tuple(int(x) for x in v) for k, v in model.items()}
    assert got == want


@pytest.mark.parametrize("strategy", STRATS)
@pytest.mark.parametrize("inline", [True, False])
def test_forced_collisions_chain_ops(strategy, inline):
    # nb=2 forces long chains: exercises displacement, chain walk, path copy.
    t = ch.CacheHash(2, vw=1, strategy=strategy, p_max=64, inline=inline,
                     max_chain=12, chain_factor=16.0)
    model = {}
    rng = np.random.default_rng(1)
    for step in range(6):
        ops = _rand_ops(rng, 8, key_space=12, vw=1)
        model = _run_and_check(t, model, ops, 1)
        got = {k: int(v[0]) for k, v in t.items().items()}
        want = {int(k): int(v[0]) for k, v in model.items()}
        assert got == want, f"step {step}: {got} != {want}"


def test_duplicate_keys_same_batch():
    # Linearization order matters: insert(k) then delete(k) then find(k).
    t = ch.CacheHash(4, vw=1, strategy="cached_me", p_max=32)
    model = {}
    kind = jnp.asarray([ch.INSERT, ch.INSERT, ch.DELETE, ch.FIND,
                        ch.INSERT, ch.FIND], jnp.int32)
    keys = jnp.asarray([7, 7, 7, 7, 7, 7], jnp.uint32)
    vals = jnp.asarray([[1], [2], [0], [0], [3], [0]], jnp.uint32)
    ops = ch.OpBatch(kind, keys, vals)
    model = _run_and_check(t, model, ops, 1)
    # second insert must have failed (add-if-absent), final value = 3
    assert {k: int(v[0]) for k, v in t.items().items()} == {7: 3}


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       strategy=st.sampled_from(["cached_me", "seqlock"]),
       inline=st.booleans(),
       steps=st.integers(1, 4))
def test_property_matches_dict_oracle(seed, strategy, inline, steps):
    rng = np.random.default_rng(seed)
    t = ch.CacheHash(8, vw=1, strategy=strategy, p_max=128, inline=inline,
                     max_chain=16, chain_factor=8.0)
    model = {}
    for _ in range(steps):
        ops = _rand_ops(rng, 16, key_space=24, vw=1)
        model = _run_and_check(t, model, ops, 1)
    got = {k: int(v[0]) for k, v in t.items().items()}
    want = {int(k): int(v[0]) for k, v in model.items()}
    assert got == want


def test_count_tracks_live_elements():
    t = ch.CacheHash(16, vw=1, strategy="cached_me", p_max=64)
    t.insert(np.arange(10, dtype=np.uint32), np.ones((10, 1), np.uint32))
    assert int(t.state.count) == 10
    t.delete(np.arange(5, dtype=np.uint32))
    assert int(t.state.count) == 5
    t.insert(np.arange(10, dtype=np.uint32), np.ones((10, 1), np.uint32))
    assert int(t.state.count) == 10


def test_pool_slots_reclaimed():
    # Insert/delete cycles must not leak pool slots.
    t = ch.CacheHash(4, vw=1, strategy="cached_me", p_max=64,
                     max_chain=16, chain_factor=8.0)
    free0 = ch.free_slots_available(t.state)
    keys = np.arange(12, dtype=np.uint32)
    for _ in range(5):
        t.insert(keys, np.ones((12, 1), np.uint32))
        t.delete(keys)
    assert int(t.state.count) == 0
    assert ch.free_slots_available(t.state) == free0


def test_inline_reduces_chain_steps():
    # The paper's headline: inlining the first link removes ~1 dependent
    # gather per op at load factor <= 1.
    rng = np.random.default_rng(3)
    keys = rng.choice(2**20, size=64, replace=False).astype(np.uint32)
    vals = np.ones((64, 1), np.uint32)
    steps = {}
    for inline in (True, False):
        t = ch.CacheHash(128, vw=1, strategy="cached_me", p_max=256,
                         inline=inline)
        t.insert(keys, vals)
        _, stats = t.find(keys)
        steps[inline] = int(stats.chain_steps)
    assert steps[True] < steps[False]
    # With 64 keys in 128 buckets ~C(64,2)/128 = 16 collisions are expected:
    # only collided keys pay a pool gather on the inline path, while the
    # chaining baseline pays >= 1 dependent gather for EVERY op.
    assert steps[True] <= 30
    assert steps[False] >= 64
