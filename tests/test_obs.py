"""repro.obs acceptance suite (ISSUE 9).

Two contracts, both tier-1:

  * OFF IS FREE — with BIGATOMIC_OBS unset/off the engine traces the exact
    pre-observability programs (zero new jit cache entries across a sweep)
    and the fused serving decode stays ONE dispatch per step; no host
    counter is ever recorded.

  * COUNTERS ARE DEFINITIONS — with BIGATOMIC_OBS=counters, every in-graph
    counter equals the `tests/oracle.TelemetryOracle` recount from the
    delivered batches/results BIT-EXACTLY, across the four lock-free
    strategies x {xla, pallas-interpret} engine kernels, including MCAS
    runs and distributed route-overflow lanes; and turning counters on
    never perturbs results (bit-equal to the off-mode run).
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from oracle import (TableOracle, TelemetryOracle, TxnOracle, mixed_batch,
                    txn_batch)
from repro import atomics, obs
from repro.analysis import tracing
from repro.core import engine

STRATEGIES = ("seqlock", "indirect", "cached_wf", "cached_me")


def _sweep(spec, *, batches, seed):
    """Drive `batches` mixed batches through engine.apply, threading ctx.
    Returns (oracle, [(ops, live_result)], final logical table)."""
    p = spec.p_max
    oc = TableOracle(spec.n, spec.k, p)
    state, ctx = engine.init(spec), None
    rng = np.random.default_rng(seed)
    seen = []
    for _ in range(batches):
        ops = mixed_batch(rng, oc.ctx, p=p, n=spec.n, k=spec.k,
                          current=oc.data)
        ref = oc.step(ops)
        state, ctx, res, stats, _ = engine.apply(spec, state, ops, ctx)
        oc.check(result=res, ref=ref, msg="live vs oracle")
        seen.append((ops, res))
    return oc, seen, np.asarray(atomics.logical(spec, state))


# ---------------------------------------------------------------------------
# Off is free.
# ---------------------------------------------------------------------------

def test_off_returns_legacy_tuple_and_adds_zero_traces(monkeypatch):
    """BIGATOMIC_OBS=off: apply returns the classic 5-tuple and a whole
    sweep adds ZERO entries to the jitted round's cache — the telem pytree
    is None (an empty pytree), so the traced program is byte-identical to
    the pre-observability one."""
    monkeypatch.delenv("BIGATOMIC_OBS", raising=False)
    n, k, p = 32, 2, 16
    spec = atomics.AtomicSpec(n, k, "cached_me", p_max=p)
    oc = TableOracle(n, k, p)
    rng = np.random.default_rng(0)
    state, ctx = engine.init(spec), None
    for _ in range(2):          # warm both signatures: ctx=None, then LinkCtx
        ops = mixed_batch(rng, oc.ctx, p=p, n=n, k=k, current=oc.data)
        oc.step(ops)
        out = engine.apply(spec, state, ops, ctx)
        assert len(out) == 5, "off-mode apply must keep the legacy 5-tuple"
        state, ctx = out[0], out[1]
    with tracing.assert_max_new_traces(engine._apply, 0):
        for _ in range(4):
            ops = mixed_batch(rng, oc.ctx, p=p, n=n, k=k, current=oc.data)
            oc.step(ops)
            state, ctx, *_ = engine.apply(spec, state, ops, ctx)
    # off also means: no host counters, device counters all zero.
    assert all(v == 0 for v in obs.snapshot().values())


def test_counters_flag_flip_is_a_mode_not_a_retrace_hazard(monkeypatch):
    """Turning counters ON and OFF mid-process must never hit a stale
    trace: the telem argument's None-ness selects the program."""
    n, k, p = 16, 2, 8
    spec = atomics.AtomicSpec(n, k, "seqlock", p_max=p)
    ops = atomics.stores(np.arange(p, dtype=np.int32) % n,
                         np.ones((p, k), np.uint32), k=k)
    monkeypatch.setenv("BIGATOMIC_OBS", "counters")
    obs.reset()
    out_on = engine.apply(spec, engine.init(spec), ops)
    assert len(out_on) == 5          # telem rides the call, not the return
    assert obs.snapshot()["engine.batches"] == 1
    monkeypatch.setenv("BIGATOMIC_OBS", "off")
    out_off = engine.apply(spec, engine.init(spec), ops)
    np.testing.assert_array_equal(np.asarray(out_on[2].success),
                                  np.asarray(out_off[2].success))
    assert obs.snapshot()["engine.batches"] == 1   # off run counted nothing


# ---------------------------------------------------------------------------
# Counters match the oracle recount, bit-exactly.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", ("xla", "pallas"))
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_engine_counters_match_oracle(monkeypatch, strategy, kernel):
    monkeypatch.setenv("BIGATOMIC_OBS", "counters")
    monkeypatch.setenv("BIGATOMIC_ENGINE_KERNEL", kernel)
    obs.reset()
    # pallas runs interpret-mode on CPU: keep it small.
    n, p, batches = (64, 24, 6) if kernel == "xla" else (32, 12, 4)
    spec = atomics.AtomicSpec(n, 2, strategy, p_max=p)
    fused = engine.round_for(spec, mode=kernel) is not engine.linearize
    tel = TelemetryOracle(n)
    _, seen, _ = _sweep(spec, batches=batches, seed=sum(map(ord, strategy)))
    for ops, res in seen:
        tel.count_table_batch(ops, res, fused=fused)
    # quiescent reads: lock-free strategies never observe a torn cell.
    _, ok = engine.read(spec, engine.init(spec), np.arange(8, dtype=np.int32))
    tel.count_read(ok)
    snap = obs.snapshot()
    want = tel.counts()
    got = {name: snap[name] for name in want}
    assert got == want, {name: (got[name], want[name])
                         for name in want if got[name] != want[name]}


def test_counters_do_not_perturb_results(monkeypatch):
    """The counters program must compute the exact same table/results as
    the off program — counters observe, never steer."""
    spec = atomics.AtomicSpec(32, 2, "cached_wf", p_max=16)
    monkeypatch.setenv("BIGATOMIC_OBS", "off")
    _, seen_off, logical_off = _sweep(spec, batches=4, seed=42)
    monkeypatch.setenv("BIGATOMIC_OBS", "counters")
    obs.reset()
    _, seen_on, logical_on = _sweep(spec, batches=4, seed=42)
    np.testing.assert_array_equal(logical_off, logical_on)
    for (_, a), (_, b) in zip(seen_off, seen_on):
        np.testing.assert_array_equal(np.asarray(a.value),
                                      np.asarray(b.value))
        np.testing.assert_array_equal(np.asarray(a.success),
                                      np.asarray(b.success))
    assert obs.snapshot()["engine.batches"] == 4


@pytest.mark.parametrize("strategy", ("seqlock", "cached_me"))
def test_mcas_counters_match_oracle(monkeypatch, strategy):
    from repro.txn import mcas as txn_mcas
    monkeypatch.setenv("BIGATOMIC_OBS", "counters")
    obs.reset()
    n, k, t, w = 12, 2, 8, 3
    spec = atomics.AtomicSpec(n, k, strategy, p_max=64)
    rng = np.random.default_rng(7)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    state = atomics.init(spec, init)
    oracle = TxnOracle(n, k, initial=init)
    tel = TelemetryOracle(n)
    for _ in range(3):
        txns = txn_batch(rng, t=t, w=w, n=n, k=k, current=oracle.data)
        state, res = atomics.mcas(spec, state, txns)
        oracle.step_and_check(txns, result=res,
                              logical=atomics.logical(spec, state),
                              order=txn_mcas.linearization_order(res))
        tel.count_mcas(res)
    snap = obs.snapshot()
    want = tel.counts()
    got = {name: snap[name] for name in want}
    assert got == want, (got, want)
    assert snap["mcas.commits"] > 0      # the sweep must exercise commits
    assert snap["mcas.aborts"] > 0       # ... and real aborts


def test_dist_counters_match_oracle_including_overflow(monkeypatch):
    """Distributed route-overflow lanes count from the same claimed-order
    overflow mask the linearization oracle uses (single-device mesh; the
    multi-host variant rides tests/dist_checks.py in CI)."""
    from repro.core import distributed as dsb
    monkeypatch.setenv("BIGATOMIC_OBS", "counters")
    obs.reset()
    n, k, pl, cap = 16, 2, 8, 3
    mesh = jax.make_mesh((1,), ("shard",))
    dspec = dsb.DistSpec(atomics.AtomicSpec(n, k, "cached_me", p_max=64),
                         "shard", 1, pl, route_capacity=cap)
    p = dspec.p_global
    rng = np.random.default_rng(9)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    st = dsb.init_dist(mesh, dspec, init)
    tel = TelemetryOracle(n)
    oracle = TableOracle(n, k, p, initial=init)
    for _ in range(2):
        # all lanes write shard 0 => lanes beyond cap=3 overflow.
        ops = atomics.make_ops(
            np.full(p, atomics.STORE, np.int32),
            rng.integers(0, n, p).astype(np.int32),
            desired=rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32), k=k)
        order, ovf_ref = dsb.linearization_order(dspec, ops)
        st, ctx, res, ovf = dsb.apply(mesh, dspec, st, ops)
        np.testing.assert_array_equal(np.asarray(ovf), ovf_ref)
        oracle.step_and_check(ops, result=res, order=order,
                              overflow=ovf_ref, msg="dist overflow")
        tel.count_dist_batch(ovf_ref, dsb.collective_words(dspec))
    snap = obs.snapshot()
    want = tel.counts()
    got = {name: snap[name] for name in want}
    assert got == want, (got, want)
    assert snap["dist.route_overflow"] > 0


# ---------------------------------------------------------------------------
# Host-side counters (queue retry loop, serving engine).
# ---------------------------------------------------------------------------

def test_queue_counters_record_retry_pressure(monkeypatch):
    from repro.sync.queue import BigQueue
    monkeypatch.setenv("BIGATOMIC_OBS", "counters")
    obs.reset()
    q = BigQueue(4, k=2, strategy="cached_me")
    ok = q.enqueue_batch(np.arange(6, dtype=np.uint32))   # 6 lanes, cap 4
    assert int(ok.sum()) == 4
    out, succ = q.dequeue_batch(6)                        # 4 items left
    assert int(succ.sum()) == 4
    snap = obs.snapshot()
    assert snap["queue.enq"] == 4
    assert snap["queue.deq"] == 4
    assert snap["queue.enq_full"] >= 2     # the two over-capacity lanes
    assert snap["queue.deq_empty"] >= 2    # the two over-drain lanes
    assert snap["queue.rounds"] >= 2


# -- serving: share the (expensive) reduced model across both tests --------

_SERVING = {}


def _serving_cfg_params():
    if not _SERVING:
        from repro.configs import get_config
        from repro.models.transformer import init_params
        cfg = dataclasses.replace(get_config("deepseek_7b", reduced=True),
                                  param_dtype="float32",
                                  compute_dtype="float32")
        _SERVING["cfg"] = cfg
        _SERVING["params"] = init_params(cfg, jax.random.PRNGKey(0))
    return _SERVING["cfg"], _SERVING["params"]


def _serve_two(cfg, params):
    from repro.serving import Request, ServingEngine
    rng = np.random.default_rng(3)
    eng = ServingEngine(cfg, params, max_batch=2, n_pages=24, page_size=4,
                        max_pages_per_seq=8)
    for rid, plen in enumerate((11, 6)):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, plen)
                                     .astype(np.int32),
                           max_new_tokens=5))
    eng.run_to_completion()
    return eng


def test_serving_off_keeps_single_dispatch_per_decode_step(monkeypatch):
    """ISSUE 9 acceptance: with BIGATOMIC_OBS=off the fused decode path is
    untouched — exactly ONE jitted dispatch per shared decode step and
    zero observability state recorded anywhere."""
    monkeypatch.delenv("BIGATOMIC_OBS", raising=False)
    obs.reset()
    cfg, params = _serving_cfg_params()
    eng = _serve_two(cfg, params)
    # both slots decode together for 4 fused steps, 1 dispatch each
    assert eng.dispatch_count == 4, eng.dispatch_count
    assert all(v == 0 for v in obs.snapshot().values())


def test_serving_counters_mirror_dispatch_accounting(monkeypatch):
    monkeypatch.setenv("BIGATOMIC_OBS", "counters")
    obs.reset()
    cfg, params = _serving_cfg_params()
    eng = _serve_two(cfg, params)
    snap = obs.snapshot()
    assert snap["serving.admitted"] == 2
    assert snap["serving.retired"] == 2
    assert snap["serving.decode_steps"] == 4
    assert snap["serving.dispatches"] == eng.dispatch_count == 4
