"""Scenario driver for tests/test_distributed.py.

Runs in a subprocess under `XLA_FLAGS=--xla_force_host_platform_device_count=8`
(set below before jax imports) so the mesh-sharded layer executes on 8 fake
host devices.  Every scenario checks the live sharded system against the
SHARED linearizability harness (tests/oracle.py) replaying the claimed order
from `distributed.linearization_order`.

Usage:  python tests/dist_checks.py <scenario> [strategy]
Prints `DIST_OK:<scenario>` on success (the pytest wrapper asserts on it).
"""

import os
import sys
import zlib

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

_HERE = os.path.dirname(os.path.abspath(__file__))
for path in (_HERE, os.path.join(_HERE, "..", "src")):
    if path not in sys.path:
        sys.path.insert(0, path)

import jax                                                     # noqa: E402
import numpy as np                                             # noqa: E402

from oracle import (HashOracle, MapOracle, TableOracle, TxnOracle,  # noqa: E402
                    hash_batch, mixed_batch, txn_batch)
from repro import atomics                                      # noqa: E402
from repro.core import distributed as dsb                      # noqa: E402

SHARD_COUNTS = (2, 4, 8)


def _mesh(shards: int):
    """All scenarios run on the same 8-device fleet; unused devices
    replicate over the spare axis."""
    return jax.make_mesh((shards, 8 // shards), ("shard", "rest"))


def _drive_table(dspec, mesh, rng, init, steps, make_ops, msg):
    """Run `steps` batches through the sharded table, checking state,
    results, link ctx and the overflow contract against the harness."""
    st = dsb.init_dist(mesh, dspec, init)
    ctx = dsb.init_dist_ctx(mesh, dspec)
    oracle = TableOracle(dspec.n_global, dspec.inner.k, dspec.p_global,
                         initial=init)
    for step in range(steps):
        ops = make_ops(rng, oracle)
        order, ovf_ref = dsb.linearization_order(dspec, ops)
        st, ctx, res, ovf = dsb.apply(mesh, dspec, st, ops, ctx)
        np.testing.assert_array_equal(np.asarray(ovf), ovf_ref,
                                      err_msg=f"{msg} step {step}: overflow")
        oracle.step_and_check(
            ops, result=res, logical=dsb.logical(dspec, st),
            version=dsb.versions(dspec, st), ctx=ctx, order=order,
            overflow=ovf_ref, msg=f"{msg} step {step}")
    return st, ctx, oracle


def scenario_mixed(strategy: str):
    """Randomized mixed LOAD/STORE/CAS/LL/SC/VALIDATE batches vs the shared
    oracle, over shard counts {2, 4, 8}."""
    rng = np.random.default_rng(zlib.crc32(strategy.encode()))
    n, k, pl = 48, 3, 6
    for shards in SHARD_COUNTS:
        dspec = dsb.DistSpec(atomics.AtomicSpec(n, k, strategy, p_max=64),
                             "shard", shards, pl)
        init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
        _drive_table(
            dspec, _mesh(shards), rng, init, steps=3,
            make_ops=lambda rng, oracle: mixed_batch(
                rng, oracle.ctx, p=dspec.p_global, n=n, k=k,
                current=oracle.data),
            msg=f"mixed {strategy} shards={shards}")


def scenario_levers(strategy: str):
    """The §Perf routing levers must not change semantics: every
    dedup_loads × interleave × route_capacity combination replays against
    the shared oracle (load-heavy hot-slot batches so dedup and capacity
    overflow actually fire)."""
    n, k, shards, pl = 32, 2, 4, 8
    rng = np.random.default_rng(29)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)

    def hot_batch(rng, oracle):
        p = shards * pl
        kind = np.where(rng.random(p) < 0.7, atomics.LOAD,
                        rng.integers(0, 7, p)).astype(np.int32)
        slot = rng.integers(0, 6, p).astype(np.int32)      # hot cells
        desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
        expected = np.where((rng.random(p) < 0.5)[:, None],
                            oracle.data[slot],
                            rng.integers(0, 2 ** 32, (p, k),
                                         dtype=np.uint32)).astype(np.uint32)
        return atomics.make_ops(kind, slot, expected, desired, k=k)

    for dedup in (False, True):
        for ilv in (False, True):
            for cap in (None, 3):
                dspec = dsb.DistSpec(
                    atomics.AtomicSpec(n, k, strategy, p_max=64), "shard",
                    shards, pl, route_capacity=cap, dedup_loads=dedup,
                    interleave=ilv)
                _drive_table(dspec, _mesh(shards), rng, init, steps=2,
                             make_ops=hot_batch,
                             msg=f"levers dedup={dedup} ilv={ilv} cap={cap}")


def scenario_sync_adversary(strategy: str):
    """Cross-batch LL/SC adversaries THROUGH the routing layer: ABA (bytes
    restored on a remote shard; SC must refuse) and the lapped linker (a
    lane sleeping on its link while every other source commits)."""
    n, k, shards, pl = 16, 2, 4, 4
    mesh = _mesh(shards)
    dspec = dsb.DistSpec(atomics.AtomicSpec(n, k, strategy, p_max=64),
                         "shard", shards, pl)
    p = dspec.p_global
    rng = np.random.default_rng(5)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)

    def batch(assign):
        """assign: {lane: (kind, slot, desired_row)}"""
        kind = np.full(p, atomics.IDLE, np.int32)
        slot = np.zeros(p, np.int32)
        desired = np.zeros((p, k), np.uint32)
        for lane, (kd, sl, des) in assign.items():
            kind[lane], slot[lane] = kd, sl
            if des is not None:
                desired[lane] = des
        return atomics.make_ops(kind, slot, desired=desired, k=k)

    st = dsb.init_dist(mesh, dspec, init)
    ctx = dsb.init_dist_ctx(mesh, dspec)
    oracle = TableOracle(n, k, p, initial=init)

    def run(ops, msg):
        nonlocal st, ctx
        order, ovf = dsb.linearization_order(dspec, ops)
        assert not ovf.any()
        st, ctx, res, _ = dsb.apply(mesh, dspec, st, ops, ctx)
        ref = oracle.step_and_check(
            ops, result=res, logical=dsb.logical(dspec, st),
            version=dsb.versions(dspec, st), ctx=ctx, order=order, msg=msg)
        return np.asarray(res.success), ref

    # --- ABA: lane 0 (src 0) links cell 9 (owner shard 2); stores from a
    # DIFFERENT source restore the original bytes; SC + VALIDATE must fail.
    cell = 9
    run(batch({0: (atomics.LL, cell, None)}), "aba ll")
    original = np.array(oracle.ctx.value[0], copy=True)
    run(batch({5: (atomics.STORE, cell, (original + 1).astype(np.uint32))}),
        "aba store B")
    run(batch({5: (atomics.STORE, cell, original)}), "aba store A")
    np.testing.assert_array_equal(
        np.asarray(dsb.logical(dspec, st))[cell], original)  # bytes match
    succ, _ = run(batch({0: (atomics.VALIDATE, cell, None)}), "aba validate")
    assert not succ[0], "VALIDATE must fail after remote A->B->A"
    succ, _ = run(batch({0: (atomics.SC, cell, original)}), "aba sc")
    assert not succ[0], "SC must fail after remote A->B->A"

    # --- Lapped linker: lane 0 links cell 0; every other lane (across all
    # sources) LLs then SCs it in turn; lane 0's eventual SC must fail.
    run(batch({0: (atomics.LL, 0, None)}), "lap ll0")
    for lane in range(1, p):
        run(batch({lane: (atomics.LL, 0, None)}), f"lap ll{lane}")
        succ, _ = run(batch({lane: (atomics.SC, 0,
                                    np.full(k, lane, np.uint32))}),
                      f"lap sc{lane}")
        assert succ[lane], f"fresh link SC of lane {lane} must succeed"
    succ, _ = run(batch({0: (atomics.SC, 0, np.zeros(k, np.uint32))}),
                  "lap sc0")
    assert not succ[0], "lapped linker's SC must fail"


def scenario_overflow(strategy: str):
    """The all_to_all capacity contract: lanes beyond route_capacity per
    (src, dst) pair surface in the overflow mask with success=False and
    leave every shard's table byte-identical to the oracle that skips them
    — never silently dropped, never corrupting."""
    n, k, shards, pl, cap = 32, 2, 4, 8, 3
    mesh = _mesh(shards)
    dspec = dsb.DistSpec(atomics.AtomicSpec(n, k, strategy, p_max=64),
                         "shard", shards, pl, route_capacity=cap)
    p = dspec.p_global
    rng = np.random.default_rng(7)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    st = dsb.init_dist(mesh, dspec, init)
    oracle = TableOracle(n, k, p, initial=init)

    # All 8 lanes of src 0 hit shard 0 (slots 0..7), alternating STORE/LOAD;
    # srcs 1..3 send two lanes each to shard 0 (within cap) plus local ops.
    kind = np.full(p, atomics.IDLE, np.int32)
    slot = np.zeros(p, np.int32)
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    for lane in range(pl):
        kind[lane] = atomics.STORE if lane % 2 == 0 else atomics.LOAD
        slot[lane] = lane                      # owner shard 0
    for src in range(1, shards):
        base = src * pl
        kind[base] = atomics.STORE
        slot[base] = src                       # owner shard 0
        kind[base + 1] = atomics.LOAD
        slot[base + 1] = src + 8 * src         # spread
    ops = atomics.make_ops(kind, slot, desired=desired, k=k)

    order, ovf_ref = dsb.linearization_order(dspec, ops)
    # by construction: src 0's lanes 3..7 exceed cap=3 toward shard 0
    assert list(np.nonzero(ovf_ref)[0]) == [3, 4, 5, 6, 7]
    st, ctx, res, ovf = dsb.apply(mesh, dspec, st, ops)
    np.testing.assert_array_equal(np.asarray(ovf), ovf_ref)
    assert not np.asarray(res.success)[ovf_ref].any(), \
        "overflowed lanes must report success=False"
    # table state matches the oracle that executes ONLY the fitting lanes:
    # the overflowed STOREs (lanes 4, 6) left no trace anywhere.
    oracle.step_and_check(ops, result=res, logical=dsb.logical(dspec, st),
                          version=dsb.versions(dspec, st), order=order,
                          overflow=ovf_ref, msg="overflow contract")


def scenario_plugin(strategy_unused: str):
    """A strategy registered HERE (never imported by core/distributed.py)
    runs sharded unchanged — the registry is the only coupling."""

    class PlainCloneDist(atomics.StrategyImpl):
        name = "dist_plugin_check"

    atomics.register_strategy(PlainCloneDist(), overwrite=True)
    rng = np.random.default_rng(23)
    n, k, shards, pl = 24, 2, 4, 4
    dspec = dsb.DistSpec(atomics.AtomicSpec(n, k, "dist_plugin_check",
                                            p_max=32), "shard", shards, pl)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    _drive_table(
        dspec, _mesh(shards), rng, init, steps=3,
        make_ops=lambda rng, oracle: mixed_batch(
            rng, oracle.ctx, p=dspec.p_global, n=n, k=k,
            current=oracle.data),
        msg="plugin shards=4")


def scenario_hash(strategy: str):
    """Key-owner-routed sharded CacheHash vs the dict-model oracle over
    shard counts {2, 4, 8}, plus the capacity-overflow contract on a
    single hot key."""
    rng = np.random.default_rng(zlib.crc32(strategy.encode()) ^ 0x5A5A)
    for shards in SHARD_COUNTS:
        hs = atomics.HashSpec(64, vw=1, strategy=strategy, p_max=64)
        dspec = dsb.DistSpec(hs, "shard", shards, 6)
        mesh = _mesh(shards)
        st = dsb.init_dist(mesh, dspec)
        oracle = HashOracle(vw=1)
        for step in range(3):
            ops = hash_batch(rng, p=dspec.p_global, key_space=40, vw=1)
            order, ovf_ref = dsb.linearization_order(dspec, ops)
            st, res, ovf = dsb.apply_hash(mesh, dspec, st, ops)
            np.testing.assert_array_equal(
                np.asarray(ovf), ovf_ref,
                err_msg=f"hash shards={shards} step {step}: overflow")
            oracle.step_and_check(
                ops, result=res, items=dsb.hash_items(dspec, st),
                order=order, overflow=ovf_ref,
                msg=f"hash {strategy} shards={shards} step {step}")

    # hot-key overflow: every lane of src 0 inserts the SAME key with cap=2
    shards, pl, cap = 4, 6, 2
    hs = atomics.HashSpec(64, vw=1, strategy=strategy, p_max=64)
    dspec = dsb.DistSpec(hs, "shard", shards, pl, route_capacity=cap)
    mesh = _mesh(shards)
    st = dsb.init_dist(mesh, dspec)
    kind = np.full(dspec.p_global, atomics.IDLE, np.int32)
    kind[:pl] = atomics.INSERT
    keys = np.full(dspec.p_global, 12345, np.uint32)
    vals = np.arange(dspec.p_global, dtype=np.uint32)[:, None]
    from repro.core import cachehash as ch
    ops = ch.make_hash_ops(kind, keys, vals, vw=1)
    order, ovf_ref = dsb.linearization_order(dspec, ops)
    assert ovf_ref.sum() == pl - cap
    st, res, ovf = dsb.apply_hash(mesh, dspec, st, ops)
    np.testing.assert_array_equal(np.asarray(ovf), ovf_ref)
    assert not np.asarray(res.found)[ovf_ref].any()
    oracle = HashOracle(vw=1)
    oracle.step_and_check(ops, result=res, items=dsb.hash_items(dspec, st),
                          order=order, overflow=ovf_ref, msg="hash overflow")


def scenario_mcas(strategy: str):
    """Cross-shard MCAS (two-round prepare/commit collective) vs the
    TxnOracle replaying the claimed whole-transaction order, shard counts
    {2, 4, 8}, widths {1, 2, 3} — cross-shard transactions arise naturally
    (random slots over all shards' cells), plus an explicit one."""
    from repro.txn import mcas as txn_mcas

    rng = np.random.default_rng(zlib.crc32(strategy.encode()) ^ 0x7777)
    n, k = 24, 2
    for shards, w in zip(SHARD_COUNTS, (1, 2, 3)):
        mesh = _mesh(shards)
        dspec = dsb.DistSpec(atomics.AtomicSpec(n, k, strategy, p_max=64),
                             "shard", shards, 8)
        init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
        st = dsb.init_dist(mesh, dspec, init)
        oracle = TxnOracle(n, k, initial=init)
        for step in range(3):
            txns = txn_batch(rng, t=8, w=w, n=n, k=k, current=oracle.data)
            st, res = dsb.mcas(mesh, dspec, st, txns)
            oracle.step_and_check(
                txns, result=res, logical=dsb.logical(dspec, st),
                version=dsb.versions(dspec, st),
                msg=f"mcas {strategy} shards={shards} w={w} step {step}")

    # explicit cross-shard all-or-nothing: one txn spans all 4 shards and
    # one stale lane on the LAST shard aborts the whole thing.
    shards, w = 4, 4
    mesh = _mesh(shards)
    dspec = dsb.DistSpec(atomics.AtomicSpec(n, k, strategy, p_max=64),
                         "shard", shards, 8)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    st = dsb.init_dist(mesh, dspec, init)
    span = np.asarray([[0, 6, 12, 18]], np.int32)     # one cell per shard
    exp = init[span[0]][None].copy()
    exp[0, 3] += 1                                     # stale on shard 3
    txns = atomics.make_txns(span, exp,
                             np.full((1, w, k), 5, np.uint32), k=k)
    st, res = dsb.mcas(mesh, dspec, st, txns)
    assert not bool(np.asarray(res.success)[0])
    np.testing.assert_array_equal(np.asarray(dsb.logical(dspec, st)), init)
    # fix the comparand: the same txn commits on every shard at once
    txns = atomics.make_txns(span, init[span[0]][None],
                             np.full((1, w, k), 5, np.uint32), k=k)
    st, res = dsb.mcas(mesh, dspec, st, txns)
    assert bool(np.asarray(res.success)[0])
    got = np.asarray(dsb.logical(dspec, st))
    np.testing.assert_array_equal(got[span[0]], np.full((w, k), 5))


def scenario_txnmap(strategy: str):
    """Transactional map over the key-owner-routed sharded CacheHash:
    read/write sets spanning shards commit serializably (MapOracle),
    including the everyone-increments-one-counter conflict storm."""
    from repro.txn import map as txn_map

    def fn(rv, rf):
        return rv.sum(axis=1, keepdims=True) + 1

    rng = np.random.default_rng(zlib.crc32(strategy.encode()) ^ 0x3333)
    for shards in (2, 4):
        mesh = _mesh(shards)
        hs = atomics.HashSpec(64, vw=1, strategy=strategy, p_max=64)
        dspec = dsb.DistSpec(hs, "shard", shards, 4)
        st = dsb.init_dist(mesh, dspec)
        oracle = MapOracle(vw=1)
        t, r, w = 5, 2, 2
        for step in range(2):
            txns = txn_map.make_map_txns(
                rng.integers(0, 30, (t, r)).astype(np.uint32),
                np.stack([rng.choice(30, size=w, replace=False)
                          for _ in range(t)]).astype(np.uint32),
                read_mask=rng.random((t, r)) < 0.8,
                write_del=rng.random((t, w)) < 0.2)
            st, res = txn_map.transact_dist(mesh, dspec, st, txns,
                                            _map_fn_copy)
            oracle.step_and_check(
                txns, _map_fn_copy, result=res,
                items=dsb.hash_items(dspec, st),
                msg=f"txnmap {strategy} shards={shards} step {step}")
        # conflict storm: T txns increment one counter key serializably
        t = 4
        txns = txn_map.make_map_txns(np.full((t, 1), 17, np.uint32),
                                     np.full((t, 1), 17, np.uint32))
        st, res = txn_map.transact_dist(mesh, dspec, st, txns, fn)
        assert int(res.rounds) == t
        oracle.step_and_check(txns, fn, result=res,
                              items=dsb.hash_items(dspec, st),
                              msg=f"txnmap storm shards={shards}")
        assert oracle.model[17][0] == t


def _map_fn_copy(rv, rf):
    return rv


def scenario_txn_plugin(strategy_unused: str):
    """A strategy registered HERE runs cross-shard MCAS and the sharded
    transactional map unchanged (the txn layer is registry-dispatched all
    the way through the collective)."""
    from repro.txn import map as txn_map

    class PlainCloneTxnDist(atomics.StrategyImpl):
        name = "dist_txn_plugin_check"

    atomics.register_strategy(PlainCloneTxnDist(), overwrite=True)
    rng = np.random.default_rng(41)
    n, k, shards, w = 24, 2, 4, 2
    mesh = _mesh(shards)
    dspec = dsb.DistSpec(
        atomics.AtomicSpec(n, k, "dist_txn_plugin_check", p_max=64),
        "shard", shards, 8)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    st = dsb.init_dist(mesh, dspec, init)
    oracle = TxnOracle(n, k, initial=init)
    for step in range(2):
        txns = txn_batch(rng, t=8, w=w, n=n, k=k, current=oracle.data)
        st, res = dsb.mcas(mesh, dspec, st, txns)
        oracle.step_and_check(
            txns, result=res, logical=dsb.logical(dspec, st),
            version=dsb.versions(dspec, st),
            msg=f"txn plugin mcas step {step}")
    hs = atomics.HashSpec(64, vw=1, strategy="dist_txn_plugin_check",
                          p_max=64)
    hdspec = dsb.DistSpec(hs, "shard", shards, 4)
    hst = dsb.init_dist(mesh, hdspec)
    txns = txn_map.make_map_txns(np.full((3, 1), 8, np.uint32),
                                 np.full((3, 1), 8, np.uint32))

    def fn(rv, rf):
        return rv.sum(axis=1, keepdims=True) + 1

    hst, res = txn_map.transact_dist(mesh, hdspec, hst, txns, fn)
    MapOracle(vw=1).step_and_check(txns, fn, result=res,
                                   items=dsb.hash_items(hdspec, hst),
                                   msg="txn plugin map")


def scenario_serving(strategy: str):
    """The serving engine with a mesh: sharded page table + sharded
    admission/slot rings must produce tokens identical to the single-device
    engine (one fused program per decode step, executed per shard)."""
    import dataclasses

    from repro.configs import get_config
    from repro.models.transformer import init_params
    from repro.serving import Request, ServingEngine

    cfg = dataclasses.replace(get_config("deepseek_7b", reduced=True),
                              param_dtype="float32",
                              compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 9).astype(np.int32),
               rng.integers(0, cfg.vocab, 5).astype(np.int32)]
    n_new = 3

    def serve(mesh):
        eng = ServingEngine(cfg, params, max_batch=2, n_pages=16,
                            page_size=4, max_pages_per_seq=4,
                            strategy=strategy, mesh=mesh)
        for rid, prompt in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=prompt,
                               max_new_tokens=n_new))
        out = eng.run_to_completion(max_steps=40)
        # both slots decode together: n_new - 1 fused steps, 1 dispatch each
        assert eng.dispatch_count == n_new - 1, eng.dispatch_count
        return out

    want = serve(None)
    got = serve(_mesh(2))
    assert got == want, (got, want)
    assert all(len(v) == n_new for v in got.values())


def scenario_twolevel(strategy: str):
    """Hierarchical two-level routing (DistSpec.n_nodes > 1): intra-node
    combine over `axis` then ONE cross-node all_to_all over `node_axis`,
    replayed against the shared oracle over interleave × capacity
    variants (the tight caps force overflow at BOTH hops)."""
    rng = np.random.default_rng(zlib.crc32(strategy.encode()) ^ 0x2E11)
    n, k, pl = 48, 3, 4
    mesh = jax.make_mesh((2, 4), ("node", "shard"))
    for ilv in (False, True):
        for caps in ({}, dict(route_capacity=3, node_capacity=5)):
            dspec = dsb.DistSpec(
                atomics.AtomicSpec(n, k, strategy, p_max=64), "shard", 8,
                pl, n_nodes=2, node_axis="node", interleave=ilv, **caps)
            init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
            _drive_table(
                dspec, mesh, rng, init, steps=3,
                make_ops=lambda rng, oracle: mixed_batch(
                    rng, oracle.ctx, p=dspec.p_global, n=n, k=k,
                    current=oracle.data),
                msg=f"2level ilv={ilv} capped={bool(caps)}")


def scenario_executor(strategy: str):
    """The oversubscribed executor (ISSUE 7 acceptance): S in {2, 4, 8}
    streams share one 8-shard table with in-flight budget 4; a shard loss
    injected MID-ROUND forces checkpoint-restore + reshard onto the
    survivors + journal replay, and the full multi-stream history —
    including across the recovery boundary — must replay through ONE
    sequential oracle."""
    from oracle import replay_executor_history
    from repro.runtime import (DistTarget, Executor, Fault, FaultInjector,
                               StragglerWatchdog, SyntheticStream)

    n, k = 32, 2

    def factory(n_surviving):
        s = 1
        while s * 2 <= n_surviving and n % (s * 2) == 0:
            s *= 2
        mesh = jax.make_mesh((s, 8 // s), ("shard", "rest"))
        return mesh, dsb.DistSpec(
            atomics.AtomicSpec(n, k, strategy, p_max=64), "shard", s,
            32 // s)

    rng = np.random.default_rng(zlib.crc32(strategy.encode()) ^ 0xE7)
    for n_streams in (2, 4, 8):
        init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
        mesh0, dspec0 = factory(8)
        target = DistTarget(mesh0, dspec0, init, mesh_factory=factory)
        width = dspec0.p_global
        streams = [SyntheticStream(f"s{i}", seed=1000 + 10 * n_streams + i,
                                   n=n, k=k, width=width, n_batches=3,
                                   hot_cells=4, hot_frac=0.3)
                   for i in range(n_streams)]
        inj = FaultInjector([Fault(round=2, kind="shard_loss", shard=5,
                                   after_issues=1)])
        ex = Executor(target, streams, slots=1, oversubscription=4,
                      watchdog=StragglerWatchdog(n_hosts=n_streams),
                      injector=inj, checkpoint_every=2)
        rep = ex.run()
        assert rep["recoveries"], rep
        assert rep["recoveries"][0]["n_shards"] < 8
        assert target.dspec.p_global == width      # lane layout preserved
        oracle = replay_executor_history(n, k, [width] * n_streams,
                                         ex.history, initial=init)
        np.testing.assert_array_equal(
            oracle.data, np.asarray(dsb.logical(target.dspec, target.state)),
            err_msg=f"executor S={n_streams}: final logical")
        np.testing.assert_array_equal(
            oracle.version,
            np.asarray(dsb.versions(target.dspec, target.state)),
            err_msg=f"executor S={n_streams}: final versions")


def scenario_elastic(strategy: str):
    """Elastic round-trips on the 8-device fixture.  (a) The big-atomic
    table reshards 8 -> 6 -> 4 -> 8 with logical values AND versions
    preserved at every hop — an LL link taken BEFORE the trip commits
    after it.  (b) The (params, opt) training state reshards through the
    same shrink/grow chain bit-identically, with `mesh_plan` reporting
    (never silently truncating) the devices each geometry drops."""
    from jax.sharding import Mesh
    from repro.runtime import elastic_mesh, mesh_plan, reshard_dist, \
        reshard_state

    n, k = 48, 2
    rng = np.random.default_rng(11)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)

    def geo(s):
        mesh = Mesh(np.asarray(jax.devices()[:s]), ("shard",))
        return mesh, dsb.DistSpec(
            atomics.AtomicSpec(n, k, strategy, p_max=64), "shard", s, 8)

    mesh, dspec = geo(8)
    st = dsb.init_dist(mesh, dspec, init)
    ctx = dsb.init_dist_ctx(mesh, dspec)
    # lane 0 links cell 5; lane 1 bumps cell 7 so versions are non-trivial
    kind = np.full(dspec.p_global, atomics.IDLE, np.int32)
    slot = np.zeros(dspec.p_global, np.int32)
    desired = np.zeros((dspec.p_global, k), np.uint32)
    kind[0], slot[0] = atomics.LL, 5
    kind[1], slot[1], desired[1] = atomics.STORE, 7, 77
    st, ctx, _, _ = dsb.apply(mesh, dspec, st,
                              atomics.make_ops(kind, slot, desired=desired,
                                               k=k), ctx)
    vals = np.asarray(dsb.logical(dspec, st))
    vers = np.asarray(dsb.versions(dspec, st))
    assert vers[7] == 2 and vers.sum() == 2
    for s in (6, 4, 8):
        mesh2, dspec2 = geo(s)
        st = reshard_dist(dspec, st, dspec2, mesh2)
        mesh, dspec = mesh2, dspec2
        np.testing.assert_array_equal(np.asarray(dsb.logical(dspec, st)),
                                      vals, err_msg=f"reshard->{s}: values")
        np.testing.assert_array_equal(np.asarray(dsb.versions(dspec, st)),
                                      vers, err_msg=f"reshard->{s}: versions")
    # versions survived the whole trip, so the pre-trip link commits
    kind = np.full(dspec.p_global, atomics.IDLE, np.int32)
    kind[0], slot[0], desired[0] = atomics.SC, 5, 55
    st, ctx, res, _ = dsb.apply(mesh, dspec, st,
                                atomics.make_ops(kind, slot,
                                                 desired=desired, k=k), ctx)
    assert bool(np.asarray(res.success)[0]), \
        "LL link must survive the 8->6->4->8 reshard round-trip"
    assert (np.asarray(dsb.logical(dspec, st))[5] == 55).all()

    # (b) training state through the same chain
    from repro.configs import get_config
    from repro.launch.steps import init_train_state
    from repro.optim import AdamWConfig

    cfg = get_config("deepseek_7b", reduced=True)
    params, opt = init_train_state(cfg, AdamWConfig(warmup=1, total_steps=2),
                                   0)
    want = [np.asarray(x) for x in jax.tree.leaves(params)]
    assert mesh_plan(6, model_parallel=2, global_batch=2).dropped == 4
    assert mesh_plan(6, model_parallel=2).dropped == 0
    for n_dev in (8, 6, 4, 8):
        m = elastic_mesh(n_dev, model_parallel=2, global_batch=2)
        params, opt = reshard_state((params, opt), cfg, m)
    got = [np.asarray(x) for x in jax.tree.leaves(params)]
    for a, b in zip(want, got):
        np.testing.assert_array_equal(a, b)
    lead = jax.tree.leaves(params)[1]
    assert len(lead.sharding.device_set) in (2, 4, 8)


SCENARIOS = {
    "mixed": scenario_mixed,
    "levers": scenario_levers,
    "sync_adversary": scenario_sync_adversary,
    "overflow": scenario_overflow,
    "plugin": scenario_plugin,
    "hash": scenario_hash,
    "serving": scenario_serving,
    "mcas": scenario_mcas,
    "txnmap": scenario_txnmap,
    "txn_plugin": scenario_txn_plugin,
    "twolevel": scenario_twolevel,
    "executor": scenario_executor,
    "elastic": scenario_elastic,
}


def main(argv):
    scenario = argv[1]
    strategy = argv[2] if len(argv) > 2 else \
        atomics.DEFAULT_STRATEGY
    SCENARIOS[scenario](strategy)
    print(f"DIST_OK:{scenario}")


if __name__ == "__main__":
    main(sys.argv)
