"""The §Perf optimization levers must not change semantics:
  loss_chunk     — chunked CE == monolithic CE (exact math, fp32);
  score_dtype    — bf16 scores stay close to f32 scores;
  moe_groups     — grouped dispatch == global dispatch when capacity is
                   loose enough that neither drops tokens.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import forward, init_params, lm_loss


def test_loss_chunk_matches_monolithic():
    cfg = dataclasses.replace(get_config("deepseek_7b", reduced=True),
                              param_dtype="float32", compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = np.random.default_rng(0).integers(0, cfg.vocab, (2, 96))
    batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    l0 = float(lm_loss(params, cfg, batch))
    for nc in (2, 4):                       # incl. ragged 95 % 4 != 0
        lc = float(lm_loss(params,
                           dataclasses.replace(cfg, loss_chunk=nc), batch))
        np.testing.assert_allclose(lc, l0, rtol=1e-5)


def test_loss_chunk_gradients_match():
    cfg = dataclasses.replace(get_config("deepseek_7b", reduced=True),
                              param_dtype="float32", compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(1))
    toks = np.random.default_rng(1).integers(0, cfg.vocab, (2, 64))
    batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    g0 = jax.grad(lambda p: lm_loss(p, cfg, batch))(params)
    g1 = jax.grad(lambda p: lm_loss(
        p, dataclasses.replace(cfg, loss_chunk=4), batch))(params)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_score_dtype_bf16_close():
    cfg = dataclasses.replace(get_config("deepseek_7b", reduced=True),
                              param_dtype="float32", compute_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = np.random.default_rng(0).integers(0, cfg.vocab, (2, 64))
    batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    lo0, _, _ = forward(params, cfg, batch)
    lo1, _, _ = forward(
        params, dataclasses.replace(cfg, score_dtype="bfloat16"), batch)
    a, b = np.asarray(lo0, np.float32), np.asarray(lo1, np.float32)
    assert np.abs(a - b).max() < 0.15, np.abs(a - b).max()
    assert (np.argmax(a, -1) == np.argmax(b, -1)).mean() > 0.97


def test_moe_groups_match_global_dispatch():
    cfg = get_config("mixtral_8x7b", reduced=True)
    cfg = dataclasses.replace(cfg, param_dtype="float32",
                              compute_dtype="float32",
                              moe_dropless=False, capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(2))
    toks = np.random.default_rng(2).integers(0, cfg.vocab, (2, 64))
    batch = {"tokens": jnp.asarray(toks, jnp.int32)}
    l0, _, _ = forward(params, cfg, batch)
    l1, _, _ = forward(params, dataclasses.replace(cfg, moe_groups=4), batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=3e-4, atol=3e-4)
