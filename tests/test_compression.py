"""Gradient compression: roundtrip accuracy, error feedback, and robustness
to real parameter trees (tuple containers)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.transformer import init_params
from repro.optim.compression import compress_grads, decompress_grads


def test_bf16_roundtrip_and_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    comped, res, meta = compress_grads(g, None, "bf16")
    deq = decompress_grads(comped, meta)
    assert jax.tree.leaves(comped)[0].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(deq["w"]), np.asarray(g["w"]),
                               rtol=1e-2, atol=1e-2)
    # error feedback: residual + dequantized == exact gradient
    np.testing.assert_allclose(
        np.asarray(deq["w"]) + np.asarray(res["w"]), np.asarray(g["w"]),
        rtol=1e-6, atol=1e-6)


def test_int8_error_feedback_accumulates():
    """Constant gradient compressed over N steps: the SUM of dequantized
    values converges to N x gradient (no systematic bias)."""
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)}
    res = None
    total = np.zeros((32, 32), np.float32)
    N = 8
    for _ in range(N):
        comped, res, meta = compress_grads(g, res, "int8")
        assert jax.tree.leaves(comped)[0].dtype == jnp.int8
        total += np.asarray(decompress_grads(comped, meta)["w"])
    np.testing.assert_allclose(total / N, np.asarray(g["w"]),
                               rtol=2e-2, atol=2e-2)


def test_compression_on_real_param_tree():
    """Param trees contain tuple CONTAINERS (layer tuples) — compression
    must not mistake them for leaves."""
    cfg = get_config("recurrentgemma_9b", reduced=True)   # tuple-rich tree
    params = init_params(cfg, jax.random.PRNGKey(0))
    grads = jax.tree.map(lambda p: jnp.ones_like(p, jnp.float32), params)
    for mode in ("bf16", "int8"):
        comped, res, meta = compress_grads(grads, None, mode)
        deq = decompress_grads(comped, meta)
        assert jax.tree.structure(deq) == jax.tree.structure(grads)
        for a, b in zip(jax.tree.leaves(deq), jax.tree.leaves(grads)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-2, atol=2e-2)


def test_train_step_with_compression_runs():
    from repro.configs.shapes import Shape
    from repro.data import synthetic_batch
    from repro.launch.steps import init_train_state, make_train_step
    from repro.optim import AdamWConfig

    cfg = get_config("deepseek_7b", reduced=True)
    shape = Shape("t", 64, 2, "train")
    opt_cfg = AdamWConfig(warmup=1, total_steps=4)
    params, opt = init_train_state(cfg, opt_cfg, 0)
    for mode in ("bf16", "int8"):
        step = jax.jit(make_train_step(cfg, opt_cfg, mode))
        p2, o2, m = step(params, opt, synthetic_batch(cfg, shape, seed=0,
                                                      step=0))
        assert np.isfinite(float(m["loss"]))
