"""Per-kernel interpret-mode validation against the pure-jnp/numpy oracles:
shape/dtype sweeps + hypothesis property tests (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.cachehash_probe import FULL, cachehash_probe
from repro.kernels.cas_apply import CAS, STORE, cas_apply_round
from repro.kernels.seqlock_gather import seqlock_gather

RNG = np.random.default_rng(0)


def make_table(n, k, seed=0):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 2**32, (n, k), dtype=np.uint32)
    meta = np.zeros((n, 2), np.uint32)
    meta[:, 0] = rng.integers(0, 8, n) * 2          # even versions
    return jnp.asarray(data), jnp.asarray(meta)


# ---------------------------------------------------------------------------
# seqlock_gather
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,q", [(8, 4, 5), (64, 8, 64), (128, 128, 32),
                                   (16, 16, 100), (1024, 32, 7)])
def test_seqlock_gather_matches_ref(n, k, q):
    data, meta = make_table(n, k)
    idx = jnp.asarray(RNG.integers(0, n, q), jnp.int32)
    vals, ok = seqlock_gather(data, meta, idx, interpret=True)
    rvals, rok = ref.seqlock_gather_ref(data, meta, idx)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rvals))
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(rok))


def test_seqlock_gather_detects_locked_and_marked():
    data, meta = make_table(32, 8)
    meta = meta.at[3, 0].add(jnp.uint32(1))          # odd version = locked
    meta = meta.at[7, 1].set(jnp.uint32(1))          # marked = cache invalid
    idx = jnp.asarray([3, 7, 1], jnp.int32)
    _, ok = seqlock_gather(data, meta, idx, interpret=True)
    np.testing.assert_array_equal(np.asarray(ok[:, 0]), [0, 0, 1])


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 64), k=st.integers(1, 16), q=st.integers(1, 32),
       seed=st.integers(0, 2**31))
def test_seqlock_gather_property(n, k, q, seed):
    data, meta = make_table(n, k, seed)
    rng = np.random.default_rng(seed + 1)
    meta = meta.at[:, 0].set(jnp.asarray(
        rng.integers(0, 16, n).astype(np.uint32)))   # mixed parity
    meta = meta.at[:, 1].set(jnp.asarray(
        (rng.random(n) < 0.3).astype(np.uint32)))
    idx = jnp.asarray(rng.integers(0, n, q), jnp.int32)
    vals, ok = seqlock_gather(data, meta, idx, interpret=True)
    rvals, rok = ref.seqlock_gather_ref(data, meta, idx)
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rvals))
    np.testing.assert_array_equal(np.asarray(ok), np.asarray(rok))


# ---------------------------------------------------------------------------
# cas_apply_round
# ---------------------------------------------------------------------------

def _round_inputs(n, k, p, seed, live_frac=0.8):
    """Distinct live slots (round invariant) + dummy-row dead lanes."""
    rng = np.random.default_rng(seed)
    n_live = min(int(p * live_frac) + 1, n, p)
    slots = np.full(p, n, np.int32)                 # dummy row n
    slots[:n_live] = rng.choice(n, n_live, replace=False)
    kind = np.zeros(p, np.int32)
    kind[:n_live] = rng.choice([STORE, CAS], n_live)
    expected = rng.integers(0, 2**32, (p, k), dtype=np.uint32)
    desired = rng.integers(0, 2**32, (p, k), dtype=np.uint32)
    return slots, kind, expected, desired, n_live


@pytest.mark.parametrize("n,k,p", [(8, 4, 6), (64, 8, 32), (32, 128, 16),
                                   (128, 16, 64)])
def test_cas_apply_round_matches_ref(n, k, p):
    data, meta = make_table(n + 1, k)                # +1 dummy row
    slots, kind, expected, desired, n_live = _round_inputs(n, k, p, seed=n + p)
    # make some CASes succeed: expected := current value
    cur = np.asarray(data)
    for i in range(0, n_live, 2):
        expected[i] = cur[slots[i]]
    args = (jnp.asarray(slots), jnp.asarray(kind), jnp.asarray(expected),
            jnp.asarray(desired))
    d1, m1, s1, w1 = cas_apply_round(data, meta, *args, interpret=True)
    d2, m2, s2, w2 = ref.cas_apply_round_ref(data, meta, *args)
    np.testing.assert_array_equal(np.asarray(d1)[:n], np.asarray(d2)[:n])
    np.testing.assert_array_equal(np.asarray(m1)[:n], np.asarray(m2)[:n])
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    live = kind != 0
    np.testing.assert_array_equal(np.asarray(w1)[live], np.asarray(w2)[live])


@settings(max_examples=15, deadline=None)
@given(n=st.integers(4, 32), k=st.integers(1, 8), p=st.integers(1, 16),
       seed=st.integers(0, 2**31))
def test_cas_apply_round_property(n, k, p, seed):
    data, meta = make_table(n + 1, k, seed)
    slots, kind, expected, desired, n_live = _round_inputs(n, k, p, seed)
    cur = np.asarray(data)
    rng = np.random.default_rng(seed + 2)
    for i in range(n_live):
        if rng.random() < 0.5:
            expected[i] = cur[slots[i]]
    args = (jnp.asarray(slots), jnp.asarray(kind), jnp.asarray(expected),
            jnp.asarray(desired))
    d1, m1, s1, w1 = cas_apply_round(data, meta, *args, interpret=True)
    d2, m2, s2, w2 = ref.cas_apply_round_ref(data, meta, *args)
    np.testing.assert_array_equal(np.asarray(d1)[:n], np.asarray(d2)[:n])
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    # versions advance by exactly 2 per success, stay even
    assert (np.asarray(m1)[:n, 0] % 2 == 0).all()


def test_cas_version_parity_advances():
    n, k, p = 16, 4, 8
    data, meta = make_table(n + 1, k)
    slots = np.arange(p, dtype=np.int32)
    kind = np.full(p, STORE, np.int32)
    desired = np.ones((p, k), np.uint32)
    expected = np.zeros((p, k), np.uint32)
    _, m1, s1, _ = cas_apply_round(
        data, meta, jnp.asarray(slots), jnp.asarray(kind),
        jnp.asarray(expected), jnp.asarray(desired), interpret=True)
    assert (np.asarray(s1)[:, 0] == 1).all()
    np.testing.assert_array_equal(np.asarray(m1)[:p, 0],
                                  np.asarray(meta)[:p, 0] + 2)


# ---------------------------------------------------------------------------
# cachehash_probe
# ---------------------------------------------------------------------------

def make_cachehash(m, kw, vw, fill=0.6, seed=0):
    """Bucket array: [key | value | next | flags | version | pad]."""
    rng = np.random.default_rng(seed)
    cw = kw + vw + 3
    cells = np.zeros((m, cw), np.uint32)
    keys = []
    for b in range(m):
        if rng.random() < fill:
            key = rng.integers(1, 2**32, kw, dtype=np.uint32)
            val = rng.integers(0, 2**32, vw, dtype=np.uint32)
            cells[b, :kw] = key
            cells[b, kw:kw + vw] = val
            cells[b, kw + vw] = np.uint32(2**32 - 1)   # next = -1 (no chain)
            cells[b, kw + vw + 1] = FULL
            keys.append((b, key, val))
    return jnp.asarray(cells), keys


@pytest.mark.parametrize("m,kw,vw,q", [(16, 1, 1, 8), (64, 2, 4, 32),
                                       (128, 4, 2, 64), (32, 8, 8, 16)])
def test_cachehash_probe_matches_ref(m, kw, vw, q):
    cells, keys = make_cachehash(m, kw, vw)
    rng = np.random.default_rng(1)
    bidx = rng.integers(0, m, q).astype(np.int32)
    qkeys = rng.integers(0, 2**32, (q, kw), dtype=np.uint32)
    # half the queries probe the true key of their bucket
    for i in range(0, q, 2):
        c = np.asarray(cells)[bidx[i]]
        qkeys[i] = c[:kw]
    out = cachehash_probe(cells, jnp.asarray(bidx), jnp.asarray(qkeys),
                          kw=kw, vw=vw, interpret=True)
    refout = ref.cachehash_probe_ref(cells, jnp.asarray(bidx),
                                     jnp.asarray(qkeys), kw=kw, vw=vw)
    for a, b in zip(out, refout):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cachehash_find_end_to_end():
    """Kernel probe + chain walk finds inline hits, chain hits and misses."""
    m, kw, vw = 32, 2, 2
    cells, keys = make_cachehash(m, kw, vw, fill=0.8, seed=3)
    # build a chain node behind bucket of keys[0]
    b0, k0, v0 = keys[0]
    pool = np.zeros((4, kw + vw + 3), np.uint32)
    ck = np.asarray([123, 456], np.uint32)
    cv = np.asarray([7, 8], np.uint32)
    pool[0, :kw] = ck
    pool[0, kw:kw + vw] = cv
    pool[0, kw + vw] = np.uint32(2**32 - 1)
    pool[0, kw + vw + 1] = FULL
    cells = cells.at[b0, kw + vw].set(jnp.uint32(0))   # bucket -> node 0
    # force the hash of all queries to their buckets by querying via ops.hash
    qk = jnp.asarray(np.stack([np.asarray(k0), ck,
                               np.asarray([9, 9], np.uint32)]))
    bidx = ops.hash_keys(qk, m)
    # plant the inline/chain entries at the hashed buckets
    cells = cells.at[bidx[0], :kw].set(qk[0])
    cells = cells.at[bidx[0], kw + vw + 1].set(FULL)
    cells = cells.at[bidx[1], kw + vw].set(jnp.uint32(0))
    cells = cells.at[bidx[1], kw + vw + 1].set(FULL)
    cells = cells.at[bidx[2], kw + vw + 1].set(0)      # miss: empty bucket
    # bucket for ck must NOT inline-match ck
    cells = cells.at[bidx[1], :kw].set(jnp.uint32(1))
    found, vals = ops.cachehash_find(cells, jnp.asarray(pool), qk,
                                     kw=kw, vw=vw, interpret=True)
    found = np.asarray(found)
    assert found[0] and found[1] and not found[2]
    np.testing.assert_array_equal(np.asarray(vals)[1], cv)


# ---------------------------------------------------------------------------
# ops-layer integration: multi-round update path vs core semantics oracle
# ---------------------------------------------------------------------------

def test_update_rounds_vs_semantics_oracle():
    from repro.core import semantics as sem
    n, k, p = 16, 4, 24
    rng = np.random.default_rng(5)
    data0 = rng.integers(0, 2**32, (n, k), dtype=np.uint32)
    ops_b = sem.random_batch(rng, p=p, n=n, k=k, update_frac=1.0,
                             current=data0)
    # sort by slot, compute ranks (mirror of semantics.apply_batch)
    slot = np.asarray(ops_b.slot)
    kind = np.asarray(ops_b.kind)
    order = np.argsort(slot, kind="stable")
    s_slot, s_kind = slot[order], kind[order]
    s_exp = np.asarray(ops_b.expected)[order]
    s_des = np.asarray(ops_b.desired)[order]
    rank = np.zeros(p, np.int32)
    counts: dict = {}
    for i in range(p):
        rank[i] = counts.get(s_slot[i], 0)
        counts[s_slot[i]] = rank[i] + 1
    rounds = int(rank.max()) + 1

    data = jnp.asarray(np.vstack([data0, np.zeros((1, k), np.uint32)]))
    meta = jnp.zeros((n + 1, 2), jnp.uint32)
    d1, m1, succ, wit = ops.bigatomic_update_rounds(
        data, meta, jnp.asarray(s_slot), jnp.asarray(s_kind),
        jnp.asarray(s_exp), jnp.asarray(s_des), rounds,
        jnp.asarray(rank), interpret=True)

    ref_data, ref_ver, res = sem.apply_batch_reference(
        data0, np.zeros(n, np.uint32), ops_b)
    np.testing.assert_array_equal(np.asarray(d1)[:n], ref_data)
    np.testing.assert_array_equal(np.asarray(m1)[:n, 0], ref_ver)
    inv = np.argsort(order, kind="stable")
    np.testing.assert_array_equal(np.asarray(succ)[inv],
                                  np.asarray(res.success).astype(np.int32))
