"""THE shared linearizability harness (ISSUE 3 satellite).

One sequential-replay oracle for every big-atomic surface: given a spec, a
stream of op batches and a CLAIMED linearization order per batch, it replays
the ops one at a time through the repo's defining references
(`engine.apply_ops_reference` for tables, `cachehash.apply_reference` for
hash tables) and diffs the live system's results, values, versions and link
state against the replay.  It replaces the three historical copies of this
logic (tests/test_llsc.py, tests/test_atomics_v2.py and the inline reorder
check in core/distributed.py's v1 `reference_apply`).

Claimed orders: single-node `atomics.apply` linearizes in lane order (the
default); the mesh-sharded layer linearizes in the (owner, src, rank) order
that `distributed.linearization_order` emits, with capacity-rejected lanes
excluded.  Lanes absent from the order are DROPPED: they must have no table
effect and report success=False.
"""

from __future__ import annotations

import numpy as np

from repro import atomics
from repro.core import cachehash as ch
from repro.core import engine


def _np_ctx(ctx) -> engine.LinkCtx:
    return engine.LinkCtx(*[np.array(x, copy=True) for x in ctx])


class TableOracle:
    """Sequential oracle for a k-word big-atomic table + per-lane links."""

    def __init__(self, n: int, k: int, p: int,
                 initial: np.ndarray | None = None):
        self.n, self.k, self.p = n, k, p
        self.data = np.zeros((n, k), np.uint32) if initial is None \
            else np.array(initial, np.uint32)
        self.version = np.zeros((n,), np.uint32)
        self.ctx = engine.LinkCtx(
            np.full((p,), -1, np.int32), np.zeros((p,), np.uint32),
            np.zeros((p, k), np.uint32), np.zeros((p,), bool))

    def step(self, ops: engine.OpBatch, order=None) -> engine.ApplyResult:
        """Replay one batch in the claimed linearization `order` (executed
        lane ids; default = lane order).  Dropped lanes (absent from the
        order) leave no trace and report success=False / zero values.
        Returns the reference ApplyResult in lane order (numpy)."""
        kind = np.asarray(ops.kind)
        slot = np.asarray(ops.slot)
        expected = np.asarray(ops.expected)
        desired = np.asarray(ops.desired)
        if kind.shape[0] != self.p:
            raise ValueError(f"batch width {kind.shape[0]} != p {self.p}")
        order = np.arange(self.p) if order is None \
            else np.asarray(order, np.int64)
        sub = engine.OpBatch(kind[order], slot[order], expected[order],
                             desired[order])
        sub_ctx = engine.LinkCtx(*[np.asarray(x)[order] for x in self.ctx])
        data, ver, nctx, res = engine.apply_ops_reference(
            self.data, self.version, sub_ctx, sub)
        self.data, self.version = data, ver
        merged = _np_ctx(self.ctx)
        for field, rows in zip(engine.LinkCtx._fields, nctx):
            getattr(merged, field)[order] = np.asarray(rows)
        self.ctx = merged
        value = np.zeros((self.p, self.k), np.uint32)
        success = np.zeros((self.p,), bool)
        value[order] = np.asarray(res.value)
        success[order] = np.asarray(res.success)
        return engine.ApplyResult(value, success)

    # -- diffing -------------------------------------------------------------

    def check(self, *, result=None, ref=None, logical=None, version=None,
              ctx=None, overflow=None, msg: str = "") -> None:
        """Diff the live system against the replayed reference.

        result/ref:  live vs reference ApplyResult (values + success);
        logical:     live global logical values (must equal replayed data);
        version:     live global cell versions;
        ctx:         live per-lane LinkCtx;
        overflow:    bool[p] mask of capacity-rejected lanes — these must
                     report success=False (the reported-not-dropped contract).
        """
        if logical is not None:
            np.testing.assert_array_equal(np.asarray(logical), self.data,
                                          err_msg=f"{msg}: logical data")
        if version is not None:
            np.testing.assert_array_equal(np.asarray(version), self.version,
                                          err_msg=f"{msg}: versions")
        if result is not None:
            assert ref is not None, "pass ref= (the value step() returned)"
            np.testing.assert_array_equal(np.asarray(result.value), ref.value,
                                          err_msg=f"{msg}: result values")
            np.testing.assert_array_equal(np.asarray(result.success),
                                          ref.success,
                                          err_msg=f"{msg}: result success")
            if overflow is not None:
                assert not np.asarray(result.success)[overflow].any(), \
                    f"{msg}: overflow lanes must report success=False"
        if ctx is not None:
            for name, live, want in zip(engine.LinkCtx._fields, ctx,
                                        self.ctx):
                np.testing.assert_array_equal(np.asarray(live),
                                              np.asarray(want),
                                              err_msg=f"{msg}: ctx.{name}")

    def step_and_check(self, ops, *, result=None, logical=None, version=None,
                       ctx=None, order=None, overflow=None, msg: str = ""):
        """step() + check() in one call; returns the reference result."""
        ref = self.step(ops, order)
        self.check(result=result, ref=ref, logical=logical, version=version,
                   ctx=ctx, overflow=overflow, msg=msg)
        return ref


class HashOracle:
    """Sequential dict-model oracle for CacheHash FIND/INSERT/DELETE."""

    def __init__(self, vw: int = 1):
        self.vw = vw
        self.model: dict = {}

    def step(self, ops: engine.OpBatch, order=None) -> ch.HashResult:
        kind = np.asarray(ops.kind)
        p = kind.shape[0]
        order = np.arange(p) if order is None else np.asarray(order, np.int64)
        sub = engine.OpBatch(
            kind[order], np.asarray(ops.slot)[order],
            np.asarray(ops.expected)[order], np.asarray(ops.desired)[order])
        self.model, res = ch.apply_reference(self.model, sub, self.vw)
        found = np.zeros((p,), bool)
        value = np.zeros((p, self.vw), np.uint32)
        found[order] = np.asarray(res.found)
        value[order] = np.asarray(res.value)
        return ch.HashResult(found, value, np.zeros((p,), bool))

    def check(self, *, result=None, ref=None, items=None, overflow=None,
              msg: str = "") -> None:
        if result is not None:
            assert ref is not None
            np.testing.assert_array_equal(np.asarray(result.found), ref.found,
                                          err_msg=f"{msg}: found")
            np.testing.assert_array_equal(np.asarray(result.value), ref.value,
                                          err_msg=f"{msg}: values")
            if overflow is not None:
                assert not np.asarray(result.found)[overflow].any(), \
                    f"{msg}: overflow lanes must report found=False"
        if items is not None:
            want = {k: list(np.ravel(v)) for k, v in self.model.items()}
            got = {k: list(np.ravel(v)) for k, v in items.items()}
            assert got == want, f"{msg}: table contents diverge"

    def step_and_check(self, ops, *, result=None, items=None, order=None,
                       overflow=None, msg: str = ""):
        ref = self.step(ops, order)
        self.check(result=result, ref=ref, items=items, overflow=overflow,
                   msg=msg)
        return ref


class TxnOracle:
    """Sequential whole-transaction oracle for k-word MCAS (ISSUE 4).

    Replays CLAIMED linearization orders of entire transactions — each one
    all-or-nothing, including aborted txns (which must leave no trace but
    still witness a consistent read of every claimed cell) — through
    `txn.mcas.mcas_reference`, and diffs the live system's success masks,
    witnesses, logical values and versions against the replay."""

    def __init__(self, n: int, k: int, initial: np.ndarray | None = None):
        self.n, self.k = n, k
        self.data = np.zeros((n, k), np.uint32) if initial is None \
            else np.array(initial, np.uint32)
        self.version = np.zeros((n,), np.uint32)

    def step(self, txns, order=None):
        """Replay one txn batch in the claimed `order` (default: txn id
        order).  Returns (success[T], witness[T, W, k]) as numpy."""
        from repro.txn import mcas as txn_mcas
        if order is None:
            order = np.arange(np.asarray(txns.slot).shape[0])
        self.data, self.version, success, witness = \
            txn_mcas.mcas_reference(self.data, self.version, txns, order)
        return success, witness

    def check(self, *, result=None, ref=None, logical=None, version=None,
              msg: str = "") -> None:
        if logical is not None:
            np.testing.assert_array_equal(np.asarray(logical), self.data,
                                          err_msg=f"{msg}: logical data")
        if version is not None:
            np.testing.assert_array_equal(np.asarray(version), self.version,
                                          err_msg=f"{msg}: versions")
        if result is not None:
            assert ref is not None, "pass ref= (the value step() returned)"
            ref_success, ref_witness = ref
            np.testing.assert_array_equal(np.asarray(result.success),
                                          ref_success,
                                          err_msg=f"{msg}: txn success")
            np.testing.assert_array_equal(np.asarray(result.witness),
                                          ref_witness,
                                          err_msg=f"{msg}: txn witness")

    def step_and_check(self, txns, *, result=None, logical=None,
                       version=None, order=None, msg: str = ""):
        """step() + check() in one call; `order` defaults to the claimed
        order the live result encodes.  Returns the reference tuple."""
        from repro.txn import mcas as txn_mcas
        if order is None and result is not None:
            order = txn_mcas.linearization_order(result)
        ref = self.step(txns, order)
        self.check(result=result, ref=ref, logical=logical, version=version,
                   msg=msg)
        return ref


class MapOracle:
    """Sequential dict-model oracle for the transactional map: replays
    whole read-set/write-set transactions in the claimed serialization."""

    def __init__(self, vw: int = 1):
        self.vw = vw
        self.model: dict = {}

    def step(self, txns, fn, order=None):
        from repro.txn import map as txn_map
        if order is None:
            order = np.arange(txns.t)
        self.model, rv, rf = txn_map.transact_reference(
            self.model, txns, fn, order, self.vw)
        return rv, rf

    def check(self, *, result=None, ref=None, items=None,
              msg: str = "") -> None:
        if result is not None:
            assert ref is not None
            rv, rf = ref
            np.testing.assert_array_equal(np.asarray(result.read_found), rf,
                                          err_msg=f"{msg}: read_found")
            np.testing.assert_array_equal(np.asarray(result.read_value), rv,
                                          err_msg=f"{msg}: read_value")
        if items is not None:
            want = {k: list(np.ravel(v)) for k, v in self.model.items()}
            got = {k: list(np.ravel(v)) for k, v in items.items()}
            assert got == want, f"{msg}: table contents diverge"

    def step_and_check(self, txns, fn, *, result=None, items=None,
                       order=None, msg: str = ""):
        from repro.txn import map as txn_map
        if order is None and result is not None:
            order = txn_map.linearization_order(result)
        ref = self.step(txns, fn, order)
        self.check(result=result, ref=ref, items=items, msg=msg)
        return ref


# ---------------------------------------------------------------------------
# Executor histories: the multi-stream interleaving as ONE linearization.
# ---------------------------------------------------------------------------

def replay_executor_history(n: int, k: int, widths: list[int], history, *,
                            initial=None, check: bool = True) -> TableOracle:
    """Replay a `runtime.Executor` issue history — S streams' batches in
    their issue interleaving, each with its claimed per-batch order —
    through ONE sequential TableOracle, and diff every delivered result.

    Each stream owns a fixed lane slice of a width-sum(widths) oracle
    (stream si's lane j is oracle lane offset(si) + j), so per-stream
    LL/SC link state persists across batches exactly as the executor's
    per-stream LinkCtx does.  Works unchanged across a recovery boundary:
    post-recovery records carry orders computed under the NEW geometry,
    and replayed (re-delivered) seqs simply appear as fresh records whose
    results must STILL match — that is the linearizability-across-the-
    fault claim being checked.

    history: iterable of `runtime.executor.IssueRec` (retired, i.e. with
    value/success filled).  Returns the oracle (final data/versions inside)
    for end-state diffs against the live target.
    """
    offs = np.concatenate([[0], np.cumsum(widths)]).astype(np.int64)
    p_all = int(offs[-1])
    oracle = TableOracle(n, k, p_all, initial=initial)
    for rec in history:
        si, off, w = rec.stream, int(offs[rec.stream]), widths[rec.stream]
        kind = np.asarray(rec.ops.kind)
        q = kind.shape[0]
        assert q <= w, f"stream {si} batch width {q} > declared {w}"
        pk = np.full(p_all, engine.IDLE, np.int32)
        ps = np.zeros(p_all, np.int32)
        pe = np.zeros((p_all, k), np.uint32)
        pd = np.zeros((p_all, k), np.uint32)
        pk[off:off + q] = kind
        ps[off:off + q] = np.asarray(rec.ops.slot)
        pe[off:off + q] = np.asarray(rec.ops.expected)
        pd[off:off + q] = np.asarray(rec.ops.desired)
        order = (np.arange(q, dtype=np.int64) if rec.order is None
                 else np.asarray(rec.order, np.int64)) + off
        ref = oracle.step(engine.OpBatch(pk, ps, pe, pd), order=order)
        if not check:
            continue
        msg = f"stream {si} seq {rec.seq}"
        np.testing.assert_array_equal(
            rec.value, ref.value[off:off + q], err_msg=f"{msg}: values")
        np.testing.assert_array_equal(
            rec.success, ref.success[off:off + q], err_msg=f"{msg}: success")
        if rec.overflow is not None:
            assert not np.asarray(rec.success)[rec.overflow].any(), \
                f"{msg}: overflow lanes must report success=False"
    return oracle


# ---------------------------------------------------------------------------
# Shared randomized batch generators (tests + the distributed suite).
# ---------------------------------------------------------------------------

def mixed_batch(rng: np.random.Generator, ref_ctx, *, p: int, n: int, k: int,
                current: np.ndarray) -> engine.OpBatch:
    """All seven table kinds in one batch; SC/VALIDATE lanes mostly target
    their live link, half the CAS comparands match the live value."""
    kind = rng.integers(0, 7, p).astype(np.int32)
    slot = rng.integers(0, n, p).astype(np.int32)
    linked = np.asarray(ref_ctx.linked)
    lslot = np.asarray(ref_ctx.slot)
    for i in range(p):
        if kind[i] in (atomics.SC, atomics.VALIDATE) and linked[i] \
                and rng.random() < 0.7:
            slot[i] = lslot[i]
    expected = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    use_cur = rng.random(p) < 0.5
    expected = np.where(use_cur[:, None], np.asarray(current)[slot], expected)
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    return atomics.make_ops(kind, slot, expected, desired, k=k)


def txn_batch(rng: np.random.Generator, *, t: int, w: int, n: int, k: int,
              current: np.ndarray, match_frac: float = 0.6):
    """Random MCAS batch: mixed widths (-1-padded lanes), distinct slots
    per txn, `match_frac` of txns expecting the CURRENT values (commit
    candidates; small n => real conflicts), the rest stale comparands."""
    slot = np.full((t, w), -1, np.int32)
    for i in range(t):
        width = int(rng.integers(1, w + 1))
        slot[i, :width] = rng.choice(n, size=min(width, n), replace=False)
    expected = rng.integers(0, 2 ** 32, (t, w, k), dtype=np.uint32)
    fresh = rng.random(t) < match_frac
    for i in range(t):
        if fresh[i]:
            for j in range(w):
                if slot[i, j] >= 0:
                    expected[i, j] = np.asarray(current)[slot[i, j]]
    desired = rng.integers(0, 2 ** 32, (t, w, k), dtype=np.uint32)
    return atomics.make_txns(slot, expected, desired, k=k)


def hash_batch(rng: np.random.Generator, *, p: int, key_space: int,
               vw: int = 1) -> engine.OpBatch:
    """Random FIND/INSERT/DELETE batch over a bounded key space."""
    kind = rng.integers(atomics.FIND, atomics.DELETE + 1, p).astype(np.int32)
    keys = rng.integers(0, key_space, p).astype(np.uint32)
    vals = rng.integers(0, 2 ** 32, (p, vw), dtype=np.uint32)
    return ch.make_hash_ops(kind, keys, vals, vw=vw)
