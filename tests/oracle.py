"""THE shared linearizability harness (ISSUE 3 satellite).

One sequential-replay oracle for every big-atomic surface: given a spec, a
stream of op batches and a CLAIMED linearization order per batch, it replays
the ops one at a time through the repo's defining references
(`engine.apply_ops_reference` for tables, `cachehash.apply_reference` for
hash tables) and diffs the live system's results, values, versions and link
state against the replay.  It replaces the three historical copies of this
logic (tests/test_llsc.py, tests/test_atomics_v2.py and the inline reorder
check in core/distributed.py's v1 `reference_apply`).

Claimed orders: single-node `atomics.apply` linearizes in lane order (the
default); the mesh-sharded layer linearizes in the (owner, src, rank) order
that `distributed.linearization_order` emits, with capacity-rejected lanes
excluded.  Lanes absent from the order are DROPPED: they must have no table
effect and report success=False.
"""

from __future__ import annotations

import numpy as np

from repro import atomics
from repro.core import cachehash as ch
from repro.core import engine


def _np_ctx(ctx) -> engine.LinkCtx:
    return engine.LinkCtx(*[np.array(x, copy=True) for x in ctx])


class TableOracle:
    """Sequential oracle for a k-word big-atomic table + per-lane links."""

    def __init__(self, n: int, k: int, p: int,
                 initial: np.ndarray | None = None):
        self.n, self.k, self.p = n, k, p
        self.data = np.zeros((n, k), np.uint32) if initial is None \
            else np.array(initial, np.uint32)
        self.version = np.zeros((n,), np.uint32)
        self.ctx = engine.LinkCtx(
            np.full((p,), -1, np.int32), np.zeros((p,), np.uint32),
            np.zeros((p, k), np.uint32), np.zeros((p,), bool))

    def step(self, ops: engine.OpBatch, order=None) -> engine.ApplyResult:
        """Replay one batch in the claimed linearization `order` (executed
        lane ids; default = lane order).  Dropped lanes (absent from the
        order) leave no trace and report success=False / zero values.
        Returns the reference ApplyResult in lane order (numpy)."""
        kind = np.asarray(ops.kind)
        slot = np.asarray(ops.slot)
        expected = np.asarray(ops.expected)
        desired = np.asarray(ops.desired)
        if kind.shape[0] != self.p:
            raise ValueError(f"batch width {kind.shape[0]} != p {self.p}")
        order = np.arange(self.p) if order is None \
            else np.asarray(order, np.int64)
        sub = engine.OpBatch(kind[order], slot[order], expected[order],
                             desired[order])
        sub_ctx = engine.LinkCtx(*[np.asarray(x)[order] for x in self.ctx])
        data, ver, nctx, res = engine.apply_ops_reference(
            self.data, self.version, sub_ctx, sub)
        self.data, self.version = data, ver
        merged = _np_ctx(self.ctx)
        for field, rows in zip(engine.LinkCtx._fields, nctx):
            getattr(merged, field)[order] = np.asarray(rows)
        self.ctx = merged
        value = np.zeros((self.p, self.k), np.uint32)
        success = np.zeros((self.p,), bool)
        value[order] = np.asarray(res.value)
        success[order] = np.asarray(res.success)
        return engine.ApplyResult(value, success)

    # -- diffing -------------------------------------------------------------

    def check(self, *, result=None, ref=None, logical=None, version=None,
              ctx=None, overflow=None, msg: str = "") -> None:
        """Diff the live system against the replayed reference.

        result/ref:  live vs reference ApplyResult (values + success);
        logical:     live global logical values (must equal replayed data);
        version:     live global cell versions;
        ctx:         live per-lane LinkCtx;
        overflow:    bool[p] mask of capacity-rejected lanes — these must
                     report success=False (the reported-not-dropped contract).
        """
        if logical is not None:
            np.testing.assert_array_equal(np.asarray(logical), self.data,
                                          err_msg=f"{msg}: logical data")
        if version is not None:
            np.testing.assert_array_equal(np.asarray(version), self.version,
                                          err_msg=f"{msg}: versions")
        if result is not None:
            assert ref is not None, "pass ref= (the value step() returned)"
            np.testing.assert_array_equal(np.asarray(result.value), ref.value,
                                          err_msg=f"{msg}: result values")
            np.testing.assert_array_equal(np.asarray(result.success),
                                          ref.success,
                                          err_msg=f"{msg}: result success")
            if overflow is not None:
                assert not np.asarray(result.success)[overflow].any(), \
                    f"{msg}: overflow lanes must report success=False"
        if ctx is not None:
            for name, live, want in zip(engine.LinkCtx._fields, ctx,
                                        self.ctx):
                np.testing.assert_array_equal(np.asarray(live),
                                              np.asarray(want),
                                              err_msg=f"{msg}: ctx.{name}")

    def step_and_check(self, ops, *, result=None, logical=None, version=None,
                       ctx=None, order=None, overflow=None, msg: str = ""):
        """step() + check() in one call; returns the reference result."""
        ref = self.step(ops, order)
        self.check(result=result, ref=ref, logical=logical, version=version,
                   ctx=ctx, overflow=overflow, msg=msg)
        return ref


class HashOracle:
    """Sequential dict-model oracle for CacheHash FIND/INSERT/DELETE."""

    def __init__(self, vw: int = 1):
        self.vw = vw
        self.model: dict = {}

    def step(self, ops: engine.OpBatch, order=None) -> ch.HashResult:
        kind = np.asarray(ops.kind)
        p = kind.shape[0]
        order = np.arange(p) if order is None else np.asarray(order, np.int64)
        sub = engine.OpBatch(
            kind[order], np.asarray(ops.slot)[order],
            np.asarray(ops.expected)[order], np.asarray(ops.desired)[order])
        self.model, res = ch.apply_reference(self.model, sub, self.vw)
        found = np.zeros((p,), bool)
        value = np.zeros((p, self.vw), np.uint32)
        found[order] = np.asarray(res.found)
        value[order] = np.asarray(res.value)
        return ch.HashResult(found, value, np.zeros((p,), bool))

    def check(self, *, result=None, ref=None, items=None, overflow=None,
              msg: str = "") -> None:
        if result is not None:
            assert ref is not None
            np.testing.assert_array_equal(np.asarray(result.found), ref.found,
                                          err_msg=f"{msg}: found")
            np.testing.assert_array_equal(np.asarray(result.value), ref.value,
                                          err_msg=f"{msg}: values")
            if overflow is not None:
                assert not np.asarray(result.found)[overflow].any(), \
                    f"{msg}: overflow lanes must report found=False"
        if items is not None:
            want = {k: list(np.ravel(v)) for k, v in self.model.items()}
            got = {k: list(np.ravel(v)) for k, v in items.items()}
            assert got == want, f"{msg}: table contents diverge"

    def step_and_check(self, ops, *, result=None, items=None, order=None,
                       overflow=None, msg: str = ""):
        ref = self.step(ops, order)
        self.check(result=result, ref=ref, items=items, overflow=overflow,
                   msg=msg)
        return ref


class TxnOracle:
    """Sequential whole-transaction oracle for k-word MCAS (ISSUE 4).

    Replays CLAIMED linearization orders of entire transactions — each one
    all-or-nothing, including aborted txns (which must leave no trace but
    still witness a consistent read of every claimed cell) — through
    `txn.mcas.mcas_reference`, and diffs the live system's success masks,
    witnesses, logical values and versions against the replay."""

    def __init__(self, n: int, k: int, initial: np.ndarray | None = None):
        self.n, self.k = n, k
        self.data = np.zeros((n, k), np.uint32) if initial is None \
            else np.array(initial, np.uint32)
        self.version = np.zeros((n,), np.uint32)

    def step(self, txns, order=None):
        """Replay one txn batch in the claimed `order` (default: txn id
        order).  Returns (success[T], witness[T, W, k]) as numpy."""
        from repro.txn import mcas as txn_mcas
        if order is None:
            order = np.arange(np.asarray(txns.slot).shape[0])
        self.data, self.version, success, witness = \
            txn_mcas.mcas_reference(self.data, self.version, txns, order)
        return success, witness

    def check(self, *, result=None, ref=None, logical=None, version=None,
              msg: str = "") -> None:
        if logical is not None:
            np.testing.assert_array_equal(np.asarray(logical), self.data,
                                          err_msg=f"{msg}: logical data")
        if version is not None:
            np.testing.assert_array_equal(np.asarray(version), self.version,
                                          err_msg=f"{msg}: versions")
        if result is not None:
            assert ref is not None, "pass ref= (the value step() returned)"
            ref_success, ref_witness = ref
            np.testing.assert_array_equal(np.asarray(result.success),
                                          ref_success,
                                          err_msg=f"{msg}: txn success")
            np.testing.assert_array_equal(np.asarray(result.witness),
                                          ref_witness,
                                          err_msg=f"{msg}: txn witness")

    def step_and_check(self, txns, *, result=None, logical=None,
                       version=None, order=None, msg: str = ""):
        """step() + check() in one call; `order` defaults to the claimed
        order the live result encodes.  Returns the reference tuple."""
        from repro.txn import mcas as txn_mcas
        if order is None and result is not None:
            order = txn_mcas.linearization_order(result)
        ref = self.step(txns, order)
        self.check(result=result, ref=ref, logical=logical, version=version,
                   msg=msg)
        return ref


class MapOracle:
    """Sequential dict-model oracle for the transactional map: replays
    whole read-set/write-set transactions in the claimed serialization."""

    def __init__(self, vw: int = 1):
        self.vw = vw
        self.model: dict = {}

    def step(self, txns, fn, order=None):
        from repro.txn import map as txn_map
        if order is None:
            order = np.arange(txns.t)
        self.model, rv, rf = txn_map.transact_reference(
            self.model, txns, fn, order, self.vw)
        return rv, rf

    def check(self, *, result=None, ref=None, items=None,
              msg: str = "") -> None:
        if result is not None:
            assert ref is not None
            rv, rf = ref
            np.testing.assert_array_equal(np.asarray(result.read_found), rf,
                                          err_msg=f"{msg}: read_found")
            np.testing.assert_array_equal(np.asarray(result.read_value), rv,
                                          err_msg=f"{msg}: read_value")
        if items is not None:
            want = {k: list(np.ravel(v)) for k, v in self.model.items()}
            got = {k: list(np.ravel(v)) for k, v in items.items()}
            assert got == want, f"{msg}: table contents diverge"

    def step_and_check(self, txns, fn, *, result=None, items=None,
                       order=None, msg: str = ""):
        from repro.txn import map as txn_map
        if order is None and result is not None:
            order = txn_map.linearization_order(result)
        ref = self.step(txns, fn, order)
        self.check(result=result, ref=ref, items=items, msg=msg)
        return ref


# ---------------------------------------------------------------------------
# Executor histories: the multi-stream interleaving as ONE linearization.
# ---------------------------------------------------------------------------

def replay_executor_history(n: int, k: int, widths: list[int], history, *,
                            initial=None, check: bool = True) -> TableOracle:
    """Replay a `runtime.Executor` issue history — S streams' batches in
    their issue interleaving, each with its claimed per-batch order —
    through ONE sequential TableOracle, and diff every delivered result.

    Each stream owns a fixed lane slice of a width-sum(widths) oracle
    (stream si's lane j is oracle lane offset(si) + j), so per-stream
    LL/SC link state persists across batches exactly as the executor's
    per-stream LinkCtx does.  Works unchanged across a recovery boundary:
    post-recovery records carry orders computed under the NEW geometry,
    and replayed (re-delivered) seqs simply appear as fresh records whose
    results must STILL match — that is the linearizability-across-the-
    fault claim being checked.

    history: iterable of `runtime.executor.IssueRec` (retired, i.e. with
    value/success filled).  Returns the oracle (final data/versions inside)
    for end-state diffs against the live target.
    """
    offs = np.concatenate([[0], np.cumsum(widths)]).astype(np.int64)
    p_all = int(offs[-1])
    oracle = TableOracle(n, k, p_all, initial=initial)
    for rec in history:
        si, off, w = rec.stream, int(offs[rec.stream]), widths[rec.stream]
        kind = np.asarray(rec.ops.kind)
        q = kind.shape[0]
        assert q <= w, f"stream {si} batch width {q} > declared {w}"
        pk = np.full(p_all, engine.IDLE, np.int32)
        ps = np.zeros(p_all, np.int32)
        pe = np.zeros((p_all, k), np.uint32)
        pd = np.zeros((p_all, k), np.uint32)
        pk[off:off + q] = kind
        ps[off:off + q] = np.asarray(rec.ops.slot)
        pe[off:off + q] = np.asarray(rec.ops.expected)
        pd[off:off + q] = np.asarray(rec.ops.desired)
        order = (np.arange(q, dtype=np.int64) if rec.order is None
                 else np.asarray(rec.order, np.int64)) + off
        ref = oracle.step(engine.OpBatch(pk, ps, pe, pd), order=order)
        if not check:
            continue
        msg = f"stream {si} seq {rec.seq}"
        np.testing.assert_array_equal(
            rec.value, ref.value[off:off + q], err_msg=f"{msg}: values")
        np.testing.assert_array_equal(
            rec.success, ref.success[off:off + q], err_msg=f"{msg}: success")
        if rec.overflow is not None:
            assert not np.asarray(rec.success)[rec.overflow].any(), \
                f"{msg}: overflow lanes must report success=False"
    return oracle


# ---------------------------------------------------------------------------
# Telemetry recount (ISSUE 9): the repro.obs counters, recomputed in numpy
# from claimed linearization orders / delivered results alone.
# ---------------------------------------------------------------------------

def _np_fast_path_ok(n: int, kind: np.ndarray, slot: np.ndarray) -> bool:
    """Numpy mirror of `kernels.engine_round.fast_path_ok`."""
    active = kind != engine.IDLE
    in_range = (slot >= 0) & (slot < n)
    all_in = not np.any(active & ~in_range)
    is_write = active & ((kind == engine.STORE) | (kind == engine.CAS)
                         | (kind == engine.SC))
    read_only = not np.any(is_write)
    cslot = np.where(active & in_range, slot, n).astype(np.int64)
    counts = np.bincount(cslot, minlength=n + 1)
    no_dup = np.max(counts[:n], initial=0) <= 1
    return bool(all_in and (read_only or no_dup))


def _np_contention_hist(n: int, kind: np.ndarray, slot: np.ndarray):
    """Numpy mirror of the telemetry contention histogram: cells bucketed by
    floor(log2(active lanes)) via the SAME integer-threshold compares as the
    in-graph version (`obs.telemetry.contention_bucket`) — bit-exact."""
    from repro.obs.telemetry import N_HIST
    active = kind != engine.IDLE
    in_range = (slot >= 0) & (slot < n)
    cslot = np.where(active & in_range, slot, n).astype(np.int64)
    c = np.bincount(cslot, minlength=n + 1)[:n]
    c = c[c > 0]
    th = 2 ** np.arange(1, N_HIST, dtype=np.int64)
    bucket = (c[:, None] >= th[None, :]).sum(axis=1)
    return np.bincount(bucket, minlength=N_HIST).astype(np.int64)


def _np_stats_sorted(n: int, kind: np.ndarray, slot: np.ndarray,
                     success: np.ndarray):
    """Numpy mirror of `engine.stats_on_sorted` on the (slot, lane)-sorted
    order, fed the DELIVERED per-lane success (within the engine contract
    `result.success` equals the internal sorted-order update success on
    every STORE/CAS/SC lane, which is the only place it is read).
    Returns (rounds, n_raced_loads, n_dirty_cells)."""
    p = kind.shape[0]
    active = kind != engine.IDLE
    aslot = np.where(active, slot, n)
    order = np.argsort(aslot, kind="stable")
    s_slot, s_kind, succ_s = aslot[order], kind[order], success[order]
    seg_start = np.ones(p, bool)
    seg_start[1:] = s_slot[1:] != s_slot[:-1]
    seg_id = np.cumsum(seg_start) - 1
    is_valcas = (s_kind == engine.STORE) | (s_kind == engine.CAS)
    is_sc = (s_kind == engine.SC) & (s_slot < n)
    is_upd = is_valcas | is_sc
    is_read = (s_kind == engine.LOAD) | (s_kind == engine.LL)
    excl_upd = np.cumsum(is_upd) - is_upd
    start_idx = np.arange(p)[seg_start][seg_id]
    upd_rank = excl_upd - excl_upd[start_idx]
    n_rounds = int(upd_rank[is_upd].max() + 1) if is_upd.any() else 0
    rounds = n_rounds if is_valcas.any() else (1 if is_sc.any() else 0)
    wrote = is_valcas | (is_sc & succ_s)
    # `engine._seg_broadcast_any` is a flipped inclusive scan: a SUFFIX-any
    # within the segment (any(flags[i:seg_last])), so a load only races a
    # write AT-OR-AFTER it in sorted order.  Mirror that exactly; for
    # `dirty` (read at seg starts only) suffix-any == whole-segment any.
    def _suffix_any(flags):
        out = np.zeros(p, bool)
        acc = False
        for i in range(p - 1, -1, -1):
            if i == p - 1 or seg_start[i + 1]:
                acc = False
            acc = acc or bool(flags[i])
            out[i] = acc
        return out

    raced = int(np.sum(is_read & _suffix_any(wrote)))
    dirty = int(np.sum(seg_start & _suffix_any(succ_s & is_upd)
                       & (s_slot < n)))
    return rounds, raced, dirty


class TelemetryOracle:
    """Recount the `repro.obs` in-graph counters from the oracle's own
    inputs: op batches, delivered results, MCAS results and distributed
    claimed orders.  `tests/test_obs.py` requires `counts()` to equal the
    matching keys of `obs.snapshot()` BIT-EXACTLY across strategies and
    engine-kernel modes — the counters are definitions, not estimates."""

    _KINDS = ("load", "store", "cas", "idle", "ll", "sc", "validate",
              "find", "insert", "delete")

    def __init__(self, n: int):
        from repro.obs.telemetry import N_HIST
        self.n = n
        self._n_hist = N_HIST
        self.c: dict[str, int] = {}

    def _add(self, name: str, v) -> None:
        self.c[name] = self.c.get(name, 0) + int(v)

    def count_table_batch(self, ops, result, *, fused: bool) -> None:
        """One `engine.apply` batch: `fused` says whether the engine ran a
        lowered kernel round (resolved BIGATOMIC_ENGINE_KERNEL != off)."""
        kind = np.asarray(ops.kind)
        slot = np.asarray(ops.slot)
        success = np.asarray(result.success)
        active = kind != engine.IDLE
        self._add("engine.batches", 1)
        for j, name in enumerate(self._KINDS):
            self._add(f"engine.ops.{name}", np.sum(kind == j))
        eligible = _np_fast_path_ok(self.n, kind, slot)
        taken = eligible and fused
        self._add("engine.fast.eligible", eligible)
        self._add("engine.fast.taken", taken)
        rounds, raced, dirty = _np_stats_sorted(self.n, kind, slot, success)
        self._add("engine.rounds.total", rounds)
        self._add("engine.rounds.slow", 0 if taken else rounds)
        self._add("engine.fail.cas",
                  np.sum(active & (kind == engine.CAS) & ~success))
        self._add("engine.fail.sc",
                  np.sum(active & (kind == engine.SC) & ~success))
        self._add("engine.loads.raced", raced)
        self._add("engine.cells.dirty", dirty)
        hist = _np_contention_hist(self.n, kind, slot)
        for b in range(self._n_hist):
            self._add(f"engine.contention.log2_{b:02d}", hist[b])

    def count_read(self, ok) -> None:
        self._add("read.torn_retries", np.sum(~np.asarray(ok)))

    def count_mcas(self, result) -> None:
        """One drained `txn.mcas` run, recounted from the McasResult alone:
        every resolved txn committed or aborted in exactly one round, and
        `attempts` journals each arbitration loss (= backoff event)."""
        success = np.asarray(result.success)
        rnd = np.asarray(result.round)
        self._add("mcas.commits", np.sum(success))
        self._add("mcas.aborts", np.sum((rnd > 0) & ~success))
        self._add("mcas.rounds", int(result.rounds))
        self._add("mcas.backoff", np.sum(np.asarray(result.attempts)))

    def count_dist_batch(self, overflow, words: int) -> None:
        """One `distributed.apply` collective round, from the claimed-order
        overflow mask (`distributed.linearization_order`) and the static
        `distributed.collective_words(dspec)`."""
        self._add("dist.route_overflow", np.sum(np.asarray(overflow)))
        self._add("dist.rounds", 1)
        self._add("dist.words", words)

    def counts(self) -> dict:
        """Every recounted metric, keyed exactly like `obs.snapshot()`."""
        return dict(self.c)


# ---------------------------------------------------------------------------
# Shared randomized batch generators (tests + the distributed suite).
# ---------------------------------------------------------------------------

def mixed_batch(rng: np.random.Generator, ref_ctx, *, p: int, n: int, k: int,
                current: np.ndarray) -> engine.OpBatch:
    """All seven table kinds in one batch; SC/VALIDATE lanes mostly target
    their live link, half the CAS comparands match the live value."""
    kind = rng.integers(0, 7, p).astype(np.int32)
    slot = rng.integers(0, n, p).astype(np.int32)
    linked = np.asarray(ref_ctx.linked)
    lslot = np.asarray(ref_ctx.slot)
    for i in range(p):
        if kind[i] in (atomics.SC, atomics.VALIDATE) and linked[i] \
                and rng.random() < 0.7:
            slot[i] = lslot[i]
    expected = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    use_cur = rng.random(p) < 0.5
    expected = np.where(use_cur[:, None], np.asarray(current)[slot], expected)
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
    return atomics.make_ops(kind, slot, expected, desired, k=k)


def txn_batch(rng: np.random.Generator, *, t: int, w: int, n: int, k: int,
              current: np.ndarray, match_frac: float = 0.6):
    """Random MCAS batch: mixed widths (-1-padded lanes), distinct slots
    per txn, `match_frac` of txns expecting the CURRENT values (commit
    candidates; small n => real conflicts), the rest stale comparands."""
    slot = np.full((t, w), -1, np.int32)
    for i in range(t):
        width = int(rng.integers(1, w + 1))
        slot[i, :width] = rng.choice(n, size=min(width, n), replace=False)
    expected = rng.integers(0, 2 ** 32, (t, w, k), dtype=np.uint32)
    fresh = rng.random(t) < match_frac
    for i in range(t):
        if fresh[i]:
            for j in range(w):
                if slot[i, j] >= 0:
                    expected[i, j] = np.asarray(current)[slot[i, j]]
    desired = rng.integers(0, 2 ** 32, (t, w, k), dtype=np.uint32)
    return atomics.make_txns(slot, expected, desired, k=k)


def hash_batch(rng: np.random.Generator, *, p: int, key_space: int,
               vw: int = 1) -> engine.OpBatch:
    """Random FIND/INSERT/DELETE batch over a bounded key space."""
    kind = rng.integers(atomics.FIND, atomics.DELETE + 1, p).astype(np.int32)
    keys = rng.integers(0, key_space, p).astype(np.uint32)
    vals = rng.integers(0, 2 ** 32, (p, vw), dtype=np.uint32)
    return ch.make_hash_ops(kind, keys, vals, vw=vw)
