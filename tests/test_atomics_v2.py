"""v2 API acceptance tests: pytree-native states, one op schema with mixed
LOAD/STORE/CAS/LL/SC/VALIDATE batches against the sequential oracle, the
strategy registry's plug-in contract, and the checked op-construction /
return_ok satellites (see ISSUE 2 / DESIGN.md §5)."""

import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from oracle import TableOracle, mixed_batch
from repro import atomics
from repro.core import cachehash as ch
from repro.sync import llsc
from repro.sync.queue import BigQueue

LOCKFREE = ["seqlock", "indirect", "cached_wf", "cached_me"]


def _np_ctx(ctx):
    return atomics.LinkCtx(*[np.asarray(x) for x in ctx])


# ---------------------------------------------------------------------------
# Acceptance: mixed-kind batches match the shared sequential oracle
# (tests/oracle.py) on every lock-free strategy, including cross-batch
# link state.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", LOCKFREE)
def test_mixed_kind_batches_match_oracle(strategy):
    # deterministic per-strategy seed (hash() is salt-randomized per process)
    rng = np.random.default_rng(zlib.crc32(strategy.encode()))
    for trial in range(3):
        n = int(rng.integers(2, 14))
        k = int(rng.integers(1, 5))
        p = int(rng.integers(1, 28))
        spec = atomics.AtomicSpec(n, k, strategy, p_max=64)
        init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
        state = atomics.init(spec, init)
        ctx = atomics.init_ctx(p, k)
        oracle = TableOracle(n, k, p, initial=init)
        for step in range(5):
            ops = mixed_batch(rng, oracle.ctx, p=p, n=n, k=k,
                              current=oracle.data)
            state, ctx, res, stats, traffic = atomics.apply(
                spec, state, ops, ctx)
            oracle.step_and_check(
                ops, result=res, logical=atomics.logical(spec, state),
                version=state.version, ctx=ctx,
                msg=f"{strategy} trial {trial} step {step}")
        vals, ok = atomics.read(spec, state, np.arange(n))
        assert bool(np.asarray(ok).all())
        np.testing.assert_array_equal(np.asarray(vals), oracle.data)


@pytest.mark.parametrize("strategy", LOCKFREE)
def test_cross_batch_aba_adversary(strategy):
    """A store A->B->A through the value path between LL and SC: the bytes
    match the link, a CAS would succeed, SC must refuse (version moved)."""
    n, k = 4, 3
    spec = atomics.AtomicSpec(n, k, strategy, p_max=16)
    init = np.arange(n * k, dtype=np.uint32).reshape(n, k)
    state = atomics.init(spec, init)
    ctx = atomics.init_ctx(1, k)
    state, ctx, res, _, _ = atomics.apply(
        spec, state, atomics.sync_ops([atomics.LL], [2], k=k), ctx)
    original = np.asarray(res.value[0])
    for payload in ((original + 1).astype(np.uint32), original):
        state, ctx, _, _, _ = atomics.apply(
            spec, state, atomics.stores([2], payload[None], k=k), ctx)
    np.testing.assert_array_equal(
        np.asarray(atomics.logical(spec, state))[2], original)
    # mixed batch: VALIDATE and SC in one call — both must fail
    ops = atomics.make_ops([atomics.VALIDATE, atomics.SC], [2, 2],
                           desired=np.stack([original, original]), k=k)
    ctx2 = atomics.LinkCtx(*[jnp.concatenate([x, x]) for x in ctx])
    state, ctx2, res, _, _ = atomics.apply(spec, state, ops, ctx2)
    assert not bool(np.asarray(res.success).any())
    np.testing.assert_array_equal(
        np.asarray(atomics.logical(spec, state))[2], original)


@pytest.mark.parametrize("strategy", LOCKFREE)
def test_cross_batch_lapped_linker_with_mixed_traffic(strategy):
    """Lane 0 sleeps on its link while later batches mix stores, CAS and
    other lanes' SCs on the same cell; its eventual SC must fail."""
    n, k, p = 4, 2, 6
    spec = atomics.AtomicSpec(n, k, strategy, p_max=64)
    state = atomics.init(spec)
    ctx = atomics.init_ctx(p, k)
    state, ctx, _, _, _ = atomics.apply(
        spec, state, atomics.sync_ops(np.full(p, atomics.LL),
                                      np.zeros(p, np.int32), k=k), ctx)
    rng = np.random.default_rng(3)
    for lane in range(1, p):
        # mixed batch: lane re-links, then commits; a STORE lane races it
        kind = np.full(p, atomics.IDLE, np.int32)
        kind[lane] = atomics.LL
        kind[(lane + 1) % p if (lane + 1) % p != 0 else 1] = atomics.LOAD
        ops = atomics.make_ops(kind, np.zeros(p, np.int32), k=k)
        state, ctx, _, _, _ = atomics.apply(spec, state, ops, ctx)
        kind = np.full(p, atomics.IDLE, np.int32)
        kind[lane] = atomics.SC
        desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)
        ops = atomics.make_ops(kind, np.zeros(p, np.int32),
                               desired=desired, k=k)
        state, ctx, res, _, _ = atomics.apply(spec, state, ops, ctx)
        assert bool(np.asarray(res.success)[lane])
    # lane 0's link predates every commit above
    ops = atomics.make_ops([atomics.SC], [0],
                           desired=np.zeros((1, k), np.uint32), k=k)
    ctx0 = atomics.LinkCtx(*[x[:1] for x in ctx])
    state, _, res, _, _ = atomics.apply(spec, state, ops, ctx0)
    assert not bool(np.asarray(res.success)[0])


def test_valcas_and_sc_interleave_same_cell():
    """CAS chains and SCs interleaved on one cell in one batch: the general
    engine path must thread versions through the rounds correctly."""
    n, k = 1, 2
    spec = atomics.AtomicSpec(n, k, "cached_me", p_max=16)
    state = atomics.init(spec)
    ctx = atomics.init_ctx(4, k)
    state, ctx, _, _, _ = atomics.apply(
        spec, state, atomics.sync_ops(np.full(4, atomics.LL),
                                      np.zeros(4, np.int32), k=k), ctx)
    # lane 0: STORE (bumps version) | lane 1: SC (stale now -> fail)
    # lane 2: CAS expecting lane 0's value (succeeds) | lane 3: LOAD
    kind = np.asarray([atomics.STORE, atomics.SC, atomics.CAS, atomics.LOAD],
                      np.int32)
    expected = np.zeros((4, k), np.uint32)
    expected[2] = 7
    desired = np.asarray([[7] * k, [9] * k, [11] * k, [0] * k], np.uint32)
    ops = atomics.make_ops(kind, np.zeros(4, np.int32), expected, desired,
                           k=k)
    oracle = TableOracle(n, k, 4,
                         initial=np.asarray(atomics.logical(spec, state)))
    oracle.version = np.asarray(state.version).copy()
    oracle.ctx = _np_ctx(ctx)
    state, ctx, res, stats, _ = atomics.apply(spec, state, ops, ctx)
    oracle.step_and_check(ops, result=res,
                          logical=atomics.logical(spec, state),
                          version=state.version, ctx=ctx)
    succ = np.asarray(res.success)
    assert succ[0] and not succ[1] and succ[2] and succ[3]
    np.testing.assert_array_equal(
        np.asarray(atomics.logical(spec, state))[0], [11] * k)
    assert int(stats.rounds) == 3          # STORE, SC, CAS serialize


# ---------------------------------------------------------------------------
# Acceptance: states are pytrees — jit round-trip and lax.scan preserve
# semantics (oracle equality).
# ---------------------------------------------------------------------------

def test_table_state_jit_and_scan_round_trip():
    rng = np.random.default_rng(0)
    n, k, p = 8, 3, 12
    spec = atomics.AtomicSpec(n, k, "cached_wf", p_max=32)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    state = atomics.init(spec, init)
    # identity jit round-trip preserves structure and leaves
    state_rt = jax.jit(lambda s: s)(state)
    assert jax.tree_util.tree_structure(state_rt) == \
        jax.tree_util.tree_structure(state)
    for a, b in zip(jax.tree_util.tree_leaves(state_rt),
                    jax.tree_util.tree_leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    ops = [atomics.OpBatch(*[jnp.asarray(f) for f in
                             mixed_batch(rng, _np_ctx(atomics.init_ctx(p, k)),
                                         p=p, n=n, k=k, current=init)])
           for _ in range(3)]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ops)

    def step(carry, op):
        st, cx = carry
        st, cx, res, _, _ = atomics.apply(spec, st, op, cx)
        return (st, cx), res.success

    (st_scan, _), _ = jax.lax.scan(step, (state_rt, atomics.init_ctx(p, k)),
                                   stacked)
    # shared oracle over the same 3 batches
    oracle = TableOracle(n, k, p, initial=init)
    for op in ops:
        oracle.step(op)
    oracle.check(logical=atomics.logical(spec, st_scan),
                 version=st_scan.version)


def test_hash_state_and_linkctx_are_pytrees():
    spec = atomics.HashSpec(8, vw=1, strategy="cached_me", p_max=32)
    hstate = ch.init_hash(spec)
    hstate_rt = jax.jit(lambda s: s)(hstate)
    ops = ch.make_hash_ops(
        np.full(4, atomics.INSERT, np.int32), np.arange(4, dtype=np.uint32),
        np.ones((4, 1), np.uint32), vw=1)
    h2, res, _ = ch.apply_hash(spec, hstate_rt, ops)
    assert bool(np.asarray(res.found).all())
    items = ch.items(h2, inline=spec.inline, vw=spec.vw)
    assert set(items) == {0, 1, 2, 3}

    ctx = atomics.init_ctx(4, 2)
    ctx_rt = jax.jit(lambda c: c)(ctx)
    assert jax.tree_util.tree_structure(ctx_rt) == \
        jax.tree_util.tree_structure(ctx)

    # the queue's ring state is a TableState pytree too
    q = BigQueue(spec=atomics.QueueSpec(4, k=2, strategy="cached_me"))
    q.state = jax.jit(lambda s: s)(q.state)
    assert q.enqueue_batch(np.asarray([5], np.uint32)).all()
    out, ok = q.dequeue_batch(1)
    assert ok.all() and int(out[0, 0]) == 5


# ---------------------------------------------------------------------------
# Acceptance: a new strategy registers from a test file, without touching
# core, and passes the oracle suite.
# ---------------------------------------------------------------------------

def test_register_strategy_plain_clone_runs_oracle_suite():
    class PlainClone(atomics.StrategyImpl):
        name = "plain_clone_v2test"

    atomics.register_strategy(PlainClone(), overwrite=True)
    try:
        rng = np.random.default_rng(11)
        n, k, p = 10, 3, 16
        spec = atomics.AtomicSpec(n, k, "plain_clone_v2test", p_max=32)
        init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
        state = atomics.init(spec, init)
        ctx = atomics.init_ctx(p, k)
        oracle = TableOracle(n, k, p, initial=init)
        for _ in range(4):
            ops = mixed_batch(rng, oracle.ctx, p=p, n=n, k=k,
                              current=oracle.data)
            state, ctx, res, _, _ = atomics.apply(spec, state, ops, ctx)
            oracle.step_and_check(
                ops, result=res, logical=atomics.logical(spec, state))
        # the registry rejects silent double-registration
        with pytest.raises(ValueError, match="already registered"):
            atomics.register_strategy(PlainClone())
        assert "plain_clone_v2test" in atomics.registered_strategies()
    finally:
        atomics.unregister_strategy("plain_clone_v2test")


def test_registered_strategy_with_non_shadow_layout():
    """The engine must linearize against `engine_view` (default: logical),
    not the raw data field — a layout that stores data obfuscated and
    derives logical values in `logical()` still gets correct semantics."""
    class Obfuscated(atomics.StrategyImpl):
        name = "obfuscated_v2test"

        def init(self, n, k, p_max, data):
            base = super().init(n, k, p_max, data)
            return base._replace(data=base.data + jnp.uint32(1))

        def logical(self, state):
            return state.data - jnp.uint32(1)

        def commit(self, state, new_data, new_version, n_updates, p):
            return state._replace(data=new_data + jnp.uint32(1),
                                  version=new_version)

        def read(self, state, slots):
            return (self.logical(state)[slots],
                    jnp.ones((slots.shape[0],), bool))

    atomics.register_strategy(Obfuscated(), overwrite=True)
    try:
        rng = np.random.default_rng(17)
        n, k, p = 6, 2, 12
        spec = atomics.AtomicSpec(n, k, "obfuscated_v2test", p_max=16)
        init = rng.integers(0, 2 ** 31, (n, k), dtype=np.uint32)
        state = atomics.init(spec, init)
        ctx = atomics.init_ctx(p, k)
        oracle = TableOracle(n, k, p, initial=init)
        for _ in range(3):
            ops = mixed_batch(rng, oracle.ctx, p=p, n=n, k=k,
                              current=oracle.data)
            state, ctx, res, _, _ = atomics.apply(spec, state, ops, ctx)
            oracle.step_and_check(
                ops, result=res, logical=atomics.logical(spec, state))
    finally:
        atomics.unregister_strategy("obfuscated_v2test")


# ---------------------------------------------------------------------------
# Satellites: checked op construction; load(..., return_ok=True).
# ---------------------------------------------------------------------------

def test_apply_enforces_kind_namespaces():
    """Hash kinds never reach the table engine (the oracle raises on them)
    and table kinds never reach the hash engine."""
    spec = atomics.AtomicSpec(4, 2, "cached_me", p_max=8)
    state = atomics.init(spec)
    with pytest.raises(ValueError, match="not table ops"):
        atomics.apply(spec, state,
                      atomics.make_ops([atomics.FIND], [0], k=2))
    hspec = atomics.HashSpec(4, vw=1, strategy="cached_me", p_max=8)
    hstate = ch.init_hash(hspec)
    with pytest.raises(ValueError, match="not hash ops"):
        ch.apply_hash(hspec, hstate,
                      atomics.make_ops([atomics.STORE], [0], k=1))


def test_make_ops_validates_and_coerces():
    with pytest.raises(ValueError, match="unknown op kinds"):
        atomics.make_ops([42], [0], k=2)
    with pytest.raises(ValueError, match="desired shape"):
        atomics.make_ops([atomics.STORE], [0],
                         desired=np.zeros((1, 3), np.uint32), k=2)
    with pytest.raises(ValueError, match="slot shape"):
        atomics.make_ops([atomics.LOAD, atomics.LOAD], [0], k=2)
    ops = atomics.make_ops([atomics.CAS], [0],
                           expected=np.ones((1, 2), np.int64),
                           desired=np.ones((1, 2), np.float64), k=2)
    assert ops.expected.dtype == jnp.uint32      # coerced
    assert ops.desired.dtype == jnp.uint32


def test_table_cas_routes_through_checked_constructor():
    from repro.core.bigatomic import BigAtomicTable
    tab = BigAtomicTable(4, 2, "cached_me", p_max=8)
    with pytest.raises(ValueError, match="desired shape"):
        tab.cas([0], np.zeros((1, 2), np.uint32), np.zeros((1, 3), np.uint32))
    res, _, _ = tab.cas([0], np.zeros((1, 2), np.uint32),
                        np.ones((1, 2), np.uint32))
    assert bool(np.asarray(res.success)[0])


def test_load_return_ok_surfaces_blocked_readers():
    from repro.core.bigatomic import BigAtomicTable, begin_update
    tab = BigAtomicTable(4, 4, "seqlock", p_max=8)
    vals, ok = tab.load([0, 1], return_ok=True)
    assert bool(np.asarray(ok).all())
    tab.state = begin_update(tab.state, 1, np.arange(4, dtype=np.uint32),
                             strategy="seqlock")
    vals, ok = tab.load([0, 1], return_ok=True)
    ok = np.asarray(ok)
    assert bool(ok[0]) and not bool(ok[1])       # torn cell surfaces
    # default form still returns bare values (v1 compatibility)
    assert tab.load([0]).shape == (1, 4)


# ---------------------------------------------------------------------------
# Deprecation shims: apply_sync survives and agrees with the unified path.
# ---------------------------------------------------------------------------

def test_apply_sync_shim_matches_unified_apply():
    n, k, p = 6, 2, 8
    spec = atomics.AtomicSpec(n, k, "indirect", p_max=32)
    rng = np.random.default_rng(9)
    init = rng.integers(0, 2 ** 32, (n, k), dtype=np.uint32)
    slots = rng.integers(0, n, p).astype(np.int32)
    desired = rng.integers(0, 2 ** 32, (p, k), dtype=np.uint32)

    state_a = atomics.init(spec, init)
    ctx_a = atomics.init_ctx(p, k)
    state_a, ctx_a, _, _, _ = atomics.apply(
        spec, state_a, atomics.sync_ops(np.full(p, atomics.LL), slots, k=k),
        ctx_a)
    state_a, ctx_a, res_a, _, _ = atomics.apply(
        spec, state_a,
        atomics.sync_ops(np.full(p, atomics.SC), slots, desired, k=k), ctx_a)

    state_b = atomics.init(spec, init)
    ctx_b = llsc.init_ctx(p, k)
    ctx_b, _ = llsc.ll(state_b, ctx_b, slots, strategy="indirect", k=k)
    state_b, ctx_b, succ_b = llsc.sc(state_b, ctx_b, slots, desired,
                                     strategy="indirect", k=k)
    np.testing.assert_array_equal(np.asarray(res_a.success),
                                  np.asarray(succ_b))
    np.testing.assert_array_equal(
        np.asarray(atomics.logical(spec, state_a)),
        np.asarray(atomics.logical(spec, state_b)))
