"""Deprecation contract (ISSUE 3 satellite): every v1 shim emits a
`DeprecationWarning` EXACTLY once per process — so tier-1 stays readable —
and keeps computing correct results.  Internal code paths (sync wrappers,
serving, queues) never route through the warning shims, so a default tier-1
run is warning-free."""

import warnings

import jax
import numpy as np
import pytest

from repro.core import bigatomic as ba
from repro.core import cachehash as ch
from repro.core import deprecation
from repro.core import distributed as dsb
from repro.core import engine
from repro.core import semantics as sem
from repro.sync import llsc


def _catch(fn):
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out = fn()
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)]
    return dep, out


def _call_apply_sync():
    state = ba.init(2, 2, "cached_me", p_max=8)
    ctx = llsc.init_ctx(2, 2)
    ops = llsc.make_sync_batch(np.full(2, llsc.LL, np.int32),
                               np.zeros(2, np.int32), k=2)
    return llsc.apply_sync(state, ctx, ops, strategy="cached_me", k=2)


def _call_apply_ops():
    state = ba.init(2, 2, "cached_me", p_max=8)
    ops = engine.loads([0, 1], k=2)
    return ba.apply_ops(state, ops, strategy="cached_me", k=2)


def _call_apply_hash_ops():
    from repro.core.specs import HashSpec
    state = ch.init_hash(HashSpec(4, vw=1, strategy="cached_me", p_max=8))
    ops = ch.make_hash_ops(np.asarray([engine.FIND], np.int32),
                           np.asarray([3], np.uint32), vw=1)
    return ch.apply_hash_ops(state, ops, strategy="cached_me", inline=True,
                             vw=1)


@pytest.mark.parametrize("name,call", [
    ("sync.llsc.apply_sync", _call_apply_sync),
    ("core.bigatomic.apply_ops", _call_apply_ops),
    ("core.cachehash.apply_hash_ops", _call_apply_hash_ops),
], ids=lambda x: x if isinstance(x, str) else "")
def test_shims_warn_exactly_once(name, call):
    deprecation.reset(name)
    first, _ = _catch(call)
    assert len(first) == 1, [str(w.message) for w in first]
    assert "deprecated" in str(first[0].message)
    second, _ = _catch(call)
    assert not second, "shim warned twice"


def test_internal_sync_wrappers_are_warning_free():
    """ll/sc/validate (and everything else repro.sync routes) go through
    atomics.apply directly — no DeprecationWarning ever."""
    state = ba.init(2, 2, "cached_me", p_max=8)
    ctx = llsc.init_ctx(1, 2)

    def drive():
        c, _ = llsc.ll(state, ctx, [0], strategy="cached_me", k=2)
        st, c, succ = llsc.sc(state, c, [0], np.ones((1, 2), np.uint32),
                              strategy="cached_me", k=2)
        llsc.validate(st, c, [0], strategy="cached_me", k=2)
        return succ

    warned, succ = _catch(drive)
    assert not warned, [str(w.message) for w in warned]
    assert bool(np.asarray(succ)[0])


def test_distributed_shims_warn_once_and_still_work():
    mesh = jax.make_mesh((1,), ("shard",))
    n, k, pl = 4, 2, 4
    deprecation.reset("core.distributed.init_sharded")
    deprecation.reset("core.distributed.make_apply")
    w_init, table = _catch(lambda: dsb.init_sharded(mesh, "shard", n, k))
    assert len(w_init) == 1
    w_make, apply_ops = _catch(lambda: dsb.make_apply(mesh, "shard", n, k,
                                                      pl))
    assert len(w_make) == 1
    again, _ = _catch(lambda: dsb.init_sharded(mesh, "shard", n, k))
    assert not again

    rng = np.random.default_rng(0)
    ops = sem.random_batch(rng, p=pl, n=n, k=k, update_frac=0.5)
    table, res, ovf = apply_ops(table, ops)
    ref_d, ref_v, ref_res, dropped = dsb.reference_apply(
        np.zeros((n, k), np.uint32), np.zeros(n, np.uint32), ops,
        n_shards=1, p_local=pl)
    assert int(ovf) == len(dropped) == 0
    np.testing.assert_array_equal(np.asarray(table.data), ref_d)
    np.testing.assert_array_equal(np.asarray(res.success), ref_res.success)
