"""Mesh-sharded big atomics v2: the route -> apply -> return collective round
must match the SHARED sequential oracle (tests/oracle.py) replaying the
claimed linearization order, over the registered lock-free strategy matrix,
shard counts {2, 4, 8}, the full mixed op schema (incl. cross-batch LL/SC
ABA and lapped-linker adversaries through the routing layer), the sharded
CacheHash, the all_to_all capacity-overflow contract, and a test-registered
plug-in strategy that never touches core/distributed.py.

Scenarios run in subprocesses (tests/dist_checks.py) with 8 fake host
devices via XLA_FLAGS; the shim/deprecation surface is covered in-process
by tests/test_deprecations.py.
"""

import os
import subprocess
import sys

import pytest

ALL_LOCKFREE = ["seqlock", "indirect", "cached_wf", "cached_me"]
# Under the CI BIGATOMIC_STRATEGY matrix each job runs only its own
# strategy (the other three run in sibling jobs); unset -> the full matrix.
_ENV = os.environ.get("BIGATOMIC_STRATEGY")
LOCKFREE = [_ENV] if _ENV in ALL_LOCKFREE else ALL_LOCKFREE

_HERE = os.path.dirname(os.path.abspath(__file__))
_DRIVER = os.path.join(_HERE, "dist_checks.py")


def _run(scenario: str, strategy: str | None = None, timeout: int = 900):
    cmd = [sys.executable, _DRIVER, scenario] + \
        ([strategy] if strategy else [])
    env = dict(os.environ,
               PYTHONPATH=os.path.join(_HERE, "..", "src"))
    r = subprocess.run(cmd, env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert f"DIST_OK:{scenario}" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-4000:]


@pytest.mark.parametrize("strategy", LOCKFREE)
def test_mixed_kind_batches_match_oracle_sharded(strategy):
    """Random mixed LOAD/STORE/CAS/LL/SC/VALIDATE batches, shards {2,4,8}."""
    _run("mixed", strategy)


def test_routing_levers_preserve_semantics():
    """dedup_loads × interleave × route_capacity all replay against the
    shared oracle (semantics never change, only wire cost)."""
    _run("levers")


def test_llsc_adversaries_through_routing():
    """Cross-batch ABA (remote byte restore) + lapped linker, sharded."""
    _run("sync_adversary")


def test_all_to_all_overflow_contract():
    """Capacity-rejected lanes: reported in the overflow mask with
    success=False, never silently dropped, never corrupting any shard."""
    _run("overflow")


def test_plugin_strategy_runs_sharded():
    """A strategy registered from the test process runs sharded without
    editing core/distributed.py (ISSUE 3 acceptance)."""
    _run("plugin")


def test_sharded_cachehash_matches_oracle():
    """Key-owner-routed FIND/INSERT/DELETE vs the dict-model oracle,
    shards {2,4,8}, plus the hot-key capacity contract."""
    _run("hash")


def test_serving_engine_on_sharded_table():
    """Sharded page table + sharded admission/slot rings: token-identical
    to the single-device engine, still one dispatch per decode step."""
    _run("serving")


@pytest.mark.parametrize("strategy", LOCKFREE)
def test_cross_shard_mcas_matches_txn_oracle(strategy):
    """Two-round prepare/commit MCAS vs the whole-transaction oracle,
    shards {2,4,8}, incl. an all-shards-spanning abort/commit pair."""
    _run("mcas", strategy)


def test_transactional_map_sharded():
    """Read/write sets spanning shards commit serializably; the counter
    conflict storm serializes one commit per round."""
    _run("txnmap")


def test_txn_plugin_strategy_runs_sharded():
    """A test-registered strategy runs cross-shard MCAS + the sharded map
    without touching core (ISSUE 4 acceptance)."""
    _run("txn_plugin")


def test_two_level_routing_matches_oracle():
    """Hierarchical intra-node combine + one cross-node all_to_all replays
    against the shared oracle (interleave × capacity variants)."""
    _run("twolevel")


@pytest.mark.parametrize("strategy", [s for s in ("seqlock", "cached_wf")
                                      if s in LOCKFREE])
def test_oversubscribed_executor_recovers_from_shard_loss(strategy):
    """Streams {2,4,8} × injected mid-round shard loss: checkpoint-restore,
    reshard onto survivors, journal replay — the whole interleaving
    (across the recovery boundary) replays through one sequential oracle
    (ISSUE 7 acceptance)."""
    _run("executor", strategy)


def test_elastic_reshard_round_trips():
    """Table 8->6->4->8 preserving values+versions (LL link survives);
    training state through the same chain bit-identically, with dropped
    devices reported by mesh_plan."""
    _run("elastic")
