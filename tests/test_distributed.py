"""Mesh-sharded big-atomic table: the distributed apply (all_to_all routing +
local linearization) must match the sequential oracle in the distributed
linearization order.  Runs in a subprocess with 8 placeholder devices."""

import os
import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np, jax.numpy as jnp
    from repro.core import distributed as dsb
    from repro.core import semantics as sem

    mesh = jax.make_mesh((4, 2), ("shard", "rest"))
    n, k, n_shards, p_local = 64, 4, 4, 16
    rng = np.random.default_rng({seed})
    init = rng.integers(0, 2**32, (n, k), dtype=np.uint32)
    table = dsb.init_sharded(mesh, "shard", n, k, initial=init)
    apply_ops = dsb.make_apply(mesh, "shard", n, k, p_local)

    ref_data = init.copy()
    ref_ver = np.zeros(n, np.uint32)
    for step in range({steps}):
        ops = sem.random_batch(rng, p=n_shards * p_local, n=n, k=k,
                               update_frac=0.6, current=ref_data)
        table, res, overflow = apply_ops(table, ops)
        ref_data, ref_ver, ref_res, dropped = dsb.reference_apply(
            ref_data, ref_ver, ops, n_shards=n_shards, p_local=p_local)
        assert int(overflow) == len(dropped), (int(overflow), len(dropped))
        np.testing.assert_array_equal(np.asarray(table.data), ref_data)
        np.testing.assert_array_equal(np.asarray(table.version), ref_ver)
        live = ~np.isin(np.arange(ops.kind.shape[0]), dropped)
        live &= np.asarray(ops.kind) != sem.IDLE
        np.testing.assert_array_equal(np.asarray(res.success)[live],
                                      np.asarray(ref_res.success)[live])
        np.testing.assert_array_equal(np.asarray(res.value)[live],
                                      np.asarray(ref_res.value)[live])
    print("DIST_OK")
""")


def _run(seed, steps=4):
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT.format(seed=seed, steps=steps)],
        env=env, capture_output=True, text=True, timeout=900)
    assert "DIST_OK" in r.stdout, r.stdout + r.stderr[-3000:]


def test_distributed_table_matches_oracle():
    _run(seed=0)


def test_distributed_table_matches_oracle_seed1():
    _run(seed=1, steps=3)
