"""Unit tests for the HLO cost analyzer — the §Roofline numbers stand on
this module, so its core behaviors are pinned here against a program with
hand-computable costs (and against XLA's own body-once undercount)."""

import os
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.hlo import (_group_size, _shape_bytes, _shape_dims,
                                analyze_hlo, roofline_terms, HloCost,
                                TPU_V5E)


def test_shape_parsing():
    assert _shape_bytes("f32[64,256]{1,0}") == 64 * 256 * 4
    assert _shape_bytes("bf16[8,128]") == 8 * 128 * 2
    assert _shape_bytes("(s32[], f32[4,4]{1,0})") == 4 + 64
    assert _shape_bytes("pred[7]") == 7
    assert _shape_dims("f32[2,3,4]{2,1,0}") == [2, 3, 4]
    assert _shape_bytes("token[]") == 0


def test_replica_group_parsing():
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert _group_size("replica_groups=[2,4]<=[8]") == 4
    assert _group_size("replica_groups=[4,2]<=[2,4]T(1,0)") == 2
    assert _group_size("") == 1


def test_roofline_terms_math():
    c = HloCost(flops=197e12, bytes_hbm=819e9, coll_bytes=25e9)
    rl = roofline_terms(c, TPU_V5E, model_flops_per_device=197e12 / 2)
    assert abs(rl["compute_s"] - 1.0) < 1e-9
    assert abs(rl["memory_s"] - 1.0) < 1e-9
    assert abs(rl["collective_s"] - 0.5) < 1e-9
    assert rl["bottleneck"] in ("compute", "memory")
    assert abs(rl["useful_flops_ratio"] - 0.5) < 1e-9
    assert abs(rl["mfu_bound"] - 0.5) < 1e-9


PROBE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from jax import lax
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.analysis import analyze_hlo

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    def f(x, ws):
        def body(c, w):
            y = c @ w
            y = lax.with_sharding_constraint(
                y, NamedSharding(mesh, P("data", None)))
            return jnp.tanh(y) @ w.T, None
        y, _ = lax.scan(body, x, ws)
        return y
    ws = jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)
    sx = NamedSharding(mesh, P("data", None))
    sw = NamedSharding(mesh, P(None, None, "model"))
    co = jax.jit(f, in_shardings=(sx, sw),
                 out_shardings=sx).lower(x, ws).compile()
    c = analyze_hlo(co.as_text())
    # hand-computed per-device: 12 trips x (dot1: 2*64*256*256 over the
    # gathered w + dot2: 2*64*256*64) ; AG out [256,256]f32 x 3/4 ; AR in
    # [64,256]f32 x 2 x 3/4
    assert c.flops == 12 * (2*64*256*256 + 2*64*256*64), c.flops
    assert c.coll_bytes == 12 * (262144 * 3/4 + 2 * 65536 * 3/4), c.coll_bytes
    assert c.unknown_trip_whiles == 0
    assert set(c.coll_by_kind) == {"all-gather", "all-reduce"}
    # XLA's own cost_analysis counts the body ONCE (the undercount this
    # module exists to fix); returns [dict] on some jax versions
    ca = co.cost_analysis()
    xla = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
    assert xla < c.flops / 6, (xla, c.flops)
    print("ANALYSIS_OK")
""")


def test_analyzer_trip_counts_and_collectives_subprocess():
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    r = subprocess.run([sys.executable, "-c", PROBE], env=env,
                       capture_output=True, text=True, timeout=600)
    assert "ANALYSIS_OK" in r.stdout, r.stdout + r.stderr[-2000:]


def test_model_flops_sanity():
    from repro.analysis.model_flops import model_flops
    from repro.configs import get_config
    from repro.configs.shapes import SHAPES

    cfg = get_config("deepseek_7b")
    mf_train = model_flops(cfg, SHAPES["train_4k"])
    n = cfg.n_active_params()
    tokens = 256 * 4096
    assert mf_train >= 6 * n * tokens                 # 6ND floor
    assert mf_train < 6 * n * tokens * 1.6            # attention adds < 60%
    mf_dec = model_flops(cfg, SHAPES["decode_32k"])
    assert mf_dec < mf_train / 1000                   # one token vs 4k

    moe = get_config("mixtral_8x7b")
    assert moe.n_active_params() < 0.35 * moe.n_params()   # top-2 of 8
