"""Serving engine tests: the paged (CacheHash page-table) decode path must be
token-identical to the dense slot-cache path, and page lifecycle must recycle
physical pages through the big-atomic table."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models.transformer import init_params
from repro.serving import Request, ServingEngine
from repro.serving import paged_kv as pk


def _cfg():
    cfg = get_config("deepseek_7b", reduced=True)
    return dataclasses.replace(cfg, param_dtype="float32",
                               compute_dtype="float32")


def _dense_greedy(cfg, params, prompt, n_new):
    T = len(prompt)
    prefill = make_prefill_step(cfg, max_len=T + n_new)
    serve = jax.jit(make_serve_step(cfg))
    logits, cache = prefill(params, {"tokens": jnp.asarray(prompt[None])})
    toks = [int(jnp.argmax(logits[0, -1]))]
    for d in range(n_new - 1):
        batch = {"tokens": jnp.asarray([[toks[-1]]], jnp.int32),
                 "pos": jnp.asarray([T + d], jnp.int32)}
        logits, cache = serve(params, cache, batch)
        toks.append(int(jnp.argmax(logits[0, 0])))
    return toks


def test_paged_engine_matches_dense_path():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 20).astype(np.int32)
    n_new = 6
    want = _dense_greedy(cfg, params, prompt, n_new)

    eng = ServingEngine(cfg, params, max_batch=2, n_pages=32, page_size=8,
                        max_pages_per_seq=8)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=n_new))
    got = eng.run_to_completion()[0]
    assert got == want, (got, want)


def test_two_concurrent_requests_and_retirement():
    """Two sequences share the page pool; one finishes early and its pages
    recycle while the other keeps decoding (readers never blocked)."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    p1 = rng.integers(0, cfg.vocab, 12).astype(np.int32)
    p2 = rng.integers(0, cfg.vocab, 17).astype(np.int32)
    w1 = _dense_greedy(cfg, params, p1, 3)
    w2 = _dense_greedy(cfg, params, p2, 8)

    eng = ServingEngine(cfg, params, max_batch=2, n_pages=24, page_size=8,
                        max_pages_per_seq=8)
    free0 = len(eng.paged.free)
    eng.submit(Request(rid=1, prompt=p1, max_new_tokens=3))
    eng.submit(Request(rid=2, prompt=p2, max_new_tokens=8))
    out = eng.run_to_completion()
    assert out[1] == w1, (out[1], w1)
    assert out[2] == w2, (out[2], w2)
    assert len(eng.paged.free) == free0          # all pages recycled


def test_page_pool_exhaustion_raises():
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=1, n_pages=2, page_size=8,
                        max_pages_per_seq=4)
    prompt = np.zeros(40, np.int32)             # needs 5 pages > 2
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    with pytest.raises(RuntimeError, match="out of KV pages"):
        eng.step()


def test_page_table_lookup_consistency():
    cfg = _cfg()
    paged = pk.init_paged(cfg, n_pages=16, page_size=4, max_seqs=4)
    paged, phys = pk.alloc_pages(paged, [7, 7, 9], [0, 1, 0])
    paged, got = pk.lookup_pages(paged, [7, 9], 3)
    got = np.asarray(got)
    np.testing.assert_array_equal(got[0, :2], np.asarray(phys[:2]))
    assert got[0, 2] == -1                       # unmapped
    assert got[1, 0] == int(phys[2])
    paged = pk.free_pages(paged, 7, 2)
    paged, got = pk.lookup_pages(paged, [7], 2)
    assert (np.asarray(got) == -1).all()


def test_txn_bookkeeping_keeps_one_dispatch_and_tokens():
    """ISSUE 4 acceptance: with the transactional bookkeeping path enabled
    (the default), each decode step is still exactly ONE jitted dispatch,
    tokens are identical to the legacy alloc/free path, and retirement
    still recycles every page through the one-transaction commit."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, 11).astype(np.int32),
               rng.integers(0, cfg.vocab, 6).astype(np.int32)]

    def serve(txn: bool):
        eng = ServingEngine(cfg, params, max_batch=2, n_pages=24,
                            page_size=4, max_pages_per_seq=8,
                            txn_bookkeeping=txn)
        assert eng.txn_bookkeeping is txn
        free0 = len(eng.paged.free)
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=5))
        out = eng.run_to_completion()
        # both slots decode together for 4 fused steps, 1 dispatch each
        assert eng.dispatch_count == 4, eng.dispatch_count
        assert len(eng.paged.free) == free0        # all pages recycled
        assert not eng._pending_retire             # txn committed them
        return out

    assert serve(True) == serve(False)


def test_txn_bookkeeping_frees_pages_before_admission():
    """Regression: deferred retirement deletes must commit BEFORE a queued
    request's prefill allocates, or a tight page pool spuriously exhausts
    (pages sat in _pending_retire while admission asked for them)."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(3))
    rng = np.random.default_rng(3)
    eng = ServingEngine(cfg, params, max_batch=1, n_pages=4, page_size=4,
                        max_pages_per_seq=4)
    free0 = len(eng.paged.free)
    for rid in range(2):                     # rid 1 queues behind rid 0
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab, 11)
                           .astype(np.int32), max_new_tokens=2))
    out = eng.run_to_completion()
    assert len(out[0]) == 2 and len(out[1]) == 2
    assert len(eng.paged.free) == free0


def test_failed_admission_leaks_nothing():
    """A prefill that dies (page exhaustion) must hand its decode slot and
    every not-yet-admitted request back to the big-atomic rings."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, params, max_batch=2, n_pages=2, page_size=8,
                        max_pages_per_seq=4)
    eng.submit(Request(rid=0, prompt=np.zeros(40, np.int32),
                       max_new_tokens=2))          # needs 5 pages > 2
    eng.submit(Request(rid=1, prompt=np.zeros(4, np.int32),
                       max_new_tokens=2))
    with pytest.raises(RuntimeError, match="out of KV pages"):
        eng.step()
    assert len(eng.slot_q) == 2                    # no decode slot leaked
    assert len(eng.admit_q) == 1                   # rid 1 back in the queue
    out = eng.run_to_completion()
    assert len(out[1]) == 2                        # survivor still serves


def test_pipelined_engine_matches_run_to_completion():
    """ISSUE 7 acceptance (serving satellite): the executor-driven
    decoupled loop — admission prefill compute overlapping the in-flight
    decode dispatch, page-table commits deferred to retire time — yields
    tokens identical to the sequential loop, with the same number of
    fused dispatches and no leaked pages."""
    cfg = _cfg()
    params = init_params(cfg, jax.random.PRNGKey(4))
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab, t).astype(np.int32)
               for t in (13, 7, 5)]

    def fresh():
        eng = ServingEngine(cfg, params, max_batch=2, n_pages=24,
                            page_size=4, max_pages_per_seq=8)
        for rid, p in enumerate(prompts):
            eng.submit(Request(rid=rid, prompt=p, max_new_tokens=4 + rid))
        return eng

    a = fresh()
    want = a.run_to_completion()
    b = fresh()
    free0 = len(b.paged.free)
    got = b.run_pipelined()
    assert got == want, (got, want)
    # decoupling may cost at most one extra fused step per admission wave
    # (a decode launches while the admission is still in flight, so the
    # admitted slot joins one step later); never more, never fewer ops.
    assert a.dispatch_count <= b.dispatch_count <= a.dispatch_count + 2, \
        (b.dispatch_count, a.dispatch_count)
    assert len(b.paged.free) == free0              # all pages recycled
    assert not b._pending_retire
